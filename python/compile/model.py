"""L2: the Llama-GQA model in JAX, calling the L1 Pallas kernels.

Build-time only — `aot.py` lowers `prefill_fn` / `decode_fn` to HLO text
once; the Rust engine executes the result. The parameter list is FLAT and
ordered exactly like `ModelWeights::flat_params()` on the Rust side:

    embed,
    per layer: wq, wk, wv, wo, w_gate, w_up, w_down, rms_attn, rms_mlp,
    final_norm, lm_head

Calling conventions (shared with rust/src/runtime/xla_backend.rs):

* prefill(params…, tokens i32[S]) →
    (logits f32[S, V], ks f32[L, S, KVD], vs f32[L, S, KVD])
* decode(params…, tokens i32[B], ctx_lens i32[B],
         block_tables i32[B, MBS],
         k_cache f32[L, NB, BS, KVH, HD], v_cache …) →
    (logits f32[B, V], k_new f32[L, B, KVD], v_new f32[L, B, KVD])
"""

from dataclasses import dataclass

import jax.numpy as jnp

from .kernels.gqa_prefill import gqa_prefill_attention
from .kernels.paged_attention import paged_decode_attention


@dataclass(frozen=True)
class ModelConfig:
    """Mirror of rust model::config::ModelConfig (shape fields only)."""

    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    max_seq: int
    alibi: bool
    rms_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim


PRESETS = {
    "tiny": ModelConfig(384, 64, 2, 4, 2, 128, 256, True),
    "tiny-mha": ModelConfig(384, 64, 2, 4, 4, 128, 256, False),
    "small": ModelConfig(384, 256, 6, 8, 2, 768, 1024, True),
    "mini": ModelConfig(384, 768, 12, 12, 4, 3072, 2048, True),
}

PARAMS_PER_LAYER = 9  # wq wk wv wo w_gate w_up w_down rms_attn rms_mlp


def num_params(cfg: ModelConfig) -> int:
    """Flat-parameter count (embed + layers + final_norm + lm_head)."""
    return 1 + PARAMS_PER_LAYER * cfg.n_layers + 2


def param_shapes(cfg: ModelConfig):
    """Shapes in flat order — used by aot.py to build ShapeDtypeStructs."""
    d, kv, ff, v = cfg.d_model, cfg.kv_dim, cfg.d_ff, cfg.vocab
    shapes = [("embed", (v, d))]
    for i in range(cfg.n_layers):
        shapes += [
            (f"layer{i}.wq", (d, d)),
            (f"layer{i}.wk", (kv, d)),
            (f"layer{i}.wv", (kv, d)),
            (f"layer{i}.wo", (d, d)),
            (f"layer{i}.w_gate", (ff, d)),
            (f"layer{i}.w_up", (ff, d)),
            (f"layer{i}.w_down", (d, ff)),
            (f"layer{i}.rms_attn", (d,)),
            (f"layer{i}.rms_mlp", (d,)),
        ]
    shapes += [("final_norm", (d,)), ("lm_head", (v, d))]
    return shapes


def _split_params(cfg: ModelConfig, params):
    assert len(params) == num_params(cfg), (len(params), num_params(cfg))
    embed = params[0]
    layers = []
    for i in range(cfg.n_layers):
        base = 1 + i * PARAMS_PER_LAYER
        layers.append(params[base : base + PARAMS_PER_LAYER])
    final_norm = params[-2]
    lm_head = params[-1]
    return embed, layers, final_norm, lm_head


def _rmsnorm(x, w, eps):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jnp.reciprocal(jnp.sqrt(ms + eps)) * w


def _mlp(x, w_gate, w_up, w_down):
    g = x @ w_gate.T
    u = x @ w_up.T
    return (g * jnp.reciprocal(1.0 + jnp.exp(-g)) * u) @ w_down.T


def prefill_fn(cfg: ModelConfig, params, tokens):
    """Dense prefill over `tokens` (i32[S]); see module docstring."""
    embed, layers, final_norm, lm_head = _split_params(cfg, params)
    s = tokens.shape[0]
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    x = jnp.take(embed, tokens, axis=0)  # [S, d]
    ks, vs = [], []
    for wq, wk, wv, wo, w_gate, w_up, w_down, rms_attn, rms_mlp in layers:
        xn = _rmsnorm(x, rms_attn, cfg.rms_eps)
        q = (xn @ wq.T).reshape(s, h, hd)
        k = (xn @ wk.T).reshape(s, kvh, hd)
        v = (xn @ wv.T).reshape(s, kvh, hd)
        ks.append(k.reshape(s, cfg.kv_dim))
        vs.append(v.reshape(s, cfg.kv_dim))
        attn = gqa_prefill_attention(q, k, v, alibi=cfg.alibi)  # L1 kernel
        x = x + attn.reshape(s, cfg.d_model) @ wo.T
        x = x + _mlp(_rmsnorm(x, rms_mlp, cfg.rms_eps), w_gate, w_up, w_down)
    logits = _rmsnorm(x, final_norm, cfg.rms_eps) @ lm_head.T  # [S, V]
    return logits, jnp.stack(ks), jnp.stack(vs)


def decode_fn(cfg: ModelConfig, params, tokens, ctx_lens, block_tables, k_cache, v_cache):
    """Batched paged decode step; see module docstring."""
    embed, layers, final_norm, lm_head = _split_params(cfg, params)
    b = tokens.shape[0]
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    x = jnp.take(embed, tokens, axis=0)  # [B, d]
    k_new, v_new = [], []
    for li, (wq, wk, wv, wo, w_gate, w_up, w_down, rms_attn, rms_mlp) in enumerate(layers):
        xn = _rmsnorm(x, rms_attn, cfg.rms_eps)
        q = (xn @ wq.T).reshape(b, h, hd)
        k_cur = (xn @ wk.T).reshape(b, kvh, hd)
        v_cur = (xn @ wv.T).reshape(b, kvh, hd)
        k_new.append(k_cur.reshape(b, cfg.kv_dim))
        v_new.append(v_cur.reshape(b, cfg.kv_dim))
        attn = paged_decode_attention(  # L1 kernel
            q, k_cache[li], v_cache[li], block_tables, ctx_lens, k_cur, v_cur, alibi=cfg.alibi
        )
        x = x + attn.reshape(b, cfg.d_model) @ wo.T
        x = x + _mlp(_rmsnorm(x, rms_mlp, cfg.rms_eps), w_gate, w_up, w_down)
    logits = _rmsnorm(x, final_norm, cfg.rms_eps) @ lm_head.T  # [B, V]
    return logits, jnp.stack(k_new), jnp.stack(v_new)
