"""AOT lowering: JAX model → HLO text artifacts + manifest.

Usage (from `make artifacts`):

    cd python && python -m compile.aot --out ../artifacts [--model tiny]

Emits, for the chosen preset:

* `prefill_s{S}.hlo.txt` for each prefill sequence bucket,
* `decode_b{B}.hlo.txt` for each decode batch bucket,
* `gptq_matmul.hlo.txt` — the packed dequant-matmul kernel as a
  standalone executable (cross-language packing-format check),
* `manifest.json` — geometry + entry index (see rust runtime/artifacts.rs).

HLO **text** is the interchange format, not serialized protos: jax ≥ 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.gptq_matmul import gptq_matmul
from .model import PRESETS, decode_fn, param_shapes, prefill_fn

# Bucket grids per preset: (prefill seq buckets, decode batch buckets).
BUCKETS = {
    "tiny": ([16, 64], [1, 2, 4]),
    "tiny-mha": ([16, 64], [1, 2, 4]),
    "small": ([32, 128], [1, 4]),
    "mini": ([32, 128], [1, 4, 8]),
}

# Paged-cache geometry baked into the decode artifacts.
GEOMETRY = {
    "tiny": dict(num_blocks=64, block_size=16),
    "tiny-mha": dict(num_blocks=64, block_size=16),
    "small": dict(num_blocks=128, block_size=16),
    "mini": dict(num_blocks=256, block_size=16),
}

# GPTQ aux-kernel example shape (rows, cols, group_size, pack_bits, n).
GPTQ_SHAPE = dict(rows=64, cols=64, group_size=32, pack_bits=4, n=4)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_prefill(cfg, seq: int) -> str:
    params = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in param_shapes(cfg)]
    tokens = jax.ShapeDtypeStruct((seq,), jnp.int32)
    fn = functools.partial(prefill_fn, cfg)
    return to_hlo_text(jax.jit(lambda *a: fn(list(a[:-1]), a[-1])).lower(*params, tokens))


def lower_decode(cfg, batch: int, num_blocks: int, block_size: int, max_blocks_per_seq: int) -> str:
    params = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in param_shapes(cfg)]
    np_ = len(params)
    tokens = jax.ShapeDtypeStruct((batch,), jnp.int32)
    ctx_lens = jax.ShapeDtypeStruct((batch,), jnp.int32)
    tables = jax.ShapeDtypeStruct((batch, max_blocks_per_seq), jnp.int32)
    cache = jax.ShapeDtypeStruct(
        (cfg.n_layers, num_blocks, block_size, cfg.n_kv_heads, cfg.head_dim), jnp.float32
    )
    fn = functools.partial(decode_fn, cfg)

    def wrapper(*a):
        return fn(list(a[:np_]), a[np_], a[np_ + 1], a[np_ + 2], a[np_ + 3], a[np_ + 4])

    return to_hlo_text(jax.jit(wrapper).lower(*params, tokens, ctx_lens, tables, cache, cache))


def lower_gptq_matmul() -> str:
    s = GPTQ_SHAPE
    lpw = 32 // s["pack_bits"]
    words_per_row = -(-s["cols"] // lpw)
    groups = -(-s["cols"] // s["group_size"])
    x = jax.ShapeDtypeStruct((s["n"], s["cols"]), jnp.float32)
    words = jax.ShapeDtypeStruct((s["rows"], words_per_row), jnp.int32)
    scales = jax.ShapeDtypeStruct((s["rows"], groups), jnp.float32)
    zeros = jax.ShapeDtypeStruct((s["rows"], groups), jnp.int32)
    fn = functools.partial(
        gptq_matmul, cols=s["cols"], pack_bits=s["pack_bits"], group_size=s["group_size"]
    )
    return to_hlo_text(jax.jit(lambda *a: (fn(*a),)).lower(x, words, scales, zeros))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--model", default="tiny", choices=sorted(PRESETS))
    args = ap.parse_args()
    cfg = PRESETS[args.model]
    prefill_buckets, decode_buckets = BUCKETS[args.model]
    geom = GEOMETRY[args.model]
    max_blocks_per_seq = cfg.max_seq // geom["block_size"]
    os.makedirs(args.out, exist_ok=True)

    entries = []
    for s in prefill_buckets:
        path = f"prefill_s{s}.hlo.txt"
        text = lower_prefill(cfg, s)
        with open(os.path.join(args.out, path), "w") as f:
            f.write(text)
        entries.append({"kind": "prefill", "batch": 1, "seq": s, "path": path})
        print(f"wrote {path} ({len(text)} chars)")
    for b in decode_buckets:
        path = f"decode_b{b}.hlo.txt"
        text = lower_decode(cfg, b, geom["num_blocks"], geom["block_size"], max_blocks_per_seq)
        with open(os.path.join(args.out, path), "w") as f:
            f.write(text)
        entries.append({"kind": "decode", "batch": b, "seq": 0, "path": path})
        print(f"wrote {path} ({len(text)} chars)")

    gptq_path = "gptq_matmul.hlo.txt"
    text = lower_gptq_matmul()
    with open(os.path.join(args.out, gptq_path), "w") as f:
        f.write(text)
    print(f"wrote {gptq_path} ({len(text)} chars)")

    manifest = {
        "model": args.model,
        "config": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "n_kv_heads": cfg.n_kv_heads,
            "d_ff": cfg.d_ff,
            "max_seq": cfg.max_seq,
            "alibi": cfg.alibi,
            "rms_eps": cfg.rms_eps,
        },
        "num_blocks": geom["num_blocks"],
        "block_size": geom["block_size"],
        "max_blocks_per_seq": max_blocks_per_seq,
        "entries": entries,
        "aux": {"gptq_matmul": {"path": gptq_path, **GPTQ_SHAPE}},
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json ({len(entries)} entries)")


if __name__ == "__main__":
    main()
