"""L1 analytic performance model: VMEM footprint + MXU utilization.

Pallas runs under ``interpret=True`` here (CPU PJRT cannot execute Mosaic
custom-calls), so real-TPU performance is *estimated* structurally from
the kernel's block schedule rather than measured — exactly the analysis a
kernel author does before committing a BlockSpec layout. EXPERIMENTS.md
§Perf quotes these numbers.

Model (TPU v4-ish single core):
* VMEM budget ~16 MiB per core; a grid step must fit its blocks.
* MXU: 128×128 systolic matmul; utilization of a (M, K)·(K, N)
  contraction ≈ how well the operand dims fill 128-lanes.
* HBM bandwidth dominates decode attention (small FLOP/byte), so the
  figure of merit is bytes moved per grid step — where GQA's G× sharing
  shows up directly.
"""

from dataclasses import dataclass

VMEM_BYTES = 16 * 1024 * 1024
MXU_DIM = 128


@dataclass(frozen=True)
class KernelEstimate:
    name: str
    vmem_bytes_per_step: int
    hbm_bytes_per_step: int
    flops_per_step: int
    mxu_utilization: float  # 0..1, lane-fill of the dominant contraction

    @property
    def fits_vmem(self) -> bool:
        return self.vmem_bytes_per_step <= VMEM_BYTES

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops_per_step / max(self.hbm_bytes_per_step, 1)


def _lane_fill(dim: int) -> float:
    """Fraction of MXU lanes a dimension of size `dim` keeps busy."""
    if dim >= MXU_DIM:
        return 1.0
    return dim / MXU_DIM


def paged_decode_estimate(
    *, kvh: int, g: int, hd: int, block_size: int, blocks_per_seq: int, f32: bool = True
) -> KernelEstimate:
    """One grid step of the paged GQA decode kernel = one sequence.

    Per KV block staged HBM→VMEM once and consumed by all G heads of each
    group: the paper's sharing means HBM traffic is `kv_bytes / G` of the
    MHA equivalent (which would stage per query head).
    """
    el = 4 if f32 else 2
    kv_block = block_size * kvh * hd * el  # one K (or V) block
    q_bytes = kvh * g * hd * el
    acc_bytes = kvh * g * hd * el + 2 * kvh * g * el  # acc + m + l
    vmem = 2 * kv_block + q_bytes + acc_bytes  # K-block + V-block resident
    hbm = blocks_per_seq * 2 * kv_block + q_bytes + kvh * g * hd * el
    # scores: (G, hd)·(hd, BS) per kv head, twice (QK^T and PV).
    flops = blocks_per_seq * kvh * (2 * g * hd * block_size) * 2
    # Dominant contraction dims: G rows × hd contraction × BS cols.
    mxu = _lane_fill(g * hd) * _lane_fill(block_size)
    return KernelEstimate("paged_decode", vmem, hbm, flops, mxu)


def mha_decode_estimate(*, h: int, hd: int, block_size: int, blocks_per_seq: int) -> KernelEstimate:
    """The MHA baseline: every query head stages its own K/V head."""
    return paged_decode_estimate(kvh=h, g=1, hd=hd, block_size=block_size, blocks_per_seq=blocks_per_seq)


def gqa_prefill_estimate(*, kvh: int, g: int, s: int, hd: int) -> KernelEstimate:
    el = 4
    q_bytes = g * s * hd * el
    kv_bytes = 2 * s * hd * el  # this kv head's K and V
    scores = g * s * s * el
    vmem = q_bytes + kv_bytes + scores + g * s * hd * el
    hbm = q_bytes + kv_bytes + g * s * hd * el
    flops = 2 * g * s * s * hd * 2
    mxu = _lane_fill(g * s) * _lane_fill(hd)
    return KernelEstimate("gqa_prefill", vmem, hbm, flops, mxu)


def gptq_matmul_estimate(*, n: int, rows: int, cols: int, pack_bits: int, tile: int) -> KernelEstimate:
    words_per_row = -(-cols // (32 // pack_bits))
    w_tile = tile * words_per_row * 4
    x_bytes = n * cols * 4
    out_tile = n * tile * 4
    deq_tile = tile * cols * 4  # unpacked tile in registers/VMEM
    vmem = w_tile + x_bytes + out_tile + deq_tile
    # The point of the fused kernel: HBM moves PACKED weights (bits/8 per
    # element), never the f32 dequantized matrix.
    hbm = w_tile + x_bytes + out_tile
    flops = 2 * n * tile * cols
    mxu = _lane_fill(n) * _lane_fill(cols)
    return KernelEstimate("gptq_matmul", vmem, hbm, flops, mxu)


def report(preset: str = "mini") -> str:
    """Human-readable estimate block for EXPERIMENTS.md."""
    from ..model import PRESETS

    cfg = PRESETS[preset]
    g = cfg.n_heads // cfg.n_kv_heads
    bs, mbs = 16, cfg.max_seq // 16
    dec = paged_decode_estimate(kvh=cfg.n_kv_heads, g=g, hd=cfg.head_dim, block_size=bs, blocks_per_seq=mbs)
    mha = mha_decode_estimate(h=cfg.n_heads, hd=cfg.head_dim, block_size=bs, blocks_per_seq=mbs)
    lines = [
        f"paged GQA decode ({preset}, full {cfg.max_seq}-token context):",
        f"  VMEM/step {dec.vmem_bytes_per_step / 1024:.1f} KiB (fits 16 MiB: {dec.fits_vmem})",
        f"  HBM/step  {dec.hbm_bytes_per_step / 1024:.1f} KiB vs MHA {mha.hbm_bytes_per_step / 1024:.1f} KiB"
        f"  → {mha.hbm_bytes_per_step / dec.hbm_bytes_per_step:.2f}× less traffic (G = {g})",
        f"  MXU lane-fill {dec.mxu_utilization:.2f}, arithmetic intensity {dec.arithmetic_intensity:.2f} flop/byte",
    ]
    return "\n".join(lines)
