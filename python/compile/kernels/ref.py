"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has a reference here written with plain
jax.numpy ops in the most obvious way possible; pytest asserts
allclose(kernel, ref) across a shape/seed sweep. These oracles also match
the Rust native implementations (rust/src/attention/), closing the
three-way loop: Rust native ↔ jnp ref ↔ Pallas kernel.
"""

import jax.numpy as jnp
import numpy as np


def alibi_slopes(num_heads: int) -> np.ndarray:
    """Per-head ALiBi slopes (Press et al.), matching rust alibi.rs."""

    def pow2_slopes(n):
        start = 2.0 ** (-8.0 / n)
        return [start ** (i + 1) for i in range(n)]

    if num_heads & (num_heads - 1) == 0:
        return np.asarray(pow2_slopes(num_heads), dtype=np.float32)
    base = 1 << ((num_heads).bit_length() - 1)
    slopes = pow2_slopes(base)
    extra = pow2_slopes(2 * base)
    slopes += extra[0::2][: num_heads - base]
    return np.asarray(slopes, dtype=np.float32)


def gqa_prefill_ref(q, k, v, *, alibi: bool, q_offset: int = 0):
    """Causal grouped-query attention over contiguous K/V.

    q: [S, H, hd]; k, v: [T, KVH, hd] with T >= q_offset + S.
    Query row i sits at absolute position q_offset + i and may attend to
    keys 0..=that position. Returns [S, H, hd].
    """
    s, h, hd = q.shape
    t, kvh, _ = k.shape
    g = h // kvh
    scale = 1.0 / np.sqrt(hd)
    # Expand K/V to per-query-head views.
    k_exp = jnp.repeat(k, g, axis=1)  # [T, H, hd]
    v_exp = jnp.repeat(v, g, axis=1)
    scores = jnp.einsum("shd,thd->hst", q, k_exp) * scale  # [H, S, T]
    q_pos = q_offset + jnp.arange(s)[:, None]  # [S, 1]
    k_pos = jnp.arange(t)[None, :]  # [1, T]
    if alibi:
        slopes = jnp.asarray(alibi_slopes(h))[:, None, None]
        scores = scores - slopes * (q_pos - k_pos)[None, :, :]
    causal = k_pos <= q_pos  # [S, T]
    scores = jnp.where(causal[None, :, :], scores, -jnp.inf)
    w = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    w = w / w.sum(axis=-1, keepdims=True)
    return jnp.einsum("hst,thd->shd", w, v_exp)


def paged_decode_ref(q, k_cache, v_cache, block_tables, ctx_lens, k_cur, v_cur, *, alibi: bool):
    """Paged decode attention reference.

    q: [B, H, hd]; k_cache/v_cache: [NB, BS, KVH, hd];
    block_tables: [B, MBS] i32; ctx_lens: [B] i32 (tokens already in the
    cache); k_cur/v_cur: [B, KVH, hd] (the current token's K/V, logically
    at position ctx_lens[b]). Returns [B, H, hd].
    """
    b, h, hd = q.shape
    nb, bs, kvh, _ = k_cache.shape
    mbs = block_tables.shape[1]
    g = h // kvh
    outs = []
    for i in range(b):
        ctx = int(ctx_lens[i])
        # Gather the sequence's K/V from its blocks.
        ks, vs = [], []
        for j in range(mbs):
            bid = int(block_tables[i, j])
            ks.append(k_cache[bid])
            vs.append(v_cache[bid])
        ks = jnp.concatenate(ks, axis=0)[:ctx]  # [ctx, KVH, hd]
        vs = jnp.concatenate(vs, axis=0)[:ctx]
        ks = jnp.concatenate([ks, k_cur[i][None]], axis=0)  # + current
        vs = jnp.concatenate([vs, v_cur[i][None]], axis=0)
        out = gqa_prefill_ref(q[i][None], ks, vs, alibi=alibi, q_offset=ctx)
        outs.append(out[0])
    return jnp.stack(outs)


# ---------------------------------------------------------------------------
# GPTQ packed-matmul reference (format shared with rust quant/packing.rs).
# ---------------------------------------------------------------------------


def pack_rows_ref(q_levels: np.ndarray, pack_bits: int) -> np.ndarray:
    """Pack integer levels [rows, cols] little-endian into i32 words.

    Level k of a word occupies bits [k*pack_bits, (k+1)*pack_bits) —
    identical to rust `quant::packing::pack_rows`.
    """
    rows, cols = q_levels.shape
    lpw = 32 // pack_bits
    words_per_row = -(-cols // lpw)
    words = np.zeros((rows, words_per_row), dtype=np.int64)
    for c in range(cols):
        words[:, c // lpw] |= q_levels[:, c].astype(np.int64) << ((c % lpw) * pack_bits)
    return words.astype(np.uint32).view(np.int32).reshape(rows, words_per_row)


def unpack_rows_ref(words: np.ndarray, cols: int, pack_bits: int) -> np.ndarray:
    """Inverse of pack_rows_ref → [rows, cols] uint8 levels."""
    rows = words.shape[0]
    lpw = 32 // pack_bits
    mask = (1 << pack_bits) - 1
    u = words.view(np.uint32)
    out = np.zeros((rows, cols), dtype=np.uint8)
    for c in range(cols):
        out[:, c] = (u[:, c // lpw] >> ((c % lpw) * pack_bits)) & mask
    return out


def gptq_matmul_ref(x, words, scales, zeros, *, cols: int, pack_bits: int, group_size: int):
    """x [N, cols] · dequant(packed W [rows, words]).T → [N, rows]."""
    q = unpack_rows_ref(np.asarray(words), cols, pack_bits).astype(np.float32)
    groups = -(-cols // group_size)
    gidx = np.arange(cols) // group_size  # [cols]
    sc = np.asarray(scales).reshape(-1, groups)[:, gidx]  # [rows, cols]
    zp = np.asarray(zeros).reshape(-1, groups)[:, gidx]
    w = (q - zp) * sc  # [rows, cols]
    return jnp.asarray(x) @ jnp.asarray(w).T
