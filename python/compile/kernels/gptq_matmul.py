"""L1 Pallas kernel: GPTQ int4/int8 dequant-matmul.

Consumes the packed format produced by rust `quant::packing::pack_rows`
(little-endian fields in i32 words, group-wise scales/zeros) and fuses
unpack → dequantize → matmul, so the f32 weight matrix never exists in
memory — the weight-only-quantization serving pattern (W4A16) the paper's
"GPTQ" side relies on.

The grid tiles output rows; each program unpacks its tile of W once into
registers/VMEM and contracts it against the full activation block on the
MXU.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dequant_matmul_kernel(
    x_ref,  # [N, COLS]
    w_ref,  # [TILE, WORDS] i32
    sc_ref,  # [TILE, GROUPS]
    zp_ref,  # [TILE, GROUPS] i32
    out_ref,  # [N, TILE]
    *,
    cols: int,
    pack_bits: int,
    group_size: int,
):
    x = x_ref[...]
    words = w_ref[...]
    lpw = 32 // pack_bits
    mask = (1 << pack_bits) - 1
    # Unpack: level c of a row lives in word c//lpw, bits (c%lpw)*pack_bits.
    c = jnp.arange(cols)
    word_idx = c // lpw
    shifts = (c % lpw) * pack_bits
    # i32 >> with sign: mask after shift keeps the field unsigned.
    fields = (words[:, word_idx] >> shifts[None, :]) & mask  # [TILE, COLS]
    gidx = c // group_size
    sc = sc_ref[...][:, gidx]  # [TILE, COLS]
    zp = zp_ref[...][:, gidx]
    w = (fields - zp).astype(jnp.float32) * sc
    out_ref[...] = jnp.dot(x, w.T)


def gptq_matmul(x, words, scales, zeros, *, cols: int, pack_bits: int, group_size: int, tile: int = 0):
    """x `[N, cols]` · dequant(W packed `[rows, words]`)ᵀ → `[N, rows]`."""
    n = x.shape[0]
    rows = words.shape[0]
    groups = -(-cols // group_size)
    if tile <= 0 or rows % tile != 0:
        tile = rows  # single tile fallback
    words_per_row = words.shape[1]
    kernel = functools.partial(
        _dequant_matmul_kernel, cols=cols, pack_bits=pack_bits, group_size=group_size
    )
    return pl.pallas_call(
        kernel,
        grid=(rows // tile,),
        in_specs=[
            pl.BlockSpec((n, cols), lambda i: (0, 0)),
            pl.BlockSpec((tile, words_per_row), lambda i: (i, 0)),
            pl.BlockSpec((tile, groups), lambda i: (i, 0)),
            pl.BlockSpec((tile, groups), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((n, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n, rows), jnp.float32),
        interpret=True,
    )(x, words, scales, zeros)
