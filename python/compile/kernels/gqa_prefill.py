"""L1 Pallas kernel: blocked causal GQA prefill attention with fused ALiBi.

One grid step per KV head: the program loads that head's K/V once and
serves all `G` query heads of the group — prefill-side KV sharing, the
same `G×` traffic saving as the decode kernel. Causality and ALiBi are
applied in-register from position arithmetic; no `[S, S]` mask tensor is
ever built (paper §III.A).

`q_offset` supports chunked prefill: query row i sits at absolute
position `q_offset + i` over a KV span of `T` rows.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import alibi_slopes

NEG_INF = -1.0e30


def _prefill_kernel(
    q_ref,  # [1, G, S, hd] — this KV head's query group
    k_ref,  # [1, T, hd]
    v_ref,  # [1, T, hd]
    slopes_ref,  # [1, G]
    out_ref,  # [1, G, S, hd]
    *,
    q_offset: int,
):
    q = q_ref[0]  # [G, S, hd]
    k = k_ref[0]  # [T, hd]
    v = v_ref[0]
    g, s, hd = q.shape
    t = k.shape[0]
    scale = 1.0 / (hd**0.5)
    scores = jnp.einsum("gsd,td->gst", q, k) * scale  # [G, S, T]
    q_pos = q_offset + jnp.arange(s)[:, None]  # [S, 1]
    k_pos = jnp.arange(t)[None, :]  # [1, T]
    slopes = slopes_ref[0]  # [G]
    # ALiBi + causality from position arithmetic (zero slopes = causal only).
    scores = scores - slopes[:, None, None] * (q_pos - k_pos)[None, :, :]
    scores = jnp.where((k_pos <= q_pos)[None, :, :], scores, NEG_INF)
    m = scores.max(axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    w = p / p.sum(axis=-1, keepdims=True)
    out_ref[0] = jnp.einsum("gst,td->gsd", w, v)


def gqa_prefill_attention(q, k, v, *, alibi: bool, q_offset: int = 0):
    """Causal GQA prefill attention (Pallas, interpret mode).

    q: [S, H, hd]; k, v: [T, KVH, hd] (T ≥ q_offset + S).
    Returns [S, H, hd].
    """
    s, h, hd = q.shape
    t, kvh, _ = k.shape
    g = h // kvh
    # [KVH, G, S, hd]: group-major so one grid step owns one KV head.
    qg = q.reshape(s, kvh, g, hd).transpose(1, 2, 0, 3)
    kg = k.transpose(1, 0, 2)  # [KVH, T, hd]
    vg = v.transpose(1, 0, 2)
    if alibi:
        slopes = jnp.asarray(alibi_slopes(h), dtype=jnp.float32).reshape(kvh, g)
    else:
        slopes = jnp.zeros((kvh, g), dtype=jnp.float32)

    kernel = functools.partial(_prefill_kernel, q_offset=q_offset)
    out = pl.pallas_call(
        kernel,
        grid=(kvh,),
        in_specs=[
            pl.BlockSpec((1, g, s, hd), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, t, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, t, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, g), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, s, hd), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((kvh, g, s, hd), jnp.float32),
        interpret=True,
    )(qg, kg, vg, slopes)
    # [KVH, G, S, hd] → [S, H, hd]
    return out.transpose(2, 0, 1, 3).reshape(s, h, hd)
