"""L1 Pallas kernel: paged grouped-query decode attention with fused ALiBi.

The paper's DCU kernel restated for the TPU memory hierarchy (DESIGN.md
§Hardware-Adaptation):

* the grid runs one program per *sequence*; inside, a `fori_loop` walks
  the sequence's KV blocks — each block is staged HBM→VMEM **once** and
  consumed by *all* query heads of each KV group (`G×` fewer KV loads
  than an MHA kernel, the paper's sharing win);
* scores are `(KVH, G, hd) · (BS, KVH, hd)` contractions so a whole
  query group hits the MXU as one matmul;
* the ALiBi penalty is computed in-register from `(slope, distance)` —
  no mask tensor is ever materialized (paper §III.A);
* softmax is *online* (running max/normalizer across blocks), so VMEM
  holds one KV block + `[KVH, G, hd]` accumulators regardless of context
  length.

Compiled with `interpret=True`: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO ops. The structure above
is what a real-TPU build would pin with BlockSpecs; EXPERIMENTS.md
estimates its VMEM/MXU profile analytically.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import alibi_slopes

NEG_INF = -1.0e30


def _decode_kernel(
    # refs (per grid step: one sequence)
    q_ref,  # [1, KVH, G, hd]
    bt_ref,  # [1, MBS] i32
    ctx_ref,  # [1] i32
    k_cur_ref,  # [1, KVH, hd]
    v_cur_ref,  # [1, KVH, hd]
    k_cache_ref,  # [NB, BS, KVH, hd] (whole pool)
    v_cache_ref,  # [NB, BS, KVH, hd]
    slopes_ref,  # [KVH, G]
    out_ref,  # [1, KVH, G, hd]
    *,
    block_size: int,
    max_blocks: int,
):
    q = q_ref[0]  # [KVH, G, hd]
    ctx = ctx_ref[0]
    kvh, g, hd = q.shape
    scale = 1.0 / (hd**0.5)
    slopes = slopes_ref[...]  # [KVH, G]

    def body(j, carry):
        m, l, acc = carry  # [KVH,G], [KVH,G], [KVH,G,hd]
        bid = bt_ref[0, j]
        # One KV block: staged once, shared by all G heads of each group.
        k_blk = k_cache_ref[pl.dslice(bid, 1)][0]  # [BS, KVH, hd]
        v_blk = v_cache_ref[pl.dslice(bid, 1)][0]
        # Whole-group MXU contraction: [KVH, G, BS].
        s = jnp.einsum("kgd,bkd->kgb", q, k_blk) * scale
        k_pos = j * block_size + jnp.arange(block_size)  # [BS]
        dist = (ctx - k_pos).astype(jnp.float32)  # q sits at position ctx
        s = s - slopes[:, :, None] * dist[None, None, :]
        valid = k_pos < ctx
        s = jnp.where(valid[None, None, :], s, NEG_INF)
        # Online softmax update.
        m_new = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, :, None])
        p = jnp.where(valid[None, None, :], p, 0.0)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[:, :, None] + jnp.einsum("kgb,bkd->kgd", p, v_blk)
        return m_new, l_new, acc_new

    m0 = jnp.full((kvh, g), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((kvh, g), dtype=jnp.float32)
    acc0 = jnp.zeros((kvh, g, hd), dtype=jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, max_blocks, body, (m0, l0, acc0))

    # The current token (position ctx, ALiBi distance 0) — always valid.
    k_cur = k_cur_ref[0]  # [KVH, hd]
    v_cur = v_cur_ref[0]
    s_cur = jnp.einsum("kgd,kd->kg", q, k_cur) * scale
    m_new = jnp.maximum(m, s_cur)
    corr = jnp.exp(m - m_new)
    p_cur = jnp.exp(s_cur - m_new)
    l = l * corr + p_cur
    acc = acc * corr[:, :, None] + p_cur[:, :, None] * v_cur[:, None, :]

    out_ref[0] = acc / l[:, :, None]


def paged_decode_attention(q, k_cache, v_cache, block_tables, ctx_lens, k_cur, v_cur, *, alibi: bool):
    """Paged GQA decode attention (Pallas, interpret mode).

    q: [B, H, hd]; k_cache/v_cache: [NB, BS, KVH, hd];
    block_tables: [B, MBS] i32; ctx_lens: [B] i32;
    k_cur/v_cur: [B, KVH, hd]. Returns [B, H, hd].
    """
    b, h, hd = q.shape
    nb, bs, kvh, _ = k_cache.shape
    mbs = block_tables.shape[1]
    g = h // kvh
    # Head h = kv_head * G + gq ordering (matches rust attention/gqa.rs).
    q_grouped = q.reshape(b, kvh, g, hd)
    if alibi:
        slopes = jnp.asarray(alibi_slopes(h), dtype=jnp.float32).reshape(kvh, g)
    else:
        slopes = jnp.zeros((kvh, g), dtype=jnp.float32)

    kernel = functools.partial(_decode_kernel, block_size=bs, max_blocks=mbs)
    out = pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, kvh, g, hd), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, mbs), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1, kvh, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, kvh, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((nb, bs, kvh, hd), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((nb, bs, kvh, hd), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((kvh, g), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, kvh, g, hd), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, hd), jnp.float32),
        interpret=True,
    )(q_grouped, block_tables, ctx_lens, k_cur, v_cur, k_cache, v_cache, slopes)
    return out.reshape(b, h, hd)
