"""L1 kernel correctness: Pallas vs pure-jnp oracle (the CORE signal).

hypothesis is unavailable offline, so shape/seed coverage comes from
seeded parametrized sweeps over the axes that change kernel control flow:
GQA group factor (MHA / grouped / MQA), block size vs context alignment,
ragged final blocks, ALiBi on/off, batch composition.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import ref
from compile.kernels.gqa_prefill import gqa_prefill_attention
from compile.kernels.gptq_matmul import gptq_matmul
from compile.kernels.paged_attention import paged_decode_attention

ATOL = 3e-5
RTOL = 3e-5


def rng_for(*key):
    return np.random.default_rng(abs(hash(key)) % (2**32))


# ---------------------------------------------------------------------------
# Prefill kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("h,kvh", [(4, 4), (4, 2), (4, 1), (8, 2), (6, 3)])
@pytest.mark.parametrize("s", [1, 5, 16])
@pytest.mark.parametrize("alibi", [True, False])
def test_prefill_matches_ref(h, kvh, s, alibi):
    hd = 8
    r = rng_for("prefill", h, kvh, s, alibi)
    q = r.standard_normal((s, h, hd), dtype=np.float32)
    k = r.standard_normal((s, kvh, hd), dtype=np.float32)
    v = r.standard_normal((s, kvh, hd), dtype=np.float32)
    out = gqa_prefill_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), alibi=alibi)
    expect = ref.gqa_prefill_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), alibi=alibi)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=ATOL, rtol=RTOL)


def test_prefill_chunked_offset():
    """q_offset chunk must equal the same rows of a full prefill."""
    h, kvh, hd, t = 4, 2, 8, 12
    r = rng_for("chunk")
    q = r.standard_normal((t, h, hd), dtype=np.float32)
    k = r.standard_normal((t, kvh, hd), dtype=np.float32)
    v = r.standard_normal((t, kvh, hd), dtype=np.float32)
    full = gqa_prefill_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), alibi=True)
    tail = gqa_prefill_attention(
        jnp.asarray(q[8:]), jnp.asarray(k), jnp.asarray(v), alibi=True, q_offset=8
    )
    np.testing.assert_allclose(np.asarray(tail), np.asarray(full[8:]), atol=ATOL, rtol=RTOL)


def test_prefill_is_causal():
    """Future K/V rows must not affect earlier outputs."""
    h, kvh, hd, s = 4, 2, 8, 6
    r = rng_for("causal")
    q = r.standard_normal((s, h, hd), dtype=np.float32)
    k = r.standard_normal((s, kvh, hd), dtype=np.float32)
    v = r.standard_normal((s, kvh, hd), dtype=np.float32)
    out1 = np.asarray(gqa_prefill_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), alibi=True))
    k2, v2 = k.copy(), v.copy()
    k2[-1] += 100.0
    v2[-1] -= 50.0
    out2 = np.asarray(gqa_prefill_attention(jnp.asarray(q), jnp.asarray(k2), jnp.asarray(v2), alibi=True))
    np.testing.assert_array_equal(out1[:-1], out2[:-1])
    assert not np.allclose(out1[-1], out2[-1])


# ---------------------------------------------------------------------------
# Paged decode kernel
# ---------------------------------------------------------------------------


def make_paged_case(key, b, h, kvh, hd, nb, bs, mbs, ctx_choices):
    r = rng_for(*key)
    kc = r.standard_normal((nb, bs, kvh, hd), dtype=np.float32)
    vc = r.standard_normal((nb, bs, kvh, hd), dtype=np.float32)
    # Distinct random block tables per sequence.
    bt = np.stack([r.permutation(nb)[:mbs] for _ in range(b)]).astype(np.int32)
    ctx = np.asarray([ctx_choices[i % len(ctx_choices)] for i in range(b)], dtype=np.int32)
    q = r.standard_normal((b, h, hd), dtype=np.float32)
    k_cur = r.standard_normal((b, kvh, hd), dtype=np.float32)
    v_cur = r.standard_normal((b, kvh, hd), dtype=np.float32)
    return q, kc, vc, bt, ctx, k_cur, v_cur


@pytest.mark.parametrize("h,kvh", [(4, 4), (4, 2), (4, 1), (8, 4)])
@pytest.mark.parametrize("bs,mbs", [(4, 3), (8, 2), (16, 1)])
@pytest.mark.parametrize("alibi", [True, False])
def test_paged_decode_matches_ref(h, kvh, bs, mbs, alibi):
    b, hd, nb = 3, 8, 8
    max_ctx = bs * mbs
    ctxs = [max_ctx, max_ctx // 2 + 1, 1]
    q, kc, vc, bt, ctx, k_cur, v_cur = make_paged_case(
        ("paged", h, kvh, bs, mbs, alibi), b, h, kvh, hd, nb, bs, mbs, ctxs
    )
    out = paged_decode_attention(
        jnp.asarray(q), jnp.asarray(kc), jnp.asarray(vc), jnp.asarray(bt),
        jnp.asarray(ctx), jnp.asarray(k_cur), jnp.asarray(v_cur), alibi=alibi,
    )
    expect = ref.paged_decode_ref(
        jnp.asarray(q), jnp.asarray(kc), jnp.asarray(vc), bt, ctx,
        jnp.asarray(k_cur), jnp.asarray(v_cur), alibi=alibi,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=ATOL, rtol=RTOL)


def test_paged_decode_zero_context():
    """ctx=0: the token attends only to itself → output is v_cur."""
    b, h, kvh, hd, nb, bs, mbs = 1, 2, 1, 4, 2, 4, 2
    q, kc, vc, bt, _, k_cur, v_cur = make_paged_case(
        ("zero",), b, h, kvh, hd, nb, bs, mbs, [1]
    )
    ctx = np.zeros((b,), dtype=np.int32)
    out = paged_decode_attention(
        jnp.asarray(q), jnp.asarray(kc), jnp.asarray(vc), jnp.asarray(bt),
        jnp.asarray(ctx), jnp.asarray(k_cur), jnp.asarray(v_cur), alibi=True,
    )
    for head in range(h):
        np.testing.assert_allclose(np.asarray(out[0, head]), v_cur[0, 0], atol=ATOL, rtol=RTOL)


def test_paged_decode_ignores_stale_slots():
    """Garbage in slots beyond ctx and in unreferenced blocks is invisible."""
    b, h, kvh, hd, nb, bs, mbs = 1, 4, 2, 8, 6, 4, 2
    q, kc, vc, bt, ctx, k_cur, v_cur = make_paged_case(
        ("stale",), b, h, kvh, hd, nb, bs, mbs, [5]
    )
    out1 = np.asarray(paged_decode_attention(
        jnp.asarray(q), jnp.asarray(kc), jnp.asarray(vc), jnp.asarray(bt),
        jnp.asarray(ctx), jnp.asarray(k_cur), jnp.asarray(v_cur), alibi=True,
    ))
    kc2, vc2 = kc.copy(), vc.copy()
    # Poison beyond-ctx slots of the last used block and all unused blocks.
    used = set(int(x) for x in bt[0])
    last_block = int(bt[0, 1])
    kc2[last_block, 5 - bs :] = 999.0
    vc2[last_block, 5 - bs :] = 999.0
    for blk in range(nb):
        if blk not in used:
            kc2[blk] = -999.0
            vc2[blk] = -999.0
    out2 = np.asarray(paged_decode_attention(
        jnp.asarray(q), jnp.asarray(kc2), jnp.asarray(vc2), jnp.asarray(bt),
        jnp.asarray(ctx), jnp.asarray(k_cur), jnp.asarray(v_cur), alibi=True,
    ))
    np.testing.assert_allclose(out1, out2, atol=ATOL, rtol=RTOL)


def test_paged_decode_extreme_scores_stable():
    """Online softmax must stay finite under ±50 magnitude keys."""
    b, h, kvh, hd, nb, bs, mbs = 1, 2, 1, 4, 2, 4, 2
    q, kc, vc, bt, ctx, k_cur, v_cur = make_paged_case(
        ("extreme",), b, h, kvh, hd, nb, bs, mbs, [8]
    )
    kc = np.where(np.arange(bs)[None, :, None, None] % 2 == 0, 50.0, -50.0) * np.ones_like(kc)
    out = np.asarray(paged_decode_attention(
        jnp.asarray(q), jnp.asarray(kc), jnp.asarray(vc), jnp.asarray(bt),
        jnp.asarray(ctx), jnp.asarray(k_cur), jnp.asarray(v_cur), alibi=False,
    ))
    assert np.isfinite(out).all()


# ---------------------------------------------------------------------------
# GPTQ dequant-matmul kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("pack_bits", [4, 8])
@pytest.mark.parametrize("rows,cols,group_size", [(8, 20, 8), (16, 64, 32), (4, 7, 7)])
def test_gptq_matmul_matches_ref(pack_bits, rows, cols, group_size):
    r = rng_for("gptq", pack_bits, rows, cols, group_size)
    max_q = (1 << pack_bits) - 1
    q = r.integers(0, max_q + 1, size=(rows, cols)).astype(np.uint8)
    words = ref.pack_rows_ref(q, pack_bits)
    groups = -(-cols // group_size)
    sc = (r.standard_normal((rows, groups)) * 0.1).astype(np.float32)
    zp = r.integers(0, max_q + 1, size=(rows, groups)).astype(np.int32)
    x = r.standard_normal((5, cols)).astype(np.float32)
    out = gptq_matmul(
        jnp.asarray(x), jnp.asarray(words), jnp.asarray(sc), jnp.asarray(zp),
        cols=cols, pack_bits=pack_bits, group_size=group_size,
    )
    expect = ref.gptq_matmul_ref(x, words, sc, zp, cols=cols, pack_bits=pack_bits, group_size=group_size)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-4, rtol=1e-4)


def test_gptq_matmul_tiled_equals_untiled():
    r = rng_for("tiled")
    rows, cols, gs, pb = 32, 16, 8, 4
    q = r.integers(0, 16, size=(rows, cols)).astype(np.uint8)
    words = ref.pack_rows_ref(q, pb)
    sc = (r.standard_normal((rows, 2)) * 0.1).astype(np.float32)
    zp = r.integers(0, 16, size=(rows, 2)).astype(np.int32)
    x = r.standard_normal((3, cols)).astype(np.float32)
    a = gptq_matmul(jnp.asarray(x), jnp.asarray(words), jnp.asarray(sc), jnp.asarray(zp),
                    cols=cols, pack_bits=pb, group_size=gs, tile=8)
    b = gptq_matmul(jnp.asarray(x), jnp.asarray(words), jnp.asarray(sc), jnp.asarray(zp),
                    cols=cols, pack_bits=pb, group_size=gs)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-5)


def test_pack_unpack_roundtrip_sign_bit():
    """Top-nibble 15 exercises the i32 sign bit (matches rust packing)."""
    q = np.full((1, 8), 15, dtype=np.uint8)
    words = ref.pack_rows_ref(q, 4)
    assert words[0, 0] < 0  # sign bit set
    np.testing.assert_array_equal(ref.unpack_rows_ref(words, 8, 4), q)


def test_alibi_slopes_match_rust_values():
    s = ref.alibi_slopes(8)
    np.testing.assert_allclose(s, [2.0 ** -(i + 1) for i in range(8)], rtol=1e-6)
    s12 = ref.alibi_slopes(12)
    assert len(s12) == 12 and len(set(s12.tolist())) == 12
