"""L2 model-graph correctness (python-side; rust cross-checks in cargo)."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.model import PRESETS, decode_fn, num_params, param_shapes, prefill_fn

CFG = PRESETS["tiny"]
BS, NB, MBS = 16, 8, 4


def make_params(cfg, seed=0):
    r = np.random.default_rng(seed)
    out = []
    for name, shape in param_shapes(cfg):
        if name.endswith(("rms_attn", "rms_mlp")) or name == "final_norm":
            out.append(jnp.ones(shape, dtype=jnp.float32))
        else:
            std = 1.0 / np.sqrt(shape[-1])
            out.append(jnp.asarray(r.standard_normal(shape).astype(np.float32) * std))
    return out


def run_prefill(params, tokens):
    return prefill_fn(CFG, params, jnp.asarray(tokens, dtype=jnp.int32))


def test_param_accounting():
    assert len(make_params(CFG)) == num_params(CFG)
    names = [n for n, _ in param_shapes(CFG)]
    assert names[0] == "embed" and names[-1] == "lm_head" and names[-2] == "final_norm"


def test_prefill_shapes():
    params = make_params(CFG)
    logits, ks, vs = run_prefill(params, [256, 1, 2, 3])
    assert logits.shape == (4, CFG.vocab)
    assert ks.shape == (CFG.n_layers, 4, CFG.kv_dim)
    assert vs.shape == (CFG.n_layers, 4, CFG.kv_dim)
    assert np.isfinite(np.asarray(logits)).all()


def place_kv_in_cache(ks, vs, block_table, block_size):
    """Scatter prefill K/V rows into a fresh paged cache."""
    kvh, hd = CFG.n_kv_heads, CFG.head_dim
    kc = np.zeros((CFG.n_layers, NB, block_size, kvh, hd), dtype=np.float32)
    vc = np.zeros_like(kc)
    n = ks.shape[1]
    for pos in range(n):
        blk = int(block_table[pos // block_size])
        slot = pos % block_size
        kc[:, blk, slot] = np.asarray(ks[:, pos]).reshape(CFG.n_layers, kvh, hd)
        vc[:, blk, slot] = np.asarray(vs[:, pos]).reshape(CFG.n_layers, kvh, hd)
    return kc, vc


@pytest.mark.parametrize("prompt_len", [3, 7])
def test_decode_consistent_with_prefill(prompt_len):
    """prefill(t[..n]) == prefill(t[..n-1]) + paged decode of t[n-1]."""
    params = make_params(CFG)
    tokens = [256] + list(range(1, prompt_len))
    full_logits, _, _ = run_prefill(params, tokens)

    head = tokens[:-1]
    logits_h, ks, vs = run_prefill(params, head)
    block_table = np.asarray([2, 5, 1, 0], dtype=np.int32)  # non-contiguous
    kc, vc = place_kv_in_cache(ks, vs, block_table, BS)

    logits_d, k_new, v_new = decode_fn(
        CFG,
        params,
        jnp.asarray([tokens[-1]], dtype=jnp.int32),
        jnp.asarray([len(head)], dtype=jnp.int32),
        jnp.asarray(block_table[None, :], dtype=jnp.int32),
        jnp.asarray(kc),
        jnp.asarray(vc),
    )
    np.testing.assert_allclose(
        np.asarray(logits_d[0]), np.asarray(full_logits[-1]), atol=2e-4, rtol=2e-4
    )
    assert k_new.shape == (CFG.n_layers, 1, CFG.kv_dim)
    assert v_new.shape == (CFG.n_layers, 1, CFG.kv_dim)


def test_decode_batch_matches_individual():
    """A padded batch row must produce the same logits as batch=1."""
    params = make_params(CFG)
    logits_h, ks, vs = run_prefill(params, [256, 9, 8])
    block_table = np.asarray([0, 1, 2, 3], dtype=np.int32)
    kc, vc = place_kv_in_cache(ks, vs, block_table, BS)

    def decode(batch_tokens, ctxs, tables):
        return decode_fn(
            CFG, params,
            jnp.asarray(batch_tokens, dtype=jnp.int32),
            jnp.asarray(ctxs, dtype=jnp.int32),
            jnp.asarray(tables, dtype=jnp.int32),
            jnp.asarray(kc), jnp.asarray(vc),
        )[0]

    single = decode([7], [3], block_table[None, :])
    # Same sequence in slot 0, a pad-like row (ctx 0) in slot 1.
    batch = decode([7, 258], [3, 0], np.stack([block_table, np.zeros(4, np.int32)]))
    np.testing.assert_allclose(np.asarray(batch[0]), np.asarray(single[0]), atol=1e-4, rtol=1e-4)


def test_mha_preset_runs():
    cfg = PRESETS["tiny-mha"]
    r = np.random.default_rng(1)
    params = []
    for name, shape in param_shapes(cfg):
        if len(shape) == 1:
            params.append(jnp.ones(shape, dtype=jnp.float32))
        else:
            params.append(jnp.asarray(r.standard_normal(shape).astype(np.float32) * 0.05))
    logits, ks, vs = prefill_fn(cfg, params, jnp.asarray([256, 1, 2], dtype=jnp.int32))
    assert logits.shape == (3, cfg.vocab)
    assert ks.shape[2] == cfg.n_heads * cfg.head_dim  # full KV width for MHA
    assert np.isfinite(np.asarray(logits)).all()


def test_alibi_changes_logits():
    """The ALiBi path must actually differ from the causal-only path."""
    import dataclasses

    params = make_params(CFG)
    no_alibi = dataclasses.replace(CFG, alibi=False)
    la, _, _ = prefill_fn(CFG, params, jnp.asarray([256, 1, 2, 3], dtype=jnp.int32))
    lb, _, _ = prefill_fn(no_alibi, params, jnp.asarray([256, 1, 2, 3], dtype=jnp.int32))
    # Row 0 attends only to itself → identical; later rows must differ.
    np.testing.assert_allclose(np.asarray(la[0]), np.asarray(lb[0]), atol=1e-5)
    assert not np.allclose(np.asarray(la[-1]), np.asarray(lb[-1]))
