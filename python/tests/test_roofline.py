"""Structural checks on the L1 analytic performance model."""

from compile.kernels.roofline import (
    VMEM_BYTES,
    gptq_matmul_estimate,
    gqa_prefill_estimate,
    mha_decode_estimate,
    paged_decode_estimate,
    report,
)


def test_decode_fits_vmem_for_all_presets():
    for kvh, g, hd in [(2, 2, 16), (4, 3, 64), (8, 1, 64)]:
        e = paged_decode_estimate(kvh=kvh, g=g, hd=hd, block_size=16, blocks_per_seq=128)
        assert e.fits_vmem, (kvh, g, hd, e.vmem_bytes_per_step)
        assert e.vmem_bytes_per_step < VMEM_BYTES // 8  # lots of headroom


def test_gqa_saves_exactly_g_times_kv_traffic():
    # Same total query heads (h = kvh*g); KV traffic ratio must be ~G.
    h, hd = 12, 64
    for g in [2, 3, 4, 6]:
        kvh = h // g
        gqa = paged_decode_estimate(kvh=kvh, g=g, hd=hd, block_size=16, blocks_per_seq=64)
        mha = mha_decode_estimate(h=h, hd=hd, block_size=16, blocks_per_seq=64)
        ratio = mha.hbm_bytes_per_step / gqa.hbm_bytes_per_step
        assert abs(ratio - g) < 0.1, (g, ratio)


def test_flops_invariant_under_grouping():
    # Grouping shares memory, not compute: FLOPs depend on h = kvh*g only.
    a = paged_decode_estimate(kvh=2, g=6, hd=64, block_size=16, blocks_per_seq=64)
    b = paged_decode_estimate(kvh=12, g=1, hd=64, block_size=16, blocks_per_seq=64)
    assert a.flops_per_step == b.flops_per_step


def test_grouping_raises_arithmetic_intensity():
    gqa = paged_decode_estimate(kvh=4, g=3, hd=64, block_size=16, blocks_per_seq=64)
    mha = mha_decode_estimate(h=12, hd=64, block_size=16, blocks_per_seq=64)
    assert gqa.arithmetic_intensity > mha.arithmetic_intensity


def test_gqa_groups_fill_mxu_better_than_mha():
    # (G×hd) rows feed the MXU: grouped > per-head vectors.
    gqa = paged_decode_estimate(kvh=4, g=3, hd=64, block_size=16, blocks_per_seq=64)
    mha = mha_decode_estimate(h=12, hd=64, block_size=16, blocks_per_seq=64)
    assert gqa.mxu_utilization > mha.mxu_utilization


def test_gptq_kernel_moves_packed_bytes_only():
    e4 = gptq_matmul_estimate(n=8, rows=256, cols=256, pack_bits=4, tile=64)
    e8 = gptq_matmul_estimate(n=8, rows=256, cols=256, pack_bits=8, tile=64)
    assert e4.hbm_bytes_per_step < e8.hbm_bytes_per_step
    assert e4.fits_vmem


def test_prefill_estimate_sane():
    e = gqa_prefill_estimate(kvh=4, g=3, s=128, hd=64)
    assert e.fits_vmem
    assert e.flops_per_step > 0
    assert 0 < e.mxu_utilization <= 1


def test_report_renders():
    r = report("mini")
    assert "paged GQA decode" in r
    assert "less traffic" in r
    print("\n" + r)
