"""AOT lowering sanity: artifacts exist, parse as HLO text, manifest valid."""

import json
import os
import subprocess
import sys

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def artifacts_present():
    return os.path.exists(os.path.join(ART, "manifest.json"))


@pytest.fixture(scope="module")
def manifest():
    if not artifacts_present():
        # Build them (same command as `make artifacts`).
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out", ART],
            cwd=os.path.join(os.path.dirname(__file__), ".."),
            check=True,
        )
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_manifest_structure(manifest):
    assert manifest["model"] in ("tiny", "small", "mini", "tiny-mha")
    cfg = manifest["config"]
    for key in ("vocab", "d_model", "n_layers", "n_heads", "n_kv_heads", "d_ff", "max_seq"):
        assert isinstance(cfg[key], int) and cfg[key] > 0, key
    assert cfg["vocab"] % 128 == 0
    assert manifest["block_size"] > 0
    assert manifest["max_blocks_per_seq"] * manifest["block_size"] == cfg["max_seq"]
    kinds = {e["kind"] for e in manifest["entries"]}
    assert kinds == {"prefill", "decode"}


def test_artifacts_are_hlo_text(manifest):
    paths = [e["path"] for e in manifest["entries"]] + [manifest["aux"]["gptq_matmul"]["path"]]
    for p in paths:
        full = os.path.join(ART, p)
        assert os.path.exists(full), p
        with open(full) as f:
            head = f.read(4096)
        assert "HloModule" in head, f"{p} does not look like HLO text"
        assert "ENTRY" in open(full).read(), p


def test_decode_entries_have_batch_grid(manifest):
    batches = sorted(e["batch"] for e in manifest["entries"] if e["kind"] == "decode")
    assert batches[0] == 1
    assert batches == sorted(set(batches))


def test_prefill_entries_cover_short_prompts(manifest):
    seqs = sorted(e["seq"] for e in manifest["entries"] if e["kind"] == "prefill")
    assert seqs[0] >= 8
    assert seqs[-1] <= manifest["config"]["max_seq"]
