//! E2E driver: the ~100M-parameter `mini` model served under a batched
//! workload through the full router→scheduler→paged-cache→backend path,
//! reporting the paper's metrics (recorded in EXPERIMENTS.md).
//!
//! ```bash
//! cargo run --release --example serve_batch                 # mini, 16 req
//! cargo run --release --example serve_batch -- --model small --requests 32
//! cargo run --release --example serve_batch -- --quantize   # GPTQ int4 first
//! ```

use opt_gptq::coordinator::{BucketPolicy, Engine, EngineConfig, SchedulerConfig};
use opt_gptq::model::weights::{quantize_weights, QuantMethod};
use opt_gptq::model::{ModelConfig, ModelWeights, NativeModel, SamplingParams};
use opt_gptq::runtime::NativeBackend;
use opt_gptq::tokenizer::ByteTokenizer;
use opt_gptq::util::cli::Args;
use opt_gptq::workload::{generate, synth_prompt, LenDist, WorkloadConfig};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    opt_gptq::util::logging::init();
    let args = Args::from_env();
    let preset = args.get_str("model", "mini");
    let cfg = ModelConfig::preset(preset).expect("preset");
    println!("model: {preset} ({} params)", cfg.param_count());

    // Weights, optionally GPTQ-quantized first (full calibration pipeline).
    let t0 = Instant::now();
    let mut weights = ModelWeights::init(&cfg, 0);
    println!("initialized weights in {:.1}s", t0.elapsed().as_secs_f64());
    if args.flag("quantize") {
        let t = Instant::now();
        let model = NativeModel::new(weights.clone());
        let tok = ByteTokenizer::new();
        let calib = tok.encode(&synth_prompt(128, 0));
        let (a, m, f) = model.calibrate(&calib);
        let report = quantize_weights(&mut weights, QuantMethod::Gptq, 4, 128, false, &a, &m, &f);
        println!(
            "GPTQ int4: mean rel err {:.5}, {:.2}× weight compression ({:.1}s)",
            report.mean_error(),
            report.compression_ratio(),
            t.elapsed().as_secs_f64()
        );
    }

    // Engine with a KV budget sized for real concurrency on this model.
    let block_size = 16;
    let kv_tokens = args.get_usize("kv-tokens", 4096);
    let max_batch = args.get_usize("max-batch", 8);
    let backend = NativeBackend::new(NativeModel::new(weights));
    let mut engine = Engine::new(
        Box::new(backend),
        EngineConfig {
            num_blocks: kv_tokens / block_size,
            block_size,
            sched: SchedulerConfig {
                max_running: 32,
                max_decode_batch: max_batch,
                watermark_blocks: 2,
                ..Default::default()
            },
            decode_buckets: BucketPolicy::exact(max_batch),
            prefill_chunk: usize::MAX,
            prefix_cache_blocks: 0,
            // `--kv-dtype q8` serves the same workload from a packed
            // 8-bit KV pool (~0.26× the bytes).
            kv_dtype: opt_gptq::coordinator::KvCacheDtype::parse(
                args.get_str("kv-dtype", "f32"),
            )
            .expect("--kv-dtype f32|q8"),
            weight_dtype: opt_gptq::coordinator::WeightDtype::F32,
            spill: None,
        },
    );
    println!(
        "engine: {} blocks × {} slots = {} KV tokens",
        kv_tokens / block_size,
        block_size,
        engine.capacity_tokens()
    );

    // Batched workload (the paper's offline-batch setting).
    let wl = WorkloadConfig {
        num_requests: args.get_usize("requests", 16),
        arrival_rate: f64::INFINITY,
        prompt_len: LenDist::Uniform(32, 96),
        gen_len: LenDist::Uniform(16, 48),
        seed: args.get_u64("seed", 0),
    };
    let trace = generate(&wl);
    let tok = ByteTokenizer::new();
    for (i, r) in trace.iter().enumerate() {
        let params = SamplingParams { max_tokens: r.gen_len, ..Default::default() };
        engine.add_request(tok.encode(&synth_prompt(r.prompt_len, i as u64)), params)?;
    }
    println!("queued {} requests; serving…", trace.len());

    let report = engine.run_to_completion();
    let outs = engine.take_outputs();
    assert_eq!(outs.len(), trace.len(), "every request must complete");

    println!();
    print!("{}", report.paper_block(&format!("serve_batch ({preset})")));
    println!();
    println!("mean request latency : {:.3}s", report.mean_request_latency_s);
    println!("p95 request latency  : {:.3}s", report.p95_request_latency_s);
    println!("mean TTFT            : {:.3}s", report.mean_ttft_s);
    println!("TTFT p50 / p95       : {:.3}s / {:.3}s", report.ttft_p50_s, report.ttft_p95_s);
    println!(
        "inter-token mean/p95 : {:.4}s / {:.4}s",
        report.mean_inter_token_s, report.p95_inter_token_s
    );
    println!("decode stall steps   : {}", report.decode_stall_steps);
    println!("mean decode batch    : {:.2} seqs", report.mean_decode_batch);
    println!("padding waste        : {:.1}%", report.padding_waste * 100.0);
    println!("preemptions          : {}", report.preemptions);
    println!("peak KV blocks       : {}/{}", report.peak_blocks, kv_tokens / block_size);
    Ok(())
}
