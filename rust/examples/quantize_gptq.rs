//! GPTQ vs RTN quantization walkthrough (the "GPTQ" in Opt-GPTQ).
//!
//! Calibrates a model on synthetic text, quantizes every projection
//! matrix with both GPTQ (Hessian-aware) and RTN (round-to-nearest), and
//! reports per-bit-width layer error + storage — the engine-side pipeline
//! behind the Abl-D bench. Finishes with the **packed-serving parity
//! check**: the GPTQ int4 projections are packed (no f32 round-trip) and
//! served through the fused dequant-matmul, and the logits must be
//! bit-identical to the fake-quant (dequantized-reconstruction) model.
//! `--act-order` turns on GPTQ's decreasing-diagonal column ordering.
//!
//! ```bash
//! cargo run --release --example quantize_gptq -- --model small
//! ```

use opt_gptq::model::weights::{quantize_weights, quantize_weights_packed, QuantMethod};
use opt_gptq::model::{ModelConfig, ModelWeights, NativeModel};
use opt_gptq::tokenizer::ByteTokenizer;
use opt_gptq::util::benchkit::Table;
use opt_gptq::util::cli::Args;
use opt_gptq::workload::synth_prompt;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    opt_gptq::util::logging::init();
    let args = Args::from_env();
    let cfg = ModelConfig::preset(args.get_str("model", "tiny")).expect("preset");
    let act_order = args.flag("act-order");
    let weights = ModelWeights::init(&cfg, 0);
    let model = NativeModel::new(weights.clone());

    // Calibration: a forward pass capturing per-layer activations.
    let tok = ByteTokenizer::new();
    let calib = tok.encode(&synth_prompt(args.get_usize("calib-tokens", 192), 1));
    println!("calibrating on {} tokens…", calib.len());
    let (attn, mlp, ff) = model.calibrate(&calib);

    // Held-out prompt: compare quantized logits against the f32 model —
    // the error GPTQ actually minimizes is *output* error, not weight
    // error (its weight-space error is often higher than RTN's).
    let eval = tok.encode(&synth_prompt(64, 9));
    let logits_of = |m: &NativeModel| -> Vec<f32> {
        let c = m.config();
        let mut cache = opt_gptq::kvcache::PagedKvCache::new(
            c.n_layers,
            16,
            16,
            c.n_kv_heads,
            c.head_dim(),
        );
        let mut alloc = opt_gptq::kvcache::BlockAllocator::new(16, 16);
        let mut table = opt_gptq::kvcache::BlockTable::new();
        table.reserve(eval.len(), &mut alloc);
        m.prefill(&eval, &mut cache, &mut table)
    };
    let ref_logits = logits_of(&model);

    let mut table = Table::new(
        "GPTQ vs RTN",
        &["bits", "group", "GPTQ logit err", "RTN logit err", "GPTQ wins", "compression"],
    );
    for bits in [8u32, 4, 3] {
        let group = args.get_usize("group-size", 64);
        let mut wg = weights.clone();
        let rg =
            quantize_weights(&mut wg, QuantMethod::Gptq, bits, group, act_order, &attn, &mlp, &ff);
        let mut wr = weights.clone();
        let _rr = quantize_weights(&mut wr, QuantMethod::Rtn, bits, group, false, &[], &[], &[]);
        let eg = opt_gptq::quant::relative_error(&ref_logits, &logits_of(&NativeModel::new(wg)));
        let er = opt_gptq::quant::relative_error(&ref_logits, &logits_of(&NativeModel::new(wr)));
        table.row(&[
            bits.to_string(),
            group.to_string(),
            format!("{eg:.5}"),
            format!("{er:.5}"),
            if eg <= er { "yes".into() } else { "NO".into() },
            format!("{:.2}×", rg.compression_ratio()),
        ]);
    }
    table.print();
    println!("\n(logit err = relative error of final-position logits vs f32, held-out prompt)");

    // Packed serving parity: the same GPTQ int4 quantization, kept
    // packed end to end, must serve logits BIT-IDENTICAL to the
    // fake-quant reconstruction — the contract that lets --weight-dtype
    // shrink serving memory without touching sampling.
    let group = args.get_usize("group-size", 64);
    let mut recon = weights.clone();
    quantize_weights(&mut recon, QuantMethod::Gptq, 4, group, act_order, &attn, &mlp, &ff);
    let (packed, _) =
        quantize_weights_packed(&weights, QuantMethod::Gptq, 4, group, act_order, &attn, &mlp, &ff);
    let f32_proj_bytes: usize = weights
        .layers
        .iter()
        .flat_map(|l| {
            [&l.wq, &l.wk, &l.wv, &l.wo, &l.w_gate, &l.w_up, &l.w_down].map(|t| t.len() * 4)
        })
        .sum();
    let packed_bytes = packed.projection_bytes();
    let l_packed = logits_of(&NativeModel::from_store(Arc::new(packed)));
    let l_recon = logits_of(&NativeModel::new(recon));
    assert_eq!(
        l_packed, l_recon,
        "packed q4 serving must be bit-identical to the dequantized reconstruction"
    );
    println!(
        "packed q4 serving: bit-identical to reconstruction ✓  (projection bytes {} → {}, {:.3}×)",
        f32_proj_bytes,
        packed_bytes,
        packed_bytes as f64 / f32_proj_bytes as f64
    );
    Ok(())
}
