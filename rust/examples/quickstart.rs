//! Quickstart: build an engine, serve a few requests, print the paper-style
//! report.
//!
//! ```bash
//! cargo run --release --example quickstart            # native backend
//! cargo run --release --example quickstart -- --xla   # AOT/PJRT backend
//! ```

use opt_gptq::coordinator::{
    BucketPolicy, Engine, EngineConfig, KvCacheDtype, SchedulerConfig, WeightDtype,
};
use opt_gptq::model::weights::{quantize_weights_packed, QuantMethod};
use opt_gptq::model::{ModelConfig, ModelWeights, NativeModel, SamplingParams};
use opt_gptq::runtime::{ArtifactManifest, Backend, NativeBackend, XlaBackend};
use opt_gptq::tokenizer::ByteTokenizer;
use opt_gptq::util::cli::Args;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    opt_gptq::util::logging::init();
    let args = Args::from_env();

    // 1. A model. Presets: tiny (~1M), small (~13M), mini (~100M).
    let cfg = ModelConfig::preset(args.get_str("model", "tiny")).expect("preset");
    let weights = ModelWeights::init(&cfg, 0);

    // 2. A backend: native Rust, or AOT-compiled HLO on PJRT (`--xla`,
    //    needs `make artifacts`). `--kv-dtype q8` packs the KV pool to
    //    8-bit (~0.26× bytes); `--weight-dtype q4` serves the projections
    //    from packed GPTQ/RTN storage (~0.16× the projection bytes,
    //    bit-identical to serving the dequantized reconstruction).
    //    Engine::new rejects both on the XLA backend (it consumes raw
    //    f32 buffers).
    let kv_dtype =
        KvCacheDtype::parse(args.get_str("kv-dtype", "f32")).expect("--kv-dtype f32|q8");
    let weight_dtype = WeightDtype::parse(args.get_str("weight-dtype", "f32"))
        .expect("--weight-dtype f32|q8|q4|q3");
    let (backend, econf): (Box<dyn Backend>, EngineConfig) = if args.flag("xla") {
        assert_eq!(weight_dtype, WeightDtype::F32, "--xla serves f32 weights");
        let manifest = ArtifactManifest::load(std::path::Path::new("artifacts"))?;
        let econf = EngineConfig {
            num_blocks: manifest.num_blocks,
            block_size: manifest.block_size,
            sched: SchedulerConfig {
                max_decode_batch: manifest.max_decode_batch(),
                ..Default::default()
            },
            decode_buckets: BucketPolicy::new(
                manifest.entries.iter().filter(|e| e.kind == "decode").map(|e| e.batch).collect(),
            ),
            prefill_chunk: manifest.max_prefill_seq(),
            prefix_cache_blocks: 0,
            kv_dtype,
            weight_dtype,
            spill: None,
        };
        (Box::new(XlaBackend::load(manifest, &weights)?), econf)
    } else {
        let model = match weight_dtype.bits() {
            None => NativeModel::new(weights),
            Some(bits) => {
                // Calibration-free RTN pack for the demo; `opt-gptq
                // quantize --pack` produces the GPTQ-calibrated artifact.
                let (packed, report) =
                    quantize_weights_packed(&weights, QuantMethod::Rtn, bits, 64, false, &[], &[], &[]);
                println!(
                    "packed weights: {bits}-bit, mean rel err {:.5}, projections {} B",
                    report.mean_error(),
                    packed.projection_bytes()
                );
                NativeModel::from_store(Arc::new(packed))
            }
        };
        let econf = EngineConfig {
            num_blocks: 128,
            block_size: 16,
            sched: SchedulerConfig::default(),
            decode_buckets: BucketPolicy::exact(8),
            prefill_chunk: usize::MAX,
            prefix_cache_blocks: 0,
            kv_dtype,
            weight_dtype,
            spill: None,
        };
        (Box::new(NativeBackend::new(model)), econf)
    };

    // 3. The engine: paged KV cache + continuous batching.
    let mut engine = Engine::new(backend, econf);
    println!(
        "engine up: backend={}, KV pool = {} tokens, weight store = {} B",
        engine.backend_name(),
        engine.capacity_tokens(),
        engine.weight_bytes()
    );

    // 4. Requests.
    let tok = ByteTokenizer::new();
    let prompts = ["the paged cache", "grouped query heads", "share key values"];
    for p in &prompts {
        let params = SamplingParams { max_tokens: 12, ..Default::default() };
        let id = engine.add_request(tok.encode(p), params)?;
        println!("queued request {id}: {p:?}");
    }

    // 5. Run and report (the paper's three headline metrics).
    let report = engine.run_to_completion();
    for out in engine.take_outputs() {
        println!(
            "request {} → {:?} ({} tokens, latency {:.3}s, ttft {:.3}s)",
            out.id,
            tok.decode(&out.tokens),
            out.tokens.len(),
            out.latency_s,
            out.ttft_s
        );
    }
    print!("{}", report.paper_block("quickstart"));
    println!("mean decode batch: {:.2}", engine.metrics.mean_decode_batch());
    Ok(())
}
