//! Quickstart: build an engine, serve a few requests, print the paper-style
//! report.
//!
//! ```bash
//! cargo run --release --example quickstart            # native backend
//! cargo run --release --example quickstart -- --xla   # AOT/PJRT backend
//! ```

use opt_gptq::coordinator::{BucketPolicy, Engine, EngineConfig, KvCacheDtype, SchedulerConfig};
use opt_gptq::model::{ModelConfig, ModelWeights, NativeModel, SamplingParams};
use opt_gptq::runtime::{ArtifactManifest, Backend, NativeBackend, XlaBackend};
use opt_gptq::tokenizer::ByteTokenizer;
use opt_gptq::util::cli::Args;

fn main() -> anyhow::Result<()> {
    opt_gptq::util::logging::init();
    let args = Args::from_env();

    // 1. A model. Presets: tiny (~1M), small (~13M), mini (~100M).
    let cfg = ModelConfig::preset(args.get_str("model", "tiny")).expect("preset");
    let weights = ModelWeights::init(&cfg, 0);

    // 2. A backend: native Rust, or AOT-compiled HLO on PJRT (`--xla`,
    //    needs `make artifacts`). `--kv-dtype q8` packs the KV pool to
    //    8-bit (~0.26× bytes); Engine::new rejects q8 on the XLA backend
    //    (it consumes raw f32 pools).
    let kv_dtype =
        KvCacheDtype::parse(args.get_str("kv-dtype", "f32")).expect("--kv-dtype f32|q8");
    let (backend, econf): (Box<dyn Backend>, EngineConfig) = if args.flag("xla") {
        let manifest = ArtifactManifest::load(std::path::Path::new("artifacts"))?;
        let econf = EngineConfig {
            num_blocks: manifest.num_blocks,
            block_size: manifest.block_size,
            sched: SchedulerConfig {
                max_decode_batch: manifest.max_decode_batch(),
                ..Default::default()
            },
            decode_buckets: BucketPolicy::new(
                manifest.entries.iter().filter(|e| e.kind == "decode").map(|e| e.batch).collect(),
            ),
            prefill_chunk: manifest.max_prefill_seq(),
            prefix_cache_blocks: 0,
            kv_dtype,
        };
        (Box::new(XlaBackend::load(manifest, &weights)?), econf)
    } else {
        let econf = EngineConfig {
            num_blocks: 128,
            block_size: 16,
            sched: SchedulerConfig::default(),
            decode_buckets: BucketPolicy::exact(8),
            prefill_chunk: usize::MAX,
            prefix_cache_blocks: 0,
            kv_dtype,
        };
        (Box::new(NativeBackend::new(NativeModel::new(weights))), econf)
    };

    // 3. The engine: paged KV cache + continuous batching.
    let mut engine = Engine::new(backend, econf);
    println!(
        "engine up: backend={}, KV pool = {} tokens",
        engine.backend_name(),
        engine.capacity_tokens()
    );

    // 4. Requests.
    let tok = ByteTokenizer::new();
    let prompts = ["the paged cache", "grouped query heads", "share key values"];
    for p in &prompts {
        let params = SamplingParams { max_tokens: 12, ..Default::default() };
        let id = engine.add_request(tok.encode(p), params)?;
        println!("queued request {id}: {p:?}");
    }

    // 5. Run and report (the paper's three headline metrics).
    let report = engine.run_to_completion();
    for out in engine.take_outputs() {
        println!(
            "request {} → {:?} ({} tokens, latency {:.3}s, ttft {:.3}s)",
            out.id,
            tok.decode(&out.tokens),
            out.tokens.len(),
            out.latency_s,
            out.ttft_s
        );
    }
    print!("{}", report.paper_block("quickstart"));
    println!("mean decode batch: {:.2}", engine.metrics.mean_decode_batch());
    Ok(())
}
