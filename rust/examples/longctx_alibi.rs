//! Long-sequence serving with paged attention + ALiBi (paper §III.A).
//!
//! Demonstrates the two long-context claims:
//! 1. ALiBi adds position bias with **zero** mask memory, while a
//!    materialized causal mask grows as O(S²);
//! 2. the paged cache spreads a long sequence across non-contiguous
//!    blocks with bounded waste (< one block).
//!
//! ```bash
//! cargo run --release --example longctx_alibi -- --seq 512
//! ```

use opt_gptq::attention::alibi::alibi_slopes;
use opt_gptq::coordinator::{BucketPolicy, Engine, EngineConfig, SchedulerConfig};
use opt_gptq::model::{ModelConfig, ModelWeights, NativeModel, SamplingParams};
use opt_gptq::runtime::NativeBackend;
use opt_gptq::tokenizer::ByteTokenizer;
use opt_gptq::util::cli::Args;
use opt_gptq::workload::synth_prompt;

fn main() -> anyhow::Result<()> {
    opt_gptq::util::logging::init();
    let args = Args::from_env();
    let seq = args.get_usize("seq", 512);
    let gen = args.get_usize("gen", 32);
    let cfg = ModelConfig::small(); // max_seq 1024, ALiBi on
    assert!(seq + gen <= cfg.max_seq, "seq too long for the small preset");

    // --- Claim 1: mask memory. -------------------------------------------
    let mask_bytes = seq * seq * 4; // f32 [S, S] causal mask
    let slope_bytes = cfg.n_heads * 4; // ALiBi slope vector
    println!("sequence length {seq}:");
    println!("  materialized causal mask : {:>12} bytes (O(S²))", mask_bytes);
    println!("  ALiBi slopes             : {:>12} bytes (O(H))", slope_bytes);
    println!(
        "  slopes: {:?}…",
        &alibi_slopes(cfg.n_heads)[..4.min(cfg.n_heads)]
    );

    // --- Claim 2: paged long-context serving. ----------------------------
    let block_size = 16;
    let backend = NativeBackend::new(NativeModel::new(ModelWeights::init(&cfg, 0)));
    let mut engine = Engine::new(
        Box::new(backend),
        EngineConfig {
            num_blocks: (seq + gen) / block_size + 8,
            block_size,
            sched: SchedulerConfig::default(),
            decode_buckets: BucketPolicy::exact(4),
            prefill_chunk: usize::MAX,
            prefix_cache_blocks: 0,
            kv_dtype: opt_gptq::coordinator::KvCacheDtype::F32,
            weight_dtype: opt_gptq::coordinator::WeightDtype::F32,
            spill: None,
        },
    );
    let tok = ByteTokenizer::new();
    let prompt = tok.encode(&synth_prompt(seq - 1, 42)); // -1 for BOS
    assert_eq!(prompt.len(), seq);
    let params = SamplingParams { max_tokens: gen, ..Default::default() };
    engine.add_request(prompt, params)?;

    let report = engine.run_to_completion();
    let out = engine.take_outputs().pop().expect("one output");
    println!();
    println!("served 1 × {seq}-token prompt + {gen} generated:");
    println!("  latency              : {:.3}s", out.latency_s);
    println!("  TTFT (prefill)       : {:.3}s", out.ttft_s);
    println!(
        "  decode rate          : {:.1} tok/s",
        (gen as f64 - 1.0) / (out.latency_s - out.ttft_s).max(1e-9)
    );
    println!("  peak KV blocks       : {}", report.peak_blocks);
    let total = seq + gen;
    let blocks_used = total.div_ceil(block_size);
    println!(
        "  cache waste          : {} slots of {} (< one block)",
        blocks_used * block_size - total,
        blocks_used * block_size
    );
    Ok(())
}
