//! Synthetic request-trace generator.
//!
//! The paper benchmarks a serving engine against a request workload but
//! does not publish its trace, so benches use this generator: Poisson
//! arrivals with configurable prompt/generation length distributions and
//! a fixed seed, making every figure self-contained and reproducible.

use crate::util::rng::Rng;

/// One request in a trace.
#[derive(Debug, Clone)]
pub struct TraceRequest {
    /// Arrival time offset from trace start, seconds.
    pub arrival_s: f64,
    /// Prompt token count (before BOS).
    pub prompt_len: usize,
    /// Number of tokens to generate.
    pub gen_len: usize,
}

/// Length distribution for prompts / generations.
#[derive(Debug, Clone, Copy)]
pub enum LenDist {
    /// Every request has exactly this length.
    Fixed(usize),
    /// Uniform over `[lo, hi]`.
    Uniform(usize, usize),
    /// Log-normal-ish: `exp(N(mu, sigma))` clamped to `[lo, hi]` —
    /// matches the heavy-tailed shape of real serving traces.
    LogNormal { mu: f64, sigma: f64, lo: usize, hi: usize },
}

impl LenDist {
    fn sample(&self, rng: &mut Rng) -> usize {
        match *self {
            LenDist::Fixed(n) => n,
            LenDist::Uniform(lo, hi) => rng.range(lo, hi),
            LenDist::LogNormal { mu, sigma, lo, hi } => {
                let v = (mu + sigma * rng.normal()).exp();
                (v.round() as usize).clamp(lo, hi)
            }
        }
    }
}

/// Workload configuration.
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    pub num_requests: usize,
    /// Mean request arrival rate (requests/second). `f64::INFINITY`
    /// means all requests arrive at t=0 (offline/batch workload).
    pub arrival_rate: f64,
    pub prompt_len: LenDist,
    pub gen_len: LenDist,
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            num_requests: 16,
            arrival_rate: f64::INFINITY,
            prompt_len: LenDist::Uniform(16, 64),
            gen_len: LenDist::Uniform(8, 32),
            seed: 0,
        }
    }
}

/// Generate a trace. Deterministic for a given config.
pub fn generate(cfg: &WorkloadConfig) -> Vec<TraceRequest> {
    let mut rng = Rng::new(cfg.seed);
    let mut t = 0.0f64;
    (0..cfg.num_requests)
        .map(|_| {
            if cfg.arrival_rate.is_finite() {
                t += rng.exponential(cfg.arrival_rate);
            }
            TraceRequest {
                arrival_s: t,
                prompt_len: cfg.prompt_len.sample(&mut rng).max(1),
                gen_len: cfg.gen_len.sample(&mut rng).max(1),
            }
        })
        .collect()
}

/// Deterministic printable prompt of exactly `len` byte-tokens.
pub fn synth_prompt(len: usize, seed: u64) -> String {
    const WORDS: &[&str] = &[
        "the", "model", "serves", "tokens", "with", "paged", "attention", "groups", "share",
        "keys", "values", "memory", "blocks", "fast", "query", "cache",
    ];
    let mut rng = Rng::new(seed);
    let mut s = String::new();
    while s.len() < len {
        if !s.is_empty() {
            s.push(' ');
        }
        s.push_str(rng.choice(WORDS).as_ref());
    }
    s.truncate(len);
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let cfg = WorkloadConfig { seed: 42, ..Default::default() };
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt_len, y.prompt_len);
            assert_eq!(x.gen_len, y.gen_len);
            assert_eq!(x.arrival_s, y.arrival_s);
        }
    }

    #[test]
    fn offline_workload_arrives_at_zero() {
        let cfg = WorkloadConfig::default();
        for r in generate(&cfg) {
            assert_eq!(r.arrival_s, 0.0);
        }
    }

    #[test]
    fn poisson_arrivals_are_monotonic() {
        let cfg = WorkloadConfig { arrival_rate: 5.0, num_requests: 50, ..Default::default() };
        let trace = generate(&cfg);
        for w in trace.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
        }
        // Mean inter-arrival ≈ 1/rate.
        let total = trace.last().unwrap().arrival_s;
        let mean_gap = total / (trace.len() - 1) as f64;
        assert!((mean_gap - 0.2).abs() < 0.1, "mean_gap={mean_gap}");
    }

    #[test]
    fn lengths_respect_bounds() {
        let cfg = WorkloadConfig {
            prompt_len: LenDist::LogNormal { mu: 4.0, sigma: 1.0, lo: 8, hi: 256 },
            gen_len: LenDist::Uniform(4, 9),
            num_requests: 200,
            ..Default::default()
        };
        for r in generate(&cfg) {
            assert!((8..=256).contains(&r.prompt_len));
            assert!((4..=9).contains(&r.gen_len));
        }
    }

    #[test]
    fn synth_prompt_exact_length() {
        for len in [1, 7, 64, 300] {
            assert_eq!(synth_prompt(len, 1).len(), len);
        }
    }
}
