//! Packed storage format shared with the Pallas dequant-matmul kernel.
//!
//! Integer levels are packed little-endian into `i32` words: for 4-bit,
//! 8 levels per word with level `k` in bits `[4k, 4k+4)`; for 8-bit,
//! 4 levels per word. The Python kernel
//! (`python/compile/kernels/gptq_matmul.py`) unpacks with the same shifts,
//! so a matrix packed here can be fed directly to the AOT-compiled HLO as
//! a runtime argument. 3-bit levels are stored in 4-bit fields (simple,
//! and still demonstrates the bits ablation; the *storage_bytes* metric
//! reports true 3-bit size).

use super::{QuantParams, QuantizedMatrix};

/// A nibble/byte-packed quantized matrix plus its grids, ready for upload.
#[derive(Debug, Clone)]
pub struct PackedMatrix {
    pub rows: usize,
    pub cols: usize,
    /// Field width in bits actually used for packing (4 or 8).
    pub pack_bits: u32,
    /// Words per row.
    pub words_per_row: usize,
    /// `[rows, words_per_row]` packed payload.
    pub words: Vec<i32>,
    /// `[rows, groups_per_row]` scales.
    pub scales: Vec<f32>,
    /// `[rows, groups_per_row]` zero points.
    pub zeros: Vec<i32>,
    pub group_size: usize,
}

/// Levels packed per i32 word for a field width.
pub fn levels_per_word(pack_bits: u32) -> usize {
    (32 / pack_bits) as usize
}

fn field_bits(bits: u32) -> u32 {
    if bits <= 4 {
        4
    } else {
        8
    }
}

/// Pack a quantized matrix row-wise.
pub fn pack_rows(m: &QuantizedMatrix) -> PackedMatrix {
    let pack_bits = field_bits(m.bits);
    let lpw = levels_per_word(pack_bits);
    let words_per_row = m.cols.div_ceil(lpw);
    let mut words = vec![0i32; m.rows * words_per_row];
    for r in 0..m.rows {
        for c in 0..m.cols {
            let q = m.q[r * m.cols + c] as u32;
            debug_assert!(q < (1 << pack_bits));
            let w = &mut words[r * words_per_row + c / lpw];
            *w |= ((q as i64) << ((c % lpw) as u32 * pack_bits)) as i32;
        }
    }
    let groups = m.groups_per_row();
    let mut scales = Vec::with_capacity(m.rows * groups);
    let mut zeros = Vec::with_capacity(m.rows * groups);
    for p in &m.params {
        scales.push(p.scale);
        zeros.push(p.zero);
    }
    PackedMatrix {
        rows: m.rows,
        cols: m.cols,
        pack_bits,
        words_per_row,
        words,
        scales,
        zeros,
        group_size: m.group_size,
    }
}

/// Unpack back to integer levels (`[rows, cols]`) — test/oracle path.
pub fn unpack_rows(p: &PackedMatrix) -> Vec<u8> {
    let lpw = levels_per_word(p.pack_bits);
    let mask = (1u32 << p.pack_bits) - 1;
    let mut q = vec![0u8; p.rows * p.cols];
    for r in 0..p.rows {
        for c in 0..p.cols {
            let w = p.words[r * p.words_per_row + c / lpw] as u32;
            q[r * p.cols + c] = ((w >> ((c % lpw) as u32 * p.pack_bits)) & mask) as u8;
        }
    }
    q
}

/// Quantize `vals` onto `p`'s grid and pack the levels little-endian into
/// `words` (the slice is fully rewritten). `p.bits` is the packed field
/// width and must divide 32 (the KV cache packs full bytes; nibble
/// packing works the same way). This is the streaming single-row form of
/// [`pack_rows`]: the quantized paged KV cache packs one `(token,
/// kv_head)` vector per call instead of a whole matrix.
pub fn quant_pack_row(vals: &[f32], p: &QuantParams, words: &mut [i32]) {
    debug_assert!(32 % p.bits == 0, "field width must divide 32");
    let lpw = levels_per_word(p.bits);
    debug_assert!(words.len() >= vals.len().div_ceil(lpw));
    words.fill(0);
    for (c, &x) in vals.iter().enumerate() {
        let q = p.quantize(x) as u32;
        words[c / lpw] |= ((q as i64) << ((c % lpw) as u32 * p.bits)) as i32;
    }
}

/// An `i32` word with every level lane set to `level` — bulk-fill for
/// packed rows holding one constant level. Filling a row with the grid's
/// zero point makes it decode to exactly 0.0, which is how the KV cache
/// refit skips requantizing its known-zero unwritten tail.
pub fn broadcast_level_word(level: i32, pack_bits: u32) -> i32 {
    let lpw = levels_per_word(pack_bits);
    let mask = (1i64 << pack_bits) - 1;
    let mut w = 0i64;
    for i in 0..lpw as u32 {
        w |= (level as i64 & mask) << (i * pack_bits);
    }
    w as i32
}

/// Unpack `out.len()` levels from `words` and dequantize them with one
/// `(scale, zero)` grid — the attention kernel's per-tile dequant
/// primitive (one call per `(tile row, kv_head)`).
#[inline]
pub fn unpack_dequant_row(words: &[i32], pack_bits: u32, scale: f32, zero: i32, out: &mut [f32]) {
    let lpw = levels_per_word(pack_bits);
    let mask = (1u32 << pack_bits) - 1;
    debug_assert!(words.len() * lpw >= out.len());
    for (c, o) in out.iter_mut().enumerate() {
        let w = words[c / lpw] as u32;
        let q = ((w >> ((c % lpw) as u32 * pack_bits)) & mask) as i32;
        *o = (q - zero) as f32 * scale;
    }
}

impl PackedMatrix {
    /// Grids per row (`ceil(cols / group_size)`).
    #[inline]
    pub fn groups_per_row(&self) -> usize {
        self.cols.div_ceil(self.group_size)
    }

    /// Bytes actually held by the packed representation: payload words
    /// plus the per-group scale/zero grids. This is the steady-state
    /// serving footprint the `weight_pool_bytes_*` bench series reports
    /// (3-bit levels ride in 4-bit fields, so q3 counts nibble bytes).
    pub fn packed_bytes(&self) -> usize {
        self.words.len() * 4 + self.scales.len() * 4 + self.zeros.len() * 4
    }

    /// Dequantize one row into `out` (`out.len() == cols`), applying each
    /// group's scale/zero once — the fused dequant-matmul's per-row
    /// primitive (`quant::matmul` calls it once per (tile, row), then
    /// reuses the dequantized tile across every activation row).
    ///
    /// The produced values are **bit-identical** to
    /// [`PackedMatrix::dequantize`]'s (same `(q - zero) as f32 * scale`
    /// expression), which is what anchors the packed-serving bit-identity
    /// contract.
    #[inline]
    pub fn dequant_row_into(&self, row: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.cols);
        let lpw = levels_per_word(self.pack_bits);
        let mask = (1u32 << self.pack_bits) - 1;
        let words = &self.words[row * self.words_per_row..(row + 1) * self.words_per_row];
        let groups = self.groups_per_row();
        let scales = &self.scales[row * groups..(row + 1) * groups];
        let zeros = &self.zeros[row * groups..(row + 1) * groups];
        for g in 0..groups {
            let scale = scales[g];
            let zero = zeros[g];
            let lo = g * self.group_size;
            let hi = (lo + self.group_size).min(self.cols);
            for (c, o) in out[lo..hi].iter_mut().enumerate().map(|(i, o)| (lo + i, o)) {
                let w = words[c / lpw] as u32;
                let q = ((w >> ((c % lpw) as u32 * self.pack_bits)) & mask) as i32;
                *o = (q - zero) as f32 * scale;
            }
        }
    }

    /// Dequantize the packed payload (must equal the source matrix's
    /// `dequantize()` output) — test/oracle path; serving dequantizes
    /// per row-tile inside the fused matmul instead.
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for (r, row_out) in out.chunks_mut(self.cols).enumerate() {
            self.dequant_row_into(r, row_out);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn_quantize;
    use crate::util::rng::Rng;

    #[test]
    fn broadcast_level_word_decodes_to_exact_zero_at_the_zero_point() {
        for bits in [4u32, 8] {
            let lpw = levels_per_word(bits);
            for level in [0i32, 1, 7, (1 << bits) - 1] {
                let w = broadcast_level_word(level, bits);
                let mut out = vec![9.0f32; lpw];
                unpack_dequant_row(&[w], bits, 0.37, level, &mut out);
                assert!(out.iter().all(|&v| v == 0.0), "bits={bits} level={level}: {out:?}");
            }
        }
        assert_eq!(broadcast_level_word(0x7f, 8), 0x7f7f7f7f);
        assert_eq!(broadcast_level_word(0xf, 4), -1i32); // 0xffffffff
    }

    #[test]
    fn pack_unpack_roundtrip_4bit() {
        let mut rng = Rng::new(1);
        let w = rng.normal_vec(8 * 20, 1.0);
        let qm = rtn_quantize(&w, 8, 20, 4, 8);
        let packed = pack_rows(&qm);
        assert_eq!(packed.pack_bits, 4);
        assert_eq!(packed.words_per_row, 3); // ceil(20/8)
        assert_eq!(unpack_rows(&packed), qm.q);
    }

    #[test]
    fn pack_unpack_roundtrip_8bit() {
        let mut rng = Rng::new(2);
        let w = rng.normal_vec(4 * 9, 1.0);
        let qm = rtn_quantize(&w, 4, 9, 8, 4);
        let packed = pack_rows(&qm);
        assert_eq!(packed.pack_bits, 8);
        assert_eq!(packed.words_per_row, 3); // ceil(9/4)
        assert_eq!(unpack_rows(&packed), qm.q);
    }

    #[test]
    fn three_bit_packs_in_nibbles() {
        let mut rng = Rng::new(3);
        let w = rng.normal_vec(2 * 16, 1.0);
        let qm = rtn_quantize(&w, 2, 16, 3, 16);
        let packed = pack_rows(&qm);
        assert_eq!(packed.pack_bits, 4);
        assert_eq!(unpack_rows(&packed), qm.q);
    }

    #[test]
    fn packed_dequantize_matches_matrix() {
        let mut rng = Rng::new(4);
        let w = rng.normal_vec(6 * 33, 1.0);
        let qm = rtn_quantize(&w, 6, 33, 4, 16);
        let packed = pack_rows(&qm);
        let a = qm.dequantize();
        let b = packed.dequantize();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x, y);
        }
    }

    #[test]
    fn quant_pack_row_roundtrips_through_grid() {
        use crate::quant::QuantParams;
        let mut rng = Rng::new(5);
        for &(bits, n) in &[(8u32, 13usize), (8, 4), (4, 9), (8, 1)] {
            let vals = rng.normal_vec(n, 1.0);
            let p = QuantParams::fit(&vals, bits);
            let lpw = levels_per_word(bits);
            let mut words = vec![-1i32; n.div_ceil(lpw)];
            quant_pack_row(&vals, &p, &mut words);
            let mut out = vec![0.0f32; n];
            unpack_dequant_row(&words, bits, p.scale, p.zero, &mut out);
            for (x, y) in vals.iter().zip(&out) {
                assert_eq!(p.roundtrip(*x), *y, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn quant_pack_row_matches_matrix_packing() {
        // One row packed via the streaming helper must be word-identical
        // to the whole-matrix pack_rows path on the same levels.
        use crate::quant::QuantParams;
        let mut rng = Rng::new(6);
        let cols = 11;
        let w = rng.normal_vec(cols, 1.0);
        let qm = rtn_quantize(&w, 1, cols, 8, cols);
        let packed = pack_rows(&qm);
        let p = QuantParams { scale: qm.params[0].scale, zero: qm.params[0].zero, bits: 8 };
        let mut words = vec![0i32; packed.words_per_row];
        quant_pack_row(&w, &p, &mut words);
        assert_eq!(words, packed.words);
    }

    #[test]
    fn roundtrip_grid_over_bits_shapes_and_ragged_groups() {
        // Property-style grid: every supported bit width × shapes whose
        // group size does not divide the column count, single-column
        // matrices, and single-element groups. For each point the packed
        // payload must unpack to the exact source levels and dequantize
        // bit-identically to the unpacked matrix.
        let mut rng = Rng::new(11);
        for &bits in &[2u32, 3, 4, 8] {
            for &(rows, cols) in &[(1usize, 1usize), (3, 1), (2, 5), (4, 20), (3, 33)] {
                for &group in &[1usize, 3, 7, 32, 64] {
                    let w = rng.normal_vec(rows * cols, 1.0);
                    let qm = rtn_quantize(&w, rows, cols, bits, group);
                    let packed = pack_rows(&qm);
                    assert_eq!(packed.pack_bits, if bits <= 4 { 4 } else { 8 });
                    assert_eq!(
                        unpack_rows(&packed),
                        qm.q,
                        "levels: bits={bits} rows={rows} cols={cols} group={group}"
                    );
                    let a = qm.dequantize();
                    let b = packed.dequantize();
                    assert_eq!(
                        a, b,
                        "dequant: bits={bits} rows={rows} cols={cols} group={group}"
                    );
                    // Row-tile primitive agrees with the whole-matrix path.
                    let mut row_out = vec![0.0f32; cols];
                    for r in 0..rows {
                        packed.dequant_row_into(r, &mut row_out);
                        assert_eq!(
                            &a[r * cols..(r + 1) * cols],
                            row_out.as_slice(),
                            "row {r}: bits={bits} cols={cols} group={group}"
                        );
                    }
                    // Byte accounting never undercounts the payload.
                    assert!(packed.packed_bytes() >= packed.words.len() * 4);
                }
            }
        }
    }

    #[test]
    fn high_nibble_values_survive_sign_bit() {
        // Level 15 in the top nibble of a word exercises the i32 sign bit.
        let mut qm = rtn_quantize(&vec![1.0; 8], 1, 8, 4, 8);
        qm.q = vec![15; 8];
        let packed = pack_rows(&qm);
        assert_eq!(unpack_rows(&packed), vec![15; 8]);
        assert!(packed.words[0] < 0, "top nibble set → negative i32");
    }
}
