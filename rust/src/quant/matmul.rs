//! Fused group-wise dequant-matmul — the packed-weight serving kernel.
//!
//! `out = A · Wᵀ` where `W` stays in its packed representation
//! ([`PackedMatrix`]: nibble/byte-packed integer levels + per-(row,
//! group) scale/zero grids). The kernel is cache-blocked over W's rows
//! (output columns): each [`ROW_TILE`]-row tile is dequantized **once**
//! into reusable workspace scratch — every group's scale/zero applied
//! once per (tile row, group) — and then shared by every activation row,
//! so the packed bytes are the only weight traffic per tile and the
//! dequant cost amortizes over `m` activation rows.
//!
//! ## Bit-identity contract
//!
//! The fused kernel is **bit-identical** to
//! [`crate::tensor::matmul_nt_into`] run over the eagerly-dequantized
//! reconstruction ([`PackedMatrix::dequantize`]):
//!
//! * dequantized tile values are produced by the same
//!   `(q - zero) as f32 * scale` expression
//!   ([`PackedMatrix::dequant_row_into`]);
//! * [`ROW_TILE`] is a multiple of 8 and tiles start at multiples of
//!   `ROW_TILE`, so every complete 8-column block of the reference
//!   schedule falls entirely inside one tile and keeps its eight
//!   sequential accumulator chains; the global tail (`n/8*8..n`) uses
//!   the same [`crate::tensor::dot`] reduction.
//!
//! Per-output-element accumulation order is therefore identical, which
//! is what lets packed weights slide under the engine without touching
//! any interleaving/determinism test (see `tests/weights_parity.rs`).
//!
//! ## Zero-alloc and threading
//!
//! Scratch lives in a [`MatmulWorkspace`] (same discipline as
//! `attention::Workspace`): buffers grow once, steady-state calls
//! allocate nothing (audited by `tests/alloc_steadystate.rs`). The
//! allocating wrappers route through a thread-local workspace
//! ([`with_matmul_workspace`]). [`packed_matmul_rows_parallel`] fans
//! activation rows across the persistent worker pool
//! (`crate::runtime::pool`) for prefill/mixed steps — each job
//! re-dequantizes the tiles it walks, so jobs are capped at
//! [`MIN_PACKED_ROWS_PER_JOB`] rows minimum to keep the duplicated
//! dequant a small fraction of each job's MAC work. Outputs are
//! bit-identical at every width (rows are independent). At decode
//! (`m == 1`) the row split is empty — [`packed_gemv_cols_parallel`]
//! fans the *output columns* instead, in [`ROW_TILE`]-aligned spans that
//! preserve the serial schedule per element (and dequantize each tile
//! exactly once across jobs).
//!
//! All f32 inner loops (the 8-row chains and the dot tail) go through
//! the runtime-dispatched kernel table (`crate::tensor::simd`), whose
//! SIMD entries are bit-identical to the scalar reference — the
//! bit-identity contract above survives dispatch unchanged.

use super::packing::PackedMatrix;
use crate::runtime::pool;
use crate::tensor::simd;
use std::cell::RefCell;

/// W rows dequantized per tile (multiple of 8 — required for the
/// bit-identity argument above; 64 rows × a few-hundred-column `k` keeps
/// the tile comfortably in L1/L2).
pub const ROW_TILE: usize = 64;

/// Minimum activation rows per parallel job on the packed path: each
/// job dequantizes its own copy of every tile it needs, so narrower
/// jobs would multiply the chunk's dequant work by the fan-out width
/// (the weight-matmul twin of `attention::paged::MIN_Q8_ROWS_PER_JOB`).
pub const MIN_PACKED_ROWS_PER_JOB: usize = 8;

/// Minimum activation rows per parallel job on the dense path — no
/// dequant to amortize there, this floor only keeps pool-dispatch
/// overhead a small fraction of each job's work.
pub const MIN_DENSE_ROWS_PER_JOB: usize = 8;

/// Floor on per-job multiply-accumulate work before fanning out at all —
/// a tiny matmul is faster run in place than dispatched.
const MIN_MACS_PER_JOB: usize = 1 << 20;

/// Reusable scratch for the fused dequant-matmul: one dequantized
/// [`ROW_TILE`]`× k` weight tile. Grown once per shape, then reused —
/// steady-state fused matmuls perform zero heap allocations.
#[derive(Debug, Default)]
pub struct MatmulWorkspace {
    deq: Vec<f32>,
}

impl MatmulWorkspace {
    pub fn new() -> MatmulWorkspace {
        MatmulWorkspace { deq: Vec::new() }
    }

    #[inline]
    fn ensure(&mut self, len: usize) {
        if self.deq.len() < len {
            self.deq.resize(len, 0.0);
        }
    }
}

thread_local! {
    static WORKSPACE: RefCell<MatmulWorkspace> = RefCell::new(MatmulWorkspace::new());
}

/// Run `f` with this thread's reusable dequant-matmul workspace (the
/// pool's worker threads are persistent, so worker workspaces live
/// across jobs, layers and steps). `f` must not re-enter
/// `with_matmul_workspace`.
pub fn with_matmul_workspace<R>(f: impl FnOnce(&mut MatmulWorkspace) -> R) -> R {
    WORKSPACE.with(|w| f(&mut w.borrow_mut()))
}

/// `out = a · wᵀ` straight off the packed representation: `a` is
/// `[m, w.cols]` row-major activations, `out` is `[m, w.rows]` and fully
/// overwritten. Bit-identical to [`crate::tensor::matmul_nt_into`] over
/// `w.dequantize()` (see the module docs for why), without ever
/// materializing the dense matrix.
pub fn packed_matmul_nt_into(
    a: &[f32],
    m: usize,
    w: &PackedMatrix,
    ws: &mut MatmulWorkspace,
    out: &mut [f32],
) {
    packed_matmul_nt_into_with(simd::active(), a, m, w, ws, out)
}

/// [`packed_matmul_nt_into`] pinned to the scalar kernel table — the bit
/// reference the SIMD parity suite (`tests/simd_parity.rs`) compares the
/// dispatched path against. Not a hot path.
pub fn packed_matmul_nt_into_scalar(
    a: &[f32],
    m: usize,
    w: &PackedMatrix,
    ws: &mut MatmulWorkspace,
    out: &mut [f32],
) {
    packed_matmul_nt_into_with(simd::scalar(), a, m, w, ws, out)
}

fn packed_matmul_nt_into_with(
    kr: &simd::Kernels,
    a: &[f32],
    m: usize,
    w: &PackedMatrix,
    ws: &mut MatmulWorkspace,
    out: &mut [f32],
) {
    let k = w.cols;
    let n = w.rows;
    assert_eq!(a.len(), m * k, "packed_matmul_nt_into: bad A length");
    assert_eq!(out.len(), m * n, "packed_matmul_nt_into: bad out length");
    let n8 = n / 8 * 8;
    ws.ensure(ROW_TILE.min(n) * k);
    let mut tile_start = 0usize;
    while tile_start < n {
        let tile_rows = ROW_TILE.min(n - tile_start);
        let tile_end = tile_start + tile_rows;
        // Dequantize the tile's rows once — every group's scale/zero is
        // applied exactly once per (tile row, group) — then reuse the
        // tile for all `m` activation rows.
        for r in 0..tile_rows {
            w.dequant_row_into(tile_start + r, &mut ws.deq[r * k..(r + 1) * k]);
        }
        let deq = &ws.deq;
        // Complete 8-column blocks of the reference schedule inside this
        // tile (`tile_start` and `blk_end` are both multiples of 8); the
        // 8-row chains and the dot tail go through the dispatched kernel
        // table, whose SIMD entries are bit-identical to the scalar ones
        // (frozen accumulation order — see `tensor::simd`).
        let blk_end = tile_end.min(n8);
        for i in 0..m {
            let a_row = &a[i * k..(i + 1) * k];
            let c_row = &mut out[i * n..(i + 1) * n];
            let mut j = tile_start;
            while j < blk_end {
                let r0 = j - tile_start;
                let mut s = [0.0f32; 8];
                (kr.nt_block8)(a_row, &deq[r0 * k..(r0 + 8) * k], &mut s);
                c_row[j..j + 8].copy_from_slice(&s);
                j += 8;
            }
            // Global tail columns (only the last tile can hold any).
            for j in blk_end..tile_end {
                let rr = j - tile_start;
                c_row[j] = (kr.dot)(a_row, &deq[rr * k..(rr + 1) * k]);
            }
        }
        tile_start = tile_end;
    }
}

/// One job's span of the column-split decode GEMV: W rows
/// `row_start..row_end` (`row_start` must be [`ROW_TILE`]-aligned)
/// against the single activation row `a`, writing
/// `out[0..row_end-row_start]`.
///
/// Because job boundaries are tile-aligned, the span's tile partition
/// and its 8-chain/tail split (computed against the *global* `n8`) are
/// exactly the serial kernel's — each output element sees the identical
/// instruction sequence regardless of how spans are assigned to jobs.
fn packed_gemv_span(
    kr: &simd::Kernels,
    a: &[f32],
    w: &PackedMatrix,
    row_start: usize,
    row_end: usize,
    ws: &mut MatmulWorkspace,
    out: &mut [f32],
) {
    debug_assert_eq!(row_start % ROW_TILE, 0, "span must start on a tile boundary");
    debug_assert!(row_end <= w.rows);
    debug_assert_eq!(out.len(), row_end - row_start);
    let k = w.cols;
    let n8 = w.rows / 8 * 8;
    ws.ensure(ROW_TILE.min(row_end - row_start) * k);
    let mut tile_start = row_start;
    while tile_start < row_end {
        let tile_rows = ROW_TILE.min(row_end - tile_start);
        let tile_end = tile_start + tile_rows;
        for r in 0..tile_rows {
            w.dequant_row_into(tile_start + r, &mut ws.deq[r * k..(r + 1) * k]);
        }
        let deq = &ws.deq;
        let blk_end = tile_end.min(n8);
        let mut j = tile_start;
        while j < blk_end {
            let r0 = j - tile_start;
            let mut s = [0.0f32; 8];
            (kr.nt_block8)(a, &deq[r0 * k..(r0 + 8) * k], &mut s);
            out[j - row_start..j - row_start + 8].copy_from_slice(&s);
            j += 8;
        }
        for j in blk_end..tile_end {
            let rr = j - tile_start;
            out[j - row_start] = (kr.dot)(a, &deq[rr * k..(rr + 1) * k]);
        }
        tile_start = tile_end;
    }
}

/// Allocating convenience wrapper over [`packed_matmul_nt_into`]
/// (thread-local workspace — test/oracle ergonomics; hot paths hold a
/// workspace or go through the parallel driver).
pub fn packed_matmul_nt(a: &[f32], m: usize, w: &PackedMatrix) -> Vec<f32> {
    let mut out = vec![0.0f32; m * w.rows];
    with_matmul_workspace(|ws| packed_matmul_nt_into(a, m, w, ws, &mut out));
    out
}

/// Auto-size a serving matmul's fan-out width for an `[m, k]·[n, k]ᵀ`
/// call: bounded by the pool size, by the caller's `min_rows_per_job`
/// floor (pass the same constant the parallel driver clamps with —
/// [`MIN_PACKED_ROWS_PER_JOB`] or [`MIN_DENSE_ROWS_PER_JOB`] — so the
/// sizing and the clamp can never drift apart), and by a MAC-work floor
/// so small calls (decode GEMVs) stay serial. Purely a performance knob
/// — outputs are identical at every width.
pub fn auto_matmul_threads(m: usize, n: usize, k: usize, min_rows_per_job: usize) -> usize {
    let by_rows = (m / min_rows_per_job.max(1)).max(1);
    let by_work = (m.saturating_mul(n).saturating_mul(k) / MIN_MACS_PER_JOB).max(1);
    pool::global().size().min(by_rows).min(by_work).max(1)
}

/// Shared row-fan-out driver: split `m` activation rows into at most
/// `threads` contiguous chunks of at least `min_rows_per_job` rows each
/// and run `stage(a_chunk, rows, out_chunk)` per chunk on the persistent
/// worker pool (serially in place when one job suffices). Outputs are
/// **bit-identical** at every width: rows are computed independently and
/// a row's instruction order does not depend on the partition.
fn rows_parallel(
    a: &[f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
    min_rows_per_job: usize,
    out: &mut [f32],
    stage: &(dyn Fn(&[f32], usize, &mut [f32]) + Sync),
) {
    assert_eq!(a.len(), m * k, "rows_parallel: bad A length");
    assert_eq!(out.len(), m * n, "rows_parallel: bad out length");
    if m == 0 {
        return;
    }
    let threads = threads.clamp(1, (m / min_rows_per_job).max(1));
    if threads == 1 {
        return stage(a, m, out);
    }
    let per = m.div_ceil(threads);
    let mut jobs: Vec<pool::Job<'_>> = Vec::with_capacity(m.div_ceil(per));
    let mut rest = out;
    let mut start = 0usize;
    while start < m {
        let take = per.min(m - start);
        let (chunk_out, tail) = std::mem::take(&mut rest).split_at_mut(take * n);
        rest = tail;
        let a_chunk = &a[start * k..(start + take) * k];
        jobs.push(Box::new(move || stage(a_chunk, take, chunk_out)));
        start += take;
    }
    pool::global().run(jobs);
}

/// [`packed_matmul_nt_into`] with activation rows fanned across the
/// persistent worker pool (each worker uses its own thread-local
/// [`MatmulWorkspace`], so steady-state parallel calls stay
/// allocation-free on the workers, and scratch grows once per worker —
/// workspaces persist across jobs, layers and steps).
///
/// The effective width is clamped so every job covers at least
/// [`MIN_PACKED_ROWS_PER_JOB`] rows — each job re-dequantizes the tiles
/// it walks, and the clamp bounds that duplicated dequant at a small
/// fraction of the job's MAC work. Bit-identical at every width.
pub fn packed_matmul_rows_parallel(
    a: &[f32],
    m: usize,
    w: &PackedMatrix,
    threads: usize,
    out: &mut [f32],
) {
    rows_parallel(a, m, w.cols, w.rows, threads, MIN_PACKED_ROWS_PER_JOB, out, &|a_chunk, rows, out_chunk| {
        with_matmul_workspace(|ws| packed_matmul_nt_into(a_chunk, rows, w, ws, out_chunk));
    });
}

/// Minimum W rows (output columns) per job in the decode-GEMV column
/// fan-out. Jobs must be a whole number of [`ROW_TILE`]s for the
/// bit-identity argument, and unlike the row fan-out there is **no
/// duplicated dequant to amortize** — the column split partitions W's
/// tiles disjointly — so one tile per job is already sound; the MAC
/// floor in [`auto_gemv_threads`] is what keeps dispatch overhead small.
pub const MIN_GEMV_COLS_PER_JOB: usize = ROW_TILE;

/// Floor on per-job multiply-accumulate work for the GEMV column split.
/// Lower than the row-path `MIN_MACS_PER_JOB`: a decode GEMV is
/// memory-bound on packed weight bytes and each job streams a disjoint
/// span of them, so modest jobs still scale; `m == 1` work can never
/// reach the row path's floor at serving shapes anyway.
const MIN_GEMV_MACS_PER_JOB: usize = 1 << 18;

/// Auto-size the decode-GEMV column fan-out for an `[1, k]·[n, k]ᵀ`
/// call: bounded by the pool, a whole-tile floor
/// ([`MIN_GEMV_COLS_PER_JOB`]), and a MAC floor
/// (`MIN_GEMV_MACS_PER_JOB`). Purely a performance knob — outputs are
/// bit-identical at every width. The complement of
/// [`auto_matmul_threads`], which keeps `m == 1` calls serial because
/// *row* fan-out has no rows to split at decode.
pub fn auto_gemv_threads(n: usize, k: usize) -> usize {
    let by_cols = (n / MIN_GEMV_COLS_PER_JOB.max(1)).max(1);
    let by_work = (n.saturating_mul(k) / MIN_GEMV_MACS_PER_JOB).max(1);
    pool::global().size().min(by_cols).min(by_work).max(1)
}

/// Column-split decode GEMV: `out = a · wᵀ` for a **single** activation
/// row, with W's rows (the output columns) fanned across the persistent
/// worker pool in contiguous [`ROW_TILE`]-aligned spans — the decode-side
/// complement of [`packed_matmul_rows_parallel`], whose row split is
/// empty at `m == 1`.
///
/// **Bit-identical to the serial kernel at every width**: span
/// boundaries are tile-aligned, so each job's tile partition and
/// 8-chain/tail schedule are exactly the serial walk's over its rows;
/// every output element is produced by exactly one job with an unchanged
/// instruction order, and there is no cross-job reduction. Each W tile
/// is dequantized exactly once across all jobs (disjoint spans), so the
/// fan-out adds no dequant work — unlike the row split, which
/// re-dequantizes per job.
pub fn packed_gemv_cols_parallel(a: &[f32], w: &PackedMatrix, threads: usize, out: &mut [f32]) {
    let k = w.cols;
    let n = w.rows;
    assert_eq!(a.len(), k, "packed_gemv_cols_parallel: bad A length");
    assert_eq!(out.len(), n, "packed_gemv_cols_parallel: bad out length");
    if n == 0 {
        return;
    }
    let tiles = n.div_ceil(ROW_TILE);
    let threads = threads.clamp(1, tiles);
    if threads == 1 {
        return with_matmul_workspace(|ws| packed_matmul_nt_into(a, 1, w, ws, out));
    }
    let per_tiles = tiles.div_ceil(threads);
    let mut jobs: Vec<pool::Job<'_>> = Vec::with_capacity(tiles.div_ceil(per_tiles));
    let mut rest = out;
    let mut tile0 = 0usize;
    while tile0 < tiles {
        let take = per_tiles.min(tiles - tile0);
        let row_start = tile0 * ROW_TILE;
        let row_end = n.min((tile0 + take) * ROW_TILE);
        let (chunk_out, tail) = std::mem::take(&mut rest).split_at_mut(row_end - row_start);
        rest = tail;
        jobs.push(Box::new(move || {
            with_matmul_workspace(|ws| {
                packed_gemv_span(simd::active(), a, w, row_start, row_end, ws, chunk_out)
            });
        }));
        tile0 += take;
    }
    pool::global().run(jobs);
}

/// Dense twin of [`packed_matmul_rows_parallel`]: `tensor::matmul_nt`'s
/// schedule through the same `rows_parallel` driver, so dense and
/// packed stores share one threading model (and the `BENCH_gptq.json`
/// comparison is like-for-like). The dense path has no dequant to
/// amortize; its row floor ([`MIN_DENSE_ROWS_PER_JOB`]) only keeps job
/// dispatch overhead small. Bit-identical to the serial form at every
/// width.
pub fn dense_matmul_rows_parallel(
    a: &[f32],
    m: usize,
    k: usize,
    b: &[f32],
    n: usize,
    threads: usize,
    out: &mut [f32],
) {
    assert_eq!(b.len(), n * k, "dense_matmul_rows_parallel: bad B length");
    rows_parallel(a, m, k, n, threads, MIN_DENSE_ROWS_PER_JOB, out, &|a_chunk, rows, out_chunk| {
        crate::tensor::matmul_nt_into(a_chunk, rows, k, b, n, out_chunk);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn_quantize;
    use crate::tensor::matmul_nt_into;
    use crate::util::rng::Rng;

    /// Dense reconstruction via the row-tile primitive (the in-file
    /// oracle; eager `.dequantize()` stays off gated files).
    fn reconstruct(w: &PackedMatrix) -> Vec<f32> {
        let mut dense = vec![0.0f32; w.rows * w.cols];
        for (r, row) in dense.chunks_mut(w.cols).enumerate() {
            w.dequant_row_into(r, row);
        }
        dense
    }

    #[test]
    fn fused_matmul_bit_identical_to_dense_reference_across_grid() {
        // The tentpole contract: for every bit width, ragged output
        // width (n % 8 ≠ 0, n < 8, n > ROW_TILE), ragged group, and
        // activation count (including the decode GEMV m == 1), the fused
        // kernel equals matmul_nt_into over the dequantized
        // reconstruction EXACTLY (same f32 accumulation order).
        let mut rng = Rng::new(21);
        for &bits in &[2u32, 3, 4, 8] {
            for &(m, k, n, group) in &[
                (1usize, 16usize, 9usize, 16usize),
                (3, 24, 7, 5),
                (4, 32, 8, 32),
                (5, 33, 70, 7),
                (2, 16, ROW_TILE + 12, 16),
                (9, 8, 2 * ROW_TILE + 3, 3),
            ] {
                let wd = rng.normal_vec(n * k, 1.0);
                let qm = rtn_quantize(&wd, n, k, bits, group);
                let packed = super::super::pack_rows(&qm);
                let dense = reconstruct(&packed);
                let a = rng.normal_vec(m * k, 1.0);
                let mut want = vec![0.0f32; m * n];
                matmul_nt_into(&a, m, k, &dense, n, &mut want);
                let got = packed_matmul_nt(&a, m, &packed);
                assert_eq!(got, want, "bits={bits} m={m} k={k} n={n} group={group}");
            }
        }
    }

    #[test]
    fn parallel_fan_out_is_bit_identical_at_every_width() {
        let mut rng = Rng::new(22);
        let (m, k, n) = (37usize, 24usize, 50usize);
        let wd = rng.normal_vec(n * k, 1.0);
        let packed = super::super::pack_rows(&rtn_quantize(&wd, n, k, 4, 8));
        let a = rng.normal_vec(m * k, 1.0);
        let serial = packed_matmul_nt(&a, m, &packed);
        for threads in [1usize, 2, 3, 5, 64] {
            let mut out = vec![0.0f32; m * n];
            packed_matmul_rows_parallel(&a, m, &packed, threads, &mut out);
            assert_eq!(out, serial, "threads={threads}");
        }
        // Dense twin too.
        let dense = reconstruct(&packed);
        let mut want = vec![0.0f32; m * n];
        matmul_nt_into(&a, m, k, &dense, n, &mut want);
        for threads in [1usize, 3, 64] {
            let mut out = vec![0.0f32; m * n];
            dense_matmul_rows_parallel(&a, m, k, &dense, n, threads, &mut out);
            assert_eq!(out, want, "dense threads={threads}");
        }
    }

    #[test]
    fn workspace_reuse_across_shapes() {
        // One workspace across growing and shrinking shapes: results
        // stay exact (stale scratch beyond the current shape is ignored).
        let mut rng = Rng::new(23);
        let mut ws = MatmulWorkspace::new();
        for &(m, k, n) in &[(2usize, 8usize, 24usize), (4, 40, 9), (1, 8, 24), (3, 16, 80)] {
            let wd = rng.normal_vec(n * k, 1.0);
            let packed = super::super::pack_rows(&rtn_quantize(&wd, n, k, 8, 16));
            let a = rng.normal_vec(m * k, 1.0);
            let dense = reconstruct(&packed);
            let mut want = vec![0.0f32; m * n];
            matmul_nt_into(&a, m, k, &dense, n, &mut want);
            let mut got = vec![0.0f32; m * n];
            packed_matmul_nt_into(&a, m, &packed, &mut ws, &mut got);
            assert_eq!(got, want, "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn auto_threads_keeps_small_calls_serial() {
        let floor = MIN_PACKED_ROWS_PER_JOB;
        assert_eq!(auto_matmul_threads(1, 4096, 4096, floor), 1, "decode GEMV stays serial");
        assert_eq!(auto_matmul_threads(7, 1 << 14, 1 << 14, floor), 1, "below the row floor");
        assert!(auto_matmul_threads(256, 1024, 1024, MIN_DENSE_ROWS_PER_JOB) >= 1);
    }

    #[test]
    fn gemv_col_split_bit_identical_at_every_width() {
        // The column fan-out must equal the serial m == 1 kernel exactly:
        // ragged n (tail columns, partial last tile, sub-8 widths) and
        // absurd requested widths included.
        let mut rng = Rng::new(29);
        for &(k, n, bits, group) in &[
            (24usize, 7usize, 4u32, 8usize),     // single sub-8 tile
            (16, 70, 8, 16),                     // 8-chains + tail in one tile
            (33, ROW_TILE + 12, 4, 7),           // tile boundary + ragged tail
            (8, 3 * ROW_TILE + 5, 2, 3),         // many tiles
        ] {
            let wd = rng.normal_vec(n * k, 1.0);
            let packed = super::super::pack_rows(&rtn_quantize(&wd, n, k, bits, group));
            let a = rng.normal_vec(k, 1.0);
            let serial = packed_matmul_nt(&a, 1, &packed);
            for threads in [1usize, 2, 3, 5, 64] {
                let mut out = vec![0.0f32; n];
                packed_gemv_cols_parallel(&a, &packed, threads, &mut out);
                assert_eq!(out, serial, "k={k} n={n} threads={threads}");
            }
        }
    }

    #[test]
    fn auto_gemv_threads_heuristic() {
        assert_eq!(auto_gemv_threads(ROW_TILE - 1, 1 << 14), 1, "sub-tile output stays serial");
        assert_eq!(auto_gemv_threads(4 * ROW_TILE, 16), 1, "tiny MAC volume stays serial");
        assert!(auto_gemv_threads(3072, 768) >= 1);
        // The width never exceeds what tile-aligned jobs can use.
        assert!(auto_gemv_threads(usize::MAX / 4, 4) <= pool::global().size().max(1));
    }

    #[test]
    fn scalar_pinned_packed_matmul_matches_dispatched() {
        // Dispatch contract: whatever table is active, the packed kernel
        // must be bit-identical to its scalar-pinned twin.
        let mut rng = Rng::new(31);
        for &(m, k, n) in &[(1usize, 16usize, 9usize), (3, 33, 70), (2, 8, ROW_TILE + 3)] {
            let wd = rng.normal_vec(n * k, 1.0);
            let packed = super::super::pack_rows(&rtn_quantize(&wd, n, k, 4, 8));
            let a = rng.normal_vec(m * k, 1.0);
            let mut ws = MatmulWorkspace::new();
            let mut got = vec![0.0f32; m * n];
            let mut want = vec![0.0f32; m * n];
            packed_matmul_nt_into(&a, m, &packed, &mut ws, &mut got);
            packed_matmul_nt_into_scalar(&a, m, &packed, &mut ws, &mut want);
            assert_eq!(got, want, "m={m} k={k} n={n}");
        }
    }
}
