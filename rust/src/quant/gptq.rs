//! GPTQ: Hessian-aware post-training quantization.
//!
//! Implements the GPTQ algorithm (Frantar et al.) the paper's engine uses
//! for its weight-only quantized serving path:
//!
//! 1. accumulate the layer Hessian `H = 2/n Σ x xᵀ` from calibration
//!    activations;
//! 2. dampen (`H += λ·mean(diag H)·I`) and form the upper-triangular
//!    Cholesky factor of `H⁻¹`;
//! 3. quantize weight columns left-to-right, each time propagating the
//!    rounding error into all not-yet-quantized columns, scaled by the
//!    inverse-Hessian row — so later columns *compensate* earlier
//!    rounding.
//!
//! All linear algebra is done in f64 and lives here (no external linalg
//! crate is available offline): Cholesky decomposition, lower-triangular
//! inversion, and SPD inversion.

use super::{QuantParams, QuantizedMatrix};

/// GPTQ hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct GptqConfig {
    /// Bit width (2..=8).
    pub bits: u32,
    /// Columns sharing one scale/zero pair.
    pub group_size: usize,
    /// Relative diagonal damping λ (GPTQ default 0.01).
    pub damp: f64,
    /// Quantize columns in order of decreasing Hessian diagonal
    /// (GPTQ's `act_order` / `desc_act`).
    pub act_order: bool,
}

impl Default for GptqConfig {
    fn default() -> Self {
        GptqConfig { bits: 4, group_size: 64, damp: 0.01, act_order: false }
    }
}

/// Streaming Hessian accumulator: `H = 2/n Σ x xᵀ` over calibration rows.
#[derive(Debug, Clone)]
pub struct HessianAccumulator {
    dim: usize,
    n: usize,
    h: Vec<f64>,
}

impl HessianAccumulator {
    pub fn new(dim: usize) -> Self {
        HessianAccumulator { dim, n: 0, h: vec![0.0; dim * dim] }
    }

    /// Add `rows` calibration activation rows (`x` is `[rows, dim]`).
    pub fn add_batch(&mut self, x: &[f32], rows: usize) {
        assert_eq!(x.len(), rows * self.dim);
        for r in 0..rows {
            let row = &x[r * self.dim..(r + 1) * self.dim];
            for i in 0..self.dim {
                let xi = row[i] as f64;
                if xi == 0.0 {
                    continue;
                }
                let hrow = &mut self.h[i * self.dim..(i + 1) * self.dim];
                for (j, &xj) in row.iter().enumerate() {
                    hrow[j] += xi * xj as f64;
                }
            }
        }
        self.n += rows;
    }

    /// Finalized Hessian (`[dim, dim]`, row-major).
    pub fn finalize(mut self) -> Vec<f64> {
        let scale = if self.n > 0 { 2.0 / self.n as f64 } else { 1.0 };
        for v in &mut self.h {
            *v *= scale;
        }
        self.h
    }

    pub fn dim(&self) -> usize {
        self.dim
    }
}

/// In-place Cholesky decomposition of an SPD matrix: returns lower L with
/// `L·Lᵀ = A`. Errors if the matrix is not positive definite.
pub fn cholesky(a: &[f64], n: usize) -> Result<Vec<f64>, &'static str> {
    assert_eq!(a.len(), n * n);
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if s <= 0.0 {
                    return Err("matrix not positive definite");
                }
                l[i * n + j] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    Ok(l)
}

/// Invert a lower-triangular matrix.
fn invert_lower(l: &[f64], n: usize) -> Vec<f64> {
    let mut inv = vec![0.0f64; n * n];
    for i in 0..n {
        inv[i * n + i] = 1.0 / l[i * n + i];
        for j in 0..i {
            let mut s = 0.0;
            for k in j..i {
                s += l[i * n + k] * inv[k * n + j];
            }
            inv[i * n + j] = -s / l[i * n + i];
        }
    }
    inv
}

/// Inverse of an SPD matrix via Cholesky: `A⁻¹ = L⁻ᵀ·L⁻¹`.
pub fn spd_inverse(a: &[f64], n: usize) -> Result<Vec<f64>, &'static str> {
    let l = cholesky(a, n)?;
    let li = invert_lower(&l, n);
    // A^-1 = Li^T * Li
    let mut inv = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            let mut s = 0.0;
            // (Li^T Li)[i,j] = sum_k Li[k,i] * Li[k,j]; Li lower → k >= max(i,j)
            for k in i.max(j)..n {
                s += li[k * n + i] * li[k * n + j];
            }
            inv[i * n + j] = s;
        }
    }
    Ok(inv)
}

/// Upper-triangular Cholesky factor of `H⁻¹` (what the GPTQ inner loop
/// consumes): `U` with `Uᵀ·U = H⁻¹`... computed as `U = (L⁻¹)ᵀ·D` where
/// the exact identity used is `H⁻¹ = L⁻ᵀ L⁻¹ = Uᵀ U` with `U = L⁻¹`
/// *read as an upper factor through transposition*.
fn hinv_cholesky_upper(h: &mut [f64], n: usize, damp: f64) -> Result<Vec<f64>, &'static str> {
    // Dampen: H += λ·mean(diag H)·I (and rescue zero columns).
    let mut mean_diag = 0.0;
    for i in 0..n {
        mean_diag += h[i * n + i];
    }
    mean_diag /= n as f64;
    if mean_diag <= 0.0 {
        mean_diag = 1.0;
    }
    let lambda = damp * mean_diag;
    for i in 0..n {
        let d = &mut h[i * n + i];
        if *d == 0.0 {
            *d = mean_diag; // dead input channel: any grid works
        }
        *d += lambda;
    }
    let hinv = spd_inverse(h, n)?;
    // Upper Cholesky of hinv: hinv = L·Lᵀ = Uᵀ·U with U = Lᵀ — the factor
    // whose rows (diagonal rightwards) drive the GPTQ error propagation.
    upper_cholesky(&hinv, n)
}

/// Upper Cholesky: returns `U = Lᵀ` (upper triangular) with `Uᵀ·U = A`.
fn upper_cholesky(a: &[f64], n: usize) -> Result<Vec<f64>, &'static str> {
    let l = cholesky(a, n)?;
    let mut u = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            u[j * n + i] = l[i * n + j];
        }
    }
    Ok(u)
}

/// Quantize `w` (`[rows, cols]` = `[out_features, in_features]`) with GPTQ
/// against a Hessian over the `cols` (input) dimension.
///
/// The returned matrix stores integer levels on the *original* column
/// order even when `act_order` permutes the processing order.
pub fn gptq_quantize(
    w: &[f32],
    rows: usize,
    cols: usize,
    hessian: &[f64],
    cfg: &GptqConfig,
) -> QuantizedMatrix {
    assert_eq!(w.len(), rows * cols);
    assert_eq!(hessian.len(), cols * cols);
    assert!(cfg.group_size > 0);

    // Column processing order (act_order: decreasing Hessian diagonal).
    let mut perm: Vec<usize> = (0..cols).collect();
    if cfg.act_order {
        perm.sort_by(|&a, &b| {
            hessian[b * cols + b]
                .partial_cmp(&hessian[a * cols + a])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
    }

    // Permuted Hessian.
    let mut h = vec![0.0f64; cols * cols];
    for i in 0..cols {
        for j in 0..cols {
            h[i * cols + j] = hessian[perm[i] * cols + perm[j]];
        }
    }
    let u = hinv_cholesky_upper(&mut h, cols, cfg.damp).expect("damped Hessian must be SPD");

    // Working copy of W in permuted column order, f64.
    let mut wp = vec![0.0f64; rows * cols];
    for r in 0..rows {
        for c in 0..cols {
            wp[r * cols + c] = w[r * cols + perm[c]] as f64;
        }
    }

    let groups = cols.div_ceil(cfg.group_size);
    let mut q_perm = vec![0u8; rows * cols]; // levels in permuted order
    let mut params = vec![QuantParams { scale: 1.0, zero: 0, bits: cfg.bits }; rows * groups];

    // Column-by-column quantization with error propagation.
    for c in 0..cols {
        let g = c / cfg.group_size;
        // (Re)fit grids at each group boundary from the *current*
        // error-compensated values of the group's columns.
        if c % cfg.group_size == 0 {
            let hi = (c + cfg.group_size).min(cols);
            for r in 0..rows {
                let vals: Vec<f32> =
                    (c..hi).map(|cc| wp[r * cols + cc] as f32).collect();
                params[r * groups + g] = QuantParams::fit(&vals, cfg.bits);
            }
        }
        let d = u[c * cols + c];
        for r in 0..rows {
            let p = params[r * groups + g];
            let x = wp[r * cols + c];
            let qi = p.quantize(x as f32);
            q_perm[r * cols + c] = qi as u8;
            let xq = p.dequantize(qi) as f64;
            let err = (x - xq) / d;
            // Propagate into the not-yet-quantized columns.
            let urow = &u[c * cols..(c + 1) * cols];
            let wrow = &mut wp[r * cols..(r + 1) * cols];
            for cc in c + 1..cols {
                wrow[cc] -= err * urow[cc];
            }
        }
    }

    // Un-permute: q[orig_col] = q_perm[proc_pos]; per-group params follow
    // the *processing* groups, so re-expand params to per-column grids
    // in original order, then re-group by original columns.
    //
    // To keep the storage format identical to RTN (params per original
    // group), act_order mode stores per-column params via group_size=1
    // semantics when a permutation is active.
    if cfg.act_order {
        let mut q = vec![0u8; rows * cols];
        let mut col_params =
            vec![QuantParams { scale: 1.0, zero: 0, bits: cfg.bits }; rows * cols];
        for c in 0..cols {
            let g = c / cfg.group_size;
            for r in 0..rows {
                q[r * cols + perm[c]] = q_perm[r * cols + c];
                col_params[r * cols + perm[c]] = params[r * groups + g];
            }
        }
        QuantizedMatrix { rows, cols, group_size: 1, bits: cfg.bits, q, params: col_params }
    } else {
        QuantizedMatrix { rows, cols, group_size: cfg.group_size, bits: cfg.bits, q: q_perm, params }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{layer_mse, rtn_quantize};
    use crate::util::rng::Rng;

    fn matmul_nt(x: &[f32], w: &[f32], n: usize, din: usize, dout: usize) -> Vec<f32> {
        // x: [n, din], w: [dout, din] -> [n, dout]
        let mut out = vec![0.0f32; n * dout];
        for i in 0..n {
            for o in 0..dout {
                let mut s = 0.0;
                for k in 0..din {
                    s += x[i * din + k] * w[o * din + k];
                }
                out[i * dout + o] = s;
            }
        }
        out
    }

    /// Correlated calibration activations (what makes GPTQ beat RTN).
    fn correlated_acts(rng: &mut Rng, n: usize, dim: usize) -> Vec<f32> {
        let mut x = vec![0.0f32; n * dim];
        for r in 0..n {
            let base = rng.normal_f32(0.0, 1.0);
            for c in 0..dim {
                // Shared component + per-channel scale structure.
                let chan_scale = 0.2 + 1.8 * (c as f32 / dim as f32);
                x[r * dim + c] = chan_scale * (0.7 * base + 0.3 * rng.normal_f32(0.0, 1.0));
            }
        }
        x
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::new(1);
        let n = 8;
        // SPD: A = B·Bᵀ + I
        let b: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        let mut a = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = if i == j { 1.0 } else { 0.0 };
                for k in 0..n {
                    s += b[i * n + k] * b[j * n + k];
                }
                a[i * n + j] = s;
            }
        }
        let l = cholesky(&a, n).unwrap();
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += l[i * n + k] * l[j * n + k];
                }
                assert!((s - a[i * n + j]).abs() < 1e-9, "({i},{j})");
            }
        }
    }

    #[test]
    fn spd_inverse_identity_check() {
        let mut rng = Rng::new(2);
        let n = 6;
        let b: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        let mut a = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = if i == j { 2.0 } else { 0.0 };
                for k in 0..n {
                    s += b[i * n + k] * b[j * n + k];
                }
                a[i * n + j] = s;
            }
        }
        let inv = spd_inverse(&a, n).unwrap();
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += a[i * n + k] * inv[k * n + j];
                }
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((s - expect).abs() < 1e-8, "({i},{j})={s}");
            }
        }
    }

    #[test]
    fn upper_cholesky_factorizes() {
        let mut rng = Rng::new(3);
        let n = 5;
        let b: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        let mut a = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = if i == j { 1.5 } else { 0.0 };
                for k in 0..n {
                    s += b[i * n + k] * b[j * n + k];
                }
                a[i * n + j] = s;
            }
        }
        let u = upper_cholesky(&a, n).unwrap();
        // Check upper-triangularity and UᵀU = A.
        for i in 0..n {
            for j in 0..i {
                assert_eq!(u[i * n + j], 0.0, "not upper at ({i},{j})");
            }
        }
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += u[k * n + i] * u[k * n + j];
                }
                assert!((s - a[i * n + j]).abs() < 1e-9, "({i},{j})");
            }
        }
    }

    #[test]
    fn identity_hessian_equals_rtn() {
        let mut rng = Rng::new(4);
        let (rows, cols) = (6, 32);
        let w = rng.normal_vec(rows * cols, 1.0);
        let mut h = vec![0.0f64; cols * cols];
        for i in 0..cols {
            h[i * cols + i] = 1.0;
        }
        let cfg = GptqConfig { bits: 4, group_size: 16, damp: 0.01, act_order: false };
        let g = gptq_quantize(&w, rows, cols, &h, &cfg);
        let r = rtn_quantize(&w, rows, cols, 4, 16);
        // Identity Hessian → inverse factor is diagonal → no propagation
        // → same integer levels as RTN.
        assert_eq!(g.q, r.q);
    }

    #[test]
    fn gptq_beats_rtn_on_layer_output() {
        // The GPTQ guarantee: lower *layer output* error wrt the
        // calibration distribution, across seeds and bit widths.
        for seed in [10u64, 11, 12] {
            for bits in [3u32, 4] {
                let mut rng = Rng::new(seed);
                let (rows, cols, n) = (16, 64, 256);
                let w = rng.normal_vec(rows * cols, 1.0);
                let x = correlated_acts(&mut rng, n, cols);

                let mut acc = HessianAccumulator::new(cols);
                acc.add_batch(&x, n);
                let h = acc.finalize();

                let cfg = GptqConfig { bits, group_size: 64, damp: 0.01, act_order: false };
                let g = gptq_quantize(&w, rows, cols, &h, &cfg);
                let r = rtn_quantize(&w, rows, cols, bits, 64);

                let y_ref = matmul_nt(&x, &w, n, cols, rows);
                let y_gptq = matmul_nt(&x, &g.dequantize(), n, cols, rows);
                let y_rtn = matmul_nt(&x, &r.dequantize(), n, cols, rows);
                let e_gptq = layer_mse(&y_ref, &y_gptq);
                let e_rtn = layer_mse(&y_ref, &y_rtn);
                assert!(
                    e_gptq < e_rtn,
                    "seed {seed} bits {bits}: gptq {e_gptq} !< rtn {e_rtn}"
                );
            }
        }
    }

    #[test]
    fn act_order_not_worse() {
        let mut rng = Rng::new(20);
        let (rows, cols, n) = (8, 48, 192);
        let w = rng.normal_vec(rows * cols, 1.0);
        let x = correlated_acts(&mut rng, n, cols);
        let mut acc = HessianAccumulator::new(cols);
        acc.add_batch(&x, n);
        let h = acc.finalize();

        let base = GptqConfig { bits: 3, group_size: 16, damp: 0.01, act_order: false };
        let ao = GptqConfig { act_order: true, ..base };
        let gq = gptq_quantize(&w, rows, cols, &h, &base);
        let ga = gptq_quantize(&w, rows, cols, &h, &ao);

        let y_ref = matmul_nt(&x, &w, n, cols, rows);
        let e_base = layer_mse(&y_ref, &matmul_nt(&x, &gq.dequantize(), n, cols, rows));
        let e_ao = layer_mse(&y_ref, &matmul_nt(&x, &ga.dequantize(), n, cols, rows));
        // act_order should help (or at worst be comparable) on skewed Hessians.
        assert!(e_ao <= e_base * 1.25, "act_order {e_ao} vs base {e_base}");
        assert_eq!(ga.dequantize().len(), rows * cols);
    }

    #[test]
    fn act_order_permutes_and_unpermutes_columns_exactly() {
        // With a DIAGONAL Hessian the inverse factor is diagonal, so no
        // error propagates between columns — processing order cannot
        // change any column's quantization. Per-column grids
        // (group_size 1) remove the grouping difference too. act_order
        // over a scrambled descending diagonal must therefore produce
        // EXACTLY the no-reorder result: columns were processed in
        // desc-diag order and stored back in original positions. A
        // mis-permutation (or a missed un-permutation) would swap
        // columns and fail bit-for-bit.
        let mut rng = Rng::new(50);
        let (rows, cols) = (5, 24);
        let w = rng.normal_vec(rows * cols, 1.0);
        // Distinct diagonal values in scrambled order, so the act_order
        // permutation is a nontrivial derangement of 0..cols.
        let mut h = vec![0.0f64; cols * cols];
        for i in 0..cols {
            h[i * cols + i] = 1.0 + ((i * 7 + 3) % cols) as f64;
        }
        let base = GptqConfig { bits: 4, group_size: 1, damp: 0.01, act_order: false };
        let ao = GptqConfig { act_order: true, ..base };
        let g_base = gptq_quantize(&w, rows, cols, &h, &base);
        let g_ao = gptq_quantize(&w, rows, cols, &h, &ao);
        assert_eq!(g_ao.q, g_base.q, "levels must land on original columns");
        assert_eq!(g_ao.dequantize(), g_base.dequantize());
        // act_order stores per-column grids regardless of the requested
        // group size (the storage contract the packed store relies on).
        assert_eq!(g_ao.group_size, 1);
        assert_eq!(g_ao.params.len(), rows * cols);
    }

    #[test]
    fn dead_channels_are_survivable() {
        // Zero calibration activity on some channels must not break the
        // Cholesky (damping + diagonal rescue).
        let mut rng = Rng::new(30);
        let (rows, cols, n) = (4, 16, 64);
        let w = rng.normal_vec(rows * cols, 1.0);
        let mut x = correlated_acts(&mut rng, n, cols);
        for r in 0..n {
            x[r * cols] = 0.0; // channel 0 dead
            x[r * cols + 7] = 0.0; // channel 7 dead
        }
        let mut acc = HessianAccumulator::new(cols);
        acc.add_batch(&x, n);
        let h = acc.finalize();
        let g = gptq_quantize(&w, rows, cols, &h, &GptqConfig::default());
        assert!(g.dequantize().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn hessian_accumulator_is_symmetric_psd_diag() {
        let mut rng = Rng::new(40);
        let (dim, n) = (12, 100);
        let x = rng.normal_vec(n * dim, 1.0);
        let mut acc = HessianAccumulator::new(dim);
        acc.add_batch(&x, n);
        let h = acc.finalize();
        for i in 0..dim {
            assert!(h[i * dim + i] >= 0.0);
            for j in 0..dim {
                assert!((h[i * dim + j] - h[j * dim + i]).abs() < 1e-9);
            }
        }
    }
}
