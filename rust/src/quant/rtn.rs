//! Round-to-nearest (RTN) quantization — the baseline GPTQ is compared to.

use super::{QuantParams, QuantizedMatrix};

/// Quantize `w` (`[rows, cols]`, row-major, `[out_features, in_features]`)
/// by independent round-to-nearest within each (row, group).
pub fn rtn_quantize(w: &[f32], rows: usize, cols: usize, bits: u32, group_size: usize) -> QuantizedMatrix {
    assert_eq!(w.len(), rows * cols);
    assert!(group_size > 0);
    let groups = cols.div_ceil(group_size);
    let mut q = vec![0u8; rows * cols];
    let mut params = Vec::with_capacity(rows * groups);
    for r in 0..rows {
        let row = &w[r * cols..(r + 1) * cols];
        for g in 0..groups {
            let lo = g * group_size;
            let hi = (lo + group_size).min(cols);
            let p = QuantParams::fit(&row[lo..hi], bits);
            for c in lo..hi {
                q[r * cols + c] = p.quantize(row[c]) as u8;
            }
            params.push(p);
        }
    }
    QuantizedMatrix { rows, cols, group_size, bits, q, params }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn int8_roundtrip_is_tight() {
        let mut rng = Rng::new(1);
        let w = rng.normal_vec(16 * 32, 1.0);
        let qm = rtn_quantize(&w, 16, 32, 8, 32);
        let back = qm.dequantize();
        for (a, b) in w.iter().zip(&back) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
    }

    #[test]
    fn lower_bits_higher_error() {
        let mut rng = Rng::new(2);
        let w = rng.normal_vec(8 * 64, 1.0);
        let err = |bits| {
            let qm = rtn_quantize(&w, 8, 64, bits, 64);
            super::super::layer_mse(&w, &qm.dequantize())
        };
        let (e8, e4, e3) = (err(8), err(4), err(3));
        assert!(e8 < e4 && e4 < e3, "e8={e8} e4={e4} e3={e3}");
    }

    #[test]
    fn grouping_reduces_error_on_heterogeneous_rows() {
        // First half of each row is tiny, second half is large: per-group
        // scales should beat one whole-row scale.
        let cols = 64;
        let mut rng = Rng::new(3);
        let mut w = Vec::new();
        for _ in 0..8 {
            w.extend(rng.normal_vec(cols / 2, 0.01));
            w.extend(rng.normal_vec(cols / 2, 1.0));
        }
        let grouped = rtn_quantize(&w, 8, cols, 4, 32);
        let whole = rtn_quantize(&w, 8, cols, 4, cols);
        let eg = super::super::layer_mse(&w, &grouped.dequantize());
        let ew = super::super::layer_mse(&w, &whole.dequantize());
        assert!(eg < ew, "grouped {eg} vs whole-row {ew}");
    }

    #[test]
    fn ragged_final_group() {
        let mut rng = Rng::new(4);
        let w = rng.normal_vec(4 * 10, 1.0);
        let qm = rtn_quantize(&w, 4, 10, 4, 4); // 3 groups: 4+4+2
        assert_eq!(qm.groups_per_row(), 3);
        assert_eq!(qm.dequantize().len(), 40);
    }
}
