//! Quantization error metrics used by tests and the bits ablation.

/// Mean squared error between two equally-shaped buffers.
pub fn layer_mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let d = (*x - *y) as f64;
            d * d
        })
        .sum::<f64>()
        / a.len() as f64
}

/// Relative Frobenius error `‖a−b‖ / ‖a‖` (0 when `a` is all-zero and b==a).
pub fn relative_error(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        let d = (*x - *y) as f64;
        num += d * d;
        den += (*x as f64) * (*x as f64);
    }
    if den == 0.0 {
        return if num == 0.0 { 0.0 } else { f64::INFINITY };
    }
    (num / den).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mse_zero_for_identical() {
        assert_eq!(layer_mse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
    }

    #[test]
    fn mse_known_value() {
        assert!((layer_mse(&[0.0, 0.0], &[1.0, 1.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn relative_error_scale_invariant() {
        let a = [2.0, 4.0];
        let b = [2.2, 4.4];
        let a10: Vec<f32> = a.iter().map(|x| x * 10.0).collect();
        let b10: Vec<f32> = b.iter().map(|x| x * 10.0).collect();
        assert!((relative_error(&a, &b) - relative_error(&a10, &b10)).abs() < 1e-6);
    }

    #[test]
    fn relative_error_degenerate() {
        assert_eq!(relative_error(&[0.0], &[0.0]), 0.0);
        assert_eq!(relative_error(&[0.0], &[1.0]), f64::INFINITY);
    }
}
