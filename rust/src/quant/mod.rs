//! Post-training weight quantization — the "GPTQ" in Opt-GPTQ.
//!
//! The serving engine holds weight-only quantized matrices (int3/int4/int8,
//! group-wise scales) produced by either:
//!
//! * [`gptq`] — the full GPTQ algorithm: accumulate a Hessian from
//!   calibration activations, invert it with a damped Cholesky, then
//!   quantize column-by-column while propagating the quantization error
//!   into the not-yet-quantized columns;
//! * [`rtn`] — round-to-nearest, the standard baseline GPTQ is measured
//!   against.
//!
//! [`packing`] defines the nibble-packed storage format shared with the
//! Pallas dequant-matmul kernel (`python/compile/kernels/gptq_matmul.py`),
//! and [`matmul`] is the native fused dequant-matmul that serves straight
//! off it (group-major row tiles dequantized once into workspace scratch,
//! bit-identical to the dense reference — the packed-weight serving hot
//! path; see ARCHITECTURE.md "Packed-weight serving").

pub mod error;
pub mod gptq;
pub mod matmul;
pub mod packing;
pub mod rtn;

pub use error::{layer_mse, relative_error};
pub use gptq::{gptq_quantize, GptqConfig, HessianAccumulator};
pub use matmul::{
    auto_gemv_threads, packed_gemv_cols_parallel, packed_matmul_nt, packed_matmul_nt_into,
    packed_matmul_nt_into_scalar, packed_matmul_rows_parallel, MatmulWorkspace,
};
pub use packing::{pack_rows, unpack_rows, PackedMatrix};
pub use rtn::rtn_quantize;

/// Quantization grid parameters for one group of weights.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantParams {
    /// Grid step.
    pub scale: f32,
    /// Integer zero-point (asymmetric grids; 2^(bits-1) for symmetric).
    pub zero: i32,
    /// Bit width (2..=8).
    pub bits: u32,
}

impl QuantParams {
    /// Max representable integer level.
    #[inline]
    pub fn max_q(&self) -> i32 {
        (1 << self.bits) - 1
    }

    /// Quantize one value to an integer level on the grid.
    #[inline]
    pub fn quantize(&self, x: f32) -> i32 {
        let q = (x / self.scale).round() as i32 + self.zero;
        q.clamp(0, self.max_q())
    }

    /// Dequantize an integer level.
    #[inline]
    pub fn dequantize(&self, q: i32) -> f32 {
        (q - self.zero) as f32 * self.scale
    }

    /// Round-trip a value through the grid.
    #[inline]
    pub fn roundtrip(&self, x: f32) -> f32 {
        self.dequantize(self.quantize(x))
    }

    /// Fit an asymmetric min/max grid to a slice of weights.
    pub fn fit(xs: &[f32], bits: u32) -> QuantParams {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &x in xs {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        Self::fit_range(lo, hi, bits)
    }

    /// Fit an asymmetric grid to a known `[lo, hi]` range (the streaming
    /// form of [`QuantParams::fit`] — the quantized KV cache tracks a
    /// running min/max per block and refits from it without rescanning).
    ///
    /// The grid is widened to contain zero so zero values round-trip
    /// exactly; a degenerate or empty range falls back to `scale = 1`.
    pub fn fit_range(lo: f32, hi: f32, bits: u32) -> QuantParams {
        assert!((2..=8).contains(&bits));
        // Grid must contain zero so zero weights stay exact.
        let lo = lo.min(0.0);
        let hi = hi.max(0.0);
        let max_q = ((1u32 << bits) - 1) as f32;
        let mut scale = (hi - lo) / max_q;
        if scale <= 0.0 || !scale.is_finite() {
            scale = 1.0;
        }
        let zero = (-lo / scale).round() as i32;
        QuantParams { scale, zero: zero.clamp(0, max_q as i32), bits }
    }
}

/// A group-wise quantized matrix in `[out_features, in_features]` layout
/// (row-major), with one `QuantParams` per (row, group) pair.
#[derive(Debug, Clone)]
pub struct QuantizedMatrix {
    pub rows: usize,
    pub cols: usize,
    /// Columns covered by one scale/zero pair; `cols` if ungrouped.
    pub group_size: usize,
    pub bits: u32,
    /// Integer levels, row-major `[rows, cols]`.
    pub q: Vec<u8>,
    /// `[rows, ceil(cols/group_size)]` quantization grids.
    pub params: Vec<QuantParams>,
}

impl QuantizedMatrix {
    pub fn groups_per_row(&self) -> usize {
        self.cols.div_ceil(self.group_size)
    }

    #[inline]
    pub fn param(&self, row: usize, col: usize) -> &QuantParams {
        &self.params[row * self.groups_per_row() + col / self.group_size]
    }

    /// Dequantize the whole matrix to f32 (row-major `[rows, cols]`).
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[r * self.cols + c] =
                    self.param(r, c).dequantize(self.q[r * self.cols + c] as i32);
            }
        }
        out
    }

    /// Storage bytes: packed integer payload + scales/zeros.
    pub fn storage_bytes(&self) -> usize {
        let payload = (self.rows * self.cols * self.bits as usize).div_ceil(8);
        let params = self.rows * self.groups_per_row() * (4 + 4); // f32 scale + i32 zero
        payload + params
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_contains_zero_and_extremes() {
        let p = QuantParams::fit(&[-1.0, 0.5, 2.0], 4);
        assert_eq!(p.roundtrip(0.0), 0.0);
        assert!((p.roundtrip(2.0) - 2.0).abs() <= p.scale / 2.0 + 1e-6);
        assert!((p.roundtrip(-1.0) + 1.0).abs() <= p.scale / 2.0 + 1e-6);
    }

    #[test]
    fn quantize_clamps_outliers() {
        let p = QuantParams::fit(&[-1.0, 1.0], 4);
        assert_eq!(p.quantize(100.0), p.max_q());
        assert_eq!(p.quantize(-100.0), 0);
    }

    #[test]
    fn fit_range_matches_fit() {
        let xs = [-1.5f32, 0.25, 2.0, 0.75];
        let a = QuantParams::fit(&xs, 8);
        let b = QuantParams::fit_range(-1.5, 2.0, 8);
        assert_eq!(a, b);
        // Positive-only data still gets a grid anchored at zero.
        let p = QuantParams::fit_range(0.5, 3.0, 8);
        assert_eq!(p.zero, 0);
        assert_eq!(p.roundtrip(0.0), 0.0);
    }

    #[test]
    fn fit_degenerate_all_zero() {
        let p = QuantParams::fit(&[0.0, 0.0], 4);
        assert!(p.scale.is_finite() && p.scale > 0.0);
        assert_eq!(p.roundtrip(0.0), 0.0);
    }

    #[test]
    fn roundtrip_error_bounded_by_half_step() {
        let p = QuantParams::fit(&[-2.0, 3.0], 8);
        for i in 0..100 {
            let x = -2.0 + 5.0 * i as f32 / 99.0;
            assert!((p.roundtrip(x) - x).abs() <= p.scale / 2.0 + 1e-6);
        }
    }

    #[test]
    fn storage_bytes_scaling() {
        let q4 = QuantizedMatrix {
            rows: 4,
            cols: 64,
            group_size: 32,
            bits: 4,
            q: vec![0; 256],
            params: vec![QuantParams { scale: 1.0, zero: 0, bits: 4 }; 8],
        };
        // 4 rows × 64 cols × 4 bits / 8 = 128 payload bytes + 8 × 8 param bytes.
        assert_eq!(q4.storage_bytes(), 128 + 64);
    }
}
