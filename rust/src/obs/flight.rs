//! Crash flight recorder: a fixed ring of the last N engine step
//! records — batch composition, budget use, queue depth, AIMD limit,
//! pool occupancy — written once per step and dumped to the log by the
//! worker supervisor when an engine crashes.
//!
//! The point is post-mortem context: a panic inside `forward_step`
//! tells you *where* it died, the flight ring tells you *what the
//! engine was doing* for the last N steps leading up to it (was the
//! pool pinned? was a preemption storm running? had the AIMD limit
//! collapsed?). The ring is preallocated, bounded, and overwrites
//! oldest-first, so a long-lived engine's memory never grows; the
//! `Arc<Telemetry>` holding it is created by the router *outside* the
//! worker thread, so it survives the engine's panic unwind.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Default flight-ring capacity (step records). The acceptance floor
/// is 64; the default doubles it so a crash dump covers a couple of
/// preemption cycles.
pub const DEFAULT_FLIGHT_RECORDS: usize = 128;

/// One engine step, compressed to the numbers a post-mortem needs.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepRecord {
    /// Monotonic step counter for this engine incarnation.
    pub step: u64,
    /// Engine-clock timestamp, microseconds since engine start.
    pub t_us: u64,
    /// Prefill chunks executed this step.
    pub prefill_chunks: u32,
    /// Prompt tokens those chunks covered.
    pub prefill_tokens: u32,
    /// Decode rows executed this step.
    pub decode_batch: u32,
    /// The step token budget the plan was sized against.
    pub budget_tokens: u32,
    /// Sequences waiting for admission after this step.
    pub waiting: u32,
    /// Sequences in the running set after this step.
    pub running: u32,
    /// Admission-queue depth (router-side gauge at step time).
    pub queue_depth: u32,
    /// AIMD concurrency limit at step time.
    pub aimd_limit: u32,
    /// KV blocks in use after this step.
    pub used_blocks: u32,
    /// KV blocks free after this step.
    pub free_blocks: u32,
}

struct FlightInner {
    slots: Vec<StepRecord>,
    /// Index of the oldest slot once the ring is full.
    head: usize,
    cap: usize,
    total: u64,
}

/// Bounded ring of [`StepRecord`]s with a crash-dump hook.
#[derive(Debug)]
pub struct FlightRecorder {
    inner: Mutex<FlightInner>,
    dumps: AtomicU64,
}

impl std::fmt::Debug for FlightInner {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FlightInner")
            .field("len", &self.slots.len())
            .field("cap", &self.cap)
            .field("total", &self.total)
            .finish()
    }
}

impl FlightRecorder {
    /// Ring with room for `cap ≥ 1` records, fully preallocated.
    pub fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        FlightRecorder {
            inner: Mutex::new(FlightInner {
                slots: Vec::with_capacity(cap),
                head: 0,
                cap,
                total: 0,
            }),
            dumps: AtomicU64::new(0),
        }
    }

    /// Resize the ring (startup configuration — `--flight-records`).
    /// Clears retained records; the new capacity is preallocated here
    /// so the steady state stays allocation-free.
    pub fn set_capacity(&self, cap: usize) {
        let cap = cap.max(1);
        let mut g = self.inner.lock().unwrap();
        g.slots = Vec::with_capacity(cap);
        g.head = 0;
        g.cap = cap;
    }

    /// Current ring capacity in records.
    pub fn capacity(&self) -> usize {
        self.inner.lock().unwrap().cap
    }

    /// Records ever written (including overwritten ones).
    pub fn total(&self) -> u64 {
        self.inner.lock().unwrap().total
    }

    /// Times [`dump_to_log`](Self::dump_to_log) ran (crash-dump count).
    pub fn dumps(&self) -> u64 {
        self.dumps.load(Ordering::Relaxed)
    }

    /// Record one step, evicting the oldest when full. Never allocates
    /// once constructed.
    pub fn record(&self, r: StepRecord) {
        let mut g = self.inner.lock().unwrap();
        if g.slots.len() < g.cap {
            g.slots.push(r);
        } else {
            let h = g.head;
            g.slots[h] = r;
            g.head = (h + 1) % g.cap;
        }
        g.total += 1;
    }

    /// Retained records, oldest → newest. Allocates the result —
    /// debug/dump path only.
    pub fn snapshot(&self) -> Vec<StepRecord> {
        let g = self.inner.lock().unwrap();
        let n = g.slots.len();
        (0..n).map(|i| g.slots[(g.head + i) % n.max(1)]).collect()
    }

    /// Dump the retained ring to the log at `warn` — the supervisor
    /// calls this from the crash branch, so the last N steps of engine
    /// state land next to the panic report.
    pub fn dump_to_log(&self, worker: usize) {
        let records = self.snapshot();
        self.dumps.fetch_add(1, Ordering::Relaxed);
        log::warn!(
            "engine-worker-{worker}: flight recorder dump — {} step record(s), {} written total",
            records.len(),
            self.total(),
        );
        for r in &records {
            log::warn!(
                "engine-worker-{worker}: flight step={} t_us={} prefill={}ch/{}tok \
                 decode={} budget={} wait={} run={} queue={} limit={} blocks={}used/{}free",
                r.step,
                r.t_us,
                r.prefill_chunks,
                r.prefill_tokens,
                r.decode_batch,
                r.budget_tokens,
                r.waiting,
                r.running,
                r.queue_depth,
                r.aimd_limit,
                r.used_blocks,
                r.free_blocks,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: u64) -> StepRecord {
        StepRecord { step, decode_batch: 1, ..StepRecord::default() }
    }

    #[test]
    fn ring_wraps_bounded() {
        let f = FlightRecorder::new(64);
        for s in 0..200u64 {
            f.record(rec(s));
        }
        let snap = f.snapshot();
        assert_eq!(snap.len(), 64, "ring stays bounded at capacity");
        // Oldest 136 evicted: survivors are exactly steps 136..200 in order.
        assert_eq!(snap.first().unwrap().step, 136);
        assert_eq!(snap.last().unwrap().step, 199);
        for w in snap.windows(2) {
            assert_eq!(w[1].step, w[0].step + 1, "chronological order");
        }
        assert_eq!(f.total(), 200);
    }

    #[test]
    fn set_capacity_resizes_and_clears() {
        let f = FlightRecorder::new(4);
        for s in 0..10u64 {
            f.record(rec(s));
        }
        f.set_capacity(2);
        assert_eq!(f.capacity(), 2);
        assert!(f.snapshot().is_empty());
        f.record(rec(1));
        f.record(rec(2));
        f.record(rec(3));
        let snap = f.snapshot();
        assert_eq!(snap.iter().map(|r| r.step).collect::<Vec<_>>(), vec![2, 3]);
    }

    #[test]
    fn dump_counts() {
        let f = FlightRecorder::new(8);
        f.record(rec(1));
        assert_eq!(f.dumps(), 0);
        f.dump_to_log(0);
        assert_eq!(f.dumps(), 1);
    }
}
