//! Per-request trace rings: bounded span records covering a request's
//! life (enqueue → admit → prefill chunks → first token → preemptions →
//! spill restores → finish).
//!
//! The ring is a fixed slot array preallocated at construction; a
//! record is one short `Mutex` critical section writing a `Copy` struct
//! into a slot (no allocation once the ring reaches capacity, and none
//! before it either — the backing `Vec` is reserved up front). The
//! lock-free guarantee of the registry does not extend here, but the
//! critical section is a couple of stores and the ring is only written
//! by the owning worker thread — readers are the debug endpoints.
//!
//! Retention is by eviction, not by request: the ring keeps the most
//! recent `capacity` events across *all* requests, so a long-lived
//! request's earliest spans may have been overwritten by the time it is
//! queried. That is the deal a bounded ring makes; size it with
//! `Telemetry::with_capacities` if the default window is too short.

use std::sync::Mutex;

/// Default per-worker trace ring capacity (events, not requests).
pub const DEFAULT_TRACE_EVENTS: usize = 4096;

/// The span kinds a request can stamp.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceKind {
    /// Request entered the engine (`detail` = prompt tokens).
    Enqueue,
    /// Scheduler admitted it into the running set (`detail` = prefill
    /// start position, i.e. tokens adopted from prefix cache + spill).
    Admit,
    /// One prefill chunk executed (`detail` = chunk tokens).
    Chunk,
    /// First generated token sampled (`detail` = 0).
    FirstToken,
    /// Preempted back to the waiting queue (`detail` = 0).
    Preempt,
    /// KV blocks restored from the disk spill tier at admission
    /// (`detail` = restored tokens).
    SpillRestore,
    /// Request finished (`detail` = generated tokens).
    Finish,
}

impl TraceKind {
    /// Stable lowercase name used in JSON renderings.
    pub fn as_str(self) -> &'static str {
        match self {
            TraceKind::Enqueue => "enqueue",
            TraceKind::Admit => "admit",
            TraceKind::Chunk => "chunk",
            TraceKind::FirstToken => "first_token",
            TraceKind::Preempt => "preempt",
            TraceKind::SpillRestore => "spill_restore",
            TraceKind::Finish => "finish",
        }
    }
}

/// One span record. `Copy` on purpose: recording is a slot store.
#[derive(Debug, Clone, Copy)]
pub struct TraceEvent {
    /// Request id (router-assigned, echoed to the client).
    pub id: u64,
    /// Engine-clock timestamp, microseconds since engine start.
    pub t_us: u64,
    /// Span kind.
    pub kind: TraceKind,
    /// Kind-specific detail (see [`TraceKind`] variants).
    pub detail: u64,
}

struct TraceInner {
    slots: Vec<TraceEvent>,
    /// Index of the oldest slot once the ring is full.
    head: usize,
    total: u64,
}

/// Bounded ring of [`TraceEvent`]s, oldest-evicted.
#[derive(Debug)]
pub struct TraceRing {
    inner: Mutex<TraceInner>,
    cap: usize,
}

impl std::fmt::Debug for TraceInner {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceInner")
            .field("len", &self.slots.len())
            .field("head", &self.head)
            .field("total", &self.total)
            .finish()
    }
}

impl TraceRing {
    /// Ring with room for `cap ≥ 1` events, fully preallocated.
    pub fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        TraceRing {
            inner: Mutex::new(TraceInner { slots: Vec::with_capacity(cap), head: 0, total: 0 }),
            cap,
        }
    }

    /// Ring capacity in events.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Events ever recorded (including evicted ones).
    pub fn total(&self) -> u64 {
        self.inner.lock().unwrap().total
    }

    /// Record one event, evicting the oldest when full. Never
    /// allocates: pushes land inside the reserved capacity, overwrites
    /// after that.
    pub fn record(&self, ev: TraceEvent) {
        let mut g = self.inner.lock().unwrap();
        if g.slots.len() < self.cap {
            g.slots.push(ev);
        } else {
            let h = g.head;
            g.slots[h] = ev;
            g.head = (h + 1) % self.cap;
        }
        g.total += 1;
    }

    /// All retained events for request `id`, in chronological order.
    /// Allocates the result — debug-endpoint path, not the hot path.
    pub fn events_for(&self, id: u64) -> Vec<TraceEvent> {
        let g = self.inner.lock().unwrap();
        let n = g.slots.len();
        (0..n)
            .map(|i| g.slots[(g.head + i) % n.max(1)])
            .filter(|ev| ev.id == id)
            .collect()
    }

    /// Retained event count (≤ capacity).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().slots.len()
    }

    /// True when nothing has been recorded yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(id: u64, t_us: u64) -> TraceEvent {
        TraceEvent { id, t_us, kind: TraceKind::Chunk, detail: 0 }
    }

    #[test]
    fn ring_retains_and_filters() {
        let r = TraceRing::new(8);
        r.record(ev(1, 10));
        r.record(ev(2, 20));
        r.record(ev(1, 30));
        let got = r.events_for(1);
        assert_eq!(got.len(), 2);
        assert_eq!((got[0].t_us, got[1].t_us), (10, 30));
        assert!(r.events_for(3).is_empty());
        assert_eq!(r.total(), 3);
    }

    #[test]
    fn ring_wraps_evicting_oldest() {
        let r = TraceRing::new(4);
        for t in 0..10u64 {
            r.record(ev(7, t));
        }
        let got = r.events_for(7);
        assert_eq!(got.len(), 4);
        // Oldest six evicted; survivors in chronological order.
        assert_eq!(got.iter().map(|e| e.t_us).collect::<Vec<_>>(), vec![6, 7, 8, 9]);
        assert_eq!(r.total(), 10);
        assert_eq!(r.len(), 4);
    }
}
