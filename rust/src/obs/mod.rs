//! Telemetry subsystem: the layer every serving PR reads its own
//! behavior through.
//!
//! Four pieces, all bounded and preallocated:
//!
//! * [`registry`] — lock-free atomic counters/gauges ([`Telemetry`])
//!   plus fixed-bucket log₂-scale latency [`Histogram`]s, mirrored from
//!   `EngineMetrics` once per step and stamped with per-phase
//!   [`StepPhase`] spans by the engine loop.
//! * [`trace`] — per-request [`TraceRing`]: bounded span records
//!   (enqueue → admit → chunks → first token → preemptions → spill
//!   restores → finish), served at `GET /debug/trace/{id}`.
//! * [`flight`] — the crash [`FlightRecorder`]: a fixed ring of recent
//!   step records the supervisor dumps to the log on a worker crash,
//!   served at `GET /debug/flight`.
//! * [`expose`] — Prometheus text exposition for `GET /metrics`, with
//!   per-worker labels.
//!
//! **Placement contract.** Spans are stamped at the coordinator layer
//! only — around the scheduler plan, the single `forward_step` call,
//! sampling, spill offers and the eviction sweep — never inside the
//! attention/matmul kernels (`verify.sh` grep-gates clock reads off the
//! kernel hot-path files). Timing therefore cannot perturb kernel
//! control flow, and the bit-identity contracts hold with telemetry
//! armed by construction. Recording is allocation-free once the rings
//! are built (`tests/alloc_steadystate.rs` audits this with the
//! counting allocator).

pub mod expose;
pub mod flight;
pub mod registry;
pub mod trace;

pub use expose::{render_prometheus, ExtraMetric, PREFIX};
pub use flight::{FlightRecorder, StepRecord, DEFAULT_FLIGHT_RECORDS};
pub use registry::{
    EngineStat, Histogram, MetricDef, MetricKind, StepPhase, Telemetry, ENGINE_STATS, HIST_BUCKETS,
};
pub use trace::{TraceEvent, TraceKind, TraceRing, DEFAULT_TRACE_EVENTS};
