//! Prometheus text exposition (format 0.0.4) over the telemetry
//! registry.
//!
//! One `# HELP`/`# TYPE` pair per metric name, then one sample line per
//! worker with a `worker="i"` label — the shape a federation scraper
//! expects from a multi-worker process. Histograms render the full
//! cumulative `_bucket{le=...}` ladder plus `_sum`/`_count`. Rendering
//! reads the atomics lock-free (the rings are untouched); it allocates
//! the output string, which is fine — scrapes run on the server thread,
//! never the engine loop.

use super::registry::{Histogram, MetricDef, Telemetry, ENGINE_STATS, HIST_BUCKETS};
use crate::obs::StepPhase;

/// Metric name prefix for every exported series.
pub const PREFIX: &str = "opt_gptq";

/// A router-side scalar series injected at scrape time (values the
/// engine cannot see, e.g. supervisor health flags), one value per
/// worker.
pub struct ExtraMetric {
    /// Static series definition (name suffix, help, kind).
    pub def: MetricDef,
    /// `(worker index, value)` samples.
    pub values: Vec<(usize, u64)>,
}

/// Render the full exposition for a set of workers plus any
/// router-side extras. Worker entries are `(worker index, telemetry)`.
pub fn render_prometheus(workers: &[(usize, &Telemetry)], extras: &[ExtraMetric]) -> String {
    // Rough sizing: scalar table + 6 histograms × 30 lines, per worker.
    let mut out = String::with_capacity(4096 + workers.len() * 16 * 1024);
    for (row, def) in ENGINE_STATS.iter().enumerate() {
        header(&mut out, def);
        for &(w, t) in workers {
            sample(&mut out, def.name, w, t.get_by_index(row));
        }
    }
    for phase in StepPhase::ALL {
        let name = format!("step_time_{}_us", phase.as_str());
        out.push_str(&format!(
            "# HELP {PREFIX}_{name} Wall time of the {} phase per engine step, microseconds.\n",
            phase.as_str()
        ));
        out.push_str(&format!("# TYPE {PREFIX}_{name} histogram\n"));
        for &(w, t) in workers {
            histogram(&mut out, &name, w, t.phase(phase));
        }
    }
    for extra in extras {
        header(&mut out, &extra.def);
        for &(w, v) in &extra.values {
            sample(&mut out, extra.def.name, w, v);
        }
    }
    out
}

fn header(out: &mut String, def: &MetricDef) {
    out.push_str(&format!("# HELP {PREFIX}_{} {}\n", def.name, def.help));
    out.push_str(&format!("# TYPE {PREFIX}_{} {}\n", def.name, def.kind.as_str()));
}

fn sample(out: &mut String, name: &str, worker: usize, v: u64) {
    out.push_str(&format!("{PREFIX}_{name}{{worker=\"{worker}\"}} {v}\n"));
}

fn histogram(out: &mut String, name: &str, worker: usize, h: &Histogram) {
    let mut cum = 0u64;
    for i in 0..HIST_BUCKETS {
        cum += h.bucket_count(i);
        match Histogram::bucket_bound_us(i) {
            Some(b) => out.push_str(&format!(
                "{PREFIX}_{name}_bucket{{worker=\"{worker}\",le=\"{b}\"}} {cum}\n"
            )),
            None => out.push_str(&format!(
                "{PREFIX}_{name}_bucket{{worker=\"{worker}\",le=\"+Inf\"}} {cum}\n"
            )),
        }
    }
    out.push_str(&format!("{PREFIX}_{name}_sum{{worker=\"{worker}\"}} {}\n", h.sum_us()));
    out.push_str(&format!("{PREFIX}_{name}_count{{worker=\"{worker}\"}} {}\n", h.count()));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{EngineStat, MetricKind};

    /// Minimal grammar check for one sample line:
    /// `name{label="v",...} value` with a bare-integer value.
    fn is_sample_line(line: &str) -> bool {
        let Some(brace) = line.find('{') else {
            // Unlabeled sample: `name value`.
            let mut parts = line.split_whitespace();
            let (Some(name), Some(value), None) = (parts.next(), parts.next(), parts.next())
            else {
                return false;
            };
            return is_metric_name(name) && value.parse::<f64>().is_ok();
        };
        let name = &line[..brace];
        let Some(close) = line.rfind('}') else { return false };
        let labels = &line[brace + 1..close];
        let value = line[close + 1..].trim();
        is_metric_name(name)
            && value.parse::<f64>().is_ok()
            && labels.split(',').all(|kv| {
                let Some((k, v)) = kv.split_once('=') else { return false };
                !k.is_empty()
                    && k.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
                    && v.starts_with('"')
                    && v.ends_with('"')
            })
    }

    fn is_metric_name(name: &str) -> bool {
        !name.is_empty()
            && !name.starts_with(|c: char| c.is_ascii_digit())
            && name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
    }

    #[test]
    fn exposition_grammar_holds_on_every_line() {
        let t = Telemetry::new();
        t.set(EngineStat::MixedSteps, 12);
        t.phase(StepPhase::Plan).observe_us(100);
        let extras = [ExtraMetric {
            def: MetricDef {
                name: "worker_healthy",
                help: "1 while the worker accepts requests.",
                kind: MetricKind::Gauge,
            },
            values: vec![(0, 1)],
        }];
        let text = render_prometheus(&[(0, &t)], &extras);
        assert!(text.ends_with('\n'), "exposition must end with a newline");
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("# HELP ") {
                assert!(rest.split(' ').next().unwrap().starts_with(PREFIX), "{line}");
            } else if let Some(rest) = line.strip_prefix("# TYPE ") {
                let mut it = rest.split(' ');
                let name = it.next().unwrap();
                let kind = it.next().unwrap();
                assert!(name.starts_with(PREFIX), "{line}");
                assert!(
                    matches!(kind, "counter" | "gauge" | "histogram"),
                    "bad TYPE keyword: {line}"
                );
            } else {
                assert!(is_sample_line(line), "malformed sample line: {line}");
            }
        }
    }

    #[test]
    fn counter_vs_gauge_typing_matches_table() {
        let t = Telemetry::new();
        let text = render_prometheus(&[(0, &t)], &[]);
        assert!(text.contains("# TYPE opt_gptq_mixed_steps counter"));
        assert!(text.contains("# TYPE opt_gptq_shed_count counter"));
        assert!(text.contains("# TYPE opt_gptq_concurrency_limit gauge"));
        assert!(text.contains("# TYPE opt_gptq_queue_depth gauge"));
        assert!(text.contains("# TYPE opt_gptq_peak_blocks gauge"));
        assert!(text.contains("# TYPE opt_gptq_step_time_plan_us histogram"));
    }

    #[test]
    fn per_worker_labels_and_values() {
        let a = Telemetry::new();
        let b = Telemetry::new();
        a.set(EngineStat::ShedCount, 3);
        b.set(EngineStat::ShedCount, 9);
        let text = render_prometheus(&[(0, &a), (1, &b)], &[]);
        assert!(text.contains("opt_gptq_shed_count{worker=\"0\"} 3\n"));
        assert!(text.contains("opt_gptq_shed_count{worker=\"1\"} 9\n"));
        // HELP/TYPE emitted once per metric name, not once per worker.
        assert_eq!(text.matches("# TYPE opt_gptq_shed_count ").count(), 1);
    }

    #[test]
    fn histogram_ladder_is_cumulative_and_complete() {
        let t = Telemetry::new();
        t.phase(StepPhase::Decode).observe_us(3); // bucket le="4"
        t.phase(StepPhase::Decode).observe_us(3);
        t.phase(StepPhase::Decode).observe_us(1 << 30); // +Inf bucket
        let text = render_prometheus(&[(0, &t)], &[]);
        assert!(text.contains("opt_gptq_step_time_decode_us_bucket{worker=\"0\",le=\"2\"} 0\n"));
        assert!(text.contains("opt_gptq_step_time_decode_us_bucket{worker=\"0\",le=\"4\"} 2\n"));
        assert!(text.contains("opt_gptq_step_time_decode_us_bucket{worker=\"0\",le=\"+Inf\"} 3\n"));
        assert!(text.contains("opt_gptq_step_time_decode_us_count{worker=\"0\"} 3\n"));
        let n_buckets = text
            .lines()
            .filter(|l| l.starts_with("opt_gptq_step_time_decode_us_bucket{worker=\"0\""))
            .count();
        assert_eq!(n_buckets, HIST_BUCKETS, "full le ladder rendered");
    }
}
