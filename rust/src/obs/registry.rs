//! Lock-free metrics registry: atomic counters/gauges plus fixed-bucket
//! log₂-scale latency histograms.
//!
//! Everything here is sized and allocated at construction
//! ([`Telemetry::new`]); recording is a handful of `Relaxed` atomic adds
//! with no locks and no allocation, so arming telemetry cannot perturb
//! the engine's zero-alloc steady-state contract (audited by
//! `tests/alloc_steadystate.rs`). Reads are equally lock-free — a
//! `/metrics` scrape never stalls a worker.
//!
//! The registry is deliberately *mirror-shaped*: the engine keeps
//! accumulating into its plain-field [`EngineMetrics`] exactly as
//! before (single-threaded, no atomics on the hot path beyond what the
//! mirror costs once per step), and [`EngineMetrics::mirror_into`]
//! copies every counter into this registry's atomics at the end of each
//! step. The server thread then reads the atomics without touching the
//! engine. One mirror per step, not one atomic RMW per event.
//!
//! [`EngineMetrics`]: crate::coordinator::EngineMetrics
//! [`EngineMetrics::mirror_into`]: crate::coordinator::EngineMetrics::mirror_into

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use super::flight::{FlightRecorder, DEFAULT_FLIGHT_RECORDS};
use super::trace::{TraceRing, DEFAULT_TRACE_EVENTS};

/// Metric kind for the Prometheus exposition (`# TYPE` line).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricKind {
    /// Monotonically non-decreasing over an engine incarnation.
    Counter,
    /// Instantaneous level; may go down.
    Gauge,
}

impl MetricKind {
    /// Prometheus `# TYPE` keyword.
    pub fn as_str(self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
        }
    }
}

/// Static description of one exported scalar series.
#[derive(Debug, Clone, Copy)]
pub struct MetricDef {
    /// Metric name *suffix* (the exposition prepends `opt_gptq_`).
    pub name: &'static str,
    /// One-line `# HELP` text.
    pub help: &'static str,
    /// Counter vs gauge typing.
    pub kind: MetricKind,
}

/// Every scalar the engine mirrors into the registry, one enum variant
/// per [`ENGINE_STATS`] row (the discriminant is the row index).
///
/// The list covers every `EngineMetrics` counter — scheduling, sparse
/// attention, overload control, and the spill tier — plus the
/// router-side queue gauges the worker loop stamps in directly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(usize)]
pub enum EngineStat {
    /// Requests finished (≡ `RunReport` record count).
    RequestsCompleted = 0,
    /// Engine steps that executed any work.
    MixedSteps,
    /// Prefill chunks executed (a prompt spans several).
    PrefillChunks,
    /// Prompt tokens processed through prefill chunks.
    PrefillChunkTokens,
    /// Steps that decoded at least one token.
    DecodeSteps,
    /// Decode tokens generated.
    DecodeBatchTokens,
    /// Decode tokens after bucket padding (batch-shape waste metric).
    DecodeBucketTokens,
    /// Steps where decoders existed but none could run.
    DecodeStallSteps,
    /// Inter-token gaps observed (windowed ITL sample count).
    InterTokenCount,
    /// Sum of inter-token gaps, microseconds.
    InterTokenSumUs,
    /// Sequences preempted under memory pressure.
    Preemptions,
    /// High-water mark of KV blocks in use.
    PeakBlocks,
    /// Prompt tokens served from the RAM prefix cache.
    PrefixHitTokens,
    /// KV tiles dequantized during prefill walks.
    PrefillDequantTiles,
    /// Bytes moved by dense `KvStore::gather` dumps (≈ 0 in serving).
    GatherBytes,
    /// KV tiles skipped by the score-bound sparse test.
    SkippedTiles,
    /// KV blocks evicted by the sliding-window policy.
    EvictedBlocks,
    /// Requests shed by admission control (queue full).
    ShedCount,
    /// Requests shed because their deadline passed while queued.
    DeadlineMissCount,
    /// Current AIMD concurrency limit.
    ConcurrencyLimit,
    /// Worker crash-restarts performed by the supervisor.
    WorkerRestarts,
    /// Prompt tokens restored from the disk spill tier.
    SpillHitTokens,
    /// Bytes appended to spill segments.
    SpillBytes,
    /// Spill records quarantined by checksum failures.
    SpillCorruptRecords,
    /// Restorable records currently indexed by the spill tier.
    SpillRecords,
    /// Bytes currently committed across spill segments.
    SpillDiskBytes,
    /// Live spill IO failures (reads + writes).
    SpillIoFailures,
    /// Requests waiting in the admission queue (router-side gauge).
    QueueDepth,
    /// Requests admitted into the engine and not yet answered.
    InflightRequests,
}

/// Exposition metadata for every [`EngineStat`], indexed by
/// discriminant. Order must match the enum exactly.
pub const ENGINE_STATS: &[MetricDef] = &[
    MetricDef {
        name: "requests_completed",
        help: "Requests finished by this worker's engine.",
        kind: MetricKind::Counter,
    },
    MetricDef {
        name: "mixed_steps",
        help: "Engine steps that executed any work.",
        kind: MetricKind::Counter,
    },
    MetricDef {
        name: "prefill_chunks",
        help: "Prefill chunks executed (a prompt spans several).",
        kind: MetricKind::Counter,
    },
    MetricDef {
        name: "prefill_chunk_tokens",
        help: "Prompt tokens processed through prefill chunks.",
        kind: MetricKind::Counter,
    },
    MetricDef {
        name: "decode_steps",
        help: "Steps that decoded at least one token.",
        kind: MetricKind::Counter,
    },
    MetricDef {
        name: "decode_batch_tokens",
        help: "Decode tokens generated.",
        kind: MetricKind::Counter,
    },
    MetricDef {
        name: "decode_bucket_tokens",
        help: "Decode tokens after bucket padding (batch-shape waste).",
        kind: MetricKind::Counter,
    },
    MetricDef {
        name: "decode_stall_steps",
        help: "Steps where decoders existed but none could run.",
        kind: MetricKind::Counter,
    },
    MetricDef {
        name: "inter_token_count",
        help: "Inter-token gaps observed (windowed ITL samples).",
        kind: MetricKind::Counter,
    },
    MetricDef {
        name: "inter_token_sum_us",
        help: "Sum of observed inter-token gaps in microseconds.",
        kind: MetricKind::Counter,
    },
    MetricDef {
        name: "preemptions",
        help: "Sequences preempted under memory pressure.",
        kind: MetricKind::Counter,
    },
    MetricDef {
        name: "peak_blocks",
        help: "High-water mark of KV blocks in use.",
        kind: MetricKind::Gauge,
    },
    MetricDef {
        name: "prefix_hit_tokens",
        help: "Prompt tokens served from the RAM prefix cache.",
        kind: MetricKind::Counter,
    },
    MetricDef {
        name: "prefill_dequant_tiles",
        help: "KV tiles dequantized during prefill walks.",
        kind: MetricKind::Counter,
    },
    MetricDef {
        name: "gather_bytes",
        help: "Bytes moved by dense KvStore::gather dumps (~0 serving).",
        kind: MetricKind::Counter,
    },
    MetricDef {
        name: "skipped_tiles",
        help: "KV tiles skipped by the score-bound sparse test.",
        kind: MetricKind::Counter,
    },
    MetricDef {
        name: "evicted_blocks",
        help: "KV blocks evicted by the sliding-window policy.",
        kind: MetricKind::Counter,
    },
    MetricDef {
        name: "shed_count",
        help: "Requests shed by admission control (queue full).",
        kind: MetricKind::Counter,
    },
    MetricDef {
        name: "deadline_miss_count",
        help: "Requests shed because their deadline passed while queued.",
        kind: MetricKind::Counter,
    },
    MetricDef {
        name: "concurrency_limit",
        help: "Current AIMD concurrency limit.",
        kind: MetricKind::Gauge,
    },
    MetricDef {
        name: "worker_restarts",
        help: "Crash-restarts performed by the supervisor.",
        kind: MetricKind::Counter,
    },
    MetricDef {
        name: "spill_hit_tokens",
        help: "Prompt tokens restored from the disk spill tier.",
        kind: MetricKind::Counter,
    },
    MetricDef {
        name: "spill_bytes",
        help: "Bytes appended to spill segments.",
        kind: MetricKind::Counter,
    },
    MetricDef {
        name: "spill_corrupt_records",
        help: "Spill records quarantined by checksum failures.",
        kind: MetricKind::Counter,
    },
    MetricDef {
        name: "spill_records",
        help: "Restorable records currently indexed by the spill tier.",
        kind: MetricKind::Gauge,
    },
    MetricDef {
        name: "spill_disk_bytes",
        help: "Bytes currently committed across spill segments.",
        kind: MetricKind::Gauge,
    },
    MetricDef {
        name: "spill_io_failures",
        help: "Live spill IO failures (reads + writes).",
        kind: MetricKind::Counter,
    },
    MetricDef {
        name: "queue_depth",
        help: "Requests waiting in the admission queue.",
        kind: MetricKind::Gauge,
    },
    MetricDef {
        name: "inflight_requests",
        help: "Requests admitted into the engine and not yet answered.",
        kind: MetricKind::Gauge,
    },
];

/// The step phases the engine stamps into latency histograms — spans
/// taken at the **coordinator layer only**. Kernels are never timed
/// from inside (a clock read in the attention/matmul inner loops would
/// cost every tile and tempt data-dependent control flow, so the
/// bit-identity argument stays structural; `verify.sh` grep-gates
/// `Instant::now` off the kernel hot-path files).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(usize)]
pub enum StepPhase {
    /// Scheduler planning (includes prefix-cache lookups and any disk
    /// spill restores performed at admission).
    Plan = 0,
    /// `forward_step` wall time for steps carrying ≥ 1 prefill chunk
    /// (the chunk dominates the step's cost; decode rows ride along).
    Prefill,
    /// `forward_step` wall time for decode-only steps — the
    /// inter-token-latency-critical number.
    Decode,
    /// Post-forward sampling, bookkeeping and request finish handling.
    Sample,
    /// Prefix-cache eviction offers into the disk spill tier (write
    /// side; only stamped when a tier is armed).
    Spill,
    /// The sliding-window KV eviction sweep.
    Evict,
}

impl StepPhase {
    /// Every phase, in discriminant order.
    pub const ALL: [StepPhase; 6] = [
        StepPhase::Plan,
        StepPhase::Prefill,
        StepPhase::Decode,
        StepPhase::Sample,
        StepPhase::Spill,
        StepPhase::Evict,
    ];

    /// Stable lowercase name used in metric names and docs.
    pub fn as_str(self) -> &'static str {
        match self {
            StepPhase::Plan => "plan",
            StepPhase::Prefill => "prefill",
            StepPhase::Decode => "decode",
            StepPhase::Sample => "sample",
            StepPhase::Spill => "spill",
            StepPhase::Evict => "evict",
        }
    }
}

/// Number of histogram buckets: finite upper bounds 2⁰..2²⁶ µs
/// (1 µs .. ~67 s) plus a `+Inf` overflow bucket.
pub const HIST_BUCKETS: usize = 28;

/// Fixed-bucket log₂-scale latency histogram over microseconds.
///
/// Bucket `i < 27` counts samples `v` with `v ≤ 2^i` µs (and, for
/// `i > 0`, `v > 2^(i-1)`); the last bucket is the `+Inf` overflow.
/// Storage is a fixed array of atomics — recording is two `Relaxed`
/// adds and one `fetch_add` on the bucket, allocation-free and
/// wait-free.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// An empty histogram (all storage inline, no heap).
    pub fn new() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }

    /// Bucket index for a sample of `us` microseconds:
    /// `ceil(log2(us))` clamped to the `+Inf` bucket (0 and 1 µs both
    /// land in bucket 0, bound 1 µs).
    pub fn bucket_index(us: u64) -> usize {
        if us <= 1 {
            0
        } else {
            let idx = (64 - (us - 1).leading_zeros()) as usize;
            idx.min(HIST_BUCKETS - 1)
        }
    }

    /// Inclusive upper bound of bucket `i` in µs; `None` for `+Inf`.
    pub fn bucket_bound_us(i: usize) -> Option<u64> {
        if i + 1 < HIST_BUCKETS {
            Some(1u64 << i)
        } else {
            None
        }
    }

    /// Record one sample.
    pub fn observe_us(&self, us: u64) {
        self.buckets[Self::bucket_index(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    /// Record one span duration (saturating at u64 µs).
    pub fn observe(&self, d: Duration) {
        self.observe_us(d.as_micros().min(u64::MAX as u128) as u64);
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all samples, microseconds.
    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    /// Raw (non-cumulative) count in bucket `i`.
    pub fn bucket_count(&self, i: usize) -> u64 {
        self.buckets[i].load(Ordering::Relaxed)
    }

    /// Upper-bound estimate of the `q`-quantile (`0 < q ≤ 1`): the
    /// bound of the first bucket whose cumulative count reaches
    /// `q · count`. Returns 0 for an empty histogram; samples in the
    /// `+Inf` bucket report the largest finite bound.
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for i in 0..HIST_BUCKETS {
            cum += self.bucket_count(i);
            if cum >= rank {
                return Self::bucket_bound_us(i)
                    .unwrap_or_else(|| Self::bucket_bound_us(HIST_BUCKETS - 2).unwrap());
            }
        }
        Self::bucket_bound_us(HIST_BUCKETS - 2).unwrap()
    }
}

/// One worker's complete telemetry surface: the scalar mirror of
/// `EngineMetrics`, six per-phase step-time histograms, the crash
/// flight recorder, and the per-request trace ring.
///
/// Created once (per worker) and shared by `Arc`: the engine stamps it
/// from the worker thread, the supervisor dumps the flight ring on a
/// crash (the `Arc` outlives the panicked engine), and the HTTP server
/// scrapes it lock-free. All storage is preallocated here — nothing
/// grows afterwards.
#[derive(Debug)]
pub struct Telemetry {
    engine: Vec<AtomicU64>,
    step_time: [Histogram; StepPhase::ALL.len()],
    /// Bounded ring of recent step records, dumped on worker crash.
    pub flight: FlightRecorder,
    /// Bounded ring of per-request span records.
    pub traces: TraceRing,
}

impl Default for Telemetry {
    fn default() -> Self {
        Self::new()
    }
}

impl Telemetry {
    /// Registry with the default flight/trace ring capacities.
    pub fn new() -> Self {
        Self::with_capacities(DEFAULT_FLIGHT_RECORDS, DEFAULT_TRACE_EVENTS)
    }

    /// Registry with explicit ring capacities (both ≥ 1).
    pub fn with_capacities(flight_records: usize, trace_events: usize) -> Self {
        Telemetry {
            engine: (0..ENGINE_STATS.len()).map(|_| AtomicU64::new(0)).collect(),
            step_time: std::array::from_fn(|_| Histogram::new()),
            flight: FlightRecorder::new(flight_records),
            traces: TraceRing::new(trace_events),
        }
    }

    /// Set a mirrored scalar (last-write-wins; the engine mirrors once
    /// per step, the router stamps the queue gauges per iteration).
    pub fn set(&self, s: EngineStat, v: u64) {
        self.engine[s as usize].store(v, Ordering::Relaxed);
    }

    /// Read a mirrored scalar.
    pub fn get(&self, s: EngineStat) -> u64 {
        self.engine[s as usize].load(Ordering::Relaxed)
    }

    /// Read a mirrored scalar by [`ENGINE_STATS`] row index.
    pub fn get_by_index(&self, i: usize) -> u64 {
        self.engine[i].load(Ordering::Relaxed)
    }

    /// The step-time histogram for one phase.
    pub fn phase(&self, p: StepPhase) -> &Histogram {
        &self.step_time[p as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_boundaries() {
        assert_eq!(Histogram::bucket_index(0), 0);
        assert_eq!(Histogram::bucket_index(1), 0);
        assert_eq!(Histogram::bucket_index(2), 1);
        assert_eq!(Histogram::bucket_index(3), 2);
        assert_eq!(Histogram::bucket_index(4), 2);
        assert_eq!(Histogram::bucket_index(5), 3);
        // Exactly on a power of two stays in that bucket (inclusive
        // upper bounds).
        for i in 1..(HIST_BUCKETS - 1) {
            let bound = 1u64 << i;
            assert_eq!(Histogram::bucket_index(bound), i, "bound 2^{i}");
            assert_eq!(Histogram::bucket_index(bound + 1), i + 1, "2^{i}+1");
        }
        // Past the largest finite bound everything overflows to +Inf.
        assert_eq!(Histogram::bucket_index(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn histogram_counts_and_sum() {
        let h = Histogram::new();
        h.observe_us(1);
        h.observe_us(3);
        h.observe_us(1000);
        assert_eq!(h.count(), 3);
        assert_eq!(h.sum_us(), 1004);
        assert_eq!(h.bucket_count(0), 1);
        assert_eq!(h.bucket_count(2), 1);
        assert_eq!(h.bucket_count(10), 1); // 1000 ≤ 1024 = 2^10
    }

    #[test]
    fn quantile_reports_bucket_upper_bound() {
        let h = Histogram::new();
        assert_eq!(h.quantile_us(0.5), 0); // empty
        for _ in 0..9 {
            h.observe_us(10); // bucket 4 (bound 16)
        }
        h.observe_us(100_000); // bucket 17 (bound 131072)
        assert_eq!(h.quantile_us(0.5), 16);
        assert_eq!(h.quantile_us(0.9), 16);
        assert_eq!(h.quantile_us(1.0), 131_072);
    }

    #[test]
    fn engine_stat_table_matches_enum() {
        // The enum discriminants index the metadata table; the last
        // variant must land on the last row.
        assert_eq!(EngineStat::InflightRequests as usize, ENGINE_STATS.len() - 1);
        let t = Telemetry::new();
        t.set(EngineStat::ShedCount, 7);
        assert_eq!(t.get(EngineStat::ShedCount), 7);
        assert_eq!(t.get_by_index(EngineStat::ShedCount as usize), 7);
        // Names are unique (duplicate exposition series would be
        // rejected by a Prometheus scraper).
        for (i, a) in ENGINE_STATS.iter().enumerate() {
            for b in ENGINE_STATS.iter().skip(i + 1) {
                assert_ne!(a.name, b.name);
            }
        }
    }

    #[test]
    fn phase_histograms_are_independent() {
        let t = Telemetry::new();
        t.phase(StepPhase::Plan).observe_us(5);
        t.phase(StepPhase::Decode).observe_us(50);
        assert_eq!(t.phase(StepPhase::Plan).count(), 1);
        assert_eq!(t.phase(StepPhase::Decode).count(), 1);
        assert_eq!(t.phase(StepPhase::Sample).count(), 0);
    }
}
