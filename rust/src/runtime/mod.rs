//! Execution backends.
//!
//! The coordinator is generic over a [`Backend`]: the same scheduler,
//! paged cache and router drive either
//!
//! * [`NativeBackend`] — the in-crate f32 forward pass (fast on CPU,
//!   dependency-free, deterministic; benches and tests default to it), or
//! * [`XlaBackend`] — AOT-compiled HLO (from `python/compile/aot.py`)
//!   executed through the PJRT C API, proving the three-layer
//!   JAX/Pallas → HLO → Rust path end-to-end. The offline build links
//!   the in-tree [`pjrt_stub`] (compiles everywhere, fails fast at
//!   runtime); swap it for a real PJRT binding to execute artifacts.
//!
//! [`pool`] holds the persistent worker pool both native attention
//! fan-outs (prefill rows, decode batches) run on — spawned once,
//! parked while idle, per-worker thread-local workspaces.

pub mod artifacts;
pub mod backend;
// Deterministic fault injection (panic / latency spike / allocator
// exhaustion) for the overload & supervision tests. Gated so release
// builds without the `fault-inject` feature compile none of it;
// scripts/verify.sh additionally grep-gates fault hooks off the kernel
// hot-path files.
#[cfg(any(test, feature = "fault-inject"))]
pub mod fault;
pub mod pjrt_stub;
pub mod pool;
pub mod xla_backend;

pub use artifacts::{ArtifactManifest, BucketSpec};
pub use backend::{Backend, DecodeItem, MixedBatch, NativeBackend, PrefillChunkItem, StepOutputs};
#[cfg(any(test, feature = "fault-inject"))]
pub use fault::{
    FaultInjector, FaultPlan, FaultyBackend, IoFaultInjector, IoFaultPlan, IoWriteFault, StepFault,
};
pub use pool::WorkerPool;
pub use xla_backend::XlaBackend;
