//! Execution backends.
//!
//! The coordinator is generic over a [`Backend`]: the same scheduler,
//! paged cache and router drive either
//!
//! * [`NativeBackend`] — the in-crate f32 forward pass (fast on CPU,
//!   dependency-free, deterministic; benches and tests default to it), or
//! * [`XlaBackend`] — AOT-compiled HLO (from `python/compile/aot.py`)
//!   executed through the PJRT C API, proving the three-layer
//!   JAX/Pallas → HLO → Rust path end-to-end.

pub mod artifacts;
pub mod backend;
pub mod xla_backend;

pub use artifacts::{ArtifactManifest, BucketSpec};
pub use backend::{Backend, DecodeItem, NativeBackend};
pub use xla_backend::XlaBackend;
