//! Persistent, std-only worker pool — the spawn amortizer behind every
//! parallel attention fan-out.
//!
//! Before this module the model drivers paid one `std::thread::scope`
//! spawn-and-join per **layer** per step (prefill chunks and decode
//! batches alike): a 32-layer model spawned and tore down hundreds of
//! OS threads per engine step. The pool replaces that with a fixed set
//! of workers, spawned once and **parked** on a condvar while idle;
//! submitting a batch of jobs is a queue push plus a wakeup.
//!
//! ## Contract
//!
//! * [`WorkerPool::run`] submits a batch of borrowed jobs and **blocks
//!   until every job has finished** — that barrier is what makes the
//!   lifetime-erasure sound (see the safety comment in `run`), and it is
//!   the same semantics the old scoped spawn had, so callers did not
//!   change shape.
//! * **Determinism** — the pool never influences results: callers
//!   partition work into jobs *before* submission (the partition depends
//!   only on the requested width, exactly as with scoped spawns), jobs
//!   write disjoint output slices, and a job's arithmetic does not
//!   depend on which worker runs it. Outputs are bit-identical at every
//!   pool size and every width.
//! * **Per-worker workspaces** — workers are persistent threads, so the
//!   attention kernel's thread-local [`crate::attention::Workspace`]
//!   (reached via `with_workspace` inside a job) lives across jobs,
//!   layers and steps: scratch grows once per worker and is never
//!   reallocated, where the scoped spawns built a fresh workspace per
//!   worker per layer.
//! * **Panics propagate** — a panicking job does not poison the pool;
//!   the first panic payload is re-raised from `run` after the batch
//!   drains.
//!
//! ## Sizing and pinning
//!
//! [`global`] holds the process-wide pool, sized to
//! `available_parallelism` and spawned lazily on first use. How many
//! *jobs* a call fans out into is the caller's width knob — sized by
//! `attention::paged::auto_decode_threads` /
//! `attention::gqa::auto_prefill_threads`, pinnable via
//! `NativeBackend::with_decode_threads` / `with_prefill_threads` — and
//! may exceed the worker count (jobs queue and drain). Tests that need
//! an isolated pool construct their own [`WorkerPool::new`].

use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock, PoisonError};
use std::thread;

thread_local! {
    /// True on pool worker threads — the re-entrancy guard behind
    /// [`WorkerPool::run`]'s no-nesting contract (a worker blocking on a
    /// nested batch could deadlock the pool once every worker does it).
    static IN_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// One borrowed unit of work. Jobs run exactly once on some pool worker;
/// worker threads are persistent, so thread-local state (notably the
/// attention workspace) survives across jobs.
pub type Job<'a> = Box<dyn FnOnce() + Send + 'a>;

type StaticJob = Box<dyn FnOnce() + Send + 'static>;
type PanicPayload = Box<dyn std::any::Any + Send + 'static>;

/// Completion tracking for one `run` batch (several batches may be in
/// flight from different submitter threads; each tracks its own).
struct Batch {
    state: Mutex<BatchState>,
    done: Condvar,
}

struct BatchState {
    remaining: usize,
    panic: Option<PanicPayload>,
}

struct Inner {
    queue: VecDeque<(StaticJob, Arc<Batch>)>,
    shutdown: bool,
}

struct Shared {
    inner: Mutex<Inner>,
    work: Condvar,
}

/// A fixed set of parked worker threads accepting scoped job batches.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<thread::JoinHandle<()>>,
}

/// Lock a mutex, shrugging off poisoning: the pool holds its locks only
/// around queue/counter updates (never around user code), so a poisoned
/// lock's data is still consistent.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

impl WorkerPool {
    /// Spawn a pool of `workers` parked threads (clamped to ≥ 1).
    pub fn new(workers: usize) -> WorkerPool {
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            inner: Mutex::new(Inner { queue: VecDeque::new(), shutdown: false }),
            work: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|i| {
                let shared = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("opt-gptq-pool-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { shared, handles }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.handles.len()
    }

    /// Run a batch of borrowed jobs to completion.
    ///
    /// Blocks until every job has finished (the scoped-spawn barrier,
    /// without the spawns). If any job panicked, the first panic payload
    /// is re-raised here once the whole batch has drained; the pool
    /// itself stays usable.
    ///
    /// **Must not be called from inside a pool job**: a worker blocking
    /// on a nested batch occupies its slot, and once every worker does
    /// so the queue can never drain. The contract is enforced — calling
    /// `run` on a worker thread panics immediately (an explicit failure
    /// instead of a silent process hang).
    pub fn run(&self, jobs: Vec<Job<'_>>) {
        assert!(
            !IN_POOL_WORKER.with(Cell::get),
            "WorkerPool::run called from inside a pool job — nested batches would deadlock \
             the pool; restructure the caller to submit one flat batch"
        );
        if jobs.is_empty() {
            return;
        }
        let batch = Arc::new(Batch {
            state: Mutex::new(BatchState { remaining: jobs.len(), panic: None }),
            done: Condvar::new(),
        });
        {
            let mut inner = lock(&self.shared.inner);
            for job in jobs {
                // SAFETY: this function blocks below until `remaining`
                // reaches zero, and a job's count is decremented only
                // *after* the job has returned (or panicked), so every
                // borrow captured by the job strictly outlives its
                // execution. The transmute erases only the lifetime;
                // the trait object's layout and vtable are unchanged.
                let job: StaticJob = unsafe { std::mem::transmute::<Job<'_>, StaticJob>(job) };
                inner.queue.push_back((job, Arc::clone(&batch)));
            }
            self.shared.work.notify_all();
        }
        let mut st = lock(&batch.state);
        while st.remaining > 0 {
            st = batch.done.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
        if let Some(p) = st.panic.take() {
            drop(st);
            resume_unwind(p);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut inner = lock(&self.shared.inner);
            inner.shutdown = true;
        }
        self.shared.work.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool").field("workers", &self.handles.len()).finish()
    }
}

fn worker_loop(shared: Arc<Shared>) {
    IN_POOL_WORKER.with(|c| c.set(true));
    loop {
        let (job, batch) = {
            let mut inner = lock(&shared.inner);
            loop {
                if let Some(item) = inner.queue.pop_front() {
                    break item;
                }
                if inner.shutdown {
                    return;
                }
                inner = shared.work.wait(inner).unwrap_or_else(PoisonError::into_inner);
            }
        };
        let result = catch_unwind(AssertUnwindSafe(job));
        let mut st = lock(&batch.state);
        st.remaining -= 1;
        if let Err(p) = result {
            st.panic.get_or_insert(p);
        }
        if st.remaining == 0 {
            batch.done.notify_all();
        }
    }
}

/// The process-wide pool: sized to `available_parallelism`, spawned
/// lazily on the first parallel attention call, parked while idle, and
/// never torn down (workers exit with the process).
pub fn global() -> &'static WorkerPool {
    static POOL: OnceLock<WorkerPool> = OnceLock::new();
    POOL.get_or_init(|| {
        WorkerPool::new(thread::available_parallelism().map(|p| p.get()).unwrap_or(1))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs_over_borrowed_disjoint_slices() {
        let pool = WorkerPool::new(3);
        let mut data = vec![0u64; 64];
        let mut jobs: Vec<Job<'_>> = Vec::new();
        let mut rest = data.as_mut_slice();
        let mut base = 0u64;
        while !rest.is_empty() {
            let take = rest.len().min(10);
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(take);
            rest = tail;
            let b = base;
            jobs.push(Box::new(move || {
                for (i, x) in chunk.iter_mut().enumerate() {
                    *x = b + i as u64;
                }
            }));
            base += take as u64;
        }
        pool.run(jobs);
        for (i, &x) in data.iter().enumerate() {
            assert_eq!(x, i as u64);
        }
    }

    #[test]
    fn more_jobs_than_workers_all_complete() {
        let pool = WorkerPool::new(2);
        assert_eq!(pool.size(), 2);
        let hits = AtomicUsize::new(0);
        let jobs: Vec<Job<'_>> = (0..37)
            .map(|_| {
                Box::new(|| {
                    hits.fetch_add(1, Ordering::Relaxed);
                }) as Job<'_>
            })
            .collect();
        pool.run(jobs);
        assert_eq!(hits.load(Ordering::Relaxed), 37);
    }

    #[test]
    fn workers_persist_across_batches() {
        // The whole point of the pool: the second batch runs on the SAME
        // threads as the first (thread-local workspaces survive).
        let pool = WorkerPool::new(2);
        let collect_ids = || {
            let ids = Mutex::new(std::collections::HashSet::new());
            let jobs: Vec<Job<'_>> = (0..8)
                .map(|_| {
                    Box::new(|| {
                        ids.lock().unwrap().insert(thread::current().id());
                        thread::yield_now();
                    }) as Job<'_>
                })
                .collect();
            pool.run(jobs);
            ids.into_inner().unwrap()
        };
        let first = collect_ids();
        let second = collect_ids();
        assert!(!first.is_empty());
        for id in &second {
            assert!(first.contains(id), "second batch ran on a thread the first never used");
        }
    }

    #[test]
    fn empty_batch_is_a_noop() {
        WorkerPool::new(1).run(Vec::new());
    }

    #[test]
    fn panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let survived = AtomicUsize::new(0);
        let jobs: Vec<Job<'_>> = vec![
            Box::new(|| panic!("job blew up")),
            Box::new(|| {
                survived.fetch_add(1, Ordering::Relaxed);
            }),
        ];
        let err = catch_unwind(AssertUnwindSafe(|| pool.run(jobs)));
        assert!(err.is_err(), "the job's panic must re-raise from run()");
        // The non-panicking job still ran, and the pool still works.
        assert_eq!(survived.load(Ordering::Relaxed), 1);
        let again = AtomicUsize::new(0);
        pool.run(vec![Box::new(|| {
            again.fetch_add(1, Ordering::Relaxed);
        }) as Job<'_>]);
        assert_eq!(again.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn nested_run_from_a_job_panics_instead_of_deadlocking() {
        // The re-entrancy guard: submitting a batch from inside a pool
        // job must fail fast (assert), not silently wedge the pool.
        let pool = WorkerPool::new(1);
        let nested_panicked = AtomicUsize::new(0);
        let jobs: Vec<Job<'_>> = vec![Box::new(|| {
            let attempt = catch_unwind(AssertUnwindSafe(|| {
                global().run(vec![Box::new(|| {}) as Job<'_>]);
            }));
            if attempt.is_err() {
                nested_panicked.fetch_add(1, Ordering::Relaxed);
            }
        })];
        pool.run(jobs);
        assert_eq!(nested_panicked.load(Ordering::Relaxed), 1, "nested run must panic");
        // And the pool is still healthy.
        let ok = AtomicUsize::new(0);
        pool.run(vec![Box::new(|| {
            ok.fetch_add(1, Ordering::Relaxed);
        }) as Job<'_>]);
        assert_eq!(ok.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn concurrent_batches_from_two_threads() {
        let pool = WorkerPool::new(4);
        thread::scope(|s| {
            for _ in 0..2 {
                s.spawn(|| {
                    let count = AtomicUsize::new(0);
                    let jobs: Vec<Job<'_>> = (0..16)
                        .map(|_| {
                            Box::new(|| {
                                count.fetch_add(1, Ordering::Relaxed);
                            }) as Job<'_>
                        })
                        .collect();
                    pool.run(jobs);
                    assert_eq!(count.load(Ordering::Relaxed), 16);
                });
            }
        });
    }

    #[test]
    fn global_pool_is_a_singleton() {
        let a = global() as *const WorkerPool;
        let b = global() as *const WorkerPool;
        assert_eq!(a, b);
        assert!(global().size() >= 1);
    }
}
