//! Deterministic fault injection — the harness that makes the overload
//! and supervision contracts testable.
//!
//! A [`FaultPlan`] is a seeded, declarative schedule of faults keyed by
//! *step index* (each consult advances a counter):
//!
//! * **panic** — at fixed step indices or with a seeded per-step
//!   probability (models a crashing backend / poisoned kernel);
//! * **delay** — a latency spike over a step range (models a straggler
//!   device; drives the AIMD controller's breach path);
//! * **exhaust** — over a step range the KV allocator's
//!   admission-visible probes report an empty pool (models memory
//!   pressure; `BlockAllocator::alloc` itself is untouched so scheduled
//!   work never stalls mid-flight).
//!
//! Two attachment points consume a plan, each with its own
//! [`FaultInjector`] instance (the step counter is per-injector):
//! [`FaultyBackend`] wraps any [`Backend`] and applies panic/delay in
//! `forward_step` (what the router's supervision tests use — the panic
//! unwinds through the engine into `catch_unwind`), and
//! `Engine::arm_faults` consults an injector at the top of every
//! `step()` (panic/delay/exhaust, before any scheduling).
//!
//! Everything here is `#[cfg(any(test, feature = "fault-inject"))]` —
//! zero code and zero cost in a release build without the feature.
//! `scripts/verify.sh` grep-gates fault hooks off the kernel hot-path
//! files, same as the `gather`/`.dequantize()` gates.
//!
//! Determinism: the probabilistic panic derives from a splitmix64 hash
//! of `(seed, step)` — no shared RNG state, so the fault sequence is a
//! pure function of the plan regardless of thread interleaving.

use crate::kvcache::{BlockTable, KvStore};
use crate::model::{ModelConfig, WeightDtype};
use crate::runtime::backend::{Backend, DecodeItem, MixedBatch, StepOutputs};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Declarative, seeded fault schedule. Build with the chainable
/// constructors, then [`FaultPlan::injector`] to get the shareable
/// runtime handle.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    seed: u64,
    /// Exact step indices (0-based consult order) that panic.
    panic_at: Vec<u64>,
    /// Seeded per-step panic probability in [0, 1].
    panic_prob: f64,
    /// `(from, to, ms)`: steps in `[from, to)` sleep `ms` first.
    delay: Option<(u64, u64, u64)>,
    /// `(from, to)`: steps in `[from, to)` arm allocator exhaustion
    /// (engine attachment point only).
    exhaust: Option<(u64, u64)>,
}

impl FaultPlan {
    pub fn new(seed: u64) -> Self {
        FaultPlan { seed, ..Default::default() }
    }

    /// Panic on the given step index (repeatable for several).
    pub fn panic_at_step(mut self, step: u64) -> Self {
        self.panic_at.push(step);
        self
    }

    /// Panic each step with probability `p`, derived deterministically
    /// from `(seed, step)`.
    pub fn panic_with_prob(mut self, p: f64) -> Self {
        self.panic_prob = p.clamp(0.0, 1.0);
        self
    }

    /// Sleep `ms` before every step in `[from, to)`.
    pub fn delay_steps(mut self, from: u64, to: u64, ms: u64) -> Self {
        self.delay = Some((from, to, ms));
        self
    }

    /// Report an exhausted KV pool to admission probes for every step
    /// in `[from, to)` (only meaningful via `Engine::arm_faults`).
    pub fn exhaust_steps(mut self, from: u64, to: u64) -> Self {
        self.exhaust = Some((from, to));
        self
    }

    /// Finalize into a cloneable runtime handle with its own step
    /// counter. Attach one injector to one site.
    pub fn injector(self) -> FaultInjector {
        FaultInjector { inner: Arc::new(InjectorInner { plan: self, step: AtomicU64::new(0) }) }
    }
}

/// The fault decision for one step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StepFault {
    pub panic: bool,
    pub delay_ms: u64,
    pub exhaust: bool,
}

#[derive(Debug)]
struct InjectorInner {
    plan: FaultPlan,
    step: AtomicU64,
}

/// Shareable handle over a [`FaultPlan`]; each
/// [`next_step`](Self::next_step) consult advances the step counter.
#[derive(Debug, Clone)]
pub struct FaultInjector {
    inner: Arc<InjectorInner>,
}

impl FaultInjector {
    /// Decide the fault for the current step and advance the counter.
    pub fn next_step(&self) -> StepFault {
        let s = self.inner.step.fetch_add(1, Ordering::SeqCst);
        let plan = &self.inner.plan;
        let mut panic = plan.panic_at.contains(&s);
        if plan.panic_prob > 0.0 && unit_hash(plan.seed, s) < plan.panic_prob {
            panic = true;
        }
        let delay_ms = match plan.delay {
            Some((from, to, ms)) if s >= from && s < to => ms,
            _ => 0,
        };
        let exhaust = matches!(plan.exhaust, Some((from, to)) if s >= from && s < to);
        StepFault { panic, delay_ms, exhaust }
    }

    /// Steps consulted so far (test observability).
    pub fn steps_taken(&self) -> u64 {
        self.inner.step.load(Ordering::SeqCst)
    }
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Uniform [0, 1) hash of (seed, step) — stateless, thread-safe,
/// replay-identical.
fn unit_hash(seed: u64, step: u64) -> f64 {
    let h = splitmix64(seed ^ step.wrapping_mul(0xA24B_AED4_963E_E407));
    (h >> 11) as f64 / (1u64 << 53) as f64
}

/// A [`Backend`] decorator that applies panic/delay faults at the top
/// of `forward_step`, then delegates. The panic unwinds through
/// `Engine::step` into the router's supervision `catch_unwind` — the
/// exact crash path a poisoned kernel would take.
pub struct FaultyBackend {
    inner: Box<dyn Backend>,
    faults: FaultInjector,
}

impl FaultyBackend {
    pub fn new(inner: Box<dyn Backend>, faults: FaultInjector) -> Self {
        FaultyBackend { inner, faults }
    }
}

impl Backend for FaultyBackend {
    fn config(&self) -> &ModelConfig {
        self.inner.config()
    }

    fn prefill(
        &self,
        tokens: &[u32],
        cache: &mut dyn KvStore,
        table: &mut BlockTable,
    ) -> Vec<f32> {
        self.inner.prefill(tokens, cache, table)
    }

    fn decode(&self, items: &mut [DecodeItem<'_>], cache: &mut dyn KvStore) -> Vec<Vec<f32>> {
        self.inner.decode(items, cache)
    }

    fn forward_step(&self, batch: &mut MixedBatch<'_>, cache: &mut dyn KvStore) -> StepOutputs {
        let fault = self.faults.next_step();
        if fault.delay_ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(fault.delay_ms));
        }
        if fault.panic {
            panic!(
                "injected fault: backend step panic at step {}",
                self.faults.steps_taken().saturating_sub(1)
            );
        }
        self.inner.forward_step(batch, cache)
    }

    fn supports_mixed_step(&self) -> bool {
        self.inner.supports_mixed_step()
    }

    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn supports_offset_prefill(&self) -> bool {
        self.inner.supports_offset_prefill()
    }

    fn supports_quantized_kv(&self) -> bool {
        self.inner.supports_quantized_kv()
    }

    fn weight_dtype(&self) -> WeightDtype {
        self.inner.weight_dtype()
    }

    fn weight_bytes(&self) -> usize {
        self.inner.weight_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_steps_are_deterministic() {
        let mk = || FaultPlan::new(42).panic_with_prob(0.3).delay_steps(2, 4, 5).injector();
        let (a, b) = (mk(), mk());
        for _ in 0..64 {
            assert_eq!(a.next_step(), b.next_step());
        }
        assert_eq!(a.steps_taken(), 64);
    }

    #[test]
    fn fixed_panic_step_fires_exactly_there() {
        let inj = FaultPlan::new(0).panic_at_step(3).injector();
        let panics: Vec<bool> = (0..6).map(|_| inj.next_step().panic).collect();
        assert_eq!(panics, vec![false, false, false, true, false, false]);
    }

    #[test]
    fn delay_and_exhaust_windows_are_half_open() {
        let inj = FaultPlan::new(0).delay_steps(1, 3, 7).exhaust_steps(2, 4).injector();
        let faults: Vec<StepFault> = (0..5).map(|_| inj.next_step()).collect();
        assert_eq!(faults.iter().map(|f| f.delay_ms).collect::<Vec<_>>(), vec![0, 7, 7, 0, 0]);
        assert_eq!(
            faults.iter().map(|f| f.exhaust).collect::<Vec<_>>(),
            vec![false, false, true, true, false]
        );
    }

    #[test]
    fn probabilistic_panic_rate_tracks_p() {
        let inj = FaultPlan::new(7).panic_with_prob(0.25).injector();
        let n = 4000;
        let hits = (0..n).filter(|_| inj.next_step().panic).count();
        let rate = hits as f64 / n as f64;
        assert!((rate - 0.25).abs() < 0.05, "seeded panic rate {rate} far from 0.25");
    }

    #[test]
    fn zero_prob_never_panics() {
        let inj = FaultPlan::new(9).injector();
        assert!((0..256).all(|_| !inj.next_step().panic));
    }
}
