//! Deterministic fault injection — the harness that makes the overload
//! and supervision contracts testable.
//!
//! A [`FaultPlan`] is a seeded, declarative schedule of faults keyed by
//! *step index* (each consult advances a counter):
//!
//! * **panic** — at fixed step indices or with a seeded per-step
//!   probability (models a crashing backend / poisoned kernel);
//! * **delay** — a latency spike over a step range (models a straggler
//!   device; drives the AIMD controller's breach path);
//! * **exhaust** — over a step range the KV allocator's
//!   admission-visible probes report an empty pool (models memory
//!   pressure; `BlockAllocator::alloc` itself is untouched so scheduled
//!   work never stalls mid-flight).
//!
//! Two attachment points consume a plan, each with its own
//! [`FaultInjector`] instance (the step counter is per-injector):
//! [`FaultyBackend`] wraps any [`Backend`] and applies panic/delay in
//! `forward_step` (what the router's supervision tests use — the panic
//! unwinds through the engine into `catch_unwind`), and
//! `Engine::arm_faults` consults an injector at the top of every
//! `step()` (panic/delay/exhaust, before any scheduling).
//!
//! Everything here is `#[cfg(any(test, feature = "fault-inject"))]` —
//! zero code and zero cost in a release build without the feature.
//! `scripts/verify.sh` grep-gates fault hooks off the kernel hot-path
//! files, same as the `gather`/`.dequantize()` gates.
//!
//! Determinism: the probabilistic panic derives from a splitmix64 hash
//! of `(seed, step)` — no shared RNG state, so the fault sequence is a
//! pure function of the plan regardless of thread interleaving.

use crate::kvcache::{BlockTable, KvStore};
use crate::model::{ModelConfig, WeightDtype};
use crate::runtime::backend::{Backend, DecodeItem, MixedBatch, StepOutputs};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Declarative, seeded fault schedule. Build with the chainable
/// constructors, then [`FaultPlan::injector`] to get the shareable
/// runtime handle.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    seed: u64,
    /// Exact step indices (0-based consult order) that panic.
    panic_at: Vec<u64>,
    /// Seeded per-step panic probability in [0, 1].
    panic_prob: f64,
    /// `(from, to, ms)`: steps in `[from, to)` sleep `ms` first.
    delay: Option<(u64, u64, u64)>,
    /// `(from, to)`: steps in `[from, to)` arm allocator exhaustion
    /// (engine attachment point only).
    exhaust: Option<(u64, u64)>,
}

impl FaultPlan {
    pub fn new(seed: u64) -> Self {
        FaultPlan { seed, ..Default::default() }
    }

    /// Panic on the given step index (repeatable for several).
    pub fn panic_at_step(mut self, step: u64) -> Self {
        self.panic_at.push(step);
        self
    }

    /// Panic each step with probability `p`, derived deterministically
    /// from `(seed, step)`.
    pub fn panic_with_prob(mut self, p: f64) -> Self {
        self.panic_prob = p.clamp(0.0, 1.0);
        self
    }

    /// Sleep `ms` before every step in `[from, to)`.
    pub fn delay_steps(mut self, from: u64, to: u64, ms: u64) -> Self {
        self.delay = Some((from, to, ms));
        self
    }

    /// Report an exhausted KV pool to admission probes for every step
    /// in `[from, to)` (only meaningful via `Engine::arm_faults`).
    pub fn exhaust_steps(mut self, from: u64, to: u64) -> Self {
        self.exhaust = Some((from, to));
        self
    }

    /// Finalize into a cloneable runtime handle with its own step
    /// counter. Attach one injector to one site.
    pub fn injector(self) -> FaultInjector {
        FaultInjector { inner: Arc::new(InjectorInner { plan: self, step: AtomicU64::new(0) }) }
    }
}

/// The fault decision for one step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StepFault {
    pub panic: bool,
    pub delay_ms: u64,
    pub exhaust: bool,
}

#[derive(Debug)]
struct InjectorInner {
    plan: FaultPlan,
    step: AtomicU64,
}

/// Shareable handle over a [`FaultPlan`]; each
/// [`next_step`](Self::next_step) consult advances the step counter.
#[derive(Debug, Clone)]
pub struct FaultInjector {
    inner: Arc<InjectorInner>,
}

impl FaultInjector {
    /// Decide the fault for the current step and advance the counter.
    pub fn next_step(&self) -> StepFault {
        let s = self.inner.step.fetch_add(1, Ordering::SeqCst);
        let plan = &self.inner.plan;
        let mut panic = plan.panic_at.contains(&s);
        if plan.panic_prob > 0.0 && unit_hash(plan.seed, s) < plan.panic_prob {
            panic = true;
        }
        let delay_ms = match plan.delay {
            Some((from, to, ms)) if s >= from && s < to => ms,
            _ => 0,
        };
        let exhaust = matches!(plan.exhaust, Some((from, to)) if s >= from && s < to);
        StepFault { panic, delay_ms, exhaust }
    }

    /// Steps consulted so far (test observability).
    pub fn steps_taken(&self) -> u64 {
        self.inner.step.load(Ordering::SeqCst)
    }
}

/// Declarative, seeded schedule of **IO** faults — consumed by the KV
/// spill tier (`kvcache::spill`), the disk analogue of [`FaultPlan`]:
///
/// * **short write** — the nth write call stops short at a seeded torn
///   point, modelling a kill mid-append (the torn tail stays on disk;
///   recovery must truncate it at the next open);
/// * **ENOSPC** — a running byte budget; the write that would cross it
///   gets the partial write a full filesystem would allow, then the
///   error (the live process repairs back to its commit frontier);
/// * **corrupt read** — the nth read call has one seeded bit flipped
///   after the bytes arrive, modelling media rot (CRC must catch it);
/// * **fail open** — every open attempt fails (missing mount / perms).
///
/// Counters are per-injector atomics, so the fault sequence is a pure
/// function of the plan and the call order — replay-identical.
#[derive(Debug, Clone, Default)]
pub struct IoFaultPlan {
    seed: u64,
    /// 0-based write-call index that stops short.
    short_write_at: Option<u64>,
    /// Total byte budget before ENOSPC.
    enospc_after_bytes: Option<u64>,
    /// 0-based read-call index that gets one bit flipped.
    corrupt_read_bit: Option<u64>,
    /// Every open attempt fails.
    fail_open: bool,
}

impl IoFaultPlan {
    pub fn new(seed: u64) -> Self {
        IoFaultPlan { seed, ..Default::default() }
    }

    /// The `nth` write call (0-based) writes only a seeded prefix of
    /// its bytes and reports a short write (kill mid-append).
    pub fn short_write_at(mut self, nth: u64) -> Self {
        self.short_write_at = Some(nth);
        self
    }

    /// Writes succeed until `bytes` total bytes have been written; the
    /// crossing write lands its allowed prefix and reports ENOSPC.
    pub fn enospc_after_bytes(mut self, bytes: u64) -> Self {
        self.enospc_after_bytes = Some(bytes);
        self
    }

    /// The `nth` read call (0-based) has one seeded bit flipped in the
    /// buffer after the read completes.
    pub fn corrupt_read_bit(mut self, nth: u64) -> Self {
        self.corrupt_read_bit = Some(nth);
        self
    }

    /// Every open attempt fails.
    pub fn fail_open(mut self) -> Self {
        self.fail_open = true;
        self
    }

    /// Finalize into a cloneable runtime handle with its own write/read
    /// counters. Attach one injector to one store.
    pub fn injector(self) -> IoFaultInjector {
        IoFaultInjector {
            inner: Arc::new(IoInjectorInner {
                plan: self,
                writes: AtomicU64::new(0),
                reads: AtomicU64::new(0),
                bytes_written: AtomicU64::new(0),
            }),
        }
    }
}

/// The injected outcome of one write call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoWriteFault {
    /// Write proceeds in full.
    None,
    /// Only this many leading bytes reach the file; the process is
    /// (simulated-)killed before the rest (no error returned to a real
    /// caller — the tier must treat it as a crash).
    Short(usize),
    /// This many leading bytes land, then the filesystem is full.
    Enospc(usize),
}

#[derive(Debug)]
struct IoInjectorInner {
    plan: IoFaultPlan,
    writes: AtomicU64,
    reads: AtomicU64,
    bytes_written: AtomicU64,
}

/// Shareable handle over an [`IoFaultPlan`]; write/read consults
/// advance their own counters.
#[derive(Debug, Clone)]
pub struct IoFaultInjector {
    inner: Arc<IoInjectorInner>,
}

impl IoFaultInjector {
    /// Does the next open attempt fail?
    pub fn fail_open(&self) -> bool {
        self.inner.plan.fail_open
    }

    /// Decide the fault for a write of `len` bytes and advance the
    /// write counter (the byte counter advances by what actually
    /// lands, so an ENOSPC budget is a true running total).
    pub fn write_outcome(&self, len: usize) -> IoWriteFault {
        let w = self.inner.writes.fetch_add(1, Ordering::SeqCst);
        let plan = &self.inner.plan;
        if plan.short_write_at == Some(w) && len > 0 {
            let torn = (splitmix64(plan.seed ^ w) as usize) % len;
            self.inner.bytes_written.fetch_add(torn as u64, Ordering::SeqCst);
            return IoWriteFault::Short(torn);
        }
        if let Some(cap) = plan.enospc_after_bytes {
            let before = self.inner.bytes_written.load(Ordering::SeqCst);
            if before + len as u64 > cap {
                let allowed = cap.saturating_sub(before) as usize;
                self.inner.bytes_written.fetch_add(allowed as u64, Ordering::SeqCst);
                return IoWriteFault::Enospc(allowed);
            }
        }
        self.inner.bytes_written.fetch_add(len as u64, Ordering::SeqCst);
        IoWriteFault::None
    }

    /// Advance the read counter and, on the armed call, flip one seeded
    /// bit in `buf`. Returns whether a flip happened.
    pub fn corrupt_read(&self, buf: &mut [u8]) -> bool {
        let r = self.inner.reads.fetch_add(1, Ordering::SeqCst);
        if self.inner.plan.corrupt_read_bit == Some(r) && !buf.is_empty() {
            let bit = (splitmix64(self.inner.plan.seed ^ r.wrapping_mul(0x9E37)) as usize)
                % (buf.len() * 8);
            buf[bit / 8] ^= 1 << (bit % 8);
            return true;
        }
        false
    }

    /// Write calls consulted so far (test observability).
    pub fn writes_taken(&self) -> u64 {
        self.inner.writes.load(Ordering::SeqCst)
    }

    /// Read calls consulted so far (test observability).
    pub fn reads_taken(&self) -> u64 {
        self.inner.reads.load(Ordering::SeqCst)
    }
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Uniform [0, 1) hash of (seed, step) — stateless, thread-safe,
/// replay-identical.
fn unit_hash(seed: u64, step: u64) -> f64 {
    let h = splitmix64(seed ^ step.wrapping_mul(0xA24B_AED4_963E_E407));
    (h >> 11) as f64 / (1u64 << 53) as f64
}

/// A [`Backend`] decorator that applies panic/delay faults at the top
/// of `forward_step`, then delegates. The panic unwinds through
/// `Engine::step` into the router's supervision `catch_unwind` — the
/// exact crash path a poisoned kernel would take.
pub struct FaultyBackend {
    inner: Box<dyn Backend>,
    faults: FaultInjector,
}

impl FaultyBackend {
    pub fn new(inner: Box<dyn Backend>, faults: FaultInjector) -> Self {
        FaultyBackend { inner, faults }
    }
}

impl Backend for FaultyBackend {
    fn config(&self) -> &ModelConfig {
        self.inner.config()
    }

    fn prefill(
        &self,
        tokens: &[u32],
        cache: &mut dyn KvStore,
        table: &mut BlockTable,
    ) -> Vec<f32> {
        self.inner.prefill(tokens, cache, table)
    }

    fn decode(&self, items: &mut [DecodeItem<'_>], cache: &mut dyn KvStore) -> Vec<Vec<f32>> {
        self.inner.decode(items, cache)
    }

    fn forward_step(&self, batch: &mut MixedBatch<'_>, cache: &mut dyn KvStore) -> StepOutputs {
        let fault = self.faults.next_step();
        if fault.delay_ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(fault.delay_ms));
        }
        if fault.panic {
            panic!(
                "injected fault: backend step panic at step {}",
                self.faults.steps_taken().saturating_sub(1)
            );
        }
        self.inner.forward_step(batch, cache)
    }

    fn supports_mixed_step(&self) -> bool {
        self.inner.supports_mixed_step()
    }

    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn supports_offset_prefill(&self) -> bool {
        self.inner.supports_offset_prefill()
    }

    fn supports_quantized_kv(&self) -> bool {
        self.inner.supports_quantized_kv()
    }

    fn weight_dtype(&self) -> WeightDtype {
        self.inner.weight_dtype()
    }

    fn weight_bytes(&self) -> usize {
        self.inner.weight_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_steps_are_deterministic() {
        let mk = || FaultPlan::new(42).panic_with_prob(0.3).delay_steps(2, 4, 5).injector();
        let (a, b) = (mk(), mk());
        for _ in 0..64 {
            assert_eq!(a.next_step(), b.next_step());
        }
        assert_eq!(a.steps_taken(), 64);
    }

    #[test]
    fn fixed_panic_step_fires_exactly_there() {
        let inj = FaultPlan::new(0).panic_at_step(3).injector();
        let panics: Vec<bool> = (0..6).map(|_| inj.next_step().panic).collect();
        assert_eq!(panics, vec![false, false, false, true, false, false]);
    }

    #[test]
    fn delay_and_exhaust_windows_are_half_open() {
        let inj = FaultPlan::new(0).delay_steps(1, 3, 7).exhaust_steps(2, 4).injector();
        let faults: Vec<StepFault> = (0..5).map(|_| inj.next_step()).collect();
        assert_eq!(faults.iter().map(|f| f.delay_ms).collect::<Vec<_>>(), vec![0, 7, 7, 0, 0]);
        assert_eq!(
            faults.iter().map(|f| f.exhaust).collect::<Vec<_>>(),
            vec![false, false, true, true, false]
        );
    }

    #[test]
    fn probabilistic_panic_rate_tracks_p() {
        let inj = FaultPlan::new(7).panic_with_prob(0.25).injector();
        let n = 4000;
        let hits = (0..n).filter(|_| inj.next_step().panic).count();
        let rate = hits as f64 / n as f64;
        assert!((rate - 0.25).abs() < 0.05, "seeded panic rate {rate} far from 0.25");
    }

    #[test]
    fn zero_prob_never_panics() {
        let inj = FaultPlan::new(9).injector();
        assert!((0..256).all(|_| !inj.next_step().panic));
    }

    #[test]
    fn io_short_write_fires_once_at_nth_and_is_deterministic() {
        let mk = || IoFaultPlan::new(5).short_write_at(2).injector();
        let (a, b) = (mk(), mk());
        let outs_a: Vec<IoWriteFault> = (0..5).map(|_| a.write_outcome(100)).collect();
        let outs_b: Vec<IoWriteFault> = (0..5).map(|_| b.write_outcome(100)).collect();
        assert_eq!(outs_a, outs_b);
        assert_eq!(outs_a[0], IoWriteFault::None);
        assert_eq!(outs_a[1], IoWriteFault::None);
        match outs_a[2] {
            IoWriteFault::Short(n) => assert!(n < 100, "torn point must be a strict prefix"),
            other => panic!("expected Short at write 2, got {other:?}"),
        }
        assert_eq!(outs_a[3], IoWriteFault::None);
        assert_eq!(a.writes_taken(), 5);
    }

    #[test]
    fn io_enospc_budget_is_a_running_total() {
        let inj = IoFaultPlan::new(0).enospc_after_bytes(250).injector();
        assert_eq!(inj.write_outcome(100), IoWriteFault::None);
        assert_eq!(inj.write_outcome(100), IoWriteFault::None);
        // 200 written; the next 100 crosses the 250 budget at 50.
        assert_eq!(inj.write_outcome(100), IoWriteFault::Enospc(50));
        // Budget stays exhausted: nothing more fits.
        assert_eq!(inj.write_outcome(10), IoWriteFault::Enospc(0));
    }

    #[test]
    fn io_corrupt_read_flips_exactly_one_bit_on_the_nth_read() {
        let inj = IoFaultPlan::new(11).corrupt_read_bit(1).injector();
        let clean = vec![0xA5u8; 64];
        let mut buf = clean.clone();
        assert!(!inj.corrupt_read(&mut buf));
        assert_eq!(buf, clean, "read 0 untouched");
        assert!(inj.corrupt_read(&mut buf));
        let flipped: u32 =
            buf.iter().zip(&clean).map(|(a, b)| (a ^ b).count_ones()).sum();
        assert_eq!(flipped, 1, "exactly one bit differs");
        assert!(!inj.corrupt_read(&mut buf));
        assert_eq!(inj.reads_taken(), 3);
    }

    #[test]
    fn io_fail_open_is_sticky() {
        let inj = IoFaultPlan::new(0).fail_open().injector();
        assert!(inj.fail_open() && inj.fail_open());
        assert!(!IoFaultPlan::new(0).injector().fail_open());
    }
}
