//! The `Backend` trait and its native implementation.

use crate::kvcache::{BlockTable, KvStore};
use crate::model::{ModelConfig, NativeModel, WeightDtype, WeightStore};

/// One sequence's slot in a decode batch.
pub struct DecodeItem<'a> {
    /// Token produced by the previous step (input to this one).
    pub token: u32,
    /// The sequence's block table (one slot of reserved capacity).
    pub table: &'a mut BlockTable,
}

/// One prefill chunk's slice of a mixed step.
pub struct PrefillChunkItem<'a> {
    /// Replay tokens to prefill, placed at positions
    /// `table.len()..table.len()+tokens.len()`.
    pub tokens: &'a [u32],
    /// The sequence's block table (chunk capacity reserved).
    pub table: &'a mut BlockTable,
    /// Whether the caller needs this chunk's last-position logits — set
    /// on a sequence's *final* chunk, where the engine samples the first
    /// generated token.
    pub want_logits: bool,
}

/// One engine step's worth of work: prefill chunks and decode tokens
/// sharing a token budget. Either side may be empty; a sequence appears
/// at most once across both.
pub struct MixedBatch<'a> {
    pub prefill: Vec<PrefillChunkItem<'a>>,
    pub decode: Vec<DecodeItem<'a>>,
    /// Upper bound on tokens per `Backend::prefill` call for the serial
    /// fallback (`EngineConfig::prefill_chunk`, the XLA artifact bucket
    /// cap). The fused native path ignores it.
    pub prefill_call_cap: usize,
}

/// Outputs of one [`Backend::forward_step`] call.
pub struct StepOutputs {
    /// Last-position logits per prefill chunk, in order; `Some` iff the
    /// chunk's `want_logits` was set.
    pub prefill_logits: Vec<Option<Vec<f32>>>,
    /// One logits vector per decode item, in order.
    pub decode_logits: Vec<Vec<f32>>,
    /// Quantized KV tiles dequantized by the step's streamed prefill
    /// attention (0 on an f32 cache or a backend without the counter) —
    /// mirrored into `EngineMetrics::prefill_dequant_tiles`.
    pub prefill_dequant_tiles: usize,
    /// KV tiles elided by score-bound skipping across the step's prefill
    /// and decode attention (0 under a dense sparsity config or on a
    /// backend without the counter) — mirrored into
    /// `EngineMetrics::skipped_tiles`. Window-invisible tiles are not
    /// counted: they are outside the schedule, not skipped.
    pub skipped_tiles: usize,
}

/// A model-execution backend the engine can drive.
///
/// Contract shared by all implementations:
/// * `prefill` appends `tokens.len()` slots to `table` (capacity must be
///   reserved) and returns the last position's logits.
/// * `decode` appends one slot per item and returns one logits vector per
///   item, in order.
/// * `forward_step` executes a whole mixed step (prefill chunks +
///   decode) against one cache; the default implementation decomposes it
///   into `prefill`/`decode` calls, so only `supports_mixed_step`
///   backends see genuinely interleaved work.
pub trait Backend: Send {
    fn config(&self) -> &ModelConfig;

    fn prefill(&self, tokens: &[u32], cache: &mut dyn KvStore, table: &mut BlockTable)
        -> Vec<f32>;

    fn decode(&self, items: &mut [DecodeItem<'_>], cache: &mut dyn KvStore) -> Vec<Vec<f32>>;

    /// Execute one mixed step: every prefill chunk and every decode
    /// token of the plan, against the same cache.
    ///
    /// The default implementation is the serial fallback — one
    /// `prefill` call per chunk (split at `prefill_call_cap`), then one
    /// `decode` batch — byte-for-byte the legacy execution order for
    /// backends without mixed-step support. [`NativeBackend`] overrides
    /// it with a fused pass that streams each weight matrix **once per
    /// step** across prefill and decode rows.
    fn forward_step(&self, batch: &mut MixedBatch<'_>, cache: &mut dyn KvStore) -> StepOutputs {
        let mut prefill_logits = Vec::with_capacity(batch.prefill.len());
        for item in batch.prefill.iter_mut() {
            let mut logits = Vec::new();
            for sub in item.tokens.chunks(batch.prefill_call_cap.max(1)) {
                logits = self.prefill(sub, cache, item.table);
            }
            prefill_logits.push(item.want_logits.then_some(logits));
        }
        let decode_logits = if batch.decode.is_empty() {
            Vec::new()
        } else {
            self.decode(&mut batch.decode, cache)
        };
        StepOutputs { prefill_logits, decode_logits, prefill_dequant_tiles: 0, skipped_tiles: 0 }
    }

    /// Whether `forward_step` executes interleaved chunked prefill
    /// natively (prefill resuming at nonzero cache positions, mixed
    /// with decode in one pass). The engine plans token-budget mixed
    /// steps only when true; otherwise it falls back to exclusive
    /// whole-prompt planning (the XLA artifacts assume fresh
    /// sequences — see [`Backend::supports_offset_prefill`]).
    fn supports_mixed_step(&self) -> bool {
        false
    }

    /// Human-readable backend name (metrics/logs).
    fn name(&self) -> &'static str;

    /// Whether `prefill` supports a non-empty table (chunked prefill /
    /// prefix-cache adoption). The XLA artifacts are lowered for fresh
    /// sequences (positions start at 0), so only the native backend
    /// opts in.
    fn supports_offset_prefill(&self) -> bool {
        false
    }

    /// Whether this backend can read a non-f32 [`KvStore`]
    /// (`KvCacheDtype::Q8`). The native kernel dequantizes per tile; the
    /// XLA artifacts expect raw f32 pools, so only the native backend
    /// opts in. The engine checks this at construction.
    fn supports_quantized_kv(&self) -> bool {
        false
    }

    /// Storage dtype of the weights this backend serves from. The engine
    /// checks it against `EngineConfig::weight_dtype` at construction so
    /// a deployment's declared dtype and the backend actually wired in
    /// can never drift apart silently. F32 unless the backend holds a
    /// packed `WeightStore` (the XLA artifacts upload raw f32 buffers).
    fn weight_dtype(&self) -> WeightDtype {
        WeightDtype::F32
    }

    /// True bytes held by the backend's weight store (packed payload +
    /// grids on a quantized store) — observability surface; 0 when the
    /// backend does not track it.
    fn weight_bytes(&self) -> usize {
        0
    }

    /// Which kernel table this backend's hot loops resolved to at
    /// startup (`"scalar"`, `"avx2"` — see `tensor::simd`); `"n/a"` for
    /// backends that do not run the native kernels. Observability
    /// surface (`info`/metrics), never a behavioural switch.
    fn kernel_dispatch(&self) -> &'static str {
        "n/a"
    }

    /// Whether this backend can score q8 decode attention in the
    /// integer domain (`ScoreDomain::Int`, CLI `--q8-score-domain int`).
    /// Only the native kernel implements the widening i8×i8→i32 path;
    /// the engine/CLI checks this before accepting the flag.
    fn supports_int_score_domain(&self) -> bool {
        false
    }
}

/// Pure-Rust backend executing [`NativeModel`].
pub struct NativeBackend {
    model: NativeModel,
    /// Attention fan-out width for decode steps: `0` auto-sizes from the
    /// batch's KV footprint and available cores (see
    /// `attention::paged::auto_decode_threads`); any other value pins it.
    decode_threads: usize,
    /// Attention fan-out width for prefill chunk rows: `0` auto-sizes
    /// per chunk from its score work (see
    /// `attention::gqa::auto_prefill_threads`); any other value pins
    /// every chunk's width. Widths partition work across the persistent
    /// worker pool (`crate::runtime::pool`); they do not spawn threads.
    prefill_threads: usize,
}

impl NativeBackend {
    pub fn new(model: NativeModel) -> Self {
        NativeBackend { model, decode_threads: 0, prefill_threads: 0 }
    }

    /// Pin the decode attention fan-out (`0` restores auto-sizing).
    /// Outputs are bit-identical across widths, so this is purely a
    /// performance knob.
    pub fn with_decode_threads(mut self, threads: usize) -> Self {
        self.decode_threads = threads;
        self
    }

    /// Pin the prefill attention fan-out (`0` restores auto-sizing) —
    /// the prefill twin of [`NativeBackend::with_decode_threads`], and
    /// bit-identical across widths for the same reason. On a Q8 cache
    /// the pinned width acts as an upper bound: the driver additionally
    /// caps jobs at `attention::paged::MIN_Q8_ROWS_PER_JOB` rows each so
    /// per-job tile re-dequantization stays amortized.
    pub fn with_prefill_threads(mut self, threads: usize) -> Self {
        self.prefill_threads = threads;
        self
    }

    fn prefill_width(&self) -> Option<usize> {
        match self.prefill_threads {
            0 => None,
            t => Some(t),
        }
    }

    fn decode_width(&self) -> Option<usize> {
        match self.decode_threads {
            0 => None,
            t => Some(t),
        }
    }

    pub fn model(&self) -> &NativeModel {
        &self.model
    }
}

impl Backend for NativeBackend {
    fn config(&self) -> &ModelConfig {
        self.model.config()
    }

    fn prefill(
        &self,
        tokens: &[u32],
        cache: &mut dyn KvStore,
        table: &mut BlockTable,
    ) -> Vec<f32> {
        self.model.prefill_with(tokens, cache, table, self.prefill_width())
    }

    fn decode(&self, items: &mut [DecodeItem<'_>], cache: &mut dyn KvStore) -> Vec<Vec<f32>> {
        // One joint pass: weights are streamed once per STEP, not once per
        // sequence (see NativeModel::decode_batch), and the per-sequence
        // attention fans out across the persistent worker pool with
        // per-worker workspaces.
        let tokens: Vec<u32> = items.iter().map(|i| i.token).collect();
        let mut tables: Vec<&mut BlockTable> =
            items.iter_mut().map(|i| &mut *i.table).collect();
        self.model.decode_batch_with(&tokens, cache, &mut tables, self.decode_width()).0
    }

    fn forward_step(&self, batch: &mut MixedBatch<'_>, cache: &mut dyn KvStore) -> StepOutputs {
        // One fused pass (see `NativeModel::forward_mixed`): prefill
        // chunk rows and decode rows share every matmul, so weights
        // stream from memory once per STEP across both kinds of work,
        // and both attention paths fan out across the persistent worker
        // pool (prefill streaming KV tiles straight out of the paged
        // store — no dense gather).
        let want: Vec<bool> = batch.prefill.iter().map(|c| c.want_logits).collect();
        let chunk_tokens: Vec<&[u32]> = batch.prefill.iter().map(|c| c.tokens).collect();
        let mut chunk_tables: Vec<&mut BlockTable> =
            batch.prefill.iter_mut().map(|c| &mut *c.table).collect();
        let decode_tokens: Vec<u32> = batch.decode.iter().map(|i| i.token).collect();
        let mut decode_tables: Vec<&mut BlockTable> =
            batch.decode.iter_mut().map(|i| &mut *i.table).collect();
        let (prefill_logits, decode_logits, prefill_dequant_tiles, skipped_tiles) =
            self.model.forward_mixed(
                &chunk_tokens,
                &mut chunk_tables,
                &want,
                &decode_tokens,
                &mut decode_tables,
                cache,
                self.prefill_width(),
                self.decode_width(),
            );
        StepOutputs { prefill_logits, decode_logits, prefill_dequant_tiles, skipped_tiles }
    }

    fn supports_mixed_step(&self) -> bool {
        true
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn supports_offset_prefill(&self) -> bool {
        true
    }

    fn supports_quantized_kv(&self) -> bool {
        true
    }

    fn weight_dtype(&self) -> WeightDtype {
        self.model.store().dtype()
    }

    fn weight_bytes(&self) -> usize {
        self.model.store().weight_bytes()
    }

    fn kernel_dispatch(&self) -> &'static str {
        crate::tensor::simd::active().name
    }

    fn supports_int_score_domain(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::{BlockAllocator, PagedKvCache};
    use crate::model::{ModelConfig, ModelWeights};

    #[test]
    fn native_backend_decode_matches_model() {
        let cfg = ModelConfig::tiny();
        let model = NativeModel::new(ModelWeights::init(&cfg, 1));
        let backend = NativeBackend::new(model.clone());
        let mut cache = PagedKvCache::new(cfg.n_layers, 16, 8, cfg.n_kv_heads, cfg.head_dim());
        let mut alloc = BlockAllocator::new(16, 8);

        // Two sequences decoding in one batch must match individual calls.
        let mut t1 = BlockTable::new();
        let mut t2 = BlockTable::new();
        t1.reserve(4, &mut alloc);
        t2.reserve(4, &mut alloc);
        backend.prefill(&[256, 1, 2], &mut cache, &mut t1);
        backend.prefill(&[256, 9], &mut cache, &mut t2);

        // Reference: clone state, decode separately.
        let mut cache_ref = PagedKvCache::new(cfg.n_layers, 16, 8, cfg.n_kv_heads, cfg.head_dim());
        let mut alloc_ref = BlockAllocator::new(16, 8);
        let mut r1 = BlockTable::new();
        let mut r2 = BlockTable::new();
        r1.reserve(4, &mut alloc_ref);
        r2.reserve(4, &mut alloc_ref);
        model.prefill(&[256, 1, 2], &mut cache_ref, &mut r1);
        model.prefill(&[256, 9], &mut cache_ref, &mut r2);
        let ref1 = model.decode_step(3, &mut cache_ref, &mut r1);
        let ref2 = model.decode_step(10, &mut cache_ref, &mut r2);

        let mut items = [
            DecodeItem { token: 3, table: &mut t1 },
            DecodeItem { token: 10, table: &mut t2 },
        ];
        let out = backend.decode(&mut items, &mut cache);
        assert_eq!(out[0], ref1);
        assert_eq!(out[1], ref2);
    }
}
