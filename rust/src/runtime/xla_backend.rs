//! PJRT-backed execution of AOT-lowered HLO artifacts.
//!
//! The three-layer path: Pallas kernels (L1) lower inside the JAX model
//! (L2) to HLO text via `python/compile/aot.py`; this backend loads that
//! text, compiles it on the PJRT CPU client, uploads the weights **once**
//! as device buffers, and executes prefill/decode from the Rust request
//! loop. Python never runs here.
//!
//! ## HLO calling conventions (shared with `python/compile/model.py`)
//!
//! Prefill (`prefill_s{S}.hlo.txt`), batch 1:
//! * inputs: `flat_params…`, `tokens: i32[S]`
//! * outputs (tuple): `logits: f32[S, vocab]`, `ks: f32[L, S, kv_dim]`,
//!   `vs: f32[L, S, kv_dim]`
//!
//! Decode (`decode_b{B}.hlo.txt`):
//! * inputs: `flat_params…`, `tokens: i32[B]`, `ctx_lens: i32[B]`,
//!   `block_tables: i32[B, max_blocks_per_seq]`,
//!   `k_cache: f32[L, num_blocks, block_size, kv_heads, head_dim]`,
//!   `v_cache: …`
//! * outputs (tuple): `logits: f32[B, vocab]`, `k_new: f32[L, B, kv_dim]`,
//!   `v_new: f32[L, B, kv_dim]`
//!
//! The decode HLO computes paged GQA attention (the Pallas kernel) over
//! the cache contents (`ctx_lens` tokens per sequence) *plus* the current
//! token's in-graph K/V; Rust writes `k_new`/`v_new` into the paged pool
//! afterwards, keeping cache ownership on the Rust side.

use super::artifacts::ArtifactManifest;
use super::backend::{Backend, DecodeItem};
// The offline build has no PJRT binding crate; the in-tree stub exposes
// the same API and fails fast at runtime (see `runtime::pjrt_stub`).
use super::pjrt_stub as xla;
use crate::kvcache::{BlockTable, KvStore, PagedKvCache};
use crate::model::{ModelConfig, ModelWeights};
use crate::tokenizer::PAD;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// A compiled bucket executable.
struct CompiledBucket {
    exe: xla::PjRtLoadedExecutable,
}

/// PJRT CPU backend over AOT artifacts.
pub struct XlaBackend {
    client: xla::PjRtClient,
    manifest: ArtifactManifest,
    config: ModelConfig,
    /// Weights as device buffers, in `flat_params` order.
    weight_bufs: Vec<xla::PjRtBuffer>,
    prefill: BTreeMap<usize, CompiledBucket>, // seq bucket → exe
    decode: BTreeMap<usize, CompiledBucket>,  // batch bucket → exe
}

// The PJRT client/buffers are only touched from the engine thread; the
// xla crate wrappers are raw pointers without auto-Send, so we assert it.
unsafe impl Send for XlaBackend {}

impl XlaBackend {
    /// Load every artifact in `manifest`, compile, and upload `weights`.
    pub fn load(manifest: ArtifactManifest, weights: &ModelWeights) -> Result<XlaBackend> {
        if !weights.config.shape_eq(&manifest.config) {
            bail!(
                "weights config {:?} != artifact config {:?}",
                weights.config,
                manifest.config
            );
        }
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let mut prefill = BTreeMap::new();
        let mut decode = BTreeMap::new();
        for e in &manifest.entries {
            let proto = xla::HloModuleProto::from_text_file(&e.path)
                .with_context(|| format!("load HLO {:?}", e.path))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).with_context(|| format!("compile {:?}", e.path))?;
            let bucket = CompiledBucket { exe };
            match e.kind.as_str() {
                "prefill" => {
                    prefill.insert(e.seq, bucket);
                }
                "decode" => {
                    decode.insert(e.batch, bucket);
                }
                other => bail!("unknown artifact kind {other:?}"),
            }
        }
        let mut weight_bufs = Vec::new();
        for (name, shape, data) in weights.flat_params() {
            let buf = client
                .buffer_from_host_buffer::<f32>(data, &shape, None)
                .with_context(|| format!("upload weight {name}"))?;
            weight_bufs.push(buf);
        }
        let config = manifest.config;
        Ok(XlaBackend { client, manifest, config, weight_bufs, prefill, decode })
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    fn i32_buffer(&self, data: &[i32], shape: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<i32>(data, shape, None)?)
    }

    fn f32_buffer(&self, data: &[f32], shape: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<f32>(data, shape, None)?)
    }

    /// Execute with the pre-uploaded weights plus call-specific buffers;
    /// returns the flattened output tuple as literals.
    fn run(&self, exe: &xla::PjRtLoadedExecutable, extra: Vec<xla::PjRtBuffer>) -> Result<Vec<xla::Literal>> {
        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        for b in &extra {
            args.push(b);
        }
        let outs = exe.execute_b(&args)?;
        let lit = outs[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    fn prefill_impl(
        &self,
        tokens: &[u32],
        cache: &mut PagedKvCache,
        table: &mut BlockTable,
    ) -> Result<Vec<f32>> {
        if !table.is_empty() {
            bail!(
                "XLA prefill artifacts assume a fresh sequence (positions \
                 start at 0); chunked prefill / prefix adoption is native-only"
            );
        }
        let n = tokens.len();
        let bucket = self
            .manifest
            .prefill_bucket(n)
            .with_context(|| format!("no prefill bucket ≥ {n} tokens"))?;
        let s = bucket.seq;
        let exe = &self.prefill[&s].exe;
        let mut padded: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        padded.resize(s, PAD as i32);
        let outs = self.run(exe, vec![self.i32_buffer(&padded, &[s])?])?;
        let (logits, ks, vs) = match &outs[..] {
            [a, b, c] => (a, b, c),
            other => bail!("prefill returned {} outputs, expected 3", other.len()),
        };
        let kvd = self.config.kv_dim();
        let l_count = self.config.n_layers;
        let ks: Vec<f32> = ks.to_vec::<f32>()?;
        let vs: Vec<f32> = vs.to_vec::<f32>()?;
        // Append slots and write the valid K/V rows.
        let slots: Vec<_> = (0..n).map(|_| table.append_slot(cache.block_size())).collect();
        for (i, &(b, slot)) in slots.iter().enumerate() {
            for layer in 0..l_count {
                let off = (layer * s + i) * kvd;
                // write_token writes one layer at a time — direct pool write.
                cache.write_token(layer, b, slot, &ks[off..off + kvd], &vs[off..off + kvd]);
            }
        }
        // Last valid row's logits.
        let logits: Vec<f32> = logits.to_vec::<f32>()?;
        let vocab = self.config.vocab;
        Ok(logits[(n - 1) * vocab..n * vocab].to_vec())
    }

    fn decode_impl(
        &self,
        items: &mut [DecodeItem<'_>],
        cache: &mut PagedKvCache,
    ) -> Result<Vec<Vec<f32>>> {
        let n = items.len();
        assert!(n > 0);
        let bucket = self
            .manifest
            .decode_bucket(n)
            .with_context(|| format!("no decode bucket ≥ batch {n}"))?;
        let b = bucket.batch;
        let exe = &self.decode[&b].exe;
        let mbs = self.manifest.max_blocks_per_seq;

        let mut tokens = vec![PAD as i32; b];
        let mut ctx_lens = vec![0i32; b];
        let mut tables = vec![0i32; b * mbs];
        for (i, item) in items.iter().enumerate() {
            tokens[i] = item.token as i32;
            ctx_lens[i] = item.table.len() as i32;
            for (j, &blk) in item.table.blocks().iter().enumerate() {
                assert!(j < mbs, "sequence exceeds max_blocks_per_seq");
                tables[i * mbs + j] = blk as i32;
            }
        }
        // Concatenate per-layer pools into [L, nb, bs, kvh, hd].
        let l_count = self.config.n_layers;
        let pool = cache.num_blocks() * cache.block_size() * cache.kv_heads() * cache.head_dim();
        let mut k_cat = Vec::with_capacity(l_count * pool);
        let mut v_cat = Vec::with_capacity(l_count * pool);
        for layer in 0..l_count {
            k_cat.extend_from_slice(cache.raw_keys(layer));
            v_cat.extend_from_slice(cache.raw_values(layer));
        }
        let cache_shape = [
            l_count,
            cache.num_blocks(),
            cache.block_size(),
            cache.kv_heads(),
            cache.head_dim(),
        ];
        let extra = vec![
            self.i32_buffer(&tokens, &[b])?,
            self.i32_buffer(&ctx_lens, &[b])?,
            self.i32_buffer(&tables, &[b, mbs])?,
            self.f32_buffer(&k_cat, &cache_shape)?,
            self.f32_buffer(&v_cat, &cache_shape)?,
        ];
        let outs = self.run(exe, extra)?;
        let (logits, k_new, v_new) = match &outs[..] {
            [a, x, y] => (a, x, y),
            other => bail!("decode returned {} outputs, expected 3", other.len()),
        };
        let logits: Vec<f32> = logits.to_vec::<f32>()?;
        let k_new: Vec<f32> = k_new.to_vec::<f32>()?;
        let v_new: Vec<f32> = v_new.to_vec::<f32>()?;
        let kvd = self.config.kv_dim();
        let vocab = self.config.vocab;
        let mut result = Vec::with_capacity(n);
        for (i, item) in items.iter_mut().enumerate() {
            let (blk, slot) = item.table.append_slot(cache.block_size());
            for layer in 0..l_count {
                let off = (layer * b + i) * kvd;
                cache.write_token(
                    layer,
                    blk,
                    slot,
                    &k_new[off..off + kvd],
                    &v_new[off..off + kvd],
                );
            }
            result.push(logits[i * vocab..(i + 1) * vocab].to_vec());
        }
        Ok(result)
    }
}

impl Backend for XlaBackend {
    fn config(&self) -> &ModelConfig {
        &self.config
    }

    fn prefill(
        &self,
        tokens: &[u32],
        cache: &mut dyn KvStore,
        table: &mut BlockTable,
    ) -> Vec<f32> {
        let cache = cache
            .dense_f32_mut()
            .expect("XLA backend requires the dense f32 KV cache (kv_dtype = F32)");
        self.prefill_impl(tokens, cache, table).expect("XLA prefill failed")
    }

    fn decode(&self, items: &mut [DecodeItem<'_>], cache: &mut dyn KvStore) -> Vec<Vec<f32>> {
        let cache = cache
            .dense_f32_mut()
            .expect("XLA backend requires the dense f32 KV cache (kv_dtype = F32)");
        self.decode_impl(items, cache).expect("XLA decode failed")
    }

    /// The AOT artifacts are lowered for fixed shapes and fresh
    /// sequences — prefill cannot resume at a nonzero cache position —
    /// so the engine plans exclusive (whole-prompt XOR decode) steps and
    /// `forward_step` runs the serial default implementation.
    fn supports_mixed_step(&self) -> bool {
        false
    }

    fn name(&self) -> &'static str {
        "xla-pjrt"
    }
}
