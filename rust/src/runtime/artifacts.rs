//! AOT artifact manifest.
//!
//! `python/compile/aot.py` lowers the JAX model for a grid of shape
//! buckets and writes `artifacts/manifest.json` describing them; this
//! module parses the manifest and maps runtime shapes onto buckets.

use crate::model::ModelConfig;
use crate::util::json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// One lowered executable.
#[derive(Debug, Clone, PartialEq)]
pub struct BucketSpec {
    /// "prefill" or "decode".
    pub kind: String,
    /// Batch bucket (decode) — 1 for prefill entries.
    pub batch: usize,
    /// Sequence bucket (prefill) — 0 for decode entries.
    pub seq: usize,
    /// HLO text path relative to the manifest.
    pub path: PathBuf,
}

/// Parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    pub model: String,
    pub config: ModelConfig,
    /// Paged-cache geometry baked into the decode HLO.
    pub num_blocks: usize,
    pub block_size: usize,
    /// Max block-table length per sequence baked into the decode HLO.
    pub max_blocks_per_seq: usize,
    pub entries: Vec<BucketSpec>,
    /// Directory containing the artifacts.
    pub dir: PathBuf,
}

impl ArtifactManifest {
    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<ArtifactManifest> {
        let path = dir.join("manifest.json");
        let text =
            std::fs::read_to_string(&path).with_context(|| format!("read {path:?}"))?;
        let v = json::parse(&text).with_context(|| format!("parse {path:?}"))?;
        let cfg = v.get("config").context("manifest missing 'config'")?;
        let req = |k: &str| -> Result<usize> {
            cfg.get_usize(k).with_context(|| format!("config missing '{k}'"))
        };
        let config = ModelConfig {
            vocab: req("vocab")?,
            d_model: req("d_model")?,
            n_layers: req("n_layers")?,
            n_heads: req("n_heads")?,
            n_kv_heads: req("n_kv_heads")?,
            d_ff: req("d_ff")?,
            max_seq: req("max_seq")?,
            alibi: cfg.get("alibi").and_then(|b| b.as_bool()).context("config missing 'alibi'")?,
            rms_eps: cfg.get_f64("rms_eps").context("config missing 'rms_eps'")? as f32,
            // Runtime serving knobs, never artifact state (see
            // `ModelConfig::sparsity` / `ModelConfig::score_domain`).
            sparsity: Default::default(),
            score_domain: Default::default(),
        };
        let mut entries = Vec::new();
        for e in v.get("entries").and_then(|e| e.as_arr()).context("manifest missing 'entries'")? {
            entries.push(BucketSpec {
                kind: e.get_str("kind").context("entry missing 'kind'")?.to_string(),
                batch: e.get_usize("batch").unwrap_or(1),
                seq: e.get_usize("seq").unwrap_or(0),
                path: dir.join(e.get_str("path").context("entry missing 'path'")?),
            });
        }
        if entries.is_empty() {
            bail!("manifest has no entries");
        }
        Ok(ArtifactManifest {
            model: v.get_str("model").unwrap_or("unknown").to_string(),
            config,
            num_blocks: v.get_usize("num_blocks").context("manifest missing 'num_blocks'")?,
            block_size: v.get_usize("block_size").context("manifest missing 'block_size'")?,
            max_blocks_per_seq: v
                .get_usize("max_blocks_per_seq")
                .context("manifest missing 'max_blocks_per_seq'")?,
            entries,
            dir: dir.to_path_buf(),
        })
    }

    /// Smallest prefill bucket with `seq >= n`.
    pub fn prefill_bucket(&self, n: usize) -> Option<&BucketSpec> {
        self.entries
            .iter()
            .filter(|e| e.kind == "prefill" && e.seq >= n)
            .min_by_key(|e| e.seq)
    }

    /// Smallest decode bucket with `batch >= n`.
    pub fn decode_bucket(&self, n: usize) -> Option<&BucketSpec> {
        self.entries
            .iter()
            .filter(|e| e.kind == "decode" && e.batch >= n)
            .min_by_key(|e| e.batch)
    }

    /// Largest decode batch available (the scheduler's cap under XLA).
    pub fn max_decode_batch(&self) -> usize {
        self.entries.iter().filter(|e| e.kind == "decode").map(|e| e.batch).max().unwrap_or(0)
    }

    /// Largest prefill bucket (prompt-length cap under XLA).
    pub fn max_prefill_seq(&self) -> usize {
        self.entries.iter().filter(|e| e.kind == "prefill").map(|e| e.seq).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, extra_entry: &str) {
        std::fs::create_dir_all(dir).unwrap();
        let text = format!(
            r#"{{
          "model": "tiny",
          "config": {{"vocab":384,"d_model":64,"n_layers":2,"n_heads":4,
                      "n_kv_heads":2,"d_ff":128,"max_seq":256,"alibi":true,
                      "rms_eps":1e-5}},
          "num_blocks": 64, "block_size": 16, "max_blocks_per_seq": 16,
          "entries": [
            {{"kind":"prefill","batch":1,"seq":16,"path":"prefill_s16.hlo.txt"}},
            {{"kind":"prefill","batch":1,"seq":64,"path":"prefill_s64.hlo.txt"}},
            {{"kind":"decode","batch":1,"path":"decode_b1.hlo.txt"}},
            {{"kind":"decode","batch":4,"path":"decode_b4.hlo.txt"}}{extra_entry}
          ]
        }}"#
        );
        std::fs::write(dir.join("manifest.json"), text).unwrap();
    }

    #[test]
    fn load_and_bucket_selection() {
        let dir = std::env::temp_dir().join("opt_gptq_manifest_test");
        write_manifest(&dir, "");
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.model, "tiny");
        assert_eq!(m.config.d_model, 64);
        assert!(m.config.alibi);
        assert_eq!(m.prefill_bucket(10).unwrap().seq, 16);
        assert_eq!(m.prefill_bucket(17).unwrap().seq, 64);
        assert!(m.prefill_bucket(65).is_none());
        assert_eq!(m.decode_bucket(1).unwrap().batch, 1);
        assert_eq!(m.decode_bucket(2).unwrap().batch, 4);
        assert_eq!(m.max_decode_batch(), 4);
        assert_eq!(m.max_prefill_seq(), 64);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_fields_error() {
        let dir = std::env::temp_dir().join("opt_gptq_manifest_bad");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"model":"x"}"#).unwrap();
        assert!(ArtifactManifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
