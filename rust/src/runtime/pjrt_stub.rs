//! In-tree stand-in for a PJRT binding crate.
//!
//! The offline build environment vendors no PJRT/XLA binding, so this
//! module provides the exact API surface [`super::XlaBackend`] and the
//! XLA integration tests compile against (`PjRtClient`, `PjRtBuffer`,
//! `PjRtLoadedExecutable`, `Literal`, `HloModuleProto`,
//! `XlaComputation`). Every entry point returns [`Error`] at runtime, so
//! code paths that reach PJRT fail fast with a clear message while the
//! rest of the engine — including `cargo build` / `cargo test` with no
//! artifacts present — works normally (the XLA tests skip when
//! `artifacts/` is absent, so they never touch these stubs in CI).
//!
//! To run HLO artifacts for real, swap the `use super::pjrt_stub as xla;`
//! alias in `xla_backend.rs` (and `tests/xla_runtime.rs`) for a real
//! binding crate with this interface.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Error every stub entry point returns: PJRT is not linked into this
/// build.
#[derive(Debug, Clone)]
pub struct Error(&'static str);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>() -> Result<T, Error> {
    Err(Error(
        "PJRT runtime is not available in this build (in-tree stub); \
         link a real PJRT binding to execute HLO artifacts",
    ))
}

/// Element types a host buffer can carry.
pub trait NativeType: Copy + Default {}
impl NativeType for f32 {}
impl NativeType for i32 {}

/// Stub of a PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient(());

/// Stub of a device buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer(());

/// Stub of a compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

/// Stub of a host-side literal (tensor) value.
#[derive(Debug)]
pub struct Literal(());

/// Stub of a parsed HLO module.
#[derive(Debug)]
pub struct HloModuleProto(());

/// Stub of an XLA computation.
#[derive(Debug)]
pub struct XlaComputation(());

impl PjRtClient {
    /// Create a CPU client. Always fails in the stub.
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable()
    }

    /// Compile a computation into an executable.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }

    /// Upload a host buffer to the device.
    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _shape: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        unavailable()
    }
}

impl HloModuleProto {
    /// Parse an HLO text file.
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto, Error> {
        unavailable()
    }
}

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

impl PjRtLoadedExecutable {
    /// Execute with the given argument buffers; returns per-device,
    /// per-output buffers.
    pub fn execute_b<B: Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

impl Literal {
    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        unavailable()
    }

    /// Decompose a 1-tuple literal into its single element.
    pub fn to_tuple1(self) -> Result<Literal, Error> {
        unavailable()
    }

    /// Read the literal out as a typed host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_fast_with_context() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("PJRT runtime is not available"));
        assert!(HloModuleProto::from_text_file("nope.hlo.txt").is_err());
    }
}
