//! `opt-gptq` — CLI for the Opt-GPTQ serving stack.
//!
//! ```text
//! opt-gptq serve    --model tiny --port 8765 --workers 1 [--kv-dtype q8]
//!                   [--weight-dtype q4 [--weights w.bin]] [--xla --artifacts DIR]
//! opt-gptq generate --model tiny --prompt "hello" --max-tokens 32
//! opt-gptq quantize --model tiny --bits 4 --group-size 64 [--act-order]
//!                   [--pack] --out weights.bin
//! opt-gptq info     --model tiny
//! ```
//!
//! Scheduling knobs (serve/generate): `--step-budget N` caps the tokens
//! per mixed engine step (decode + prefill chunks, default 256);
//! `--no-chunked-prefill` restores the legacy one-prompt-per-step
//! planner. Storage knobs: `--kv-dtype q8` packs the KV pool;
//! `--weight-dtype q8|q4|q3` serves the projections from packed storage
//! — from a saved `quantize --pack` artifact when `--weights FILE` is
//! given, otherwise calibration-free RTN on the synthetic-init weights;
//! either way bit-identical to f32 serving of the dequantized
//! reconstruction. `quantize --pack` writes the GPTQ-calibrated packed
//! artifact instead of the fake-quant dense one. Overload knobs
//! (serve): `--queue-depth`, `--deadline-ms`, `--target-itl-ms`,
//! `--max-restarts` — see [`admission_config`]. Sparsity knobs
//! (serve/generate, native backend): `--window-blocks W` caps attention
//! to the last `W` KV blocks (out-of-window blocks are freed back to
//! the pool), `--sink-blocks S` keeps the first `S` blocks always
//! visible, `--skip-threshold T` enables score-bound tile skipping
//! (`0` = provably exact, `0<T<1` = bounded-error threshold mode) — see
//! [`sparsity_config`]. `--q8-score-domain int` (native + `--kv-dtype
//! q8` only) scores decode attention in the integer domain straight off
//! the packed K tiles — bounded-error, default `f32` — see
//! [`score_domain`]. Spill knobs (serve/generate, **opt-in**):
//! `--spill-dir DIR` roots the crash-safe disk tier for evicted prefix
//! KV (without it no tier is built and the serving path performs no
//! file IO); `--spill-cap-bytes B` bounds its on-disk footprint
//! (oldest segment reclaimed past the cap) — see [`spill_config`].
//! Observability knobs (serve): `--flight-records N` sizes each
//! worker's crash flight-recorder ring (default 128 recent step
//! records, dumped to the log on a worker crash and served at
//! `GET /debug/flight`); `/metrics` (Prometheus text) and
//! `GET /debug/trace/{id}` need no flags.

use opt_gptq::attention::{ScoreDomain, SparsityConfig};
use opt_gptq::coordinator::{
    AdmissionConfig, AimdConfig, BucketPolicy, EngineConfig, KvCacheDtype, Router, RouterConfig,
    SchedulerConfig, SpillConfig, WeightDtype,
};
use opt_gptq::model::{
    weights::{quantize_weights, quantize_weights_packed, QuantMethod},
    ModelConfig, ModelWeights, NativeModel, SamplingParams,
};
use opt_gptq::runtime::{ArtifactManifest, Backend, NativeBackend, XlaBackend};
use opt_gptq::server::Server;
use opt_gptq::tokenizer::ByteTokenizer;
use opt_gptq::util::cli::Args;
use std::sync::Arc;

fn main() {
    opt_gptq::util::logging::init();
    let args = Args::from_env();
    let code = match args.command.as_deref() {
        Some("serve") => cmd_serve(&args),
        Some("generate") => cmd_generate(&args),
        Some("quantize") => cmd_quantize(&args),
        Some("info") => cmd_info(&args),
        _ => {
            eprintln!(
                "usage: opt-gptq <serve|generate|quantize|info> [--model tiny|small|mini] …\n\
                 see README.md for the full flag list"
            );
            2
        }
    };
    std::process::exit(code);
}

fn model_config(args: &Args) -> ModelConfig {
    let name = args.get_str("model", "tiny");
    let cfg = ModelConfig::preset(name).unwrap_or_else(|| {
        eprintln!("unknown model preset '{name}' (tiny|small|mini)");
        std::process::exit(2);
    });
    cfg.with_sparsity(sparsity_config(args)).with_score_domain(score_domain(args))
}

/// Parse `--q8-score-domain f32|int` (default `"f32"` — every baseline
/// unchanged). `int` scores q8 decode attention in the integer domain
/// (widening i8×i8→i32 dots over packed K tiles, one rescale per tile):
/// bounded-error and **opt-in only**, and it needs both the packed KV
/// cache to score from and the native kernels to score with.
fn score_domain(args: &Args) -> ScoreDomain {
    let name = args.get_str("q8-score-domain", "f32");
    let sd = ScoreDomain::parse(name).unwrap_or_else(|| {
        eprintln!("unknown --q8-score-domain '{name}' (f32|int)");
        std::process::exit(2);
    });
    if sd == ScoreDomain::Int {
        if args.flag("xla") {
            eprintln!(
                "--q8-score-domain int requires the native backend (the XLA decode HLO \
                 scores in f32 over raw pools)"
            );
            std::process::exit(2);
        }
        if args.get_str("kv-dtype", "f32") != "q8" {
            eprintln!(
                "--q8-score-domain int requires --kv-dtype q8 (integer-domain scoring reads \
                 packed K tiles; an f32 cache has nothing to score in the integer domain)"
            );
            std::process::exit(2);
        }
    }
    sd
}

/// Parse the sparse-attention flags into a [`SparsityConfig`]. Defaults
/// are dense (`window-blocks 0`, `sink-blocks 0`, `skip-threshold -1`),
/// so a flagless run is bit-identical to every pre-sparsity baseline.
/// Threshold-mode skipping (`0 < T < 1`) is the only lossy mode and is
/// reachable **only** through this explicit opt-in flag.
fn sparsity_config(args: &Args) -> SparsityConfig {
    let sp = SparsityConfig {
        window_blocks: args.get_usize("window-blocks", 0),
        sink_blocks: args.get_usize("sink-blocks", 0),
        skip_threshold: args.get_f64("skip-threshold", -1.0) as f32,
    };
    if sp.skip_threshold >= 1.0 {
        eprintln!(
            "--skip-threshold must be below 1 (0 = exact skipping, 0<T<1 = lossy threshold \
             mode, negative = off), got {}",
            sp.skip_threshold
        );
        std::process::exit(2);
    }
    if !sp.is_dense() && args.flag("xla") {
        eprintln!(
            "--window-blocks/--sink-blocks/--skip-threshold require the native backend \
             (the XLA decode HLO walks the full block table)"
        );
        std::process::exit(2);
    }
    sp
}

fn weight_dtype(args: &Args) -> WeightDtype {
    let name = args.get_str("weight-dtype", "f32");
    let dtype = WeightDtype::parse(name).unwrap_or_else(|| {
        eprintln!("unknown --weight-dtype '{name}' (f32|q8|q4|q3)");
        std::process::exit(2);
    });
    if dtype != WeightDtype::F32 && args.flag("xla") {
        eprintln!("--weight-dtype {name} requires the native backend (the XLA artifacts upload raw f32 weight buffers)");
        std::process::exit(2);
    }
    dtype
}

/// Build a model from a `--weights FILE` artifact, if one was given:
/// the packed `OGPTQP01` format when a quantized `--weight-dtype` is
/// requested (the `quantize --pack` output), the dense `OGPTQW01`
/// format otherwise. Bit width and model config are validated against
/// the flags (the engine budgets and reports by the `--model` preset,
/// so a silently different artifact must not slip in). The returned
/// model is Arc-backed — `serve` loads once and clones per worker.
fn load_weights_model(args: &Args, cfg: &ModelConfig) -> Option<NativeModel> {
    let path = args.get("weights")?;
    // Shape comparison only: sparsity is a runtime knob, never artifact
    // state, so a windowed serve of a dense-saved artifact is fine.
    let check_config = |loaded: &ModelConfig| {
        if !loaded.shape_eq(cfg) {
            eprintln!(
                "--weights {path} holds a different model shape than --model {} — \
                 pass the preset the artifact was quantized from",
                args.get_str("model", "tiny")
            );
            std::process::exit(2);
        }
    };
    Some(match weight_dtype(args).bits() {
        Some(bits) => {
            let packed = opt_gptq::model::PackedModelWeights::load(std::path::Path::new(path))
                .unwrap_or_else(|e| {
                    eprintln!("failed to load packed weights from {path}: {e:#}");
                    std::process::exit(1);
                });
            if packed.bits != bits {
                eprintln!(
                    "--weight-dtype asks for {bits}-bit but {path} holds a {}-bit artifact",
                    packed.bits
                );
                std::process::exit(2);
            }
            check_config(&packed.config);
            NativeModel::from_store(Arc::new(packed))
        }
        None => {
            let loaded = ModelWeights::load(std::path::Path::new(path)).unwrap_or_else(|e| {
                eprintln!("failed to load weights from {path}: {e:#}");
                std::process::exit(1);
            });
            check_config(&loaded.config);
            NativeModel::new(loaded)
        }
    })
}

/// Native model for one worker: the `--weights` artifact when given,
/// otherwise synthetic-init weights (packed with calibration-free RTN
/// under a quantized `--weight-dtype`; GPTQ-calibrated artifacts come
/// from `opt-gptq quantize --pack`). Either packed path is
/// bit-identical to serving the dequantized reconstruction.
fn native_model(args: &Args, cfg: &ModelConfig, seed: u64) -> NativeModel {
    if let Some(model) = load_weights_model(args, cfg) {
        return model;
    }
    match weight_dtype(args).bits() {
        None => NativeModel::new(ModelWeights::init(cfg, seed)),
        Some(bits) => {
            let weights = ModelWeights::init(cfg, seed);
            let group = args.get_usize("group-size", 64);
            let (packed, report) =
                quantize_weights_packed(&weights, QuantMethod::Rtn, bits, group, false, &[], &[], &[]);
            log::info!(
                "packed weights: {bits}-bit group {group}, mean rel err {:.5}, projections {} B",
                report.mean_error(),
                packed.projection_bytes()
            );
            NativeModel::from_store(Arc::new(packed))
        }
    }
}

fn make_backend(args: &Args, cfg: &ModelConfig, seed: u64) -> Box<dyn Backend> {
    if args.flag("xla") {
        let weights = ModelWeights::init(cfg, seed);
        let dir = std::path::PathBuf::from(args.get_str("artifacts", "artifacts"));
        let manifest = ArtifactManifest::load(&dir).unwrap_or_else(|e| {
            eprintln!("failed to load artifacts from {dir:?}: {e:#}\n(run `make artifacts` first)");
            std::process::exit(1);
        });
        Box::new(XlaBackend::load(manifest, &weights).unwrap_or_else(|e| {
            eprintln!("failed to initialize XLA backend: {e:#}");
            std::process::exit(1);
        }))
    } else {
        Box::new(NativeBackend::new(native_model(args, cfg, seed)))
    }
}

fn engine_config(args: &Args, cfg: &ModelConfig) -> EngineConfig {
    let kv_budget = args.get_usize("kv-tokens", 4096.min(cfg.max_seq * 8));
    let block_size = args.get_usize("block-size", 16);
    let max_batch = args.get_usize("max-batch", 8);
    let kv_dtype_name = args.get_str("kv-dtype", "f32");
    let kv_dtype = KvCacheDtype::parse(kv_dtype_name).unwrap_or_else(|| {
        eprintln!("unknown --kv-dtype '{kv_dtype_name}' (f32|q8)");
        std::process::exit(2);
    });
    if kv_dtype != KvCacheDtype::F32 && args.flag("xla") {
        eprintln!("--kv-dtype {kv_dtype_name} requires the native backend (the XLA artifacts consume raw f32 KV pools)");
        std::process::exit(2);
    }
    EngineConfig {
        num_blocks: kv_budget.div_ceil(block_size),
        block_size,
        sched: SchedulerConfig {
            max_running: args.get_usize("max-running", 64),
            max_decode_batch: max_batch,
            watermark_blocks: 2,
            // Token budget per mixed step (decode tokens + prefill-chunk
            // tokens); larger = bigger prefill chunks, smaller = tighter
            // inter-token latency under prompt load.
            step_token_budget: args.get_usize("step-budget", 256),
            // Interleaved chunked prefill is on by default; the engine
            // auto-disables it on backends without mixed-step support
            // (`--xla`).
            chunked_prefill: !args.flag("no-chunked-prefill"),
        },
        decode_buckets: BucketPolicy::exact(max_batch),
        prefill_chunk: usize::MAX,
        prefix_cache_blocks: 0,
        kv_dtype,
        weight_dtype: weight_dtype(args),
        spill: spill_config(args),
    }
}

/// Parse the spill-tier flags (`--spill-dir`, `--spill-cap-bytes`).
/// **Off unless `--spill-dir` is given** — the default serving path
/// must never touch the filesystem (ARCHITECTURE.md "Spill & recovery
/// contract"). A tier that fails to open degrades to serving without
/// it; it is never a startup error.
fn spill_config(args: &Args) -> Option<SpillConfig> {
    let dir = args.get_str("spill-dir", "");
    if dir.is_empty() {
        return None;
    }
    let mut sc = SpillConfig::new(dir);
    sc.cap_bytes = args.get_u64("spill-cap-bytes", sc.cap_bytes);
    Some(sc)
}

/// Overload-control knobs (see ARCHITECTURE.md "Overload & failure
/// contract"): `--queue-depth N` bounds the per-worker admission queue
/// (beyond it requests get 429 + Retry-After), `--deadline-ms D` is the
/// default scheduling deadline for requests without `timeout_ms`,
/// `--target-itl-ms T` is the inter-token SLO the AIMD concurrency
/// controller steers to, and `--max-restarts R` caps crash→respawn
/// cycles per worker before it goes permanently unhealthy.
fn admission_config(args: &Args) -> AdmissionConfig {
    let defaults = AdmissionConfig::default();
    let aimd_defaults = defaults.aimd;
    AdmissionConfig {
        queue_depth: args.get_usize("queue-depth", defaults.queue_depth),
        default_deadline_ms: args.get_u64("deadline-ms", defaults.default_deadline_ms),
        max_restarts: args.get_usize("max-restarts", defaults.max_restarts),
        aimd: AimdConfig {
            target_itl_s: args.get_f64("target-itl-ms", aimd_defaults.target_itl_s * 1e3) / 1e3,
            ..aimd_defaults
        },
    }
}

fn cmd_serve(args: &Args) -> i32 {
    let cfg = model_config(args);
    let econf = engine_config(args, &cfg);
    let workers = args.get_usize("workers", 1);
    let seed = args.get_u64("seed", 0);
    // A `--weights` artifact is loaded ONCE and shared: NativeModel is
    // Arc-backed, so every worker serves the same store instead of
    // paying one artifact copy each.
    let preloaded = (!args.flag("xla")).then(|| load_weights_model(args, &cfg)).flatten();
    // The factory is retained by the router for crash→respawn, so it
    // captures owned clones (it may outlive this frame and run on any
    // worker's supervisor thread).
    let factory_args = args.clone();
    let factory_cfg = cfg.clone();
    let router = Arc::new(Router::new(
        RouterConfig { engine: econf, workers, admission: admission_config(args) },
        move |w| match &preloaded {
            Some(model) => Box::new(NativeBackend::new(model.clone())) as Box<dyn Backend>,
            None => make_backend(&factory_args, &factory_cfg, seed + w as u64),
        },
    ));
    // Flight-recorder depth is a startup knob (resizing clears the
    // ring); the default keeps well above the 64-record post-mortem
    // floor while staying a bounded, preallocated buffer.
    let flight_records = args.get_usize("flight-records", 128);
    if flight_records != opt_gptq::obs::DEFAULT_FLIGHT_RECORDS {
        router.set_flight_records(flight_records.max(1));
    }
    let port = args.get_usize("port", 8765);
    let addr = format!("127.0.0.1:{port}");
    let server = match Server::bind(router, &addr) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("bind failed: {e:#}");
            return 1;
        }
    };
    log::info!(
        "serving model '{}' on http://{}",
        args.get_str("model", "tiny"),
        server.local_addr()
    );
    if let Err(e) = server.serve() {
        eprintln!("server error: {e:#}");
        return 1;
    }
    0
}

fn cmd_generate(args: &Args) -> i32 {
    let cfg = model_config(args);
    let backend = make_backend(args, &cfg, args.get_u64("seed", 0));
    let mut engine = opt_gptq::coordinator::Engine::new(backend, engine_config(args, &cfg));
    let tok = ByteTokenizer::new();
    let prompt = args.get_str("prompt", "the quick brown fox");
    let params = SamplingParams {
        max_tokens: args.get_usize("max-tokens", 32),
        temperature: args.get_f64("temperature", 0.0) as f32,
        top_k: args.get_usize("top-k", 0),
        ignore_eos: true,
    };
    if let Err(e) = engine.add_request(tok.encode(prompt), params) {
        eprintln!("request rejected: {e:#}");
        return 1;
    }
    let report = engine.run_to_completion();
    for out in engine.take_outputs() {
        println!("prompt : {prompt}");
        println!("output : {}", tok.decode(&out.tokens));
        println!("tokens : {:?}", out.tokens);
    }
    print!("{}", report.paper_block("run"));
    0
}

fn cmd_quantize(args: &Args) -> i32 {
    let cfg = model_config(args);
    let bits = args.get_usize("bits", 4) as u32;
    let group_size = args.get_usize("group-size", 64);
    let act_order = args.flag("act-order");
    let method = match args.get_str("method", "gptq") {
        "rtn" => QuantMethod::Rtn,
        _ => QuantMethod::Gptq,
    };
    let mut weights = ModelWeights::init(&cfg, args.get_u64("seed", 0));
    let model = NativeModel::new(weights.clone());
    let calib_text = opt_gptq::workload::synth_prompt(256, 1);
    let calib_tokens = ByteTokenizer::new().encode(&calib_text);
    log::info!("calibrating over {} tokens…", calib_tokens.len());
    let (a, m, f) = model.calibrate(&calib_tokens);
    if args.flag("pack") {
        // Straight to the packed serving artifact — no dequantized-f32
        // round-trip; `serve`/`generate` read it back via
        // `--weight-dtype qN --weights FILE`.
        if WeightDtype::from_bits(bits).is_none() {
            eprintln!("--pack serves 3|4|8-bit weights, not {bits}");
            return 2;
        }
        let (packed, report) =
            quantize_weights_packed(&weights, method, bits, group_size, act_order, &a, &m, &f);
        println!(
            "packed {:?} to {} bits (group {}{}): mean relative error {:.5}, projections {} B ({:.2}× whole-model compression)",
            args.get_str("model", "tiny"),
            bits,
            group_size,
            if act_order { ", act_order" } else { "" },
            report.mean_error(),
            packed.projection_bytes(),
            report.compression_ratio()
        );
        if let Some(out) = args.get("out") {
            if let Err(e) = packed.save(std::path::Path::new(out)) {
                eprintln!("save failed: {e:#}");
                return 1;
            }
            println!("wrote packed weights to {out}");
        }
        return 0;
    }
    let report =
        quantize_weights(&mut weights, method, bits, group_size, act_order, &a, &m, &f);
    println!(
        "quantized {:?} to {} bits (group {}{}): mean relative error {:.5}, {:.2}× compression",
        args.get_str("model", "tiny"),
        bits,
        group_size,
        if act_order { ", act_order" } else { "" },
        report.mean_error(),
        report.compression_ratio()
    );
    if let Some(out) = args.get("out") {
        if let Err(e) = weights.save(std::path::Path::new(out)) {
            eprintln!("save failed: {e:#}");
            return 1;
        }
        println!("wrote dequantized weights to {out}");
    }
    0
}

fn cmd_info(args: &Args) -> i32 {
    let cfg = model_config(args);
    println!("model preset : {}", args.get_str("model", "tiny"));
    println!("parameters   : {}", cfg.param_count());
    println!("d_model      : {}", cfg.d_model);
    println!("layers       : {}", cfg.n_layers);
    println!(
        "heads        : {} query / {} kv (G = {})",
        cfg.n_heads,
        cfg.n_kv_heads,
        cfg.group_size()
    );
    println!("d_ff         : {}", cfg.d_ff);
    println!("max_seq      : {}", cfg.max_seq);
    println!("alibi        : {}", cfg.alibi);
    println!("KV bytes/tok : {} (f32, all layers)", cfg.kv_bytes_per_token());
    let mha = cfg.as_mha_baseline();
    println!(
        "MHA baseline : {} KV bytes/tok ({}× more)",
        mha.kv_bytes_per_token(),
        cfg.group_size()
    );
    println!(
        "kernel table : {} (runtime dispatch; OPT_GPTQ_NO_SIMD=1 forces scalar)",
        opt_gptq::tensor::simd::active().name
    );
    println!("score domain : {} (--q8-score-domain)", cfg.score_domain.name());
    0
}
