//! Threaded TCP/HTTP front-end.
//!
//! A minimal HTTP/1.1 server (no async runtime is available offline)
//! speaking a JSON API over the [`Router`]:
//!
//! * `POST /generate` — `{"prompt": "...", "max_tokens": N,
//!   "temperature": T?, "top_k": K?}` → `{"id", "text", "tokens",
//!   "latency_s", "ttft_s"}`
//! * `GET /health` — `{"status":"ok","workers":N,"inflight":M}`
//!
//! Each connection is handled on its own thread; generation itself runs
//! on the router's engine workers, so slow clients never stall decoding.

use crate::coordinator::Router;
use crate::model::SamplingParams;
use crate::tokenizer::ByteTokenizer;
use crate::util::json::{self, Value};
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

/// HTTP server over a router.
pub struct Server {
    router: Arc<Router>,
    listener: TcpListener,
}

impl Server {
    /// Bind to `addr` (e.g. "127.0.0.1:8765"; port 0 picks a free port).
    pub fn bind(router: Arc<Router>, addr: &str) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        Ok(Server { router, listener })
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.listener.local_addr().expect("listener has an address")
    }

    /// Accept loop; one thread per connection. Blocks forever (callers
    /// run it on a dedicated thread; tests connect then drop).
    pub fn serve(&self) -> Result<()> {
        for stream in self.listener.incoming() {
            let stream = match stream {
                Ok(s) => s,
                Err(e) => {
                    log::warn!("accept error: {e}");
                    continue;
                }
            };
            let router = self.router.clone();
            std::thread::spawn(move || {
                if let Err(e) = handle_connection(stream, &router) {
                    log::debug!("connection error: {e}");
                }
            });
        }
        Ok(())
    }
}

/// Parse one HTTP request; returns (method, path, body).
fn read_request(stream: &mut TcpStream) -> Result<(String, String, String)> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut request_line = String::new();
    reader.read_line(&mut request_line)?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    let mut content_length = 0usize;
    loop {
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some((k, v)) = line.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap_or(0);
            }
        }
    }
    let mut body = vec![0u8; content_length.min(16 << 20)];
    reader.read_exact(&mut body)?;
    Ok((method, path, String::from_utf8_lossy(&body).into_owned()))
}

fn respond(stream: &mut TcpStream, status: &str, body: &str) -> Result<()> {
    let resp = format!(
        "HTTP/1.1 {status}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(resp.as_bytes())?;
    Ok(())
}

fn handle_connection(mut stream: TcpStream, router: &Router) -> Result<()> {
    let (method, path, body) = read_request(&mut stream)?;
    match (method.as_str(), path.as_str()) {
        ("GET", "/health") => {
            let v = json::obj(vec![
                ("status", "ok".into()),
                ("workers", router.num_workers().into()),
                ("inflight", router.inflight().into()),
            ]);
            respond(&mut stream, "200 OK", &v.to_string_compact())
        }
        ("POST", "/generate") => match handle_generate(router, &body) {
            Ok(v) => respond(&mut stream, "200 OK", &v.to_string_compact()),
            Err(e) => {
                let v = json::obj(vec![("error", format!("{e}").into())]);
                respond(&mut stream, "400 Bad Request", &v.to_string_compact())
            }
        },
        _ => {
            let v = json::obj(vec![("error", "not found".into())]);
            respond(&mut stream, "404 Not Found", &v.to_string_compact())
        }
    }
}

fn handle_generate(router: &Router, body: &str) -> Result<Value> {
    let req = json::parse(body).context("invalid JSON body")?;
    let prompt_text = req.get_str("prompt").context("missing 'prompt'")?;
    let tok = ByteTokenizer::new();
    let prompt = tok.encode(prompt_text);
    let params = SamplingParams {
        max_tokens: req.get_usize("max_tokens").unwrap_or(32),
        temperature: req.get_f64("temperature").unwrap_or(0.0) as f32,
        top_k: req.get_usize("top_k").unwrap_or(0),
        ignore_eos: req.get("ignore_eos").and_then(|b| b.as_bool()).unwrap_or(false),
    };
    let rx = router.submit(prompt, params)?;
    let out = rx
        .recv()
        .map_err(|_| anyhow::anyhow!("request rejected (too long for the KV pool?)"))?;
    Ok(json::obj(vec![
        ("id", out.id.into()),
        ("text", tok.decode(&out.tokens).into()),
        ("tokens", out.tokens.iter().map(|&t| t as usize).collect::<Vec<usize>>().into()),
        ("prompt_len", out.prompt_len.into()),
        ("latency_s", out.latency_s.into()),
        ("ttft_s", out.ttft_s.into()),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BucketPolicy, EngineConfig, RouterConfig, SchedulerConfig};
    use crate::model::{ModelConfig, ModelWeights, NativeModel};
    use crate::runtime::NativeBackend;

    fn start_server() -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
        let router = Arc::new(Router::new(
            RouterConfig {
                engine: EngineConfig {
                    num_blocks: 32,
                    block_size: 8,
                    sched: SchedulerConfig::default(),
                    decode_buckets: BucketPolicy::exact(8),
                    prefill_chunk: usize::MAX,
                    prefix_cache_blocks: 0,
                    kv_dtype: crate::kvcache::KvCacheDtype::F32,
                    weight_dtype: crate::model::WeightDtype::F32,
                },
                workers: 1,
            },
            |_| {
                let mc = ModelConfig::tiny();
                Box::new(NativeBackend::new(NativeModel::new(ModelWeights::init(&mc, 3))))
            },
        ));
        let server = Server::bind(router, "127.0.0.1:0").unwrap();
        let addr = server.local_addr();
        let h = std::thread::spawn(move || {
            let _ = server.serve();
        });
        (addr, h)
    }

    fn http(addr: std::net::SocketAddr, req: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(req.as_bytes()).unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        buf
    }

    #[test]
    fn health_endpoint() {
        let (addr, _h) = start_server();
        let resp = http(addr, "GET /health HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(resp.contains("200 OK"), "{resp}");
        assert!(resp.contains("\"status\":\"ok\""), "{resp}");
    }

    #[test]
    fn generate_endpoint_roundtrip() {
        let (addr, _h) = start_server();
        let body = r#"{"prompt":"hello","max_tokens":4}"#;
        let req = format!(
            "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let resp = http(addr, &req);
        assert!(resp.contains("200 OK"), "{resp}");
        let json_body = resp.split("\r\n\r\n").nth(1).unwrap();
        let v = json::parse(json_body).unwrap();
        assert_eq!(v.get("tokens").unwrap().as_arr().unwrap().len(), 4);
        assert!(v.get_f64("latency_s").unwrap() >= 0.0);
    }

    #[test]
    fn bad_request_is_400() {
        let (addr, _h) = start_server();
        let body = r#"{"max_tokens":4}"#; // missing prompt
        let req = format!(
            "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let resp = http(addr, &req);
        assert!(resp.contains("400"), "{resp}");
    }

    #[test]
    fn unknown_path_is_404() {
        let (addr, _h) = start_server();
        let resp = http(addr, "GET /nope HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(resp.contains("404"), "{resp}");
    }
}
