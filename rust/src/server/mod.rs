//! Threaded TCP/HTTP front-end.
//!
//! A minimal HTTP/1.1 server (no async runtime is available offline)
//! speaking a JSON API over the [`Router`]:
//!
//! * `POST /generate` — `{"prompt": "...", "max_tokens": N,
//!   "temperature": T?, "top_k": K?, "timeout_ms": D?, "trace":
//!   bool?}` → `{"id", "text", "tokens", "latency_s", "ttft_s"}`,
//!   plus a `"trace"` span array when requested.
//! * `GET /health` — `{"status", "workers", "healthy_workers",
//!   "inflight", "worker_restarts", "detail": [...]}`; `503` when no
//!   worker is healthy.
//! * `GET /metrics` — Prometheus text exposition (0.0.4) of every
//!   worker's telemetry registry: all mirrored `EngineMetrics`
//!   counters, the per-phase step-time histograms and router-side
//!   health gauges, labeled `worker="i"`.
//! * `GET /debug/trace/{id}` — span records for one request from the
//!   bounded trace ring (404 once overwritten or unknown).
//! * `GET /debug/flight` — every worker's flight-recorder ring: the
//!   last N step records the supervisor would dump on a crash.
//!
//! Every response and error body that concerns a specific request
//! carries its router-assigned `"id"`, and the same id appears on the
//! worker-side log lines — one id space from client to engine.
//!
//! Overload and failure map to honest statuses (ARCHITECTURE.md
//! "Overload & failure contract") instead of a catch-all 400:
//!
//! | condition                         | status | extras              |
//! |-----------------------------------|--------|---------------------|
//! | malformed JSON / missing field    | 400    |                     |
//! | [`SubmitError::PromptTooLong`]    | 400    | reason in `error`    |
//! | body over [`MAX_BODY_BYTES`]      | 413    |                     |
//! | [`SubmitError::QueueFull`]        | 429    | `Retry-After` header + `retry_after_ms` |
//! | [`SubmitError::DeadlineExceeded`] | 503    |                     |
//! | [`SubmitError::WorkerFailed`]     | 503    |                     |
//!
//! Each connection is handled on its own thread with socket read/write
//! timeouts ([`SOCKET_TIMEOUT_S`]) so a stalled client can neither hold
//! a handler thread forever nor stall decoding (generation itself runs
//! on the router's engine workers).
//!
//! **Graceful drain**: setting the flag from [`Server::shutdown_flag`]
//! stops the accept loop, lets in-flight connections finish under
//! [`DRAIN_DEADLINE_MS`], then returns from `serve`. Dropping the
//! router afterwards delivers `Shutdown` to every engine worker, which
//! flushes the spill tier's commit frontier before exiting — so an
//! orderly shutdown never loses an acknowledged spill record.

use crate::coordinator::{Router, SubmitError};
use crate::model::SamplingParams;
use crate::obs::{render_prometheus, ExtraMetric, MetricDef, MetricKind};
use crate::tokenizer::ByteTokenizer;
use crate::util::json::{self, Value};
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Request bodies above this are rejected with `413 Payload Too Large`
/// (never silently truncated — a truncated prompt would generate from a
/// different prefix than the client sent).
pub const MAX_BODY_BYTES: usize = 16 << 20;

/// Per-connection socket read/write timeout, seconds.
pub const SOCKET_TIMEOUT_S: u64 = 10;

/// Default in-flight drain budget at shutdown, ms. Connections still
/// open past this are detached (their socket timeouts bound them), so
/// drain can never wedge shutdown behind a stalled client.
pub const DRAIN_DEADLINE_MS: u64 = 5_000;

/// How often the accept loop polls the shutdown flag while idle.
const ACCEPT_POLL_MS: u64 = 5;

/// HTTP server over a router.
pub struct Server {
    router: Arc<Router>,
    listener: TcpListener,
    shutdown: Arc<AtomicBool>,
    drain_deadline: Duration,
}

impl Server {
    /// Bind to `addr` (e.g. "127.0.0.1:8765"; port 0 picks a free port).
    pub fn bind(router: Arc<Router>, addr: &str) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        Ok(Server {
            router,
            listener,
            shutdown: Arc::new(AtomicBool::new(false)),
            drain_deadline: Duration::from_millis(DRAIN_DEADLINE_MS),
        })
    }

    /// Override the drain budget (tests; operational tuning).
    pub fn with_drain_deadline(mut self, deadline: Duration) -> Server {
        self.drain_deadline = deadline;
        self
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.listener.local_addr().expect("listener has an address")
    }

    /// Cloneable shutdown flag: store `true` (any thread, a signal
    /// handler, …) and `serve` stops accepting, drains in-flight
    /// connections under the drain deadline, and returns.
    pub fn shutdown_flag(&self) -> Arc<AtomicBool> {
        self.shutdown.clone()
    }

    /// Accept loop; one thread per connection. Runs until the shutdown
    /// flag is set (callers run it on a dedicated thread; tests connect
    /// then drop), then drains and returns.
    pub fn serve(&self) -> Result<()> {
        // Nonblocking accept so the loop can observe the shutdown flag;
        // handler sockets are switched back to blocking (+timeouts).
        self.listener.set_nonblocking(true).context("listener set_nonblocking")?;
        let mut handlers: Vec<std::thread::JoinHandle<()>> = Vec::new();
        while !self.shutdown.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    // Accepted sockets may inherit the listener's
                    // nonblocking mode on some platforms — undo it.
                    if let Err(e) = stream.set_nonblocking(false) {
                        log::warn!("set_nonblocking(false) failed: {e}");
                        continue;
                    }
                    let router = self.router.clone();
                    handlers.push(std::thread::spawn(move || {
                        if let Err(e) = handle_connection(stream, &router) {
                            log::debug!("connection error: {e}");
                        }
                    }));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    handlers.retain(|h| !h.is_finished());
                    std::thread::sleep(Duration::from_millis(ACCEPT_POLL_MS));
                }
                Err(e) => log::warn!("accept error: {e}"),
            }
        }
        // Drain: nothing new is accepted; in-flight connections get the
        // deadline to finish, stragglers are detached (bounded by their
        // socket timeouts). The spill-tier flush rides the router's
        // worker shutdown, after the caller drops it.
        let deadline = Instant::now() + self.drain_deadline;
        while handlers.iter().any(|h| !h.is_finished()) && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(ACCEPT_POLL_MS));
        }
        let (done, stragglers): (Vec<_>, Vec<_>) =
            handlers.into_iter().partition(|h| h.is_finished());
        for h in done {
            let _ = h.join();
        }
        if !stragglers.is_empty() {
            log::warn!(
                "drain deadline hit with {} connection(s) in flight; detaching",
                stragglers.len()
            );
        }
        Ok(())
    }
}

/// One parsed HTTP request, or the typed refusal to read it.
enum HttpRead {
    Request { method: String, path: String, body: String },
    /// Declared Content-Length over [`MAX_BODY_BYTES`]; the body was
    /// not read.
    TooLarge { content_length: usize },
}

/// Parse one HTTP request. Oversized bodies are refused before any
/// body byte is read — truncating to a cap and serving the prefix (the
/// old behavior) silently answers a different request than was sent.
fn read_request(stream: &mut TcpStream) -> Result<HttpRead> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut request_line = String::new();
    reader.read_line(&mut request_line)?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    let mut content_length = 0usize;
    loop {
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some((k, v)) = line.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap_or(0);
            }
        }
    }
    if content_length > MAX_BODY_BYTES {
        return Ok(HttpRead::TooLarge { content_length });
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok(HttpRead::Request { method, path, body: String::from_utf8_lossy(&body).into_owned() })
}

fn respond(stream: &mut TcpStream, status: &str, body: &str) -> Result<()> {
    respond_with(stream, status, &[], body)
}

fn respond_with(
    stream: &mut TcpStream,
    status: &str,
    extra_headers: &[(&str, String)],
    body: &str,
) -> Result<()> {
    respond_typed(stream, status, "application/json", extra_headers, body)
}

/// The Prometheus exposition format has its own content type; every
/// JSON route goes through [`respond_with`] instead.
fn respond_typed(
    stream: &mut TcpStream,
    status: &str,
    content_type: &str,
    extra_headers: &[(&str, String)],
    body: &str,
) -> Result<()> {
    let mut resp = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n",
        body.len()
    );
    for (k, v) in extra_headers {
        resp.push_str(&format!("{k}: {v}\r\n"));
    }
    resp.push_str("\r\n");
    resp.push_str(body);
    stream.write_all(resp.as_bytes())?;
    Ok(())
}

/// Generate-path failure, carrying enough to pick an honest status.
enum ApiError {
    /// Malformed request (bad JSON, missing field) — rejected before a
    /// request id was minted.
    Bad(String),
    /// Typed rejection from the serving stack, tagged with the id the
    /// router assigned before admission — shed requests are debuggable
    /// by id too.
    Submit { id: u64, err: SubmitError },
}

impl ApiError {
    /// `(status line, extra headers, JSON body)`.
    fn render(&self) -> (&'static str, Vec<(&'static str, String)>, Value) {
        match self {
            ApiError::Bad(msg) => (
                "400 Bad Request",
                vec![],
                json::obj(vec![("error", msg.as_str().into()), ("kind", "bad_request".into())]),
            ),
            ApiError::Submit { id, err: e } => {
                let mut body = vec![
                    ("error", format!("{e}").into()),
                    ("kind", e.kind().into()),
                    ("id", (*id).into()),
                ];
                match e {
                    SubmitError::PromptTooLong { .. } => ("400 Bad Request", vec![], json::obj(body)),
                    SubmitError::QueueFull { retry_after_ms } => {
                        body.push(("retry_after_ms", (*retry_after_ms).into()));
                        // Retry-After is whole seconds; round up so a
                        // compliant client never retries early.
                        let secs = retry_after_ms.div_ceil(1000).max(1);
                        (
                            "429 Too Many Requests",
                            vec![("Retry-After", secs.to_string())],
                            json::obj(body),
                        )
                    }
                    SubmitError::DeadlineExceeded => {
                        ("503 Service Unavailable", vec![], json::obj(body))
                    }
                    SubmitError::WorkerFailed => {
                        ("503 Service Unavailable", vec![], json::obj(body))
                    }
                }
            }
        }
    }
}

fn handle_connection(mut stream: TcpStream, router: &Router) -> Result<()> {
    // A stalled or malicious client may neither wedge this handler on
    // read nor on write.
    let timeout = Some(Duration::from_secs(SOCKET_TIMEOUT_S));
    stream.set_read_timeout(timeout)?;
    stream.set_write_timeout(timeout)?;
    let (method, path, body) = match read_request(&mut stream)? {
        HttpRead::Request { method, path, body } => (method, path, body),
        HttpRead::TooLarge { content_length } => {
            let v = json::obj(vec![
                (
                    "error",
                    format!("request body {content_length} bytes exceeds limit {MAX_BODY_BYTES}")
                        .into(),
                ),
                ("kind", "payload_too_large".into()),
            ]);
            return respond(&mut stream, "413 Payload Too Large", &v.to_string_compact());
        }
    };
    match (method.as_str(), path.as_str()) {
        ("GET", "/health") => {
            let detail: Vec<Value> = router
                .worker_health()
                .iter()
                .map(|w| {
                    json::obj(vec![
                        ("healthy", w.healthy.into()),
                        ("restarts", w.restarts.into()),
                        ("inflight", w.inflight.into()),
                        ("queued", w.queued.into()),
                        ("concurrency_limit", w.concurrency_limit.into()),
                    ])
                })
                .collect();
            let healthy = router.num_healthy();
            let v = json::obj(vec![
                ("status", if healthy > 0 { "ok" } else { "unhealthy" }.into()),
                ("workers", router.num_workers().into()),
                ("healthy_workers", healthy.into()),
                ("inflight", router.inflight().into()),
                ("worker_restarts", router.worker_restarts().into()),
                ("detail", Value::Arr(detail)),
            ]);
            let status = if healthy > 0 { "200 OK" } else { "503 Service Unavailable" };
            respond(&mut stream, status, &v.to_string_compact())
        }
        ("POST", "/generate") => match handle_generate(router, &body) {
            Ok(v) => respond(&mut stream, "200 OK", &v.to_string_compact()),
            Err(e) => {
                let (status, headers, v) = e.render();
                respond_with(&mut stream, status, &headers, &v.to_string_compact())
            }
        },
        ("GET", "/metrics") => {
            let text = render_metrics(router);
            respond_typed(&mut stream, "200 OK", "text/plain; version=0.0.4", &[], &text)
        }
        ("GET", "/debug/flight") => {
            let v = render_flight(router);
            respond(&mut stream, "200 OK", &v.to_string_compact())
        }
        ("GET", p) if p.strip_prefix("/debug/trace/").is_some() => {
            let id_str = p.strip_prefix("/debug/trace/").unwrap();
            match id_str.parse::<u64>() {
                Err(_) => {
                    let v = json::obj(vec![
                        ("error", format!("invalid request id '{id_str}'").into()),
                        ("kind", "bad_request".into()),
                    ]);
                    respond(&mut stream, "400 Bad Request", &v.to_string_compact())
                }
                Ok(id) => {
                    let events = router.trace_events(id);
                    if events.is_empty() {
                        let v = json::obj(vec![
                            (
                                "error",
                                "no trace events for this id (unknown, or evicted from the bounded ring)".into(),
                            ),
                            ("kind", "not_found".into()),
                            ("id", id.into()),
                        ]);
                        respond(&mut stream, "404 Not Found", &v.to_string_compact())
                    } else {
                        let v = json::obj(vec![
                            ("id", id.into()),
                            ("events", trace_events_json(&events)),
                        ]);
                        respond(&mut stream, "200 OK", &v.to_string_compact())
                    }
                }
            }
        }
        _ => {
            let v = json::obj(vec![("error", "not found".into())]);
            respond(&mut stream, "404 Not Found", &v.to_string_compact())
        }
    }
}

/// The `/metrics` exposition: every worker's registry plus the
/// router-side health gauges the engine cannot see.
fn render_metrics(router: &Router) -> String {
    let telems = router.telemetries();
    let workers: Vec<(usize, &crate::obs::Telemetry)> =
        telems.iter().enumerate().map(|(i, t)| (i, t.as_ref())).collect();
    let health = router.worker_health();
    let extras = [
        ExtraMetric {
            def: MetricDef {
                name: "worker_healthy",
                help: "1 while the worker accepts requests, 0 once permanently dead.",
                kind: MetricKind::Gauge,
            },
            values: health.iter().enumerate().map(|(i, h)| (i, h.healthy as u64)).collect(),
        },
        ExtraMetric {
            def: MetricDef {
                name: "flight_dumps",
                help: "Crash dumps emitted from the worker's flight recorder.",
                kind: MetricKind::Counter,
            },
            values: telems.iter().enumerate().map(|(i, t)| (i, t.flight.dumps())).collect(),
        },
    ];
    render_prometheus(&workers, &extras)
}

/// The `/debug/flight` body: each worker's ring of recent step records,
/// oldest first (bounded by the configured ring capacity).
fn render_flight(router: &Router) -> Value {
    let workers: Vec<Value> = router
        .telemetries()
        .iter()
        .enumerate()
        .map(|(i, t)| {
            let records: Vec<Value> = t
                .flight
                .snapshot()
                .iter()
                .map(|r| {
                    json::obj(vec![
                        ("step", r.step.into()),
                        ("t_us", r.t_us.into()),
                        ("prefill_chunks", (r.prefill_chunks as u64).into()),
                        ("prefill_tokens", (r.prefill_tokens as u64).into()),
                        ("decode_batch", (r.decode_batch as u64).into()),
                        ("budget_tokens", (r.budget_tokens as u64).into()),
                        ("waiting", (r.waiting as u64).into()),
                        ("running", (r.running as u64).into()),
                        ("queue_depth", (r.queue_depth as u64).into()),
                        ("aimd_limit", (r.aimd_limit as u64).into()),
                        ("used_blocks", (r.used_blocks as u64).into()),
                        ("free_blocks", (r.free_blocks as u64).into()),
                    ])
                })
                .collect();
            json::obj(vec![
                ("worker", i.into()),
                ("capacity", t.flight.capacity().into()),
                ("total_recorded", t.flight.total().into()),
                ("dumps", t.flight.dumps().into()),
                ("records", Value::Arr(records)),
            ])
        })
        .collect();
    json::obj(vec![("workers", Value::Arr(workers))])
}

/// Trace events as a JSON array (shared by `/debug/trace/{id}` and the
/// generate response's opt-in `"trace"` summary).
fn trace_events_json(events: &[crate::obs::TraceEvent]) -> Value {
    Value::Arr(
        events
            .iter()
            .map(|e| {
                json::obj(vec![
                    ("t_us", e.t_us.into()),
                    ("event", e.kind.as_str().into()),
                    ("detail", e.detail.into()),
                ])
            })
            .collect(),
    )
}

fn handle_generate(router: &Router, body: &str) -> Result<Value, ApiError> {
    let req = json::parse(body).map_err(|e| ApiError::Bad(format!("invalid JSON body: {e}")))?;
    let prompt_text =
        req.get_str("prompt").ok_or_else(|| ApiError::Bad("missing 'prompt'".into()))?;
    let tok = ByteTokenizer::new();
    let prompt = tok.encode(prompt_text);
    let params = SamplingParams {
        max_tokens: req.get_usize("max_tokens").unwrap_or(32),
        temperature: req.get_f64("temperature").unwrap_or(0.0) as f32,
        top_k: req.get_usize("top_k").unwrap_or(0),
        ignore_eos: req.get("ignore_eos").and_then(|b| b.as_bool()).unwrap_or(false),
    };
    // Client scheduling deadline; the admission config's default applies
    // when absent.
    let timeout = req.get_usize("timeout_ms").map(|ms| Duration::from_millis(ms as u64));
    let want_trace = req.get("trace").and_then(|b| b.as_bool()).unwrap_or(false);
    let (id, submitted) = router.submit_traced(prompt, params, timeout);
    let rx = match submitted {
        Ok(rx) => rx,
        Err(e) => {
            log::debug!("request {id}: rejected at submit ({})", e.kind());
            return Err(ApiError::Submit { id, err: e });
        }
    };
    let out = match rx.recv() {
        Ok(Ok(out)) => out,
        Ok(Err(e)) => {
            log::debug!("request {id}: failed ({})", e.kind());
            return Err(ApiError::Submit { id, err: e });
        }
        // Reply channel dropped without an answer: the worker died in a
        // way supervision could not translate.
        Err(_) => {
            log::debug!("request {id}: reply channel dropped");
            return Err(ApiError::Submit { id, err: SubmitError::WorkerFailed });
        }
    };
    let mut fields = vec![
        ("id", out.id.into()),
        ("text", tok.decode(&out.tokens).into()),
        ("tokens", out.tokens.iter().map(|&t| t as usize).collect::<Vec<usize>>().into()),
        ("prompt_len", out.prompt_len.into()),
        ("latency_s", out.latency_s.into()),
        ("ttft_s", out.ttft_s.into()),
    ];
    if want_trace {
        // Best-effort: events may already be evicted from the bounded
        // ring under heavy traffic — an empty array, never an error.
        fields.push(("trace", trace_events_json(&router.trace_events(id))));
    }
    Ok(json::obj(fields))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{
        AdmissionConfig, BucketPolicy, EngineConfig, RouterConfig, SchedulerConfig,
    };
    use crate::model::{ModelConfig, ModelWeights, NativeModel};
    use crate::runtime::{FaultPlan, FaultyBackend, NativeBackend};

    fn engine_cfg() -> EngineConfig {
        EngineConfig {
            num_blocks: 32,
            block_size: 8,
            sched: SchedulerConfig::default(),
            decode_buckets: BucketPolicy::exact(8),
            prefill_chunk: usize::MAX,
            prefix_cache_blocks: 0,
            kv_dtype: crate::kvcache::KvCacheDtype::F32,
            weight_dtype: crate::model::WeightDtype::F32,
            spill: None,
        }
    }

    fn tiny_backend() -> Box<dyn crate::runtime::Backend> {
        let mc = ModelConfig::tiny();
        Box::new(NativeBackend::new(NativeModel::new(ModelWeights::init(&mc, 3))))
    }

    fn start_server_with(
        admission: AdmissionConfig,
    ) -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
        let router = Arc::new(Router::new(
            RouterConfig { engine: engine_cfg(), workers: 1, admission },
            |_| tiny_backend(),
        ));
        spawn_server(router)
    }

    fn spawn_server(
        router: Arc<Router>,
    ) -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
        let server = Server::bind(router, "127.0.0.1:0").unwrap();
        let addr = server.local_addr();
        let h = std::thread::spawn(move || {
            let _ = server.serve();
        });
        (addr, h)
    }

    fn start_server() -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
        start_server_with(AdmissionConfig::default())
    }

    fn http(addr: std::net::SocketAddr, req: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(req.as_bytes()).unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        buf
    }

    fn post_generate(addr: std::net::SocketAddr, body: &str) -> String {
        let req = format!(
            "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        http(addr, &req)
    }

    #[test]
    fn health_endpoint() {
        let (addr, _h) = start_server();
        let resp = http(addr, "GET /health HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(resp.contains("200 OK"), "{resp}");
        assert!(resp.contains("\"status\":\"ok\""), "{resp}");
        assert!(resp.contains("\"healthy_workers\":1"), "{resp}");
        assert!(resp.contains("\"detail\":[{\"healthy\":true"), "{resp}");
    }

    #[test]
    fn generate_endpoint_roundtrip() {
        let (addr, _h) = start_server();
        let resp = post_generate(addr, r#"{"prompt":"hello","max_tokens":4}"#);
        assert!(resp.contains("200 OK"), "{resp}");
        let json_body = resp.split("\r\n\r\n").nth(1).unwrap();
        let v = json::parse(json_body).unwrap();
        assert_eq!(v.get("tokens").unwrap().as_arr().unwrap().len(), 4);
        assert!(v.get_f64("latency_s").unwrap() >= 0.0);
    }

    #[test]
    fn bad_request_is_400() {
        let (addr, _h) = start_server();
        let resp = post_generate(addr, r#"{"max_tokens":4}"#); // missing prompt
        assert!(resp.contains("400"), "{resp}");
        assert!(resp.contains("\"kind\":\"bad_request\""), "{resp}");
    }

    #[test]
    fn unknown_path_is_404() {
        let (addr, _h) = start_server();
        let resp = http(addr, "GET /nope HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(resp.contains("404"), "{resp}");
    }

    #[test]
    fn oversized_body_is_413_not_truncated() {
        // Only the header is sent: the server must refuse from the
        // declared length alone, never read-then-truncate.
        let (addr, _h) = start_server();
        let req = format!(
            "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n",
            MAX_BODY_BYTES + 1
        );
        let resp = http(addr, &req);
        assert!(resp.contains("413"), "{resp}");
        assert!(resp.contains("\"kind\":\"payload_too_large\""), "{resp}");
    }

    #[test]
    fn prompt_too_long_is_400_with_reason() {
        let (addr, _h) = start_server();
        // 32 blocks × 8 slots = 256-token pool; this can never fit.
        let resp = post_generate(addr, r#"{"prompt":"hi","max_tokens":100000}"#);
        assert!(resp.contains("400"), "{resp}");
        assert!(resp.contains("\"kind\":\"prompt_too_long\""), "{resp}");
        assert!(resp.contains("KV tokens"), "{resp}");
    }

    #[test]
    fn queue_full_is_429_with_retry_after() {
        let (addr, _h) =
            start_server_with(AdmissionConfig { queue_depth: 0, ..Default::default() });
        let resp = post_generate(addr, r#"{"prompt":"hello","max_tokens":4}"#);
        assert!(resp.contains("429"), "{resp}");
        assert!(resp.contains("Retry-After:"), "{resp}");
        assert!(resp.contains("\"kind\":\"queue_full\""), "{resp}");
        assert!(resp.contains("retry_after_ms"), "{resp}");
    }

    #[test]
    fn expired_deadline_is_503() {
        let (addr, _h) = start_server();
        let resp = post_generate(addr, r#"{"prompt":"hello","max_tokens":4,"timeout_ms":0}"#);
        assert!(resp.contains("503"), "{resp}");
        assert!(resp.contains("\"kind\":\"deadline_exceeded\""), "{resp}");
    }

    #[test]
    fn metrics_exposition_covers_counters_and_default_run_keeps_opt_ins_zero() {
        let (addr, _h) = start_server();
        // Drive one request through so the mirrored counters move; its
        // reply is sent after the engine's end-of-step mirror, so the
        // scrape below observes it deterministically.
        let resp = post_generate(addr, r#"{"prompt":"hello","max_tokens":4}"#);
        assert!(resp.contains("200 OK"), "{resp}");
        let m = http(addr, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(m.contains("200 OK"), "{m}");
        assert!(m.contains("text/plain; version=0.0.4"), "{m}");
        assert!(m.contains("opt_gptq_requests_completed{worker=\"0\"} 1"), "{m}");
        assert!(m.contains("# TYPE opt_gptq_requests_completed counter"), "{m}");
        assert!(m.contains("# TYPE opt_gptq_step_time_decode_us histogram"), "{m}");
        assert!(m.contains("opt_gptq_step_time_decode_us_bucket{worker=\"0\",le=\"+Inf\"}"), "{m}");
        assert!(m.contains("opt_gptq_worker_healthy{worker=\"0\"} 1"), "{m}");
        // The default config is dense, spill-less and fault-free: every
        // opt-in mechanism's counter must read exactly 0.
        for series in [
            "opt_gptq_skipped_tiles",
            "opt_gptq_evicted_blocks",
            "opt_gptq_spill_hit_tokens",
            "opt_gptq_spill_bytes",
            "opt_gptq_spill_corrupt_records",
            "opt_gptq_spill_io_failures",
            "opt_gptq_gather_bytes",
            "opt_gptq_worker_restarts",
            "opt_gptq_shed_count",
            "opt_gptq_preemptions",
        ] {
            assert!(
                m.contains(&format!("{series}{{worker=\"0\"}} 0\n")),
                "{series} must be 0 under the default config:\n{m}"
            );
        }
    }

    #[test]
    fn trace_flag_and_debug_endpoints_roundtrip() {
        let (addr, _h) = start_server();
        let resp = post_generate(addr, r#"{"prompt":"hello","max_tokens":4,"trace":true}"#);
        assert!(resp.contains("200 OK"), "{resp}");
        let json_body = resp.split("\r\n\r\n").nth(1).unwrap();
        let v = json::parse(json_body).unwrap();
        let id = v.get_usize("id").unwrap();
        let trace = v.get("trace").unwrap().as_arr().unwrap();
        assert!(!trace.is_empty(), "trace requested but empty");
        let kinds: Vec<&str> =
            trace.iter().map(|e| e.get_str("event").unwrap()).collect();
        assert_eq!(kinds.first().copied(), Some("enqueue"));
        assert_eq!(kinds.last().copied(), Some("finish"));
        assert!(kinds.contains(&"first_token"), "{kinds:?}");
        // The same lifecycle is served at the debug endpoint.
        let t = http(addr, &format!("GET /debug/trace/{id} HTTP/1.1\r\nHost: x\r\n\r\n"));
        assert!(t.contains("200 OK"), "{t}");
        assert!(t.contains("\"event\":\"enqueue\""), "{t}");
        assert!(t.contains("\"event\":\"finish\""), "{t}");
        // Unknown ids 404; non-numeric ids 400.
        let missing = http(addr, "GET /debug/trace/999 HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(missing.contains("404"), "{missing}");
        let bad = http(addr, "GET /debug/trace/xyz HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(bad.contains("400"), "{bad}");
        // And the flight recorder holds step records for the run.
        let f = http(addr, "GET /debug/flight HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(f.contains("200 OK"), "{f}");
        assert!(f.contains("\"records\":[{"), "{f}");
        assert!(f.contains("\"dumps\":0"), "{f}");
    }

    #[test]
    fn error_bodies_carry_the_request_id() {
        let (addr, _h) =
            start_server_with(AdmissionConfig { queue_depth: 0, ..Default::default() });
        let resp = post_generate(addr, r#"{"prompt":"hello","max_tokens":4}"#);
        assert!(resp.contains("429"), "{resp}");
        assert!(resp.contains("\"id\":1"), "shed errors must carry the minted id: {resp}");
    }

    #[test]
    fn graceful_shutdown_drains_in_flight_then_stops_accepting() {
        let router = Arc::new(Router::new(
            RouterConfig {
                engine: engine_cfg(),
                workers: 1,
                admission: AdmissionConfig::default(),
            },
            |_| tiny_backend(),
        ));
        let server = Server::bind(router, "127.0.0.1:0").unwrap();
        let addr = server.local_addr();
        let flag = server.shutdown_flag();
        let h = std::thread::spawn(move || server.serve().unwrap());
        // A request in flight when the flag flips must still complete.
        let client = std::thread::spawn(move || {
            post_generate(addr, r#"{"prompt":"hello","max_tokens":16}"#)
        });
        std::thread::sleep(Duration::from_millis(100));
        flag.store(true, Ordering::SeqCst);
        let resp = client.join().unwrap();
        assert!(resp.contains("200 OK"), "in-flight request must drain cleanly: {resp}");
        h.join().unwrap();
        // serve returned → the server (and its listener) are gone; new
        // connections are refused rather than silently queued.
        assert!(
            TcpStream::connect(addr).is_err(),
            "listener must be closed once drain completes"
        );
    }

    #[test]
    fn dead_worker_is_503_and_health_degrades() {
        // A worker with no restart budget that panics on its first step:
        // generate maps the crash to 503 and /health flips to 503.
        let router = Arc::new(Router::new(
            RouterConfig {
                engine: engine_cfg(),
                workers: 1,
                admission: AdmissionConfig { max_restarts: 0, ..Default::default() },
            },
            |_| {
                Box::new(FaultyBackend::new(
                    tiny_backend(),
                    FaultPlan::new(1).panic_at_step(0).injector(),
                ))
            },
        ));
        let (addr, _h) = spawn_server(router);
        let resp = post_generate(addr, r#"{"prompt":"hello","max_tokens":4}"#);
        assert!(resp.contains("503"), "{resp}");
        assert!(resp.contains("\"kind\":\"worker_failed\""), "{resp}");
        // healthy=false is stored before the failing reply is sent, so
        // this follow-up observation is deterministic.
        let health = http(addr, "GET /health HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(health.contains("503"), "{health}");
        assert!(health.contains("\"status\":\"unhealthy\""), "{health}");
    }
}
