//! Serving metrics — the paper's §IV measurement surface.
//!
//! Fig. 2/3 report three numbers per run:
//! * **latency** — wall time from first request to last completion;
//! * **all throughput** — requests/s and (prompt+generated) tokens/s over
//!   that window;
//! * **generate throughput** — generated tokens/s over the same window.

use crate::obs::{EngineStat, Telemetry};
use crate::util::{mean, percentile};

/// Per-request completion record.
#[derive(Debug, Clone)]
pub struct RequestRecord {
    pub id: u64,
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    pub t_enqueue: f64,
    pub t_first_token: f64,
    pub t_finish: f64,
}

impl RequestRecord {
    pub fn latency(&self) -> f64 {
        self.t_finish - self.t_enqueue
    }
    pub fn ttft(&self) -> f64 {
        self.t_first_token - self.t_enqueue
    }
}

/// Live engine counters.
///
/// Under mixed-step scheduling one engine step can carry both prefill
/// chunks and decode tokens, so the step counters are disentangled:
/// `mixed_steps` counts engine iterations that did any work,
/// `prefill_steps` counts prefill *chunks* executed (a prompt spanning
/// three steps contributes three), and `decode_steps` counts steps in
/// which at least one decode token advanced. TTFT stays per-request
/// honest (first sampled token, not first chunk); inter-token gaps are
/// wall-clock between consecutive emitted tokens of a sequence,
/// *including* recompute-preemption stalls.
#[derive(Debug, Default, Clone)]
pub struct EngineMetrics {
    pub records: Vec<RequestRecord>,
    /// Engine steps that executed any work (prefill and/or decode).
    pub mixed_steps: usize,
    /// Prefill chunks executed (≥ number of prompts under chunking).
    pub prefill_steps: usize,
    /// Prompt/replay tokens pushed through prefill chunks.
    pub prefill_chunk_tokens: usize,
    /// Steps in which at least one decode token advanced.
    pub decode_steps: usize,
    /// Sum over decode steps of sequences in the batch.
    pub decode_batch_tokens: usize,
    /// Sum over decode steps of the *bucket* size used (padding waste =
    /// bucket − batch).
    pub decode_bucket_tokens: usize,
    /// Steps where decoding sequences existed but none advanced — under
    /// the mixed planner this only happens in a preemption storm, so it
    /// should sit at ~0 (the head-of-line metric). Under the exclusive
    /// planner every whole-prompt prefill with live decoders counts.
    pub decode_stall_steps: usize,
    /// Retained inter-token gap samples (percentile reporting), bounded
    /// to [`ITL_WINDOW`] entries — overwritten ring-style so a
    /// long-lived server engine never grows without limit. Record via
    /// [`EngineMetrics::record_gap`]; the mean stays exact over ALL
    /// gaps through the running sum/count.
    pub inter_token_gaps: Vec<f64>,
    itl_cursor: usize,
    inter_token_sum: f64,
    inter_token_count: u64,
    pub preemptions: usize,
    /// Peak KV blocks in use.
    pub peak_blocks: usize,
    /// Prompt tokens skipped via prefix-cache block adoption (§III.C).
    pub prefix_hit_tokens: usize,
    /// Quantized KV tiles dequantized by streamed prefill attention
    /// (from `StepOutputs::prefill_dequant_tiles`; 0 on an f32 cache).
    /// The paged-native prefill's work meter: tiles are dequantized in
    /// place instead of materializing the context densely.
    pub prefill_dequant_tiles: usize,
    /// Dense f32 bytes the KV pool materialized via `KvStore::gather`
    /// (mirrored from the cache each step). ≈ 0 in a healthy engine —
    /// `gather` is a test/debug dump since the paged-native prefill
    /// refactor; growth here means a dense KV copy crept back onto the
    /// hot path.
    pub gather_bytes: usize,
    /// Attention tiles elided by the score-bound skip (from
    /// `StepOutputs::skipped_tiles`). MUST stay 0 under the dense
    /// default config — skipping only arms when `--skip-threshold` is
    /// set (window-invisible tiles are outside the schedule and are
    /// not counted here).
    pub skipped_tiles: usize,
    /// KV blocks reclaimed by the sliding-window eviction sweep
    /// (mirrored from `Scheduler::evicted_blocks` each step). MUST stay
    /// 0 under the dense default config; under a window it is the
    /// admission headroom the AIMD controller sees come back.
    pub evicted_blocks: usize,
    /// Requests shed by the admission layer before any work was
    /// scheduled (queue-full rejections + deadline sheds). Mirrored in
    /// by the router worker loop; stays 0 when the engine is driven
    /// directly.
    pub shed_count: usize,
    /// Subset of `shed_count` shed because the request's deadline
    /// passed before it could be scheduled.
    pub deadline_miss_count: usize,
    /// Current AIMD concurrency limit (gauge; mirrored in by the router
    /// worker loop, 0 when the engine is driven directly).
    pub concurrency_limit: usize,
    /// Cumulative engine-worker crash/respawn count under supervision
    /// (mirrored in by the router worker loop).
    pub worker_restarts: usize,
    /// Prompt tokens covered by disk-spill restores at admission
    /// (subset of `prefix_hit_tokens`). MUST stay 0 without `--spill-dir`
    /// — the default baseline never touches the tier.
    pub spill_hit_tokens: usize,
    /// Record bytes appended to the spill store (mirrored from
    /// `SpillTier::stats().bytes_written` each step; 0 when off).
    pub spill_bytes: usize,
    /// Spill records quarantined by a read-time checksum failure —
    /// each one served via recompute instead (mirrored from the tier).
    pub spill_corrupt_records: usize,
}

/// Max inter-token gap samples retained for percentiles (~512 KiB).
pub const ITL_WINDOW: usize = 65_536;

impl EngineMetrics {
    pub fn record_finish(&mut self, rec: RequestRecord) {
        self.records.push(rec);
    }

    /// Record one inter-token gap: exact running mean over every gap,
    /// bounded ring of samples for the percentile fields.
    pub fn record_gap(&mut self, gap: f64) {
        self.inter_token_sum += gap;
        self.inter_token_count += 1;
        if self.inter_token_gaps.len() < ITL_WINDOW {
            self.inter_token_gaps.push(gap);
        } else {
            self.inter_token_gaps[self.itl_cursor] = gap;
            self.itl_cursor = (self.itl_cursor + 1) % ITL_WINDOW;
        }
    }

    /// Cumulative inter-token totals `(count, sum_seconds)` over ALL
    /// recorded gaps (exact, not the bounded percentile window). The
    /// AIMD controller diffs consecutive snapshots to get per-window
    /// means without copying the ring.
    pub fn inter_token_totals(&self) -> (u64, f64) {
        (self.inter_token_count, self.inter_token_sum)
    }

    /// Mirror every counter into a worker's telemetry registry
    /// ([`Telemetry`]) — one batch of `Relaxed` stores, called by the
    /// engine at the end of each step so the `/metrics` scrape thread
    /// reads fresh atomics without ever touching the engine. The
    /// engine keeps accumulating into these plain fields exactly as
    /// before; the registry is a read-side mirror, not a replacement.
    pub fn mirror_into(&self, t: &Telemetry) {
        use EngineStat as S;
        t.set(S::RequestsCompleted, self.records.len() as u64);
        t.set(S::MixedSteps, self.mixed_steps as u64);
        t.set(S::PrefillChunks, self.prefill_steps as u64);
        t.set(S::PrefillChunkTokens, self.prefill_chunk_tokens as u64);
        t.set(S::DecodeSteps, self.decode_steps as u64);
        t.set(S::DecodeBatchTokens, self.decode_batch_tokens as u64);
        t.set(S::DecodeBucketTokens, self.decode_bucket_tokens as u64);
        t.set(S::DecodeStallSteps, self.decode_stall_steps as u64);
        let (gaps, sum_s) = self.inter_token_totals();
        t.set(S::InterTokenCount, gaps);
        t.set(S::InterTokenSumUs, (sum_s * 1e6) as u64);
        t.set(S::Preemptions, self.preemptions as u64);
        t.set(S::PeakBlocks, self.peak_blocks as u64);
        t.set(S::PrefixHitTokens, self.prefix_hit_tokens as u64);
        t.set(S::PrefillDequantTiles, self.prefill_dequant_tiles as u64);
        t.set(S::GatherBytes, self.gather_bytes as u64);
        t.set(S::SkippedTiles, self.skipped_tiles as u64);
        t.set(S::EvictedBlocks, self.evicted_blocks as u64);
        t.set(S::ShedCount, self.shed_count as u64);
        t.set(S::DeadlineMissCount, self.deadline_miss_count as u64);
        t.set(S::ConcurrencyLimit, self.concurrency_limit as u64);
        t.set(S::WorkerRestarts, self.worker_restarts as u64);
        t.set(S::SpillHitTokens, self.spill_hit_tokens as u64);
        t.set(S::SpillBytes, self.spill_bytes as u64);
        t.set(S::SpillCorruptRecords, self.spill_corrupt_records as u64);
    }

    /// Mean decode batch occupancy (sequences per step).
    pub fn mean_decode_batch(&self) -> f64 {
        if self.decode_steps == 0 {
            return 0.0;
        }
        self.decode_batch_tokens as f64 / self.decode_steps as f64
    }

    /// Fraction of decode-bucket slots wasted on padding.
    pub fn padding_waste(&self) -> f64 {
        if self.decode_bucket_tokens == 0 {
            return 0.0;
        }
        1.0 - self.decode_batch_tokens as f64 / self.decode_bucket_tokens as f64
    }

    /// Aggregate into the paper's report over the run window.
    pub fn report(&self) -> RunReport {
        let n = self.records.len();
        if n == 0 {
            // No completions — but overload counters must still surface
            // (a fully-shed run is exactly when they matter).
            return RunReport {
                shed_count: self.shed_count,
                deadline_miss_count: self.deadline_miss_count,
                concurrency_limit: self.concurrency_limit,
                worker_restarts: self.worker_restarts,
                spill_hit_tokens: self.spill_hit_tokens,
                spill_bytes: self.spill_bytes,
                spill_corrupt_records: self.spill_corrupt_records,
                ..RunReport::default()
            };
        }
        let t0 = self.records.iter().map(|r| r.t_enqueue).fold(f64::INFINITY, f64::min);
        let t1 = self.records.iter().map(|r| r.t_finish).fold(0.0f64, f64::max);
        let window = (t1 - t0).max(1e-9);
        let all_tokens: usize =
            self.records.iter().map(|r| r.prompt_tokens + r.generated_tokens).sum();
        let gen_tokens: usize = self.records.iter().map(|r| r.generated_tokens).sum();
        let latencies: Vec<f64> = self.records.iter().map(|r| r.latency()).collect();
        let ttfts: Vec<f64> = self.records.iter().map(|r| r.ttft()).collect();
        RunReport {
            num_requests: n,
            latency_s: window,
            req_per_s: n as f64 / window,
            all_tok_per_s: all_tokens as f64 / window,
            gen_tok_per_s: gen_tokens as f64 / window,
            mean_request_latency_s: mean(&latencies),
            p95_request_latency_s: percentile(&latencies, 95.0),
            mean_ttft_s: mean(&ttfts),
            ttft_p50_s: percentile(&ttfts, 50.0),
            ttft_p95_s: percentile(&ttfts, 95.0),
            mean_inter_token_s: if self.inter_token_count > 0 {
                self.inter_token_sum / self.inter_token_count as f64
            } else {
                0.0
            },
            p95_inter_token_s: percentile(&self.inter_token_gaps, 95.0),
            mean_decode_batch: self.mean_decode_batch(),
            padding_waste: self.padding_waste(),
            decode_stall_steps: self.decode_stall_steps,
            preemptions: self.preemptions,
            peak_blocks: self.peak_blocks,
            prefill_dequant_tiles: self.prefill_dequant_tiles,
            gather_bytes: self.gather_bytes,
            skipped_tiles: self.skipped_tiles,
            evicted_blocks: self.evicted_blocks,
            shed_count: self.shed_count,
            deadline_miss_count: self.deadline_miss_count,
            concurrency_limit: self.concurrency_limit,
            worker_restarts: self.worker_restarts,
            spill_hit_tokens: self.spill_hit_tokens,
            spill_bytes: self.spill_bytes,
            spill_corrupt_records: self.spill_corrupt_records,
        }
    }
}

/// The paper-format run summary.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct RunReport {
    pub num_requests: usize,
    /// End-to-end wall time ("Latency" in Fig. 2).
    pub latency_s: f64,
    /// "All Throughput" requests/s.
    pub req_per_s: f64,
    /// "All Throughput" tokens/s (prompt + generated).
    pub all_tok_per_s: f64,
    /// "Generate Throughput" tokens/s.
    pub gen_tok_per_s: f64,
    pub mean_request_latency_s: f64,
    pub p95_request_latency_s: f64,
    pub mean_ttft_s: f64,
    pub ttft_p50_s: f64,
    pub ttft_p95_s: f64,
    /// Mean wall-clock gap between consecutive tokens of a sequence
    /// (includes recompute-preemption stalls — honest ITL).
    pub mean_inter_token_s: f64,
    pub p95_inter_token_s: f64,
    pub mean_decode_batch: f64,
    pub padding_waste: f64,
    /// Steps where decoders existed but none advanced (head-of-line
    /// indicator; ~0 under the mixed planner).
    pub decode_stall_steps: usize,
    pub preemptions: usize,
    pub peak_blocks: usize,
    /// Quantized KV tiles dequantized in place by streamed prefill
    /// attention (0 on an f32 cache) — the paged-native prefill meter.
    pub prefill_dequant_tiles: usize,
    /// Dense f32 bytes materialized by `KvStore::gather` — ≈ 0 in a
    /// healthy engine (gather is test/debug only on the serving path).
    pub gather_bytes: usize,
    /// Attention tiles elided by the score-bound skip (0 when
    /// `--skip-threshold` is unset — the dense-default contract).
    pub skipped_tiles: usize,
    /// KV blocks reclaimed by sliding-window eviction (0 without
    /// `--window-blocks`).
    pub evicted_blocks: usize,
    /// Requests shed by the admission layer before scheduling
    /// (queue-full + deadline); 0 when the engine is driven directly.
    pub shed_count: usize,
    /// Subset of `shed_count` shed for deadline expiry.
    pub deadline_miss_count: usize,
    /// AIMD concurrency limit at report time (gauge; 0 without a
    /// router).
    pub concurrency_limit: usize,
    /// Cumulative supervised engine-worker restarts.
    pub worker_restarts: usize,
    /// Prompt tokens restored from the disk spill tier at admission
    /// (0 without `--spill-dir`).
    pub spill_hit_tokens: usize,
    /// Record bytes appended to the spill store (0 when off).
    pub spill_bytes: usize,
    /// Spill records quarantined by read-time checksum failures (each
    /// one degraded to recompute).
    pub spill_corrupt_records: usize,
}

impl RunReport {
    /// The paper's three headline numbers as a formatted block.
    pub fn paper_block(&self, label: &str) -> String {
        format!(
            "{label}\n  Latency: {:.2} seconds\n  All Throughput: {:.2} requests/s, {:.2} tokens/s\n  Generate Throughput: {:.2} tokens/s\n",
            self.latency_s, self.req_per_s, self.all_tok_per_s, self.gen_tok_per_s
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64, t0: f64, tf: f64, p: usize, g: usize) -> RequestRecord {
        RequestRecord {
            id,
            prompt_tokens: p,
            generated_tokens: g,
            t_enqueue: t0,
            t_first_token: t0 + 0.1,
            t_finish: tf,
        }
    }

    #[test]
    fn report_math() {
        let mut m = EngineMetrics::default();
        m.record_finish(rec(1, 0.0, 2.0, 10, 20));
        m.record_finish(rec(2, 0.0, 4.0, 30, 40));
        for g in [0.1, 0.2, 0.3] {
            m.record_gap(g);
        }
        let r = m.report();
        assert_eq!(r.num_requests, 2);
        assert!((r.latency_s - 4.0).abs() < 1e-9);
        assert!((r.req_per_s - 0.5).abs() < 1e-9);
        assert!((r.all_tok_per_s - 100.0 / 4.0).abs() < 1e-9);
        assert!((r.gen_tok_per_s - 60.0 / 4.0).abs() < 1e-9);
        assert!((r.mean_request_latency_s - 3.0).abs() < 1e-9);
        assert!((r.mean_ttft_s - 0.1).abs() < 1e-9);
        assert!((r.ttft_p50_s - 0.1).abs() < 1e-9);
        assert!((r.ttft_p95_s - 0.1).abs() < 1e-9);
        assert!((r.mean_inter_token_s - 0.2).abs() < 1e-9);
        assert!((r.p95_inter_token_s - 0.3).abs() < 1e-9);
    }

    #[test]
    fn empty_report_is_zeroes() {
        let m = EngineMetrics::default();
        assert_eq!(m.report(), RunReport::default());
    }

    #[test]
    fn itl_window_is_bounded_but_mean_stays_exact() {
        let mut m = EngineMetrics::default();
        let n = ITL_WINDOW + 100;
        for i in 0..n {
            m.record_gap(i as f64);
        }
        assert_eq!(m.inter_token_gaps.len(), ITL_WINDOW, "window must not grow unbounded");
        // Mean is exact over ALL n gaps, not just the retained window
        // (report() needs at least one finished record to emit anything).
        let expect = (0..n).sum::<usize>() as f64 / n as f64;
        m.record_finish(rec(1, 0.0, 1.0, 1, 1));
        let r = m.report();
        assert!((r.mean_inter_token_s - expect).abs() < 1e-6, "{}", r.mean_inter_token_s);
    }

    #[test]
    fn overload_counters_survive_empty_and_full_reports() {
        let mut m = EngineMetrics::default();
        m.shed_count = 7;
        m.deadline_miss_count = 3;
        m.concurrency_limit = 5;
        m.worker_restarts = 2;
        // No completions: the counters must still reach the report (a
        // fully-shed run is exactly when they matter).
        let r = m.report();
        assert_eq!(r.num_requests, 0);
        assert_eq!(r.shed_count, 7);
        assert_eq!(r.deadline_miss_count, 3);
        assert_eq!(r.concurrency_limit, 5);
        assert_eq!(r.worker_restarts, 2);
        // And with completions.
        m.record_finish(rec(1, 0.0, 1.0, 4, 4));
        let r = m.report();
        assert_eq!(r.num_requests, 1);
        assert_eq!((r.shed_count, r.deadline_miss_count), (7, 3));
    }

    #[test]
    fn inter_token_totals_are_exact_cumulative() {
        let mut m = EngineMetrics::default();
        assert_eq!(m.inter_token_totals(), (0, 0.0));
        m.record_gap(0.1);
        m.record_gap(0.3);
        let (n, s) = m.inter_token_totals();
        assert_eq!(n, 2);
        assert!((s - 0.4).abs() < 1e-12);
    }

    #[test]
    fn batch_occupancy_and_padding() {
        let mut m = EngineMetrics::default();
        m.decode_steps = 2;
        m.decode_batch_tokens = 6; // e.g. batches of 3 and 3
        m.decode_bucket_tokens = 8; // bucket 4 twice
        assert!((m.mean_decode_batch() - 3.0).abs() < 1e-9);
        assert!((m.padding_waste() - 0.25).abs() < 1e-9);
    }

    #[test]
    fn paper_block_formatting() {
        let mut m = EngineMetrics::default();
        m.record_finish(rec(1, 0.0, 2.0, 10, 20));
        let block = m.report().paper_block("test");
        assert!(block.contains("Latency: 2.00 seconds"));
        assert!(block.contains("All Throughput: 0.50 requests/s, 15.00 tokens/s"));
        assert!(block.contains("Generate Throughput: 10.00 tokens/s"));
    }
}
