//! Per-request sequence state.

use crate::kvcache::BlockTable;
use crate::model::{Sampler, SamplingParams};

/// Lifecycle phase of a sequence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeqPhase {
    /// Queued; no KV blocks held.
    Waiting,
    /// Admitted; prompt tokens are being prefilled.
    Prefilling,
    /// Generating tokens.
    Decoding,
    /// Evicted under memory pressure; blocks freed, waiting to recompute.
    Preempted,
    /// Done (EOS or max_tokens); blocks freed.
    Finished,
}

/// One in-flight request.
#[derive(Debug)]
pub struct Sequence {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub generated: Vec<u32>,
    pub params: SamplingParams,
    pub table: BlockTable,
    pub phase: SeqPhase,
    pub sampler: Sampler,
    /// Monotonic admission counter (eviction priority).
    pub arrival: u64,
    /// Replay tokens already written to the KV cache. Prefill spans
    /// multiple engine steps (chunked, budget-sized); this cursor marks
    /// where the next chunk starts. Equals `table.len()` whenever the
    /// sequence holds blocks; reset to 0 on recompute-preemption.
    pub prefill_pos: usize,
    // Timestamps (engine-clock seconds) for metrics.
    pub t_enqueue: f64,
    pub t_first_token: Option<f64>,
    /// When the most recent token was emitted (inter-token latency).
    pub t_last_token: Option<f64>,
    pub t_finish: Option<f64>,
}

impl Sequence {
    pub fn new(id: u64, prompt: Vec<u32>, params: SamplingParams, t_enqueue: f64) -> Sequence {
        assert!(!prompt.is_empty(), "empty prompt");
        Sequence {
            id,
            prompt,
            generated: Vec::new(),
            params,
            table: BlockTable::new(),
            phase: SeqPhase::Waiting,
            sampler: Sampler::new(id.wrapping_mul(0x9E37_79B9)),
            arrival: id,
            prefill_pos: 0,
            t_enqueue,
            t_first_token: None,
            t_last_token: None,
            t_finish: None,
        }
    }

    /// Total tokens this sequence will occupy in the cache when complete.
    pub fn max_cache_tokens(&self) -> usize {
        self.prompt.len() + self.params.max_tokens
    }

    /// Tokens currently in the cache.
    pub fn cache_tokens(&self) -> usize {
        self.table.len()
    }

    /// Input token for the next decode step: last generated, or — right
    /// after prefill — the token sampled from the prefill logits is
    /// already in `generated`, so this is always `generated.last()`.
    pub fn last_token(&self) -> u32 {
        *self.generated.last().expect("no generated token yet")
    }

    /// Generation-complete check.
    pub fn is_done(&self) -> bool {
        if self.generated.len() >= self.params.max_tokens {
            return true;
        }
        if !self.params.ignore_eos {
            if let Some(&t) = self.generated.last() {
                return t == crate::tokenizer::EOS;
            }
        }
        false
    }

    /// Reset to `Waiting` after preemption (blocks must already be freed;
    /// generated tokens are kept and will be replayed via prefill —
    /// recompute-style preemption).
    pub fn reset_for_recompute(&mut self) {
        assert!(self.table.is_empty(), "free blocks before recompute reset");
        self.phase = SeqPhase::Preempted;
        self.prefill_pos = 0;
    }

    /// The token stream to replay on re-admission (prompt + generated).
    pub fn replay_tokens(&self) -> Vec<u32> {
        let mut t = self.prompt.clone();
        t.extend_from_slice(&self.generated);
        t
    }

    /// Length of the replay stream (prompt + generated) without
    /// materializing it.
    pub fn replay_len(&self) -> usize {
        self.prompt.len() + self.generated.len()
    }

    /// Replay tokens still to be prefilled (`replay_len - prefill_pos`).
    pub fn remaining_prefill(&self) -> usize {
        self.replay_len() - self.prefill_pos
    }

    /// One chunk of the replay stream, `[start, start + len)`, without
    /// cloning the whole stream. Chunks may straddle the prompt/generated
    /// boundary after a recompute-preemption replay.
    pub fn replay_range(&self, start: usize, len: usize) -> Vec<u32> {
        let p = self.prompt.len();
        let end = start + len;
        assert!(end <= self.replay_len(), "replay range {start}..{end} out of bounds");
        let mut out = Vec::with_capacity(len);
        if start < p {
            out.extend_from_slice(&self.prompt[start..end.min(p)]);
        }
        if end > p {
            out.extend_from_slice(&self.generated[start.max(p) - p..end - p]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(max_tokens: usize) -> Sequence {
        let params = SamplingParams { max_tokens, ..Default::default() };
        Sequence::new(1, vec![256, 1, 2], params, 0.0)
    }

    #[test]
    fn lifecycle_defaults() {
        let s = seq(8);
        assert_eq!(s.phase, SeqPhase::Waiting);
        assert_eq!(s.max_cache_tokens(), 11);
        assert!(!s.is_done());
    }

    #[test]
    fn done_at_max_tokens() {
        let mut s = seq(2);
        s.generated = vec![5, 6];
        assert!(s.is_done());
    }

    #[test]
    fn eos_respected_when_not_ignored() {
        let mut s = seq(10);
        s.params.ignore_eos = false;
        s.generated = vec![crate::tokenizer::EOS];
        assert!(s.is_done());
        s.params.ignore_eos = true;
        assert!(!s.is_done());
    }

    #[test]
    fn replay_covers_prompt_and_generated() {
        let mut s = seq(4);
        s.generated = vec![7, 8];
        assert_eq!(s.replay_tokens(), vec![256, 1, 2, 7, 8]);
        assert_eq!(s.replay_len(), 5);
        assert_eq!(s.remaining_prefill(), 5);
        s.prefill_pos = 2;
        assert_eq!(s.remaining_prefill(), 3);
    }

    #[test]
    fn replay_range_straddles_prompt_boundary() {
        let mut s = seq(4); // prompt [256, 1, 2]
        s.generated = vec![7, 8];
        assert_eq!(s.replay_range(0, 5), vec![256, 1, 2, 7, 8]);
        assert_eq!(s.replay_range(0, 2), vec![256, 1]);
        assert_eq!(s.replay_range(2, 2), vec![2, 7]);
        assert_eq!(s.replay_range(3, 2), vec![7, 8]);
        assert_eq!(s.replay_range(4, 1), vec![8]);
        assert!(s.replay_range(5, 0).is_empty());
    }

    #[test]
    fn recompute_resets_prefill_cursor() {
        let mut s = seq(4);
        s.prefill_pos = 3;
        s.reset_for_recompute();
        assert_eq!(s.phase, SeqPhase::Preempted);
        assert_eq!(s.prefill_pos, 0);
    }

    #[test]
    #[should_panic(expected = "empty prompt")]
    fn empty_prompt_rejected() {
        let _ = Sequence::new(1, vec![], SamplingParams::default(), 0.0);
    }
}
