//! Request router: the overload-hardened front door over one or more
//! engine workers.
//!
//! Each worker owns an [`Engine`] on its own thread; the router
//! validates requests, applies the admission policy, and dispatches to
//! the least-loaded healthy worker (paper §III.C "dynamic load
//! balancing"). Responses flow back over a per-request channel carrying
//! a typed [`SubmitResult`]. With `workers == 1` this degenerates to a
//! serialized engine with an async submission API — the configuration
//! every bench uses (determinism), while multi-worker exercises the
//! balancing and supervision paths.
//!
//! Overload control (see [`super::admission`] and ARCHITECTURE.md
//! "Overload & failure contract"):
//!
//! * **Bounded admission** — at most `AdmissionConfig::queue_depth`
//!   requests queue in front of each worker; beyond that `submit`
//!   sheds synchronously with [`SubmitError::QueueFull`] and a
//!   `retry_after_ms` hint instead of queueing without bound.
//! * **Deadlines** — every request carries one (caller-supplied or the
//!   config default); the worker sheds expired entries with
//!   [`SubmitError::DeadlineExceeded`] *before* scheduling, never by
//!   aborting scheduled work.
//! * **AIMD concurrency limit** — the worker admits into the engine
//!   only up to a limit that probes up additively while observed
//!   inter-token latency tracks the SLO target and halves on breach.
//! * **Supervision** — each worker thread is a supervisor around the
//!   engine loop: `catch_unwind` on crash, pending (in-engine) requests
//!   failed with [`SubmitError::WorkerFailed`], queued-but-unadmitted
//!   requests retained, backend + engine rebuilt from the retained
//!   factory (a fresh engine owns a fresh KV pool, so a crash can never
//!   leak blocks). After `max_restarts` crashes the worker goes
//!   permanently unhealthy: `pick_worker` skips it and `/health`
//!   reports it (503 when none are left).

use super::admission::{AdmissionConfig, AdmissionQueue, AimdController, SubmitError};
use super::engine::{Engine, EngineConfig, RequestOutput};
use super::metrics::{EngineMetrics, RunReport};
use crate::model::SamplingParams;
use crate::obs::{EngineStat, Telemetry, TraceEvent};
use crate::runtime::Backend;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// What a reply channel yields: the completed output, or a typed
/// rejection (queue full / deadline / too long / worker crash).
pub type SubmitResult = Result<RequestOutput, SubmitError>;

/// Router construction parameters.
pub struct RouterConfig {
    pub engine: EngineConfig,
    pub workers: usize,
    /// Overload-control policy (queue depth, deadlines, AIMD, restart
    /// budget).
    pub admission: AdmissionConfig,
}

enum WorkerMsg {
    Request {
        /// Router-assigned request id: globally unique across workers,
        /// threaded into the engine so engine id == client-visible id.
        id: u64,
        prompt: Vec<u32>,
        params: SamplingParams,
        deadline: Instant,
        reply: Sender<SubmitResult>,
    },
    /// Point-in-time state probe, answered by the worker loop between
    /// steps (tests, benches, observability).
    Inspect { reply: Sender<WorkerSnapshot> },
    Shutdown,
}

/// A queued request the worker has accepted but not yet admitted into
/// the engine.
struct PendingReq {
    id: u64,
    prompt: Vec<u32>,
    params: SamplingParams,
    reply: Sender<SubmitResult>,
}

/// Point-in-time worker state (via [`Router::snapshot`]).
#[derive(Debug, Clone)]
pub struct WorkerSnapshot {
    /// The worker engine's metrics report (includes the mirrored
    /// overload counters). Reset on respawn — a dead worker reports
    /// defaults plus its restart count.
    pub report: RunReport,
    /// Requests queued in front of the engine.
    pub queued: usize,
    /// Requests admitted into the engine and not yet completed.
    pub engine_inflight: usize,
    /// KV blocks currently allocated (leak probe: 0 when idle).
    pub used_blocks: usize,
    /// KV blocks currently free.
    pub free_blocks: usize,
    pub restarts: usize,
    pub healthy: bool,
    pub concurrency_limit: usize,
}

/// Cheap per-worker health view (atomics only, no worker round-trip) —
/// the `/health` endpoint's data source.
#[derive(Debug, Clone)]
pub struct WorkerHealth {
    pub healthy: bool,
    pub restarts: usize,
    pub inflight: usize,
    pub queued: usize,
    pub concurrency_limit: usize,
}

/// Counters shared between the submit side and the worker thread.
struct WorkerShared {
    /// Accepted but not yet admitted into the engine (the bounded
    /// quantity: `submit` sheds when it reaches `queue_depth`).
    queued: AtomicUsize,
    /// Accepted and not yet replied to (load signal for `pick_worker`).
    inflight: AtomicUsize,
    healthy: AtomicBool,
    /// Successful crash→respawn cycles (a permanently dead worker does
    /// not count its final crash as a restart).
    restarts: AtomicUsize,
    shed_queue_full: AtomicUsize,
    shed_deadline: AtomicUsize,
    /// EWMA of completed-request latency in ms (retry-after hints).
    service_ms: AtomicU64,
    /// Mirror of the worker's current AIMD concurrency limit.
    limit: AtomicUsize,
}

impl WorkerShared {
    fn new(initial_limit: usize) -> Self {
        WorkerShared {
            queued: AtomicUsize::new(0),
            inflight: AtomicUsize::new(0),
            // Born healthy: requests submitted before the worker thread
            // finishes construction just queue in its mailbox.
            healthy: AtomicBool::new(true),
            restarts: AtomicUsize::new(0),
            shed_queue_full: AtomicUsize::new(0),
            shed_deadline: AtomicUsize::new(0),
            service_ms: AtomicU64::new(0),
            limit: AtomicUsize::new(initial_limit),
        }
    }

    fn observe_service_ms(&self, ms: f64) {
        let old = self.service_ms.load(Ordering::Relaxed);
        let new = if old == 0 { ms } else { 0.8 * old as f64 + 0.2 * ms };
        self.service_ms.store(new.max(1.0) as u64, Ordering::Relaxed);
    }
}

struct Worker {
    tx: Sender<WorkerMsg>,
    handle: Option<JoinHandle<()>>,
    shared: Arc<WorkerShared>,
    /// Telemetry registry shared with every engine incarnation on this
    /// worker. Created router-side so it survives a panic unwind — the
    /// supervisor dumps the flight ring from it after a crash, and
    /// `/metrics` scrapes it without a worker round-trip.
    telem: Arc<Telemetry>,
}

/// Multi-worker request router with bounded admission and supervision.
pub struct Router {
    workers: Vec<Worker>,
    next: AtomicUsize,
    /// Monotonic request-id source: ids are assigned *before* admission
    /// so even shed requests carry one in their error body and logs.
    req_ids: AtomicU64,
    admission: AdmissionConfig,
}

impl Router {
    /// Spawn `cfg.workers` supervised engine workers. `make_backend`
    /// is retained (shared across worker threads) so a crashed worker
    /// can rebuild its backend; it runs on the worker's own thread,
    /// once per incarnation.
    pub fn new<F>(cfg: RouterConfig, make_backend: F) -> Router
    where
        F: Fn(usize) -> Box<dyn Backend> + Send + Sync + 'static,
    {
        assert!(cfg.workers > 0);
        let factory = Arc::new(make_backend);
        let mut workers = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let (tx, rx) = channel::<WorkerMsg>();
            let shared = Arc::new(WorkerShared::new(cfg.admission.aimd.initial_limit));
            let telem = Arc::new(Telemetry::new());
            let econf = cfg.engine.clone();
            let acfg = cfg.admission.clone();
            let factory = factory.clone();
            let shared_thread = shared.clone();
            let telem_thread = telem.clone();
            let handle = std::thread::Builder::new()
                .name(format!("engine-worker-{w}"))
                .spawn(move || {
                    supervise(w, factory, econf, acfg, rx, shared_thread, telem_thread)
                })
                .expect("spawn engine worker");
            workers.push(Worker { tx, handle: Some(handle), shared, telem });
        }
        Router {
            workers,
            next: AtomicUsize::new(0),
            req_ids: AtomicU64::new(0),
            admission: cfg.admission,
        }
    }

    /// Submit with the config's default deadline. The receiver yields
    /// exactly one [`SubmitResult`] — completion or typed rejection.
    pub fn submit(
        &self,
        prompt: Vec<u32>,
        params: SamplingParams,
    ) -> Result<Receiver<SubmitResult>, SubmitError> {
        self.submit_with_deadline(prompt, params, None)
    }

    /// Submit with an explicit scheduling deadline (`None` → the
    /// admission config's `default_deadline_ms`). Synchronous errors:
    /// [`SubmitError::QueueFull`] when the picked worker's admission
    /// queue is at depth, [`SubmitError::WorkerFailed`] when no healthy
    /// worker exists.
    pub fn submit_with_deadline(
        &self,
        prompt: Vec<u32>,
        params: SamplingParams,
        timeout: Option<Duration>,
    ) -> Result<Receiver<SubmitResult>, SubmitError> {
        self.submit_traced(prompt, params, timeout).1
    }

    /// [`Router::submit_with_deadline`] that also returns the assigned
    /// request id. The id is minted *before* admission, so a shed
    /// request still has one for its error body and log line — and it
    /// is the engine-side id too ([`Engine::add_request_with_id`]), so
    /// `GET /debug/trace/{id}` resolves unambiguously across workers.
    pub fn submit_traced(
        &self,
        prompt: Vec<u32>,
        params: SamplingParams,
        timeout: Option<Duration>,
    ) -> (u64, Result<Receiver<SubmitResult>, SubmitError>) {
        let id = self.req_ids.fetch_add(1, Ordering::Relaxed) + 1;
        let Some(w) = self.pick_worker() else {
            log::debug!("request {id}: no healthy worker");
            return (id, Err(SubmitError::WorkerFailed));
        };
        (id, self.submit_to(w, id, prompt, params, timeout))
    }

    fn submit_to(
        &self,
        w: usize,
        id: u64,
        prompt: Vec<u32>,
        params: SamplingParams,
        timeout: Option<Duration>,
    ) -> Result<Receiver<SubmitResult>, SubmitError> {
        let shared = &self.workers[w].shared;
        // Strict bound under concurrent submitters: reserve the slot
        // first; whoever overshoots rolls back and sheds.
        if shared.queued.fetch_add(1, Ordering::SeqCst) >= self.admission.queue_depth {
            shared.queued.fetch_sub(1, Ordering::SeqCst);
            shared.shed_queue_full.fetch_add(1, Ordering::SeqCst);
            let retry_after_ms = self.retry_hint_ms(w);
            log::debug!("request {id}: shed queue-full at worker {w} (retry {retry_after_ms} ms)");
            return Err(SubmitError::QueueFull { retry_after_ms });
        }
        shared.inflight.fetch_add(1, Ordering::SeqCst);
        let deadline = Instant::now()
            + timeout.unwrap_or(Duration::from_millis(self.admission.default_deadline_ms));
        let (reply, rx) = channel();
        let msg = WorkerMsg::Request { id, prompt, params, deadline, reply };
        if self.workers[w].tx.send(msg).is_err() {
            // The worker is gone. Roll back BOTH counters — leaving
            // `inflight` raised would skew pick_worker away from this
            // worker forever (the pre-supervision leak).
            shared.queued.fetch_sub(1, Ordering::SeqCst);
            shared.inflight.fetch_sub(1, Ordering::SeqCst);
            log::debug!("request {id}: worker {w} channel dead");
            return Err(SubmitError::WorkerFailed);
        }
        Ok(rx)
    }

    /// Estimated ms until worker `w` frees a queue slot: its service
    /// EWMA scaled by backlog over concurrency, clamped to a sane
    /// client-retry range.
    fn retry_hint_ms(&self, w: usize) -> u64 {
        let shared = &self.workers[w].shared;
        let service = shared.service_ms.load(Ordering::Relaxed).max(10);
        let backlog = shared.queued.load(Ordering::SeqCst).max(1) as u64;
        let limit = shared.limit.load(Ordering::SeqCst).max(1) as u64;
        (service * backlog / limit).clamp(10, 60_000)
    }

    /// Least-loaded *healthy* worker, round-robin tie-break. `None`
    /// when every worker is dead.
    fn pick_worker(&self) -> Option<usize> {
        let n = self.workers.len();
        let start = self.next.fetch_add(1, Ordering::Relaxed) % n;
        let mut best: Option<(usize, usize)> = None;
        for i in 0..n {
            let w = (start + i) % n;
            let shared = &self.workers[w].shared;
            if !shared.healthy.load(Ordering::SeqCst) {
                continue;
            }
            let load = shared.inflight.load(Ordering::SeqCst);
            if best.map_or(true, |(_, b)| load < b) {
                best = Some((w, load));
            }
        }
        best.map(|(w, _)| w)
    }

    /// Current total in-flight count.
    pub fn inflight(&self) -> usize {
        self.workers.iter().map(|w| w.shared.inflight.load(Ordering::SeqCst)).sum()
    }

    pub fn num_workers(&self) -> usize {
        self.workers.len()
    }

    pub fn num_healthy(&self) -> usize {
        self.workers.iter().filter(|w| w.shared.healthy.load(Ordering::SeqCst)).count()
    }

    /// Total crash→respawn cycles across workers.
    pub fn worker_restarts(&self) -> usize {
        self.workers.iter().map(|w| w.shared.restarts.load(Ordering::SeqCst)).sum()
    }

    /// Per-worker health view from shared atomics (no worker
    /// round-trip; safe to call on a wedged router).
    pub fn worker_health(&self) -> Vec<WorkerHealth> {
        self.workers
            .iter()
            .map(|w| WorkerHealth {
                healthy: w.shared.healthy.load(Ordering::SeqCst),
                restarts: w.shared.restarts.load(Ordering::SeqCst),
                inflight: w.shared.inflight.load(Ordering::SeqCst),
                queued: w.shared.queued.load(Ordering::SeqCst),
                concurrency_limit: w.shared.limit.load(Ordering::SeqCst),
            })
            .collect()
    }

    /// Worker `w`'s telemetry registry (counters, histograms, trace
    /// ring, flight recorder). Always readable — even mid-crash or
    /// after the worker went permanently unhealthy — because the
    /// registry is owned router-side and only *shared* with the engine.
    pub fn telemetry(&self, w: usize) -> Option<&Arc<Telemetry>> {
        self.workers.get(w).map(|w| &w.telem)
    }

    /// Every worker's telemetry, in worker order (the `/metrics`
    /// scrape path).
    pub fn telemetries(&self) -> Vec<Arc<Telemetry>> {
        self.workers.iter().map(|w| w.telem.clone()).collect()
    }

    /// Trace events recorded for request `id`, searched across every
    /// worker's ring (ids are globally unique, so at most one worker
    /// has any). Empty when the id is unknown or its events have been
    /// overwritten by ring wrap.
    pub fn trace_events(&self, id: u64) -> Vec<TraceEvent> {
        for w in &self.workers {
            let evs = w.telem.traces.events_for(id);
            if !evs.is_empty() {
                return evs;
            }
        }
        Vec::new()
    }

    /// Resize every worker's flight-recorder ring (startup-time
    /// configuration: clears any recorded history).
    pub fn set_flight_records(&self, records: usize) {
        for w in &self.workers {
            w.telem.flight.set_capacity(records);
        }
    }

    /// Ask worker `w` for a state snapshot (engine metrics, queue and
    /// pool occupancy). `None` if the worker cannot answer within 10 s.
    pub fn snapshot(&self, w: usize) -> Option<WorkerSnapshot> {
        let (reply, rx) = channel();
        self.workers[w].tx.send(WorkerMsg::Inspect { reply }).ok()?;
        rx.recv_timeout(Duration::from_secs(10)).ok()
    }

    /// Test hook: cleanly stop worker `w` and join its thread, leaving
    /// its channel dead but its health flag untouched — the setup for
    /// exercising the send-failure rollback in `submit_to`.
    #[cfg(test)]
    fn kill_worker_for_test(&mut self, w: usize) {
        let _ = self.workers[w].tx.send(WorkerMsg::Shutdown);
        if let Some(h) = self.workers[w].handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.tx.send(WorkerMsg::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

/// Supervisor body for one worker thread: run the engine loop under
/// `catch_unwind`; on crash, fail in-engine requests with
/// [`SubmitError::WorkerFailed`], keep queued-but-unadmitted requests
/// (they were not the poison), and respawn the engine from the factory
/// — a fresh engine owns a fresh allocator, so no KV block survives a
/// crash. After `max_restarts` crashes, go permanently unhealthy and
/// keep draining the mailbox so late submits get a typed failure.
fn supervise<F>(
    w: usize,
    factory: Arc<F>,
    econf: EngineConfig,
    acfg: AdmissionConfig,
    rx: Receiver<WorkerMsg>,
    shared: Arc<WorkerShared>,
    telem: Arc<Telemetry>,
) where
    F: Fn(usize) -> Box<dyn Backend> + Send + Sync + 'static,
{
    let mut queue: AdmissionQueue<PendingReq> = AdmissionQueue::new();
    let mut pending: Vec<(u64, Sender<SubmitResult>)> = Vec::new();
    let mut restarts_left = acfg.max_restarts;
    loop {
        let run = std::panic::catch_unwind(AssertUnwindSafe(|| {
            worker_loop(w, &*factory, &econf, &acfg, &rx, &shared, &telem, &mut queue, &mut pending)
        }));
        match run {
            // Clean exit: Shutdown message or every sender dropped.
            Ok(()) => return,
            Err(_) => {
                log::warn!(
                    "engine-worker-{w}: engine crashed; failing {} in-flight request(s), {} queued retained",
                    pending.len(),
                    queue.len()
                );
                // The flight recorder survives the unwind (router-owned
                // Arc): dump the last N step records — the black box for
                // the post-mortem — before touching any request state.
                telem.flight.dump_to_log(w);
                let dead = restarts_left == 0;
                if dead {
                    // Permanently dead. Unhealthy FIRST — before any
                    // failing reply is delivered — so a client that sees
                    // WorkerFailed and immediately probes /health (or
                    // resubmits through pick_worker) observes the
                    // degraded state deterministically.
                    shared.healthy.store(false, Ordering::SeqCst);
                    log::error!(
                        "engine-worker-{w}: crash budget exhausted (max_restarts = {}); going unhealthy",
                        acfg.max_restarts
                    );
                }
                for (id, reply) in pending.drain(..) {
                    shared.inflight.fetch_sub(1, Ordering::SeqCst);
                    log::debug!("request {id}: failed by engine-worker-{w} crash");
                    let _ = reply.send(Err(SubmitError::WorkerFailed));
                }
                if dead {
                    for req in queue.drain_all() {
                        shared.queued.fetch_sub(1, Ordering::SeqCst);
                        shared.inflight.fetch_sub(1, Ordering::SeqCst);
                        let _ = req.reply.send(Err(SubmitError::WorkerFailed));
                    }
                    drain_dead(&rx, &shared);
                    return;
                }
                restarts_left -= 1;
                shared.restarts.fetch_add(1, Ordering::SeqCst);
            }
        }
    }
}

/// Mailbox loop of a permanently dead worker: answer (rather than
/// strand) anything that still arrives, until the router drops.
fn drain_dead(rx: &Receiver<WorkerMsg>, shared: &WorkerShared) {
    for msg in rx.iter() {
        match msg {
            WorkerMsg::Request { id, reply, .. } => {
                shared.queued.fetch_sub(1, Ordering::SeqCst);
                shared.inflight.fetch_sub(1, Ordering::SeqCst);
                log::debug!("request {id}: rejected by permanently dead worker");
                let _ = reply.send(Err(SubmitError::WorkerFailed));
            }
            WorkerMsg::Inspect { reply } => {
                let restarts = shared.restarts.load(Ordering::SeqCst);
                let _ = reply.send(WorkerSnapshot {
                    report: RunReport { worker_restarts: restarts, ..Default::default() },
                    queued: 0,
                    engine_inflight: 0,
                    used_blocks: 0,
                    free_blocks: 0,
                    restarts,
                    healthy: false,
                    concurrency_limit: 0,
                });
            }
            WorkerMsg::Shutdown => return,
        }
    }
}

/// One engine incarnation: build backend + engine, then loop
/// mailbox-drain → deadline-shed → AIMD-bounded admission → step →
/// replies → controller update → metrics mirror. Returns on clean
/// shutdown; panics (engine/backend crashes) unwind to [`supervise`].
#[allow(clippy::too_many_arguments)]
fn worker_loop<F>(
    w: usize,
    factory: &F,
    econf: &EngineConfig,
    acfg: &AdmissionConfig,
    rx: &Receiver<WorkerMsg>,
    shared: &WorkerShared,
    telem: &Arc<Telemetry>,
    queue: &mut AdmissionQueue<PendingReq>,
    pending: &mut Vec<(u64, Sender<SubmitResult>)>,
) where
    F: Fn(usize) -> Box<dyn Backend>,
{
    let backend = factory(w);
    // Re-attach the worker's long-lived telemetry: histograms, traces
    // and the flight ring accumulate across engine incarnations, while
    // the mirrored scalar counters reset with the engine's metrics.
    let mut engine = Engine::with_telemetry(backend, econf.clone(), telem.clone());
    let mut aimd = AimdController::new(acfg.aimd);
    shared.limit.store(aimd.limit(), Ordering::SeqCst);
    shared.healthy.store(true, Ordering::SeqCst);
    loop {
        // Drain the mailbox (non-blocking while there is engine or
        // queued work; blocking when fully idle to avoid spinning).
        loop {
            let msg = if engine.has_work() || !queue.is_empty() {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        // Orphaned worker (router dropped without
                        // Shutdown): still flush the spill commit
                        // frontier so restored-KV durability survives.
                        engine.flush_spill();
                        return;
                    }
                }
            } else {
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => {
                        engine.flush_spill();
                        return;
                    }
                }
            };
            match msg {
                WorkerMsg::Request { id, prompt, params, deadline, reply } => {
                    queue.push(deadline, PendingReq { id, prompt, params, reply });
                }
                WorkerMsg::Inspect { reply } => {
                    // Refresh the mirrored counters first: a shed can
                    // land (on the submit side) while this loop idles in
                    // recv, after its last end-of-iteration mirror.
                    mirror_overload_counters(&mut engine.metrics, shared, aimd.limit());
                    let _ = reply.send(WorkerSnapshot {
                        report: engine.metrics.report(),
                        queued: queue.len(),
                        engine_inflight: pending.len(),
                        used_blocks: engine.used_blocks(),
                        free_blocks: engine.free_blocks(),
                        restarts: shared.restarts.load(Ordering::SeqCst),
                        healthy: true,
                        concurrency_limit: aimd.limit(),
                    });
                }
                WorkerMsg::Shutdown => {
                    // Graceful drain: the spill tier's commit frontier
                    // must be durable before the worker exits, so a
                    // restarted deployment recovers every offered block
                    // (ARCHITECTURE.md "Spill & recovery contract").
                    engine.flush_spill();
                    return;
                }
            }
        }
        // Deadline shedding — strictly before admission/scheduling, so
        // an expired request never costs engine work.
        let now = Instant::now();
        for req in queue.shed_expired(now) {
            shared.queued.fetch_sub(1, Ordering::SeqCst);
            shared.inflight.fetch_sub(1, Ordering::SeqCst);
            shared.shed_deadline.fetch_add(1, Ordering::SeqCst);
            log::debug!("request {}: shed expired deadline at engine-worker-{w}", req.id);
            let _ = req.reply.send(Err(SubmitError::DeadlineExceeded));
        }
        // Admit into the engine up to the AIMD concurrency limit. The
        // router-assigned id becomes the engine id, so the trace ring,
        // response JSON and log lines all speak one id space.
        while pending.len() < aimd.limit() {
            let Some((_deadline, req)) = queue.pop() else { break };
            shared.queued.fetch_sub(1, Ordering::SeqCst);
            match engine.add_request_with_id(req.id, req.prompt, req.params) {
                Ok(id) => pending.push((id, req.reply)),
                Err(e) => {
                    shared.inflight.fetch_sub(1, Ordering::SeqCst);
                    log::debug!("request {}: rejected at engine-worker-{w}: {e:?}", req.id);
                    let _ = req.reply.send(Err(e));
                }
            }
        }
        // Stamp the queue-depth gauge the engine cannot see (it lives
        // in the admission layer) before the step records its flight
        // entry, which reads QueueDepth back from the registry. The
        // InflightRequests gauge is the engine's to write — it mirrors
        // waiting + running at the end of every step.
        telem.set(EngineStat::QueueDepth, queue.len() as u64);
        engine.step();
        for out in engine.take_outputs() {
            if let Some(pos) = pending.iter().position(|(id, _)| *id == out.id) {
                let (_, reply) = pending.swap_remove(pos);
                shared.inflight.fetch_sub(1, Ordering::SeqCst);
                shared.observe_service_ms(out.latency_s * 1e3);
                log::debug!(
                    "request {}: completed at engine-worker-{w} ({} tokens, {:.1} ms)",
                    out.id,
                    out.tokens.len(),
                    out.latency_s * 1e3
                );
                let _ = reply.send(Ok(out));
            }
        }
        // Feed the AIMD controller the engine's cumulative inter-token
        // totals; it adjusts once a full sample window has accumulated.
        let (count, sum) = engine.metrics.inter_token_totals();
        if aimd.observe_totals(count, sum) {
            shared.limit.store(aimd.limit(), Ordering::SeqCst);
        }
        // Mirror admission-layer counters into the engine's metrics so
        // RunReport carries the overload story.
        mirror_overload_counters(&mut engine.metrics, shared, aimd.limit());
    }
}

/// Copy the admission-layer counters (kept in [`WorkerShared`] atomics,
/// some bumped from the submit side) into the engine's metrics, where
/// `RunReport` picks them up.
fn mirror_overload_counters(metrics: &mut EngineMetrics, shared: &WorkerShared, limit: usize) {
    metrics.deadline_miss_count = shared.shed_deadline.load(Ordering::SeqCst);
    metrics.shed_count =
        metrics.deadline_miss_count + shared.shed_queue_full.load(Ordering::SeqCst);
    metrics.concurrency_limit = limit;
    metrics.worker_restarts = shared.restarts.load(Ordering::SeqCst);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::admission::AimdConfig;
    use crate::coordinator::batcher::BucketPolicy;
    use crate::coordinator::scheduler::SchedulerConfig;
    use crate::model::{ModelConfig, ModelWeights, NativeModel};
    use crate::runtime::{FaultPlan, FaultyBackend, NativeBackend};

    fn engine_cfg() -> EngineConfig {
        EngineConfig {
            num_blocks: 32,
            block_size: 8,
            sched: SchedulerConfig::default(),
            decode_buckets: BucketPolicy::exact(8),
            prefill_chunk: usize::MAX,
            prefix_cache_blocks: 0,
            kv_dtype: crate::kvcache::KvCacheDtype::F32,
            weight_dtype: crate::model::WeightDtype::F32,
            spill: None,
        }
    }

    fn tiny_backend(seed: u64) -> Box<dyn Backend> {
        let mc = ModelConfig::tiny();
        Box::new(NativeBackend::new(NativeModel::new(ModelWeights::init(&mc, seed))))
    }

    fn router_with(workers: usize, admission: AdmissionConfig) -> Router {
        Router::new(RouterConfig { engine: engine_cfg(), workers, admission }, |_| {
            tiny_backend(7)
        })
    }

    fn router(workers: usize) -> Router {
        router_with(workers, AdmissionConfig::default())
    }

    #[test]
    fn single_worker_roundtrip() {
        let r = router(1);
        let params = SamplingParams { max_tokens: 4, ..Default::default() };
        let rx = r.submit(vec![256, 1, 2], params).unwrap();
        let out = rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
        assert_eq!(out.tokens.len(), 4);
        assert_eq!(r.inflight(), 0);
        assert_eq!(r.num_healthy(), 1);
        assert_eq!(r.worker_restarts(), 0);
    }

    #[test]
    fn multi_worker_distributes_and_completes() {
        let r = router(2);
        let params = SamplingParams { max_tokens: 3, ..Default::default() };
        let rxs: Vec<_> =
            (0..6).map(|i| r.submit(vec![256, i as u32], params).unwrap()).collect();
        for rx in rxs {
            let out = rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
            assert_eq!(out.tokens.len(), 3);
        }
        assert_eq!(r.inflight(), 0);
    }

    #[test]
    fn oversized_request_gets_typed_rejection() {
        let r = router(1);
        let params = SamplingParams { max_tokens: 100_000, ..Default::default() };
        let rx = r.submit(vec![256; 10], params).unwrap();
        match rx.recv_timeout(Duration::from_secs(10)).unwrap() {
            Err(SubmitError::PromptTooLong { reason }) => {
                assert!(reason.contains("KV tokens"), "{reason}");
            }
            other => panic!("expected PromptTooLong, got {other:?}"),
        }
        assert_eq!(r.inflight(), 0, "typed rejection must release the inflight slot");
    }

    #[test]
    fn zero_depth_queue_sheds_with_retry_hint() {
        let r = router_with(1, AdmissionConfig { queue_depth: 0, ..Default::default() });
        let params = SamplingParams { max_tokens: 4, ..Default::default() };
        match r.submit(vec![256, 1], params) {
            Err(SubmitError::QueueFull { retry_after_ms }) => {
                assert!(retry_after_ms >= 10, "hint {retry_after_ms} below the clamp floor");
            }
            other => panic!("expected QueueFull, got {other:?}"),
        }
        assert_eq!(r.inflight(), 0);
        // The shed is visible in the worker's mirrored metrics.
        let snap = r.snapshot(0).expect("live worker answers Inspect");
        assert_eq!(snap.report.shed_count, 1);
        assert_eq!(snap.report.deadline_miss_count, 0);
    }

    #[test]
    fn expired_deadline_is_shed_before_scheduling() {
        let r = router(1);
        let params = SamplingParams { max_tokens: 4, ..Default::default() };
        let rx = r
            .submit_with_deadline(vec![256, 1, 2], params, Some(Duration::ZERO))
            .expect("queue accepts; the worker sheds");
        match rx.recv_timeout(Duration::from_secs(10)).unwrap() {
            Err(SubmitError::DeadlineExceeded) => {}
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        assert_eq!(r.inflight(), 0);
        let snap = r.snapshot(0).unwrap();
        assert_eq!(snap.report.deadline_miss_count, 1);
        assert_eq!(snap.report.shed_count, 1);
        // Shed strictly pre-scheduling: the engine never saw a request.
        assert_eq!(snap.report.num_requests, 0);
        assert_eq!(snap.engine_inflight, 0);
    }

    #[test]
    fn send_failure_rolls_back_inflight_and_queued() {
        // Regression for the pre-supervision leak: `submit` incremented
        // inflight before `tx.send` and the error path never undid it,
        // permanently skewing pick_worker.
        let mut r = router(1);
        r.kill_worker_for_test(0);
        let params = SamplingParams { max_tokens: 4, ..Default::default() };
        match r.submit_to(0, vec![256, 1], params, None) {
            Err(SubmitError::WorkerFailed) => {}
            other => panic!("expected WorkerFailed on a dead channel, got {other:?}"),
        }
        assert_eq!(r.inflight(), 0, "inflight leaked on the send-failure path");
        assert_eq!(r.worker_health()[0].queued, 0, "queued leaked on the send-failure path");
    }

    #[test]
    fn worker_crash_fails_pending_restarts_and_recovers_without_leaks() {
        // Satellite: a backend panic mid-decode → the pending request
        // fails typed, the worker respawns, the next request succeeds,
        // and the fresh engine's pool shows zero leaked KV blocks.
        let calls = Arc::new(AtomicUsize::new(0));
        let calls_f = calls.clone();
        let r = Router::new(
            RouterConfig {
                engine: engine_cfg(),
                workers: 1,
                admission: AdmissionConfig::default(),
            },
            move |_| {
                let inner = tiny_backend(7);
                if calls_f.fetch_add(1, Ordering::SeqCst) == 0 {
                    // First incarnation: panic on the 3rd forward_step —
                    // after prefill, mid-decode, with KV blocks live.
                    Box::new(FaultyBackend::new(
                        inner,
                        FaultPlan::new(1).panic_at_step(2).injector(),
                    ))
                } else {
                    inner
                }
            },
        );
        let params = SamplingParams { max_tokens: 8, ..Default::default() };
        let rx = r.submit(vec![256, 1, 2, 3], params).unwrap();
        match rx.recv_timeout(Duration::from_secs(30)).unwrap() {
            Err(SubmitError::WorkerFailed) => {}
            other => panic!("expected WorkerFailed from the crash, got {other:?}"),
        }
        assert_eq!(r.inflight(), 0, "crash recovery must release inflight slots");

        // The respawned worker serves the next request.
        let params = SamplingParams { max_tokens: 5, ..Default::default() };
        let rx = r.submit(vec![256, 4, 5], params).unwrap();
        let out = rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
        assert_eq!(out.tokens.len(), 5);
        assert_eq!(calls.load(Ordering::SeqCst), 2, "factory rebuilds the backend once");

        let snap = r.snapshot(0).unwrap();
        assert!(snap.healthy);
        assert_eq!(snap.restarts, 1);
        assert_eq!(snap.report.worker_restarts, 1);
        assert_eq!(snap.used_blocks, 0, "KV blocks leaked across the crash");
        assert_eq!(snap.free_blocks, engine_cfg().num_blocks);
        assert_eq!(r.worker_restarts(), 1);
    }

    #[test]
    fn crash_budget_exhaustion_goes_permanently_unhealthy() {
        let r = Router::new(
            RouterConfig {
                engine: engine_cfg(),
                workers: 1,
                admission: AdmissionConfig { max_restarts: 0, ..Default::default() },
            },
            |_| {
                Box::new(FaultyBackend::new(
                    tiny_backend(7),
                    FaultPlan::new(1).panic_at_step(0).injector(),
                ))
            },
        );
        let params = SamplingParams { max_tokens: 4, ..Default::default() };
        let rx = r.submit(vec![256, 1], params).unwrap();
        match rx.recv_timeout(Duration::from_secs(30)).unwrap() {
            Err(SubmitError::WorkerFailed) => {}
            other => panic!("expected WorkerFailed, got {other:?}"),
        }
        // healthy=false is stored before the failing reply is sent, so
        // this observation is deterministic.
        assert_eq!(r.num_healthy(), 0);
        assert_eq!(r.worker_restarts(), 0, "a dead worker's final crash is not a restart");
        // With no healthy worker, submit fails synchronously and typed.
        match r.submit(vec![256, 2], SamplingParams::default()) {
            Err(SubmitError::WorkerFailed) => {}
            other => panic!("expected WorkerFailed with no healthy workers, got {other:?}"),
        }
        assert_eq!(r.inflight(), 0);
        // A dead worker still answers Inspect (via the drain loop).
        let snap = r.snapshot(0).unwrap();
        assert!(!snap.healthy);
    }

    #[test]
    fn traced_submit_threads_ids_end_to_end() {
        use crate::obs::TraceKind;
        let r = router(1);
        let params = SamplingParams { max_tokens: 3, ..Default::default() };
        let (id1, rx1) = r.submit_traced(vec![256, 1, 2], params, None);
        let (id2, rx2) = r.submit_traced(vec![256, 3], params, None);
        assert_eq!((id1, id2), (1, 2), "router ids are minted 1, 2, ...");
        let out1 = rx1.unwrap().recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
        let out2 = rx2.unwrap().recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
        // The engine id IS the router id — the response echoes it.
        assert_eq!(out1.id, id1);
        assert_eq!(out2.id, id2);
        // And the trace ring resolves it: full lifecycle, in order.
        let evs = r.trace_events(id1);
        assert!(!evs.is_empty(), "no trace events for request {id1}");
        assert_eq!(evs.first().unwrap().kind, TraceKind::Enqueue);
        assert_eq!(evs.last().unwrap().kind, TraceKind::Finish);
        assert!(evs.iter().any(|e| e.kind == TraceKind::FirstToken));
        assert!(r.trace_events(999).is_empty(), "unknown id has no trace");
    }

    #[test]
    fn crash_dumps_the_flight_recorder() {
        // The supervisor's black box: a worker crash must dump the
        // flight ring (recorded by the doomed incarnation) before any
        // failing reply is delivered, so observing WorkerFailed implies
        // the dump already happened.
        let calls = Arc::new(AtomicUsize::new(0));
        let calls_f = calls.clone();
        let r = Router::new(
            RouterConfig {
                engine: engine_cfg(),
                workers: 1,
                admission: AdmissionConfig::default(),
            },
            move |_| {
                let inner = tiny_backend(7);
                if calls_f.fetch_add(1, Ordering::SeqCst) == 0 {
                    Box::new(FaultyBackend::new(
                        inner,
                        FaultPlan::new(1).panic_at_step(2).injector(),
                    ))
                } else {
                    inner
                }
            },
        );
        let params = SamplingParams { max_tokens: 8, ..Default::default() };
        let rx = r.submit(vec![256, 1, 2, 3], params).unwrap();
        match rx.recv_timeout(Duration::from_secs(30)).unwrap() {
            Err(SubmitError::WorkerFailed) => {}
            other => panic!("expected WorkerFailed from the crash, got {other:?}"),
        }
        let telem = r.telemetry(0).expect("worker 0 exists");
        assert_eq!(telem.flight.dumps(), 1, "crash must dump the flight ring exactly once");
        assert!(telem.flight.total() > 0, "the doomed incarnation recorded step records");
        // The registry survives the respawn: the ring keeps appending.
        let before = telem.flight.total();
        let params = SamplingParams { max_tokens: 2, ..Default::default() };
        let rx = r.submit(vec![256, 9], params).unwrap();
        rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
        assert!(telem.flight.total() > before, "flight ring froze across respawn");
    }

    #[test]
    fn slo_breach_halves_the_concurrency_limit() {
        // A 25 ms injected step delay against a 2 ms ITL target: every
        // observation window breaches, so the AIMD limit must have
        // decreased from its initial value by completion.
        let aimd = AimdConfig {
            target_itl_s: 0.002,
            initial_limit: 8,
            min_samples: 2,
            ..Default::default()
        };
        let r = Router::new(
            RouterConfig {
                engine: engine_cfg(),
                workers: 1,
                admission: AdmissionConfig { aimd, ..Default::default() },
            },
            |_| {
                Box::new(FaultyBackend::new(
                    tiny_backend(7),
                    FaultPlan::new(1).delay_steps(0, u64::MAX, 25).injector(),
                ))
            },
        );
        let params = SamplingParams { max_tokens: 6, ..Default::default() };
        let rxs: Vec<_> =
            (0..2).map(|i| r.submit(vec![256, i as u32], params).unwrap()).collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
        }
        let snap = r.snapshot(0).unwrap();
        assert!(
            snap.concurrency_limit < 8,
            "limit {} did not decrease under sustained SLO breach",
            snap.concurrency_limit
        );
        assert!(snap.concurrency_limit >= 1, "limit must respect the floor");
    }
}
