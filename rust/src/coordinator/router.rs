//! Request router: the front door over one or more engine workers.
//!
//! Each worker owns an [`Engine`] on its own thread; the router validates
//! requests, assigns global ids, and dispatches to the least-loaded
//! worker (paper §III.C "dynamic load balancing"). Responses flow back
//! over a channel. With `workers == 1` this degenerates to a serialized
//! engine with an async submission API — the configuration every bench
//! uses (determinism), while multi-worker exercises the balancing path.

use super::engine::{Engine, EngineConfig, RequestOutput};
use crate::model::SamplingParams;
use crate::runtime::Backend;
use anyhow::Result;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Router construction parameters.
pub struct RouterConfig {
    pub engine: EngineConfig,
    pub workers: usize,
}

enum WorkerMsg {
    Request { prompt: Vec<u32>, params: SamplingParams, reply: Sender<RequestOutput> },
    Shutdown,
}

struct Worker {
    tx: Sender<WorkerMsg>,
    handle: Option<JoinHandle<()>>,
    /// Requests submitted and not yet completed (load signal).
    inflight: Arc<AtomicUsize>,
}

/// Multi-worker request router.
pub struct Router {
    workers: Vec<Worker>,
    next: AtomicUsize,
}

impl Router {
    /// Spawn `cfg.workers` engines; `make_backend` is called once per
    /// worker (each worker owns its backend + cache).
    pub fn new<F>(cfg: RouterConfig, make_backend: F) -> Router
    where
        F: Fn(usize) -> Box<dyn Backend>,
    {
        assert!(cfg.workers > 0);
        let mut workers = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let backend = make_backend(w);
            let econf = cfg.engine.clone();
            let (tx, rx) = channel::<WorkerMsg>();
            let inflight = Arc::new(AtomicUsize::new(0));
            let inflight_thread = inflight.clone();
            let handle = std::thread::Builder::new()
                .name(format!("engine-worker-{w}"))
                .spawn(move || worker_loop(backend, econf, rx, inflight_thread))
                .expect("spawn engine worker");
            workers.push(Worker { tx, handle: Some(handle), inflight });
        }
        Router { workers, next: AtomicUsize::new(0) }
    }

    /// Submit a request; the returned receiver yields the output when
    /// generation completes.
    pub fn submit(
        &self,
        prompt: Vec<u32>,
        params: SamplingParams,
    ) -> Result<Receiver<RequestOutput>> {
        let (reply, rx) = channel();
        let w = self.pick_worker();
        self.workers[w].inflight.fetch_add(1, Ordering::SeqCst);
        self.workers[w]
            .tx
            .send(WorkerMsg::Request { prompt, params, reply })
            .map_err(|_| anyhow::anyhow!("worker {w} is gone"))?;
        Ok(rx)
    }

    /// Least-loaded worker, round-robin tie-break.
    fn pick_worker(&self) -> usize {
        let start = self.next.fetch_add(1, Ordering::Relaxed) % self.workers.len();
        let mut best = start;
        let mut best_load = usize::MAX;
        for i in 0..self.workers.len() {
            let w = (start + i) % self.workers.len();
            let load = self.workers[w].inflight.load(Ordering::SeqCst);
            if load < best_load {
                best_load = load;
                best = w;
            }
        }
        best
    }

    /// Current total in-flight count.
    pub fn inflight(&self) -> usize {
        self.workers.iter().map(|w| w.inflight.load(Ordering::SeqCst)).sum()
    }

    pub fn num_workers(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.tx.send(WorkerMsg::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

fn worker_loop(
    backend: Box<dyn Backend>,
    econf: EngineConfig,
    rx: Receiver<WorkerMsg>,
    inflight: Arc<AtomicUsize>,
) {
    let mut engine = Engine::new(backend, econf);
    let mut pending: Vec<(u64, Sender<RequestOutput>)> = Vec::new();
    loop {
        // Drain the mailbox (non-blocking while there is engine work;
        // blocking when idle to avoid spinning).
        loop {
            let msg = if engine.has_work() {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(std::sync::mpsc::TryRecvError::Empty) => break,
                    Err(std::sync::mpsc::TryRecvError::Disconnected) => return,
                }
            } else {
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => return,
                }
            };
            match msg {
                WorkerMsg::Request { prompt, params, reply } => {
                    match engine.add_request(prompt, params) {
                        Ok(id) => pending.push((id, reply)),
                        Err(e) => {
                            log::warn!("router: rejecting request: {e}");
                            inflight.fetch_sub(1, Ordering::SeqCst);
                            // Dropping `reply` signals the error to the caller.
                        }
                    }
                }
                WorkerMsg::Shutdown => return,
            }
        }
        engine.step();
        for out in engine.take_outputs() {
            if let Some(pos) = pending.iter().position(|(id, _)| *id == out.id) {
                let (_, reply) = pending.swap_remove(pos);
                inflight.fetch_sub(1, Ordering::SeqCst);
                let _ = reply.send(out);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BucketPolicy;
    use crate::coordinator::scheduler::SchedulerConfig;
    use crate::model::{ModelConfig, ModelWeights, NativeModel};
    use crate::runtime::NativeBackend;

    fn router(workers: usize) -> Router {
        let cfg = RouterConfig {
            engine: EngineConfig {
                num_blocks: 32,
                block_size: 8,
                sched: SchedulerConfig::default(),
                decode_buckets: BucketPolicy::exact(8),
                prefill_chunk: usize::MAX,
                prefix_cache_blocks: 0,
                kv_dtype: crate::kvcache::KvCacheDtype::F32,
                weight_dtype: crate::model::WeightDtype::F32,
            },
            workers,
        };
        Router::new(cfg, |_| {
            let mc = ModelConfig::tiny();
            Box::new(NativeBackend::new(NativeModel::new(ModelWeights::init(&mc, 7))))
        })
    }

    #[test]
    fn single_worker_roundtrip() {
        let r = router(1);
        let params = SamplingParams { max_tokens: 4, ..Default::default() };
        let rx = r.submit(vec![256, 1, 2], params).unwrap();
        let out = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        assert_eq!(out.tokens.len(), 4);
        assert_eq!(r.inflight(), 0);
    }

    #[test]
    fn multi_worker_distributes_and_completes() {
        let r = router(2);
        let params = SamplingParams { max_tokens: 3, ..Default::default() };
        let rxs: Vec<_> =
            (0..6).map(|i| r.submit(vec![256, i as u32], params).unwrap()).collect();
        for rx in rxs {
            let out = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
            assert_eq!(out.tokens.len(), 3);
        }
        assert_eq!(r.inflight(), 0);
    }

    #[test]
    fn oversized_request_drops_reply_channel() {
        let r = router(1);
        let params = SamplingParams { max_tokens: 100_000, ..Default::default() };
        let rx = r.submit(vec![256; 10], params).unwrap();
        // Worker rejects → reply sender dropped → recv errors.
        assert!(rx.recv_timeout(std::time::Duration::from_secs(10)).is_err());
    }
}
