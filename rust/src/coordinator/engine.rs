//! The engine: scheduler decisions → backend execution → sampling.

use super::batcher::BucketPolicy;
use super::metrics::{EngineMetrics, RequestRecord, RunReport};
use super::scheduler::{PrefillChunk, Scheduler, SchedulerConfig, SpillCtx, StepPlan};
use super::sequence::{SeqPhase, Sequence};
use crate::kvcache::spill::{dtype_tag, shape_fingerprint};
use crate::kvcache::{
    BlockAllocator, BlockId, BlockTable, CacheStats, KvCacheDtype, KvStore, PagedKvCache,
    QuantizedPagedKvCache, SpillConfig, SpillStats, SpillTier,
};
use super::admission::SubmitError;
use crate::model::{SamplingParams, WeightDtype};
use crate::obs::{EngineStat, StepPhase, StepRecord, Telemetry, TraceEvent, TraceKind};
use crate::runtime::{Backend, DecodeItem, MixedBatch, PrefillChunkItem};
use std::sync::Arc;
use std::time::Instant;

/// Engine construction parameters.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// KV pool size in blocks (the fixed pre-allocated budget).
    pub num_blocks: usize,
    /// Tokens per block.
    pub block_size: usize,
    pub sched: SchedulerConfig,
    /// Decode batch buckets (exact for native, manifest grid for XLA).
    pub decode_buckets: BucketPolicy,
    /// Max tokens per prefill call (XLA: largest prefill bucket; native:
    /// effectively unlimited). Longer prompts prefill in chunks.
    pub prefill_chunk: usize,
    /// Prefix-cache capacity in blocks (0 = disabled). Paper §III.C
    /// "cache sharing and reuse": finished sequences' full KV blocks are
    /// indexed by token-chain hash; later requests with a matching
    /// prefix adopt them (COW) instead of recomputing. Native backend
    /// only (the XLA artifacts assume fresh sequences).
    pub prefix_cache_blocks: usize,
    /// KV-pool storage dtype: dense f32 or packed 8-bit
    /// ([`KvCacheDtype::Q8`], ~0.26× the pool bytes; native backend
    /// only — see `Backend::supports_quantized_kv`).
    pub kv_dtype: KvCacheDtype,
    /// Weight storage dtype the deployment serves from: dense f32 or a
    /// packed GPTQ/RTN store ([`WeightDtype::Q8`]/`Q4`/`Q3`, native
    /// backend only). The backend owns the actual store; `Engine::new`
    /// checks it against this declaration so config and wiring cannot
    /// drift apart. Packed serving is bit-identical to f32 serving of
    /// the dequantized reconstruction (see ARCHITECTURE.md
    /// "Packed-weight serving"), so flipping this knob on a quantized
    /// artifact never perturbs scheduling or sampling.
    pub weight_dtype: WeightDtype,
    /// Crash-safe disk spill tier for evicted prefix KV
    /// (`kvcache::spill`). **`None` (the default) leaves every
    /// baseline byte-for-byte untouched** — no file IO, no extra
    /// branches taken. When set, blocks evicted by the prefix cache or
    /// the sliding-window sweep are offered to the on-disk store, and
    /// admissions that miss the RAM pool restore them bit-identically
    /// (exact bytes, CRC re-verified). Every tier failure degrades to
    /// recompute-on-miss; an unopenable store logs and serves without
    /// the tier.
    pub spill: Option<SpillConfig>,
}

impl EngineConfig {
    /// Native-backend defaults for a given KV token budget.
    pub fn native(kv_budget_tokens: usize, block_size: usize) -> EngineConfig {
        let num_blocks = kv_budget_tokens.div_ceil(block_size);
        EngineConfig {
            num_blocks,
            block_size,
            sched: SchedulerConfig::default(),
            decode_buckets: BucketPolicy::exact(SchedulerConfig::default().max_decode_batch),
            prefill_chunk: usize::MAX,
            prefix_cache_blocks: 0,
            kv_dtype: KvCacheDtype::F32,
            weight_dtype: WeightDtype::F32,
            spill: None,
        }
    }
}

/// A completed request.
#[derive(Debug, Clone)]
pub struct RequestOutput {
    pub id: u64,
    pub prompt_len: usize,
    pub tokens: Vec<u32>,
    pub latency_s: f64,
    pub ttft_s: f64,
}

/// Single-worker serving engine.
pub struct Engine {
    backend: Box<dyn Backend>,
    cfg: EngineConfig,
    cache: Box<dyn KvStore>,
    alloc: BlockAllocator,
    scheduler: Scheduler,
    pub metrics: EngineMetrics,
    prefix_cache: Option<crate::kvcache::PrefixCache>,
    /// Crash-safe disk tier for evicted prefix KV. `None` unless
    /// `EngineConfig::spill` was set AND the store opened cleanly; every
    /// failure afterwards degrades to recompute-on-miss, never an error
    /// surfaced to requests.
    spill: Option<SpillTier>,
    outputs: Vec<RequestOutput>,
    next_id: u64,
    t0: Instant,
    /// Steps executed by this engine incarnation (flight-record index).
    steps: u64,
    /// Telemetry registry: step-phase histograms, the `EngineMetrics`
    /// mirror, the trace ring and the flight recorder. Shared by `Arc`
    /// so the router's supervisor and the HTTP server read it without
    /// touching the engine — and so it survives a panic unwind.
    telem: Arc<Telemetry>,
    /// Test-only deterministic fault injector (`runtime::fault`);
    /// compiled out of release builds without the `fault-inject`
    /// feature.
    #[cfg(any(test, feature = "fault-inject"))]
    faults: Option<crate::runtime::fault::FaultInjector>,
}

impl Engine {
    pub fn new(backend: Box<dyn Backend>, cfg: EngineConfig) -> Engine {
        Self::with_telemetry(backend, cfg, Arc::new(Telemetry::new()))
    }

    /// [`Engine::new`] with a caller-owned telemetry registry. The
    /// router creates one `Arc<Telemetry>` per worker *outside* the
    /// worker thread and re-attaches it to every engine incarnation, so
    /// step-time histograms and the flight ring survive crash-restarts
    /// (the mirrored `EngineMetrics` scalars reset with the engine, as
    /// they always have).
    pub fn with_telemetry(
        backend: Box<dyn Backend>,
        mut cfg: EngineConfig,
        telem: Arc<Telemetry>,
    ) -> Engine {
        // Mixed-step (interleaved chunked prefill) planning needs a
        // backend whose prefill can resume at a nonzero cache position;
        // otherwise fall back to exclusive whole-prompt planning (the
        // XLA artifacts — see `Backend::supports_mixed_step`).
        cfg.sched.chunked_prefill &= backend.supports_mixed_step();
        let mc = backend.config();
        assert!(
            cfg.kv_dtype == KvCacheDtype::F32 || backend.supports_quantized_kv(),
            "backend '{}' cannot read a {:?} KV cache",
            backend.name(),
            cfg.kv_dtype
        );
        assert!(
            cfg.weight_dtype == backend.weight_dtype(),
            "EngineConfig::weight_dtype is {:?} but backend '{}' serves {:?} weights — \
             build the backend from the matching WeightStore",
            cfg.weight_dtype,
            backend.name(),
            backend.weight_dtype()
        );
        let cache: Box<dyn KvStore> = match cfg.kv_dtype {
            KvCacheDtype::F32 => Box::new(PagedKvCache::new(
                mc.n_layers,
                cfg.num_blocks,
                cfg.block_size,
                mc.n_kv_heads,
                mc.head_dim(),
            )),
            KvCacheDtype::Q8 => Box::new(QuantizedPagedKvCache::new(
                mc.n_layers,
                cfg.num_blocks,
                cfg.block_size,
                mc.n_kv_heads,
                mc.head_dim(),
            )),
        };
        let alloc = BlockAllocator::new(cfg.num_blocks, cfg.block_size);
        let scheduler = Scheduler::new(cfg.sched);
        let prefix_cache = if cfg.prefix_cache_blocks > 0 && backend.supports_offset_prefill() {
            Some(crate::kvcache::PrefixCache::new(cfg.block_size, cfg.prefix_cache_blocks))
        } else {
            None
        };
        // Spill tier: keyed to the exact pool geometry + dtype so a
        // store written by a differently-shaped deployment can never be
        // restored into this pool (records with a foreign fingerprint
        // are skipped at recovery). Open failure downgrades to serving
        // without the tier — never a construction error.
        let spill = cfg.spill.as_ref().and_then(|sc| {
            let fp = shape_fingerprint(&[
                mc.n_layers,
                cfg.block_size,
                mc.n_kv_heads,
                mc.head_dim(),
            ]);
            match SpillTier::open(sc.clone(), dtype_tag(cfg.kv_dtype), fp) {
                Ok(tier) => Some(tier),
                Err(e) => {
                    log::warn!("spill tier unavailable, serving without it: {e}");
                    None
                }
            }
        });
        Engine {
            backend,
            cfg,
            cache,
            alloc,
            scheduler,
            metrics: EngineMetrics::default(),
            prefix_cache,
            spill,
            outputs: Vec::new(),
            next_id: 1,
            t0: Instant::now(),
            steps: 0,
            telem,
            #[cfg(any(test, feature = "fault-inject"))]
            faults: None,
        }
    }

    /// This engine's telemetry registry (shared with the router's
    /// supervisor and the HTTP scrape path).
    pub fn telemetry(&self) -> &Arc<Telemetry> {
        &self.telem
    }

    /// Arm a deterministic fault injector: each `step()` first consults
    /// it and applies the planned fault (panic / latency spike /
    /// admission-visible allocator exhaustion) before any scheduling.
    #[cfg(any(test, feature = "fault-inject"))]
    pub fn arm_faults(&mut self, inj: crate::runtime::fault::FaultInjector) {
        self.faults = Some(inj);
    }

    /// Engine-clock seconds.
    pub fn now(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    /// Engine-clock microseconds (the trace/flight timestamp domain).
    fn now_us(&self) -> u64 {
        self.t0.elapsed().as_micros().min(u64::MAX as u128) as u64
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// KV-pool capacity in tokens.
    pub fn capacity_tokens(&self) -> usize {
        self.cfg.num_blocks * self.cfg.block_size
    }

    /// Enqueue a request; returns its id. Rejections are typed
    /// ([`SubmitError::PromptTooLong`] — every condition here is a
    /// permanent property of request vs deployment, so retrying is
    /// pointless) and flow unchanged through router and server.
    pub fn add_request(
        &mut self,
        prompt: Vec<u32>,
        params: SamplingParams,
    ) -> Result<u64, SubmitError> {
        let id = self.next_id;
        self.add_request_with_id(id, prompt, params)
    }

    /// [`Engine::add_request`] with a caller-assigned id. The router
    /// threads one globally unique request id end to end — client JSON,
    /// error bodies, log lines and trace rings all agree on it even
    /// across workers (each engine's own counter restarts at 1, so
    /// engine-local ids would collide between workers). The id must not
    /// collide with a live sequence; internal assignment continues
    /// after the largest id seen.
    pub fn add_request_with_id(
        &mut self,
        id: u64,
        prompt: Vec<u32>,
        params: SamplingParams,
    ) -> Result<u64, SubmitError> {
        let too_long = |reason: String| SubmitError::PromptTooLong { reason };
        if prompt.is_empty() {
            return Err(too_long("empty prompt".into()));
        }
        let total = prompt.len() + params.max_tokens;
        if total > self.capacity_tokens() {
            return Err(too_long(format!(
                "request needs {total} KV tokens but the pool holds {}",
                self.capacity_tokens()
            )));
        }
        if total > self.backend.config().max_seq {
            return Err(too_long(format!(
                "request length {total} exceeds model max_seq {}",
                self.backend.config().max_seq
            )));
        }
        assert!(self.scheduler.get(id).is_none(), "request id {id} is already live");
        self.next_id = self.next_id.max(id + 1);
        let prompt_len = prompt.len();
        let seq = Sequence::new(id, prompt, params, self.now());
        self.scheduler.add(seq);
        self.telem.traces.record(TraceEvent {
            id,
            t_us: self.now_us(),
            kind: TraceKind::Enqueue,
            detail: prompt_len as u64,
        });
        Ok(id)
    }

    /// Unfinished sequences remain?
    pub fn has_work(&self) -> bool {
        !self.scheduler.is_idle()
    }

    pub fn num_waiting(&self) -> usize {
        self.scheduler.num_waiting()
    }

    pub fn num_running(&self) -> usize {
        self.scheduler.num_running()
    }

    /// Snapshot of a live sequence's progress:
    /// `(phase, generated_tokens, prefill_pos)`. `None` once collected.
    /// Lets tests and benches assert per-step liveness (e.g. "decoders
    /// advance every step while a long prompt prefills").
    pub fn seq_progress(&self, id: u64) -> Option<(SeqPhase, usize, usize)> {
        self.scheduler.get(id).map(|s| (s.phase, s.generated.len(), s.prefill_pos))
    }

    /// Point-in-time cache statistics, including the pool's true byte
    /// footprint (packed bytes for a Q8 cache) and the dense-gather
    /// byte counter (≈ 0: gather is a test/debug dump, not a hot path).
    pub fn cache_stats(&self) -> CacheStats {
        CacheStats::collect(&self.alloc, self.scheduler.live_tables())
            .with_pool_bytes(self.cache.pool_bytes())
            .with_gather_bytes(self.cache.gather_bytes())
    }

    /// Prefix-cache counters (hits, misses, pinned blocks) if enabled.
    pub fn prefix_cache_stats(&self) -> Option<(u64, u64, usize)> {
        self.prefix_cache.as_ref().map(|c| (c.hits, c.misses, c.len()))
    }

    /// True bytes held by the backend's weight store (packed payload +
    /// grids on a quantized store) — the weight-side twin of
    /// `CacheStats::pool_bytes`.
    pub fn weight_bytes(&self) -> usize {
        self.backend.weight_bytes()
    }

    /// KV blocks currently allocated (leak probe for crash-recovery
    /// tests: must return to 0 once all sequences finish).
    pub fn used_blocks(&self) -> usize {
        self.alloc.num_used()
    }

    /// KV blocks currently free.
    pub fn free_blocks(&self) -> usize {
        self.alloc.num_free()
    }

    /// Execute one scheduler step (one mixed prefill+decode batch).
    /// Returns `false` when idle.
    pub fn step(&mut self) -> bool {
        #[cfg(any(test, feature = "fault-inject"))]
        if let Some(inj) = &self.faults {
            let fault = inj.next_step();
            // Exhaustion gates only admission-visible probes; scheduled
            // work is never perturbed (the overload contract).
            self.alloc.set_fault_exhausted(fault.exhaust);
            if fault.delay_ms > 0 {
                std::thread::sleep(std::time::Duration::from_millis(fault.delay_ms));
            }
            if fault.panic {
                panic!("injected fault: engine step panic");
            }
        }
        // Phase spans are stamped HERE, at the coordinator layer —
        // around the plan, the single forward_step call (inside
        // run_mixed), sampling, spill offers and the eviction sweep —
        // never inside kernels (verify.sh grep-gates clock reads off
        // the kernel hot files), so timing cannot perturb bit-identity.
        let t_plan = Instant::now();
        let mut plan = match &mut self.spill {
            Some(tier) if tier.enabled() => {
                let mut ctx = SpillCtx::new(tier, self.cache.as_mut());
                let plan = self.scheduler.plan_with_spill(
                    &mut self.alloc,
                    self.prefix_cache.as_mut(),
                    Some(&mut ctx),
                );
                self.metrics.spill_hit_tokens += ctx.restored_tokens;
                plan
            }
            _ => self.scheduler.plan(&mut self.alloc, self.prefix_cache.as_mut()),
        };
        // Memory-pressure release valve: if the pool is too pinned by the
        // prefix cache to admit anything while work is queued, flush it.
        // Victims go to the spill tier first (their bytes are intact
        // until the allocator reuses the blocks).
        if plan == StepPlan::Idle && self.has_work() {
            if let Some(pc) = &mut self.prefix_cache {
                if !pc.is_empty() {
                    log::debug!("flushing prefix cache under memory pressure");
                    let victims = pc.clear(&mut self.alloc);
                    let t_spill = Instant::now();
                    Self::offer_victims(&mut self.spill, self.cache.as_ref(), &victims);
                    if self.spill.is_some() {
                        self.telem.phase(StepPhase::Spill).observe(t_spill.elapsed());
                    }
                    plan = self.scheduler.plan(&mut self.alloc, None);
                }
            }
        }
        self.telem.phase(StepPhase::Plan).observe(t_plan.elapsed());
        self.trace_plan_events();
        let (worked, prefill_chunks, prefill_tokens, decode_batch) = match plan {
            StepPlan::Mixed { prefill, decode } => {
                let chunks = prefill.len();
                let chunk_tokens = prefill.iter().map(|c| c.len).sum::<usize>();
                let batch = decode.len();
                self.run_mixed(&prefill, &decode);
                (true, chunks, chunk_tokens, batch)
            }
            StepPlan::Idle => (false, 0, 0, 0),
        };
        // Sliding-window eviction sweep: reclaim KV blocks behind every
        // live sequence's window frontier (a no-op under the dense
        // default). Freed blocks are admission-visible headroom by the
        // next plan() call.
        let t_evict = Instant::now();
        let sp = self.backend.config().sparsity;
        match &mut self.spill {
            Some(tier) if tier.enabled() => {
                let mut ctx = SpillCtx::new(tier, self.cache.as_mut());
                self.scheduler.enforce_window_with_spill(&sp, &mut self.alloc, Some(&mut ctx));
            }
            _ => self.scheduler.enforce_window(&sp, &mut self.alloc),
        }
        self.telem.phase(StepPhase::Evict).observe(t_evict.elapsed());
        if let Some(tier) = &self.spill {
            let st = tier.stats();
            self.metrics.spill_bytes = st.bytes_written as usize;
            self.metrics.spill_corrupt_records = st.corrupt_records;
        }
        self.metrics.evicted_blocks = self.scheduler.evicted_blocks;
        self.metrics.preemptions = self.scheduler.preemptions;
        self.metrics.prefix_hit_tokens = self.scheduler.prefix_hit_tokens;
        self.metrics.decode_stall_steps = self.scheduler.decode_stall_steps;
        self.metrics.peak_blocks = self.metrics.peak_blocks.max(self.alloc.num_used());
        self.metrics.gather_bytes = self.cache.gather_bytes();
        self.steps += 1;
        self.telem.flight.record(StepRecord {
            step: self.steps,
            t_us: self.now_us(),
            prefill_chunks: prefill_chunks as u32,
            prefill_tokens: prefill_tokens as u32,
            decode_batch: decode_batch as u32,
            budget_tokens: self.scheduler.config().step_token_budget as u32,
            waiting: self.scheduler.num_waiting() as u32,
            running: self.scheduler.num_running() as u32,
            // Router-side gauges, stamped into the registry by the
            // worker loop before each step; 0 when engine-driven.
            queue_depth: self.telem.get(EngineStat::QueueDepth) as u32,
            aimd_limit: self.metrics.concurrency_limit as u32,
            used_blocks: self.alloc.num_used() as u32,
            free_blocks: self.alloc.num_free() as u32,
        });
        self.mirror_telemetry();
        worked
    }

    /// Turn the scheduler's per-plan admission/preemption/restore lists
    /// into request trace events.
    fn trace_plan_events(&self) {
        let t_us = self.now_us();
        for &(id, start) in &self.scheduler.last_admitted {
            self.telem.traces.record(TraceEvent {
                id,
                t_us,
                kind: TraceKind::Admit,
                detail: start as u64,
            });
        }
        for &(id, tokens) in &self.scheduler.last_restored {
            self.telem.traces.record(TraceEvent {
                id,
                t_us,
                kind: TraceKind::SpillRestore,
                detail: tokens as u64,
            });
        }
        for &id in &self.scheduler.last_preempted {
            self.telem.traces.record(TraceEvent { id, t_us, kind: TraceKind::Preempt, detail: 0 });
        }
    }

    /// Refresh the telemetry registry from the engine's plain counters
    /// — one batch of relaxed stores at the end of each step.
    fn mirror_telemetry(&self) {
        self.metrics.mirror_into(&self.telem);
        if let Some(tier) = &self.spill {
            let st = tier.stats();
            self.telem.set(EngineStat::SpillRecords, st.records as u64);
            self.telem.set(EngineStat::SpillDiskBytes, tier.total_bytes());
            self.telem.set(EngineStat::SpillIoFailures, st.io_failures as u64);
        }
        self.telem.set(
            EngineStat::InflightRequests,
            (self.scheduler.num_waiting() + self.scheduler.num_running()) as u64,
        );
    }

    /// Drive until every queued request completes; returns the run report.
    pub fn run_to_completion(&mut self) -> RunReport {
        while self.step() {}
        self.metrics.report()
    }

    /// Drain finished outputs.
    pub fn take_outputs(&mut self) -> Vec<RequestOutput> {
        std::mem::take(&mut self.outputs)
    }

    /// Execute one mixed step: every planned prefill chunk and decode
    /// token goes to the backend as ONE [`MixedBatch`]. The native
    /// backend streams every weight matrix once per step across both
    /// kinds of rows and fans the per-sequence attention across scoped
    /// workers (`NativeBackend::forward_step`); fan-out outputs are
    /// bit-identical to serial execution, so scheduling, sampling and
    /// the determinism tests are unaffected by the thread count.
    fn run_mixed(&mut self, prefill: &[PrefillChunk], decode: &[u64]) {
        // (Head-of-line stalls — decoders that existed at plan time but
        // did not advance — are counted by the scheduler, which sees the
        // pre-preemption decoding set; `step` mirrors the counter.)
        // Materialize chunk tokens and detach tables so the batch can
        // hold `&mut` to several tables at once.
        let chunk_tokens: Vec<Vec<u32>> = prefill
            .iter()
            .map(|c| self.scheduler.get(c.seq_id).unwrap().replay_range(c.start, c.len))
            .collect();
        let mut chunk_tables: Vec<BlockTable> = prefill
            .iter()
            .map(|c| std::mem::take(&mut self.scheduler.get_mut(c.seq_id).unwrap().table))
            .collect();
        let mut decode_tokens = Vec::with_capacity(decode.len());
        let mut decode_tables = Vec::with_capacity(decode.len());
        for &id in decode {
            let seq = self.scheduler.get_mut(id).unwrap();
            decode_tokens.push(seq.last_token());
            decode_tables.push(std::mem::take(&mut seq.table));
        }
        let mut batch = MixedBatch {
            prefill: chunk_tokens
                .iter()
                .zip(chunk_tables.iter_mut())
                .zip(prefill)
                .map(|((tokens, table), c)| PrefillChunkItem {
                    tokens: tokens.as_slice(),
                    table,
                    want_logits: c.last,
                })
                .collect(),
            decode: decode_tokens
                .iter()
                .zip(decode_tables.iter_mut())
                .map(|(&token, table)| DecodeItem { token, table })
                .collect(),
            prefill_call_cap: self.cfg.prefill_chunk,
        };
        let t_fwd = Instant::now();
        let outs = self.backend.forward_step(&mut batch, &mut self.cache);
        drop(batch);
        // Step-level forward attribution: prefill and decode execute in
        // ONE forward_step call, so the span goes to `prefill` whenever
        // the step carried a chunk (the chunk dominates its cost) and
        // to `decode` only for pure-decode steps — which makes the
        // decode histogram exactly the inter-token-latency-critical
        // number. Documented in ARCHITECTURE.md "Observability
        // contract".
        let fwd_phase = if prefill.is_empty() { StepPhase::Decode } else { StepPhase::Prefill };
        self.telem.phase(fwd_phase).observe(t_fwd.elapsed());
        let t_sample = Instant::now();

        self.metrics.mixed_steps += 1;
        self.metrics.prefill_steps += prefill.len(); // chunks executed
        self.metrics.prefill_chunk_tokens += prefill.iter().map(|c| c.len).sum::<usize>();
        self.metrics.prefill_dequant_tiles += outs.prefill_dequant_tiles;
        self.metrics.skipped_tiles += outs.skipped_tiles;
        if !decode.is_empty() {
            self.metrics.decode_steps += 1;
            self.metrics.decode_batch_tokens += decode.len();
            self.metrics.decode_bucket_tokens += self.cfg.decode_buckets.pad(decode.len());
        }

        let now = self.now();
        let t_us = self.now_us();
        for c in prefill {
            self.telem.traces.record(TraceEvent {
                id: c.seq_id,
                t_us,
                kind: TraceKind::Chunk,
                detail: c.len as u64,
            });
        }
        let mut done = Vec::new();
        // Prefill side: advance cursors; sample on completed prefills.
        for ((c, table), logits) in prefill.iter().zip(chunk_tables).zip(outs.prefill_logits) {
            let seq = self.scheduler.get_mut(c.seq_id).unwrap();
            seq.table = table;
            debug_assert_eq!(seq.prefill_pos, c.start, "chunk resumed off-cursor");
            seq.prefill_pos += c.len;
            debug_assert_eq!(seq.prefill_pos, seq.table.len());
            if c.last {
                debug_assert_eq!(seq.prefill_pos, seq.replay_len());
                let logits = logits.expect("final chunk must return logits");
                let tok = seq.sampler.sample(&logits, &seq.params);
                seq.phase = SeqPhase::Decoding;
                seq.generated.push(tok);
                if seq.t_first_token.is_none() {
                    self.telem.traces.record(TraceEvent {
                        id: c.seq_id,
                        t_us,
                        kind: TraceKind::FirstToken,
                        detail: 0,
                    });
                }
                seq.t_first_token.get_or_insert(now);
                if let Some(prev) = seq.t_last_token {
                    // A replayed (preempted) sequence emitting again:
                    // the stall is a real inter-token gap.
                    self.metrics.record_gap(now - prev);
                }
                seq.t_last_token = Some(now);
                if seq.is_done() {
                    done.push(c.seq_id);
                }
            }
        }
        // Decode side.
        for ((&id, table), logit) in decode.iter().zip(decode_tables).zip(outs.decode_logits) {
            let seq = self.scheduler.get_mut(id).unwrap();
            seq.table = table;
            let tok = seq.sampler.sample(&logit, &seq.params);
            seq.generated.push(tok);
            if seq.t_first_token.is_none() {
                self.telem.traces.record(TraceEvent {
                    id,
                    t_us,
                    kind: TraceKind::FirstToken,
                    detail: 0,
                });
            }
            seq.t_first_token.get_or_insert(now);
            if let Some(prev) = seq.t_last_token {
                self.metrics.record_gap(now - prev);
            }
            seq.t_last_token = Some(now);
            if seq.is_done() {
                done.push(id);
            }
        }
        for id in done {
            self.finish_seq(id);
        }
        // Sample span: everything after the forward — cursor updates,
        // sampling, gap accounting and request finish (which may nest a
        // spill offer; its span is stamped independently).
        self.telem.phase(StepPhase::Sample).observe(t_sample.elapsed());
    }

    fn finish_seq(&mut self, id: u64) {
        let now = self.now();
        self.scheduler.get_mut(id).unwrap().t_finish = Some(now);
        // Index the finished sequence's full KV blocks for prefix reuse
        // before its references are released.
        if let Some(pc) = &mut self.prefix_cache {
            let seq = self.scheduler.get(id).unwrap();
            // A window-evicted table has tombstoned leading blocks: its
            // KV prefix is gone, so it must never seed the prefix cache.
            if seq.table.live_blocks() == seq.table.blocks().len() {
                let in_cache = seq.table.len();
                let toks = seq.replay_tokens();
                let blocks = seq.table.blocks().to_vec();
                let victims = pc.insert(&toks[..in_cache.min(toks.len())], &blocks, &mut self.alloc);
                let t_spill = Instant::now();
                Self::offer_victims(&mut self.spill, self.cache.as_ref(), &victims);
                if self.spill.is_some() {
                    self.telem.phase(StepPhase::Spill).observe(t_spill.elapsed());
                }
            }
        }
        self.scheduler.finish(id, &mut self.alloc);
        let seq = self.scheduler.collect(id).expect("finished sequence must collect");
        self.telem.traces.record(TraceEvent {
            id,
            t_us: self.now_us(),
            kind: TraceKind::Finish,
            detail: seq.generated.len() as u64,
        });
        self.metrics.record_finish(RequestRecord {
            id,
            prompt_tokens: seq.prompt.len(),
            generated_tokens: seq.generated.len(),
            t_enqueue: seq.t_enqueue,
            t_first_token: seq.t_first_token.unwrap_or(now),
            t_finish: now,
        });
        self.outputs.push(RequestOutput {
            id,
            prompt_len: seq.prompt.len(),
            tokens: seq.generated,
            latency_s: now - seq.t_enqueue,
            ttft_s: seq.t_first_token.unwrap_or(now) - seq.t_enqueue,
        });
    }

    /// Offer prefix-cache eviction victims to the spill tier. The
    /// victims' bytes are still intact (the allocator has not reused
    /// the blocks — no `alloc()` happens between eviction and here), so
    /// `export_block` reads exactly the KV that was cached. Every
    /// failure is absorbed by the tier's own degradation ladder.
    fn offer_victims(
        spill: &mut Option<SpillTier>,
        cache: &dyn KvStore,
        victims: &[(u64, BlockId)],
    ) {
        let Some(tier) = spill.as_mut() else { return };
        if !tier.enabled() {
            return;
        }
        for &(hash, block) in victims {
            if tier.contains(hash) {
                continue;
            }
            let payload = cache.export_block(block);
            let _ = tier.offer(hash, &payload);
        }
    }

    /// Flush the spill tier's commit frontier to durable storage — the
    /// graceful-shutdown barrier (router workers call this before
    /// exiting). No-op when the tier is off or already disabled.
    pub fn flush_spill(&mut self) {
        if let Some(tier) = &mut self.spill {
            let _ = tier.flush();
        }
    }

    /// Spill-tier counters, if a tier was configured (it may since have
    /// self-disabled; `SpillStats` still reports what happened).
    pub fn spill_stats(&self) -> Option<SpillStats> {
        self.spill.as_ref().map(|t| t.stats())
    }

    /// Is the spill tier live (configured, opened, and not tripped by
    /// its IO-failure circuit breaker)?
    pub fn spill_enabled(&self) -> bool {
        self.spill.as_ref().is_some_and(|t| t.enabled())
    }

    /// Arm deterministic IO faults on the spill tier (test-only twin of
    /// [`Engine::arm_faults`]). Returns `false` if no tier is open.
    #[cfg(any(test, feature = "fault-inject"))]
    pub fn arm_spill_io_faults(&mut self, inj: crate::runtime::fault::IoFaultInjector) -> bool {
        match &mut self.spill {
            Some(tier) => {
                tier.arm_io_faults(inj);
                true
            }
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelConfig, ModelWeights, NativeModel};
    use crate::runtime::NativeBackend;

    fn engine(num_blocks: usize) -> Engine {
        engine_with_dtype(num_blocks, KvCacheDtype::F32)
    }

    fn engine_with_dtype(num_blocks: usize, kv_dtype: KvCacheDtype) -> Engine {
        let cfg = ModelConfig::tiny();
        let backend = NativeBackend::new(NativeModel::new(ModelWeights::init(&cfg, 1)));
        let econf = EngineConfig {
            num_blocks,
            block_size: 8,
            sched: SchedulerConfig {
                max_running: 8,
                max_decode_batch: 4,
                watermark_blocks: 1,
                ..Default::default()
            },
            decode_buckets: BucketPolicy::exact(4),
            prefill_chunk: usize::MAX,
            prefix_cache_blocks: 0,
            kv_dtype,
            weight_dtype: WeightDtype::F32,
            spill: None,
        };
        Engine::new(Box::new(backend), econf)
    }

    fn params(n: usize) -> SamplingParams {
        SamplingParams { max_tokens: n, ..Default::default() }
    }

    #[test]
    fn single_request_completes() {
        let mut e = engine(32);
        let id = e.add_request(vec![256, 1, 2, 3], params(5)).unwrap();
        let report = e.run_to_completion();
        assert_eq!(report.num_requests, 1);
        let outs = e.take_outputs();
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].id, id);
        assert_eq!(outs[0].tokens.len(), 5);
        assert!(outs[0].ttft_s <= outs[0].latency_s);
        // All blocks returned.
        assert_eq!(e.alloc.num_used(), 0);
    }

    #[test]
    fn telemetry_stamps_phases_traces_and_flight() {
        use crate::obs::{EngineStat, StepPhase, TraceKind};
        let mut e = engine(32);
        let id = e.add_request(vec![256, 1, 2, 3], params(5)).unwrap();
        e.run_to_completion();
        let t = e.telemetry().clone();
        // Phase histograms: every step stamps plan + evict; the forward
        // span lands in prefill (chunk-carrying step) or decode.
        assert!(t.phase(StepPhase::Plan).count() > 0, "plan spans stamped");
        assert!(t.phase(StepPhase::Evict).count() > 0, "evict spans stamped");
        assert!(t.phase(StepPhase::Prefill).count() >= 1, "prefill forward span");
        assert!(t.phase(StepPhase::Decode).count() >= 1, "decode-only forward spans");
        assert!(t.phase(StepPhase::Sample).count() > 0, "sample spans stamped");
        // No spill tier armed: the spill phase must stay untouched.
        assert_eq!(t.phase(StepPhase::Spill).count(), 0);
        // Trace ring: the request's whole life is spanned.
        let evs = t.traces.events_for(id);
        let kinds: Vec<TraceKind> = evs.iter().map(|e| e.kind).collect();
        assert_eq!(kinds.first(), Some(&TraceKind::Enqueue));
        assert!(kinds.contains(&TraceKind::Admit));
        assert!(kinds.contains(&TraceKind::Chunk));
        assert!(kinds.contains(&TraceKind::FirstToken));
        assert_eq!(kinds.last(), Some(&TraceKind::Finish));
        for w in evs.windows(2) {
            assert!(w[0].t_us <= w[1].t_us, "trace events in time order");
        }
        // Flight ring: one record per step, mirrored counters fresh.
        assert_eq!(t.flight.total(), e.metrics.mixed_steps as u64 + 1, "one record per step (incl. final idle)");
        assert_eq!(t.get(EngineStat::RequestsCompleted), 1);
        assert_eq!(t.get(EngineStat::MixedSteps), e.metrics.mixed_steps as u64);
        // Default config: every sparse/spill counter stays 0.
        for s in [
            EngineStat::SkippedTiles,
            EngineStat::EvictedBlocks,
            EngineStat::SpillHitTokens,
            EngineStat::SpillBytes,
            EngineStat::SpillCorruptRecords,
            EngineStat::GatherBytes,
        ] {
            assert_eq!(t.get(s), 0, "{s:?} must stay 0 on the dense default");
        }
    }

    #[test]
    fn caller_assigned_ids_thread_through() {
        let mut e = engine(32);
        let id = e.add_request_with_id(41, vec![256, 1, 2], params(3)).unwrap();
        assert_eq!(id, 41);
        // Internal assignment continues after the largest id seen.
        let id2 = e.add_request(vec![256, 4, 5], params(3)).unwrap();
        assert_eq!(id2, 42);
        e.run_to_completion();
        let outs = e.take_outputs();
        assert_eq!(outs.len(), 2);
        assert!(outs.iter().any(|o| o.id == 41));
        assert!(outs.iter().any(|o| o.id == 42));
    }

    #[test]
    fn batch_of_requests_all_complete() {
        let mut e = engine(64);
        let mut ids = Vec::new();
        for i in 0..6 {
            ids.push(e.add_request(vec![256, i as u32, 2], params(4)).unwrap());
        }
        let report = e.run_to_completion();
        assert_eq!(report.num_requests, 6);
        let outs = e.take_outputs();
        assert_eq!(outs.len(), 6);
        for o in &outs {
            assert_eq!(o.tokens.len(), 4);
        }
        // Continuous batching actually batched decodes.
        assert!(e.metrics.mean_decode_batch() > 1.0, "batch occupancy {}", e.metrics.mean_decode_batch());
    }

    #[test]
    fn deterministic_outputs_across_runs() {
        let run = || {
            let mut e = engine(64);
            for i in 0..3 {
                e.add_request(vec![256, 40 + i, 41], params(6)).unwrap();
            }
            e.run_to_completion();
            let mut outs = e.take_outputs();
            outs.sort_by_key(|o| o.id);
            outs.iter().map(|o| o.tokens.clone()).collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn memory_pressure_preempts_but_completes() {
        // Pool of 8 blocks × 8 slots = 64 KV tokens; 4 requests needing
        // ~14 tokens each admit ~3-wide, with pressure as they grow.
        let mut e = engine(8);
        for i in 0..4 {
            e.add_request(vec![256; 6 + i], params(8)).unwrap();
        }
        let report = e.run_to_completion();
        assert_eq!(report.num_requests, 4);
        assert_eq!(e.take_outputs().len(), 4);
        assert_eq!(e.alloc.num_used(), 0, "all blocks must be released");
    }

    #[test]
    fn quantized_kv_engine_completes_with_smaller_pool() {
        let mut q = engine_with_dtype(32, KvCacheDtype::Q8);
        let mut f = engine_with_dtype(32, KvCacheDtype::F32);
        for e in [&mut q, &mut f] {
            for i in 0..3 {
                e.add_request(vec![256, 10 + i, 11], params(5)).unwrap();
            }
            let report = e.run_to_completion();
            assert_eq!(report.num_requests, 3);
            let outs = e.take_outputs();
            assert_eq!(outs.len(), 3);
            for o in &outs {
                assert_eq!(o.tokens.len(), 5);
            }
        }
        // CacheStats reports true packed bytes: the q8 pool must be ≤
        // 0.3× the f32 pool at identical capacity.
        let (qb, fb) = (q.cache_stats().pool_bytes, f.cache_stats().pool_bytes);
        assert!(qb > 0 && fb > 0);
        assert!(10 * qb <= 3 * fb, "q8 pool {qb} vs f32 pool {fb}");
    }

    #[test]
    #[should_panic(expected = "weight_dtype")]
    fn engine_rejects_weight_dtype_mismatch() {
        // A deployment declaring packed weights must not silently run a
        // dense backend (and vice versa) — the constructor assert is the
        // drift guard.
        let cfg = ModelConfig::tiny();
        let backend = NativeBackend::new(NativeModel::new(ModelWeights::init(&cfg, 1)));
        let mut econf = EngineConfig::native(256, 8);
        econf.weight_dtype = WeightDtype::Q4;
        let _ = Engine::new(Box::new(backend), econf);
    }

    #[test]
    fn packed_weight_engine_serves_and_reports_bytes() {
        // EngineConfig::weight_dtype = Q4 over a matching packed backend:
        // requests complete and the reported weight bytes shrink vs the
        // dense twin. (Bit-identity vs the reconstruction is enforced in
        // tests/weights_parity.rs.)
        use crate::model::weights::{quantize_weights_packed, QuantMethod};
        let cfg = ModelConfig::tiny();
        let weights = ModelWeights::init(&cfg, 1);
        let dense_bytes = {
            let mut e = engine(32);
            e.add_request(vec![256, 1, 2, 3], params(4)).unwrap();
            e.run_to_completion();
            e.weight_bytes()
        };
        let (packed, _) =
            quantize_weights_packed(&weights, QuantMethod::Rtn, 4, 64, false, &[], &[], &[]);
        let backend = NativeBackend::new(crate::model::NativeModel::from_store(
            std::sync::Arc::new(packed),
        ));
        let mut econf = EngineConfig::native(256, 8);
        econf.weight_dtype = WeightDtype::Q4;
        let mut e = Engine::new(Box::new(backend), econf);
        e.add_request(vec![256, 1, 2, 3], params(4)).unwrap();
        let r = e.run_to_completion();
        assert_eq!(r.num_requests, 1);
        assert_eq!(e.take_outputs()[0].tokens.len(), 4);
        assert!(
            e.weight_bytes() < dense_bytes,
            "packed {} !< dense {}",
            e.weight_bytes(),
            dense_bytes
        );
    }

    #[test]
    fn rejects_oversized_request() {
        let mut e = engine(4); // 32-token pool
        assert!(e.add_request(vec![256; 30], params(10)).is_err());
        assert!(e.add_request(vec![], params(1)).is_err());
    }

    fn engine_with_prefix_cache(num_blocks: usize, cache_blocks: usize) -> Engine {
        let cfg = ModelConfig::tiny();
        let backend = NativeBackend::new(NativeModel::new(ModelWeights::init(&cfg, 1)));
        let econf = EngineConfig {
            num_blocks,
            block_size: 8,
            sched: SchedulerConfig {
                max_running: 8,
                max_decode_batch: 4,
                watermark_blocks: 1,
                ..Default::default()
            },
            decode_buckets: BucketPolicy::exact(4),
            prefill_chunk: usize::MAX,
            prefix_cache_blocks: cache_blocks,
            kv_dtype: KvCacheDtype::F32,
            weight_dtype: WeightDtype::F32,
            spill: None,
        };
        Engine::new(Box::new(backend), econf)
    }

    #[test]
    fn prefix_cache_reuses_blocks_with_identical_outputs() {
        // Same prompt served twice: the second request must hit the
        // prefix cache AND produce the same greedy tokens as a
        // cache-disabled engine.
        let prompt: Vec<u32> = (0..20).map(|i| 256 - 0 * i + (i % 100)).collect();
        let run = |cache_blocks: usize| {
            let mut e = engine_with_prefix_cache(48, cache_blocks);
            let p = params(5);
            e.add_request(prompt.clone(), p).unwrap();
            e.run_to_completion();
            let first = e.take_outputs().pop().unwrap().tokens;
            e.add_request(prompt.clone(), p).unwrap();
            e.run_to_completion();
            let second = e.take_outputs().pop().unwrap().tokens;
            (first, second, e.metrics.prefix_hit_tokens, e.prefix_cache_stats())
        };
        let (f_off, s_off, hits_off, stats_off) = run(0);
        let (f_on, s_on, hits_on, stats_on) = run(16);
        assert!(stats_off.is_none());
        assert_eq!(hits_off, 0);
        // 20-token prompt → 2 full 8-slot blocks reusable.
        assert_eq!(hits_on, 16, "second request must adopt 2 blocks");
        let (h, _m, pinned) = stats_on.unwrap();
        assert!(h >= 2 && pinned > 0);
        // Numerics unaffected by reuse.
        assert_eq!(f_on, f_off);
        assert_eq!(s_on, s_off);
        assert_eq!(f_on, s_on, "same prompt, greedy → same generation");
    }

    #[test]
    fn prefix_cache_flushes_under_memory_pressure() {
        // A cache allowed to pin most of a small pool must not deadlock
        // admission: the engine flushes it and completes the work.
        let mut e = engine_with_prefix_cache(8, 6);
        let p = params(4);
        e.add_request(vec![256; 24], p).unwrap();
        e.run_to_completion();
        assert_eq!(e.take_outputs().len(), 1);
        // Pool now heavily pinned by the cache; a big request must still go.
        e.add_request(vec![300; 40], p).unwrap();
        let r = e.run_to_completion();
        assert_eq!(r.num_requests, 2);
        assert_eq!(e.take_outputs().len(), 1);
    }

    #[test]
    fn metrics_report_is_populated() {
        let mut e = engine(32);
        e.add_request(vec![256, 5, 6, 7], params(3)).unwrap();
        e.add_request(vec![256, 8], params(3)).unwrap();
        let r = e.run_to_completion();
        assert!(r.latency_s > 0.0);
        assert!(r.all_tok_per_s > 0.0);
        assert!(r.gen_tok_per_s > 0.0);
        assert!(r.gen_tok_per_s < r.all_tok_per_s);
        assert!(e.metrics.prefill_steps >= 2, "one chunk per prompt at least");
        assert!(e.metrics.decode_steps >= 2);
        assert!(e.metrics.mixed_steps >= 2);
        assert_eq!(e.metrics.prefill_chunk_tokens, 4 + 2);
        assert!(r.ttft_p95_s >= r.ttft_p50_s);
        // 2 requests × 3 tokens → 4 recorded inter-token gaps.
        assert_eq!(e.metrics.inter_token_gaps.len(), 4);
        assert!(r.mean_inter_token_s >= 0.0);
        // The paged-native prefill contract, observable: nothing on the
        // serving path materialized a dense KV copy, and the f32 cache
        // dequantized no tiles.
        assert_eq!(r.gather_bytes, 0, "dense gather crept onto the hot path");
        assert_eq!(e.cache_stats().gather_bytes, 0);
        assert_eq!(r.prefill_dequant_tiles, 0, "f32 cache has nothing to dequantize");
        // The dense-default sparsity contract, observable end to end:
        // no tile was score-skipped and no block was window-evicted.
        assert_eq!(r.skipped_tiles, 0, "dense default must never skip a tile");
        assert_eq!(r.evicted_blocks, 0, "dense default must never evict a block");
    }

    /// The sliding-window memory claim end to end: a windowed engine
    /// reclaims out-of-window KV blocks while the sequence still
    /// decodes, so its live-block peak plateaus well below the dense
    /// footprint of the same request.
    #[test]
    fn windowed_engine_evicts_blocks_and_pool_plateaus() {
        use crate::attention::SparsityConfig;
        let mut mc = ModelConfig::tiny();
        mc.sparsity = SparsityConfig::windowed(2, 1);
        let backend = NativeBackend::new(NativeModel::new(ModelWeights::init(&mc, 1)));
        let mut econf = EngineConfig::native(256, 8);
        econf.sched.watermark_blocks = 1;
        let mut e = Engine::new(Box::new(backend), econf);
        // 20 prompt + 30 generated = 50 tokens → 7 dense blocks; the
        // window holds sink(1) + window(2) + the growth block.
        e.add_request(vec![256; 20], params(30)).unwrap();
        let mut peak_live = 0usize;
        while e.step() {
            peak_live = peak_live.max(e.used_blocks());
        }
        let r = e.metrics.report();
        assert!(r.evicted_blocks > 0, "window must reclaim trailing blocks");
        assert!(
            peak_live <= 4,
            "windowed pool peaked at {peak_live} blocks, expected plateau ≤ 4 (dense needs 7)"
        );
        assert_eq!(e.used_blocks(), 0, "all blocks released at completion");
        let outs = e.take_outputs();
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].tokens.len(), 30, "eviction must not end generation early");
    }

    #[test]
    fn q8_engine_counts_prefill_dequant_tiles_and_stays_gather_free() {
        let mut e = engine_with_dtype(32, KvCacheDtype::Q8);
        e.add_request(vec![256; 20], params(3)).unwrap();
        let r = e.run_to_completion();
        assert_eq!(r.num_requests, 1);
        assert_eq!(r.gather_bytes, 0, "q8 prefill must stream, not gather");
        // 20 prompt tokens over 8-slot blocks: the streamed prefill
        // dequantized at least ⌈20/8⌉ tiles per layer.
        let min_tiles = 20usize.div_ceil(8) * e.backend.config().n_layers;
        assert!(
            r.prefill_dequant_tiles >= min_tiles,
            "tiles {} < {min_tiles}",
            r.prefill_dequant_tiles
        );
    }

    /// The bit-exactness anchor for the whole refactor: interleaved
    /// token-budget mixed steps must produce the same tokens as the
    /// step-serial exclusive planner (whole prefill XOR decode per
    /// step), for every request, at any budget.
    #[test]
    fn mixed_interleaving_matches_exclusive_reference() {
        let run = |chunked: bool, budget: usize| {
            let cfg = ModelConfig::tiny();
            let backend = NativeBackend::new(NativeModel::new(ModelWeights::init(&cfg, 1)));
            let econf = EngineConfig {
                num_blocks: 48,
                block_size: 8,
                sched: SchedulerConfig {
                    max_running: 8,
                    max_decode_batch: 4,
                    watermark_blocks: 1,
                    step_token_budget: budget,
                    chunked_prefill: chunked,
                },
                decode_buckets: BucketPolicy::exact(4),
                prefill_chunk: usize::MAX,
                prefix_cache_blocks: 0,
                kv_dtype: KvCacheDtype::F32,
                weight_dtype: WeightDtype::F32,
                spill: None,
            };
            let mut e = Engine::new(Box::new(backend), econf);
            // A long prompt among short ones so chunking really happens.
            e.add_request(vec![256; 40], params(6)).unwrap();
            for i in 0..3 {
                e.add_request(vec![256, 30 + i, 31], params(6)).unwrap();
            }
            e.run_to_completion();
            let mut outs = e.take_outputs();
            outs.sort_by_key(|o| o.id);
            outs.iter().map(|o| o.tokens.clone()).collect::<Vec<_>>()
        };
        let reference = run(false, 256);
        // Budgets small enough to force multi-step prefill + interleave.
        assert_eq!(run(true, 8), reference, "budget 8 diverged");
        assert_eq!(run(true, 16), reference, "budget 16 diverged");
        assert_eq!(run(true, 256), reference, "budget 256 diverged");
    }

    /// The head-of-line claim: a long prompt injected mid-decode must
    /// not stall decoding sequences — they advance every engine step
    /// while the prompt prefills chunk by chunk.
    #[test]
    fn long_prefill_never_stalls_decode() {
        let cfg = ModelConfig::tiny();
        let backend = NativeBackend::new(NativeModel::new(ModelWeights::init(&cfg, 2)));
        let econf = EngineConfig {
            num_blocks: 64,
            block_size: 8,
            sched: SchedulerConfig {
                max_running: 8,
                max_decode_batch: 4,
                watermark_blocks: 1,
                step_token_budget: 12,
                chunked_prefill: true,
            },
            decode_buckets: BucketPolicy::exact(4),
            prefill_chunk: usize::MAX,
            prefix_cache_blocks: 0,
            kv_dtype: KvCacheDtype::F32,
            weight_dtype: WeightDtype::F32,
            spill: None,
        };
        let mut e = Engine::new(Box::new(backend), econf);
        let d1 = e.add_request(vec![256, 1, 2], params(40)).unwrap();
        let d2 = e.add_request(vec![256, 3], params(40)).unwrap();
        // Get both decoding.
        while e.seq_progress(d1).unwrap().0 != SeqPhase::Decoding
            || e.seq_progress(d2).unwrap().0 != SeqPhase::Decoding
        {
            assert!(e.step());
        }
        // Inject a 50-token prompt: needs ⌈50/11⌉ = 5+ chunked steps at
        // budget 12 with 2 decode tokens reserved per step.
        let long = e.add_request(vec![256; 50], params(4)).unwrap();
        let mut prefill_steps_seen = 0;
        while e.seq_progress(long).unwrap().0 != SeqPhase::Decoding {
            let g1 = e.seq_progress(d1).unwrap().1;
            let g2 = e.seq_progress(d2).unwrap().1;
            let pf = e.seq_progress(long).unwrap().2;
            assert!(e.step());
            assert_eq!(e.seq_progress(d1).unwrap().1, g1 + 1, "d1 stalled behind prefill");
            assert_eq!(e.seq_progress(d2).unwrap().1, g2 + 1, "d2 stalled behind prefill");
            assert!(e.seq_progress(long).unwrap().2 > pf, "prefill made no progress");
            prefill_steps_seen += 1;
        }
        assert!(prefill_steps_seen >= 5, "budget must split the prompt ({prefill_steps_seen})");
        assert_eq!(e.metrics.decode_stall_steps, 0);
        let r = e.run_to_completion();
        assert_eq!(r.num_requests, 3);
        assert_eq!(r.decode_stall_steps, 0);
    }

    #[test]
    fn fault_exhaustion_blocks_admission_then_recovers() {
        use crate::runtime::FaultPlan;
        let mut e = engine(32);
        // Steps [0, 3) report an exhausted pool to admission probes.
        e.arm_faults(FaultPlan::new(1).exhaust_steps(0, 3).injector());
        e.add_request(vec![256, 1, 2], params(4)).unwrap();
        // While exhaustion is armed the scheduler cannot admit: the
        // request stays waiting and steps report idle.
        for _ in 0..3 {
            assert!(!e.step(), "no work should be schedulable under exhaustion");
            assert_eq!(e.num_waiting(), 1);
            assert_eq!(e.num_running(), 0);
        }
        // Fault window over: the same request admits and completes.
        let r = e.run_to_completion();
        assert_eq!(r.num_requests, 1);
        assert_eq!(e.take_outputs().len(), 1);
        assert_eq!(e.used_blocks(), 0);
        assert_eq!(e.free_blocks(), 32, "probes must recover after the fault window");
    }

    #[test]
    fn fault_delay_inflates_observed_inter_token_latency() {
        use crate::runtime::FaultPlan;
        let run = |delay_ms: u64| {
            let mut e = engine(32);
            e.arm_faults(FaultPlan::new(1).delay_steps(0, u64::MAX, delay_ms).injector());
            e.add_request(vec![256, 1, 2], params(6)).unwrap();
            e.run_to_completion();
            let (n, sum) = e.metrics.inter_token_totals();
            assert!(n > 0);
            sum / n as f64
        };
        let (fast, slow) = (run(0), run(15));
        assert!(
            slow > fast + 0.010,
            "15 ms injected step delay must dominate ITL: fast {fast} slow {slow}"
        );
    }

    #[test]
    #[should_panic(expected = "injected fault: engine step panic")]
    fn fault_panic_unwinds_out_of_step() {
        use crate::runtime::FaultPlan;
        let mut e = engine(32);
        e.arm_faults(FaultPlan::new(1).panic_at_step(1).injector());
        e.add_request(vec![256, 1], params(4)).unwrap();
        e.step(); // step 0: clean
        e.step(); // step 1: unwinds (what router supervision catches)
    }

    /// Preemption + re-admission under the mixed planner: the tight run
    /// must actually preempt, replay deterministically (identical
    /// outputs across reruns — recompute replays don't depend on
    /// wall-clock), complete every request at full length, and leak
    /// nothing. (Replays go through the prefill tile schedule, so
    /// token-exactness vs a pressure-free run is NOT a contract — only
    /// determinism is.)
    #[test]
    fn preemption_under_mixed_planner_is_deterministic_and_complete() {
        let run = |num_blocks: usize| {
            let mut e = engine(num_blocks);
            for i in 0..4 {
                e.add_request(vec![256; 6 + i], params(8)).unwrap();
            }
            e.run_to_completion();
            let mut outs = e.take_outputs();
            outs.sort_by_key(|o| o.id);
            (
                outs.iter().map(|o| o.tokens.clone()).collect::<Vec<_>>(),
                e.metrics.preemptions,
                e.alloc.num_used(),
            )
        };
        let (roomy_tokens, roomy_preempt, _) = run(64);
        assert_eq!(roomy_preempt, 0, "roomy pool must not preempt");
        let (tight_tokens, tight_preempt, used) = run(8);
        assert!(tight_preempt > 0, "tight pool must exercise preemption");
        assert_eq!(used, 0, "all blocks released");
        for toks in &tight_tokens {
            assert_eq!(toks.len(), 8, "every request runs to max_tokens");
        }
        assert_eq!(tight_tokens.len(), roomy_tokens.len());
        let (tight_again, preempt_again, _) = run(8);
        assert_eq!(tight_again, tight_tokens, "preempted schedule must be deterministic");
        assert_eq!(preempt_again, tight_preempt);
    }

    // ---- spill tier (ARCHITECTURE.md "Spill & recovery contract") ----

    fn spill_dir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("opt_gptq_engine_spill_{name}"));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    /// Spill-enabled engine: small prefix cache (so inserts evict to
    /// disk quickly) over a roomy pool, tier rooted at `dir`.
    fn engine_with_spill(
        kv_dtype: KvCacheDtype,
        threads: usize,
        dir: &std::path::Path,
    ) -> Engine {
        let cfg = ModelConfig::tiny();
        let backend = NativeBackend::new(NativeModel::new(ModelWeights::init(&cfg, 1)))
            .with_decode_threads(threads);
        let econf = EngineConfig {
            num_blocks: 48,
            block_size: 8,
            sched: SchedulerConfig {
                max_running: 8,
                max_decode_batch: 4,
                watermark_blocks: 1,
                ..Default::default()
            },
            decode_buckets: BucketPolicy::exact(4),
            prefill_chunk: usize::MAX,
            prefix_cache_blocks: 2,
            kv_dtype,
            weight_dtype: WeightDtype::F32,
            spill: Some(crate::kvcache::SpillConfig::new(dir)),
        };
        Engine::new(Box::new(backend), econf)
    }

    fn prompt_a() -> Vec<u32> {
        (0..20).map(|i| 256 + (i % 100)).collect()
    }

    fn prompt_b() -> Vec<u32> {
        (0..20).map(|i| 300 + (i % 90)).collect()
    }

    /// One request served to completion; returns its tokens.
    fn serve(e: &mut Engine, prompt: Vec<u32>) -> Vec<u32> {
        e.add_request(prompt, params(5)).unwrap();
        e.run_to_completion();
        e.take_outputs().pop().unwrap().tokens
    }

    #[test]
    fn spill_disabled_by_default_and_counters_stay_zero() {
        let mut e = engine(32);
        assert!(!e.spill_enabled());
        assert!(e.spill_stats().is_none());
        e.add_request(vec![256, 1, 2, 3], params(5)).unwrap();
        let r = e.run_to_completion();
        assert_eq!(r.spill_hit_tokens, 0, "default config must never touch a spill tier");
        assert_eq!(r.spill_bytes, 0);
        assert_eq!(r.spill_corrupt_records, 0);
    }

    /// The restore-correctness anchor: a prompt whose prefix KV was
    /// evicted to disk and restored must generate the SAME tokens as a
    /// plain recompute engine — for both cache dtypes and across
    /// attention thread widths (restored KV is exact bytes, not a
    /// requantization).
    #[test]
    fn spill_restore_is_bit_identical_across_dtypes_and_thread_widths() {
        for kv_dtype in [KvCacheDtype::F32, KvCacheDtype::Q8] {
            // Baseline: no prefix cache, no spill — plain recompute.
            let baseline = serve(&mut engine_with_dtype(48, kv_dtype), prompt_a());
            for threads in [1usize, 4] {
                let dir = spill_dir(&format!("roundtrip_{kv_dtype:?}_{threads}"));
                let mut e = engine_with_spill(kv_dtype, threads, &dir);
                // A seeds the 2-block prefix cache (its own tail insert
                // already spills one block); B's insert evicts the rest
                // of A's blocks to disk; A again misses RAM and must
                // restore from the tier.
                let first = serve(&mut e, prompt_a());
                let _ = serve(&mut e, prompt_b());
                let st = e.spill_stats().unwrap();
                assert!(st.records >= 2, "eviction must have spilled A's blocks, got {st:?}");
                let again = serve(&mut e, prompt_a());
                let r = e.metrics.report();
                assert!(
                    r.spill_hit_tokens >= 16,
                    "A's 2 leading blocks must restore from disk (hit {} tokens)",
                    r.spill_hit_tokens
                );
                assert!(r.spill_bytes > 0);
                assert_eq!(r.spill_corrupt_records, 0);
                assert_eq!(r.decode_stall_steps, 0);
                assert_eq!(first, baseline);
                assert_eq!(
                    again, baseline,
                    "disk-restored KV diverged from recompute ({kv_dtype:?}, {threads} threads)"
                );
                let _ = std::fs::remove_dir_all(&dir);
            }
        }
    }

    /// Torn-record degradation: a bit flip on the restore read
    /// quarantines the record and the request silently recomputes —
    /// same tokens, no stall, tier still live.
    #[test]
    fn spill_corrupt_read_quarantines_and_serving_recomputes() {
        use crate::runtime::fault::IoFaultPlan;
        let baseline = serve(&mut engine_with_dtype(48, KvCacheDtype::F32), prompt_a());
        let dir = spill_dir("corrupt");
        let mut e = engine_with_spill(KvCacheDtype::F32, 0, &dir);
        let _ = serve(&mut e, prompt_a());
        let _ = serve(&mut e, prompt_b());
        assert!(e.arm_spill_io_faults(IoFaultPlan::new(11).corrupt_read_bit(0).injector()));
        let again = serve(&mut e, prompt_a());
        let r = e.metrics.report();
        assert_eq!(again, baseline, "recompute fallback must be invisible in the tokens");
        assert!(r.spill_corrupt_records >= 1, "flipped bit must be counted: {r:?}");
        assert_eq!(r.spill_hit_tokens, 0, "a failed first-block restore adopts nothing");
        assert_eq!(r.decode_stall_steps, 0);
        assert!(e.spill_enabled(), "corruption quarantines records, not the tier");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Kill-mid-write: a short write disables the tier (torn tail left
    /// for recovery), serving continues undisturbed, and a NEW engine
    /// over the same directory recovers the store and serves restores.
    #[test]
    fn spill_short_write_disables_tier_and_reopen_recovers() {
        use crate::runtime::fault::IoFaultPlan;
        let baseline = serve(&mut engine_with_dtype(48, KvCacheDtype::F32), prompt_a());
        let dir = spill_dir("kill");
        {
            let mut e = engine_with_spill(KvCacheDtype::F32, 0, &dir);
            assert!(e.arm_spill_io_faults(IoFaultPlan::new(42).short_write_at(0).injector()));
            // First eviction offer is killed mid-record.
            let first = serve(&mut e, prompt_a());
            let second = serve(&mut e, prompt_b());
            assert!(!e.spill_enabled(), "kill-model short write must trip the tier off");
            assert_eq!(first, baseline);
            assert_eq!(second.len(), 5, "serving must continue with the tier down");
            assert_eq!(e.metrics.report().decode_stall_steps, 0);
        }
        // Reopen: recovery truncates the torn tail; the tier is live
        // again and a full evict/restore cycle works on the same files.
        let mut e = engine_with_spill(KvCacheDtype::F32, 0, &dir);
        assert!(e.spill_enabled(), "recovery must reopen a store with a torn tail");
        let _ = serve(&mut e, prompt_a());
        let _ = serve(&mut e, prompt_b());
        let again = serve(&mut e, prompt_a());
        assert_eq!(again, baseline);
        assert!(e.metrics.report().spill_hit_tokens >= 16);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// An unopenable store (path occupied by a regular file) degrades
    /// to serving without the tier — never a construction failure.
    #[test]
    fn spill_open_failure_degrades_to_serving_without_tier() {
        let dir = spill_dir("openfail");
        std::fs::write(&dir, b"not a directory").unwrap();
        let mut e = engine_with_spill(KvCacheDtype::F32, 0, &dir);
        assert!(e.spill_stats().is_none(), "tier must be absent, not broken");
        let toks = serve(&mut e, prompt_a());
        assert_eq!(toks.len(), 5);
        let r = e.metrics.report();
        assert_eq!(r.spill_hit_tokens, 0);
        assert_eq!(r.spill_bytes, 0);
        let _ = std::fs::remove_file(&dir);
    }
}
