//! Continuous-batching scheduler with KV-memory admission control.
//!
//! Policy (vLLM-style, per the paper's §III.C scheduling description):
//! 1. **Prefill priority**: if a waiting sequence fits in the block pool
//!    (its whole prompt + watermark), admit it and run its prefill this
//!    step — keeps the decode batch full.
//! 2. Otherwise **decode** every running sequence (round-robin capped at
//!    `max_decode_batch`), growing each sequence's block table by one
//!    slot; on allocation failure, **preempt** the youngest running
//!    sequence (recompute-style: free its blocks, re-queue it) until the
//!    step fits.

use super::sequence::{SeqPhase, Sequence};
use crate::kvcache::BlockAllocator;
use std::collections::{BTreeMap, VecDeque};

/// Scheduler tunables.
#[derive(Debug, Clone, Copy)]
pub struct SchedulerConfig {
    /// Max sequences decoding concurrently.
    pub max_running: usize,
    /// Max sequences per decode step (backend bucket cap).
    pub max_decode_batch: usize,
    /// Blocks kept free as headroom when admitting prompts.
    pub watermark_blocks: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig { max_running: 64, max_decode_batch: 8, watermark_blocks: 2 }
    }
}

/// One engine step's work.
#[derive(Debug, Clone, PartialEq)]
pub enum StepPlan {
    /// Run this sequence's prompt (or recompute replay) through prefill.
    Prefill { seq_id: u64 },
    /// Decode one token for each of these sequences (slots reserved).
    Decode { seq_ids: Vec<u64> },
    /// Nothing runnable (all queues empty).
    Idle,
}

/// Sequence store + scheduling policy.
pub struct Scheduler {
    cfg: SchedulerConfig,
    seqs: BTreeMap<u64, Sequence>,
    waiting: VecDeque<u64>,
    running: Vec<u64>,
    rr_cursor: usize,
    /// Total preemptions (engine copies into metrics).
    pub preemptions: usize,
}

impl Scheduler {
    pub fn new(cfg: SchedulerConfig) -> Scheduler {
        Scheduler {
            cfg,
            seqs: BTreeMap::new(),
            waiting: VecDeque::new(),
            running: Vec::new(),
            rr_cursor: 0,
            preemptions: 0,
        }
    }

    pub fn config(&self) -> &SchedulerConfig {
        &self.cfg
    }

    /// Enqueue a new sequence.
    pub fn add(&mut self, seq: Sequence) {
        assert_eq!(seq.phase, SeqPhase::Waiting);
        let id = seq.id;
        self.seqs.insert(id, seq);
        self.waiting.push_back(id);
    }

    pub fn get(&self, id: u64) -> Option<&Sequence> {
        self.seqs.get(&id)
    }

    pub fn get_mut(&mut self, id: u64) -> Option<&mut Sequence> {
        self.seqs.get_mut(&id)
    }

    pub fn num_waiting(&self) -> usize {
        self.waiting.len()
    }

    pub fn num_running(&self) -> usize {
        self.running.len()
    }

    /// All unfinished work drained?
    pub fn is_idle(&self) -> bool {
        self.waiting.is_empty() && self.running.is_empty()
    }

    /// Iterate live block tables (cache stats).
    pub fn live_tables(&self) -> impl Iterator<Item = &crate::kvcache::BlockTable> {
        self.seqs.values().filter(|s| !s.table.is_empty()).map(|s| &s.table)
    }

    /// Decide this step's work. Reserves blocks for whatever it returns:
    /// a `Prefill` sequence has its full replay reserved; every `Decode`
    /// sequence has one more slot reserved.
    pub fn plan(&mut self, alloc: &mut BlockAllocator) -> StepPlan {
        // 1. Try to admit the head of the waiting queue.
        if self.running.len() < self.cfg.max_running {
            if let Some(&cand) = self.waiting.front() {
                let replay_len = self.seqs[&cand].replay_tokens().len();
                let need = crate::kvcache::BlockTable::blocks_needed(replay_len, alloc.block_size());
                // Watermark headroom is waived when nothing is running —
                // otherwise a request sized near the whole pool could
                // never be admitted.
                let headroom = if self.running.is_empty() { 0 } else { self.cfg.watermark_blocks };
                if alloc.can_alloc(need + headroom) {
                    self.waiting.pop_front();
                    let seq = self.seqs.get_mut(&cand).unwrap();
                    let ok = seq.table.reserve(replay_len, alloc);
                    debug_assert!(ok, "can_alloc lied at admission");
                    seq.phase = SeqPhase::Prefilling;
                    self.running.push(cand);
                    return StepPlan::Prefill { seq_id: cand };
                }
            }
        }

        // 2. Decode a round-robin slice of the running set.
        let decoding: Vec<u64> = self
            .running
            .iter()
            .copied()
            .filter(|id| self.seqs[id].phase == SeqPhase::Decoding)
            .collect();
        if decoding.is_empty() {
            return StepPlan::Idle;
        }
        let batch_n = decoding.len().min(self.cfg.max_decode_batch);
        let start = self.rr_cursor % decoding.len();
        let mut batch: Vec<u64> =
            (0..batch_n).map(|i| decoding[(start + i) % decoding.len()]).collect();
        self.rr_cursor = self.rr_cursor.wrapping_add(batch_n);

        // Reserve one slot per batched sequence, preempting under pressure.
        let mut planned = Vec::with_capacity(batch.len());
        while let Some(id) = batch.first().copied() {
            batch.remove(0);
            loop {
                let block_size = alloc.block_size();
                let seq = self.seqs.get_mut(&id).unwrap();
                if seq.table.reserve(1, alloc) {
                    planned.push(id);
                    break;
                }
                // Memory pressure: preempt the youngest running sequence.
                let victim = match self.youngest_running() {
                    Some(v) => v,
                    None => panic!("block pool too small for a single sequence"),
                };
                self.preempt(victim, alloc);
                let _ = block_size;
                if victim == id {
                    break; // the sequence we were reserving for is gone
                }
                // Victims later in this batch must not decode this step.
                batch.retain(|&b| b != victim);
            }
        }
        if planned.is_empty() {
            // Everything got preempted; next plan() will re-admit.
            return StepPlan::Idle;
        }
        StepPlan::Decode { seq_ids: planned }
    }

    fn youngest_running(&self) -> Option<u64> {
        self.running
            .iter()
            .copied()
            .max_by_key(|id| self.seqs[id].arrival)
    }

    /// Recompute-preemption: free blocks, reset, re-queue at the front
    /// (it has priority — its work is sunk cost).
    fn preempt(&mut self, id: u64, alloc: &mut BlockAllocator) {
        let seq = self.seqs.get_mut(&id).unwrap();
        seq.table.free_all(alloc);
        seq.reset_for_recompute();
        self.running.retain(|&r| r != id);
        self.waiting.push_front(id);
        // Preempted sequences replay via prefill; phase flips to Waiting
        // at re-admission (plan() treats Preempted == Waiting).
        self.seqs.get_mut(&id).unwrap().phase = SeqPhase::Waiting;
        self.preemptions += 1;
    }

    /// Mark a sequence finished: free its blocks and remove it from the
    /// running set. The sequence stays in the store until collected.
    pub fn finish(&mut self, id: u64, alloc: &mut BlockAllocator) {
        let seq = self.seqs.get_mut(&id).expect("finish of unknown sequence");
        seq.table.free_all(alloc);
        seq.phase = SeqPhase::Finished;
        self.running.retain(|&r| r != id);
    }

    /// Remove and return a finished sequence.
    pub fn collect(&mut self, id: u64) -> Option<Sequence> {
        match self.seqs.get(&id) {
            Some(s) if s.phase == SeqPhase::Finished => self.seqs.remove(&id),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SamplingParams;

    fn seq(id: u64, prompt_len: usize, max_tokens: usize) -> Sequence {
        let params = SamplingParams { max_tokens, ..Default::default() };
        Sequence::new(id, vec![256; prompt_len.max(1)], params, 0.0)
    }

    fn sched(max_batch: usize) -> Scheduler {
        Scheduler::new(SchedulerConfig {
            max_running: 8,
            max_decode_batch: max_batch,
            watermark_blocks: 1,
        })
    }

    #[test]
    fn admits_prefill_first() {
        let mut s = sched(4);
        let mut alloc = BlockAllocator::new(16, 4);
        s.add(seq(1, 6, 4));
        match s.plan(&mut alloc) {
            StepPlan::Prefill { seq_id } => assert_eq!(seq_id, 1),
            other => panic!("expected prefill, got {other:?}"),
        }
        // Blocks for the 6-token prompt were reserved: ceil(6/4) = 2.
        assert_eq!(alloc.num_used(), 2);
        assert_eq!(s.get(1).unwrap().phase, SeqPhase::Prefilling);
    }

    #[test]
    fn decodes_after_prefill() {
        let mut s = sched(4);
        let mut alloc = BlockAllocator::new(16, 4);
        s.add(seq(1, 3, 4));
        let _ = s.plan(&mut alloc); // prefill
        s.get_mut(1).unwrap().phase = SeqPhase::Decoding;
        s.get_mut(1).unwrap().generated.push(42);
        match s.plan(&mut alloc) {
            StepPlan::Decode { seq_ids } => assert_eq!(seq_ids, vec![1]),
            other => panic!("expected decode, got {other:?}"),
        }
        // One decode slot reserved: prompt 3 tokens in 1 block (cap 4) +
        // slot 4 fits the same block → still 1 block.
        assert_eq!(alloc.num_used(), 1);
    }

    #[test]
    fn memory_pressure_defers_admission() {
        let mut s = sched(4);
        let mut alloc = BlockAllocator::new(3, 4); // tiny pool
        s.add(seq(1, 8, 4)); // needs 2 blocks + 1 watermark = ok
        s.add(seq(2, 8, 4)); // would need 2 + 1 > remaining 1
        let p1 = s.plan(&mut alloc);
        assert!(matches!(p1, StepPlan::Prefill { seq_id: 1 }));
        s.get_mut(1).unwrap().phase = SeqPhase::Decoding;
        s.get_mut(1).unwrap().generated.push(1);
        // Seq 2 cannot be admitted; falls through to decoding seq 1.
        let p2 = s.plan(&mut alloc);
        assert!(matches!(p2, StepPlan::Decode { .. }), "{p2:?}");
        assert_eq!(s.num_waiting(), 1);
    }

    #[test]
    fn preempts_youngest_under_pressure() {
        let mut s = sched(4);
        let mut alloc = BlockAllocator::new(5, 2);
        // Two sequences, 4 tokens each → 2 blocks each; 1 block spare.
        for id in [1, 2] {
            s.add(seq(id, 4, 8));
            let p = s.plan(&mut alloc);
            assert!(matches!(p, StepPlan::Prefill { .. }), "{p:?}");
            s.get_mut(id).unwrap().phase = SeqPhase::Decoding;
            s.get_mut(id).unwrap().generated.push(9);
            // Simulate the prefill having filled the reserved slots.
            for _ in 0..4 {
                s.get_mut(id).unwrap().table.append_slot(2);
            }
        }
        assert_eq!(alloc.num_free(), 1);
        // Decode step must grow both tables; no free blocks → preempt 2.
        let p = s.plan(&mut alloc);
        match p {
            StepPlan::Decode { seq_ids } => assert_eq!(seq_ids, vec![1]),
            other => panic!("{other:?}"),
        }
        assert_eq!(s.preemptions, 1);
        assert_eq!(s.num_waiting(), 1);
        assert_eq!(s.get(2).unwrap().phase, SeqPhase::Waiting);
        assert!(s.get(2).unwrap().table.is_empty());
    }

    #[test]
    fn round_robin_rotates_decode_batches() {
        let mut s = sched(2); // batch cap 2, 3 sequences
        let mut alloc = BlockAllocator::new(64, 4);
        for id in [1, 2, 3] {
            s.add(seq(id, 2, 8));
            let _ = s.plan(&mut alloc);
            s.get_mut(id).unwrap().phase = SeqPhase::Decoding;
            s.get_mut(id).unwrap().generated.push(0);
        }
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..3 {
            if let StepPlan::Decode { seq_ids } = s.plan(&mut alloc) {
                assert_eq!(seq_ids.len(), 2);
                seen.extend(seq_ids);
            }
        }
        assert_eq!(seen.len(), 3, "all sequences must get turns: {seen:?}");
    }

    #[test]
    fn finish_releases_blocks_and_collects() {
        let mut s = sched(4);
        let mut alloc = BlockAllocator::new(8, 4);
        s.add(seq(7, 4, 2));
        let _ = s.plan(&mut alloc);
        assert!(alloc.num_used() > 0);
        s.finish(7, &mut alloc);
        assert_eq!(alloc.num_used(), 0);
        assert!(s.is_idle());
        let collected = s.collect(7).unwrap();
        assert_eq!(collected.phase, SeqPhase::Finished);
        assert!(s.collect(7).is_none());
    }

    #[test]
    fn idle_when_empty() {
        let mut s = sched(4);
        let mut alloc = BlockAllocator::new(8, 4);
        assert_eq!(s.plan(&mut alloc), StepPlan::Idle);
    }
}
