//! Continuous-batching scheduler: token-budget **mixed steps** with
//! interleaved chunked prefill (vLLM-style, per the paper's §III.C
//! scheduling description).
//!
//! Every step is one [`StepPlan::Mixed`] sharing a token budget
//! (`step_token_budget`):
//! 1. **Decode first**: every `Decoding` sequence joins the step
//!    (round-robin, capped at `max_decode_batch`), one token each; on
//!    allocation failure the youngest running sequence is preempted
//!    (recompute-style: free its blocks, re-queue it) until the step
//!    fits. Decode is planned *first* so a long prompt can never stall
//!    the decoders — the head-of-line latency continuous batching
//!    exists to kill.
//! 2. **Prefill fills the rest**: the remaining budget goes to prefill
//!    chunks — first to sequences already mid-prefill (their blocks are
//!    sunk cost), then to new admissions from the waiting queue (FCFS,
//!    the head is never skipped). A prompt longer than one step's
//!    leftover budget spans multiple steps via the sequence's
//!    `prefill_pos` cursor. When prefill work is queued, decode is
//!    capped at `budget − 1` so at least one prefill token advances per
//!    step (bounded TTFT) — and prefill only ever takes the *leftover*
//!    budget, so decoders advance every step too.
//!
//! Block reservation is budget-aware: admission reserves only the first
//! chunk's blocks (plus the watermark headroom), later chunks reserve as
//! they are planned, and the preemption valve reclaims memory if the
//! pool overcommits.
//!
//! Backends whose prefill cannot resume at a nonzero position (the XLA
//! artifacts — see `Backend::supports_mixed_step`) run with
//! `chunked_prefill = false`: each step is then *either* one whole-prompt
//! prefill *or* one decode batch, the legacy exclusive policy.

use super::sequence::{SeqPhase, Sequence};
use crate::attention::SparsityConfig;
use crate::kvcache::eviction::{EvictionCandidate, EvictionPolicy, LruEviction};
use crate::kvcache::prefix_cache::chain_block_hashes;
use crate::kvcache::{
    BlockAllocator, BlockId, BlockTable, KvStore, PrefixCache, SpillTier, TOMBSTONE,
};
use std::collections::{BTreeMap, VecDeque};

/// Borrowed cold-tier context for one scheduling call: the disk spill
/// store plus the KV pool restores land in. Threaded through
/// [`Scheduler::plan_with_spill`] /
/// [`Scheduler::enforce_window_with_spill`]; every tier failure inside
/// degrades to recompute-on-miss, never into a planning error.
pub struct SpillCtx<'a> {
    pub tier: &'a mut SpillTier,
    pub cache: &'a mut dyn KvStore,
    /// Prompt tokens covered by disk restores during this borrow (the
    /// engine mirrors the total into `spill_hit_tokens`).
    pub restored_tokens: usize,
}

impl<'a> SpillCtx<'a> {
    pub fn new(tier: &'a mut SpillTier, cache: &'a mut dyn KvStore) -> SpillCtx<'a> {
        SpillCtx { tier, cache, restored_tokens: 0 }
    }
}

/// Scheduler tunables.
#[derive(Debug, Clone, Copy)]
pub struct SchedulerConfig {
    /// Max sequences decoding concurrently.
    pub max_running: usize,
    /// Max sequences per decode step (backend bucket cap).
    pub max_decode_batch: usize,
    /// Blocks kept free as headroom when admitting prompts.
    pub watermark_blocks: usize,
    /// Token budget per mixed step: decode tokens (one per decoding
    /// sequence) plus prefill-chunk tokens. Should comfortably exceed
    /// `max_decode_batch` so prefill makes progress under full decode
    /// load. The planner enforces an effective minimum of 2 — one
    /// decode token AND one prefill token must be able to coexist in a
    /// step, or one side would starve the other.
    pub step_token_budget: usize,
    /// Interleave chunked prefill with decode in one step. Forced off by
    /// the engine when the backend cannot resume prefill at a nonzero
    /// position (`Backend::supports_mixed_step`).
    pub chunked_prefill: bool,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_running: 64,
            max_decode_batch: 8,
            watermark_blocks: 2,
            step_token_budget: 256,
            chunked_prefill: true,
        }
    }
}

/// One prefill chunk inside a mixed step: `len` replay tokens starting
/// at position `start` of the sequence's prompt+generated stream.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PrefillChunk {
    pub seq_id: u64,
    /// First replay position this chunk covers (== the sequence's
    /// `prefill_pos` when the plan was made).
    pub start: usize,
    /// Tokens in the chunk (blocks already reserved).
    pub len: usize,
    /// True when this chunk completes the sequence's prefill — the
    /// engine samples the first token from its logits.
    pub last: bool,
}

/// One engine step's work. Block capacity for everything planned is
/// already reserved: each prefill chunk has `len` more slots, every
/// decode sequence one more slot.
#[derive(Debug, Clone, PartialEq)]
pub enum StepPlan {
    /// One token-budget step: prefill chunks and decode sequences
    /// executed together (either side may be empty, not both).
    Mixed { prefill: Vec<PrefillChunk>, decode: Vec<u64> },
    /// Nothing runnable (all queues empty, or the pool is pinned).
    Idle,
}

/// Sequence store + scheduling policy.
pub struct Scheduler {
    cfg: SchedulerConfig,
    seqs: BTreeMap<u64, Sequence>,
    waiting: VecDeque<u64>,
    running: Vec<u64>,
    /// Last sequence id served by the decode round-robin; the next step
    /// resumes strictly after it (in id order), so no decoding sequence
    /// is ever skipped twice in a row even as the set churns.
    rr_last: u64,
    /// Preemption-victim selection policy (youngest-admitted first —
    /// `kvcache::eviction::LruEviction`).
    eviction: LruEviction,
    /// Total preemptions (engine copies into metrics).
    pub preemptions: usize,
    /// KV blocks freed by sliding-window eviction
    /// ([`Scheduler::enforce_window`]) — reclaimed capacity the AIMD
    /// admission controller sees as headroom. Engine copies into
    /// metrics.
    pub evicted_blocks: usize,
    /// Prompt tokens skipped via prefix-cache block adoption at
    /// admission (engine copies into metrics).
    pub prefix_hit_tokens: usize,
    /// Steps where decoding sequences existed at plan time but none was
    /// planned (every one was preempted, or the cap was zero) — counted
    /// HERE because by the time the engine runs the plan, preempted
    /// decoders are no longer in the `Decoding` phase. Engine copies
    /// into metrics.
    pub decode_stall_steps: usize,
    /// Sequences admitted by the most recent `plan*` call, as
    /// `(seq_id, prefill start position after prefix/spill adoption)`.
    /// The engine turns these into request trace events. Cleared (not
    /// shrunk) each plan, so the steady state never reallocates.
    pub last_admitted: Vec<(u64, usize)>,
    /// Sequences preempted by the most recent `plan*` call.
    pub last_preempted: Vec<u64>,
    /// Disk-spill restores performed at admission by the most recent
    /// `plan*` call, as `(seq_id, restored tokens)`.
    pub last_restored: Vec<(u64, usize)>,
}

impl Scheduler {
    pub fn new(cfg: SchedulerConfig) -> Scheduler {
        Scheduler {
            cfg,
            seqs: BTreeMap::new(),
            waiting: VecDeque::new(),
            running: Vec::new(),
            rr_last: 0,
            eviction: LruEviction,
            preemptions: 0,
            evicted_blocks: 0,
            prefix_hit_tokens: 0,
            decode_stall_steps: 0,
            last_admitted: Vec::new(),
            last_preempted: Vec::new(),
            last_restored: Vec::new(),
        }
    }

    pub fn config(&self) -> &SchedulerConfig {
        &self.cfg
    }

    /// Enqueue a new sequence.
    pub fn add(&mut self, seq: Sequence) {
        assert_eq!(seq.phase, SeqPhase::Waiting);
        let id = seq.id;
        self.seqs.insert(id, seq);
        self.waiting.push_back(id);
    }

    pub fn get(&self, id: u64) -> Option<&Sequence> {
        self.seqs.get(&id)
    }

    pub fn get_mut(&mut self, id: u64) -> Option<&mut Sequence> {
        self.seqs.get_mut(&id)
    }

    pub fn num_waiting(&self) -> usize {
        self.waiting.len()
    }

    pub fn num_running(&self) -> usize {
        self.running.len()
    }

    /// Running sequences currently in the `Decoding` phase.
    pub fn num_decoding(&self) -> usize {
        self.running.iter().filter(|id| self.seqs[id].phase == SeqPhase::Decoding).count()
    }

    /// All unfinished work drained?
    pub fn is_idle(&self) -> bool {
        self.waiting.is_empty() && self.running.is_empty()
    }

    /// Iterate live block tables (cache stats).
    pub fn live_tables(&self) -> impl Iterator<Item = &crate::kvcache::BlockTable> {
        self.seqs.values().filter(|s| !s.table.is_empty()).map(|s| &s.table)
    }

    /// Decide this step's work, reserving blocks for whatever it
    /// returns. `prefix` enables prefix-cache block adoption at
    /// admission (chunk `start` positions then begin after the adopted
    /// tokens).
    pub fn plan(
        &mut self,
        alloc: &mut BlockAllocator,
        prefix: Option<&mut PrefixCache>,
    ) -> StepPlan {
        self.plan_with_spill(alloc, prefix, None)
    }

    /// [`Scheduler::plan`] with a cold-tier restore context: admissions
    /// whose prefix run misses the RAM prefix cache consult the disk
    /// spill index and restore evicted blocks into freshly allocated
    /// ones before falling back to recomputation.
    pub fn plan_with_spill(
        &mut self,
        alloc: &mut BlockAllocator,
        mut prefix: Option<&mut PrefixCache>,
        mut spill: Option<&mut SpillCtx<'_>>,
    ) -> StepPlan {
        self.last_admitted.clear();
        self.last_preempted.clear();
        self.last_restored.clear();
        if self.cfg.chunked_prefill {
            self.plan_mixed(alloc, prefix.as_deref_mut(), spill.as_deref_mut())
        } else {
            self.plan_exclusive(alloc, prefix.as_deref_mut(), spill.as_deref_mut())
        }
    }

    fn plan_mixed(
        &mut self,
        alloc: &mut BlockAllocator,
        prefix: Option<&mut PrefixCache>,
        spill: Option<&mut SpillCtx<'_>>,
    ) -> StepPlan {
        // Effective floor of 2: at budget 1 either decode would starve
        // admission (unbounded TTFT) or prefill would starve decode —
        // both violate the liveness contract, so the degenerate config
        // rounds up.
        let budget = self.cfg.step_token_budget.max(2);
        let prefill_pending = !self.waiting.is_empty()
            || self.running.iter().any(|id| self.seqs[id].phase == SeqPhase::Prefilling);
        // Decode never takes the whole budget while prefill work is
        // queued: at least one token per step flows to prefill.
        let decode_cap = if prefill_pending { budget - 1 } else { budget };
        let decode = self.plan_decode(alloc, decode_cap);
        let left = budget - decode.len();
        let mut prefill = self.plan_prefill(alloc, left, prefix, spill);
        if prefill.is_empty() && decode.is_empty() {
            if self.is_idle() {
                return StepPlan::Idle;
            }
            prefill = self.force_prefill_progress(alloc, budget);
            if prefill.is_empty() {
                return StepPlan::Idle;
            }
        }
        StepPlan::Mixed { prefill, decode }
    }

    /// Legacy exclusive policy for backends without mixed-step support:
    /// one whole-prompt prefill *or* one decode batch per step.
    fn plan_exclusive(
        &mut self,
        alloc: &mut BlockAllocator,
        mut prefix: Option<&mut PrefixCache>,
        mut spill: Option<&mut SpillCtx<'_>>,
    ) -> StepPlan {
        // 1. Prefill priority: admit the waiting head if its whole
        //    replay fits under the watermark.
        if let Some(chunk) = self.try_admit_whole(alloc, prefix.as_deref_mut(), spill.as_deref_mut())
        {
            // Decoders idle behind a whole-prompt prefill (the admitted
            // sequence itself is Prefilling, so it isn't counted): the
            // head-of-line stall the mixed planner eliminates — and what
            // makes the chunked-vs-exclusive stall comparison in
            // BENCH_engine.json meaningful.
            if self.num_decoding() > 0 {
                self.decode_stall_steps += 1;
            }
            return StepPlan::Mixed { prefill: vec![chunk], decode: Vec::new() };
        }
        // 2. Decode a round-robin slice of the running set.
        let decode = self.plan_decode(alloc, self.cfg.max_decode_batch);
        if decode.is_empty() {
            // A preemption storm may have pushed every decoder back to
            // the waiting queue; its freed blocks can admit the head now
            // instead of wasting a step.
            if let Some(chunk) = self.try_admit_whole(alloc, prefix, spill) {
                return StepPlan::Mixed { prefill: vec![chunk], decode: Vec::new() };
            }
            return StepPlan::Idle;
        }
        StepPlan::Mixed { prefill: Vec::new(), decode }
    }

    /// Plan up to `cap` decode tokens (one per decoding sequence),
    /// preempting under memory pressure.
    fn plan_decode(&mut self, alloc: &mut BlockAllocator, cap: usize) -> Vec<u64> {
        let mut decoding: Vec<u64> = self
            .running
            .iter()
            .copied()
            .filter(|id| self.seqs[id].phase == SeqPhase::Decoding)
            .collect();
        if decoding.is_empty() {
            return Vec::new();
        }
        if cap == 0 {
            self.decode_stall_steps += 1;
            return Vec::new();
        }
        decoding.sort_unstable();
        let batch_n = decoding.len().min(self.cfg.max_decode_batch).min(cap);
        // Fairness: resume the rotation strictly after the last-served
        // id, in id order. Because the rotation key is the id (stable)
        // rather than a position in a churning vector, a sequence is
        // served at least once every ⌈n / batch⌉ steps.
        let start = decoding.iter().position(|&id| id > self.rr_last).unwrap_or(0);
        let batch: Vec<u64> =
            (0..batch_n).map(|i| decoding[(start + i) % decoding.len()]).collect();
        self.rr_last = *batch.last().unwrap();

        // Reserve one slot per batched sequence; preempt the youngest
        // running sequence under pressure. Index-based single pass — no
        // quadratic `remove(0)`/`retain` churn.
        let mut planned = Vec::with_capacity(batch.len());
        let mut evicted: Vec<u64> = Vec::new();
        'batch: for &id in &batch {
            if evicted.contains(&id) {
                continue;
            }
            loop {
                if self.seqs.get_mut(&id).unwrap().table.reserve(1, alloc) {
                    planned.push(id);
                    continue 'batch;
                }
                // Memory pressure: the eviction policy picks the victim
                // (youngest-admitted first under `LruEviction`).
                let victim = self
                    .select_victim(None)
                    .expect("block pool too small for a single sequence");
                self.preempt(victim, alloc);
                evicted.push(victim);
                // The victim may already hold a planned slot this step
                // (freed along with its blocks) — drop it from the plan.
                planned.retain(|&p| p != victim);
                if victim == id {
                    continue 'batch;
                }
            }
        }
        if planned.is_empty() {
            // Decoders existed but a preemption storm evicted them all:
            // the head-of-line stall the mixed planner exists to avoid.
            self.decode_stall_steps += 1;
        }
        planned
    }

    /// Plan prefill chunks into `left` budget tokens: continue mid-flight
    /// prefills first, then admit from the waiting queue.
    fn plan_prefill(
        &mut self,
        alloc: &mut BlockAllocator,
        mut left: usize,
        mut prefix: Option<&mut PrefixCache>,
        mut spill: Option<&mut SpillCtx<'_>>,
    ) -> Vec<PrefillChunk> {
        let bs = alloc.block_size();
        let mut out = Vec::new();
        // 1. Continue sequences already mid-prefill, in admission order
        //    (their blocks are sunk cost — finishing them frees capacity
        //    soonest). Spare slots in already-reserved blocks are usable
        //    even when the free pool is empty.
        let mid: Vec<u64> = self
            .running
            .iter()
            .copied()
            .filter(|id| self.seqs[id].phase == SeqPhase::Prefilling)
            .collect();
        for id in mid {
            if left == 0 {
                break;
            }
            let spare = {
                let t = &self.seqs[&id].table;
                t.blocks().len() * bs - t.len()
            };
            let reservable = spare + alloc.num_free() * bs;
            let seq = self.seqs.get_mut(&id).unwrap();
            let remaining = seq.remaining_prefill();
            let chunk = remaining.min(left).min(reservable);
            if chunk == 0 {
                continue; // pool pressure: skip this step, decode drains it
            }
            let ok = seq.table.reserve(chunk, alloc);
            debug_assert!(ok, "reservable-token math lied at continuation");
            out.push(PrefillChunk {
                seq_id: id,
                start: seq.prefill_pos,
                len: chunk,
                last: chunk == remaining,
            });
            left -= chunk;
        }
        // 2. Admit from the waiting queue head (FCFS — the head is never
        //    skipped; if it cannot start, nothing behind it starts).
        while left > 0 && self.running.len() < self.cfg.max_running {
            let Some(&cand) = self.waiting.front() else { break };
            // Watermark headroom is waived when nothing is running —
            // otherwise a request sized near the whole pool could never
            // be admitted.
            let headroom = if self.running.is_empty() { 0 } else { self.cfg.watermark_blocks };
            let free_tokens = alloc.num_free().saturating_sub(headroom) * bs;
            if free_tokens == 0 {
                break;
            }
            self.waiting.pop_front();
            let chunk = self.admit(
                cand,
                alloc,
                free_tokens.min(left),
                prefix.as_deref_mut(),
                spill.as_deref_mut(),
            );
            left -= chunk.len;
            out.push(chunk);
        }
        out
    }

    /// Admit a popped waiting sequence: adopt any cached prefix blocks
    /// (RAM first, then disk-spill restores), reserve its first chunk
    /// (≤ `cap` tokens, ≥ 1), move it to the running set.
    fn admit(
        &mut self,
        cand: u64,
        alloc: &mut BlockAllocator,
        cap: usize,
        prefix: Option<&mut PrefixCache>,
        spill: Option<&mut SpillCtx<'_>>,
    ) -> PrefillChunk {
        debug_assert!(cap > 0);
        let bs = alloc.block_size();
        let seq = self.seqs.get_mut(&cand).unwrap();
        debug_assert!(seq.table.is_empty() && seq.prefill_pos == 0, "admission of a live table");
        let toks = seq.replay_tokens();
        // Prefix reuse (§III.C "cache sharing and reuse"): adopt cached
        // leading blocks outright — they are shared (refcounted), so
        // adoption consumes no free blocks, and `lookup_shared` always
        // leaves at least one token to compute logits from.
        let mut adopted: Vec<BlockId> = match prefix {
            Some(pc) => pc.lookup_shared(&toks, alloc),
            None => Vec::new(),
        };
        // Cold-tier extension: where the RAM hits stop, consult the
        // disk spill index and restore evicted blocks into freshly
        // allocated ones — exact bytes, CRC re-verified on read, so the
        // restored KV is bit-identical to the evicted KV. Any failure
        // (miss, quarantine, IO, pool pressure) just ends the run:
        // prefill recomputes the rest. One free block is always kept
        // back so the computed chunk below can reserve.
        if let Some(ctx) = spill {
            let max_blocks = toks.len().saturating_sub(1) / bs;
            let hashes = chain_block_hashes(bs, &toks);
            let mut restored_here = 0usize;
            for &h in hashes.iter().take(max_blocks).skip(adopted.len()) {
                if !ctx.tier.enabled() || !ctx.tier.contains(h) || alloc.num_free() <= 1 {
                    break;
                }
                let Some(b) = alloc.alloc() else { break };
                if ctx.tier.restore_into(h, ctx.cache, b).is_ok() {
                    ctx.restored_tokens += bs;
                    restored_here += bs;
                    adopted.push(b);
                } else {
                    alloc.release(b);
                    break;
                }
            }
            if restored_here > 0 {
                self.last_restored.push((cand, restored_here));
            }
        }
        let seq = self.seqs.get_mut(&cand).unwrap();
        if !adopted.is_empty() {
            seq.table.adopt_prefix(&adopted, bs);
            seq.prefill_pos = seq.table.len();
            self.prefix_hit_tokens += seq.prefill_pos;
        }
        let remaining = seq.remaining_prefill();
        // Re-derived block bound: spill restores may have consumed free
        // blocks since the caller sized `cap` (never to zero — the loop
        // above keeps one back, so `chunk ≥ 1` still holds).
        let spare = seq.table.blocks().len() * bs - seq.table.len();
        let chunk = remaining.min(cap).min(spare + alloc.num_free() * bs);
        let ok = seq.table.reserve(chunk, alloc);
        debug_assert!(ok, "admission free-token math lied");
        seq.phase = SeqPhase::Prefilling;
        let start = seq.prefill_pos;
        self.running.push(cand);
        self.last_admitted.push((cand, start));
        PrefillChunk { seq_id: cand, start, len: chunk, last: chunk == remaining }
    }

    /// Whole-replay admission for the exclusive (non-chunked) policy.
    fn try_admit_whole(
        &mut self,
        alloc: &mut BlockAllocator,
        prefix: Option<&mut PrefixCache>,
        spill: Option<&mut SpillCtx<'_>>,
    ) -> Option<PrefillChunk> {
        if self.running.len() >= self.cfg.max_running {
            return None;
        }
        let &cand = self.waiting.front()?;
        let replay = self.seqs[&cand].replay_len();
        let need = BlockTable::blocks_needed(replay, alloc.block_size());
        let headroom = if self.running.is_empty() { 0 } else { self.cfg.watermark_blocks };
        if !alloc.can_alloc(need + headroom) {
            return None;
        }
        self.waiting.pop_front();
        Some(self.admit(cand, alloc, replay, prefix, spill))
    }

    /// Memory-stuck escape hatch: no decode could be planned and no
    /// prefill could move (e.g. several half-prefilled prompts exhausted
    /// the pool between them). Preempt the youngest running sequence —
    /// sparing the oldest in-flight prefill — until some prefill takes at
    /// least one token, so the engine always makes forward progress.
    /// Returns empty only when the pool is pinned by something the
    /// scheduler doesn't own (the engine then flushes the prefix cache
    /// and re-plans).
    fn force_prefill_progress(
        &mut self,
        alloc: &mut BlockAllocator,
        budget: usize,
    ) -> Vec<PrefillChunk> {
        loop {
            let plan = self.plan_prefill(alloc, budget, None, None);
            if !plan.is_empty() {
                return plan;
            }
            let target = self
                .running
                .iter()
                .copied()
                .find(|id| self.seqs[id].phase == SeqPhase::Prefilling);
            match self.select_victim(target) {
                Some(v) => self.preempt(v, alloc),
                None => return Vec::new(),
            }
        }
    }

    /// Pick the next preemption victim via the eviction policy
    /// ([`LruEviction`]: youngest-admitted first), sparing `protect`.
    /// Falls back to raw youngest-by-arrival if the policy declines
    /// (e.g. every candidate holds zero blocks) so the planner's
    /// forward-progress guarantee is unchanged.
    fn select_victim(&self, protect: Option<u64>) -> Option<u64> {
        let cands: Vec<EvictionCandidate> = self
            .running
            .iter()
            .copied()
            .filter(|&v| Some(v) != protect)
            .map(|v| {
                let s = &self.seqs[&v];
                EvictionCandidate {
                    seq_id: v,
                    blocks_held: s.table.live_blocks(),
                    arrival: s.arrival,
                }
            })
            .collect();
        self.eviction
            .select(&cands, 1)
            .first()
            .copied()
            .or_else(|| cands.iter().max_by_key(|c| c.arrival).map(|c| c.seq_id))
    }

    /// Sliding-window eviction (the sparsity contract's eviction
    /// boundary): for every running sequence, tombstone and free the KV
    /// blocks behind `SparsityConfig::evict_frontier` — blocks that no
    /// future query of that sequence can ever see, so freeing them is
    /// numerics-invariant. Returns the number of blocks whose
    /// reference was released this call (shared prefix blocks only truly
    /// free once the last holder drops them); the running total is
    /// [`Scheduler::evicted_blocks`]. No-op (0) under a dense config.
    pub fn enforce_window(&mut self, sp: &SparsityConfig, alloc: &mut BlockAllocator) -> usize {
        self.enforce_window_with_spill(sp, alloc, None)
    }

    /// [`Scheduler::enforce_window`] with a cold-tier context: each
    /// victim block is offered to the disk spill store *before*
    /// `evict_leading` releases it (its bytes are still intact — nothing
    /// allocates between the offer and the release), keyed by the same
    /// chain hash the prefix cache would use, so a later request with
    /// the same prefix can restore it instead of recomputing.
    pub fn enforce_window_with_spill(
        &mut self,
        sp: &SparsityConfig,
        alloc: &mut BlockAllocator,
        mut spill: Option<&mut SpillCtx<'_>>,
    ) -> usize {
        if !sp.is_windowed() {
            return 0;
        }
        let bs = alloc.block_size();
        let ids: Vec<u64> = self.running.clone();
        let mut freed = 0usize;
        for id in ids {
            let seq = self.seqs.get_mut(&id).unwrap();
            // The next query position: decode appends at `table.len()`,
            // and a mid-prefill chunk resumes there too.
            let frontier = sp.evict_frontier(seq.table.len(), bs);
            if let Some(ctx) = spill.as_deref_mut() {
                if ctx.tier.enabled() {
                    let hi = frontier.min(seq.table.blocks().len());
                    let lo = sp.sink_blocks.min(hi);
                    if lo < hi {
                        // A block's KV depends only on the tokens up to
                        // its end (causal attention), so the chain hash
                        // over the replay prefix names its bytes exactly.
                        let hashes = chain_block_hashes(bs, &seq.replay_tokens());
                        for i in lo..hi {
                            let b = seq.table.blocks()[i];
                            if b == TOMBSTONE {
                                continue; // evicted on an earlier pass
                            }
                            let Some(&h) = hashes.get(i) else { break };
                            if ctx.tier.contains(h) {
                                continue;
                            }
                            let payload = ctx.cache.export_block(b);
                            // Failures degrade (recompute-on-miss) and
                            // feed the tier's own circuit breaker.
                            let _ = ctx.tier.offer(h, &payload);
                        }
                    }
                }
            }
            let seq = self.seqs.get_mut(&id).unwrap();
            freed += seq.table.evict_leading(sp.sink_blocks, frontier, alloc);
        }
        self.evicted_blocks += freed;
        freed
    }

    /// Recompute-preemption: free blocks, reset the prefill cursor,
    /// re-queue at the front (it has priority — its work is sunk cost).
    fn preempt(&mut self, id: u64, alloc: &mut BlockAllocator) {
        let seq = self.seqs.get_mut(&id).unwrap();
        seq.table.free_all(alloc);
        // Preempted sequences replay prompt+generated via prefill; phase
        // flips to Waiting here (plan() treats Preempted == Waiting).
        seq.reset_for_recompute();
        seq.phase = SeqPhase::Waiting;
        self.running.retain(|&r| r != id);
        self.waiting.push_front(id);
        self.preemptions += 1;
        self.last_preempted.push(id);
    }

    /// Mark a sequence finished: free its blocks and remove it from the
    /// running set. The sequence stays in the store until collected.
    pub fn finish(&mut self, id: u64, alloc: &mut BlockAllocator) {
        let seq = self.seqs.get_mut(&id).expect("finish of unknown sequence");
        seq.table.free_all(alloc);
        seq.phase = SeqPhase::Finished;
        self.running.retain(|&r| r != id);
    }

    /// Remove and return a finished sequence.
    pub fn collect(&mut self, id: u64) -> Option<Sequence> {
        match self.seqs.get(&id) {
            Some(s) if s.phase == SeqPhase::Finished => self.seqs.remove(&id),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SamplingParams;

    fn seq(id: u64, prompt_len: usize, max_tokens: usize) -> Sequence {
        let params = SamplingParams { max_tokens, ..Default::default() };
        Sequence::new(id, vec![256; prompt_len.max(1)], params, 0.0)
    }

    fn sched(max_batch: usize, budget: usize) -> Scheduler {
        Scheduler::new(SchedulerConfig {
            max_running: 8,
            max_decode_batch: max_batch,
            watermark_blocks: 1,
            step_token_budget: budget,
            chunked_prefill: true,
        })
    }

    /// Drive one planned prefill chunk to "executed" state: advance the
    /// cursor and fill the reserved slots, flipping phase on the last
    /// chunk (what the engine does after the backend call).
    fn complete_chunk(s: &mut Scheduler, c: &PrefillChunk, block_size: usize) {
        let seq = s.get_mut(c.seq_id).unwrap();
        assert_eq!(seq.prefill_pos, c.start, "chunk must resume at the cursor");
        for _ in 0..c.len {
            seq.table.append_slot(block_size);
        }
        seq.prefill_pos += c.len;
        if c.last {
            seq.phase = SeqPhase::Decoding;
            seq.generated.push(42);
        }
    }

    fn unpack(plan: StepPlan) -> (Vec<PrefillChunk>, Vec<u64>) {
        match plan {
            StepPlan::Mixed { prefill, decode } => (prefill, decode),
            StepPlan::Idle => panic!("expected work, got Idle"),
        }
    }

    #[test]
    fn admits_prefill_first_step() {
        let mut s = sched(4, 64);
        let mut alloc = BlockAllocator::new(16, 4);
        s.add(seq(1, 6, 4));
        let (prefill, decode) = unpack(s.plan(&mut alloc, None));
        assert!(decode.is_empty());
        assert_eq!(prefill.len(), 1);
        assert_eq!(prefill[0], PrefillChunk { seq_id: 1, start: 0, len: 6, last: true });
        // Blocks for the 6-token chunk were reserved: ceil(6/4) = 2.
        assert_eq!(alloc.num_used(), 2);
        assert_eq!(s.get(1).unwrap().phase, SeqPhase::Prefilling);
    }

    #[test]
    fn long_prompt_prefills_in_budget_chunks() {
        let mut s = sched(4, 8);
        let mut alloc = BlockAllocator::new(32, 4);
        s.add(seq(1, 20, 4));
        let (p1, _) = unpack(s.plan(&mut alloc, None));
        assert_eq!(p1[0], PrefillChunk { seq_id: 1, start: 0, len: 8, last: false });
        complete_chunk(&mut s, &p1[0], 4);
        let (p2, _) = unpack(s.plan(&mut alloc, None));
        assert_eq!(p2[0], PrefillChunk { seq_id: 1, start: 8, len: 8, last: false });
        complete_chunk(&mut s, &p2[0], 4);
        let (p3, _) = unpack(s.plan(&mut alloc, None));
        assert_eq!(p3[0], PrefillChunk { seq_id: 1, start: 16, len: 4, last: true });
    }

    #[test]
    fn decode_advances_alongside_prefill_chunks() {
        // Sequence 1 decodes while sequence 2's long prompt prefills in
        // chunks: every step carries BOTH kinds of work.
        let mut s = sched(4, 6);
        let mut alloc = BlockAllocator::new(32, 4);
        s.add(seq(1, 3, 8));
        let (p, _) = unpack(s.plan(&mut alloc, None));
        complete_chunk(&mut s, &p[0], 4);
        s.add(seq(2, 16, 4));
        let mut decode_steps = 0;
        for _ in 0..4 {
            let (prefill, decode) = unpack(s.plan(&mut alloc, None));
            if s.get(2).unwrap().phase == SeqPhase::Prefilling
                || prefill.iter().any(|c| c.seq_id == 2)
            {
                assert_eq!(decode, vec![1], "decoder must advance every step");
                decode_steps += 1;
            }
            for c in &prefill {
                complete_chunk(&mut s, &c.clone(), 4);
            }
            if let Some(q) = s.get_mut(1) {
                if q.phase == SeqPhase::Decoding && decode.contains(&1) {
                    q.table.append_slot(4);
                    q.generated.push(7);
                }
            }
        }
        assert!(decode_steps >= 3, "interleaving must keep decode live ({decode_steps})");
        assert_eq!(s.get(2).unwrap().phase, SeqPhase::Decoding, "prefill must complete");
    }

    #[test]
    fn prefill_budget_is_leftover_after_decode() {
        // 3 decoders + budget 5 → 3 decode tokens, 2 prefill tokens.
        let mut s = sched(8, 5);
        let mut alloc = BlockAllocator::new(64, 4);
        for id in [1, 2, 3] {
            s.add(seq(id, 2, 8));
            let (p, _) = unpack(s.plan(&mut alloc, None));
            complete_chunk(&mut s, &p[0], 4);
        }
        s.add(seq(4, 10, 4));
        let (prefill, decode) = unpack(s.plan(&mut alloc, None));
        assert_eq!(decode.len(), 3);
        assert_eq!(prefill.len(), 1);
        assert_eq!(prefill[0].len, 2, "prefill takes exactly the leftover budget");
    }

    #[test]
    fn memory_pressure_defers_admission() {
        let mut s = sched(4, 64);
        let mut alloc = BlockAllocator::new(3, 4); // tiny pool
        s.add(seq(1, 8, 4)); // needs 2 blocks; no watermark while alone
        let (p1, _) = unpack(s.plan(&mut alloc, None));
        assert_eq!(p1[0].seq_id, 1);
        complete_chunk(&mut s, &p1[0], 4);
        // Seq 2 can only get a sliver (1 free block − 1 watermark = 0).
        s.add(seq(2, 8, 4));
        let (p2, d2) = unpack(s.plan(&mut alloc, None));
        assert_eq!(d2, vec![1]);
        assert!(p2.is_empty(), "watermark must defer admission: {p2:?}");
        assert_eq!(s.num_waiting(), 1);
    }

    #[test]
    fn preempts_youngest_under_pressure() {
        let mut s = sched(4, 64);
        let mut alloc = BlockAllocator::new(5, 2);
        // Two sequences, 4 tokens each, admitted in ONE mixed step
        // (10-token pool) → 2 full blocks each; 1 block spare.
        for id in [1, 2] {
            s.add(seq(id, 4, 8));
        }
        let (p, _) = unpack(s.plan(&mut alloc, None));
        assert_eq!(p.len(), 2, "budget admits both prompts in one step: {p:?}");
        for c in &p {
            complete_chunk(&mut s, &c.clone(), 2);
        }
        assert_eq!(alloc.num_free(), 1);
        // Decode step must grow both tables; one free block → preempt 2.
        // Its freed blocks immediately re-admit it as a replay chunk in
        // the SAME step (no wasted iteration), cursor reset to 0.
        let (p2, decode) = unpack(s.plan(&mut alloc, None));
        assert_eq!(decode, vec![1]);
        assert_eq!(s.preemptions, 1);
        assert_eq!(p2.len(), 1);
        assert_eq!(p2[0].seq_id, 2);
        assert_eq!(p2[0].start, 0);
        assert_eq!(s.get(2).unwrap().phase, SeqPhase::Prefilling);
        assert_eq!(s.get(2).unwrap().prefill_pos, 0);
    }

    #[test]
    fn round_robin_never_skips_a_sequence_twice() {
        let mut s = sched(2, 64); // batch cap 2, 3 decoders
        let mut alloc = BlockAllocator::new(64, 4);
        for id in [1, 2, 3] {
            s.add(seq(id, 2, 16));
            let (p, _) = unpack(s.plan(&mut alloc, None));
            complete_chunk(&mut s, &p[0], 4);
        }
        let mut served = std::collections::BTreeMap::new();
        let mut skipped: std::collections::BTreeMap<u64, usize> = BTreeMap::new();
        for _ in 0..6 {
            let (_, decode) = unpack(s.plan(&mut alloc, None));
            assert_eq!(decode.len(), 2);
            for id in [1u64, 2, 3] {
                if decode.contains(&id) {
                    *served.entry(id).or_insert(0) += 1;
                    skipped.insert(id, 0);
                } else {
                    let k = skipped.entry(id).or_insert(0);
                    *k += 1;
                    assert!(*k < 2, "sequence {id} skipped twice in a row");
                }
            }
        }
        // 6 steps × 2 slots over 3 sequences → exactly 4 turns each.
        assert!(served.values().all(|&n| n == 4), "{served:?}");
    }

    #[test]
    fn preempted_decoder_replays_with_cursor_reset() {
        // A preempted decoder replays prompt+generated via chunked
        // prefill from position 0 while the survivor keeps decoding.
        let mut s = sched(4, 64);
        let mut alloc = BlockAllocator::new(5, 2);
        for id in [1, 2] {
            s.add(seq(id, 4, 8));
        }
        let (p, _) = unpack(s.plan(&mut alloc, None));
        for c in &p {
            complete_chunk(&mut s, &c.clone(), 2);
        }
        // Pressure step: seq 2 preempted, then re-admitted as a partial
        // replay chunk of its 5 replay tokens (4 prompt + 1 generated).
        let (p2, d2) = unpack(s.plan(&mut alloc, None));
        assert_eq!(d2, vec![1]);
        assert_eq!(s.preemptions, 1);
        assert_eq!(p2, vec![PrefillChunk { seq_id: 2, start: 0, len: 2, last: false }]);
        assert_eq!(s.get(2).unwrap().replay_len(), 5);
        {
            // Fill seq 1's reserved decode slot (what the engine does).
            let q = s.get_mut(1).unwrap();
            q.table.append_slot(2);
            q.generated.push(9);
        }
        complete_chunk(&mut s, &p2[0].clone(), 2);
        // The replay resumes from the cursor next step, decode still live.
        let (p3, d3) = unpack(s.plan(&mut alloc, None));
        assert_eq!(d3, vec![1]);
        assert_eq!(p3.len(), 1);
        assert_eq!(p3[0].seq_id, 2);
        assert_eq!(p3[0].start, 2);
    }

    #[test]
    fn exclusive_mode_plans_whole_prefill_xor_decode() {
        let mut s = Scheduler::new(SchedulerConfig {
            max_running: 8,
            max_decode_batch: 4,
            watermark_blocks: 1,
            step_token_budget: 4, // ignored by the exclusive policy
            chunked_prefill: false,
        });
        let mut alloc = BlockAllocator::new(16, 4);
        s.add(seq(1, 10, 4));
        let (p, d) = unpack(s.plan(&mut alloc, None));
        assert!(d.is_empty());
        assert_eq!(p[0], PrefillChunk { seq_id: 1, start: 0, len: 10, last: true });
        complete_chunk(&mut s, &p[0], 4);
        s.add(seq(2, 3, 4));
        // Prefill priority: seq 2 admitted whole before seq 1 decodes.
        let (p2, d2) = unpack(s.plan(&mut alloc, None));
        assert!(d2.is_empty());
        assert_eq!(p2[0].len, 3);
        complete_chunk(&mut s, &p2[0], 4);
        let (p3, d3) = unpack(s.plan(&mut alloc, None));
        assert!(p3.is_empty());
        assert_eq!(d3.len(), 2);
    }

    #[test]
    fn finish_releases_blocks_and_collects() {
        let mut s = sched(4, 64);
        let mut alloc = BlockAllocator::new(8, 4);
        s.add(seq(7, 4, 2));
        let _ = s.plan(&mut alloc, None);
        assert!(alloc.num_used() > 0);
        s.finish(7, &mut alloc);
        assert_eq!(alloc.num_used(), 0);
        assert!(s.is_idle());
        let collected = s.collect(7).unwrap();
        assert_eq!(collected.phase, SeqPhase::Finished);
        assert!(s.collect(7).is_none());
    }

    #[test]
    fn idle_when_empty() {
        let mut s = sched(4, 64);
        let mut alloc = BlockAllocator::new(8, 4);
        assert_eq!(s.plan(&mut alloc, None), StepPlan::Idle);
    }

    #[test]
    fn window_eviction_offers_victims_and_admission_restores_them() {
        use crate::kvcache::spill::SpillConfig;
        use crate::kvcache::{PagedKvCache, SpillTier};
        let dir = std::env::temp_dir().join("opt_gptq_spill_sched_offer");
        let _ = std::fs::remove_dir_all(&dir);

        let bs = 4usize;
        let mut alloc = BlockAllocator::new(16, bs);
        // 1 layer, 16 blocks, bs 4, 1 kv head, dim 2 — enough to carry
        // recognizable bytes through evict → spill → restore.
        let mut cache = PagedKvCache::new(1, 16, bs, 1, 2);
        let mut tier = SpillTier::open(SpillConfig::new(&dir), 0, 9).unwrap();

        // Prefill an 18-token sequence, writing distinct KV per slot.
        let mut s = sched(4, 64);
        s.add(seq(1, 18, 8));
        let (p, _) = unpack(s.plan(&mut alloc, None));
        complete_chunk(&mut s, &p[0], bs);
        let table_blocks = s.get(1).unwrap().table.blocks().to_vec();
        for (i, &b) in table_blocks.iter().enumerate() {
            for slot in 0..bs {
                let t = (i * bs + slot) as f32;
                cache.write_token(0, b, slot, &[t, -t], &[t * 2.0, t + 0.5]);
            }
        }
        let replay = s.get(1).unwrap().replay_tokens();
        let hashes = chain_block_hashes(bs, &replay);
        let victim_bytes: Vec<Vec<u8>> =
            (1..3).map(|i| cache.export_block(table_blocks[i])).collect();

        // Window eviction with the spill observer: blocks 1 and 2 fall
        // behind the frontier and must be offered before they are freed.
        let sp = SparsityConfig::windowed(2, 1);
        let mut ctx = SpillCtx::new(&mut tier, &mut cache);
        let freed = s.enforce_window_with_spill(&sp, &mut alloc, Some(&mut ctx));
        assert_eq!(freed, 2);
        assert_eq!(tier.records(), 2, "both victims spilled");
        assert!(tier.contains(hashes[1]) && tier.contains(hashes[2]));
        // Spilled payloads are the exact evicted bytes.
        for (i, bytes) in victim_bytes.iter().enumerate() {
            assert_eq!(&tier.restore(hashes[i + 1]).unwrap(), bytes);
        }
        // Idempotent: a second pass has nothing new to offer.
        let mut ctx = SpillCtx::new(&mut tier, &mut cache);
        assert_eq!(s.enforce_window_with_spill(&sp, &mut alloc, Some(&mut ctx)), 0);
        assert_eq!(tier.records(), 2);

        // A fresh request whose prompt shares the prefix restores the
        // evicted blocks at admission instead of recomputing. The tier
        // needs block 0 too (restores are an unbroken *leading* run;
        // block 0 was the sink and never offered), so seed it directly.
        let h0_payload = cache.export_block(table_blocks[0]);
        assert!(tier.offer(hashes[0], &h0_payload).unwrap());
        let mut s2 = sched(4, 64);
        let params = SamplingParams { max_tokens: 4, ..Default::default() };
        s2.add(Sequence::new(9, replay[..12].to_vec(), params, 0.0));
        // 12-token prompt → at most (12−1)/4 = 2 leading blocks may be
        // adopted (≥ 1 token always left to compute logits from).
        let mut restored_pool = PagedKvCache::new(1, 16, bs, 1, 2);
        let mut ctx = SpillCtx::new(&mut tier, &mut restored_pool);
        let (p2, _) = unpack(s2.plan_with_spill(&mut alloc, None, Some(&mut ctx)));
        assert_eq!(ctx.restored_tokens, 8, "two full blocks restored from disk");
        assert_eq!(p2.len(), 1);
        assert_eq!(p2[0].start, 8, "prefill resumes after the restored run");
        assert_eq!(p2[0].len, 4, "12-token prompt: last 4 tokens computed");
        assert_eq!(s2.prefix_hit_tokens, 8);
        let adopted = s2.get(9).unwrap().table.blocks().to_vec();
        // Restored bytes are bit-identical to the evicted ones.
        assert_eq!(restored_pool.export_block(adopted[0]), h0_payload);
        assert_eq!(restored_pool.export_block(adopted[1]), victim_bytes[0]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn enforce_window_frees_behind_the_frontier() {
        let mut s = sched(4, 64);
        let bs = 4usize;
        let mut alloc = BlockAllocator::new(16, bs);
        s.add(seq(1, 18, 8)); // 5 blocks once prefilled
        let (p, _) = unpack(s.plan(&mut alloc, None));
        complete_chunk(&mut s, &p[0], bs);
        let used_before = alloc.num_used();
        let sp = SparsityConfig::windowed(2, 1);
        // next_pos = 18 → query block 4 → frontier 3: blocks 1 and 2 are
        // behind it (block 0 is the sink, 3..=4 the window).
        let freed = s.enforce_window(&sp, &mut alloc);
        assert_eq!(freed, 2);
        assert_eq!(s.evicted_blocks, 2);
        assert_eq!(alloc.num_used(), used_before - 2, "evicted blocks return to the pool");
        // Idempotent at the same position; dense is a no-op.
        assert_eq!(s.enforce_window(&sp, &mut alloc), 0);
        assert_eq!(s.enforce_window(&SparsityConfig::dense(), &mut alloc), 0);
        // The sequence keeps decoding with a tombstoned table.
        let (_, d) = unpack(s.plan(&mut alloc, None));
        assert_eq!(d, vec![1]);
    }
}
