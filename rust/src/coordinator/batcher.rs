//! Decode/prefill batch planning against backend shape buckets.
//!
//! PJRT executables are fixed-shape, so the XLA backend exposes a bucket
//! grid (from the artifact manifest) and batches are padded up to the
//! chosen bucket. The native backend has no shape constraint and uses
//! [`BucketPolicy::exact`]. Padding waste is tracked by the engine
//! metrics (`padding_waste`).

/// Available batch sizes (sorted ascending).
#[derive(Debug, Clone)]
pub struct BucketPolicy {
    buckets: Vec<usize>,
}

impl BucketPolicy {
    /// Explicit bucket grid (e.g. from the artifact manifest).
    pub fn new(mut buckets: Vec<usize>) -> BucketPolicy {
        assert!(!buckets.is_empty(), "no buckets");
        buckets.sort_unstable();
        buckets.dedup();
        assert!(buckets[0] > 0);
        BucketPolicy { buckets }
    }

    /// Shape-unconstrained policy: every size up to `max` is its own
    /// bucket (zero padding). Native backend.
    pub fn exact(max: usize) -> BucketPolicy {
        BucketPolicy { buckets: (1..=max.max(1)).collect() }
    }

    /// Largest batch the policy supports.
    pub fn max_batch(&self) -> usize {
        *self.buckets.last().unwrap()
    }

    /// Smallest bucket ≥ `n`; `None` if `n` exceeds the largest bucket
    /// (caller must split the batch).
    pub fn pick(&self, n: usize) -> Option<usize> {
        self.buckets.iter().copied().find(|&b| b >= n)
    }

    /// Bucket size a batch of `n` actually executes at: the smallest
    /// bucket ≥ `n`, saturating at the largest bucket for oversized
    /// batches (the engine's padding-waste accounting).
    pub fn pad(&self, n: usize) -> usize {
        self.pick(n).unwrap_or_else(|| self.max_batch())
    }

    /// Split `n` items into bucket-sized chunks, largest-first, to cover
    /// oversized batches with minimal total padding.
    pub fn split(&self, mut n: usize) -> Vec<usize> {
        let mut out = Vec::new();
        let max = self.max_batch();
        while n > max {
            out.push(max);
            n -= max;
        }
        if n > 0 {
            out.push(self.pick(n).unwrap());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pick_smallest_fitting() {
        let p = BucketPolicy::new(vec![1, 4, 8]);
        assert_eq!(p.pick(1), Some(1));
        assert_eq!(p.pick(2), Some(4));
        assert_eq!(p.pick(8), Some(8));
        assert_eq!(p.pick(9), None);
    }

    #[test]
    fn split_oversized() {
        let p = BucketPolicy::new(vec![1, 4, 8]);
        assert_eq!(p.split(20), vec![8, 8, 4]);
        assert_eq!(p.split(3), vec![4]);
        assert_eq!(p.split(0), Vec::<usize>::new());
    }

    #[test]
    fn exact_has_no_padding() {
        let p = BucketPolicy::exact(16);
        for n in 1..=16 {
            assert_eq!(p.pick(n), Some(n));
            assert_eq!(p.pad(n), n);
        }
    }

    #[test]
    fn pad_saturates_at_max_bucket() {
        let p = BucketPolicy::new(vec![1, 4, 8]);
        assert_eq!(p.pad(3), 4);
        assert_eq!(p.pad(8), 8);
        assert_eq!(p.pad(20), 8);
    }

    #[test]
    fn dedup_and_sort() {
        let p = BucketPolicy::new(vec![8, 1, 4, 4]);
        assert_eq!(p.pick(2), Some(4));
        assert_eq!(p.max_batch(), 8);
    }
}
