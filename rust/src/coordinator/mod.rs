//! The serving coordinator — the paper's vLLM-integration layer.
//!
//! * [`sequence`] — per-request state machine (waiting → prefill →
//!   decoding → finished, with preemption and a chunked-prefill
//!   cursor).
//! * [`scheduler`] — continuous batching as token-budget **mixed
//!   steps**: decode every running sequence each step and fill the
//!   leftover budget with interleaved prefill chunks, with KV-memory
//!   admission control and recompute-preemption under pressure (§III.C
//!   "load balancing and resource scheduling").
//! * [`batcher`] — decode-batch planning against the backend's shape
//!   buckets.
//! * [`engine`] — the step loop: scheduler plan → one
//!   `Backend::forward_step` mixed batch → sampling → cache bookkeeping
//!   → metrics.
//! * [`admission`] — the overload-control vocabulary: typed rejections
//!   ([`SubmitError`]), the bounded deadline queue, and the AIMD
//!   concurrency controller (see ARCHITECTURE.md "Overload & failure
//!   contract").
//! * [`router`] — front door: validation, bounded admission with
//!   deadlines, fan-out to *supervised* engine workers (crash →
//!   typed failure → respawn).
//! * [`metrics`] — the paper's measurement surface: latency, "all"
//!   throughput (req/s and tok/s), generation throughput, plus the
//!   overload counters (sheds, deadline misses, restarts).

pub mod admission;
pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod router;
pub mod scheduler;
pub mod sequence;

pub use admission::{AdmissionConfig, AdmissionQueue, AimdConfig, AimdController, SubmitError};
pub use batcher::BucketPolicy;
pub use engine::{Engine, EngineConfig, RequestOutput};
// Re-exported so engine-config construction sites don't need separate
// kvcache/model imports for the storage-dtype knobs.
pub use crate::kvcache::KvCacheDtype;
pub use crate::kvcache::{SpillConfig, SpillError, SpillStats};
pub use crate::model::WeightDtype;
pub use metrics::{EngineMetrics, RunReport};
pub use router::{Router, RouterConfig, SubmitResult, WorkerHealth, WorkerSnapshot};
pub use scheduler::{PrefillChunk, Scheduler, SchedulerConfig, SpillCtx, StepPlan};
pub use sequence::{SeqPhase, Sequence};
