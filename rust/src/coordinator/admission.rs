//! Overload control for the serving front door: typed rejection, a
//! depth-bounded admission queue with per-request deadlines, and an
//! AIMD concurrency limit.
//!
//! The paper's serving claim (§III: paging memory management inside
//! vLLM to "maximize hardware efficiency" under large-scale load) only
//! holds if overload degrades gracefully. This module is the policy
//! layer the [`super::router`] applies **before** any work is
//! scheduled:
//!
//! * [`SubmitError`] — every rejection is typed, so the HTTP layer can
//!   answer 429/503/400 honestly instead of guessing.
//! * [`AdmissionQueue`] — a bounded FCFS queue in front of each engine
//!   worker; entries carry a deadline and are shed (never silently
//!   dropped) once it passes, **before** they reach the scheduler.
//! * [`AimdController`] — additive-increase / multiplicative-decrease
//!   concurrency limit driven by observed inter-token latency vs an SLO
//!   target (the congestion-control idiom: probe for capacity while the
//!   signal is healthy, back off multiplicatively on breach).
//!
//! Because shedding happens strictly pre-scheduling, the bit-identity,
//! zero-alloc and decode-liveness contracts of the engine are untouched:
//! an admitted request runs exactly as it would without this layer.

use std::collections::VecDeque;
use std::time::Instant;

/// Typed rejection for the submit path (engine → router → server).
///
/// Replaces the old dropped-reply-channel convention, where every
/// failure reached the client as a guessed 400.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// The worker's admission queue is at capacity. `retry_after_ms` is
    /// the router's estimate of when a slot frees up (HTTP 429 +
    /// `Retry-After`).
    QueueFull { retry_after_ms: u64 },
    /// The request's deadline passed before it could be scheduled
    /// (HTTP 503). The deadline bounds time-to-admission, not
    /// generation: once scheduled, a request runs to completion.
    DeadlineExceeded,
    /// The request can never be served by this deployment — empty
    /// prompt, or prompt + max_tokens exceed the KV pool / model
    /// max_seq (HTTP 400; retrying is pointless).
    PromptTooLong { reason: String },
    /// The engine worker crashed while the request was queued or in
    /// flight, or no healthy worker exists (HTTP 503).
    WorkerFailed,
}

impl SubmitError {
    /// Stable machine-readable discriminant — the `"error_kind"` field
    /// of HTTP error bodies and the label on shed log lines, so clients
    /// and dashboards can branch without parsing the human message.
    pub fn kind(&self) -> &'static str {
        match self {
            SubmitError::QueueFull { .. } => "queue_full",
            SubmitError::DeadlineExceeded => "deadline_exceeded",
            SubmitError::PromptTooLong { .. } => "prompt_too_long",
            SubmitError::WorkerFailed => "worker_failed",
        }
    }
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull { retry_after_ms } => {
                write!(f, "admission queue full; retry after {retry_after_ms} ms")
            }
            SubmitError::DeadlineExceeded => {
                write!(f, "deadline exceeded before the request could be scheduled")
            }
            SubmitError::PromptTooLong { reason } => write!(f, "{reason}"),
            SubmitError::WorkerFailed => write!(f, "engine worker failed"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Tunables for the [`AimdController`].
#[derive(Debug, Clone, Copy)]
pub struct AimdConfig {
    /// Inter-token latency SLO target in seconds. Mean observed ITL at
    /// or under this is "healthy" (additive increase); above it is a
    /// breach (multiplicative decrease).
    pub target_itl_s: f64,
    /// Floor for the concurrency limit (never shed to zero capacity).
    pub min_limit: usize,
    /// Ceiling for the concurrency limit (the scheduler's own
    /// `max_running` still applies independently).
    pub max_limit: usize,
    /// Starting limit.
    pub initial_limit: usize,
    /// Additive step per healthy observation window.
    pub increase: f64,
    /// Multiplicative factor applied on breach (e.g. 0.5 halves).
    pub decrease: f64,
    /// Minimum new inter-token samples per adjustment decision; smaller
    /// windows would let a single gap swing the limit.
    pub min_samples: u64,
}

impl Default for AimdConfig {
    fn default() -> Self {
        AimdConfig {
            target_itl_s: 0.050,
            min_limit: 1,
            max_limit: 64,
            initial_limit: 8,
            increase: 1.0,
            decrease: 0.5,
            min_samples: 8,
        }
    }
}

/// AIMD concurrency-limit controller.
///
/// Fed the engine's *cumulative* inter-token totals (count, sum) each
/// worker-loop iteration via [`observe_totals`](Self::observe_totals);
/// it adjusts once at least [`AimdConfig::min_samples`] new gaps have
/// accumulated, comparing the window's mean against the SLO target.
#[derive(Debug, Clone)]
pub struct AimdController {
    cfg: AimdConfig,
    limit: f64,
    seen_count: u64,
    seen_sum: f64,
}

impl AimdController {
    pub fn new(cfg: AimdConfig) -> Self {
        let limit =
            (cfg.initial_limit as f64).clamp(cfg.min_limit as f64, cfg.max_limit as f64);
        AimdController { cfg, limit, seen_count: 0, seen_sum: 0.0 }
    }

    /// Current integer limit (floor of the fractional state, at least
    /// `min_limit` — additive probing accumulates fractionally).
    pub fn limit(&self) -> usize {
        (self.limit as usize).max(self.cfg.min_limit)
    }

    /// Feed cumulative (count, sum) inter-token totals, e.g. from
    /// `EngineMetrics::inter_token_totals`. Returns `true` if the limit
    /// was adjusted this call.
    pub fn observe_totals(&mut self, count: u64, sum: f64) -> bool {
        let new = count.saturating_sub(self.seen_count);
        if new < self.cfg.min_samples {
            return false;
        }
        let window_mean = (sum - self.seen_sum) / new as f64;
        self.seen_count = count;
        self.seen_sum = sum;
        if window_mean > self.cfg.target_itl_s {
            self.limit = (self.limit * self.cfg.decrease).max(self.cfg.min_limit as f64);
        } else {
            self.limit = (self.limit + self.cfg.increase).min(self.cfg.max_limit as f64);
        }
        true
    }
}

/// Bounded FCFS admission queue with per-entry deadlines.
///
/// Generic over the payload so the policy is testable without an
/// engine; the router queues its (prompt, params, reply-sender)
/// triples. Depth enforcement lives at the submit side (the router
/// rejects before enqueueing); this structure owns ordering and
/// deadline shedding.
#[derive(Debug)]
pub struct AdmissionQueue<T> {
    items: VecDeque<(Instant, T)>,
}

impl<T> Default for AdmissionQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> AdmissionQueue<T> {
    pub fn new() -> Self {
        AdmissionQueue { items: VecDeque::new() }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn push(&mut self, deadline: Instant, item: T) {
        self.items.push_back((deadline, item));
    }

    /// Remove and return every entry whose deadline is at or before
    /// `now` (arrival order preserved). Run before admitting, so
    /// expired requests are shed instead of scheduled.
    pub fn shed_expired(&mut self, now: Instant) -> Vec<T> {
        let mut shed = Vec::new();
        let mut kept = VecDeque::with_capacity(self.items.len());
        for (deadline, item) in self.items.drain(..) {
            if deadline <= now {
                shed.push(item);
            } else {
                kept.push_back((deadline, item));
            }
        }
        self.items = kept;
        shed
    }

    /// Pop the oldest entry (FCFS).
    pub fn pop(&mut self) -> Option<(Instant, T)> {
        self.items.pop_front()
    }

    /// Drain every entry (worker teardown: fail them all explicitly).
    pub fn drain_all(&mut self) -> Vec<T> {
        self.items.drain(..).map(|(_, item)| item).collect()
    }
}

/// Admission-layer configuration, carried in `RouterConfig`.
#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    /// Max requests queued in front of each worker (accepted but not
    /// yet handed to the engine) before submit sheds with
    /// [`SubmitError::QueueFull`].
    pub queue_depth: usize,
    /// Server-side deadline (ms) applied when the client sends no
    /// `timeout_ms`.
    pub default_deadline_ms: u64,
    /// AIMD concurrency-limit tunables.
    pub aimd: AimdConfig,
    /// Engine crashes tolerated per worker before it is declared dead
    /// (supervision stops respawning and the worker goes permanently
    /// unhealthy).
    pub max_restarts: usize,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            queue_depth: 64,
            default_deadline_ms: 30_000,
            aimd: AimdConfig::default(),
            max_restarts: 8,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn submit_error_display_is_actionable() {
        assert!(SubmitError::QueueFull { retry_after_ms: 120 }.to_string().contains("120 ms"));
        assert!(SubmitError::DeadlineExceeded.to_string().contains("deadline"));
        assert_eq!(
            SubmitError::PromptTooLong { reason: "needs 99 tokens".into() }.to_string(),
            "needs 99 tokens"
        );
        assert!(SubmitError::WorkerFailed.to_string().contains("worker"));
    }

    #[test]
    fn aimd_additive_increase_under_target() {
        let mut c = AimdController::new(AimdConfig {
            target_itl_s: 0.05,
            initial_limit: 4,
            min_samples: 8,
            ..Default::default()
        });
        assert_eq!(c.limit(), 4);
        // 8 gaps averaging 10 ms — healthy → +1.
        assert!(c.observe_totals(8, 8.0 * 0.010));
        assert_eq!(c.limit(), 5);
        // Another healthy window on top of the cumulative totals.
        assert!(c.observe_totals(16, 16.0 * 0.010));
        assert_eq!(c.limit(), 6);
    }

    #[test]
    fn aimd_multiplicative_decrease_on_breach() {
        let mut c = AimdController::new(AimdConfig {
            target_itl_s: 0.05,
            initial_limit: 8,
            min_samples: 4,
            decrease: 0.5,
            ..Default::default()
        });
        // Window mean 200 ms >> 50 ms target → halve.
        assert!(c.observe_totals(4, 4.0 * 0.200));
        assert_eq!(c.limit(), 4);
        assert!(c.observe_totals(8, 8.0 * 0.200));
        assert_eq!(c.limit(), 2);
        assert!(c.observe_totals(12, 12.0 * 0.200));
        assert_eq!(c.limit(), 1);
        // Clamped at the floor — capacity never sheds to zero.
        assert!(c.observe_totals(16, 16.0 * 0.200));
        assert_eq!(c.limit(), 1);
    }

    #[test]
    fn aimd_waits_for_min_samples() {
        let mut c = AimdController::new(AimdConfig { min_samples: 8, ..Default::default() });
        let before = c.limit();
        // 7 new samples: no decision yet, regardless of their mean.
        assert!(!c.observe_totals(7, 7.0 * 10.0));
        assert_eq!(c.limit(), before);
        // The 8th completes the window (cumulative totals include all 8).
        assert!(c.observe_totals(8, 8.0 * 0.001));
        assert_eq!(c.limit(), before + 1);
    }

    #[test]
    fn aimd_ceiling_is_respected() {
        let mut c = AimdController::new(AimdConfig {
            initial_limit: 63,
            max_limit: 64,
            min_samples: 1,
            ..Default::default()
        });
        c.observe_totals(1, 0.0);
        c.observe_totals(2, 0.0);
        c.observe_totals(3, 0.0);
        assert_eq!(c.limit(), 64);
    }

    #[test]
    fn queue_fcfs_and_deadline_shedding() {
        let mut q: AdmissionQueue<u32> = AdmissionQueue::new();
        let now = Instant::now();
        q.push(now + Duration::from_secs(10), 1);
        q.push(now, 2); // already expired
        q.push(now + Duration::from_secs(10), 3);
        assert_eq!(q.len(), 3);
        let shed = q.shed_expired(now);
        assert_eq!(shed, vec![2]);
        assert_eq!(q.len(), 2);
        // FCFS order among survivors.
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 3);
        assert!(q.pop().is_none());
    }

    #[test]
    fn queue_drain_all_empties_in_order() {
        let mut q: AdmissionQueue<&str> = AdmissionQueue::new();
        let now = Instant::now();
        q.push(now + Duration::from_secs(1), "a");
        q.push(now + Duration::from_secs(2), "b");
        assert_eq!(q.drain_all(), vec!["a", "b"]);
        assert!(q.is_empty());
    }
}
