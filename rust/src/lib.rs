//! # Opt-GPTQ
//!
//! A reproduction of *"Opt-GPTQ: An Optimized GPTQ Combining Sparse
//! Attention and Quantization Techniques"* (CS.DC 2025) as a three-layer
//! Rust + JAX + Pallas serving stack:
//!
//! * **Layer 3 (this crate)** — a vLLM-style coordinator: request router,
//!   continuous-batching scheduler, paged KV-cache manager, GPTQ weight
//!   quantizer, and a PJRT runtime that executes AOT-compiled HLO.
//! * **Layer 2 (`python/compile/model.py`)** — the Llama-style GQA model
//!   authored in JAX and lowered once to HLO text (`make artifacts`).
//! * **Layer 1 (`python/compile/kernels/`)** — Pallas kernels for paged
//!   grouped-query attention with fused ALiBi and for GPTQ int4
//!   dequant-matmul.
//!
//! Python never runs on the request path: the engine is a self-contained
//! Rust binary once `artifacts/` is built.
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`util`] | offline-environment substrates: JSON, CLI, RNG, bench + property-test harnesses |
//! | [`tensor`] | minimal row-major f32 ndarray with the ops the native backend needs; [`tensor::simd`] runtime-dispatched kernel table (AVX2/scalar, bit-identical) |
//! | [`tokenizer`] | byte-level tokenizer (vocab 256 + specials) |
//! | [`kvcache`] | paged block allocator, block tables, [`kvcache::KvStore`] pools (f32 + packed 8-bit), crash-safe disk spill tier ([`kvcache::SpillTier`]), contiguous baseline, stats |
//! | [`quant`] | GPTQ (Hessian/Cholesky, error propagation), RTN baseline, int4/int8 packing, fused dequant-matmul ([`quant::matmul`]) |
//! | [`attention`] | block-tiled group-major kernel core ([`attention::kernel`]) + MHA / GQA / ALiBi / sparsity (windows, sinks, tile skip) / paged drivers |
//! | [`model`] | Llama-architecture config, [`model::WeightStore`] (dense f32 / packed GPTQ), native forward, sampler |
//! | [`runtime`] | PJRT client (stubbed offline), artifact manifest, the persistent worker pool (`runtime::pool`), `Backend` trait with the `forward_step` mixed-batch entry point (Native / Xla) |
//! | [`coordinator`] | sequence state machine, token-budget mixed-step scheduler (interleaved chunked prefill), batcher, router, engine, metrics |
//! | [`obs`] | telemetry: lock-free metrics registry + log₂ latency histograms, per-request trace rings, crash flight recorder, Prometheus exposition |
//! | [`server`] | threaded TCP/HTTP front-end speaking the JSON API |
//! | [`workload`] | synthetic request-trace generator (Poisson arrivals) |
//!
//! The request path (coordinator → model → attention kernel → kvcache),
//! the Workspace/threading/bench contracts, and the storage-dtype design
//! are documented end to end in `ARCHITECTURE.md` at the repo root; the
//! sections below are the contract summaries.
//!
//! ## Mixed-step scheduling (continuous batching)
//!
//! Every engine step is one token-budget **mixed batch**
//! (`SchedulerConfig::step_token_budget`): the scheduler plans decode
//! tokens for every running sequence *first*, then fills the leftover
//! budget with interleaved prefill chunks (a prompt spans multiple
//! steps via the sequence's `prefill_pos` cursor), so a long prompt can
//! never stall the decoders — and one prefill token per step is
//! guaranteed, so decode load can't starve admission. The engine
//! executes the whole plan through one `Backend::forward_step` call;
//! backends that can't resume prefill mid-sequence (the XLA artifacts,
//! `Backend::supports_mixed_step`) fall back to the exclusive
//! whole-prompt planner. Interleaving is **invisible to sampling**:
//! every sequence's computation is bit-identical to the step-serial
//! schedule, so outputs never depend on the budget (enforced by
//! `coordinator::engine` tests).
//!
//! ## Attention kernel core and the worker-pool threading model
//!
//! Both native attention paths — paged-native prefill and paged decode
//! — are thin drivers over one block-tiled, group-major, online-softmax
//! kernel ([`attention::kernel`]); cache blocks are the kernel's tiles
//! on both. Scratch lives in a reusable [`attention::Workspace`]; the
//! contract is that callers may (and should) reuse one workspace across
//! calls of any shape, making steady-state attention allocation-free.
//! The allocating wrappers route through a thread-local workspace.
//!
//! `NativeBackend::forward_step` executes a continuous-batching mixed
//! step as one pass: weights stream from memory once per **step**
//! across prefill-chunk rows and decode rows alike
//! (`NativeModel::forward_mixed`), and both attention fan-outs run on
//! the **persistent worker pool** ([`runtime::pool`]) — workers spawned
//! once and parked while idle, so the per-layer cost is a job dispatch,
//! not a thread spawn; each worker's thread-local workspace lives
//! across jobs, layers and steps. Fan-out *widths* partition the work:
//! auto-sized (`auto_decode_threads` / `auto_prefill_threads`),
//! pinnable via `NativeBackend::with_decode_threads` /
//! `with_prefill_threads`, and bit-identical to serial execution at
//! every width and every pool size.
//!
//! ## KV storage dtypes — no dense copies
//!
//! The engine reads and writes KV through the [`kvcache::KvStore`]
//! trait; `EngineConfig::kv_dtype` picks dense f32
//! ([`kvcache::PagedKvCache`]) or packed 8-bit
//! ([`kvcache::QuantizedPagedKvCache`]: quantize-on-append,
//! per-(block, kv_head) grids, ~0.26× the pool bytes). Both prefill and
//! decode walk KV tiles straight out of the block table
//! (`KvBlockView`): quantized blocks are dequantized **per tile inside
//! the kernel** into workspace scratch — on the prefill walk once per
//! tile, shared by every query row that sees it — so both dtypes share
//! one attention schedule, the zero-alloc contract, and a hot path
//! that never materializes the context densely (`KvStore::gather` is a
//! metered test/debug dump; `CacheStats::gather_bytes` ≈ 0).
//! `tests/attention_parity.rs` bounds the quantized path's output error
//! (decode and streamed prefill) and `tests/alloc_steadystate.rs`
//! audits the allocation contract with a counting allocator.
//!
//! Below the RAM pool sits an **opt-in disk spill tier**
//! ([`kvcache::SpillTier`], `EngineConfig::spill` / `--spill-dir`):
//! prefix-cache-evicted blocks are appended to crash-safe CRC-checked
//! segment files and restored bit-identically at admission on a later
//! prefix match; every IO failure degrades toward recompute (circuit
//! breaker, quarantine), never toward a request error, and the
//! `None` default performs zero file IO. Contract: ARCHITECTURE.md
//! "Spill & recovery contract".
//!
//! ## Sparse attention — windows, sinks, score-bound skipping
//!
//! [`attention::SparsityConfig`] (dense by default — every parity
//! baseline assumes it) adds three opt-in mechanisms over the existing
//! block-tile partition, so prefill and decode agree on visibility by
//! construction: a **sliding window** plus **sink blocks** clip which
//! KV tiles a query folds (ALiBi composes untouched); the scheduler
//! **evicts** KV blocks strictly behind every possible future window
//! each step (tombstoned in the table, freed to the allocator as
//! immediate admission headroom — a live sequence's pool usage
//! plateaus at `sink + window + 1` blocks); and per-(block, kv_head)
//! key min/max bounds maintained by both [`kvcache::KvStore`] pools
//! feed a **score-bound tile skip** in the online-softmax pass —
//! *exact* at `skip_threshold == 0.0` (skips only below f32 `exp`
//! underflow, bit-identical to the unskipped walk) or lossy with a
//! tested error bound at an explicit `0 < t < 1`. Enforced by
//! `tests/sparse_parity.rs` and the eviction/bound properties in
//! `tests/properties.rs`; `RunReport::{skipped_tiles, evicted_blocks}`
//! meter both (asserted 0 under the dense default). Full contract:
//! ARCHITECTURE.md "Sparsity contract".
//!
//! ## Kernel dispatch — SIMD without losing bit-identity
//!
//! Every architecture-specific instruction lives in [`tensor::simd`]: a
//! table of kernel function pointers (`dot`, `nt_block8`, `axpy`, and
//! the integer `q8_dot`/`q8_sum`) resolved once at first use — AVX2
//! when `is_x86_feature_detected!("avx2")` holds, the scalar reference
//! otherwise (`OPT_GPTQ_NO_SIMD=1` forces scalar; non-x86 builds
//! compile scalar only). The SIMD kernels freeze the scalar
//! accumulation order (no FMA contraction), so **dispatch never
//! changes bits** and every determinism contract in this crate holds
//! identically on every host (`tests/simd_parity.rs`; `verify.sh` runs
//! the suite under both settings). The same table powers the opt-in
//! **integer-domain q8 attention scoring**
//! (`ModelConfig::score_domain` / `--q8-score-domain int`): the query
//! is quantized once per (row, kv-head) and packed K tiles are scored
//! with widening integer dots, rescaled once per tile — no K dequant
//! on the score side; not bit-identical to f32 scoring (bounded
//! query-quantization error, tested), hence config-gated off by
//! default and inert on f32 caches.
//!
//! ## Weight storage dtypes — packed GPTQ serving
//!
//! Weights follow the same design through [`model::WeightStore`]:
//! `EngineConfig::weight_dtype` picks dense f32
//! ([`model::ModelWeights`]) or the packed store
//! ([`model::PackedModelWeights`]: GPTQ/RTN integer levels + group
//! grids, int3/int4/int8, produced by
//! `model::weights::quantize_weights_packed` with no dequantized
//! round-trip). The forward pass reads packed projections through the
//! fused group-wise dequant-matmul ([`quant::matmul`]): weight row
//! tiles are dequantized **once** into reusable workspace scratch
//! (zero-alloc steady state, same discipline as the attention
//! workspace) and shared across the step's activation rows, fanned
//! over the worker pool on prefill/mixed steps. The kernel reproduces
//! `tensor::matmul_nt`'s exact accumulation order, so packed serving
//! is **bit-identical** to serving the dequantized reconstruction —
//! every determinism/interleaving contract above holds at any weight
//! dtype (`tests/weights_parity.rs`). Eager `.dequantize()` is
//! grep-gated off the serving files by `scripts/verify.sh`; q4
//! projections cost ≈0.16× their f32 bytes (tracked in
//! `BENCH_gptq.json`).
//!
//! ## Observability — telemetry that cannot perturb the engine
//!
//! Every worker owns an [`obs::Telemetry`]: a lock-free registry of
//! atomic counters/gauges (a once-per-step mirror of
//! `EngineMetrics`), six per-phase step-time histograms
//! (plan/prefill/decode/sample/spill/evict, log₂-scale µs buckets), a
//! bounded per-request trace ring, and a crash flight recorder the
//! supervisor dumps on worker panic. All storage is preallocated at
//! construction, so the zero-alloc steady-state contract extends to
//! armed telemetry; spans are stamped at the coordinator layer only —
//! never inside kernels (`verify.sh` grep-gates clock reads off the
//! kernel hot files) — so bit-identity is untouched by construction.
//! The server exposes it at `GET /metrics` (Prometheus text,
//! per-worker labels), `GET /debug/trace/{id}` and
//! `GET /debug/flight`. Full contract: ARCHITECTURE.md "Observability
//! contract".

pub mod attention;
pub mod coordinator;
pub mod kvcache;
pub mod model;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod server;
pub mod tensor;
pub mod tokenizer;
pub mod util;
pub mod workload;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
