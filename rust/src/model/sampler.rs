//! Token sampling: greedy and top-k/temperature.

use crate::tokenizer::VOCAB_SIZE;
use crate::util::rng::Rng;

/// Per-request sampling configuration.
#[derive(Debug, Clone, Copy)]
pub struct SamplingParams {
    /// 0.0 → greedy.
    pub temperature: f32,
    /// 0 → full vocabulary.
    pub top_k: usize,
    /// Keep generating even if EOS is sampled (benches use fixed
    /// generation lengths, like the paper's workload).
    pub ignore_eos: bool,
    pub max_tokens: usize,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams { temperature: 0.0, top_k: 0, ignore_eos: true, max_tokens: 32 }
    }
}

/// Stateful sampler (owns its RNG for reproducibility per sequence).
#[derive(Debug, Clone)]
pub struct Sampler {
    rng: Rng,
}

impl Sampler {
    pub fn new(seed: u64) -> Self {
        Sampler { rng: Rng::new(seed) }
    }

    /// Sample a token id from `logits`. Only real token ids
    /// (`0..VOCAB_SIZE`) are candidates — the embedding rows padding the
    /// vocab to an MXU-friendly size are masked out.
    pub fn sample(&mut self, logits: &[f32], params: &SamplingParams) -> u32 {
        let n = logits.len().min(VOCAB_SIZE);
        let live = &logits[..n];
        if params.temperature <= 0.0 {
            return argmax(live) as u32;
        }
        // Top-k selection.
        let k = if params.top_k == 0 { n } else { params.top_k.min(n) };
        let mut idx: Vec<usize> = (0..n).collect();
        idx.sort_by(|&a, &b| live[b].partial_cmp(&live[a]).unwrap_or(std::cmp::Ordering::Equal));
        idx.truncate(k);
        // Softmax over the survivors at the given temperature.
        let inv_t = 1.0 / params.temperature;
        let max = live[idx[0]];
        let weights: Vec<f32> = idx.iter().map(|&i| ((live[i] - max) * inv_t).exp()).collect();
        let total: f32 = weights.iter().sum();
        let mut u = self.rng.f32() * total;
        for (j, &w) in weights.iter().enumerate() {
            if u < w {
                return idx[j] as u32;
            }
            u -= w;
        }
        idx[k - 1] as u32
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let mut s = Sampler::new(1);
        let mut logits = vec![0.0f32; VOCAB_SIZE];
        logits[42] = 5.0;
        let p = SamplingParams::default();
        assert_eq!(s.sample(&logits, &p), 42);
    }

    #[test]
    fn padded_vocab_rows_never_sampled() {
        let mut s = Sampler::new(2);
        let mut logits = vec![0.0f32; 384]; // padded vocab
        logits[VOCAB_SIZE + 5] = 100.0; // huge logit in the padding region
        logits[7] = 1.0;
        let p = SamplingParams::default();
        assert_eq!(s.sample(&logits, &p), 7);
        let p_hot = SamplingParams { temperature: 1.0, top_k: 10, ..p };
        for _ in 0..100 {
            assert!((s.sample(&logits, &p_hot) as usize) < VOCAB_SIZE);
        }
    }

    #[test]
    fn top_k_restricts_support() {
        let mut s = Sampler::new(3);
        let mut logits = vec![0.0f32; VOCAB_SIZE];
        logits[1] = 10.0;
        logits[2] = 9.0;
        logits[3] = 1.0;
        let p = SamplingParams { temperature: 1.0, top_k: 2, ..Default::default() };
        for _ in 0..200 {
            let t = s.sample(&logits, &p);
            assert!(t == 1 || t == 2, "sampled {t} outside top-2");
        }
    }

    #[test]
    fn temperature_zero_is_deterministic() {
        let mut s1 = Sampler::new(4);
        let mut s2 = Sampler::new(999);
        let logits: Vec<f32> = (0..VOCAB_SIZE).map(|i| (i % 37) as f32).collect();
        let p = SamplingParams::default();
        assert_eq!(s1.sample(&logits, &p), s2.sample(&logits, &p));
    }

    #[test]
    fn hot_temperature_explores() {
        let mut s = Sampler::new(5);
        let logits = vec![0.0f32; VOCAB_SIZE]; // uniform
        let p = SamplingParams { temperature: 1.0, top_k: 0, ..Default::default() };
        let samples: std::collections::BTreeSet<u32> =
            (0..100).map(|_| s.sample(&logits, &p)).collect();
        assert!(samples.len() > 10, "only {} distinct samples", samples.len());
    }
}
