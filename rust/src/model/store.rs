//! The [`WeightStore`] abstraction: one protocol, two weight dtypes —
//! the weight-side twin of `kvcache::KvStore`.
//!
//! Everything above the parameters — the native forward pass, the
//! backends, the engine — reaches projection weights through this trait,
//! so the dense f32 store ([`crate::model::ModelWeights`]) and the packed
//! store ([`PackedModelWeights`]: GPTQ/RTN integer levels +
//! per-(row, group) grids, int3/int4/int8) are interchangeable at
//! runtime. Engines pick the implementation with [`WeightDtype`]
//! (`EngineConfig::weight_dtype`).
//!
//! The serving contract (see ARCHITECTURE.md "Packed-weight serving"):
//!
//! * **Bit-identity** — [`WeightStore::proj_into`] on a packed store is
//!   bit-identical to the dense store holding the eagerly-dequantized
//!   reconstruction: the fused kernel (`quant::matmul`) reproduces
//!   `tensor::matmul_nt_into`'s exact accumulation order over
//!   tile-dequantized rows, so switching `weight_dtype` never perturbs
//!   scheduling, sampling, or the interleaving/determinism tests.
//! * **No eager dequant** — packed matrices are dequantized per row-tile
//!   inside the matmul into workspace scratch (`scripts/verify.sh`
//!   grep-gates `.dequantize()` off this file and the forward pass);
//!   steady-state packed matmuls allocate nothing.
//! * **Embedding / LM head / norms stay f32** — standard GPTQ practice;
//!   only the seven projection matrices per layer are packed.
//!
//! The trait is object-safe on purpose: [`crate::model::NativeModel`]
//! holds an `Arc<dyn WeightStore>` so one model type serves both dtypes.

use super::config::ModelConfig;
use super::weights::{LayerWeights, ModelWeights};
use crate::quant::matmul::{
    auto_gemv_threads, auto_matmul_threads, dense_matmul_rows_parallel,
    packed_gemv_cols_parallel, packed_matmul_rows_parallel, MIN_DENSE_ROWS_PER_JOB,
    MIN_PACKED_ROWS_PER_JOB,
};
use crate::quant::packing::{pack_rows, PackedMatrix};
use crate::quant::QuantizedMatrix;
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

/// Storage dtype of the weight store (the engine-config knob; the
/// weight-side twin of `kvcache::KvCacheDtype`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WeightDtype {
    /// Dense f32 tensors — 4 bytes per weight.
    #[default]
    F32,
    /// Packed 8-bit levels (byte fields) + group grids.
    Q8,
    /// Packed 4-bit levels (nibble fields) + group grids — the paper's
    /// headline GPTQ configuration (~0.16× the projection bytes at
    /// group 64).
    Q4,
    /// Packed 3-bit levels (stored in nibble fields; byte accounting
    /// reports nibble bytes) + group grids.
    Q3,
}

impl WeightDtype {
    /// Parse a CLI/config name (`"f32"` | `"q8"` | `"q4"` | `"q3"`).
    pub fn parse(name: &str) -> Option<WeightDtype> {
        match name {
            "f32" => Some(WeightDtype::F32),
            "q8" => Some(WeightDtype::Q8),
            "q4" => Some(WeightDtype::Q4),
            "q3" => Some(WeightDtype::Q3),
            _ => None,
        }
    }

    /// Quantization bit width; `None` for dense f32.
    pub fn bits(self) -> Option<u32> {
        match self {
            WeightDtype::F32 => None,
            WeightDtype::Q8 => Some(8),
            WeightDtype::Q4 => Some(4),
            WeightDtype::Q3 => Some(3),
        }
    }

    /// Dtype for a packed bit width (the widths the serving path
    /// supports; the packing format itself goes down to 2 bits).
    pub fn from_bits(bits: u32) -> Option<WeightDtype> {
        match bits {
            8 => Some(WeightDtype::Q8),
            4 => Some(WeightDtype::Q4),
            3 => Some(WeightDtype::Q3),
            _ => None,
        }
    }
}

/// One of the seven projection matrices of a decoder layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Proj {
    Wq,
    Wk,
    Wv,
    Wo,
    WGate,
    WUp,
    WDown,
}

impl Proj {
    /// Canonical layer order (matches `ModelWeights::matrices`).
    pub const ALL: [Proj; 7] =
        [Proj::Wq, Proj::Wk, Proj::Wv, Proj::Wo, Proj::WGate, Proj::WUp, Proj::WDown];

    pub fn name(self) -> &'static str {
        match self {
            Proj::Wq => "wq",
            Proj::Wk => "wk",
            Proj::Wv => "wv",
            Proj::Wo => "wo",
            Proj::WGate => "w_gate",
            Proj::WUp => "w_up",
            Proj::WDown => "w_down",
        }
    }
}

/// Model parameters servable by the native forward pass, in whichever
/// representation the store holds them.
///
/// `proj_into` is the single hot-path entry: `out = a · Wᵀ` for the
/// requested layer/projection, with `threads == 0` auto-sizing the row
/// fan-out over the persistent worker pool (small calls stay serial).
/// Outputs are bit-identical at every width and across implementations
/// holding numerically-equal weights (the packed-serving contract).
pub trait WeightStore: Send + Sync + std::fmt::Debug {
    fn config(&self) -> &ModelConfig;

    /// Storage dtype (mirrors the engine's [`WeightDtype`] choice).
    fn dtype(&self) -> WeightDtype;

    /// Token embedding table (`[vocab, d_model]`, always f32).
    fn embed(&self) -> &Tensor;

    /// LM head (`[vocab, d_model]`, always f32).
    fn lm_head(&self) -> &Tensor;

    /// Final RMSNorm scale (`[d_model]`).
    fn final_norm(&self) -> &[f32];

    /// Attention-block RMSNorm scale of one layer.
    fn rms_attn(&self, layer: usize) -> &[f32];

    /// MLP-block RMSNorm scale of one layer.
    fn rms_mlp(&self, layer: usize) -> &[f32];

    /// Output features of `(layer, p)` (the matmul's `n`).
    fn proj_rows(&self, layer: usize, p: Proj) -> usize;

    /// `out = a · W(layer, p)ᵀ`: `a` is `[m, in_features]` row-major,
    /// `out` is `[m, proj_rows]` and fully overwritten. `threads == 0`
    /// auto-sizes the row fan-out; any width is bit-identical.
    fn proj_into(&self, layer: usize, p: Proj, a: &[f32], m: usize, threads: usize, out: &mut [f32]);

    /// True bytes held by the store (packed payload + grids for packed
    /// stores; embedding/LM head/norms are f32 in both).
    fn weight_bytes(&self) -> usize;

    /// Downcast to the dense f32 weights, if that is what this store is
    /// (the XLA upload path and the dense save path need raw tensors).
    fn dense(&self) -> Option<&ModelWeights> {
        None
    }

    /// Downcast to the packed store, if that is what this store is.
    fn packed(&self) -> Option<&PackedModelWeights> {
        None
    }
}

fn dense_proj<'a>(l: &'a LayerWeights, p: Proj) -> &'a Tensor {
    match p {
        Proj::Wq => &l.wq,
        Proj::Wk => &l.wk,
        Proj::Wv => &l.wv,
        Proj::Wo => &l.wo,
        Proj::WGate => &l.w_gate,
        Proj::WUp => &l.w_up,
        Proj::WDown => &l.w_down,
    }
}

impl WeightStore for ModelWeights {
    fn config(&self) -> &ModelConfig {
        &self.config
    }
    fn dtype(&self) -> WeightDtype {
        WeightDtype::F32
    }
    fn embed(&self) -> &Tensor {
        &self.embed
    }
    fn lm_head(&self) -> &Tensor {
        &self.lm_head
    }
    fn final_norm(&self) -> &[f32] {
        &self.final_norm
    }
    fn rms_attn(&self, layer: usize) -> &[f32] {
        &self.layers[layer].rms_attn
    }
    fn rms_mlp(&self, layer: usize) -> &[f32] {
        &self.layers[layer].rms_mlp
    }
    fn proj_rows(&self, layer: usize, p: Proj) -> usize {
        dense_proj(&self.layers[layer], p).shape()[0]
    }
    fn proj_into(&self, layer: usize, p: Proj, a: &[f32], m: usize, threads: usize, out: &mut [f32]) {
        let t = dense_proj(&self.layers[layer], p);
        let (n, k) = (t.shape()[0], t.shape()[1]);
        let threads = if threads == 0 {
            auto_matmul_threads(m, n, k, MIN_DENSE_ROWS_PER_JOB)
        } else {
            threads
        };
        dense_matmul_rows_parallel(a, m, k, t.data(), n, threads, out);
    }
    fn weight_bytes(&self) -> usize {
        self.f32_bytes()
    }
    fn dense(&self) -> Option<&ModelWeights> {
        Some(self)
    }
}

/// One packed projection: the [`PackedMatrix`] payload (integer levels +
/// per-(row, group) scale/zero grids) plus the *true* quantization bit
/// width (3-bit levels ride in 4-bit storage fields).
#[derive(Debug, Clone)]
pub struct PackedProjection {
    pub w: PackedMatrix,
    pub bits: u32,
}

impl PackedProjection {
    /// Pack a freshly-quantized matrix — the calibration → serving
    /// handoff, with no dequantized f32 round-trip in between.
    pub fn from_quantized(qm: &QuantizedMatrix) -> PackedProjection {
        PackedProjection { w: pack_rows(qm), bits: qm.bits }
    }

    pub fn rows(&self) -> usize {
        self.w.rows
    }

    pub fn cols(&self) -> usize {
        self.w.cols
    }

    /// Bytes actually held (packed words + grids).
    pub fn packed_bytes(&self) -> usize {
        self.w.packed_bytes()
    }
}

/// One decoder layer's packed parameters (norms stay f32).
#[derive(Debug, Clone)]
pub struct QuantizedLayerWeights {
    pub wq: PackedProjection,
    pub wk: PackedProjection,
    pub wv: PackedProjection,
    pub wo: PackedProjection,
    pub w_gate: PackedProjection,
    pub w_up: PackedProjection,
    pub w_down: PackedProjection,
    pub rms_attn: Vec<f32>,
    pub rms_mlp: Vec<f32>,
}

impl QuantizedLayerWeights {
    pub fn proj(&self, p: Proj) -> &PackedProjection {
        match p {
            Proj::Wq => &self.wq,
            Proj::Wk => &self.wk,
            Proj::Wv => &self.wv,
            Proj::Wo => &self.wo,
            Proj::WGate => &self.w_gate,
            Proj::WUp => &self.w_up,
            Proj::WDown => &self.w_down,
        }
    }
}

/// Packed model parameters — the [`WeightStore`] the engine serves from
/// when `EngineConfig::weight_dtype` is a quantized dtype. Produced by
/// `model::weights::quantize_weights_packed` (GPTQ or RTN calibration,
/// straight to packed storage) or loaded from the packed artifact format
/// ([`PackedModelWeights::load`]).
#[derive(Debug, Clone)]
pub struct PackedModelWeights {
    pub config: ModelConfig,
    /// Quantization bit width of every projection (3 | 4 | 8).
    pub bits: u32,
    /// Columns per scale/zero group used at calibration time (per-matrix
    /// group sizes can differ — GPTQ `act_order` stores per-column
    /// grids — so this is the *requested* group size, report surface
    /// only).
    pub group_size: usize,
    pub embed: Tensor,
    pub layers: Vec<QuantizedLayerWeights>,
    pub final_norm: Vec<f32>,
    pub lm_head: Tensor,
}

/// Packed-artifact magic: `OGPTQP` + 2-digit format version. Bump the
/// version on any layout change; [`PackedModelWeights::load`] rejects
/// unknown versions outright.
const PACKED_MAGIC: &[u8; 8] = b"OGPTQP01";

impl PackedModelWeights {
    pub fn dtype(&self) -> WeightDtype {
        WeightDtype::from_bits(self.bits).expect("packed store bit width")
    }

    /// Bytes held by the packed projections alone (the compressible
    /// payload; excludes the always-f32 embedding/LM head/norms).
    pub fn projection_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| Proj::ALL.iter().map(|&p| l.proj(p).packed_bytes()).sum::<usize>())
            .sum()
    }

    // ------------------------------------------------------------------
    // Packed artifact format: `OGPTQP01` magic, config block (same field
    // order as the dense `OGPTQW01` format), bits + group_size, embed,
    // per layer 7 packed matrices (dims + words + grids) + 2 norms,
    // final_norm, lm_head — all little-endian.
    // ------------------------------------------------------------------

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path).with_context(|| format!("create {path:?}"))?,
        );
        f.write_all(PACKED_MAGIC)?;
        let c = &self.config;
        for v in [
            c.vocab, c.d_model, c.n_layers, c.n_heads, c.n_kv_heads, c.d_ff, c.max_seq,
            c.alibi as usize,
        ] {
            f.write_all(&(v as u32).to_le_bytes())?;
        }
        f.write_all(&c.rms_eps.to_le_bytes())?;
        f.write_all(&self.bits.to_le_bytes())?;
        f.write_all(&(self.group_size as u32).to_le_bytes())?;
        let write_f32s = |f: &mut dyn Write, xs: &[f32]| -> Result<()> {
            for v in xs {
                f.write_all(&v.to_le_bytes())?;
            }
            Ok(())
        };
        let write_packed = |f: &mut dyn Write, p: &PackedProjection| -> Result<()> {
            for v in [p.w.rows, p.w.cols, p.w.group_size, p.w.words_per_row] {
                f.write_all(&(v as u32).to_le_bytes())?;
            }
            f.write_all(&p.w.pack_bits.to_le_bytes())?;
            for w in &p.w.words {
                f.write_all(&w.to_le_bytes())?;
            }
            for s in &p.w.scales {
                f.write_all(&s.to_le_bytes())?;
            }
            for z in &p.w.zeros {
                f.write_all(&z.to_le_bytes())?;
            }
            Ok(())
        };
        write_f32s(&mut f, self.embed.data())?;
        for l in &self.layers {
            for p in Proj::ALL {
                write_packed(&mut f, l.proj(p))?;
            }
            write_f32s(&mut f, &l.rms_attn)?;
            write_f32s(&mut f, &l.rms_mlp)?;
        }
        write_f32s(&mut f, &self.final_norm)?;
        write_f32s(&mut f, self.lm_head.data())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<PackedModelWeights> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("open {path:?}"))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != PACKED_MAGIC {
            bail!(
                "bad packed-weights magic {magic:?} (expected {:?}; dense artifacts start \
                 with OGPTQW01 — use ModelWeights::load)",
                PACKED_MAGIC
            );
        }
        let read_u32 = |f: &mut dyn Read| -> Result<usize> {
            let mut b = [0u8; 4];
            f.read_exact(&mut b)?;
            Ok(u32::from_le_bytes(b) as usize)
        };
        let read_f32 = |f: &mut dyn Read| -> Result<f32> {
            let mut b = [0u8; 4];
            f.read_exact(&mut b)?;
            Ok(f32::from_le_bytes(b))
        };
        let vocab = read_u32(&mut f)?;
        let d_model = read_u32(&mut f)?;
        let n_layers = read_u32(&mut f)?;
        let n_heads = read_u32(&mut f)?;
        let n_kv_heads = read_u32(&mut f)?;
        let d_ff = read_u32(&mut f)?;
        let max_seq = read_u32(&mut f)?;
        let alibi = read_u32(&mut f)? != 0;
        let rms_eps = read_f32(&mut f)?;
        let config = ModelConfig {
            vocab,
            d_model,
            n_layers,
            n_heads,
            n_kv_heads,
            d_ff,
            max_seq,
            alibi,
            rms_eps,
            // Runtime serving knobs, never artifact state (see
            // `ModelConfig::sparsity` / `ModelConfig::score_domain`).
            sparsity: Default::default(),
            score_domain: Default::default(),
        };
        // Config sanity before any dimension math (kv_dim/head_dim
        // assert on these; a corrupt header must error, not panic).
        if n_heads == 0
            || n_kv_heads == 0
            || d_model == 0
            || d_model % n_heads != 0
            || n_heads % n_kv_heads != 0
        {
            bail!("packed artifact has an inconsistent model config block");
        }
        let bits = read_u32(&mut f)? as u32;
        if WeightDtype::from_bits(bits).is_none() {
            bail!("packed artifact has unsupported bit width {bits}");
        }
        let group_size = read_u32(&mut f)?;
        let read_f32s = |f: &mut dyn Read, n: usize| -> Result<Vec<f32>> {
            let mut bytes = vec![0u8; n * 4];
            f.read_exact(&mut bytes)?;
            Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
        };
        let read_i32s = |f: &mut dyn Read, n: usize| -> Result<Vec<i32>> {
            let mut bytes = vec![0u8; n * 4];
            f.read_exact(&mut bytes)?;
            Ok(bytes.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect())
        };
        let read_packed = |f: &mut dyn Read, want: (usize, usize)| -> Result<PackedProjection> {
            let rows = read_u32(f)?;
            let cols = read_u32(f)?;
            // Dimensions drive every downstream allocation and slice
            // index, so a corrupt header must fail HERE as a Result,
            // not later as an OOM abort or a mid-serve panic.
            if (rows, cols) != want {
                bail!(
                    "packed matrix is [{rows}, {cols}] but the artifact's config says \
                     [{}, {}]",
                    want.0,
                    want.1
                );
            }
            let mat_group = read_u32(f)?;
            let words_per_row = read_u32(f)?;
            let pack_bits = read_u32(f)? as u32;
            if !(pack_bits == 4 || pack_bits == 8) {
                bail!("packed matrix has bad field width {pack_bits}");
            }
            if mat_group == 0 {
                bail!("packed matrix has zero group size");
            }
            let want_wpr = cols.div_ceil(crate::quant::packing::levels_per_word(pack_bits));
            if words_per_row != want_wpr {
                bail!(
                    "packed matrix header is inconsistent: {cols} cols at {pack_bits}-bit \
                     fields needs {want_wpr} words/row, artifact says {words_per_row}"
                );
            }
            let groups = cols.div_ceil(mat_group);
            let words = read_i32s(f, rows * words_per_row)?;
            let scales = read_f32s(f, rows * groups)?;
            let zeros = read_i32s(f, rows * groups)?;
            Ok(PackedProjection {
                w: PackedMatrix {
                    rows,
                    cols,
                    pack_bits,
                    words_per_row,
                    words,
                    scales,
                    zeros,
                    group_size: mat_group,
                },
                bits,
            })
        };
        let embed = Tensor::from_vec(&[vocab, d_model], read_f32s(&mut f, vocab * d_model)?);
        let kv = config.kv_dim();
        let mut layers = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            let wq = read_packed(&mut f, (d_model, d_model))?;
            let wk = read_packed(&mut f, (kv, d_model))?;
            let wv = read_packed(&mut f, (kv, d_model))?;
            let wo = read_packed(&mut f, (d_model, d_model))?;
            let w_gate = read_packed(&mut f, (d_ff, d_model))?;
            let w_up = read_packed(&mut f, (d_ff, d_model))?;
            let w_down = read_packed(&mut f, (d_model, d_ff))?;
            let rms_attn = read_f32s(&mut f, d_model)?;
            let rms_mlp = read_f32s(&mut f, d_model)?;
            layers.push(QuantizedLayerWeights {
                wq,
                wk,
                wv,
                wo,
                w_gate,
                w_up,
                w_down,
                rms_attn,
                rms_mlp,
            });
        }
        let final_norm = read_f32s(&mut f, d_model)?;
        let lm_head = Tensor::from_vec(&[vocab, d_model], read_f32s(&mut f, vocab * d_model)?);
        Ok(PackedModelWeights { config, bits, group_size, embed, layers, final_norm, lm_head })
    }
}

impl WeightStore for PackedModelWeights {
    fn config(&self) -> &ModelConfig {
        &self.config
    }
    fn dtype(&self) -> WeightDtype {
        PackedModelWeights::dtype(self)
    }
    fn embed(&self) -> &Tensor {
        &self.embed
    }
    fn lm_head(&self) -> &Tensor {
        &self.lm_head
    }
    fn final_norm(&self) -> &[f32] {
        &self.final_norm
    }
    fn rms_attn(&self, layer: usize) -> &[f32] {
        &self.layers[layer].rms_attn
    }
    fn rms_mlp(&self, layer: usize) -> &[f32] {
        &self.layers[layer].rms_mlp
    }
    fn proj_rows(&self, layer: usize, p: Proj) -> usize {
        self.layers[layer].proj(p).rows()
    }
    fn proj_into(&self, layer: usize, p: Proj, a: &[f32], m: usize, threads: usize, out: &mut [f32]) {
        let w = &self.layers[layer].proj(p).w;
        // Decode GEMV (m == 1): the row split is empty, so auto-sized
        // calls fan the *output columns* instead (tile-aligned spans,
        // bit-identical to serial — see `packed_gemv_cols_parallel`).
        // A caller-pinned width keeps the legacy row-split behaviour.
        if m == 1 && threads == 0 {
            return packed_gemv_cols_parallel(a, w, auto_gemv_threads(w.rows, w.cols), out);
        }
        let threads = if threads == 0 {
            auto_matmul_threads(m, w.rows, w.cols, MIN_PACKED_ROWS_PER_JOB)
        } else {
            threads
        };
        packed_matmul_rows_parallel(a, m, w, threads, out);
    }
    fn weight_bytes(&self) -> usize {
        let f32_side = (self.embed.len() + self.lm_head.len()) * 4
            + (self.layers.len() * 2 + 1) * self.config.d_model * 4;
        f32_side + self.projection_bytes()
    }
    fn packed(&self) -> Option<&PackedModelWeights> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::{quantize_weights_packed, QuantMethod};

    #[test]
    fn dtype_parse_bits_roundtrip() {
        assert_eq!(WeightDtype::parse("f32"), Some(WeightDtype::F32));
        assert_eq!(WeightDtype::parse("q8"), Some(WeightDtype::Q8));
        assert_eq!(WeightDtype::parse("q4"), Some(WeightDtype::Q4));
        assert_eq!(WeightDtype::parse("q3"), Some(WeightDtype::Q3));
        assert_eq!(WeightDtype::parse("int4"), None);
        for d in [WeightDtype::Q8, WeightDtype::Q4, WeightDtype::Q3] {
            assert_eq!(WeightDtype::from_bits(d.bits().unwrap()), Some(d));
        }
        assert_eq!(WeightDtype::F32.bits(), None);
        assert_eq!(WeightDtype::from_bits(2), None);
    }

    #[test]
    fn dense_store_serves_the_same_tensors() {
        let cfg = ModelConfig::tiny();
        let w = ModelWeights::init(&cfg, 1);
        let store: &dyn WeightStore = &w;
        assert_eq!(store.dtype(), WeightDtype::F32);
        assert_eq!(store.proj_rows(0, Proj::Wq), cfg.d_model);
        assert_eq!(store.proj_rows(1, Proj::WDown), cfg.d_model);
        assert_eq!(store.proj_rows(1, Proj::WUp), cfg.d_ff);
        assert!(store.dense().is_some());
        assert!(store.packed().is_none());
        // proj_into matches the Tensor reference exactly at any width.
        let m = 3;
        let mut rng = crate::util::rng::Rng::new(2);
        let a = Tensor::from_vec(&[m, cfg.d_model], rng.normal_vec(m * cfg.d_model, 1.0));
        let want = a.matmul_nt(&w.layers[0].wq);
        for threads in [0usize, 1, 4] {
            let mut out = vec![0.0f32; m * cfg.d_model];
            store.proj_into(0, Proj::Wq, a.data(), m, threads, &mut out);
            assert_eq!(out.as_slice(), want.data(), "threads={threads}");
        }
    }

    #[test]
    fn packed_store_save_load_roundtrip_is_exact() {
        let cfg = ModelConfig::tiny();
        let w = ModelWeights::init(&cfg, 3);
        let (packed, _) =
            quantize_weights_packed(&w, QuantMethod::Rtn, 4, 32, false, &[], &[], &[]);
        let dir = std::env::temp_dir().join("opt_gptq_test_weights");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tiny_packed.bin");
        packed.save(&path).unwrap();
        let r = PackedModelWeights::load(&path).unwrap();
        assert_eq!(r.config, cfg);
        assert_eq!(r.bits, 4);
        assert_eq!(r.group_size, 32);
        assert_eq!(r.embed.data(), packed.embed.data());
        assert_eq!(r.lm_head.data(), packed.lm_head.data());
        for (a, b) in r.layers.iter().zip(&packed.layers) {
            for p in Proj::ALL {
                assert_eq!(a.proj(p).w.words, b.proj(p).w.words, "{}", p.name());
                assert_eq!(a.proj(p).w.scales, b.proj(p).w.scales, "{}", p.name());
                assert_eq!(a.proj(p).w.zeros, b.proj(p).w.zeros, "{}", p.name());
                assert_eq!(a.proj(p).bits, 4);
            }
            assert_eq!(a.rms_attn, b.rms_attn);
        }
        // A dense artifact must be rejected by the packed loader (and
        // vice versa — distinct magic).
        let dense_path = dir.join("tiny_dense_for_magic.bin");
        w.save(&dense_path).unwrap();
        assert!(PackedModelWeights::load(&dense_path).is_err());
        assert!(ModelWeights::load(&path).is_err());
        std::fs::remove_file(path).ok();
        std::fs::remove_file(dense_path).ok();
    }

    #[test]
    fn packed_store_reports_shrunk_bytes_and_dtype() {
        // Byte-accounting sanity at store level; the 0.20× acceptance
        // bound lives in tests/weights_parity.rs
        // (q4_projection_bytes_at_most_a_fifth_of_f32).
        let cfg = ModelConfig::tiny();
        let w = ModelWeights::init(&cfg, 4);
        let (q4, _) = quantize_weights_packed(&w, QuantMethod::Rtn, 4, 64, false, &[], &[], &[]);
        assert!(q4.projection_bytes() > 0);
        assert!(WeightStore::weight_bytes(&q4) < w.f32_bytes());
        assert_eq!(q4.dtype(), WeightDtype::Q4);
    }
}
