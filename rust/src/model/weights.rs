//! Model parameters: initialization, (de)serialization, GPTQ integration.
//!
//! Weight matrices use `[out_features, in_features]` row-major layout —
//! the layout `Tensor::matmul_nt` consumes and the layout the packing
//! format shares with the Pallas dequant-matmul kernel.

use super::config::ModelConfig;
use crate::quant::{gptq_quantize, rtn_quantize, GptqConfig, HessianAccumulator, QuantizedMatrix};
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

/// One decoder layer's parameters.
#[derive(Debug, Clone)]
pub struct LayerWeights {
    pub wq: Tensor,     // [d_model, d_model]
    pub wk: Tensor,     // [kv_dim, d_model]
    pub wv: Tensor,     // [kv_dim, d_model]
    pub wo: Tensor,     // [d_model, d_model]
    pub w_gate: Tensor, // [d_ff, d_model]
    pub w_up: Tensor,   // [d_ff, d_model]
    pub w_down: Tensor, // [d_model, d_ff]
    pub rms_attn: Vec<f32>,
    pub rms_mlp: Vec<f32>,
}

/// Full model parameters.
#[derive(Debug, Clone)]
pub struct ModelWeights {
    pub config: ModelConfig,
    pub embed: Tensor, // [vocab, d_model]
    pub layers: Vec<LayerWeights>,
    pub final_norm: Vec<f32>,
    pub lm_head: Tensor, // [vocab, d_model]
}

impl ModelWeights {
    /// Deterministic scaled-normal initialization (std ∝ 1/√d_in, the
    /// usual fan-in scaling, so activations stay O(1) at any size).
    pub fn init(config: &ModelConfig, seed: u64) -> ModelWeights {
        let mut rng = Rng::new(seed);
        let d = config.d_model;
        let kv = config.kv_dim();
        let ff = config.d_ff;
        let mut mat = |rows: usize, cols: usize| -> Tensor {
            let std = 1.0 / (cols as f32).sqrt();
            Tensor::from_vec(&[rows, cols], rng.normal_vec(rows * cols, std))
        };
        let embed = mat(config.vocab, d);
        let layers = (0..config.n_layers)
            .map(|_| LayerWeights {
                wq: mat(d, d),
                wk: mat(kv, d),
                wv: mat(kv, d),
                wo: mat(d, d),
                w_gate: mat(ff, d),
                w_up: mat(ff, d),
                w_down: mat(d, ff),
                rms_attn: vec![1.0; d],
                rms_mlp: vec![1.0; d],
            })
            .collect();
        let final_norm = vec![1.0; d];
        let lm_head = mat(config.vocab, d);
        ModelWeights { config: *config, embed, layers, final_norm, lm_head }
    }

    /// Iterate every weight matrix as (name, tensor) — serialization and
    /// the XLA-backend argument order both use this canonical sequence.
    pub fn matrices(&self) -> Vec<(String, &Tensor)> {
        let mut out = vec![("embed".to_string(), &self.embed)];
        for (i, l) in self.layers.iter().enumerate() {
            for (n, t) in [
                ("wq", &l.wq),
                ("wk", &l.wk),
                ("wv", &l.wv),
                ("wo", &l.wo),
                ("w_gate", &l.w_gate),
                ("w_up", &l.w_up),
                ("w_down", &l.w_down),
            ] {
                out.push((format!("layer{i}.{n}"), t));
            }
        }
        out.push(("lm_head".to_string(), &self.lm_head));
        out
    }

    /// Flat parameter list in the **AOT argument order** shared with
    /// `python/compile/model.py`: `embed`, then per layer `[wq, wk, wv,
    /// wo, w_gate, w_up, w_down, rms_attn, rms_mlp]`, then `final_norm`,
    /// then `lm_head`. The XLA backend uploads buffers in exactly this
    /// order; changing it is an artifact-format break.
    pub fn flat_params(&self) -> Vec<(String, Vec<usize>, &[f32])> {
        let d = self.config.d_model;
        let mut out: Vec<(String, Vec<usize>, &[f32])> =
            vec![("embed".into(), self.embed.shape().to_vec(), self.embed.data())];
        for (i, l) in self.layers.iter().enumerate() {
            for (n, t) in [
                ("wq", &l.wq),
                ("wk", &l.wk),
                ("wv", &l.wv),
                ("wo", &l.wo),
                ("w_gate", &l.w_gate),
                ("w_up", &l.w_up),
                ("w_down", &l.w_down),
            ] {
                out.push((format!("layer{i}.{n}"), t.shape().to_vec(), t.data()));
            }
            out.push((format!("layer{i}.rms_attn"), vec![d], l.rms_attn.as_slice()));
            out.push((format!("layer{i}.rms_mlp"), vec![d], l.rms_mlp.as_slice()));
        }
        out.push(("final_norm".into(), vec![d], self.final_norm.as_slice()));
        out.push(("lm_head".into(), self.lm_head.shape().to_vec(), self.lm_head.data()));
        out
    }

    /// Total storage bytes at f32.
    pub fn f32_bytes(&self) -> usize {
        self.matrices().iter().map(|(_, t)| t.len() * 4).sum::<usize>()
            + (self.layers.len() * 2 + 1) * self.config.d_model * 4
    }

    // ------------------------------------------------------------------
    // Binary serialization: "OGPTQW01" magic, config block, then tensors
    // in `matrices()` order, then the norm vectors — all f32 LE.
    // ------------------------------------------------------------------

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path).with_context(|| format!("create {path:?}"))?,
        );
        f.write_all(b"OGPTQW01")?;
        let c = &self.config;
        for v in [
            c.vocab, c.d_model, c.n_layers, c.n_heads, c.n_kv_heads, c.d_ff, c.max_seq,
            c.alibi as usize,
        ] {
            f.write_all(&(v as u32).to_le_bytes())?;
        }
        f.write_all(&c.rms_eps.to_le_bytes())?;
        let write_f32s = |f: &mut dyn Write, xs: &[f32]| -> Result<()> {
            for v in xs {
                f.write_all(&v.to_le_bytes())?;
            }
            Ok(())
        };
        for (_, t) in self.matrices() {
            write_f32s(&mut f, t.data())?;
        }
        for l in &self.layers {
            write_f32s(&mut f, &l.rms_attn)?;
            write_f32s(&mut f, &l.rms_mlp)?;
        }
        write_f32s(&mut f, &self.final_norm)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<ModelWeights> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("open {path:?}"))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != b"OGPTQW01" {
            bail!("bad weights magic: {magic:?}");
        }
        let read_u32 = |f: &mut dyn Read| -> Result<usize> {
            let mut b = [0u8; 4];
            f.read_exact(&mut b)?;
            Ok(u32::from_le_bytes(b) as usize)
        };
        let vocab = read_u32(&mut f)?;
        let d_model = read_u32(&mut f)?;
        let n_layers = read_u32(&mut f)?;
        let n_heads = read_u32(&mut f)?;
        let n_kv_heads = read_u32(&mut f)?;
        let d_ff = read_u32(&mut f)?;
        let max_seq = read_u32(&mut f)?;
        let alibi = read_u32(&mut f)? != 0;
        let mut eps_b = [0u8; 4];
        f.read_exact(&mut eps_b)?;
        let config = ModelConfig {
            vocab,
            d_model,
            n_layers,
            n_heads,
            n_kv_heads,
            d_ff,
            max_seq,
            alibi,
            rms_eps: f32::from_le_bytes(eps_b),
        };
        let read_f32s = |f: &mut dyn Read, n: usize| -> Result<Vec<f32>> {
            let mut bytes = vec![0u8; n * 4];
            f.read_exact(&mut bytes)?;
            Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
        };
        let kv = config.kv_dim();
        let read_mat = |f: &mut dyn Read, rows: usize, cols: usize| -> Result<Tensor> {
            Ok(Tensor::from_vec(&[rows, cols], read_f32s(f, rows * cols)?))
        };
        let embed = read_mat(&mut f, vocab, d_model)?;
        let mut layers = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            layers.push(LayerWeights {
                wq: read_mat(&mut f, d_model, d_model)?,
                wk: read_mat(&mut f, kv, d_model)?,
                wv: read_mat(&mut f, kv, d_model)?,
                wo: read_mat(&mut f, d_model, d_model)?,
                w_gate: read_mat(&mut f, d_ff, d_model)?,
                w_up: read_mat(&mut f, d_ff, d_model)?,
                w_down: read_mat(&mut f, d_model, d_ff)?,
                rms_attn: vec![1.0; d_model], // filled below
                rms_mlp: vec![1.0; d_model],
            });
        }
        let lm_head = read_mat(&mut f, vocab, d_model)?;
        for l in &mut layers {
            l.rms_attn = read_f32s(&mut f, d_model)?;
            l.rms_mlp = read_f32s(&mut f, d_model)?;
        }
        let final_norm = read_f32s(&mut f, d_model)?;
        Ok(ModelWeights { config, embed, layers, final_norm, lm_head })
    }
}

/// Which matrices were quantized and how (report surface).
#[derive(Debug, Clone)]
pub struct QuantReport {
    pub bits: u32,
    pub group_size: usize,
    /// (name, relative layer-weight error) per quantized matrix.
    pub per_matrix_error: Vec<(String, f64)>,
    pub f32_bytes: usize,
    pub quant_bytes: usize,
}

impl QuantReport {
    pub fn compression_ratio(&self) -> f64 {
        self.f32_bytes as f64 / self.quant_bytes as f64
    }

    pub fn mean_error(&self) -> f64 {
        if self.per_matrix_error.is_empty() {
            return 0.0;
        }
        self.per_matrix_error.iter().map(|(_, e)| e).sum::<f64>()
            / self.per_matrix_error.len() as f64
    }
}

/// Quantization method selector for [`quantize_weights`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum QuantMethod {
    /// Full GPTQ with per-layer Hessians from calibration activations.
    Gptq,
    /// Round-to-nearest baseline (no calibration needed).
    Rtn,
}

/// Quantize every projection matrix of `weights` in place (weights are
/// replaced by their dequantized reconstruction — weight-only quantization
/// with f32 compute, the W4A16 pattern) and report the damage.
///
/// `calib[layer]` are calibration activation rows (`[n, d_model]` for
/// attention/gate/up; the MLP-down Hessian uses hidden activations the
/// caller captured, `calib_ff[layer]`: `[n, d_ff]`). For `Rtn` the
/// calibration slices are ignored.
pub fn quantize_weights(
    weights: &mut ModelWeights,
    method: QuantMethod,
    bits: u32,
    group_size: usize,
    calib_attn: &[Vec<f32>],
    calib_mlp: &[Vec<f32>],
    calib_ff: &[Vec<f32>],
) -> QuantReport {
    let d = weights.config.d_model;
    let ff = weights.config.d_ff;
    let f32_bytes = weights.f32_bytes();
    let mut per_matrix_error = Vec::new();
    let mut quant_bytes = 0usize;

    let mut do_matrix = |name: String, t: &mut Tensor, acts: Option<&[f32]>, in_dim: usize| {
        let rows = t.shape()[0];
        let cols = t.shape()[1];
        debug_assert_eq!(cols, in_dim);
        let qm: QuantizedMatrix = match (method, acts) {
            (QuantMethod::Gptq, Some(x)) if !x.is_empty() => {
                let n = x.len() / in_dim;
                let mut acc = HessianAccumulator::new(in_dim);
                acc.add_batch(x, n);
                let h = acc.finalize();
                let cfg = GptqConfig { bits, group_size, damp: 0.01, act_order: false };
                gptq_quantize(t.data(), rows, cols, &h, &cfg)
            }
            _ => rtn_quantize(t.data(), rows, cols, bits, group_size),
        };
        quant_bytes += qm.storage_bytes();
        let deq = qm.dequantize();
        per_matrix_error.push((name, crate::quant::relative_error(t.data(), &deq)));
        *t = Tensor::from_vec(&[rows, cols], deq);
    };

    for (i, l) in weights.layers.iter_mut().enumerate() {
        let attn_x = calib_attn.get(i).map(|v| v.as_slice());
        let mlp_x = calib_mlp.get(i).map(|v| v.as_slice());
        let ff_x = calib_ff.get(i).map(|v| v.as_slice());
        do_matrix(format!("layer{i}.wq"), &mut l.wq, attn_x, d);
        do_matrix(format!("layer{i}.wk"), &mut l.wk, attn_x, d);
        do_matrix(format!("layer{i}.wv"), &mut l.wv, attn_x, d);
        do_matrix(format!("layer{i}.wo"), &mut l.wo, None, d);
        do_matrix(format!("layer{i}.w_gate"), &mut l.w_gate, mlp_x, d);
        do_matrix(format!("layer{i}.w_up"), &mut l.w_up, mlp_x, d);
        do_matrix(format!("layer{i}.w_down"), &mut l.w_down, ff_x, ff);
    }
    // Embedding / lm_head stay f32 (standard GPTQ practice).
    quant_bytes += weights.embed.len() * 4 + weights.lm_head.len() * 4;

    QuantReport { bits, group_size, per_matrix_error, f32_bytes, quant_bytes }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_deterministic() {
        let c = ModelConfig::tiny();
        let a = ModelWeights::init(&c, 1);
        let b = ModelWeights::init(&c, 1);
        assert_eq!(a.embed.data(), b.embed.data());
        assert_eq!(a.layers[0].wq.data(), b.layers[0].wq.data());
        let c2 = ModelWeights::init(&c, 2);
        assert_ne!(a.embed.data(), c2.embed.data());
    }

    #[test]
    fn save_load_roundtrip() {
        let c = ModelConfig::tiny();
        let w = ModelWeights::init(&c, 3);
        let dir = std::env::temp_dir().join("opt_gptq_test_weights");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tiny.bin");
        w.save(&path).unwrap();
        let r = ModelWeights::load(&path).unwrap();
        assert_eq!(r.config, c);
        assert_eq!(r.embed.data(), w.embed.data());
        assert_eq!(r.layers[1].w_down.data(), w.layers[1].w_down.data());
        assert_eq!(r.final_norm, w.final_norm);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn load_rejects_bad_magic() {
        let dir = std::env::temp_dir().join("opt_gptq_test_weights");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"NOTMAGIC").unwrap();
        assert!(ModelWeights::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rtn_quantize_weights_reports_compression() {
        let c = ModelConfig::tiny();
        let mut w = ModelWeights::init(&c, 4);
        let orig = w.layers[0].wq.data().to_vec();
        let report = quantize_weights(&mut w, QuantMethod::Rtn, 4, 32, &[], &[], &[]);
        // tiny's f32 embed+lm_head dominate, so the whole-model ratio is
        // modest; the quantized projection payload itself must shrink ~6×.
        assert!(report.compression_ratio() > 1.5, "ratio={}", report.compression_ratio());
        let untouched = (w.embed.len() + w.lm_head.len()) * 4;
        let proj_f32 = report.f32_bytes - untouched - (w.layers.len() * 2 + 1) * w.config.d_model * 4;
        let proj_quant = report.quant_bytes - untouched;
        assert!(
            (proj_f32 as f64) / (proj_quant as f64) > 5.0,
            "projection payload ratio {}",
            proj_f32 as f64 / proj_quant as f64
        );
        assert!(report.mean_error() > 0.0 && report.mean_error() < 0.2);
        assert_ne!(w.layers[0].wq.data(), orig.as_slice(), "weights replaced by dequant");
    }

    #[test]
    fn canonical_matrix_order() {
        let c = ModelConfig::tiny();
        let w = ModelWeights::init(&c, 5);
        let names: Vec<String> = w.matrices().iter().map(|(n, _)| n.clone()).collect();
        assert_eq!(names[0], "embed");
        assert_eq!(names[1], "layer0.wq");
        assert_eq!(names[7], "layer0.w_down");
        assert_eq!(names.last().unwrap(), "lm_head");
        assert_eq!(names.len(), 2 + 7 * c.n_layers);
    }
}
