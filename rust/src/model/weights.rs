//! Model parameters: initialization, (de)serialization, GPTQ integration.
//!
//! Weight matrices use `[out_features, in_features]` row-major layout —
//! the layout `Tensor::matmul_nt` consumes and the layout the packing
//! format shares with the Pallas dequant-matmul kernel.

use super::config::ModelConfig;
use super::store::{PackedModelWeights, PackedProjection, QuantizedLayerWeights, WeightDtype};
use crate::quant::{gptq_quantize, rtn_quantize, GptqConfig, HessianAccumulator, QuantizedMatrix};
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

/// One decoder layer's parameters.
#[derive(Debug, Clone)]
pub struct LayerWeights {
    pub wq: Tensor,     // [d_model, d_model]
    pub wk: Tensor,     // [kv_dim, d_model]
    pub wv: Tensor,     // [kv_dim, d_model]
    pub wo: Tensor,     // [d_model, d_model]
    pub w_gate: Tensor, // [d_ff, d_model]
    pub w_up: Tensor,   // [d_ff, d_model]
    pub w_down: Tensor, // [d_model, d_ff]
    pub rms_attn: Vec<f32>,
    pub rms_mlp: Vec<f32>,
}

/// Full model parameters.
#[derive(Debug, Clone)]
pub struct ModelWeights {
    pub config: ModelConfig,
    pub embed: Tensor, // [vocab, d_model]
    pub layers: Vec<LayerWeights>,
    pub final_norm: Vec<f32>,
    pub lm_head: Tensor, // [vocab, d_model]
}

impl ModelWeights {
    /// Deterministic scaled-normal initialization (std ∝ 1/√d_in, the
    /// usual fan-in scaling, so activations stay O(1) at any size).
    pub fn init(config: &ModelConfig, seed: u64) -> ModelWeights {
        let mut rng = Rng::new(seed);
        let d = config.d_model;
        let kv = config.kv_dim();
        let ff = config.d_ff;
        let mut mat = |rows: usize, cols: usize| -> Tensor {
            let std = 1.0 / (cols as f32).sqrt();
            Tensor::from_vec(&[rows, cols], rng.normal_vec(rows * cols, std))
        };
        let embed = mat(config.vocab, d);
        let layers = (0..config.n_layers)
            .map(|_| LayerWeights {
                wq: mat(d, d),
                wk: mat(kv, d),
                wv: mat(kv, d),
                wo: mat(d, d),
                w_gate: mat(ff, d),
                w_up: mat(ff, d),
                w_down: mat(d, ff),
                rms_attn: vec![1.0; d],
                rms_mlp: vec![1.0; d],
            })
            .collect();
        let final_norm = vec![1.0; d];
        let lm_head = mat(config.vocab, d);
        ModelWeights { config: *config, embed, layers, final_norm, lm_head }
    }

    /// Iterate every weight matrix as (name, tensor) — serialization and
    /// the XLA-backend argument order both use this canonical sequence.
    pub fn matrices(&self) -> Vec<(String, &Tensor)> {
        let mut out = vec![("embed".to_string(), &self.embed)];
        for (i, l) in self.layers.iter().enumerate() {
            for (n, t) in [
                ("wq", &l.wq),
                ("wk", &l.wk),
                ("wv", &l.wv),
                ("wo", &l.wo),
                ("w_gate", &l.w_gate),
                ("w_up", &l.w_up),
                ("w_down", &l.w_down),
            ] {
                out.push((format!("layer{i}.{n}"), t));
            }
        }
        out.push(("lm_head".to_string(), &self.lm_head));
        out
    }

    /// Flat parameter list in the **AOT argument order** shared with
    /// `python/compile/model.py`: `embed`, then per layer `[wq, wk, wv,
    /// wo, w_gate, w_up, w_down, rms_attn, rms_mlp]`, then `final_norm`,
    /// then `lm_head`. The XLA backend uploads buffers in exactly this
    /// order; changing it is an artifact-format break.
    pub fn flat_params(&self) -> Vec<(String, Vec<usize>, &[f32])> {
        let d = self.config.d_model;
        let mut out: Vec<(String, Vec<usize>, &[f32])> =
            vec![("embed".into(), self.embed.shape().to_vec(), self.embed.data())];
        for (i, l) in self.layers.iter().enumerate() {
            for (n, t) in [
                ("wq", &l.wq),
                ("wk", &l.wk),
                ("wv", &l.wv),
                ("wo", &l.wo),
                ("w_gate", &l.w_gate),
                ("w_up", &l.w_up),
                ("w_down", &l.w_down),
            ] {
                out.push((format!("layer{i}.{n}"), t.shape().to_vec(), t.data()));
            }
            out.push((format!("layer{i}.rms_attn"), vec![d], l.rms_attn.as_slice()));
            out.push((format!("layer{i}.rms_mlp"), vec![d], l.rms_mlp.as_slice()));
        }
        out.push(("final_norm".into(), vec![d], self.final_norm.as_slice()));
        out.push(("lm_head".into(), self.lm_head.shape().to_vec(), self.lm_head.data()));
        out
    }

    /// Total storage bytes at f32.
    pub fn f32_bytes(&self) -> usize {
        self.matrices().iter().map(|(_, t)| t.len() * 4).sum::<usize>()
            + (self.layers.len() * 2 + 1) * self.config.d_model * 4
    }

    // ------------------------------------------------------------------
    // Binary serialization: "OGPTQW01" magic, config block, then tensors
    // in `matrices()` order, then the norm vectors — all f32 LE.
    // ------------------------------------------------------------------

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path).with_context(|| format!("create {path:?}"))?,
        );
        f.write_all(b"OGPTQW01")?;
        let c = &self.config;
        for v in [
            c.vocab, c.d_model, c.n_layers, c.n_heads, c.n_kv_heads, c.d_ff, c.max_seq,
            c.alibi as usize,
        ] {
            f.write_all(&(v as u32).to_le_bytes())?;
        }
        f.write_all(&c.rms_eps.to_le_bytes())?;
        let write_f32s = |f: &mut dyn Write, xs: &[f32]| -> Result<()> {
            for v in xs {
                f.write_all(&v.to_le_bytes())?;
            }
            Ok(())
        };
        for (_, t) in self.matrices() {
            write_f32s(&mut f, t.data())?;
        }
        for l in &self.layers {
            write_f32s(&mut f, &l.rms_attn)?;
            write_f32s(&mut f, &l.rms_mlp)?;
        }
        write_f32s(&mut f, &self.final_norm)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<ModelWeights> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("open {path:?}"))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != b"OGPTQW01" {
            bail!("bad weights magic: {magic:?}");
        }
        let read_u32 = |f: &mut dyn Read| -> Result<usize> {
            let mut b = [0u8; 4];
            f.read_exact(&mut b)?;
            Ok(u32::from_le_bytes(b) as usize)
        };
        let vocab = read_u32(&mut f)?;
        let d_model = read_u32(&mut f)?;
        let n_layers = read_u32(&mut f)?;
        let n_heads = read_u32(&mut f)?;
        let n_kv_heads = read_u32(&mut f)?;
        let d_ff = read_u32(&mut f)?;
        let max_seq = read_u32(&mut f)?;
        let alibi = read_u32(&mut f)? != 0;
        let mut eps_b = [0u8; 4];
        f.read_exact(&mut eps_b)?;
        let config = ModelConfig {
            vocab,
            d_model,
            n_layers,
            n_heads,
            n_kv_heads,
            d_ff,
            max_seq,
            alibi,
            rms_eps: f32::from_le_bytes(eps_b),
            // Sparsity and score domain are runtime serving knobs, not
            // artifact state: loaded weights always come back dense /
            // f32-scored and the caller applies its CLI policy
            // afterwards (`with_sparsity` / `with_score_domain`).
            sparsity: Default::default(),
            score_domain: Default::default(),
        };
        let read_f32s = |f: &mut dyn Read, n: usize| -> Result<Vec<f32>> {
            let mut bytes = vec![0u8; n * 4];
            f.read_exact(&mut bytes)?;
            Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
        };
        let kv = config.kv_dim();
        let read_mat = |f: &mut dyn Read, rows: usize, cols: usize| -> Result<Tensor> {
            Ok(Tensor::from_vec(&[rows, cols], read_f32s(f, rows * cols)?))
        };
        let embed = read_mat(&mut f, vocab, d_model)?;
        let mut layers = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            layers.push(LayerWeights {
                wq: read_mat(&mut f, d_model, d_model)?,
                wk: read_mat(&mut f, kv, d_model)?,
                wv: read_mat(&mut f, kv, d_model)?,
                wo: read_mat(&mut f, d_model, d_model)?,
                w_gate: read_mat(&mut f, d_ff, d_model)?,
                w_up: read_mat(&mut f, d_ff, d_model)?,
                w_down: read_mat(&mut f, d_model, d_ff)?,
                rms_attn: vec![1.0; d_model], // filled below
                rms_mlp: vec![1.0; d_model],
            });
        }
        let lm_head = read_mat(&mut f, vocab, d_model)?;
        for l in &mut layers {
            l.rms_attn = read_f32s(&mut f, d_model)?;
            l.rms_mlp = read_f32s(&mut f, d_model)?;
        }
        let final_norm = read_f32s(&mut f, d_model)?;
        Ok(ModelWeights { config, embed, layers, final_norm, lm_head })
    }
}

/// Which matrices were quantized and how (report surface).
#[derive(Debug, Clone)]
pub struct QuantReport {
    pub bits: u32,
    pub group_size: usize,
    /// (name, relative layer-weight error) per quantized matrix.
    pub per_matrix_error: Vec<(String, f64)>,
    pub f32_bytes: usize,
    pub quant_bytes: usize,
}

impl QuantReport {
    pub fn compression_ratio(&self) -> f64 {
        self.f32_bytes as f64 / self.quant_bytes as f64
    }

    pub fn mean_error(&self) -> f64 {
        if self.per_matrix_error.is_empty() {
            return 0.0;
        }
        self.per_matrix_error.iter().map(|(_, e)| e).sum::<f64>()
            / self.per_matrix_error.len() as f64
    }
}

/// Quantization method selector for [`quantize_weights`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum QuantMethod {
    /// Full GPTQ with per-layer Hessians from calibration activations.
    Gptq,
    /// Round-to-nearest baseline (no calibration needed).
    Rtn,
}

/// Finalized layer Hessian for one calibration slice (`[n, dim]` rows),
/// or `None` when the method/slice can't use one — computed **once per
/// slice per layer** and shared by every projection consuming that
/// slice (wq/wk/wv share the attention Hessian; gate/up share the MLP
/// one), since Hessian accumulation is the dominant calibration cost.
fn slice_hessian(method: QuantMethod, acts: Option<&[f32]>, dim: usize) -> Option<Vec<f64>> {
    match (method, acts) {
        (QuantMethod::Gptq, Some(x)) if !x.is_empty() => {
            let n = x.len() / dim;
            let mut acc = HessianAccumulator::new(dim);
            acc.add_batch(x, n);
            Some(acc.finalize())
        }
        _ => None,
    }
}

/// Quantize one `[rows, cols]` matrix: GPTQ against a precomputed
/// Hessian when one is available, RTN otherwise. The single
/// quantization core shared by the fake-quant path
/// ([`quantize_weights`]) and the packed serving path
/// ([`quantize_weights_packed`]), so both produce the *same* integer
/// levels for the same inputs (the packed-vs-reconstruction parity
/// tests lean on this determinism).
fn quantize_matrix(
    data: &[f32],
    rows: usize,
    cols: usize,
    bits: u32,
    group_size: usize,
    act_order: bool,
    hessian: Option<&[f64]>,
) -> QuantizedMatrix {
    match hessian {
        Some(h) => {
            let cfg = GptqConfig { bits, group_size, damp: 0.01, act_order };
            gptq_quantize(data, rows, cols, h, &cfg)
        }
        None => rtn_quantize(data, rows, cols, bits, group_size),
    }
}

/// One layer's Hessians in projection order: attention (wq/wk/wv), MLP
/// input (gate/up), FFN hidden (down); `wo` never has one.
fn layer_hessians(
    method: QuantMethod,
    layer: usize,
    d_model: usize,
    d_ff: usize,
    calib_attn: &[Vec<f32>],
    calib_mlp: &[Vec<f32>],
    calib_ff: &[Vec<f32>],
) -> (Option<Vec<f64>>, Option<Vec<f64>>, Option<Vec<f64>>) {
    (
        slice_hessian(method, calib_attn.get(layer).map(|v| v.as_slice()), d_model),
        slice_hessian(method, calib_mlp.get(layer).map(|v| v.as_slice()), d_model),
        slice_hessian(method, calib_ff.get(layer).map(|v| v.as_slice()), d_ff),
    )
}

/// Quantize every projection matrix of `weights` in place (weights are
/// replaced by their dequantized reconstruction — weight-only quantization
/// with f32 compute, the W4A16 pattern) and report the damage.
///
/// This is the **fake-quant** path: useful for accuracy ablations, but
/// the serving memory win is zero because storage goes straight back to
/// dense f32. Serve from [`quantize_weights_packed`]'s output to keep
/// the projections packed end to end.
///
/// `calib[layer]` are calibration activation rows (`[n, d_model]` for
/// attention/gate/up; the MLP-down Hessian uses hidden activations the
/// caller captured, `calib_ff[layer]`: `[n, d_ff]`). For `Rtn` the
/// calibration slices are ignored. `act_order` enables GPTQ's
/// decreasing-Hessian-diagonal column ordering (`GptqConfig::act_order`).
#[allow(clippy::too_many_arguments)]
pub fn quantize_weights(
    weights: &mut ModelWeights,
    method: QuantMethod,
    bits: u32,
    group_size: usize,
    act_order: bool,
    calib_attn: &[Vec<f32>],
    calib_mlp: &[Vec<f32>],
    calib_ff: &[Vec<f32>],
) -> QuantReport {
    let d = weights.config.d_model;
    let ff = weights.config.d_ff;
    let f32_bytes = weights.f32_bytes();
    let mut per_matrix_error = Vec::new();
    let mut quant_bytes = 0usize;

    // In-place per-matrix replacement: at most one matrix's
    // reconstruction is alive at a time, so peak memory stays ≈ the
    // model itself.
    let mut do_matrix = |name: String, t: &mut Tensor, hessian: Option<&[f64]>| {
        let (rows, cols) = (t.shape()[0], t.shape()[1]);
        let qm = quantize_matrix(t.data(), rows, cols, bits, group_size, act_order, hessian);
        quant_bytes += qm.storage_bytes();
        let deq = qm.dequantize();
        per_matrix_error.push((name, crate::quant::relative_error(t.data(), &deq)));
        *t = Tensor::from_vec(&[rows, cols], deq);
    };

    for (i, l) in weights.layers.iter_mut().enumerate() {
        let (attn_h, mlp_h, ff_h) =
            layer_hessians(method, i, d, ff, calib_attn, calib_mlp, calib_ff);
        do_matrix(format!("layer{i}.wq"), &mut l.wq, attn_h.as_deref());
        do_matrix(format!("layer{i}.wk"), &mut l.wk, attn_h.as_deref());
        do_matrix(format!("layer{i}.wv"), &mut l.wv, attn_h.as_deref());
        do_matrix(format!("layer{i}.wo"), &mut l.wo, None);
        do_matrix(format!("layer{i}.w_gate"), &mut l.w_gate, mlp_h.as_deref());
        do_matrix(format!("layer{i}.w_up"), &mut l.w_up, mlp_h.as_deref());
        do_matrix(format!("layer{i}.w_down"), &mut l.w_down, ff_h.as_deref());
    }
    // Embedding / lm_head stay f32 (standard GPTQ practice).
    quant_bytes += weights.embed.len() * 4 + weights.lm_head.len() * 4;

    QuantReport { bits, group_size, per_matrix_error, f32_bytes, quant_bytes }
}

/// Quantize every projection matrix straight into the **packed serving
/// representation** — no dequantized-f32 round-trip. The returned
/// [`PackedModelWeights`] is a `WeightStore` the engine serves from
/// directly: the fused dequant-matmul (`quant::matmul`) reads the packed
/// payload per row-tile, and the result is bit-identical to serving the
/// eagerly-dequantized reconstruction (enforced by
/// `tests/weights_parity.rs`).
///
/// `bits` must be a servable width (3 | 4 | 8 — see
/// [`WeightDtype::from_bits`]); calibration slices behave exactly as in
/// [`quantize_weights`]. Embedding, LM head, and norms are copied as
/// f32.
#[allow(clippy::too_many_arguments)]
pub fn quantize_weights_packed(
    weights: &ModelWeights,
    method: QuantMethod,
    bits: u32,
    group_size: usize,
    act_order: bool,
    calib_attn: &[Vec<f32>],
    calib_mlp: &[Vec<f32>],
    calib_ff: &[Vec<f32>],
) -> (PackedModelWeights, QuantReport) {
    assert!(
        WeightDtype::from_bits(bits).is_some(),
        "packed serving supports 3/4/8-bit weights, not {bits}"
    );
    let d = weights.config.d_model;
    let ff = weights.config.d_ff;
    let f32_bytes = weights.f32_bytes();
    let mut per_matrix_error = Vec::new();
    let mut quant_bytes = 0usize;

    let mut do_matrix = |name: String, t: &Tensor, hessian: Option<&[f64]>| {
        let (rows, cols) = (t.shape()[0], t.shape()[1]);
        let qm = quantize_matrix(t.data(), rows, cols, bits, group_size, act_order, hessian);
        quant_bytes += qm.storage_bytes();
        per_matrix_error
            .push((name, crate::quant::relative_error(t.data(), &qm.dequantize())));
        PackedProjection::from_quantized(&qm)
    };

    let layers = weights
        .layers
        .iter()
        .enumerate()
        .map(|(i, l)| {
            let (attn_h, mlp_h, ff_h) =
                layer_hessians(method, i, d, ff, calib_attn, calib_mlp, calib_ff);
            QuantizedLayerWeights {
                wq: do_matrix(format!("layer{i}.wq"), &l.wq, attn_h.as_deref()),
                wk: do_matrix(format!("layer{i}.wk"), &l.wk, attn_h.as_deref()),
                wv: do_matrix(format!("layer{i}.wv"), &l.wv, attn_h.as_deref()),
                wo: do_matrix(format!("layer{i}.wo"), &l.wo, None),
                w_gate: do_matrix(format!("layer{i}.w_gate"), &l.w_gate, mlp_h.as_deref()),
                w_up: do_matrix(format!("layer{i}.w_up"), &l.w_up, mlp_h.as_deref()),
                w_down: do_matrix(format!("layer{i}.w_down"), &l.w_down, ff_h.as_deref()),
                rms_attn: l.rms_attn.clone(),
                rms_mlp: l.rms_mlp.clone(),
            }
        })
        .collect();
    quant_bytes += weights.embed.len() * 4 + weights.lm_head.len() * 4;
    let store = PackedModelWeights {
        config: weights.config,
        bits,
        group_size,
        embed: weights.embed.clone(),
        layers,
        final_norm: weights.final_norm.clone(),
        lm_head: weights.lm_head.clone(),
    };
    (store, QuantReport { bits, group_size, per_matrix_error, f32_bytes, quant_bytes })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_deterministic() {
        let c = ModelConfig::tiny();
        let a = ModelWeights::init(&c, 1);
        let b = ModelWeights::init(&c, 1);
        assert_eq!(a.embed.data(), b.embed.data());
        assert_eq!(a.layers[0].wq.data(), b.layers[0].wq.data());
        let c2 = ModelWeights::init(&c, 2);
        assert_ne!(a.embed.data(), c2.embed.data());
    }

    #[test]
    fn save_load_roundtrip() {
        let c = ModelConfig::tiny();
        let w = ModelWeights::init(&c, 3);
        let dir = std::env::temp_dir().join("opt_gptq_test_weights");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tiny.bin");
        w.save(&path).unwrap();
        let r = ModelWeights::load(&path).unwrap();
        assert_eq!(r.config, c);
        assert_eq!(r.embed.data(), w.embed.data());
        assert_eq!(r.layers[1].w_down.data(), w.layers[1].w_down.data());
        assert_eq!(r.final_norm, w.final_norm);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn load_rejects_bad_magic() {
        let dir = std::env::temp_dir().join("opt_gptq_test_weights");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"NOTMAGIC").unwrap();
        assert!(ModelWeights::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rtn_quantize_weights_reports_compression() {
        let c = ModelConfig::tiny();
        let mut w = ModelWeights::init(&c, 4);
        let orig = w.layers[0].wq.data().to_vec();
        let report = quantize_weights(&mut w, QuantMethod::Rtn, 4, 32, false, &[], &[], &[]);
        // tiny's f32 embed+lm_head dominate, so the whole-model ratio is
        // modest; the quantized projection payload itself must shrink ~6×.
        assert!(report.compression_ratio() > 1.5, "ratio={}", report.compression_ratio());
        let untouched = (w.embed.len() + w.lm_head.len()) * 4;
        let proj_f32 = report.f32_bytes - untouched - (w.layers.len() * 2 + 1) * w.config.d_model * 4;
        let proj_quant = report.quant_bytes - untouched;
        assert!(
            (proj_f32 as f64) / (proj_quant as f64) > 5.0,
            "projection payload ratio {}",
            proj_f32 as f64 / proj_quant as f64
        );
        assert!(report.mean_error() > 0.0 && report.mean_error() < 0.2);
        assert_ne!(w.layers[0].wq.data(), orig.as_slice(), "weights replaced by dequant");
    }

    #[test]
    fn packed_quantization_matches_fake_quant_levels() {
        // The packed path must be the same quantizer as the fake-quant
        // path — only the storage differs. RTN here (deterministic, no
        // calibration); the reconstruction of the packed store equals
        // the fake-quant weights bit for bit.
        let c = ModelConfig::tiny();
        let w = ModelWeights::init(&c, 6);
        let mut fake = w.clone();
        let r1 = quantize_weights(&mut fake, QuantMethod::Rtn, 4, 32, false, &[], &[], &[]);
        let (packed, r2) = quantize_weights_packed(&w, QuantMethod::Rtn, 4, 32, false, &[], &[], &[]);
        assert_eq!(r1.quant_bytes, r2.quant_bytes);
        assert_eq!(r1.per_matrix_error, r2.per_matrix_error);
        assert_eq!(packed.layers[0].wq.w.dequantize(), fake.layers[0].wq.data());
        assert_eq!(packed.layers[1].w_down.w.dequantize(), fake.layers[1].w_down.data());
        // Untouched sides are copied verbatim.
        assert_eq!(packed.embed.data(), w.embed.data());
        assert_eq!(packed.lm_head.data(), w.lm_head.data());
        assert_eq!(packed.final_norm, w.final_norm);
    }

    #[test]
    fn act_order_flag_reaches_gptq_and_stays_finite() {
        // quantize_weights used to hardcode act_order: false; the flag
        // now reaches GptqConfig. act_order stores per-column grids
        // (group_size 1 semantics), which shows up as a larger params
        // payload — observable proof the flag took effect.
        let c = ModelConfig::tiny();
        let w = ModelWeights::init(&c, 8);
        let model = crate::model::NativeModel::new(w.clone());
        let calib: Vec<u32> = (0..24).map(|i| 256 + (i % 120)).collect();
        let (a, m, f) = model.calibrate(&calib);
        let mut base = w.clone();
        let rb = quantize_weights(&mut base, QuantMethod::Gptq, 4, 32, false, &a, &m, &f);
        let mut ao = w.clone();
        let ra = quantize_weights(&mut ao, QuantMethod::Gptq, 4, 32, true, &a, &m, &f);
        assert!(ra.quant_bytes > rb.quant_bytes, "per-column grids must cost more bytes");
        assert!(ao.layers[0].wq.data().iter().all(|v| v.is_finite()));
        assert!(ra.mean_error() < 0.5, "act_order error {}", ra.mean_error());
    }

    #[test]
    fn canonical_matrix_order() {
        let c = ModelConfig::tiny();
        let w = ModelWeights::init(&c, 5);
        let names: Vec<String> = w.matrices().iter().map(|(n, _)| n.clone()).collect();
        assert_eq!(names[0], "embed");
        assert_eq!(names[1], "layer0.wq");
        assert_eq!(names[7], "layer0.w_down");
        assert_eq!(names.last().unwrap(), "lm_head");
        assert_eq!(names.len(), 2 + 7 * c.n_layers);
    }
}
