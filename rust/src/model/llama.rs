//! Native Llama-GQA forward pass over the paged KV cache.
//!
//! This is the reference/fast-CPU implementation of the same computation
//! the AOT-lowered HLO performs (`python/compile/model.py`): RMSNorm →
//! GQA attention (ALiBi) → RMSNorm → SwiGLU, residuals throughout, no
//! positional embeddings (ALiBi carries position). Both prefill and
//! decode attend **paged-natively**: KV tiles stream straight out of the
//! block table (blockwise online softmax, in-tile dequant on a Q8
//! store) — mirroring the Pallas kernel's schedule. No dense KV copy is
//! ever materialized on the forward path.
//!
//! Weights are reached through the [`WeightStore`] trait, so the same
//! forward pass serves dense f32 tensors or packed GPTQ/RTN projections
//! (`store::PackedModelWeights`, dequantized per row-tile inside the
//! fused matmul — no dense weight copy either). Packed serving is
//! **bit-identical** to serving the dequantized reconstruction, so every
//! interleaving/determinism contract below holds at any weight dtype.

use super::config::ModelConfig;
use super::store::{Proj, WeightStore};
use super::weights::ModelWeights;
use crate::attention::gqa::{auto_prefill_threads, gqa_attention};
use crate::attention::paged::{
    auto_decode_threads, paged_decode_batch, paged_prefill_rows_parallel,
};
use crate::kvcache::{BlockTable, KvStore};
use crate::tensor::{rmsnorm, Tensor};
use std::sync::Arc;

/// A model executable on the native backend, over any [`WeightStore`].
#[derive(Debug, Clone)]
pub struct NativeModel {
    store: Arc<dyn WeightStore>,
}

impl NativeModel {
    /// Model over dense f32 weights (the default store).
    pub fn new(weights: ModelWeights) -> Self {
        Self::from_store(Arc::new(weights))
    }

    /// Model over an explicit weight store (dense or packed).
    pub fn from_store(store: Arc<dyn WeightStore>) -> Self {
        NativeModel { store }
    }

    /// The weight store this model serves from.
    pub fn store(&self) -> &dyn WeightStore {
        &*self.store
    }

    /// The dense f32 weights, when that is what the store holds (the
    /// XLA upload and dense-save paths need raw tensors).
    pub fn dense_weights(&self) -> Option<&ModelWeights> {
        self.store.dense()
    }

    pub fn config(&self) -> &ModelConfig {
        self.store.config()
    }

    /// `x · W(layer, p)ᵀ` through the store — the single projection
    /// entry point for both weight dtypes (`threads == 0` auto-sizes
    /// the row fan-out; bit-identical at every width).
    fn proj(&self, layer: usize, p: Proj, x: &Tensor) -> Tensor {
        let m = x.shape()[0];
        let rows = self.store.proj_rows(layer, p);
        let mut out = Tensor::zeros(&[m, rows]);
        self.store.proj_into(layer, p, x.data(), m, 0, out.data_mut());
        out
    }

    fn embed_tokens(&self, tokens: &[u32]) -> Tensor {
        let d = self.config().d_model;
        let mut x = Vec::with_capacity(tokens.len() * d);
        for &t in tokens {
            assert!((t as usize) < self.config().vocab, "token {t} out of vocab");
            x.extend_from_slice(self.store.embed().row(t as usize));
        }
        Tensor::from_vec(&[tokens.len(), d], x)
    }

    /// One transformer block's MLP (SwiGLU) applied to `[n, d]`.
    fn mlp(&self, layer: usize, x: &Tensor) -> Tensor {
        let mut gate = self.proj(layer, Proj::WGate, x);
        let up = self.proj(layer, Proj::WUp, x);
        gate.silu_inplace();
        self.proj(layer, Proj::WDown, &gate.mul(&up))
    }

    /// Process `tokens` (prompt chunk), appending their K/V to the cache.
    ///
    /// `table` must have capacity reserved for `tokens.len()` more slots
    /// (see [`BlockTable::reserve`]). Supports chunked prefill: tokens are
    /// placed at positions `table.len()..table.len()+n` and attend to all
    /// earlier cache content. Returns the **last** position's logits
    /// (`[vocab]`).
    ///
    /// Works over any [`KvStore`] and never materializes the context
    /// densely: attention streams KV tiles straight out of the block
    /// table (on a quantized cache, tiles are dequantized once each into
    /// workspace scratch — `KvStore::gather` is off the forward path).
    pub fn prefill(
        &self,
        tokens: &[u32],
        cache: &mut dyn KvStore,
        table: &mut BlockTable,
    ) -> Vec<f32> {
        self.prefill_with(tokens, cache, table, None)
    }

    /// [`Self::prefill`] with an explicit attention fan-out width.
    ///
    /// `threads == Some(1)` forces the serial walk; `None` (or `Some(0)`)
    /// auto-sizes from the chunk's score work and the available cores
    /// ([`auto_prefill_threads`]). Outputs are bit-identical across all
    /// widths, so this is purely a performance knob (see
    /// `NativeBackend::with_prefill_threads`).
    pub fn prefill_with(
        &self,
        tokens: &[u32],
        cache: &mut dyn KvStore,
        table: &mut BlockTable,
        threads: Option<usize>,
    ) -> Vec<f32> {
        assert!(!tokens.is_empty());
        let cfg = self.config();
        let n = tokens.len();
        let base = table.len();
        // Claim physical slots for the new tokens once; every layer writes
        // its K/V through the same mapping.
        let slots: Vec<_> = (0..n).map(|_| table.append_slot(cache.block_size())).collect();
        // Layer-invariant attention fan-out width (sized once, not per
        // layer).
        let threads =
            threads.filter(|&t| t > 0).unwrap_or_else(|| auto_prefill_threads(n, base + n));

        let mut x = self.embed_tokens(tokens);
        for li in 0..cfg.n_layers {
            // Attention sub-block.
            let xn = rmsnorm(&x, self.store.rms_attn(li), cfg.rms_eps);
            let q = self.proj(li, Proj::Wq, &xn);
            let k = self.proj(li, Proj::Wk, &xn);
            let v = self.proj(li, Proj::Wv, &xn);
            let kvd = cfg.kv_dim();
            for (i, &(b, s)) in slots.iter().enumerate() {
                cache.write_token(li, b, s, &k.data()[i * kvd..(i + 1) * kvd], &v.data()[i * kvd..(i + 1) * kvd]);
            }
            // Stream the visible context (base + new) straight out of the
            // paged store, fanning query rows across the persistent
            // worker pool (bit-identical to serial at every width).
            let mut attn = vec![0.0f32; n * cfg.d_model];
            paged_prefill_rows_parallel(
                &cfg.attn_config(),
                &*cache,
                li,
                q.data(),
                n,
                base,
                table,
                threads,
                &mut attn,
            );
            let attn = self.proj(li, Proj::Wo, &Tensor::from_vec(&[n, cfg.d_model], attn));
            x.add_assign(&attn);
            // MLP sub-block.
            let xn2 = rmsnorm(&x, self.store.rms_mlp(li), cfg.rms_eps);
            let h = self.mlp(li, &xn2);
            x.add_assign(&h);
        }
        self.last_row_logits(&x)
    }

    /// Decode one token: append its K/V, return its logits (`[vocab]`).
    ///
    /// `table` must have one slot of reserved capacity.
    pub fn decode_step(
        &self,
        token: u32,
        cache: &mut dyn KvStore,
        table: &mut BlockTable,
    ) -> Vec<f32> {
        let mut tables = [table];
        self.decode_batch(&[token], cache, &mut tables).pop().unwrap()
    }

    /// Batched decode: one token per sequence, all sequences advanced in
    /// a single pass so every weight matrix is streamed from memory
    /// **once per step** instead of once per sequence — the native
    /// backend's continuous-batching payoff (decode is memory-bound on
    /// weights at batch 1).
    ///
    /// Each table must have one slot of reserved capacity. Returns one
    /// logits vector per sequence, in order. The attention fan-out width
    /// is chosen by [`auto_decode_threads`]; see [`Self::decode_batch_with`]
    /// to pin it.
    pub fn decode_batch(
        &self,
        tokens: &[u32],
        cache: &mut dyn KvStore,
        tables: &mut [&mut BlockTable],
    ) -> Vec<Vec<f32>> {
        self.decode_batch_with(tokens, cache, tables, None).0
    }

    /// [`Self::decode_batch`] with an explicit attention fan-out width.
    ///
    /// `threads == Some(1)` forces the serial loop; `None` auto-sizes
    /// from the batch's KV footprint and the available cores. Outputs
    /// are bit-identical across all widths (see
    /// [`paged_decode_batch`]), so threading never perturbs sampling.
    ///
    /// Returns `(logits, skipped_tiles)`: one logits vector per
    /// sequence, plus the step's score-bound tile skips summed across
    /// layers (0 under a dense [`crate::attention::SparsityConfig`] —
    /// the decode-side `EngineMetrics::skipped_tiles` feed).
    pub fn decode_batch_with(
        &self,
        tokens: &[u32],
        cache: &mut dyn KvStore,
        tables: &mut [&mut BlockTable],
        threads: Option<usize>,
    ) -> (Vec<Vec<f32>>, usize) {
        let cfg = self.config();
        let n = tokens.len();
        assert_eq!(n, tables.len());
        assert!(n > 0);
        let kvd = cfg.kv_dim();
        let slots: Vec<_> =
            tables.iter_mut().map(|t| t.append_slot(cache.block_size())).collect();
        // Immutable views for the attention fan-out (tables are not
        // resized again this step).
        let table_refs: Vec<&BlockTable> = tables.iter().map(|t| &**t).collect();
        let total_kv: usize = table_refs.iter().map(|t| t.len()).sum();
        let threads = threads.unwrap_or_else(|| auto_decode_threads(n, total_kv));
        let acfg = cfg.attn_config();

        let mut x = self.embed_tokens(tokens); // [n, d]
        // One attention output buffer reused across layers (fully
        // overwritten by every paged_decode_batch call).
        let mut attn = Tensor::zeros(&[n, cfg.d_model]);
        let mut skipped_tiles = 0usize;
        for li in 0..cfg.n_layers {
            let xn = rmsnorm(&x, self.store.rms_attn(li), cfg.rms_eps);
            let q = self.proj(li, Proj::Wq, &xn); // [n, d]
            let k = self.proj(li, Proj::Wk, &xn); // [n, kvd]
            let v = self.proj(li, Proj::Wv, &xn);
            for (i, &(blk, slot)) in slots.iter().enumerate() {
                cache.write_token(
                    li,
                    blk,
                    slot,
                    &k.data()[i * kvd..(i + 1) * kvd],
                    &v.data()[i * kvd..(i + 1) * kvd],
                );
            }
            // Attention is per-sequence (distinct block tables): fan the
            // batch across scoped workers, one workspace each.
            skipped_tiles +=
                paged_decode_batch(&acfg, cache, li, q.data(), &table_refs, threads, attn.data_mut());
            let attn_out = self.proj(li, Proj::Wo, &attn);
            x.add_assign(&attn_out);
            let xn2 = rmsnorm(&x, self.store.rms_mlp(li), cfg.rms_eps);
            let h = self.mlp(li, &xn2);
            x.add_assign(&h);
        }
        // Final norm + LM head for every row at once.
        let normed = rmsnorm(&x, self.store.final_norm(), cfg.rms_eps);
        let logits = normed.matmul_nt(self.store.lm_head()); // [n, vocab]
        ((0..n).map(|i| logits.row(i).to_vec()).collect(), skipped_tiles)
    }

    /// One fused **mixed step**: prefill chunk rows and decode rows run
    /// through a single forward pass, so every weight matrix streams
    /// from memory **once per step** across both kinds of work — the
    /// continuous-batching payoff extended from decode-only
    /// ([`Self::decode_batch`]) to the whole step.
    ///
    /// * `chunk_tokens[i]` prefills into `chunk_tables[i]` at positions
    ///   `table.len()..` (capacity reserved, chunked prefill welcome);
    ///   its last-position logits are computed only when
    ///   `chunk_want[i]` is set (a sequence's final chunk — mid-flight
    ///   chunks skip the LM head entirely);
    /// * `decode_tokens[j]` appends one slot to `decode_tables[j]`.
    ///
    /// A sequence must appear at most once across both lists. Attention
    /// stays per-sequence and paged-native: each chunk's query rows fan
    /// out across the persistent worker pool, streaming KV tiles out of
    /// the block table ([`paged_prefill_rows_parallel`] — no dense
    /// gather), and decode rows go through the paged fan-out
    /// ([`paged_decode_batch`]), so every row is **bit-identical** to
    /// running the chunks and the decode batch as separate calls at the
    /// same cache state — interleaving never perturbs sampling.
    ///
    /// Returns (per-chunk last-position logits — `Some` iff wanted —
    /// per-decode logits, the number of quantized KV tiles the prefill
    /// side dequantized — 0 on an f32 cache; the
    /// `EngineMetrics::prefill_dequant_tiles` feed — and the step's
    /// score-bound tile skips across both sides and all layers — 0
    /// under a dense sparsity config; the `EngineMetrics::skipped_tiles`
    /// feed).
    #[allow(clippy::too_many_arguments)]
    pub fn forward_mixed(
        &self,
        chunk_tokens: &[&[u32]],
        chunk_tables: &mut [&mut BlockTable],
        chunk_want: &[bool],
        decode_tokens: &[u32],
        decode_tables: &mut [&mut BlockTable],
        cache: &mut dyn KvStore,
        prefill_threads: Option<usize>,
        decode_threads: Option<usize>,
    ) -> (Vec<Option<Vec<f32>>>, Vec<Vec<f32>>, usize, usize) {
        let cfg = self.config();
        let n_c = chunk_tokens.len();
        assert_eq!(n_c, chunk_tables.len());
        assert_eq!(n_c, chunk_want.len());
        let n_d = decode_tokens.len();
        assert_eq!(n_d, decode_tables.len());
        // Pure decode steps keep the dedicated batch path (identical
        // numerics; also the path audited by the zero-alloc test).
        if n_c == 0 {
            if n_d == 0 {
                return (Vec::new(), Vec::new(), 0, 0);
            }
            let (logits, skipped) =
                self.decode_batch_with(decode_tokens, cache, decode_tables, decode_threads);
            return (Vec::new(), logits, 0, skipped);
        }
        let chunk_rows: Vec<usize> = chunk_tokens.iter().map(|t| t.len()).collect();
        assert!(chunk_rows.iter().all(|&r| r > 0), "empty prefill chunk");
        let n_p: usize = chunk_rows.iter().sum();
        let n = n_p + n_d;

        // Row layout: [chunk 0 rows | chunk 1 rows | … | decode rows].
        let mut all_tokens: Vec<u32> = Vec::with_capacity(n);
        for t in chunk_tokens {
            all_tokens.extend_from_slice(t);
        }
        all_tokens.extend_from_slice(decode_tokens);

        // Claim physical slots once; every layer writes K/V through the
        // same mapping.
        let bs = cache.block_size();
        let mut chunk_base = Vec::with_capacity(n_c);
        let mut slots = Vec::with_capacity(n);
        for (ci, table) in chunk_tables.iter_mut().enumerate() {
            chunk_base.push(table.len());
            for _ in 0..chunk_rows[ci] {
                slots.push(table.append_slot(bs));
            }
        }
        for table in decode_tables.iter_mut() {
            slots.push(table.append_slot(bs));
        }

        let kvd = cfg.kv_dim();
        let c_tables: Vec<&BlockTable> = chunk_tables.iter().map(|t| &**t).collect();
        let d_tables: Vec<&BlockTable> = decode_tables.iter().map(|t| &**t).collect();
        let total_decode_kv: usize = d_tables.iter().map(|t| t.len()).sum();
        let threads_d =
            decode_threads.unwrap_or_else(|| auto_decode_threads(n_d, total_decode_kv));
        // Fan-out widths are layer-invariant: size them once per chunk,
        // not once per (layer, chunk). A pinned prefill width applies to
        // every chunk.
        let threads_c: Vec<usize> = match prefill_threads.filter(|&t| t > 0) {
            Some(t) => vec![t; n_c],
            None => chunk_rows
                .iter()
                .zip(&chunk_base)
                .map(|(&rows, &base)| auto_prefill_threads(rows, base + rows))
                .collect(),
        };
        let acfg = cfg.attn_config();
        let row = cfg.d_model;
        let mut dequant_tiles = 0usize;
        let mut skipped_tiles = 0usize;

        let mut x = self.embed_tokens(&all_tokens); // [n, d]
        let mut attn = Tensor::zeros(&[n, cfg.d_model]);
        for li in 0..cfg.n_layers {
            let xn = rmsnorm(&x, self.store.rms_attn(li), cfg.rms_eps);
            let q = self.proj(li, Proj::Wq, &xn); // [n, d] — one stream of wq for ALL rows
            let k = self.proj(li, Proj::Wk, &xn);
            let v = self.proj(li, Proj::Wv, &xn);
            for (i, &(b, s)) in slots.iter().enumerate() {
                cache.write_token(
                    li,
                    b,
                    s,
                    &k.data()[i * kvd..(i + 1) * kvd],
                    &v.data()[i * kvd..(i + 1) * kvd],
                );
            }
            // Prefill chunks: stream each chunk's visible context tile
            // by tile out of the paged store (no dense gather) and fan
            // its query rows across the persistent worker pool.
            let mut r0 = 0usize;
            for ci in 0..n_c {
                let rows = chunk_rows[ci];
                let base = chunk_base[ci];
                let (dq, sk) = paged_prefill_rows_parallel(
                    &acfg,
                    &*cache,
                    li,
                    &q.data()[r0 * row..(r0 + rows) * row],
                    rows,
                    base,
                    c_tables[ci],
                    threads_c[ci],
                    &mut attn.data_mut()[r0 * row..(r0 + rows) * row],
                );
                dequant_tiles += dq;
                skipped_tiles += sk;
                r0 += rows;
            }
            // Decode rows: the per-sequence paged fan-out.
            if n_d > 0 {
                skipped_tiles += paged_decode_batch(
                    &acfg,
                    cache,
                    li,
                    &q.data()[n_p * row..],
                    &d_tables,
                    threads_d,
                    &mut attn.data_mut()[n_p * row..],
                );
            }
            let attn_out = self.proj(li, Proj::Wo, &attn); // one stream of wo
            x.add_assign(&attn_out);
            let xn2 = rmsnorm(&x, self.store.rms_mlp(li), cfg.rms_eps);
            let h = self.mlp(li, &xn2); // one stream of the MLP weights
            x.add_assign(&h);
        }
        // LM head only on the rows whose logits matter: each WANTED
        // chunk's last row (mid-flight chunks skip the largest matvec in
        // the model) plus every decode row.
        let mut sel_rows = Vec::with_capacity(n_c + n_d);
        let mut r0 = 0usize;
        for (ci, &rows) in chunk_rows.iter().enumerate() {
            if chunk_want[ci] {
                sel_rows.push(r0 + rows - 1);
            }
            r0 += rows;
        }
        let n_want = sel_rows.len();
        for i in 0..n_d {
            sel_rows.push(n_p + i);
        }
        if sel_rows.is_empty() {
            // Only mid-flight chunks this step: no logits needed at all.
            return (vec![None; n_c], Vec::new(), dequant_tiles, skipped_tiles);
        }
        let mut sel = Vec::with_capacity(sel_rows.len() * cfg.d_model);
        for &r in &sel_rows {
            sel.extend_from_slice(x.row(r));
        }
        let sel = Tensor::from_vec(&[sel_rows.len(), cfg.d_model], sel);
        let normed = rmsnorm(&sel, self.store.final_norm(), cfg.rms_eps);
        let logits = normed.matmul_nt(self.store.lm_head());
        let mut next_want = 0usize;
        let chunk_logits = (0..n_c)
            .map(|ci| {
                chunk_want[ci].then(|| {
                    let l = logits.row(next_want).to_vec();
                    next_want += 1;
                    l
                })
            })
            .collect();
        let decode_logits = (0..n_d).map(|i| logits.row(n_want + i).to_vec()).collect();
        (chunk_logits, decode_logits, dequant_tiles, skipped_tiles)
    }

    /// Final norm + LM head on the last row only (decode never needs the
    /// other rows' logits).
    fn last_row_logits(&self, x: &Tensor) -> Vec<f32> {
        let cfg = self.config();
        let n = x.shape()[0];
        let last = Tensor::from_vec(&[1, cfg.d_model], x.row(n - 1).to_vec());
        let normed = rmsnorm(&last, self.store.final_norm(), cfg.rms_eps);
        normed.matmul_nt(self.store.lm_head()).into_vec()
    }

    /// Run a calibration pass over `tokens` *without* a cache, capturing
    /// the activations GPTQ needs: per layer, the attention input rows
    /// (`[n, d_model]`), the MLP input rows (`[n, d_model]`) and the
    /// hidden rows feeding `w_down` (`[n, d_ff]`).
    pub fn calibrate(&self, tokens: &[u32]) -> (Vec<Vec<f32>>, Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let cfg = self.config();
        let n = tokens.len();
        let mut attn_in = Vec::with_capacity(cfg.n_layers);
        let mut mlp_in = Vec::with_capacity(cfg.n_layers);
        let mut ff_hidden = Vec::with_capacity(cfg.n_layers);

        let mut x = self.embed_tokens(tokens);
        for li in 0..cfg.n_layers {
            let xn = rmsnorm(&x, self.store.rms_attn(li), cfg.rms_eps);
            attn_in.push(xn.data().to_vec());
            let q = self.proj(li, Proj::Wq, &xn);
            let k = self.proj(li, Proj::Wk, &xn);
            let v = self.proj(li, Proj::Wv, &xn);
            let attn = gqa_attention(&cfg.attn_config(), q.data(), k.data(), v.data(), n, n, 0);
            let attn = self.proj(li, Proj::Wo, &Tensor::from_vec(&[n, cfg.d_model], attn));
            x.add_assign(&attn);
            let xn2 = rmsnorm(&x, self.store.rms_mlp(li), cfg.rms_eps);
            mlp_in.push(xn2.data().to_vec());
            let mut gate = self.proj(li, Proj::WGate, &xn2);
            let up = self.proj(li, Proj::WUp, &xn2);
            gate.silu_inplace();
            let h = gate.mul(&up);
            ff_hidden.push(h.data().to_vec());
            let down = self.proj(li, Proj::WDown, &h);
            x.add_assign(&down);
        }
        (attn_in, mlp_in, ff_hidden)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::{BlockAllocator, PagedKvCache};

    fn mk(seed: u64) -> (NativeModel, PagedKvCache, BlockAllocator) {
        let cfg = ModelConfig::tiny();
        let model = NativeModel::new(ModelWeights::init(&cfg, seed));
        let cache = PagedKvCache::new(cfg.n_layers, 32, 8, cfg.n_kv_heads, cfg.head_dim());
        let alloc = BlockAllocator::new(32, 8);
        (model, cache, alloc)
    }

    #[test]
    fn prefill_then_decode_matches_full_prefill() {
        // logits(prefill(t0..t4)) == logits(prefill(t0..t3) then decode(t4)).
        let (model, mut cache_a, mut alloc_a) = mk(1);
        let tokens = [256u32, 10, 20, 30, 40]; // BOS + bytes
        let mut table_a = BlockTable::new();
        table_a.reserve(tokens.len(), &mut alloc_a);
        let full = model.prefill(&tokens, &mut cache_a, &mut table_a);

        let (_, mut cache_b, mut alloc_b) = mk(1);
        let mut table_b = BlockTable::new();
        table_b.reserve(tokens.len(), &mut alloc_b);
        let _ = model.prefill(&tokens[..4], &mut cache_b, &mut table_b);
        let inc = model.decode_step(tokens[4], &mut cache_b, &mut table_b);

        assert_eq!(full.len(), inc.len());
        for (a, b) in full.iter().zip(&inc) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn chunked_prefill_matches_full_prefill() {
        let (model, mut cache_a, mut alloc_a) = mk(2);
        let tokens = [256u32, 1, 2, 3, 4, 5, 6];
        let mut table_a = BlockTable::new();
        table_a.reserve(tokens.len(), &mut alloc_a);
        let full = model.prefill(&tokens, &mut cache_a, &mut table_a);

        let (_, mut cache_b, mut alloc_b) = mk(2);
        let mut table_b = BlockTable::new();
        table_b.reserve(tokens.len(), &mut alloc_b);
        let _ = model.prefill(&tokens[..3], &mut cache_b, &mut table_b);
        let chunk2 = model.prefill(&tokens[3..], &mut cache_b, &mut table_b);

        for (a, b) in full.iter().zip(&chunk2) {
            assert!((a - b).abs() < 1e-3);
        }
    }

    #[test]
    fn logits_shape_and_finiteness() {
        let (model, mut cache, mut alloc) = mk(3);
        let mut table = BlockTable::new();
        table.reserve(4, &mut alloc);
        let logits = model.prefill(&[256, 65, 66, 67], &mut cache, &mut table);
        assert_eq!(logits.len(), model.config().vocab);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn deterministic_across_runs() {
        let (model, mut cache_a, mut alloc_a) = mk(4);
        let mut t_a = BlockTable::new();
        t_a.reserve(3, &mut alloc_a);
        let a = model.prefill(&[256, 9, 9], &mut cache_a, &mut t_a);
        let (model2, mut cache_b, mut alloc_b) = mk(4);
        let mut t_b = BlockTable::new();
        t_b.reserve(3, &mut alloc_b);
        let b = model2.prefill(&[256, 9, 9], &mut cache_b, &mut t_b);
        assert_eq!(a, b);
    }

    #[test]
    fn decode_batch_threading_is_bit_identical() {
        // The attention fan-out must never change sampled numerics.
        let run = |threads: Option<usize>| {
            let (model, mut cache, mut alloc) = mk(8);
            let mut t1 = BlockTable::new();
            let mut t2 = BlockTable::new();
            let mut t3 = BlockTable::new();
            t1.reserve(6, &mut alloc);
            t2.reserve(6, &mut alloc);
            t3.reserve(6, &mut alloc);
            model.prefill(&[256, 1, 2, 3], &mut cache, &mut t1);
            model.prefill(&[256, 9], &mut cache, &mut t2);
            model.prefill(&[256, 40, 41, 42, 43], &mut cache, &mut t3);
            let mut tables = [&mut t1, &mut t2, &mut t3];
            model.decode_batch_with(&[5, 6, 7], &mut cache, &mut tables, threads)
        };
        let serial = run(Some(1));
        assert_eq!(serial, run(Some(4)));
        assert_eq!(serial, run(None));
    }

    #[test]
    fn prefill_threads_are_bit_identical_and_gather_free() {
        // The prefill fan-out width must never change logits or cache
        // contents, and the streamed path must leave the dense-gather
        // counter untouched (gather is test/debug only now).
        let run = |threads: Option<usize>| {
            let (model, mut cache, mut alloc) = mk(19);
            let mut table = BlockTable::new();
            table.reserve(16, &mut alloc);
            let tokens: Vec<u32> = (0..12).map(|i| 256 + (i % 200)).collect();
            let logits = model.prefill_with(&tokens, &mut cache, &mut table, threads);
            assert_eq!(
                crate::kvcache::KvStore::gather_bytes(&cache),
                0,
                "prefill must not touch KvStore::gather"
            );
            let dump = cache.gather(0, &table);
            (logits, dump)
        };
        let serial = run(Some(1));
        assert_eq!(serial, run(Some(3)));
        assert_eq!(serial, run(None));
    }

    #[test]
    fn forward_mixed_is_bit_identical_to_separate_calls() {
        // A mixed step (one mid-flight prefill chunk + a decode batch)
        // must equal running the chunk and the decode as separate calls
        // at the same cache state — for logits AND cache contents, on
        // both cache dtypes. This is the contract that makes interleaved
        // scheduling invisible to sampling.
        use crate::kvcache::QuantizedPagedKvCache;
        let cfg = ModelConfig::tiny();
        let model = NativeModel::new(ModelWeights::init(&cfg, 12));
        let b_tokens = [256u32, 5, 6, 7, 8, 9, 10];
        for quant in [false, true] {
            let mk_cache = || -> Box<dyn crate::kvcache::KvStore> {
                if quant {
                    Box::new(QuantizedPagedKvCache::new(cfg.n_layers, 32, 8, cfg.n_kv_heads, cfg.head_dim()))
                } else {
                    Box::new(PagedKvCache::new(cfg.n_layers, 32, 8, cfg.n_kv_heads, cfg.head_dim()))
                }
            };
            // Shared prior state: seq A prefilled (about to decode), seq
            // B's first 3 tokens prefilled (chunk of 4 pending).
            let setup = |cache: &mut dyn crate::kvcache::KvStore| {
                let mut alloc = BlockAllocator::new(32, 8);
                let mut ta = BlockTable::new();
                let mut tb = BlockTable::new();
                ta.reserve(8, &mut alloc);
                tb.reserve(8, &mut alloc);
                model.prefill(&[256, 1, 2, 3], cache, &mut ta);
                model.prefill(&b_tokens[..3], cache, &mut tb);
                (ta, tb)
            };

            let mut cache_ref = mk_cache();
            let (mut ta1, mut tb1) = setup(cache_ref.as_mut());
            let chunk_ref = model.prefill(&b_tokens[3..], cache_ref.as_mut(), &mut tb1);
            let dec_ref = model.decode_step(4, cache_ref.as_mut(), &mut ta1);

            let mut cache_mix = mk_cache();
            let (mut ta2, mut tb2) = setup(cache_mix.as_mut());
            let (chunk_logits, dec_logits, dq_tiles, skipped) = model.forward_mixed(
                &[&b_tokens[3..]],
                &mut [&mut tb2],
                &[true],
                &[4],
                &mut [&mut ta2],
                cache_mix.as_mut(),
                Some(1),
                Some(1),
            );
            assert_eq!(
                chunk_logits[0].as_deref(),
                Some(chunk_ref.as_slice()),
                "quant={quant}: chunk logits diverged"
            );
            assert_eq!(dec_logits[0], dec_ref, "quant={quant}: decode logits diverged");
            assert_eq!(
                dq_tiles > 0,
                quant,
                "prefill dequant tiles counted iff the cache is packed"
            );
            assert_eq!(skipped, 0, "dense default must never skip a tile");
            // Cache contents match too (gathers are dense dumps).
            for li in 0..cfg.n_layers {
                assert_eq!(cache_ref.gather(li, &tb1), cache_mix.gather(li, &tb2), "layer {li}");
                assert_eq!(cache_ref.gather(li, &ta1), cache_mix.gather(li, &ta2), "layer {li}");
            }
        }
    }

    #[test]
    fn forward_mixed_multi_chunk_and_threads_deterministic() {
        // Several chunks + several decoders in one step, across thread
        // widths: outputs must not depend on the fan-out.
        let cfg = ModelConfig::tiny();
        let model = NativeModel::new(ModelWeights::init(&cfg, 13));
        let run = |threads: Option<usize>| {
            let mut cache = PagedKvCache::new(cfg.n_layers, 64, 8, cfg.n_kv_heads, cfg.head_dim());
            let mut alloc = BlockAllocator::new(64, 8);
            let mut t_c1 = BlockTable::new();
            let mut t_c2 = BlockTable::new();
            let mut t_d1 = BlockTable::new();
            let mut t_d2 = BlockTable::new();
            for t in [&mut t_c1, &mut t_c2, &mut t_d1, &mut t_d2] {
                t.reserve(16, &mut alloc);
            }
            model.prefill(&[256, 1], &mut cache, &mut t_d1);
            model.prefill(&[256, 2, 3], &mut cache, &mut t_d2);
            let c1: Vec<u32> = (0..11).map(|i| 30 + i).collect();
            let c2: Vec<u32> = (0..5).map(|i| 60 + i).collect();
            model.forward_mixed(
                &[c1.as_slice(), c2.as_slice()],
                &mut [&mut t_c1, &mut t_c2],
                &[true, true],
                &[7, 8],
                &mut [&mut t_d1, &mut t_d2],
                &mut cache,
                threads,
                threads,
            )
        };
        let serial = run(Some(1));
        assert_eq!(serial, run(Some(4)));
        assert_eq!(serial, run(None));
        assert_eq!(serial.0.len(), 2);
        assert_eq!(serial.1.len(), 2);
        assert_eq!(serial.2, 0, "f32 cache dequantizes no tiles");
        assert_eq!(serial.3, 0, "dense default must never skip a tile");
        assert!(serial.0[0].as_ref().unwrap().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn quantized_kv_cache_generates_close_to_f32() {
        // Same model, same prompt, f32 vs q8 KV pools: logits stay finite
        // and close (the KV pool is the only difference).
        use crate::kvcache::QuantizedPagedKvCache;
        let cfg = ModelConfig::tiny();
        let model = NativeModel::new(ModelWeights::init(&cfg, 9));
        let run = |quant: bool| {
            let mut fcache = PagedKvCache::new(cfg.n_layers, 32, 8, cfg.n_kv_heads, cfg.head_dim());
            let mut qcache =
                QuantizedPagedKvCache::new(cfg.n_layers, 32, 8, cfg.n_kv_heads, cfg.head_dim());
            let cache: &mut dyn crate::kvcache::KvStore =
                if quant { &mut qcache } else { &mut fcache };
            let mut alloc = BlockAllocator::new(32, 8);
            let mut table = BlockTable::new();
            table.reserve(6, &mut alloc);
            let _ = model.prefill(&[256, 7, 8, 9], cache, &mut table);
            model.decode_step(10, cache, &mut table)
        };
        let f = run(false);
        let q = run(true);
        assert!(q.iter().all(|v| v.is_finite()));
        let max_diff =
            f.iter().zip(&q).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(max_diff < 0.5, "q8 KV must not derail logits (max diff {max_diff})");
    }

    #[test]
    fn mha_baseline_runs() {
        let cfg = ModelConfig::tiny().as_mha_baseline();
        let model = NativeModel::new(ModelWeights::init(&cfg, 5));
        let mut cache = PagedKvCache::new(cfg.n_layers, 16, 8, cfg.n_kv_heads, cfg.head_dim());
        let mut alloc = BlockAllocator::new(16, 8);
        let mut table = BlockTable::new();
        table.reserve(5, &mut alloc);
        let _ = model.prefill(&[256, 1, 2, 3], &mut cache, &mut table);
        let logits = model.decode_step(4, &mut cache, &mut table);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn calibrate_shapes() {
        let (model, _, _) = mk(6);
        let cfg = *model.config();
        let (attn, mlp, ffh) = model.calibrate(&[256, 1, 2, 3, 4]);
        assert_eq!(attn.len(), cfg.n_layers);
        assert_eq!(attn[0].len(), 5 * cfg.d_model);
        assert_eq!(mlp[1].len(), 5 * cfg.d_model);
        assert_eq!(ffh[0].len(), 5 * cfg.d_ff);
    }

    #[test]
    fn gptq_calibrated_model_still_generates() {
        use crate::model::weights::{quantize_weights, QuantMethod};
        let cfg = ModelConfig::tiny();
        let weights = ModelWeights::init(&cfg, 7);
        let model = NativeModel::new(weights.clone());
        let mut cache = PagedKvCache::new(cfg.n_layers, 32, 8, cfg.n_kv_heads, cfg.head_dim());
        let mut alloc = BlockAllocator::new(32, 8);
        let calib_tokens: Vec<u32> = (0..32).map(|i| 256 + 0 * i + (i % 250)).collect();
        let (a, m, f) = model.calibrate(&calib_tokens);
        let mut w = weights;
        let report = quantize_weights(&mut w, QuantMethod::Gptq, 4, 32, false, &a, &m, &f);
        assert!(report.mean_error() < 0.25, "mean err {}", report.mean_error());
        let qmodel = NativeModel::new(w);
        let mut table = BlockTable::new();
        table.reserve(4, &mut alloc);
        let logits = qmodel.prefill(&[256, 1, 2, 3], &mut cache, &mut table);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn packed_store_forward_is_bit_identical_to_its_reconstruction() {
        // The packed-weight serving contract at model level: a packed
        // store and a dense store holding numerically-identical weights
        // (the fake-quant reconstruction of the SAME quantization) give
        // byte-identical logits on prefill and decode. The heavyweight
        // grid (bits × threads × mixed steps × engine) lives in
        // tests/weights_parity.rs.
        use crate::model::weights::{quantize_weights, quantize_weights_packed, QuantMethod};
        let cfg = ModelConfig::tiny();
        let weights = ModelWeights::init(&cfg, 17);
        let mut recon = weights.clone();
        quantize_weights(&mut recon, QuantMethod::Rtn, 4, 32, false, &[], &[], &[]);
        let (packed, _) =
            quantize_weights_packed(&weights, QuantMethod::Rtn, 4, 32, false, &[], &[], &[]);
        let dense_model = NativeModel::new(recon);
        let packed_model = NativeModel::from_store(std::sync::Arc::new(packed));
        assert_eq!(
            packed_model.store().dtype(),
            crate::model::WeightDtype::Q4,
            "store dtype surfaces"
        );
        let run = |model: &NativeModel| {
            let mut cache =
                PagedKvCache::new(cfg.n_layers, 32, 8, cfg.n_kv_heads, cfg.head_dim());
            let mut alloc = BlockAllocator::new(32, 8);
            let mut table = BlockTable::new();
            table.reserve(8, &mut alloc);
            let pre = model.prefill(&[256, 1, 2, 3, 4], &mut cache, &mut table);
            let dec = model.decode_step(5, &mut cache, &mut table);
            (pre, dec)
        };
        assert_eq!(run(&dense_model), run(&packed_model));
    }
}
