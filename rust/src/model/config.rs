//! Model shape configuration and presets.

use crate::attention::gqa::{AttnConfig, Bias, ScoreDomain};
use crate::attention::sparsity::SparsityConfig;

/// Llama-style decoder configuration.
///
/// Positional information comes from ALiBi (when `alibi` is true) — the
/// paper's configuration — so there is no rotary/positional embedding
/// table anywhere.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelConfig {
    /// Embedding-table rows (padded to a multiple of 128; see tokenizer).
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    /// KV heads (== `n_heads` for the MHA baseline).
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    /// ALiBi position bias (paper config) vs pure causal.
    pub alibi: bool,
    pub rms_eps: f32,
    /// Sliding-window/sink/skip attention sparsity (CLI
    /// `--window-blocks`/`--sink-blocks`/`--skip-threshold`). Dense by
    /// default; a **runtime serving knob**, not part of the weight
    /// artifact — `ModelWeights::save`/`load` neither writes nor reads
    /// it, and artifact config checks compare shapes with
    /// [`ModelConfig::shape_eq`].
    pub sparsity: SparsityConfig,
    /// Attention score arithmetic domain for the q8 decode walk (CLI
    /// `--q8-score-domain`). Like `sparsity`, a **runtime serving
    /// knob**: not part of the weight artifact, ignored by
    /// [`ModelConfig::shape_eq`], default `F32` everywhere.
    pub score_domain: ScoreDomain,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        assert!(self.d_model % self.n_heads == 0);
        self.d_model / self.n_heads
    }

    /// KV projection width (`n_kv_heads * head_dim`).
    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim()
    }

    /// Query heads per KV group (`G`).
    pub fn group_size(&self) -> usize {
        assert!(self.n_heads % self.n_kv_heads == 0);
        self.n_heads / self.n_kv_heads
    }

    pub fn attn_config(&self) -> AttnConfig {
        AttnConfig {
            num_heads: self.n_heads,
            num_kv_heads: self.n_kv_heads,
            head_dim: self.head_dim(),
            bias: if self.alibi { Bias::Alibi } else { Bias::None },
            sparsity: self.sparsity,
            score_domain: self.score_domain,
        }
    }

    /// Shape equality — every field except the runtime serving knobs
    /// ([`SparsityConfig`], [`ScoreDomain`]). Weight artifacts pin the
    /// shape, not the serving policy, so loaders compare with this
    /// instead of `==`.
    pub fn shape_eq(&self, other: &ModelConfig) -> bool {
        let norm = |c: &ModelConfig| ModelConfig {
            sparsity: SparsityConfig::dense(),
            score_domain: ScoreDomain::F32,
            ..*c
        };
        norm(self) == norm(other)
    }

    /// This config with a different sparsity policy (builder-style, for
    /// CLI flag application after a preset/artifact lookup).
    pub fn with_sparsity(&self, sparsity: SparsityConfig) -> ModelConfig {
        ModelConfig { sparsity, ..*self }
    }

    /// This config with a different score domain (builder-style, for
    /// CLI flag application after a preset/artifact lookup).
    pub fn with_score_domain(&self, score_domain: ScoreDomain) -> ModelConfig {
        ModelConfig { score_domain, ..*self }
    }

    /// Total parameter count.
    pub fn param_count(&self) -> usize {
        let d = self.d_model;
        let kv = self.kv_dim();
        let per_layer = d * d            // wq
            + 2 * d * kv                 // wk, wv
            + d * d                      // wo
            + 3 * d * self.d_ff          // gate, up, down
            + 2 * d; // two RMSNorm scales
        self.vocab * d                   // embedding
            + self.n_layers * per_layer
            + d                          // final norm
            + self.vocab * d // lm head
    }

    /// KV-cache bytes per token (f32), all layers.
    pub fn kv_bytes_per_token(&self) -> usize {
        2 * self.n_layers * self.kv_dim() * 4
    }

    /// MHA baseline twin: same model but `n_kv_heads == n_heads` and no
    /// ALiBi — what the paper's "before Opt-GQA" engine runs.
    pub fn as_mha_baseline(&self) -> ModelConfig {
        ModelConfig { n_kv_heads: self.n_heads, alibi: false, ..*self }
    }

    /// Test-size model (≈1M params): fast enough for unit tests.
    pub fn tiny() -> ModelConfig {
        ModelConfig {
            vocab: 384,
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            n_kv_heads: 2,
            d_ff: 128,
            max_seq: 256,
            alibi: true,
            rms_eps: 1e-5,
            sparsity: SparsityConfig::dense(),
            score_domain: ScoreDomain::F32,
        }
    }

    /// Small demo model (≈13M params): examples that must run in seconds.
    pub fn small() -> ModelConfig {
        ModelConfig {
            vocab: 384,
            d_model: 256,
            n_layers: 6,
            n_heads: 8,
            n_kv_heads: 2,
            d_ff: 768,
            max_seq: 1024,
            alibi: true,
            rms_eps: 1e-5,
            sparsity: SparsityConfig::dense(),
            score_domain: ScoreDomain::F32,
        }
    }

    /// The E2E driver model (≈100M params), Llama-3-8B shrunk with its
    /// proportions kept (GQA 3:1..4:1, wide FFN).
    pub fn mini() -> ModelConfig {
        ModelConfig {
            vocab: 384,
            d_model: 768,
            n_layers: 12,
            n_heads: 12,
            n_kv_heads: 4,
            d_ff: 3072,
            max_seq: 2048,
            alibi: true,
            rms_eps: 1e-5,
            sparsity: SparsityConfig::dense(),
            score_domain: ScoreDomain::F32,
        }
    }

    /// Look up a preset by name (CLI surface).
    pub fn preset(name: &str) -> Option<ModelConfig> {
        match name {
            "tiny" => Some(Self::tiny()),
            "small" => Some(Self::small()),
            "mini" => Some(Self::mini()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_consistent() {
        for name in ["tiny", "small", "mini"] {
            let c = ModelConfig::preset(name).unwrap();
            assert_eq!(c.d_model % c.n_heads, 0, "{name}");
            assert_eq!(c.n_heads % c.n_kv_heads, 0, "{name}");
            assert_eq!(c.vocab % 128, 0, "{name}");
            assert!(c.param_count() > 0);
        }
        assert!(ModelConfig::preset("bogus").is_none());
    }

    #[test]
    fn mini_is_about_100m_params() {
        let c = ModelConfig::mini();
        let p = c.param_count();
        assert!(
            (80_000_000..140_000_000).contains(&p),
            "mini params = {p}"
        );
    }

    #[test]
    fn gqa_shrinks_kv_bytes_by_group_factor() {
        let c = ModelConfig::mini();
        let mha = c.as_mha_baseline();
        assert_eq!(
            mha.kv_bytes_per_token(),
            c.kv_bytes_per_token() * c.group_size()
        );
    }

    #[test]
    fn baseline_twin_differs_only_in_kv_and_alibi() {
        let c = ModelConfig::tiny();
        let b = c.as_mha_baseline();
        assert_eq!(b.n_kv_heads, b.n_heads);
        assert!(!b.alibi);
        assert_eq!(b.d_model, c.d_model);
        assert_eq!(b.n_layers, c.n_layers);
    }
}
