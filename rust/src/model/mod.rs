//! Llama-architecture model: config, weights, native forward, sampling.
//!
//! The serving engine is model-agnostic up to this module's interface:
//! [`config::ModelConfig`] fixes shapes, [`weights::ModelWeights`] holds
//! (optionally GPTQ-quantized) parameters, [`llama`] implements the native
//! f32 forward pass over the paged KV cache, and [`sampler`] turns logits
//! into tokens. The XLA backend executes the same architecture from
//! AOT-lowered HLO (`python/compile/model.py`) — `llama` doubles as its
//! numerics oracle in integration tests.

pub mod config;
pub mod llama;
pub mod sampler;
pub mod weights;

pub use config::ModelConfig;
pub use llama::NativeModel;
pub use sampler::{Sampler, SamplingParams};
pub use weights::ModelWeights;
