//! Llama-architecture model: config, weights, native forward, sampling.
//!
//! The serving engine is model-agnostic up to this module's interface:
//! [`config::ModelConfig`] fixes shapes, [`store::WeightStore`] abstracts
//! parameter storage — dense f32 ([`weights::ModelWeights`]) or packed
//! GPTQ/RTN ([`store::PackedModelWeights`], served through the fused
//! dequant-matmul) — [`llama`] implements the native forward pass over
//! the paged KV cache, and [`sampler`] turns logits into tokens. The XLA
//! backend executes the same architecture from AOT-lowered HLO
//! (`python/compile/model.py`) — `llama` doubles as its numerics oracle
//! in integration tests.

pub mod config;
pub mod llama;
pub mod sampler;
pub mod store;
pub mod weights;

pub use config::ModelConfig;
pub use llama::NativeModel;
pub use sampler::{Sampler, SamplingParams};
pub use store::{
    PackedModelWeights, PackedProjection, Proj, QuantizedLayerWeights, WeightDtype, WeightStore,
};
pub use weights::ModelWeights;
