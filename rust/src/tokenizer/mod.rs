//! Byte-level tokenizer.
//!
//! Serving metrics do not depend on a trained vocabulary, so the engine
//! uses a byte-level scheme: token ids 0–255 are raw bytes, followed by
//! the special tokens. The model's embedding table is padded to an
//! MXU-friendly multiple of 128 (see [`ByteTokenizer::padded_vocab`]).

/// Beginning-of-sequence token id.
pub const BOS: u32 = 256;
/// End-of-sequence token id.
pub const EOS: u32 = 257;
/// Padding token id (scheduler bucket padding).
pub const PAD: u32 = 258;

/// Number of real token ids (bytes + specials).
pub const VOCAB_SIZE: usize = 259;

/// Stateless byte-level tokenizer.
#[derive(Debug, Clone, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub fn new() -> Self {
        ByteTokenizer
    }

    /// Real vocabulary size (bytes + specials).
    pub fn vocab_size(&self) -> usize {
        VOCAB_SIZE
    }

    /// Vocabulary padded up to a multiple of 128 for MXU-shaped matmuls.
    pub fn padded_vocab(&self) -> usize {
        crate::util::round_up(VOCAB_SIZE, 128)
    }

    /// Encode text as `[BOS, bytes...]`.
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut out = Vec::with_capacity(text.len() + 1);
        out.push(BOS);
        out.extend(text.bytes().map(|b| b as u32));
        out
    }

    /// Decode token ids back to text; specials are dropped, invalid UTF-8
    /// is replaced (lossy) so generation never panics mid-stream.
    pub fn decode(&self, tokens: &[u32]) -> String {
        let bytes: Vec<u8> = tokens
            .iter()
            .filter(|&&t| t < 256)
            .map(|&t| t as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// True when a generated token terminates the sequence.
    pub fn is_eos(&self, token: u32) -> bool {
        token == EOS
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let tok = ByteTokenizer::new();
        let ids = tok.encode("hello, world");
        assert_eq!(ids[0], BOS);
        assert_eq!(tok.decode(&ids), "hello, world");
    }

    #[test]
    fn roundtrip_utf8() {
        let tok = ByteTokenizer::new();
        let s = "héllo 😀";
        assert_eq!(tok.decode(&tok.encode(s)), s);
    }

    #[test]
    fn specials_are_dropped_on_decode() {
        let tok = ByteTokenizer::new();
        assert_eq!(tok.decode(&[BOS, b'a' as u32, EOS, PAD]), "a");
    }

    #[test]
    fn padded_vocab_is_mxu_friendly() {
        let tok = ByteTokenizer::new();
        assert_eq!(tok.padded_vocab() % 128, 0);
        assert!(tok.padded_vocab() >= tok.vocab_size());
        assert_eq!(tok.padded_vocab(), 384);
    }

    #[test]
    fn empty_text() {
        let tok = ByteTokenizer::new();
        let ids = tok.encode("");
        assert_eq!(ids, vec![BOS]);
        assert_eq!(tok.decode(&ids), "");
    }
}
