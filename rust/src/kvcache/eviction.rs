//! Preemption/eviction policy for memory-pressure recovery.
//!
//! When the block pool cannot serve a decode step, the scheduler evicts
//! (preempts) running sequences and re-queues them for recomputation —
//! vLLM's recompute-preemption, which the paper's "dynamic load balancing
//! and resource scheduling" (§III.C) builds on.

/// A candidate the policy can preempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EvictionCandidate {
    pub seq_id: u64,
    /// Blocks the sequence currently holds (freed on eviction).
    pub blocks_held: usize,
    /// Scheduler arrival order (smaller = older).
    pub arrival: u64,
}

/// Chooses which sequences to preempt to free at least `blocks_needed`.
pub trait EvictionPolicy {
    /// Return seq ids to evict, or an empty vec if the target cannot be
    /// met (caller then stalls instead of evicting uselessly).
    fn select(&self, candidates: &[EvictionCandidate], blocks_needed: usize) -> Vec<u64>;
}

/// Evict the *youngest* sequences first (vLLM's default): older requests
/// have more sunk prefill cost and finish sooner, so preempting the
/// newest minimizes wasted work. "LRU" here refers to least-recently
/// *admitted*.
#[derive(Debug, Default, Clone)]
pub struct LruEviction;

impl EvictionPolicy for LruEviction {
    fn select(&self, candidates: &[EvictionCandidate], blocks_needed: usize) -> Vec<u64> {
        let mut sorted: Vec<_> = candidates.to_vec();
        // Youngest (largest arrival) first.
        sorted.sort_by_key(|c| std::cmp::Reverse(c.arrival));
        let mut freed = 0usize;
        let mut out = Vec::new();
        for c in sorted {
            if freed >= blocks_needed {
                break;
            }
            freed += c.blocks_held;
            out.push(c.seq_id);
        }
        if freed >= blocks_needed {
            out
        } else {
            Vec::new()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(seq_id: u64, blocks: usize, arrival: u64) -> EvictionCandidate {
        EvictionCandidate { seq_id, blocks_held: blocks, arrival }
    }

    #[test]
    fn evicts_youngest_first() {
        let p = LruEviction;
        let cands = vec![cand(1, 4, 10), cand(2, 4, 30), cand(3, 4, 20)];
        let out = p.select(&cands, 4);
        assert_eq!(out, vec![2]); // arrival 30 = youngest
    }

    #[test]
    fn evicts_multiple_until_target() {
        let p = LruEviction;
        let cands = vec![cand(1, 2, 1), cand(2, 2, 2), cand(3, 2, 3)];
        let out = p.select(&cands, 3);
        assert_eq!(out, vec![3, 2]);
    }

    #[test]
    fn returns_empty_when_unsatisfiable() {
        let p = LruEviction;
        let cands = vec![cand(1, 1, 1)];
        assert!(p.select(&cands, 5).is_empty());
    }

    #[test]
    fn zero_needed_evicts_nothing() {
        let p = LruEviction;
        assert!(p.select(&[cand(1, 1, 1)], 0).is_empty());
    }
}
