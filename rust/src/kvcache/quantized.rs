//! Quantized paged KV storage: the f32 pool's 8-bit twin.
//!
//! Same geometry as [`super::paged::PagedKvCache`] — per layer, `[num_blocks,
//! block_size, kv_heads, head_dim]` for both K and V — but each value is
//! stored as an 8-bit level packed four-per-`i32` word (the
//! [`crate::quant::packing`] format), with one asymmetric
//! `(scale, zero)` grid per **(block, kv_head)** per side (K and V fitted
//! independently). Tokens are quantized on [`QuantizedPagedKvCache::write_token`]
//! (append time) and a dense f32 pool is never materialized; the attention
//! kernel dequantizes one tile at a time into workspace scratch
//! (TurboAttention-style in-tile dequant — see
//! `attention::kernel::Workspace::process_quant_tile`).
//!
//! ## Streaming grid maintenance
//!
//! A block's contents arrive one token at a time, but its grid covers the
//! whole `(block, kv_head)` group. The cache keeps a running min/max per
//! group; when a new token expands the observed range, the group is
//! **refit and requantized in place** (dequantize the stored levels under
//! the old grid, re-quantize under the new one — bounded work:
//! `filled_slots × head_dim` values, where a per-(block, head) fill
//! frontier confines the round-trip to slots actually written this
//! tenancy; the known-zero unwritten tail is bulk-filled with the new
//! grid's zero level instead). Within one tenancy ranges only ever
//! widen, so freshly written tokens always land on the final grid and
//! requantization drift is confined to a block's earliest tokens (each
//! refit adds at most half a step, and step sizes grow with the range,
//! so the total is on the order of one final step). A write to slot 0
//! resets the group — blocks fill front-to-back, so slot 0 marks a
//! freshly (re)claimed block — which keeps a reused block from
//! inheriting the previous sequence's stale, wider grid. Unwritten slots
//! hold exact zeros under every grid (grids always contain zero), so
//! stale slots cannot leak.
//!
//! Non-finite values are unsupported on this path (a NaN/∞ range has no
//! meaningful grid); debug builds assert.

use super::block_allocator::BlockId;
use super::block_table::BlockTable;
use crate::quant::packing::{self, levels_per_word};
use crate::quant::QuantParams;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Field width the KV cache packs with (full bytes).
pub const KV_PACK_BITS: u32 = 8;

/// Borrowed view of one quantized KV block (one side, K or V): packed
/// levels plus the per-kv-head grids that decode them.
///
/// This is what [`super::KvStore::block_view`] hands the attention kernel;
/// `Workspace::process_quant_tile` dequantizes it into scratch and runs
/// the ordinary tile schedule.
#[derive(Debug, Clone, Copy)]
pub struct QuantKvTile<'a> {
    /// Packed levels, `[slots, kv_heads, words_per_head]` row-major.
    pub words: &'a [i32],
    /// Grid step per kv head (`[kv_heads]`).
    pub scales: &'a [f32],
    /// Grid zero point per kv head (`[kv_heads]`).
    pub zeros: &'a [i32],
    /// `i32` words per `(slot, kv_head)` vector.
    pub words_per_head: usize,
}

impl QuantKvTile<'_> {
    /// Dequantize the first `slots` rows into `out`
    /// (`[slots, kv_heads, head_dim]`, dense — the same layout
    /// `Workspace::process_tile` consumes).
    pub fn dequantize_into(&self, slots: usize, kv_heads: usize, head_dim: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), slots * kv_heads * head_dim);
        debug_assert_eq!(self.scales.len(), kv_heads);
        debug_assert_eq!(self.zeros.len(), kv_heads);
        let wph = self.words_per_head;
        debug_assert!(self.words.len() >= slots * kv_heads * wph);
        for slot in 0..slots {
            for head in 0..kv_heads {
                let w0 = (slot * kv_heads + head) * wph;
                let o0 = (slot * kv_heads + head) * head_dim;
                packing::unpack_dequant_row(
                    &self.words[w0..w0 + wph],
                    KV_PACK_BITS,
                    self.scales[head],
                    self.zeros[head],
                    &mut out[o0..o0 + head_dim],
                );
            }
        }
    }

    /// Dequantize one slot's `[kv_heads, head_dim]` row into `out` —
    /// the decode driver reads the query's own key for self-score skip
    /// seeding without paying a whole-tile dequant.
    pub fn dequantize_slot_into(
        &self,
        slot: usize,
        kv_heads: usize,
        head_dim: usize,
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), kv_heads * head_dim);
        let wph = self.words_per_head;
        debug_assert!(self.words.len() >= (slot + 1) * kv_heads * wph);
        for head in 0..kv_heads {
            let w0 = (slot * kv_heads + head) * wph;
            packing::unpack_dequant_row(
                &self.words[w0..w0 + wph],
                KV_PACK_BITS,
                self.scales[head],
                self.zeros[head],
                &mut out[head * head_dim..(head + 1) * head_dim],
            );
        }
    }
}

/// One side (K or V) of one layer: packed pool + per-(block, kv_head)
/// grids and running ranges.
#[derive(Debug, Clone)]
struct QuantPlane {
    /// `[num_blocks, block_size, kv_heads, words_per_head]` packed levels.
    words: Vec<i32>,
    /// `[num_blocks, kv_heads]` grid steps.
    scales: Vec<f32>,
    /// `[num_blocks, kv_heads]` grid zero points.
    zeros: Vec<i32>,
    /// `[num_blocks, kv_heads]` running minima (only ever decreases).
    lo: Vec<f32>,
    /// `[num_blocks, kv_heads]` running maxima (only ever increases).
    hi: Vec<f32>,
    /// `[num_blocks, kv_heads]` fill frontier: one past the highest slot
    /// written this tenancy. Slots at or beyond the frontier hold the
    /// grid's zero level (exact 0.0), so range-widening requants skip
    /// them — a bulk zero-level fill instead of
    /// unpack→dequant→quant→pack per known-zero slot.
    filled: Vec<u32>,
}

impl QuantPlane {
    fn new(num_blocks: usize, block_size: usize, kv_heads: usize, words_per_head: usize) -> Self {
        QuantPlane {
            words: vec![0; num_blocks * block_size * kv_heads * words_per_head],
            // scale 1 / zero 0 decodes the all-zero initial pool to exact
            // zeros, and equals `fit_range(0, 0)` so the first real write
            // always triggers a refit.
            scales: vec![1.0; num_blocks * kv_heads],
            zeros: vec![0; num_blocks * kv_heads],
            lo: vec![0.0; num_blocks * kv_heads],
            hi: vec![0.0; num_blocks * kv_heads],
            filled: vec![0; num_blocks * kv_heads],
        }
    }

    /// Bytes held by this plane (packed payload + grids + range state).
    fn bytes(&self) -> usize {
        self.words.len() * 4
            + self.scales.len() * 4
            + self.zeros.len() * 4
            + self.lo.len() * 4
            + self.hi.len() * 4
            + self.filled.len() * 4
    }
}

/// Paged K/V storage with 8-bit packed blocks — the [`super::KvStore`]
/// implementation behind `KvCacheDtype::Q8`.
///
/// Geometry and the write/read protocol match [`super::paged::PagedKvCache`];
/// only the storage differs (≈0.26× the f32 pool bytes at typical shapes:
/// 1 payload byte per value plus 20 grid/state bytes per `(block,
/// kv_head, side)` — scale, zero, running range, fill frontier). Reads go through [`QuantKvTile`] views so attention dequantizes
/// per tile; [`QuantizedPagedKvCache::gather`] materializes a dense copy
/// only for the prefill path, exactly like the f32 cache's gather.
#[derive(Debug)]
pub struct QuantizedPagedKvCache {
    num_layers: usize,
    num_blocks: usize,
    block_size: usize,
    kv_heads: usize,
    head_dim: usize,
    words_per_head: usize,
    /// `keys[layer]` / `values[layer]` are the per-layer packed pools.
    keys: Vec<QuantPlane>,
    values: Vec<QuantPlane>,
    /// Requantization scratch (`head_dim` f32s) so range refits never
    /// allocate — decode steps stay allocation-free end to end.
    scratch: Vec<f32>,
    /// Bytes materialized (dequantized to dense f32) by
    /// [`QuantizedPagedKvCache::gather`] since construction — the
    /// `CacheStats::gather_bytes` observability feed; 0 on the serving
    /// hot path since the paged-native prefill refactor.
    gathered: AtomicUsize,
}

impl QuantizedPagedKvCache {
    pub fn new(
        num_layers: usize,
        num_blocks: usize,
        block_size: usize,
        kv_heads: usize,
        head_dim: usize,
    ) -> Self {
        let words_per_head = head_dim.div_ceil(levels_per_word(KV_PACK_BITS));
        QuantizedPagedKvCache {
            num_layers,
            num_blocks,
            block_size,
            kv_heads,
            head_dim,
            words_per_head,
            keys: (0..num_layers)
                .map(|_| QuantPlane::new(num_blocks, block_size, kv_heads, words_per_head))
                .collect(),
            values: (0..num_layers)
                .map(|_| QuantPlane::new(num_blocks, block_size, kv_heads, words_per_head))
                .collect(),
            scratch: vec![0.0; head_dim],
            gathered: AtomicUsize::new(0),
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }
    pub fn kv_heads(&self) -> usize {
        self.kv_heads
    }
    pub fn head_dim(&self) -> usize {
        self.head_dim
    }
    pub fn num_blocks(&self) -> usize {
        self.num_blocks
    }
    pub fn num_layers(&self) -> usize {
        self.num_layers
    }

    /// True bytes held by the packed pools: payload words plus the
    /// per-(block, kv_head) grids and range state, both sides, all layers.
    pub fn pool_bytes(&self) -> usize {
        self.keys.iter().map(QuantPlane::bytes).sum::<usize>()
            + self.values.iter().map(QuantPlane::bytes).sum::<usize>()
    }

    /// Word offset of a `(block, slot, head)` vector — THE owner of the
    /// packed-pool layout. The associated form exists because
    /// [`QuantizedPagedKvCache::write_head`] splits `&mut self` into
    /// plane + scratch borrows and cannot take `&self`.
    #[inline]
    fn word_off_for(
        block_size: usize,
        kv_heads: usize,
        words_per_head: usize,
        block: BlockId,
        slot: usize,
        head: usize,
    ) -> usize {
        ((block as usize * block_size + slot) * kv_heads + head) * words_per_head
    }

    /// Grid index of a `(block, head)` group (associated form: see
    /// [`QuantizedPagedKvCache::word_off_for`]).
    #[inline]
    fn grid_idx_for(kv_heads: usize, block: BlockId, head: usize) -> usize {
        block as usize * kv_heads + head
    }

    /// Word offset of a `(block, slot, head)` vector.
    #[inline]
    fn word_off(&self, block: BlockId, slot: usize, head: usize) -> usize {
        Self::word_off_for(self.block_size, self.kv_heads, self.words_per_head, block, slot, head)
    }

    /// Grid index of a `(block, head)` group.
    #[inline]
    fn grid_idx(&self, block: BlockId, head: usize) -> usize {
        Self::grid_idx_for(self.kv_heads, block, head)
    }

    /// Quantize-and-store one head vector, refitting + requantizing the
    /// whole `(block, head)` group first if `vals` widens its range.
    ///
    /// A write to **slot 0** resets the group first (grids, ranges, and
    /// packed words back to the pristine all-zero state): block tables
    /// fill blocks front-to-back, so slot 0 marks a freshly (re)claimed
    /// block, and without the reset a reused block would keep the
    /// previous sequence's — possibly far wider — range and quantize the
    /// new tokens on a stale coarse grid. (Mid-block continuations —
    /// chunked prefill, decode appends, post-COW writes — never start at
    /// slot 0 of a block they didn't already write or copy.)
    fn write_head(
        plane: &mut QuantPlane,
        scratch: &mut [f32],
        block_size: usize,
        kv_heads: usize,
        words_per_head: usize,
        block: BlockId,
        slot: usize,
        head: usize,
        vals: &[f32],
    ) {
        let widx =
            |s: usize| Self::word_off_for(block_size, kv_heads, words_per_head, block, s, head);
        let gi = Self::grid_idx_for(kv_heads, block, head);
        if slot == 0 {
            // Per-slot: this head's words interleave with other heads'.
            for s in 0..block_size {
                plane.words[widx(s)..widx(s) + words_per_head].fill(0);
            }
            plane.scales[gi] = 1.0;
            plane.zeros[gi] = 0;
            plane.lo[gi] = 0.0;
            plane.hi[gi] = 0.0;
            plane.filled[gi] = 0;
        }
        let mut lo = plane.lo[gi];
        let mut hi = plane.hi[gi];
        for &x in vals {
            debug_assert!(x.is_finite(), "quantized KV cache requires finite values, got {x}");
            lo = lo.min(x);
            hi = hi.max(x);
        }
        if lo < plane.lo[gi] || hi > plane.hi[gi] {
            let p = QuantParams::fit_range(lo, hi, KV_PACK_BITS);
            if p.scale != plane.scales[gi] || p.zero != plane.zeros[gi] {
                let old = QuantParams {
                    scale: plane.scales[gi],
                    zero: plane.zeros[gi],
                    bits: KV_PACK_BITS,
                };
                let d = scratch.len();
                // Only slots below the fill frontier carry live levels:
                // round-trip those through the old grid, and bulk-fill
                // the known-zero tail with the new grid's zero level
                // (decodes to exactly 0.0) instead of requantizing it.
                let frontier = plane.filled[gi] as usize;
                for s in 0..frontier {
                    let words = &mut plane.words[widx(s)..widx(s) + words_per_head];
                    packing::unpack_dequant_row(words, KV_PACK_BITS, old.scale, old.zero, &mut scratch[..d]);
                    packing::quant_pack_row(&scratch[..d], &p, words);
                }
                let zword = packing::broadcast_level_word(p.zero, KV_PACK_BITS);
                for s in frontier..block_size {
                    plane.words[widx(s)..widx(s) + words_per_head].fill(zword);
                }
                plane.scales[gi] = p.scale;
                plane.zeros[gi] = p.zero;
            }
            plane.lo[gi] = lo;
            plane.hi[gi] = hi;
        }
        let p = QuantParams { scale: plane.scales[gi], zero: plane.zeros[gi], bits: KV_PACK_BITS };
        packing::quant_pack_row(vals, &p, &mut plane.words[widx(slot)..widx(slot) + words_per_head]);
        plane.filled[gi] = plane.filled[gi].max(slot as u32 + 1);
    }

    /// Quantize and store one token's K and V vectors (all kv heads,
    /// `kv_heads * head_dim` values each) into a physical slot — the
    /// quantizing twin of `PagedKvCache::write_token`.
    pub fn write_token(&mut self, layer: usize, block: BlockId, slot: usize, k: &[f32], v: &[f32]) {
        let d = self.head_dim;
        assert_eq!(k.len(), self.kv_heads * d, "key vector length");
        assert_eq!(v.len(), self.kv_heads * d, "value vector length");
        debug_assert!((block as usize) < self.num_blocks);
        debug_assert!(slot < self.block_size);
        for head in 0..self.kv_heads {
            Self::write_head(
                &mut self.keys[layer],
                &mut self.scratch,
                self.block_size,
                self.kv_heads,
                self.words_per_head,
                block,
                slot,
                head,
                &k[head * d..(head + 1) * d],
            );
            Self::write_head(
                &mut self.values[layer],
                &mut self.scratch,
                self.block_size,
                self.kv_heads,
                self.words_per_head,
                block,
                slot,
                head,
                &v[head * d..(head + 1) * d],
            );
        }
    }

    /// Borrowed packed views of one block (K and V).
    pub fn block_tiles(&self, layer: usize, block: BlockId) -> (QuantKvTile<'_>, QuantKvTile<'_>) {
        let wpb = self.block_size * self.kv_heads * self.words_per_head;
        let w0 = block as usize * wpb;
        let g0 = block as usize * self.kv_heads;
        let kp = &self.keys[layer];
        let vp = &self.values[layer];
        let k = QuantKvTile {
            words: &kp.words[w0..w0 + wpb],
            scales: &kp.scales[g0..g0 + self.kv_heads],
            zeros: &kp.zeros[g0..g0 + self.kv_heads],
            words_per_head: self.words_per_head,
        };
        let v = QuantKvTile {
            words: &vp.words[w0..w0 + wpb],
            scales: &vp.scales[g0..g0 + self.kv_heads],
            zeros: &vp.zeros[g0..g0 + self.kv_heads],
            words_per_head: self.words_per_head,
        };
        (k, v)
    }

    /// Elementwise bounds on every K value `block_tiles` can decode for
    /// `(block, kv_head)` — the [`super::KvStore::key_tile_bounds`]
    /// metadata, derived from the quantization grid itself: a stored
    /// level is in `0..=2^bits − 1` and decodes to `(level − zero)·scale`
    /// (monotone in the level, `scale ≥ 0`), so the grid's end levels
    /// bound everything the dequantizer can produce. The endpoints are
    /// computed with the *same* f32 arithmetic as
    /// `packing::unpack_dequant_row`, so the bound is exact, not merely
    /// conservative — no extra state beyond the grids is needed.
    pub fn key_tile_bounds(&self, layer: usize, block: BlockId, kv_head: usize) -> (f32, f32) {
        let gi = self.grid_idx(block, kv_head);
        let kp = &self.keys[layer];
        let (scale, zero) = (kp.scales[gi], kp.zeros[gi]);
        let max_level = (1i32 << KV_PACK_BITS) - 1;
        ((0 - zero) as f32 * scale, (max_level - zero) as f32 * scale)
    }

    /// Dequantize one token's K and V (all kv heads) into the tails of
    /// `k_out` / `v_out` — the gather building block.
    fn dequant_token(&self, layer: usize, block: BlockId, slot: usize, k_out: &mut [f32], v_out: &mut [f32]) {
        let d = self.head_dim;
        for head in 0..self.kv_heads {
            let w0 = self.word_off(block, slot, head);
            let gi = self.grid_idx(block, head);
            let kp = &self.keys[layer];
            packing::unpack_dequant_row(
                &kp.words[w0..w0 + self.words_per_head],
                KV_PACK_BITS,
                kp.scales[gi],
                kp.zeros[gi],
                &mut k_out[head * d..(head + 1) * d],
            );
            let vp = &self.values[layer];
            packing::unpack_dequant_row(
                &vp.words[w0..w0 + self.words_per_head],
                KV_PACK_BITS,
                vp.scales[gi],
                vp.zeros[gi],
                &mut v_out[head * d..(head + 1) * d],
            );
        }
    }

    /// Gather a sequence's K and V into contiguous dense
    /// `[len, kv_heads*head_dim]` buffers (dequantized) — a **test/debug
    /// dump** since the paged-native prefill refactor (attention
    /// dequantizes tiles in place; nothing on the serving path calls
    /// this). Counted by [`QuantizedPagedKvCache::gather_bytes`].
    pub fn gather(&self, layer: usize, table: &BlockTable) -> (Vec<f32>, Vec<f32>) {
        let d = self.kv_heads * self.head_dim;
        self.gathered.fetch_add(2 * table.len() * d * 4, Ordering::Relaxed);
        let mut ks = vec![0.0f32; table.len() * d];
        let mut vs = vec![0.0f32; table.len() * d];
        for pos in 0..table.len() {
            let (b, s) = table.locate(pos, self.block_size);
            self.dequant_token(layer, b, s, &mut ks[pos * d..(pos + 1) * d], &mut vs[pos * d..(pos + 1) * d]);
        }
        (ks, vs)
    }

    /// Total dense f32 bytes materialized through
    /// [`QuantizedPagedKvCache::gather`].
    pub fn gather_bytes(&self) -> usize {
        self.gathered.load(Ordering::Relaxed)
    }

    /// Byte length of one [`QuantizedPagedKvCache::export_block`] payload.
    pub fn block_export_bytes(&self) -> usize {
        let wpb = self.block_size * self.kv_heads * self.words_per_head;
        // Per plane: packed words + 5 grid/state arrays of kv_heads u32-width values.
        self.num_layers * 2 * (wpb + 5 * self.kv_heads) * 4
    }

    /// Serialize one block's complete state — packed words, grids,
    /// running ranges and fill frontiers, all layers, both sides — as
    /// exact little-endian bytes (the same per-block state
    /// [`QuantizedPagedKvCache::copy_block`] copies).
    /// [`QuantizedPagedKvCache::import_block`] of this payload
    /// reproduces the block bit-for-bit: the stored levels are moved as
    /// levels, never dequantized, so a round trip involves no
    /// requantization and decodes identically to the source block.
    pub fn export_block(&self, block: BlockId) -> Vec<u8> {
        let wpb = self.block_size * self.kv_heads * self.words_per_head;
        let w0 = block as usize * wpb;
        let g0 = block as usize * self.kv_heads;
        let kvh = self.kv_heads;
        let mut out = Vec::with_capacity(self.block_export_bytes());
        for layer in 0..self.num_layers {
            for plane in [&self.keys[layer], &self.values[layer]] {
                for &w in &plane.words[w0..w0 + wpb] {
                    out.extend_from_slice(&w.to_le_bytes());
                }
                for &s in &plane.scales[g0..g0 + kvh] {
                    out.extend_from_slice(&s.to_le_bytes());
                }
                for &z in &plane.zeros[g0..g0 + kvh] {
                    out.extend_from_slice(&z.to_le_bytes());
                }
                for &x in &plane.lo[g0..g0 + kvh] {
                    out.extend_from_slice(&x.to_le_bytes());
                }
                for &x in &plane.hi[g0..g0 + kvh] {
                    out.extend_from_slice(&x.to_le_bytes());
                }
                for &f in &plane.filled[g0..g0 + kvh] {
                    out.extend_from_slice(&f.to_le_bytes());
                }
            }
        }
        out
    }

    /// Inverse of [`QuantizedPagedKvCache::export_block`]: overwrite
    /// `block` (all layers, both sides) from an exported payload.
    /// Returns `false` (block untouched) on a length mismatch — the
    /// caller treats that as a miss, never a panic.
    pub fn import_block(&mut self, block: BlockId, bytes: &[u8]) -> bool {
        if bytes.len() != self.block_export_bytes() {
            return false;
        }
        let wpb = self.block_size * self.kv_heads * self.words_per_head;
        let w0 = block as usize * wpb;
        let g0 = block as usize * self.kv_heads;
        let kvh = self.kv_heads;
        let mut cursor = 0usize;
        let mut word = |c: &mut usize| {
            let b: [u8; 4] = bytes[*c..*c + 4].try_into().unwrap();
            *c += 4;
            b
        };
        for layer in 0..self.num_layers {
            for plane in [&mut self.keys[layer], &mut self.values[layer]] {
                for w in &mut plane.words[w0..w0 + wpb] {
                    *w = i32::from_le_bytes(word(&mut cursor));
                }
                for s in &mut plane.scales[g0..g0 + kvh] {
                    *s = f32::from_le_bytes(word(&mut cursor));
                }
                for z in &mut plane.zeros[g0..g0 + kvh] {
                    *z = i32::from_le_bytes(word(&mut cursor));
                }
                for x in &mut plane.lo[g0..g0 + kvh] {
                    *x = f32::from_le_bytes(word(&mut cursor));
                }
                for x in &mut plane.hi[g0..g0 + kvh] {
                    *x = f32::from_le_bytes(word(&mut cursor));
                }
                for f in &mut plane.filled[g0..g0 + kvh] {
                    *f = u32::from_le_bytes(word(&mut cursor));
                }
            }
        }
        true
    }

    /// Copy a block's contents — packed words, grids and ranges, all
    /// layers, both sides (used after a COW split).
    pub fn copy_block(&mut self, src: BlockId, dst: BlockId) {
        let wpb = self.block_size * self.kv_heads * self.words_per_head;
        let (ws, wd) = (src as usize * wpb, dst as usize * wpb);
        let (gs, gd) = (src as usize * self.kv_heads, dst as usize * self.kv_heads);
        let kvh = self.kv_heads;
        for layer in 0..self.num_layers {
            for plane in [&mut self.keys[layer], &mut self.values[layer]] {
                plane.words.copy_within(ws..ws + wpb, wd);
                plane.scales.copy_within(gs..gs + kvh, gd);
                plane.zeros.copy_within(gs..gs + kvh, gd);
                plane.lo.copy_within(gs..gs + kvh, gd);
                plane.hi.copy_within(gs..gs + kvh, gd);
                plane.filled.copy_within(gs..gs + kvh, gd);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::{BlockAllocator, PagedKvCache};
    use crate::util::rng::Rng;

    fn fill(
        cache: &mut QuantizedPagedKvCache,
        table: &mut BlockTable,
        rows: &[Vec<f32>],
        vrows: &[Vec<f32>],
        block_size: usize,
    ) {
        for (k, v) in rows.iter().zip(vrows) {
            let (b, s) = table.append_slot(block_size);
            cache.write_token(0, b, s, k, v);
        }
    }

    #[test]
    fn roundtrip_error_bounded_by_final_grid() {
        let (kvh, d, bs) = (2usize, 8usize, 4usize);
        let mut rng = Rng::new(1);
        let n = 11;
        let rows: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(kvh * d, 1.0)).collect();
        let vrows: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(kvh * d, 1.0)).collect();
        let mut cache = QuantizedPagedKvCache::new(1, 4, bs, kvh, d);
        let mut alloc = BlockAllocator::new(4, bs);
        let mut table = BlockTable::new();
        assert!(table.reserve(n, &mut alloc));
        fill(&mut cache, &mut table, &rows, &vrows, bs);
        let (ks, vs) = cache.gather(0, &table);
        for t in 0..n {
            let (b, _) = table.locate(t, bs);
            for head in 0..kvh {
                let gi = cache.grid_idx(b, head);
                // Drift bound: early tokens may have been requantized as
                // the range grew; total drift stays within ~2 final steps.
                let kstep = cache.keys[0].scales[gi];
                let vstep = cache.values[0].scales[gi];
                for j in 0..d {
                    let i = head * d + j;
                    let ke = (ks[t * kvh * d + i] - rows[t][i]).abs();
                    let ve = (vs[t * kvh * d + i] - vrows[t][i]).abs();
                    assert!(ke <= 2.0 * kstep + 1e-5, "t={t} i={i}: ke={ke} step={kstep}");
                    assert!(ve <= 2.0 * vstep + 1e-5, "t={t} i={i}: ve={ve} step={vstep}");
                }
            }
        }
    }

    #[test]
    fn fresh_tokens_land_on_final_grid_exactly() {
        // The LAST token written to a block must round-trip within half a
        // step (it is never requantized afterwards).
        let (kvh, d, bs) = (1usize, 4usize, 4usize);
        let mut cache = QuantizedPagedKvCache::new(1, 1, bs, kvh, d);
        let mut alloc = BlockAllocator::new(1, bs);
        let mut table = BlockTable::new();
        assert!(table.reserve(4, &mut alloc));
        let mut rng = Rng::new(2);
        let rows: Vec<Vec<f32>> = (0..4).map(|_| rng.normal_vec(d, 1.0)).collect();
        fill(&mut cache, &mut table, &rows, &rows, bs);
        let (ks, _) = cache.gather(0, &table);
        let step = cache.keys[0].scales[0];
        for j in 0..d {
            let e = (ks[3 * d + j] - rows[3][j]).abs();
            assert!(e <= 0.5 * step + 1e-6, "j={j}: {e} vs half-step {}", 0.5 * step);
        }
    }

    #[test]
    fn unwritten_slots_decode_to_exact_zero() {
        let mut cache = QuantizedPagedKvCache::new(1, 2, 4, 2, 4);
        // Write one token with large values; the other 3 slots must stay 0.
        cache.write_token(0, 0, 1, &[5.0; 8], &[-3.0; 8]);
        let (k, v) = cache.block_tiles(0, 0);
        let mut kd = vec![9.0f32; 4 * 2 * 4];
        let mut vd = vec![9.0f32; 4 * 2 * 4];
        k.dequantize_into(4, 2, 4, &mut kd);
        v.dequantize_into(4, 2, 4, &mut vd);
        for slot in [0usize, 2, 3] {
            for i in 0..8 {
                assert_eq!(kd[slot * 8 + i], 0.0, "slot {slot}");
                assert_eq!(vd[slot * 8 + i], 0.0, "slot {slot}");
            }
        }
        // And the written slot is close.
        for i in 0..8 {
            assert!((kd[8 + i] - 5.0).abs() < 0.05);
            assert!((vd[8 + i] + 3.0).abs() < 0.05);
        }
    }

    #[test]
    fn layers_are_independent() {
        let mut cache = QuantizedPagedKvCache::new(2, 2, 4, 1, 4);
        cache.write_token(0, 0, 0, &[1.0; 4], &[2.0; 4]);
        let mut out_k = vec![0.0f32; 4];
        let mut out_v = vec![0.0f32; 4];
        cache.dequant_token(1, 0, 0, &mut out_k, &mut out_v);
        assert_eq!(out_k, vec![0.0; 4]);
        assert_eq!(out_v, vec![0.0; 4]);
    }

    #[test]
    fn copy_block_preserves_decoded_values() {
        let mut cache = QuantizedPagedKvCache::new(2, 3, 4, 2, 4);
        let mut rng = Rng::new(3);
        for layer in 0..2 {
            for slot in 0..4 {
                let k = rng.normal_vec(8, 1.0);
                let v = rng.normal_vec(8, 1.0);
                cache.write_token(layer, 0, slot, &k, &v);
            }
        }
        let mut before_k = vec![0.0f32; 8];
        let mut before_v = vec![0.0f32; 8];
        cache.dequant_token(1, 0, 2, &mut before_k, &mut before_v);
        cache.copy_block(0, 2);
        let mut after_k = vec![0.0f32; 8];
        let mut after_v = vec![0.0f32; 8];
        cache.dequant_token(1, 2, 2, &mut after_k, &mut after_v);
        assert_eq!(before_k, after_k);
        assert_eq!(before_v, after_v);
    }

    #[test]
    fn range_only_widens_and_outlier_triggers_requant() {
        let mut cache = QuantizedPagedKvCache::new(1, 1, 4, 1, 4);
        cache.write_token(0, 0, 0, &[0.1, -0.1, 0.05, 0.0], &[0.0; 4]);
        let s_before = cache.keys[0].scales[0];
        cache.write_token(0, 0, 1, &[10.0, 0.0, 0.0, 0.0], &[0.0; 4]);
        let s_after = cache.keys[0].scales[0];
        assert!(s_after > s_before, "outlier must widen the grid");
        // The earlier token survives the requant within the new step.
        let mut k = vec![0.0f32; 4];
        let mut v = vec![0.0f32; 4];
        cache.dequant_token(0, 0, 0, &mut k, &mut v);
        assert!((k[0] - 0.1).abs() <= s_after, "requant drift bound");
    }

    #[test]
    fn block_reuse_resets_stale_grids() {
        // A freed block reused by another sequence must not inherit the
        // previous tenant's (much wider) quantization range: the slot-0
        // write resets the group, so small values get a fine grid again.
        let (kvh, d, bs) = (1usize, 4usize, 4usize);
        let mut cache = QuantizedPagedKvCache::new(1, 1, bs, kvh, d);
        // Tenant A: huge range → coarse grid.
        for slot in 0..bs {
            cache.write_token(0, 0, slot, &[10.0, -10.0, 5.0, -5.0], &[8.0; 4]);
        }
        let coarse = cache.keys[0].scales[0];
        assert!(coarse > 0.05, "tenant A grid must be coarse ({coarse})");
        // Tenant B reuses the block (fresh fill from slot 0, tiny values).
        let vals = [0.11f32, -0.07, 0.05, 0.02];
        for slot in 0..bs {
            cache.write_token(0, 0, slot, &vals, &[0.01; 4]);
        }
        let fine = cache.keys[0].scales[0];
        assert!(fine < coarse / 10.0, "grid must refit to the new tenant ({fine} vs {coarse})");
        let mut k = vec![0.0f32; 4];
        let mut v = vec![0.0f32; 4];
        cache.dequant_token(0, 0, 2, &mut k, &mut v);
        for (a, b) in k.iter().zip(&vals) {
            assert!((a - b).abs() <= fine, "reused block must be accurate: {a} vs {b}");
        }
    }

    #[test]
    fn fill_frontier_tracks_writes_and_requant_keeps_tail_zero() {
        // The frontier must follow the highest written slot, reset with
        // the tenancy, and a range-widening requant must leave the
        // unwritten tail decoding to EXACT zeros under the new grid
        // (the tail is zero-level-filled, not round-tripped).
        let (kvh, d, bs) = (1usize, 4usize, 8usize);
        let mut cache = QuantizedPagedKvCache::new(1, 1, bs, kvh, d);
        cache.write_token(0, 0, 0, &[0.1, -0.1, 0.05, 0.0], &[0.2; 4]);
        cache.write_token(0, 0, 1, &[0.08, 0.0, -0.02, 0.01], &[0.1; 4]);
        assert_eq!(cache.keys[0].filled[0], 2);
        // Outlier at slot 2 widens the range → refit + requant of slots
        // 0..2 only; slots 3..8 must still decode to exact 0.0.
        cache.write_token(0, 0, 2, &[9.0, 0.0, 0.0, 0.0], &[5.0; 4]);
        assert_eq!(cache.keys[0].filled[0], 3);
        assert!(cache.keys[0].zeros[0] != 0, "asymmetric grid has a nonzero zero point");
        let (kt, vt) = cache.block_tiles(0, 0);
        let mut kd = vec![9.9f32; bs * kvh * d];
        let mut vd = vec![9.9f32; bs * kvh * d];
        kt.dequantize_into(bs, kvh, d, &mut kd);
        vt.dequantize_into(bs, kvh, d, &mut vd);
        for slot in 3..bs {
            for i in 0..d {
                assert_eq!(kd[slot * d + i], 0.0, "k slot {slot}");
                assert_eq!(vd[slot * d + i], 0.0, "v slot {slot}");
            }
        }
        // Early tokens survived the requant within the (coarse) new step.
        let step = cache.keys[0].scales[0];
        assert!((kd[0] - 0.1).abs() <= step + 1e-5);
        // Tenancy reset: a slot-0 write pulls the frontier back.
        cache.write_token(0, 0, 0, &[0.3; 4], &[0.0; 4]);
        assert_eq!(cache.keys[0].filled[0], 1);
    }

    #[test]
    fn key_bounds_cover_every_decodable_value() {
        // The grid-derived bound must dominate every value the tile view
        // can decode — including requant-widened grids and the zero tail
        // — because that is exactly what the attention kernel reads.
        let (kvh, d, bs) = (2usize, 4usize, 4usize);
        let mut cache = QuantizedPagedKvCache::new(1, 2, bs, kvh, d);
        let mut rng = Rng::new(7);
        for slot in 0..bs {
            let mut k = rng.normal_vec(kvh * d, 1.0);
            if slot == 2 {
                k[0] = 8.0; // outlier → range refit mid-block
            }
            cache.write_token(0, 0, slot, &k, &rng.normal_vec(kvh * d, 1.0));
        }
        let (kt, _) = cache.block_tiles(0, 0);
        let mut kd = vec![0.0f32; bs * kvh * d];
        kt.dequantize_into(bs, kvh, d, &mut kd);
        for head in 0..kvh {
            let (lo, hi) = cache.key_tile_bounds(0, 0, head);
            assert!(lo.is_finite() && hi.is_finite() && lo <= 0.0 && 0.0 <= hi);
            for slot in 0..bs {
                for j in 0..d {
                    let x = kd[(slot * kvh + head) * d + j];
                    assert!(lo <= x && x <= hi, "head={head} slot={slot} j={j}: {x} ∉ [{lo}, {hi}]");
                }
            }
        }
        // An untouched block's pristine grid bounds its all-zero decode.
        let (lo, hi) = cache.key_tile_bounds(0, 1, 0);
        assert!(lo <= 0.0 && 0.0 <= hi, "pristine grid must cover zero: [{lo}, {hi}]");
    }

    #[test]
    fn export_import_roundtrips_levels_grids_and_frontier_bit_exactly() {
        let (kvh, d, bs) = (2usize, 4usize, 4usize);
        let mut cache = QuantizedPagedKvCache::new(2, 3, bs, kvh, d);
        let mut rng = Rng::new(9);
        for layer in 0..2 {
            for slot in 0..3 {
                // Partial fill (3 of 4 slots) so the frontier matters.
                let mut k = rng.normal_vec(kvh * d, 1.0);
                if slot == 1 {
                    k[0] = 6.0; // mid-block refit → nontrivial grids
                }
                cache.write_token(layer, 1, slot, &k, &rng.normal_vec(kvh * d, 1.0));
            }
        }
        let bytes = cache.export_block(1);
        assert_eq!(bytes.len(), cache.block_export_bytes());
        let mut other = QuantizedPagedKvCache::new(2, 3, bs, kvh, d);
        assert!(other.import_block(2, &bytes));
        for layer in 0..2 {
            // Raw packed state matches word-for-word (no requantization).
            let (sk, sv) = cache.block_tiles(layer, 1);
            let (ok, ov) = other.block_tiles(layer, 2);
            assert_eq!(sk.words, ok.words);
            assert_eq!(sk.scales, ok.scales);
            assert_eq!(sk.zeros, ok.zeros);
            assert_eq!(sv.words, ov.words);
            assert_eq!(sv.scales, ov.scales);
            assert_eq!(sv.zeros, ov.zeros);
            for h in 0..kvh {
                let sgi = cache.grid_idx(1, h);
                let ogi = other.grid_idx(2, h);
                assert_eq!(cache.keys[layer].filled[sgi], other.keys[layer].filled[ogi]);
                assert_eq!(cache.keys[layer].lo[sgi], other.keys[layer].lo[ogi]);
                assert_eq!(cache.keys[layer].hi[sgi], other.keys[layer].hi[ogi]);
                assert_eq!(
                    cache.key_tile_bounds(layer, 1, h),
                    other.key_tile_bounds(layer, 2, h)
                );
            }
        }
        // And a continued fill behaves as if the block never left: the
        // restored fill frontier keeps the tail zero-level-filled.
        assert!(!other.import_block(0, &bytes[1..]), "length mismatch is a refusal");
    }

    #[test]
    fn pool_bytes_math_and_ratio() {
        // Realistic-ish shape: packed pool must be ≤ 0.3× the f32 pool.
        let (layers, blocks, bs, kvh, d) = (2usize, 16usize, 16usize, 2usize, 64usize);
        let q = QuantizedPagedKvCache::new(layers, blocks, bs, kvh, d);
        let f = PagedKvCache::new(layers, blocks, bs, kvh, d);
        let wph = d.div_ceil(4);
        // 20 state bytes per (block, head): scale, zero, lo, hi, frontier.
        let per_plane = blocks * bs * kvh * wph * 4 + blocks * kvh * 20;
        assert_eq!(q.pool_bytes(), 2 * layers * per_plane);
        assert!(
            10 * q.pool_bytes() <= 3 * f.pool_bytes(),
            "packed {} vs f32 {}",
            q.pool_bytes(),
            f.pool_bytes()
        );
    }
}
