//! Reference-counted fixed-size block allocator.
//!
//! The pool is pre-allocated once (paper §III.C: "pre-allocating a fixed
//! amount of DCU memory … centralized scheduling mechanism"); allocation
//! and free are O(1) free-list operations. Reference counts support
//! copy-on-write block sharing across sequences.

/// Physical block index into the pool.
pub type BlockId = u32;

/// Fixed-pool block allocator with refcounts.
#[derive(Debug)]
pub struct BlockAllocator {
    num_blocks: usize,
    block_size: usize,
    free: Vec<BlockId>,
    ref_counts: Vec<u32>,
    /// High-water mark of simultaneously allocated blocks.
    peak_used: usize,
    /// Test-only fault hook (`runtime::fault`): while set, the
    /// *admission-visible* probes (`num_free`, `can_alloc`) report an
    /// exhausted pool, so the scheduler stops admitting new work.
    /// `alloc` itself is untouched — already-scheduled sequences keep
    /// their blocks and progress, per the overload contract (shedding
    /// never perturbs scheduled work). Compiled out of release builds.
    #[cfg(any(test, feature = "fault-inject"))]
    fault_exhausted: bool,
}

impl BlockAllocator {
    /// Create a pool of `num_blocks` blocks of `block_size` token slots.
    pub fn new(num_blocks: usize, block_size: usize) -> Self {
        assert!(block_size > 0, "block_size must be positive");
        BlockAllocator {
            num_blocks,
            block_size,
            // LIFO free list; reversed so block 0 allocates first (handy in tests).
            free: (0..num_blocks as BlockId).rev().collect(),
            ref_counts: vec![0; num_blocks],
            peak_used: 0,
            #[cfg(any(test, feature = "fault-inject"))]
            fault_exhausted: false,
        }
    }

    /// Arm/disarm the admission-visible exhaustion fault (see the
    /// `fault_exhausted` field; driven by `Engine::arm_faults`).
    #[cfg(any(test, feature = "fault-inject"))]
    pub fn set_fault_exhausted(&mut self, on: bool) {
        self.fault_exhausted = on;
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn num_blocks(&self) -> usize {
        self.num_blocks
    }

    pub fn num_free(&self) -> usize {
        #[cfg(any(test, feature = "fault-inject"))]
        if self.fault_exhausted {
            return 0;
        }
        self.free.len()
    }

    pub fn num_used(&self) -> usize {
        self.num_blocks - self.free.len()
    }

    pub fn peak_used(&self) -> usize {
        self.peak_used
    }

    /// Allocate one block (refcount 1). `None` when the pool is exhausted.
    pub fn alloc(&mut self) -> Option<BlockId> {
        let id = self.free.pop()?;
        debug_assert_eq!(self.ref_counts[id as usize], 0);
        self.ref_counts[id as usize] = 1;
        self.peak_used = self.peak_used.max(self.num_used());
        Some(id)
    }

    /// Can `n` more blocks be allocated right now?
    pub fn can_alloc(&self, n: usize) -> bool {
        #[cfg(any(test, feature = "fault-inject"))]
        if self.fault_exhausted {
            return n == 0;
        }
        self.free.len() >= n
    }

    /// Increment a block's refcount (prefix sharing / COW fork).
    pub fn share(&mut self, id: BlockId) {
        let rc = &mut self.ref_counts[id as usize];
        assert!(*rc > 0, "share of unallocated block {id}");
        *rc += 1;
    }

    /// Refcount of a block (0 = free).
    pub fn ref_count(&self, id: BlockId) -> u32 {
        self.ref_counts[id as usize]
    }

    /// Drop one reference; the block returns to the free list when the
    /// count reaches zero. Returns `true` if the block was actually freed.
    pub fn release(&mut self, id: BlockId) -> bool {
        let rc = &mut self.ref_counts[id as usize];
        assert!(*rc > 0, "release of unallocated block {id}");
        *rc -= 1;
        if *rc == 0 {
            self.free.push(id);
            true
        } else {
            false
        }
    }

    /// Fraction of the pool currently allocated.
    pub fn utilization(&self) -> f64 {
        if self.num_blocks == 0 {
            return 0.0;
        }
        self.num_used() as f64 / self.num_blocks as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_cycle() {
        let mut a = BlockAllocator::new(4, 16);
        assert_eq!(a.num_free(), 4);
        let b0 = a.alloc().unwrap();
        let b1 = a.alloc().unwrap();
        assert_ne!(b0, b1);
        assert_eq!(a.num_used(), 2);
        assert!(a.release(b0));
        assert_eq!(a.num_free(), 3);
        assert!(a.release(b1));
        assert_eq!(a.num_free(), 4);
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut a = BlockAllocator::new(2, 8);
        assert!(a.alloc().is_some());
        assert!(a.alloc().is_some());
        assert!(a.alloc().is_none());
        assert!(!a.can_alloc(1));
    }

    #[test]
    fn refcounted_sharing() {
        let mut a = BlockAllocator::new(2, 8);
        let b = a.alloc().unwrap();
        a.share(b);
        assert_eq!(a.ref_count(b), 2);
        assert!(!a.release(b)); // still referenced
        assert_eq!(a.num_used(), 1);
        assert!(a.release(b)); // now freed
        assert_eq!(a.num_free(), 2);
    }

    #[test]
    #[should_panic(expected = "release of unallocated")]
    fn double_free_panics() {
        let mut a = BlockAllocator::new(1, 8);
        let b = a.alloc().unwrap();
        a.release(b);
        a.release(b);
    }

    #[test]
    fn peak_tracking() {
        let mut a = BlockAllocator::new(4, 8);
        let b0 = a.alloc().unwrap();
        let b1 = a.alloc().unwrap();
        a.release(b0);
        a.release(b1);
        assert_eq!(a.peak_used(), 2);
        assert_eq!(a.num_used(), 0);
    }

    #[test]
    fn fault_exhaustion_gates_probes_not_alloc() {
        let mut a = BlockAllocator::new(4, 8);
        a.set_fault_exhausted(true);
        // Admission-visible probes report an empty pool…
        assert_eq!(a.num_free(), 0);
        assert!(!a.can_alloc(1));
        assert!(a.can_alloc(0));
        // …but actual allocation (already-scheduled work) still works.
        let b = a.alloc().expect("alloc is never fault-gated");
        assert_eq!(a.num_used(), 1);
        a.set_fault_exhausted(false);
        assert_eq!(a.num_free(), 3);
        a.release(b);
        assert_eq!(a.num_free(), 4);
    }

    #[test]
    fn utilization_fraction() {
        let mut a = BlockAllocator::new(4, 8);
        assert_eq!(a.utilization(), 0.0);
        let _ = a.alloc().unwrap();
        assert!((a.utilization() - 0.25).abs() < 1e-12);
    }
}
