//! Cache utilization / fragmentation accounting for the paging ablation.

use super::block_allocator::BlockAllocator;
use super::block_table::BlockTable;

/// Point-in-time cache statistics.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CacheStats {
    pub total_blocks: usize,
    pub used_blocks: usize,
    pub peak_used_blocks: usize,
    /// Token slots occupied across all live tables.
    pub used_slots: usize,
    /// Token slots allocated (used_blocks × block_size).
    pub allocated_slots: usize,
    /// Internal fragmentation: allocated-but-unused slots / allocated.
    pub internal_frag: f64,
    /// Pool utilization: used blocks / total blocks.
    pub utilization: f64,
    /// True bytes held by the physical pool (`KvStore::pool_bytes`) —
    /// packed payload + quantization grids for a Q8 cache, dense f32
    /// bytes otherwise. Zero when collected without a pool (allocator +
    /// tables only).
    pub pool_bytes: usize,
    /// Dense f32 bytes the pool has materialized through
    /// `KvStore::gather` — ≈ 0 in a healthy engine, since the
    /// paged-native prefill refactor left `gather` as a test/debug dump
    /// only. A growing value here means something reintroduced a dense
    /// KV copy on the hot path. Zero when collected without a pool.
    pub gather_bytes: usize,
}

impl CacheStats {
    /// Gather stats from the allocator and the live block tables.
    pub fn collect<'a>(
        alloc: &BlockAllocator,
        tables: impl Iterator<Item = &'a BlockTable>,
    ) -> CacheStats {
        let mut used_slots = 0usize;
        let mut table_blocks = 0usize;
        for t in tables {
            used_slots += t.len();
            // Tombstoned (window-evicted) entries hold no pool block.
            table_blocks += t.live_blocks();
        }
        let allocated_slots = table_blocks * alloc.block_size();
        // On a windowed table `len` counts evicted logical positions too,
        // so it can exceed the live allocation — clamp: fragmentation is
        // a measure of unused *allocated* slots, never negative.
        let internal_frag = if allocated_slots == 0 {
            0.0
        } else {
            allocated_slots.saturating_sub(used_slots) as f64 / allocated_slots as f64
        };
        CacheStats {
            total_blocks: alloc.num_blocks(),
            used_blocks: alloc.num_used(),
            peak_used_blocks: alloc.peak_used(),
            used_slots,
            allocated_slots,
            internal_frag,
            utilization: alloc.utilization(),
            pool_bytes: 0,
            gather_bytes: 0,
        }
    }

    /// Attach the physical pool's byte count (builder-style; the engine
    /// calls this with its [`super::KvStore`]'s `pool_bytes()`).
    pub fn with_pool_bytes(mut self, bytes: usize) -> CacheStats {
        self.pool_bytes = bytes;
        self
    }

    /// Attach the pool's dense-gather byte counter (builder-style; the
    /// engine calls this with its [`super::KvStore`]'s `gather_bytes()`).
    pub fn with_gather_bytes(mut self, bytes: usize) -> CacheStats {
        self.gather_bytes = bytes;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collect_over_tables() {
        let mut alloc = BlockAllocator::new(8, 4);
        let mut t1 = BlockTable::new();
        let mut t2 = BlockTable::new();
        t1.reserve(5, &mut alloc); // 2 blocks
        for _ in 0..5 {
            t1.append_slot(4);
        }
        t2.reserve(3, &mut alloc); // 1 block
        for _ in 0..3 {
            t2.append_slot(4);
        }
        let stats = CacheStats::collect(&alloc, [&t1, &t2].into_iter());
        assert_eq!(stats.total_blocks, 8);
        assert_eq!(stats.used_blocks, 3);
        assert_eq!(stats.used_slots, 8);
        assert_eq!(stats.allocated_slots, 12);
        assert!((stats.internal_frag - 4.0 / 12.0).abs() < 1e-12);
        assert!((stats.utilization - 3.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn empty_stats() {
        let alloc = BlockAllocator::new(4, 4);
        let stats = CacheStats::collect(&alloc, std::iter::empty());
        assert_eq!(stats.internal_frag, 0.0);
        assert_eq!(stats.used_slots, 0);
        assert_eq!(stats.pool_bytes, 0, "no pool attached");
    }

    #[test]
    fn pool_bytes_reports_true_packed_bytes() {
        use crate::kvcache::{KvStore, PagedKvCache, QuantizedPagedKvCache};
        let (layers, blocks, bs, kvh, d) = (2usize, 8usize, 16usize, 2usize, 64usize);
        let alloc = BlockAllocator::new(blocks, bs);
        let f32_cache = PagedKvCache::new(layers, blocks, bs, kvh, d);
        let q8_cache = QuantizedPagedKvCache::new(layers, blocks, bs, kvh, d);

        let sf = CacheStats::collect(&alloc, std::iter::empty())
            .with_pool_bytes(KvStore::pool_bytes(&f32_cache));
        let sq = CacheStats::collect(&alloc, std::iter::empty())
            .with_pool_bytes(KvStore::pool_bytes(&q8_cache));
        // f32: 2 sides × layers × blocks × slots × kvh × d × 4 bytes.
        assert_eq!(sf.pool_bytes, 2 * layers * blocks * bs * kvh * d * 4);
        // q8: 1 payload byte per value + 20 grid/range/frontier bytes
        // per (block, kv_head, side) per layer (scale, zero, lo, hi,
        // fill frontier).
        let payload = 2 * layers * blocks * bs * kvh * d;
        let grids = 2 * layers * blocks * kvh * 20;
        assert_eq!(sq.pool_bytes, payload + grids);
        // The packed pool must be ≤ 0.3× the dense pool at this shape.
        assert!(10 * sq.pool_bytes <= 3 * sf.pool_bytes, "{} vs {}", sq.pool_bytes, sf.pool_bytes);
    }

    #[test]
    fn gather_bytes_attaches_and_defaults_to_zero() {
        use crate::kvcache::{KvStore, PagedKvCache};
        let alloc = BlockAllocator::new(4, 4);
        let cache = PagedKvCache::new(1, 4, 4, 1, 4);
        let s = CacheStats::collect(&alloc, std::iter::empty());
        assert_eq!(s.gather_bytes, 0, "no pool attached");
        let s = s.with_gather_bytes(KvStore::gather_bytes(&cache));
        assert_eq!(s.gather_bytes, 0, "fresh pool has gathered nothing");
        let mut t = BlockTable::new();
        let mut a2 = BlockAllocator::new(4, 4);
        t.reserve(3, &mut a2);
        for _ in 0..3 {
            t.append_slot(4);
        }
        let _ = KvStore::gather(&cache, 0, &t);
        let s = CacheStats::collect(&a2, [&t].into_iter())
            .with_gather_bytes(KvStore::gather_bytes(&cache));
        assert_eq!(s.gather_bytes, 2 * 3 * 4 * 4, "metered dump");
    }
}
