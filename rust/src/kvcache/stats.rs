//! Cache utilization / fragmentation accounting for the paging ablation.

use super::block_allocator::BlockAllocator;
use super::block_table::BlockTable;

/// Point-in-time cache statistics.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CacheStats {
    pub total_blocks: usize,
    pub used_blocks: usize,
    pub peak_used_blocks: usize,
    /// Token slots occupied across all live tables.
    pub used_slots: usize,
    /// Token slots allocated (used_blocks × block_size).
    pub allocated_slots: usize,
    /// Internal fragmentation: allocated-but-unused slots / allocated.
    pub internal_frag: f64,
    /// Pool utilization: used blocks / total blocks.
    pub utilization: f64,
}

impl CacheStats {
    /// Gather stats from the allocator and the live block tables.
    pub fn collect<'a>(
        alloc: &BlockAllocator,
        tables: impl Iterator<Item = &'a BlockTable>,
    ) -> CacheStats {
        let mut used_slots = 0usize;
        let mut table_blocks = 0usize;
        for t in tables {
            used_slots += t.len();
            table_blocks += t.blocks().len();
        }
        let allocated_slots = table_blocks * alloc.block_size();
        let internal_frag = if allocated_slots == 0 {
            0.0
        } else {
            (allocated_slots - used_slots) as f64 / allocated_slots as f64
        };
        CacheStats {
            total_blocks: alloc.num_blocks(),
            used_blocks: alloc.num_used(),
            peak_used_blocks: alloc.peak_used(),
            used_slots,
            allocated_slots,
            internal_frag,
            utilization: alloc.utilization(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collect_over_tables() {
        let mut alloc = BlockAllocator::new(8, 4);
        let mut t1 = BlockTable::new();
        let mut t2 = BlockTable::new();
        t1.reserve(5, &mut alloc); // 2 blocks
        for _ in 0..5 {
            t1.append_slot(4);
        }
        t2.reserve(3, &mut alloc); // 1 block
        for _ in 0..3 {
            t2.append_slot(4);
        }
        let stats = CacheStats::collect(&alloc, [&t1, &t2].into_iter());
        assert_eq!(stats.total_blocks, 8);
        assert_eq!(stats.used_blocks, 3);
        assert_eq!(stats.used_slots, 8);
        assert_eq!(stats.allocated_slots, 12);
        assert!((stats.internal_frag - 4.0 / 12.0).abs() < 1e-12);
        assert!((stats.utilization - 3.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn empty_stats() {
        let alloc = BlockAllocator::new(4, 4);
        let stats = CacheStats::collect(&alloc, std::iter::empty());
        assert_eq!(stats.internal_frag, 0.0);
        assert_eq!(stats.used_slots, 0);
    }
}
