//! The [`KvStore`] abstraction: one protocol, two storage dtypes.
//!
//! Everything above the cache — the attention drivers, the native model,
//! the backends, the engine — talks to KV storage through this trait, so
//! the dense f32 pool ([`PagedKvCache`]) and the packed 8-bit pool
//! ([`QuantizedPagedKvCache`]) are interchangeable at runtime. Engines
//! pick the implementation with [`KvCacheDtype`]
//! (`EngineConfig::kv_dtype`); the attention kernel dispatches per block
//! on [`KvBlockView`], dequantizing quantized tiles into workspace
//! scratch so both dtypes share the exact group-major online-softmax
//! schedule.
//!
//! The trait is object-safe on purpose: [`crate::runtime::Backend`] is a
//! trait object, so its methods must take `&mut dyn KvStore` rather than
//! a generic parameter. `Send + Sync` supertraits let
//! `paged_decode_batch` fan a `&dyn KvStore` across scoped worker
//! threads.

use super::block_allocator::BlockId;
use super::block_table::BlockTable;
use super::paged::PagedKvCache;
use super::quantized::{QuantKvTile, QuantizedPagedKvCache};

/// Storage dtype of the paged KV pool (the engine-config knob).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KvCacheDtype {
    /// Dense f32 pools — 4 bytes per value.
    #[default]
    F32,
    /// Packed 8-bit pools with per-(block, kv_head) grids — ~0.26× the
    /// f32 bytes; requires a backend that reads quantized tiles
    /// (`Backend::supports_quantized_kv`).
    Q8,
}

impl KvCacheDtype {
    /// Parse a CLI/config name (`"f32"` | `"q8"`).
    pub fn parse(name: &str) -> Option<KvCacheDtype> {
        match name {
            "f32" => Some(KvCacheDtype::F32),
            "q8" => Some(KvCacheDtype::Q8),
            _ => None,
        }
    }
}

/// Borrowed view of one physical block, in whichever representation the
/// store holds it. Cache blocks are exactly the attention kernel's KV
/// tiles, so this is the unit the decode path consumes.
pub enum KvBlockView<'a> {
    /// Dense rows, `[block_size, kv_heads, head_dim]` flat (K and V).
    F32 { k: &'a [f32], v: &'a [f32] },
    /// Packed 8-bit rows plus per-kv-head grids (K and V).
    Q8 { k: QuantKvTile<'a>, v: QuantKvTile<'a> },
}

/// Paged KV storage behind block tables — the physical pool interface.
///
/// Implementations share the f32 pool's write/read protocol: callers map
/// logical token positions to `(block, slot)` through a [`BlockTable`]
/// and never see the storage representation except through
/// [`KvBlockView`].
pub trait KvStore: Send + Sync + std::fmt::Debug {
    fn num_layers(&self) -> usize;
    fn num_blocks(&self) -> usize;
    fn block_size(&self) -> usize;
    fn kv_heads(&self) -> usize;
    fn head_dim(&self) -> usize;

    /// Storage dtype (mirrors the engine's [`KvCacheDtype`] choice).
    fn dtype(&self) -> KvCacheDtype;

    /// True bytes held by the pools (packed payload + quantization grids
    /// for Q8) — the number `CacheStats::pool_bytes` reports.
    fn pool_bytes(&self) -> usize;

    /// Write one token's K and V vectors (all kv heads,
    /// `kv_heads * head_dim` values each) into a physical slot,
    /// quantizing on append if the store is packed.
    ///
    /// **Protocol:** blocks are filled front-to-back (the
    /// [`BlockTable`] append order). A write to **slot 0** may
    /// reinitialize the whole block — the packed store resets its
    /// quantization grids there, treating slot 0 as the start of a new
    /// tenancy — so callers must not overwrite slot 0 of a block whose
    /// later slots still hold live data.
    fn write_token(&mut self, layer: usize, block: BlockId, slot: usize, k: &[f32], v: &[f32]);

    /// Copy a block's contents across all layers (COW split support).
    fn copy_block(&mut self, src: BlockId, dst: BlockId);

    /// Byte length of one [`KvStore::export_block`] payload (constant
    /// for a given pool geometry — the spill tier's shape fingerprint
    /// feeds on it).
    fn block_export_bytes(&self) -> usize;

    /// Serialize one block's complete state (payload + per-block
    /// metadata, all layers) as exact bytes, such that
    /// [`KvStore::import_block`] reproduces the block bit-for-bit in
    /// this or any identically-shaped store. This is the spill tier's
    /// record payload: because the bytes are exact (packed q8 levels
    /// move as levels, f32 moves as f32 — no requantization round
    /// trip), a block restored from disk is indistinguishable from one
    /// that never left the pool, and every parity contract survives
    /// eviction + restore.
    fn export_block(&self, block: BlockId) -> Vec<u8>;

    /// Overwrite `block` from an [`KvStore::export_block`] payload.
    /// Returns `false` (block untouched) on a length mismatch.
    fn import_block(&mut self, block: BlockId, bytes: &[u8]) -> bool;

    /// One block's K and V in the store's native representation.
    fn block_view(&self, layer: usize, block: BlockId) -> KvBlockView<'_>;

    /// Conservative elementwise bounds `(lo, hi)` on every K value this
    /// block's view can produce for one KV head — the per-tile metadata
    /// behind score-bound tile skipping
    /// (`attention::kernel::Workspace::tile_skippable`).
    ///
    /// The contract is *soundness*, not tightness: every element of
    /// every K row that [`KvStore::block_view`] would expose for
    /// `(layer, block, kv_head)` must lie in `[lo, hi]`. Returning
    /// `(−∞, +∞)` is always correct and simply disables skipping for the
    /// tile, which is why it is the trait default. Both in-tree stores
    /// override it: the dense pool keeps running per-(block, kv_head)
    /// ranges, the packed pool derives the bound from its quantization
    /// grid (every decodable level lies on the grid).
    fn key_tile_bounds(&self, layer: usize, block: BlockId, kv_head: usize) -> (f32, f32) {
        let _ = (layer, block, kv_head);
        (f32::NEG_INFINITY, f32::INFINITY)
    }

    /// Gather a sequence's K and V into contiguous dense
    /// `[len, kv_heads*head_dim]` buffers (dequantized if packed).
    ///
    /// **Test/debug dump only.** Since the paged-native prefill
    /// refactor nothing on the serving path materializes a dense copy:
    /// prefill and decode both stream tiles through
    /// [`KvStore::block_view`]. Every call is counted by
    /// [`KvStore::gather_bytes`], so a hot-path regression shows up in
    /// `CacheStats::gather_bytes` (asserted ≈ 0 by the engine tests).
    fn gather(&self, layer: usize, table: &BlockTable) -> (Vec<f32>, Vec<f32>);

    /// Total dense f32 bytes materialized through [`KvStore::gather`]
    /// since construction — the `CacheStats::gather_bytes` feed.
    fn gather_bytes(&self) -> usize;

    /// Downcast to the dense f32 pool, if that is what this store is.
    /// The XLA backend needs raw f32 pools to upload as device buffers.
    fn dense_f32(&self) -> Option<&PagedKvCache> {
        None
    }

    /// Mutable form of [`KvStore::dense_f32`].
    fn dense_f32_mut(&mut self) -> Option<&mut PagedKvCache> {
        None
    }
}

impl KvStore for PagedKvCache {
    fn num_layers(&self) -> usize {
        PagedKvCache::num_layers(self)
    }
    fn num_blocks(&self) -> usize {
        PagedKvCache::num_blocks(self)
    }
    fn block_size(&self) -> usize {
        PagedKvCache::block_size(self)
    }
    fn kv_heads(&self) -> usize {
        PagedKvCache::kv_heads(self)
    }
    fn head_dim(&self) -> usize {
        PagedKvCache::head_dim(self)
    }
    fn dtype(&self) -> KvCacheDtype {
        KvCacheDtype::F32
    }
    fn pool_bytes(&self) -> usize {
        PagedKvCache::pool_bytes(self)
    }
    fn write_token(&mut self, layer: usize, block: BlockId, slot: usize, k: &[f32], v: &[f32]) {
        PagedKvCache::write_token(self, layer, block, slot, k, v)
    }
    fn copy_block(&mut self, src: BlockId, dst: BlockId) {
        PagedKvCache::copy_block(self, src, dst)
    }
    fn block_export_bytes(&self) -> usize {
        PagedKvCache::block_export_bytes(self)
    }
    fn export_block(&self, block: BlockId) -> Vec<u8> {
        PagedKvCache::export_block(self, block)
    }
    fn import_block(&mut self, block: BlockId, bytes: &[u8]) -> bool {
        PagedKvCache::import_block(self, block, bytes)
    }
    fn block_view(&self, layer: usize, block: BlockId) -> KvBlockView<'_> {
        KvBlockView::F32 { k: self.key_block(layer, block), v: self.value_block(layer, block) }
    }
    fn key_tile_bounds(&self, layer: usize, block: BlockId, kv_head: usize) -> (f32, f32) {
        PagedKvCache::key_tile_bounds(self, layer, block, kv_head)
    }
    fn gather(&self, layer: usize, table: &BlockTable) -> (Vec<f32>, Vec<f32>) {
        PagedKvCache::gather(self, layer, table)
    }
    fn gather_bytes(&self) -> usize {
        PagedKvCache::gather_bytes(self)
    }
    fn dense_f32(&self) -> Option<&PagedKvCache> {
        Some(self)
    }
    fn dense_f32_mut(&mut self) -> Option<&mut PagedKvCache> {
        Some(self)
    }
}

impl KvStore for QuantizedPagedKvCache {
    fn num_layers(&self) -> usize {
        QuantizedPagedKvCache::num_layers(self)
    }
    fn num_blocks(&self) -> usize {
        QuantizedPagedKvCache::num_blocks(self)
    }
    fn block_size(&self) -> usize {
        QuantizedPagedKvCache::block_size(self)
    }
    fn kv_heads(&self) -> usize {
        QuantizedPagedKvCache::kv_heads(self)
    }
    fn head_dim(&self) -> usize {
        QuantizedPagedKvCache::head_dim(self)
    }
    fn dtype(&self) -> KvCacheDtype {
        KvCacheDtype::Q8
    }
    fn pool_bytes(&self) -> usize {
        QuantizedPagedKvCache::pool_bytes(self)
    }
    fn write_token(&mut self, layer: usize, block: BlockId, slot: usize, k: &[f32], v: &[f32]) {
        QuantizedPagedKvCache::write_token(self, layer, block, slot, k, v)
    }
    fn copy_block(&mut self, src: BlockId, dst: BlockId) {
        QuantizedPagedKvCache::copy_block(self, src, dst)
    }
    fn block_export_bytes(&self) -> usize {
        QuantizedPagedKvCache::block_export_bytes(self)
    }
    fn export_block(&self, block: BlockId) -> Vec<u8> {
        QuantizedPagedKvCache::export_block(self, block)
    }
    fn import_block(&mut self, block: BlockId, bytes: &[u8]) -> bool {
        QuantizedPagedKvCache::import_block(self, block, bytes)
    }
    fn block_view(&self, layer: usize, block: BlockId) -> KvBlockView<'_> {
        let (k, v) = self.block_tiles(layer, block);
        KvBlockView::Q8 { k, v }
    }
    fn key_tile_bounds(&self, layer: usize, block: BlockId, kv_head: usize) -> (f32, f32) {
        QuantizedPagedKvCache::key_tile_bounds(self, layer, block, kv_head)
    }
    fn gather(&self, layer: usize, table: &BlockTable) -> (Vec<f32>, Vec<f32>) {
        QuantizedPagedKvCache::gather(self, layer, table)
    }
    fn gather_bytes(&self) -> usize {
        QuantizedPagedKvCache::gather_bytes(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_parse_and_downcast() {
        assert_eq!(KvCacheDtype::parse("f32"), Some(KvCacheDtype::F32));
        assert_eq!(KvCacheDtype::parse("q8"), Some(KvCacheDtype::Q8));
        assert_eq!(KvCacheDtype::parse("int3"), None);

        let mut f: Box<dyn KvStore> = Box::new(PagedKvCache::new(1, 2, 4, 1, 4));
        assert_eq!(f.dtype(), KvCacheDtype::F32);
        assert!(f.dense_f32().is_some());
        assert!(f.dense_f32_mut().is_some());

        let mut q: Box<dyn KvStore> = Box::new(QuantizedPagedKvCache::new(1, 2, 4, 1, 4));
        assert_eq!(q.dtype(), KvCacheDtype::Q8);
        assert!(q.dense_f32().is_none());
        assert!(q.dense_f32_mut().is_none());
        assert!(q.pool_bytes() < f.pool_bytes());
    }

    #[test]
    fn both_stores_roundtrip_through_the_trait() {
        use crate::kvcache::BlockAllocator;
        for dtype in [KvCacheDtype::F32, KvCacheDtype::Q8] {
            let mut cache: Box<dyn KvStore> = match dtype {
                KvCacheDtype::F32 => Box::new(PagedKvCache::new(1, 4, 4, 2, 4)),
                KvCacheDtype::Q8 => Box::new(QuantizedPagedKvCache::new(1, 4, 4, 2, 4)),
            };
            let mut alloc = BlockAllocator::new(4, 4);
            let mut table = BlockTable::new();
            assert!(table.reserve(6, &mut alloc));
            for t in 0..6 {
                let (b, s) = table.append_slot(4);
                let x = t as f32 / 8.0;
                cache.write_token(0, b, s, &[x; 8], &[-x; 8]);
            }
            assert_eq!(cache.gather_bytes(), 0, "{dtype:?}: no gather yet");
            let (ks, vs) = cache.gather(0, &table);
            assert_eq!(ks.len(), 6 * 8);
            for t in 0..6 {
                let x = t as f32 / 8.0;
                assert!((ks[t * 8] - x).abs() < 0.01, "{dtype:?} k t={t}");
                assert!((vs[t * 8] + x).abs() < 0.01, "{dtype:?} v t={t}");
            }
            // The debug dump is metered: 6 tokens × 8 values × 4 bytes,
            // both sides.
            assert_eq!(cache.gather_bytes(), 2 * 6 * 8 * 4, "{dtype:?}: gather_bytes");
        }
    }
}
