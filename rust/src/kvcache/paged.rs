//! Paged KV storage: the physical pool the block tables index into.
//!
//! Layout per layer (both K and V): `[num_blocks, block_size, kv_heads,
//! head_dim]`, row-major — exactly the layout the Pallas paged-attention
//! kernel (python/compile/kernels/paged_attention.py) consumes, so the
//! same block tables drive both the native and the XLA backends.

use super::block_allocator::BlockId;
use super::block_table::BlockTable;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Paged K/V storage for every layer of one model.
///
/// # Example
///
/// Writing a short sequence through a block table and reading it back:
///
/// ```
/// use opt_gptq::kvcache::{BlockAllocator, BlockTable, PagedKvCache};
///
/// // 1 layer; 4 blocks × 4 slots; 2 KV heads of head_dim 3.
/// let mut cache = PagedKvCache::new(1, 4, 4, 2, 3);
/// let mut alloc = BlockAllocator::new(4, 4);
/// let mut table = BlockTable::new();
/// assert!(table.reserve(5, &mut alloc)); // claims 2 blocks
/// for t in 0..5u32 {
///     let (block, slot) = table.append_slot(4);
///     cache.write_token(0, block, slot, &[t as f32; 6], &[0.5; 6]);
/// }
/// let (block, slot) = table.locate(4, 4); // logical position 4
/// assert_eq!(cache.key_token(0, block, slot)[0], 4.0);
/// assert_eq!(cache.value_token(0, block, slot)[5], 0.5);
/// ```
#[derive(Debug)]
pub struct PagedKvCache {
    num_layers: usize,
    num_blocks: usize,
    block_size: usize,
    kv_heads: usize,
    head_dim: usize,
    /// `keys[layer]` is the flat `[num_blocks, block_size, kv_heads, head_dim]` pool.
    keys: Vec<Vec<f32>>,
    values: Vec<Vec<f32>>,
    /// Running per-(block, kv_head) K ranges, `[num_blocks * kv_heads]`
    /// per layer — the `KvStore::key_tile_bounds` metadata feeding
    /// score-bound tile skipping. Initialized to `(0.0, 0.0)`, which
    /// exactly covers the zeroed pool; a slot-0 write resets the group
    /// (new tenancy, same protocol as the packed store's grids), later
    /// writes only widen. NaN keys poison the group to `(−∞, +∞)` so the
    /// skip test refuses and the kernel's NaN semantics are preserved.
    /// Deliberately excluded from [`PagedKvCache::pool_bytes`]: that
    /// figure is the *pool* (the paper's capacity story), and the range
    /// state is O(blocks · kv_heads) bookkeeping, not payload.
    k_lo: Vec<Vec<f32>>,
    k_hi: Vec<Vec<f32>>,
    /// Bytes materialized by [`PagedKvCache::gather`] since construction
    /// — the `CacheStats::gather_bytes` observability feed. Stays 0 on
    /// the serving hot path now that attention streams blocks in place.
    gathered: AtomicUsize,
}

impl PagedKvCache {
    pub fn new(
        num_layers: usize,
        num_blocks: usize,
        block_size: usize,
        kv_heads: usize,
        head_dim: usize,
    ) -> Self {
        let pool = num_blocks * block_size * kv_heads * head_dim;
        PagedKvCache {
            num_layers,
            num_blocks,
            block_size,
            kv_heads,
            head_dim,
            keys: (0..num_layers).map(|_| vec![0.0; pool]).collect(),
            values: (0..num_layers).map(|_| vec![0.0; pool]).collect(),
            k_lo: (0..num_layers).map(|_| vec![0.0; num_blocks * kv_heads]).collect(),
            k_hi: (0..num_layers).map(|_| vec![0.0; num_blocks * kv_heads]).collect(),
            gathered: AtomicUsize::new(0),
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }
    pub fn kv_heads(&self) -> usize {
        self.kv_heads
    }
    pub fn head_dim(&self) -> usize {
        self.head_dim
    }
    pub fn num_blocks(&self) -> usize {
        self.num_blocks
    }
    pub fn num_layers(&self) -> usize {
        self.num_layers
    }

    /// Bytes held by the pools (both K and V, all layers).
    pub fn pool_bytes(&self) -> usize {
        2 * self.num_layers * self.num_blocks * self.block_size * self.kv_heads * self.head_dim * 4
    }

    #[inline]
    fn offset(&self, block: BlockId, slot: usize) -> usize {
        debug_assert!((block as usize) < self.num_blocks);
        debug_assert!(slot < self.block_size);
        (block as usize * self.block_size + slot) * self.kv_heads * self.head_dim
    }

    /// Write one token's K and V vectors (all kv heads, `kv_heads*head_dim`
    /// values each) into a physical slot.
    pub fn write_token(&mut self, layer: usize, block: BlockId, slot: usize, k: &[f32], v: &[f32]) {
        let d = self.kv_heads * self.head_dim;
        assert_eq!(k.len(), d, "key vector length");
        assert_eq!(v.len(), d, "value vector length");
        let off = self.offset(block, slot);
        self.keys[layer][off..off + d].copy_from_slice(k);
        self.values[layer][off..off + d].copy_from_slice(v);
        // Maintain the per-(block, kv_head) K range metadata. Slot 0
        // starts a tenancy: the group is re-seeded from this token alone
        // (blocks fill front-to-back, so no earlier live data exists).
        let hd = self.head_dim;
        let base = block as usize * self.kv_heads;
        for head in 0..self.kv_heads {
            let gi = base + head;
            let (mut lo, mut hi) = if slot == 0 {
                (f32::INFINITY, f32::NEG_INFINITY)
            } else {
                (self.k_lo[layer][gi], self.k_hi[layer][gi])
            };
            let mut poisoned = false;
            for &x in &k[head * hd..(head + 1) * hd] {
                poisoned |= x.is_nan();
                lo = lo.min(x);
                hi = hi.max(x);
            }
            if poisoned {
                // min/max ignore NaN; widen to the always-sound bound so
                // the skip test refuses and the NaN reaches the kernel.
                lo = f32::NEG_INFINITY;
                hi = f32::INFINITY;
            }
            self.k_lo[layer][gi] = lo;
            self.k_hi[layer][gi] = hi;
        }
    }

    /// Elementwise bounds on every K value stored in `(block, kv_head)`
    /// this tenancy — the [`super::KvStore::key_tile_bounds`] metadata.
    /// Sound for any read the attention walk performs: reads never pass
    /// the block's fill point, and every written value was folded in.
    pub fn key_tile_bounds(&self, layer: usize, block: BlockId, kv_head: usize) -> (f32, f32) {
        let gi = block as usize * self.kv_heads + kv_head;
        (self.k_lo[layer][gi], self.k_hi[layer][gi])
    }

    /// Read one token's K vector (all kv heads).
    pub fn key_token(&self, layer: usize, block: BlockId, slot: usize) -> &[f32] {
        let d = self.kv_heads * self.head_dim;
        let off = self.offset(block, slot);
        &self.keys[layer][off..off + d]
    }

    /// Read one token's V vector (all kv heads).
    pub fn value_token(&self, layer: usize, block: BlockId, slot: usize) -> &[f32] {
        let d = self.kv_heads * self.head_dim;
        let off = self.offset(block, slot);
        &self.values[layer][off..off + d]
    }

    /// One whole block of keys: `[block_size, kv_heads, head_dim]` flat.
    pub fn key_block(&self, layer: usize, block: BlockId) -> &[f32] {
        let d = self.block_size * self.kv_heads * self.head_dim;
        let off = block as usize * d;
        &self.keys[layer][off..off + d]
    }

    /// One whole block of values.
    pub fn value_block(&self, layer: usize, block: BlockId) -> &[f32] {
        let d = self.block_size * self.kv_heads * self.head_dim;
        let off = block as usize * d;
        &self.values[layer][off..off + d]
    }

    /// Copy a block's contents (all layers) — used after a COW split.
    pub fn copy_block(&mut self, src: BlockId, dst: BlockId) {
        let d = self.block_size * self.kv_heads * self.head_dim;
        let (s, t) = (src as usize * d, dst as usize * d);
        let (gs, gt) = (src as usize * self.kv_heads, dst as usize * self.kv_heads);
        let kvh = self.kv_heads;
        for layer in 0..self.num_layers {
            let (keys, values) = (&mut self.keys[layer], &mut self.values[layer]);
            keys.copy_within(s..s + d, t);
            values.copy_within(s..s + d, t);
            self.k_lo[layer].copy_within(gs..gs + kvh, gt);
            self.k_hi[layer].copy_within(gs..gs + kvh, gt);
        }
    }

    /// Gather a sequence's K and V into contiguous `[len, kv_heads*head_dim]`
    /// buffers — a **test/debug dump** since the paged-native prefill
    /// refactor (attention streams blocks in place; nothing on the
    /// serving path calls this). Counted by
    /// [`PagedKvCache::gather_bytes`] so regressions are measurable.
    pub fn gather(&self, layer: usize, table: &BlockTable) -> (Vec<f32>, Vec<f32>) {
        let d = self.kv_heads * self.head_dim;
        self.gathered.fetch_add(2 * table.len() * d * 4, Ordering::Relaxed);
        let mut ks = Vec::with_capacity(table.len() * d);
        let mut vs = Vec::with_capacity(table.len() * d);
        for pos in 0..table.len() {
            let (b, s) = table.locate(pos, self.block_size);
            ks.extend_from_slice(self.key_token(layer, b, s));
            vs.extend_from_slice(self.value_token(layer, b, s));
        }
        (ks, vs)
    }

    /// Total f32 bytes materialized through [`PagedKvCache::gather`].
    pub fn gather_bytes(&self) -> usize {
        self.gathered.load(Ordering::Relaxed)
    }

    /// Raw per-layer pools (the XLA backend feeds these to the HLO as
    /// runtime arguments).
    pub fn raw_keys(&self, layer: usize) -> &[f32] {
        &self.keys[layer]
    }
    pub fn raw_values(&self, layer: usize) -> &[f32] {
        &self.values[layer]
    }

    /// Byte length of one [`PagedKvCache::export_block`] payload.
    pub fn block_export_bytes(&self) -> usize {
        let d = self.block_size * self.kv_heads * self.head_dim;
        self.num_layers * (2 * d + 2 * self.kv_heads) * 4
    }

    /// Serialize one block's complete state — K and V payload plus the
    /// per-(block, kv_head) K-range metadata, every layer — as exact
    /// little-endian f32 bytes. [`PagedKvCache::import_block`] of this
    /// payload reproduces the block bit-for-bit (NaN/∞ range poisons
    /// included), which is what makes a spill/restore round trip
    /// indistinguishable from never having evicted the block.
    pub fn export_block(&self, block: BlockId) -> Vec<u8> {
        let d = self.block_size * self.kv_heads * self.head_dim;
        let off = block as usize * d;
        let gs = block as usize * self.kv_heads;
        let mut out = Vec::with_capacity(self.block_export_bytes());
        let mut push = |xs: &[f32]| {
            for &x in xs {
                out.extend_from_slice(&x.to_le_bytes());
            }
        };
        for layer in 0..self.num_layers {
            push(&self.keys[layer][off..off + d]);
            push(&self.values[layer][off..off + d]);
            push(&self.k_lo[layer][gs..gs + self.kv_heads]);
            push(&self.k_hi[layer][gs..gs + self.kv_heads]);
        }
        out
    }

    /// Inverse of [`PagedKvCache::export_block`]: overwrite `block`
    /// (all layers, payload + range metadata) from an exported payload.
    /// Returns `false` (block untouched) on a length mismatch — the
    /// caller treats that as a miss, never a panic.
    pub fn import_block(&mut self, block: BlockId, bytes: &[u8]) -> bool {
        if bytes.len() != self.block_export_bytes() {
            return false;
        }
        let d = self.block_size * self.kv_heads * self.head_dim;
        let off = block as usize * d;
        let gs = block as usize * self.kv_heads;
        let mut cursor = 0usize;
        let mut pull = |dst: &mut [f32]| {
            for x in dst {
                *x = f32::from_le_bytes(bytes[cursor..cursor + 4].try_into().unwrap());
                cursor += 4;
            }
        };
        for layer in 0..self.num_layers {
            pull(&mut self.keys[layer][off..off + d]);
            pull(&mut self.values[layer][off..off + d]);
            pull(&mut self.k_lo[layer][gs..gs + self.kv_heads]);
            pull(&mut self.k_hi[layer][gs..gs + self.kv_heads]);
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::BlockAllocator;

    fn mk() -> (PagedKvCache, BlockAllocator) {
        (PagedKvCache::new(2, 4, 4, 2, 3), BlockAllocator::new(4, 4))
    }

    #[test]
    fn write_read_roundtrip() {
        let (mut cache, mut alloc) = mk();
        let mut t = BlockTable::new();
        t.reserve(5, &mut alloc);
        for i in 0..5u32 {
            let (b, s) = t.append_slot(4);
            let k: Vec<f32> = (0..6).map(|j| (i * 10 + j) as f32).collect();
            let v: Vec<f32> = (0..6).map(|j| (i * 100 + j) as f32).collect();
            cache.write_token(0, b, s, &k, &v);
        }
        let (b, s) = t.locate(4, 4);
        assert_eq!(cache.key_token(0, b, s)[0], 40.0);
        assert_eq!(cache.value_token(0, b, s)[5], 405.0);
    }

    #[test]
    fn gather_is_logical_order() {
        let (mut cache, mut alloc) = mk();
        let mut t = BlockTable::new();
        t.reserve(6, &mut alloc);
        for i in 0..6u32 {
            let (b, s) = t.append_slot(4);
            cache.write_token(1, b, s, &[i as f32; 6], &[-(i as f32); 6]);
        }
        let (ks, vs) = cache.gather(1, &t);
        assert_eq!(ks.len(), 6 * 6);
        for i in 0..6 {
            assert_eq!(ks[i * 6], i as f32);
            assert_eq!(vs[i * 6], -(i as f32));
        }
    }

    #[test]
    fn layers_are_independent() {
        let (mut cache, mut alloc) = mk();
        let mut t = BlockTable::new();
        t.reserve(1, &mut alloc);
        let (b, s) = t.append_slot(4);
        cache.write_token(0, b, s, &[1.0; 6], &[1.0; 6]);
        assert_eq!(cache.key_token(1, b, s), &[0.0; 6]);
    }

    #[test]
    fn copy_block_copies_all_layers() {
        let (mut cache, mut alloc) = mk();
        let b0 = alloc.alloc().unwrap();
        let b1 = alloc.alloc().unwrap();
        cache.write_token(0, b0, 2, &[7.0; 6], &[8.0; 6]);
        cache.write_token(1, b0, 3, &[9.0; 6], &[10.0; 6]);
        cache.copy_block(b0, b1);
        assert_eq!(cache.key_token(0, b1, 2), &[7.0; 6]);
        assert_eq!(cache.value_token(1, b1, 3), &[10.0; 6]);
    }

    #[test]
    fn key_bounds_track_tenancy_and_poison_on_nan() {
        let mut cache = PagedKvCache::new(1, 2, 4, 2, 3);
        // Fresh pool: the zeroed blocks are exactly covered.
        assert_eq!(cache.key_tile_bounds(0, 0, 0), (0.0, 0.0));
        cache.write_token(0, 0, 0, &[1.0, 2.0, -3.0, 0.5, 0.5, 0.5], &[0.0; 6]);
        assert_eq!(cache.key_tile_bounds(0, 0, 0), (-3.0, 2.0));
        assert_eq!(cache.key_tile_bounds(0, 0, 1), (0.5, 0.5));
        // Later slots only widen.
        cache.write_token(0, 0, 1, &[4.0, 0.0, 0.0, -9.0, 0.0, 0.0], &[0.0; 6]);
        assert_eq!(cache.key_tile_bounds(0, 0, 0), (-3.0, 4.0));
        assert_eq!(cache.key_tile_bounds(0, 0, 1), (-9.0, 0.5));
        // Slot-0 write = new tenancy: ranges reset, no stale widening.
        cache.write_token(0, 0, 0, &[0.1; 6], &[0.0; 6]);
        assert_eq!(cache.key_tile_bounds(0, 0, 0), (0.1, 0.1));
        // COW copies carry their source's bounds.
        cache.copy_block(0, 1);
        assert_eq!(cache.key_tile_bounds(0, 1, 0), (0.1, 0.1));
        // NaN keys poison to the always-sound (−∞, +∞).
        cache.write_token(0, 1, 1, &[f32::NAN, 0.0, 0.0, 1.0, 1.0, 1.0], &[0.0; 6]);
        let (lo, hi) = cache.key_tile_bounds(0, 1, 0);
        assert_eq!((lo, hi), (f32::NEG_INFINITY, f32::INFINITY));
    }

    #[test]
    fn pool_bytes_math() {
        let cache = PagedKvCache::new(2, 4, 4, 2, 3);
        // 2 (K+V) * 2 layers * 4 blocks * 4 slots * 2 heads * 3 dim * 4 bytes
        assert_eq!(cache.pool_bytes(), 2 * 2 * 4 * 4 * 2 * 3 * 4);
    }

    #[test]
    fn export_import_roundtrips_payload_and_bounds_bit_exactly() {
        let (mut cache, _alloc) = mk();
        for s in 0..4 {
            let k: Vec<f32> = (0..6).map(|j| (s * 6 + j) as f32 * 0.37 - 2.0).collect();
            let v: Vec<f32> = (0..6).map(|j| (s * 6 + j) as f32 * -0.11).collect();
            cache.write_token(0, 1, s, &k, &v);
            cache.write_token(1, 1, s, &v, &k);
        }
        let bytes = cache.export_block(1);
        assert_eq!(bytes.len(), cache.block_export_bytes());
        // Restore into a *different* block of a fresh pool; every read
        // and every bound must match the source bit-for-bit.
        let mut other = PagedKvCache::new(2, 4, 4, 2, 3);
        assert!(other.import_block(3, &bytes));
        for layer in 0..2 {
            assert_eq!(cache.key_block(layer, 1), other.key_block(layer, 3));
            assert_eq!(cache.value_block(layer, 1), other.value_block(layer, 3));
            for h in 0..2 {
                assert_eq!(
                    cache.key_tile_bounds(layer, 1, h),
                    other.key_tile_bounds(layer, 3, h)
                );
            }
        }
        // Length mismatch is a refusal, not a panic or partial write.
        assert!(!other.import_block(0, &bytes[..bytes.len() - 1]));
        assert_eq!(other.key_block(0, 0), &[0.0; 24][..]);
    }
}
