//! Prefix-sharing cache reuse (paper §III.C "Cache Sharing and Reuse"):
//! "multiple requests may share the same key-value cache … we reuse
//! existing key-value vectors, avoiding redundant computation and
//! storage".
//!
//! Full KV blocks are indexed by a *chain hash* of the token ids they
//! cover (hash of this block's tokens mixed with the previous block's
//! hash, so a hit guarantees the entire prefix matches). The cache holds
//! its own reference on every indexed block; sequences that hit share
//! the block (refcount++) instead of recomputing its K/V. Eviction
//! releases the cache's reference FIFO — live sequences are unaffected
//! because blocks are refcounted.

use super::block_allocator::{BlockAllocator, BlockId};
use std::collections::{HashMap, VecDeque};

/// FNV-1a over token ids, chained with the parent hash.
fn chain_hash(parent: u64, tokens: &[u32]) -> u64 {
    let mut h = parent ^ 0xcbf2_9ce4_8422_2325;
    for &t in tokens {
        h ^= t as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Chain hashes for every *full* `block_size` block of `tokens` — the
/// same hashes [`PrefixCache`] indexes by, exported as a free function
/// so eviction paths that do not hold a cache (the sliding-window
/// evictor, the spill tier) can name the blocks they are about to drop.
pub fn chain_block_hashes(block_size: usize, tokens: &[u32]) -> Vec<u64> {
    let mut out = Vec::new();
    let mut parent = 0u64;
    for chunk in tokens.chunks_exact(block_size) {
        parent = chain_hash(parent, chunk);
        out.push(parent);
    }
    out
}

/// Hash-indexed cache of full KV blocks.
#[derive(Debug)]
pub struct PrefixCache {
    block_size: usize,
    /// Max blocks the cache may pin (its refcounts) at once.
    capacity: usize,
    map: HashMap<u64, BlockId>,
    /// Insertion order for FIFO eviction; entries may be stale (hash
    /// removed) — validated on pop.
    order: VecDeque<u64>,
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
}

impl PrefixCache {
    pub fn new(block_size: usize, capacity: usize) -> Self {
        PrefixCache {
            block_size,
            capacity,
            map: HashMap::new(),
            order: VecDeque::new(),
            hits: 0,
            misses: 0,
            insertions: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Chain hashes for every *full* block of `tokens`.
    pub fn block_hashes(&self, tokens: &[u32]) -> Vec<u64> {
        chain_block_hashes(self.block_size, tokens)
    }

    /// Longest run of leading full blocks of `tokens` present in the
    /// cache, **sharing** each hit block (caller adopts them). At least
    /// one token is always left uncached so prefill has something to
    /// compute logits from.
    pub fn lookup_shared(&mut self, tokens: &[u32], alloc: &mut BlockAllocator) -> Vec<BlockId> {
        let max_blocks = tokens.len().saturating_sub(1) / self.block_size;
        let mut shared = Vec::new();
        for h in self.block_hashes(tokens).into_iter().take(max_blocks) {
            match self.map.get(&h) {
                Some(&b) => {
                    alloc.share(b);
                    shared.push(b);
                    self.hits += 1;
                }
                None => {
                    self.misses += 1;
                    break;
                }
            }
        }
        shared
    }

    /// Index a finished/filled sequence's full blocks. The cache takes
    /// its own reference on each newly indexed block; already-indexed
    /// hashes keep their existing block. Returns the victims evicted to
    /// make room (see [`PrefixCache::evict_to`] for the contract).
    pub fn insert(
        &mut self,
        tokens: &[u32],
        blocks: &[BlockId],
        alloc: &mut BlockAllocator,
    ) -> Vec<(u64, BlockId)> {
        let hashes = self.block_hashes(tokens);
        let mut victims = Vec::new();
        for (i, h) in hashes.into_iter().enumerate() {
            if i >= blocks.len() {
                break;
            }
            if self.map.contains_key(&h) {
                continue;
            }
            victims.extend(self.evict_to(self.capacity.saturating_sub(1), alloc));
            alloc.share(blocks[i]);
            self.map.insert(h, blocks[i]);
            self.order.push_back(h);
            self.insertions += 1;
        }
        victims
    }

    /// Release cache references until at most `target` blocks are
    /// pinned, returning each victim as a `(chain_hash, block)` pair so
    /// the caller can offer it to a colder tier (the disk spill store)
    /// before the pool reuses it.
    ///
    /// The cache's reference is already released when this returns, but
    /// the block's *bytes* are untouched until the allocator hands the
    /// block out again — so a caller that exports victim bytes before
    /// its next `alloc()` reads exactly the KV that was cached.
    pub fn evict_to(&mut self, target: usize, alloc: &mut BlockAllocator) -> Vec<(u64, BlockId)> {
        let mut victims = Vec::new();
        while self.map.len() > target {
            let Some(h) = self.order.pop_front() else { break };
            if let Some(b) = self.map.remove(&h) {
                alloc.release(b);
                victims.push((h, b));
            }
        }
        victims
    }

    /// Drop everything (memory-pressure flush), returning the victims
    /// as in [`PrefixCache::evict_to`].
    pub fn clear(&mut self, alloc: &mut BlockAllocator) -> Vec<(u64, BlockId)> {
        self.evict_to(0, alloc)
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (PrefixCache, BlockAllocator) {
        (PrefixCache::new(4, 8), BlockAllocator::new(16, 4))
    }

    fn tokens(n: usize) -> Vec<u32> {
        (0..n as u32).map(|i| 256 + i % 50).collect()
    }

    #[test]
    fn chain_hashes_depend_on_prefix() {
        let (c, _) = setup();
        let a = c.block_hashes(&[1, 2, 3, 4, 5, 6, 7, 8]);
        let b = c.block_hashes(&[9, 2, 3, 4, 5, 6, 7, 8]);
        assert_eq!(a.len(), 2);
        assert_ne!(a[0], b[0], "first block differs");
        assert_ne!(a[1], b[1], "chained: second block must differ too");
    }

    #[test]
    fn insert_then_lookup_shares_blocks() {
        let (mut c, mut alloc) = setup();
        let toks = tokens(9); // 2 full blocks + 1
        let b0 = alloc.alloc().unwrap();
        let b1 = alloc.alloc().unwrap();
        c.insert(&toks, &[b0, b1], &mut alloc);
        assert_eq!(c.len(), 2);
        assert_eq!(alloc.ref_count(b0), 2); // owner + cache

        let shared = c.lookup_shared(&toks, &mut alloc);
        assert_eq!(shared, vec![b0, b1]);
        assert_eq!(alloc.ref_count(b0), 3);
        assert_eq!(c.hits, 2);
    }

    #[test]
    fn lookup_leaves_at_least_one_token_uncached() {
        let (mut c, mut alloc) = setup();
        let toks = tokens(8); // exactly 2 full blocks
        let b0 = alloc.alloc().unwrap();
        let b1 = alloc.alloc().unwrap();
        c.insert(&toks, &[b0, b1], &mut alloc);
        // Whole prompt covered by cached blocks → only block 0 may be
        // adopted (the last token must be computed for logits).
        let shared = c.lookup_shared(&toks, &mut alloc);
        assert_eq!(shared, vec![b0]);
    }

    #[test]
    fn miss_on_divergent_prefix() {
        let (mut c, mut alloc) = setup();
        let toks = tokens(9);
        let b0 = alloc.alloc().unwrap();
        let b1 = alloc.alloc().unwrap();
        c.insert(&toks, &[b0, b1], &mut alloc);
        let mut other = toks.clone();
        other[0] = 999; // diverge in block 0
        assert!(c.lookup_shared(&other, &mut alloc).is_empty());
        assert!(c.misses >= 1);
    }

    #[test]
    fn eviction_releases_cache_reference_only() {
        let (mut c, mut alloc) = setup();
        let toks = tokens(5);
        let b0 = alloc.alloc().unwrap();
        c.insert(&toks, &[b0], &mut alloc);
        assert_eq!(alloc.ref_count(b0), 2);
        c.clear(&mut alloc);
        assert_eq!(alloc.ref_count(b0), 1, "owner's reference survives");
        assert!(c.is_empty());
        alloc.release(b0);
        assert_eq!(alloc.num_free(), 16);
    }

    #[test]
    fn capacity_bound_is_enforced() {
        let mut c = PrefixCache::new(4, 2);
        let mut alloc = BlockAllocator::new(16, 4);
        for seed in 0..4u32 {
            let toks: Vec<u32> = (0..5).map(|i| seed * 100 + i).collect();
            let b = alloc.alloc().unwrap();
            c.insert(&toks, &[b], &mut alloc);
            alloc.release(b); // owner departs; cache ref may persist
        }
        assert!(c.len() <= 2, "cache pinned {} blocks", c.len());
        // Evicted blocks were fully released.
        assert_eq!(alloc.num_used(), c.len());
    }

    #[test]
    fn eviction_reports_every_victim_and_matches_allocator_accounting() {
        // Regression: `evict_to` used to free victims silently, so no
        // observer (e.g. the spill tier) could see a block before the
        // pool reused it. Every eviction path must now report exactly
        // the (hash, block) pairs whose references it released, and the
        // allocator's free count must move in lockstep.
        let mut c = PrefixCache::new(4, 2);
        let mut alloc = BlockAllocator::new(16, 4);
        let mut inserted: Vec<(u64, BlockId)> = Vec::new();
        let mut victims: Vec<(u64, BlockId)> = Vec::new();
        for seed in 0..5u32 {
            let toks: Vec<u32> = (0..5).map(|i| seed * 100 + i).collect();
            let b = alloc.alloc().unwrap();
            let free_before = alloc.num_free();
            let evicted = c.insert(&toks, &[b], &mut alloc);
            // Owner departs immediately: only cache references remain,
            // so every reported victim was fully freed.
            alloc.release(b);
            assert_eq!(
                alloc.num_free(),
                free_before + evicted.len(),
                "free-count delta must equal reported victims at seed {seed}"
            );
            inserted.push((c.block_hashes(&toks)[0], b));
            victims.extend(evicted);
        }
        victims.extend(c.clear(&mut alloc));
        // All 5 singly-referenced inserts were eventually evicted, FIFO,
        // with the exact (hash, block) pairs that went in.
        assert_eq!(victims, inserted);
        assert_eq!(alloc.num_free(), 16, "no block leaked by eviction");
        // An over-inserted hash is never double-reported.
        assert!(c.is_empty());
        assert!(c.evict_to(0, &mut alloc).is_empty());
    }

    #[test]
    fn duplicate_insert_is_idempotent() {
        let (mut c, mut alloc) = setup();
        let toks = tokens(5);
        let b0 = alloc.alloc().unwrap();
        c.insert(&toks, &[b0], &mut alloc);
        c.insert(&toks, &[b0], &mut alloc);
        assert_eq!(c.len(), 1);
        assert_eq!(alloc.ref_count(b0), 2);
    }
}
