//! Paged KV-cache memory management (the paper's §III.A/§III.C substrate).
//!
//! Key/value vectors are split into fixed-size blocks that live
//! non-contiguously in a pre-allocated pool; per-sequence *block tables*
//! map logical token positions to physical blocks. Blocks are
//! reference-counted so concurrent requests can share prefixes
//! (copy-on-write), and a contiguous-arena baseline exists for the
//! paging-vs-reservation ablation (Abl. B).
//!
//! Physical storage is abstracted behind [`KvStore`] with two
//! implementations selected by [`KvCacheDtype`]: the dense f32 pool
//! ([`PagedKvCache`]) and the packed 8-bit pool
//! ([`QuantizedPagedKvCache`], quantize-on-append, per-(block, kv_head)
//! grids, in-tile dequant in the attention kernel). Evicted blocks can
//! optionally spill to a crash-safe on-disk tier ([`SpillTier`], off by
//! default) and be restored bit-identically on a later prefix hit. See
//! ARCHITECTURE.md for how the request path flows through this module.

pub mod block_allocator;
pub mod block_table;
pub mod contiguous;
pub mod eviction;
pub mod paged;
pub mod prefix_cache;
pub mod quantized;
pub mod spill;
pub mod stats;
pub mod store;

pub use block_allocator::{BlockAllocator, BlockId};
pub use block_table::{BlockTable, TOMBSTONE};
pub use contiguous::ContiguousArena;
pub use eviction::{EvictionPolicy, LruEviction};
pub use paged::PagedKvCache;
pub use prefix_cache::PrefixCache;
pub use quantized::{QuantKvTile, QuantizedPagedKvCache};
pub use spill::{SpillConfig, SpillError, SpillStats, SpillTier};
pub use stats::CacheStats;
pub use store::{KvBlockView, KvCacheDtype, KvStore};
