//! Paged KV-cache memory management (the paper's §III.A/§III.C substrate).
//!
//! Key/value vectors are split into fixed-size blocks that live
//! non-contiguously in a pre-allocated pool; per-sequence *block tables*
//! map logical token positions to physical blocks. Blocks are
//! reference-counted so concurrent requests can share prefixes
//! (copy-on-write), and a contiguous-arena baseline exists for the
//! paging-vs-reservation ablation (Abl. B).

pub mod block_allocator;
pub mod block_table;
pub mod contiguous;
pub mod eviction;
pub mod paged;
pub mod prefix_cache;
pub mod stats;

pub use block_allocator::{BlockAllocator, BlockId};
pub use block_table::BlockTable;
pub use contiguous::ContiguousArena;
pub use eviction::{EvictionPolicy, LruEviction};
pub use paged::PagedKvCache;
pub use prefix_cache::PrefixCache;
pub use stats::CacheStats;
