//! Per-sequence block table: logical token position → (physical block, slot).

use super::block_allocator::{BlockAllocator, BlockId};

/// Sentinel for a block-table entry whose physical block was **evicted**
/// under the sliding-window policy (`SparsityConfig`): the entry keeps
/// its index — logical positions never renumber, so every tile keeps its
/// absolute `index · block_size` position — but the pool block behind it
/// has been released. Attention walks step over tombstones (the window
/// rule already proves them invisible), `free_all`/`fork` skip them, and
/// `locate` refuses them. `BlockId::MAX` can never be a real block: the
/// allocator's pool is indexed by `usize` vectors far smaller than 2³².
pub const TOMBSTONE: BlockId = BlockId::MAX;

/// Maps a sequence's logical KV positions onto physical pool blocks.
#[derive(Debug, Clone, Default)]
pub struct BlockTable {
    blocks: Vec<BlockId>,
    /// Number of token slots currently occupied.
    len: usize,
}

impl BlockTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Physical blocks in logical order.
    pub fn blocks(&self) -> &[BlockId] {
        &self.blocks
    }

    /// Occupied token count.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Blocks needed to hold `tokens` with the given block size.
    pub fn blocks_needed(tokens: usize, block_size: usize) -> usize {
        tokens.div_ceil(block_size)
    }

    /// Additional blocks required to extend this table by `extra` tokens.
    pub fn blocks_to_grow(&self, extra: usize, block_size: usize) -> usize {
        Self::blocks_needed(self.len + extra, block_size).saturating_sub(self.blocks.len())
    }

    /// Reserve capacity for `extra` more tokens, allocating blocks as
    /// needed. Returns `false` (with the table unchanged) if the pool
    /// cannot satisfy the request.
    pub fn reserve(&mut self, extra: usize, alloc: &mut BlockAllocator) -> bool {
        let need = self.blocks_to_grow(extra, alloc.block_size());
        if !alloc.can_alloc(need) {
            return false;
        }
        for _ in 0..need {
            self.blocks.push(alloc.alloc().expect("can_alloc lied"));
        }
        true
    }

    /// Append one token slot (capacity must have been reserved); returns
    /// the physical `(block, slot)` it landed in.
    pub fn append_slot(&mut self, block_size: usize) -> (BlockId, usize) {
        let pos = self.len;
        let bidx = pos / block_size;
        assert!(
            bidx < self.blocks.len(),
            "append beyond reserved capacity (len={}, blocks={})",
            self.len,
            self.blocks.len()
        );
        self.len += 1;
        let b = self.blocks[bidx];
        debug_assert!(b != TOMBSTONE, "append into an evicted block (pos {pos})");
        (b, pos % block_size)
    }

    /// Physical location of an existing logical position.
    pub fn locate(&self, pos: usize, block_size: usize) -> (BlockId, usize) {
        assert!(pos < self.len, "position {pos} out of range (len {})", self.len);
        let b = self.blocks[pos / block_size];
        assert!(b != TOMBSTONE, "locate({pos}) hit an evicted (tombstoned) block");
        (b, pos % block_size)
    }

    /// Number of entries still backed by a physical block (tombstones
    /// excluded) — the figure block-accounting (stats, eviction-victim
    /// sizing) must use on a windowed table.
    pub fn live_blocks(&self) -> usize {
        self.blocks.iter().filter(|&&b| b != TOMBSTONE).count()
    }

    /// Tombstone the leading window-expired entries and release their
    /// pool blocks: every entry with index in `[sink_blocks, frontier)`
    /// that still holds a block is replaced by [`TOMBSTONE`] and
    /// `alloc.release`d (a block shared with another table merely drops
    /// one reference; it returns to the free list when the last holder
    /// lets go). Returns the number of entries evicted by this call.
    ///
    /// `frontier` is `SparsityConfig::evict_frontier(next_pos)` — the
    /// exact invisibility boundary: the visibility rule
    /// `tb + window > query_block` only ever *loses* blocks as the query
    /// advances, so an entry behind the frontier can never be read again
    /// and eviction is numerics-invariant by construction (proved by the
    /// eviction property tests).
    pub fn evict_leading(
        &mut self,
        sink_blocks: usize,
        frontier: usize,
        alloc: &mut BlockAllocator,
    ) -> usize {
        let hi = frontier.min(self.blocks.len());
        let mut evicted = 0usize;
        for b in self.blocks[sink_blocks.min(hi)..hi].iter_mut() {
            if *b != TOMBSTONE {
                alloc.release(*b);
                *b = TOMBSTONE;
                evicted += 1;
            }
        }
        evicted
    }

    /// Release every live block back to the allocator and clear the table.
    pub fn free_all(&mut self, alloc: &mut BlockAllocator) {
        for &b in &self.blocks {
            if b != TOMBSTONE {
                alloc.release(b);
            }
        }
        self.blocks.clear();
        self.len = 0;
    }

    /// Fork: share all live blocks with a child table (copy-on-write
    /// prefix sharing; tombstoned entries stay tombstoned in the child).
    /// The child starts with the same logical length.
    pub fn fork(&self, alloc: &mut BlockAllocator) -> BlockTable {
        for &b in &self.blocks {
            if b != TOMBSTONE {
                alloc.share(b);
            }
        }
        self.clone()
    }

    /// Ensure the *last* block is uniquely owned before an in-place append
    /// (copy-on-write). Returns `Some((old, new))` when a copy happened so
    /// the cache storage can copy the block contents; `None` otherwise.
    pub fn cow_last_block(&mut self, alloc: &mut BlockAllocator) -> Option<(BlockId, BlockId)> {
        let last = *self.blocks.last()?;
        if last == TOMBSTONE {
            // The fill block is never evicted (the frontier sits at or
            // behind the query's own block), so a tombstoned tail means
            // the next append lands in a block yet to be reserved.
            return None;
        }
        if alloc.ref_count(last) <= 1 {
            return None;
        }
        let fresh = alloc.alloc()?;
        alloc.release(last);
        *self.blocks.last_mut().unwrap() = fresh;
        Some((last, fresh))
    }

    /// Adopt already-shared cache blocks as the leading prefix of an
    /// empty, unreserved table (prefix reuse at admission): the caller
    /// must already hold a reference on each block (see
    /// `PrefixCache::lookup_shared`). The table's logical length jumps
    /// to the end of the adopted prefix; adoption consumes no free
    /// blocks.
    pub fn adopt_prefix(&mut self, shared: &[BlockId], block_size: usize) {
        assert_eq!(self.len, 0, "adopt_prefix on a filled table");
        assert!(self.blocks.is_empty(), "adopt_prefix on a reserved table");
        debug_assert!(
            shared.iter().all(|&b| b != TOMBSTONE),
            "adopting a prefix with evicted blocks (the prefix cache must \
             never index a windowed table)"
        );
        self.blocks.extend_from_slice(shared);
        self.len = shared.len() * block_size;
    }

    /// Slots allocated but unused in the final block (internal fragmentation).
    pub fn wasted_slots(&self, block_size: usize) -> usize {
        self.blocks.len() * block_size - self.len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grow_and_append() {
        let mut alloc = BlockAllocator::new(8, 4);
        let mut t = BlockTable::new();
        assert!(t.reserve(6, &mut alloc)); // 2 blocks
        assert_eq!(t.blocks().len(), 2);
        let mut slots = Vec::new();
        for _ in 0..6 {
            slots.push(t.append_slot(4));
        }
        assert_eq!(t.len(), 6);
        // First 4 tokens in block 0, next 2 in block 1.
        assert_eq!(slots[0], (t.blocks()[0], 0));
        assert_eq!(slots[3], (t.blocks()[0], 3));
        assert_eq!(slots[4], (t.blocks()[1], 0));
        assert_eq!(t.wasted_slots(4), 2);
    }

    #[test]
    fn reserve_fails_atomically() {
        let mut alloc = BlockAllocator::new(1, 4);
        let mut t = BlockTable::new();
        assert!(!t.reserve(8, &mut alloc)); // needs 2 blocks, pool has 1
        assert_eq!(t.blocks().len(), 0);
        assert_eq!(alloc.num_free(), 1);
    }

    #[test]
    fn locate_matches_append() {
        let mut alloc = BlockAllocator::new(4, 3);
        let mut t = BlockTable::new();
        t.reserve(7, &mut alloc);
        let appended: Vec<_> = (0..7).map(|_| t.append_slot(3)).collect();
        for (pos, &loc) in appended.iter().enumerate() {
            assert_eq!(t.locate(pos, 3), loc);
        }
    }

    #[test]
    fn free_all_returns_blocks() {
        let mut alloc = BlockAllocator::new(4, 4);
        let mut t = BlockTable::new();
        t.reserve(16, &mut alloc);
        assert_eq!(alloc.num_free(), 0);
        t.free_all(&mut alloc);
        assert_eq!(alloc.num_free(), 4);
        assert!(t.is_empty());
    }

    #[test]
    fn fork_shares_and_cow_splits() {
        let mut alloc = BlockAllocator::new(4, 4);
        let mut parent = BlockTable::new();
        parent.reserve(4, &mut alloc);
        for _ in 0..4 {
            parent.append_slot(4);
        }
        let mut child = parent.fork(&mut alloc);
        assert_eq!(alloc.ref_count(parent.blocks()[0]), 2);

        // Child appends → must COW the shared last block first.
        let cow = child.cow_last_block(&mut alloc);
        assert!(cow.is_some());
        let (old, new) = cow.unwrap();
        assert_eq!(old, parent.blocks()[0]);
        assert_ne!(new, old);
        assert_eq!(alloc.ref_count(old), 1);
        assert_eq!(alloc.ref_count(new), 1);

        // Parent unaffected.
        assert_eq!(parent.len(), 4);
        parent.free_all(&mut alloc);
        child.free_all(&mut alloc);
        assert_eq!(alloc.num_free(), 4);
    }

    #[test]
    fn adopt_prefix_extends_length_without_allocating() {
        let mut alloc = BlockAllocator::new(4, 4);
        let mut donor = BlockTable::new();
        donor.reserve(8, &mut alloc);
        for _ in 0..8 {
            donor.append_slot(4);
        }
        let shared: Vec<_> = donor.blocks().to_vec();
        for &b in &shared {
            alloc.share(b);
        }
        let free_before = alloc.num_free();
        let mut t = BlockTable::new();
        t.adopt_prefix(&shared, 4);
        assert_eq!(t.len(), 8);
        assert_eq!(t.blocks(), donor.blocks());
        assert_eq!(alloc.num_free(), free_before, "adoption must not allocate");
        // Growing past the adopted prefix allocates fresh blocks.
        assert!(t.reserve(2, &mut alloc));
        assert_eq!(t.blocks().len(), 3);
        t.free_all(&mut alloc);
        donor.free_all(&mut alloc);
        assert_eq!(alloc.num_free(), 4);
    }

    #[test]
    fn cow_noop_when_unique() {
        let mut alloc = BlockAllocator::new(2, 4);
        let mut t = BlockTable::new();
        t.reserve(2, &mut alloc);
        assert!(t.cow_last_block(&mut alloc).is_none());
    }

    #[test]
    fn evict_leading_tombstones_and_frees() {
        let mut alloc = BlockAllocator::new(8, 4);
        let mut t = BlockTable::new();
        t.reserve(20, &mut alloc); // 5 blocks
        for _ in 0..20 {
            t.append_slot(4);
        }
        assert_eq!(alloc.num_free(), 3);
        // Evict [1, 3): indices 1 and 2; sinks (index 0) survive.
        assert_eq!(t.evict_leading(1, 3, &mut alloc), 2);
        assert_eq!(alloc.num_free(), 5, "evicted blocks return to the pool");
        assert_eq!(t.blocks()[1], TOMBSTONE);
        assert_eq!(t.blocks()[2], TOMBSTONE);
        assert_ne!(t.blocks()[0], TOMBSTONE);
        assert_ne!(t.blocks()[3], TOMBSTONE);
        assert_eq!(t.live_blocks(), 3);
        assert_eq!(t.len(), 20, "logical positions never renumber");
        // Idempotent: a second pass over the same range frees nothing.
        assert_eq!(t.evict_leading(1, 3, &mut alloc), 0);
        assert_eq!(alloc.num_free(), 5);
        // A wider frontier only evicts the newly-expired entry.
        assert_eq!(t.evict_leading(1, 4, &mut alloc), 1);
        // locate still works on live positions, free_all skips tombstones.
        let _ = t.locate(0, 4); // sink block
        let _ = t.locate(17, 4); // tail block
        t.free_all(&mut alloc);
        assert_eq!(alloc.num_free(), 8);
    }

    #[test]
    #[should_panic(expected = "evicted (tombstoned) block")]
    fn locate_refuses_evicted_positions() {
        let mut alloc = BlockAllocator::new(4, 4);
        let mut t = BlockTable::new();
        t.reserve(8, &mut alloc);
        for _ in 0..8 {
            t.append_slot(4);
        }
        t.evict_leading(0, 1, &mut alloc);
        let _ = t.locate(2, 4);
    }

    #[test]
    fn fork_shares_only_live_blocks_and_shared_eviction_defers_free() {
        let mut alloc = BlockAllocator::new(8, 4);
        let mut parent = BlockTable::new();
        parent.reserve(12, &mut alloc); // 3 blocks
        for _ in 0..12 {
            parent.append_slot(4);
        }
        parent.evict_leading(0, 1, &mut alloc);
        assert_eq!(alloc.num_free(), 6);
        let mut child = parent.fork(&mut alloc);
        assert_eq!(child.blocks()[0], TOMBSTONE, "tombstones survive the fork");
        assert_eq!(alloc.ref_count(parent.blocks()[1]), 2);
        // Parent evicts a block the child still reads: one reference
        // drops, the block stays allocated until the child lets go.
        let shared = parent.blocks()[1];
        assert_eq!(parent.evict_leading(0, 2, &mut alloc), 1);
        assert_eq!(alloc.ref_count(shared), 1);
        assert_eq!(alloc.num_free(), 6, "child still holds the block");
        assert_eq!(child.evict_leading(0, 2, &mut alloc), 1);
        assert_eq!(alloc.num_free(), 7, "last reference frees it");
        parent.free_all(&mut alloc);
        child.free_all(&mut alloc);
        assert_eq!(alloc.num_free(), 8);
    }

    #[test]
    fn cow_after_tail_eviction_is_a_noop() {
        let mut alloc = BlockAllocator::new(4, 4);
        let mut t = BlockTable::new();
        t.reserve(8, &mut alloc);
        for _ in 0..8 {
            t.append_slot(4);
        }
        // Evict everything (window fully advanced past both blocks).
        t.evict_leading(0, 2, &mut alloc);
        assert!(t.cow_last_block(&mut alloc).is_none());
        t.free_all(&mut alloc);
        assert_eq!(alloc.num_free(), 4);
    }

    #[test]
    fn blocks_needed_math() {
        assert_eq!(BlockTable::blocks_needed(0, 16), 0);
        assert_eq!(BlockTable::blocks_needed(1, 16), 1);
        assert_eq!(BlockTable::blocks_needed(16, 16), 1);
        assert_eq!(BlockTable::blocks_needed(17, 16), 2);
    }
}
