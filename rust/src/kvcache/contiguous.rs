//! Contiguous-reservation baseline allocator (the "before paging" world).
//!
//! Traditional serving engines reserve `max_seq_len` contiguous KV slots
//! per request up front. This arena implements that policy with first-fit
//! placement over a flat slot space, so the paging ablation (Abl. B) can
//! measure both internal fragmentation (reserved-but-unused slots) and
//! external fragmentation (free space too scattered to admit a request).

/// A contiguous reservation: `[start, start+len)` slots.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Reservation {
    pub id: u64,
    pub start: usize,
    pub len: usize,
}

/// First-fit contiguous arena over `total_slots` token slots.
#[derive(Debug)]
pub struct ContiguousArena {
    total_slots: usize,
    /// Active reservations sorted by start.
    reservations: Vec<Reservation>,
    next_id: u64,
    /// Occupied token counts per reservation id (for internal-frag stats).
    used: std::collections::BTreeMap<u64, usize>,
}

impl ContiguousArena {
    pub fn new(total_slots: usize) -> Self {
        ContiguousArena {
            total_slots,
            reservations: Vec::new(),
            next_id: 0,
            used: Default::default(),
        }
    }

    pub fn total_slots(&self) -> usize {
        self.total_slots
    }

    /// Sum of reserved slots.
    pub fn reserved_slots(&self) -> usize {
        self.reservations.iter().map(|r| r.len).sum()
    }

    /// Sum of actually-occupied slots.
    pub fn used_slots(&self) -> usize {
        self.used.values().sum()
    }

    /// First-fit reserve of `len` contiguous slots. Returns `None` when no
    /// gap is large enough (even if total free ≥ len — that is external
    /// fragmentation, which this baseline exists to exhibit).
    pub fn reserve(&mut self, len: usize) -> Option<Reservation> {
        assert!(len > 0);
        let mut cursor = 0usize;
        let mut insert_at = self.reservations.len();
        for (i, r) in self.reservations.iter().enumerate() {
            if r.start - cursor >= len {
                insert_at = i;
                break;
            }
            cursor = r.start + r.len;
        }
        if insert_at == self.reservations.len() && self.total_slots - cursor < len {
            return None;
        }
        let res = Reservation { id: self.next_id, start: cursor, len };
        self.next_id += 1;
        self.reservations.insert(insert_at, res);
        self.used.insert(res.id, 0);
        Some(res)
    }

    /// Record `n` occupied slots for a reservation (monotonic).
    pub fn occupy(&mut self, id: u64, n: usize) {
        let r = self.reservations.iter().find(|r| r.id == id).expect("unknown reservation");
        assert!(n <= r.len, "occupying beyond reservation");
        let u = self.used.get_mut(&id).expect("unknown reservation");
        *u = (*u).max(n);
    }

    /// Release a reservation.
    pub fn release(&mut self, id: u64) {
        let idx = self
            .reservations
            .iter()
            .position(|r| r.id == id)
            .expect("release of unknown reservation");
        self.reservations.remove(idx);
        self.used.remove(&id);
    }

    /// Largest free contiguous run.
    pub fn largest_free_run(&self) -> usize {
        let mut best = 0usize;
        let mut cursor = 0usize;
        for r in &self.reservations {
            best = best.max(r.start - cursor);
            cursor = r.start + r.len;
        }
        best.max(self.total_slots - cursor)
    }

    /// Total free slots (may be scattered).
    pub fn free_slots(&self) -> usize {
        self.total_slots - self.reserved_slots()
    }

    /// External fragmentation in [0,1]: 1 − largest_run/free. 0 when free
    /// space is one run (or there is no free space).
    pub fn external_fragmentation(&self) -> f64 {
        let free = self.free_slots();
        if free == 0 {
            return 0.0;
        }
        1.0 - self.largest_free_run() as f64 / free as f64
    }

    /// Internal fragmentation in [0,1]: reserved-but-unused / reserved.
    pub fn internal_fragmentation(&self) -> f64 {
        let reserved = self.reserved_slots();
        if reserved == 0 {
            return 0.0;
        }
        (reserved - self.used_slots()) as f64 / reserved as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_fit_placement() {
        let mut a = ContiguousArena::new(100);
        let r0 = a.reserve(30).unwrap();
        let r1 = a.reserve(30).unwrap();
        let _r2 = a.reserve(30).unwrap();
        assert_eq!(r0.start, 0);
        assert_eq!(r1.start, 30);
        assert!(a.reserve(20).is_none()); // only 10 left
        a.release(r1.id);
        let r3 = a.reserve(20).unwrap(); // reuses the hole
        assert_eq!(r3.start, 30);
    }

    #[test]
    fn external_fragmentation_blocks_admission() {
        let mut a = ContiguousArena::new(100);
        let ids: Vec<_> = (0..10).map(|_| a.reserve(10).unwrap().id).collect();
        // Free every other reservation: 50 free slots, max run 10.
        for (i, id) in ids.iter().enumerate() {
            if i % 2 == 0 {
                a.release(*id);
            }
        }
        assert_eq!(a.free_slots(), 50);
        assert_eq!(a.largest_free_run(), 10);
        assert!(a.reserve(20).is_none(), "externally fragmented");
        assert!(a.external_fragmentation() > 0.7);
    }

    #[test]
    fn internal_fragmentation_from_overreservation() {
        let mut a = ContiguousArena::new(100);
        let r = a.reserve(80).unwrap(); // reserve max_seq_len…
        a.occupy(r.id, 20); // …but only use 20 tokens
        assert!((a.internal_fragmentation() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn occupy_is_monotonic_and_bounded() {
        let mut a = ContiguousArena::new(10);
        let r = a.reserve(5).unwrap();
        a.occupy(r.id, 3);
        a.occupy(r.id, 2); // no shrink
        assert_eq!(a.used_slots(), 3);
    }

    #[test]
    #[should_panic(expected = "occupying beyond reservation")]
    fn occupy_overflow_panics() {
        let mut a = ContiguousArena::new(10);
        let r = a.reserve(5).unwrap();
        a.occupy(r.id, 6);
    }
}
