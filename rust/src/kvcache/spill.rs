//! Crash-safe disk spill tier for evicted prefix-KV blocks.
//!
//! When the prefix cache or the sliding-window policy evicts a block,
//! its exact bytes ([`super::KvStore::export_block`]) can be **offered**
//! here instead of being lost; a later prefix hit that misses the RAM
//! pool consults the spill index and **restores** the bytes into a
//! freshly allocated block ([`SpillTier::restore_into`]) — bit-identical
//! to the block that was evicted, because the payload is the pool's own
//! byte representation (packed q8 levels move as levels, f32 as f32; no
//! requantization round trip). See ARCHITECTURE.md "Spill & recovery
//! contract".
//!
//! ## On-disk format
//!
//! The store is a directory of append-only **segment files**
//! (`seg-NNNNNNNN.ogptqs`), each starting with the 8-byte magic
//! [`SEGMENT_MAGIC`] (`OGPTQS01` — format version 01) followed by
//! self-describing records, all fields little-endian:
//!
//! ```text
//! [len: u32] [hash: u64] [dtype: u8] [shape_fp: u64] [payload: len bytes] [crc32: u32]
//! ```
//!
//! `hash` is the prefix-chain hash that keys the record (the same chain
//! the RAM prefix cache uses), `dtype`/`shape_fp` pin the pool geometry
//! the payload came from, and the CRC32 (IEEE) covers everything before
//! it — a record either verifies end to end or does not exist.
//!
//! ## Crash safety: the commit frontier
//!
//! Each segment has an in-memory **commit frontier**: the byte offset up
//! to which every record has been fully written and flushed. The
//! frontier advances only *after* a successful append + flush, so a kill
//! mid-write leaves a torn tail strictly beyond it. The open-time
//! recovery scan re-derives the frontier from the bytes themselves —
//! records are walked until the first incomplete or CRC-failing one, and
//! the tail from that point is **truncated**, never served and never
//! grounds for refusing to start. Live IO failures (e.g. ENOSPC) repair
//! the file back to the frontier with `set_len` and count toward a
//! self-disabling circuit ([`SpillConfig::max_consecutive_io_failures`]):
//! a persistently failing disk turns the tier off, and serving continues
//! with recompute-on-miss — a spill failure is a cache miss, never a
//! wrong token, a panic, or a stuck engine.
//!
//! ## Degradation ladder
//!
//! 1. open fails → tier disabled at construction, serving undegraded;
//! 2. append fails → record dropped (the block is simply recomputed on
//!    its next miss), failure counted, circuit may open;
//! 3. restore read fails → miss, failure counted;
//! 4. restore CRC fails → record **quarantined** (never consulted
//!    again), counted in `corrupt_records`, miss;
//! 5. capacity cap reached → oldest closed segment reclaimed (deleted
//!    with its index entries) — the tier is a bounded cache, not a log.
//!
//! Deterministic IO fault injection (`runtime::fault::IoFaultPlan`)
//! drives every path above in tests; the hooks are compiled out of
//! plain release builds.

use super::block_allocator::BlockId;
use super::store::{KvCacheDtype, KvStore};
use std::collections::{HashMap, HashSet};
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

#[cfg(any(test, feature = "fault-inject"))]
use crate::runtime::fault::{IoFaultInjector, IoWriteFault};

/// Segment-file magic: format name + version (`01`). Bump the version
/// when the record layout changes; old segments then fail the magic
/// check and are discarded rather than misparsed.
pub const SEGMENT_MAGIC: &[u8; 8] = b"OGPTQS01";

/// Fixed record header: len (4) + hash (8) + dtype (1) + shape_fp (8).
const RECORD_HEADER_BYTES: usize = 21;
/// Record trailer: the CRC32.
const RECORD_TRAILER_BYTES: usize = 4;

/// CRC32 (IEEE 802.3, reflected) over a list of byte slices — the
/// per-record integrity check. Bitwise implementation: this runs on the
/// spill path only (eviction/restore), never per token.
pub fn crc32(parts: &[&[u8]]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for part in parts {
        for &b in *part {
            crc ^= b as u32;
            for _ in 0..8 {
                let mask = (crc & 1).wrapping_neg();
                crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
            }
        }
    }
    !crc
}

/// Order-sensitive fingerprint of a pool geometry (layers, heads, dims,
/// block size, …) — stored in every record so a store opened against a
/// different model/config treats foreign records as misses instead of
/// importing bytes into the wrong shape.
pub fn shape_fingerprint(dims: &[usize]) -> u64 {
    let mut h: u64 = u64::from_le_bytes(*SEGMENT_MAGIC);
    for &d in dims {
        h ^= d as u64;
        h = h.wrapping_add(0x9E37_79B9_7F4A_7C15);
        h = (h ^ (h >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        h = (h ^ (h >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        h ^= h >> 31;
    }
    h
}

/// Record dtype tag for a [`KvCacheDtype`].
pub fn dtype_tag(dtype: KvCacheDtype) -> u8 {
    match dtype {
        KvCacheDtype::F32 => 0,
        KvCacheDtype::Q8 => 1,
    }
}

/// Typed spill-tier failure. Every variant is a *degradation*, not an
/// abort: callers fall back to recompute-on-miss.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SpillError {
    /// The store directory / segment files could not be opened.
    OpenFailed(String),
    /// An underlying read/write/flush failed.
    Io(String),
    /// A write landed only a prefix of its bytes (kill mid-append).
    ShortWrite { written: usize, expected: usize },
    /// The filesystem is out of space.
    NoSpace,
    /// A record's CRC did not verify at read; it is now quarantined.
    ChecksumMismatch { hash: u64 },
    /// The record was quarantined by an earlier checksum failure.
    Quarantined { hash: u64 },
    /// The record's dtype/shape fingerprint does not match this pool.
    ShapeMismatch { hash: u64 },
    /// No record under this hash.
    Missing { hash: u64 },
    /// The self-disabling circuit is open (or the tier was never live).
    Disabled,
}

impl std::fmt::Display for SpillError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SpillError::OpenFailed(e) => write!(f, "spill open failed: {e}"),
            SpillError::Io(e) => write!(f, "spill io error: {e}"),
            SpillError::ShortWrite { written, expected } => {
                write!(f, "spill short write: {written} of {expected} bytes")
            }
            SpillError::NoSpace => write!(f, "spill device out of space"),
            SpillError::ChecksumMismatch { hash } => {
                write!(f, "spill record {hash:#018x} failed checksum (quarantined)")
            }
            SpillError::Quarantined { hash } => {
                write!(f, "spill record {hash:#018x} is quarantined")
            }
            SpillError::ShapeMismatch { hash } => {
                write!(f, "spill record {hash:#018x} has a foreign dtype/shape")
            }
            SpillError::Missing { hash } => write!(f, "spill record {hash:#018x} not found"),
            SpillError::Disabled => write!(f, "spill tier is disabled"),
        }
    }
}

impl std::error::Error for SpillError {}

/// Spill-tier configuration (`EngineConfig::spill`; **off by default** —
/// the engine only builds a tier when this is `Some`, so the dense
/// default baseline never touches the filesystem).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpillConfig {
    /// Directory holding the segment files (created if absent).
    pub dir: PathBuf,
    /// Total on-disk budget; crossing it reclaims the oldest closed
    /// segment (the tier is a bounded cache, not an unbounded log).
    pub cap_bytes: u64,
    /// Segment rotation size: an active segment at or beyond this many
    /// bytes is closed and a fresh one started (reclamation granularity).
    pub segment_bytes: u64,
    /// Consecutive live IO failures before the tier disables itself.
    pub max_consecutive_io_failures: u32,
}

impl SpillConfig {
    pub fn new(dir: impl Into<PathBuf>) -> SpillConfig {
        SpillConfig {
            dir: dir.into(),
            cap_bytes: 256 << 20,
            segment_bytes: 8 << 20,
            max_consecutive_io_failures: 3,
        }
    }

    /// Builder: override the capacity cap.
    pub fn with_cap_bytes(mut self, cap: u64) -> SpillConfig {
        self.cap_bytes = cap;
        self
    }

    /// Builder: override the segment rotation size.
    pub fn with_segment_bytes(mut self, seg: u64) -> SpillConfig {
        self.segment_bytes = seg;
        self
    }
}

/// Observability counters (all monotonic since open, except `records`).
///
/// The engine mirrors these into the telemetry registry once per step
/// (`spill_hit_tokens`, `spill_bytes`, `spill_corrupt_records`,
/// `spill_records`, `spill_disk_bytes`, `spill_io_failures` on
/// `GET /metrics`), so dashboards see the tier's health without any
/// extra instrumentation inside the IO paths themselves — the same
/// coordinator-layer-only placement rule attention kernels follow
/// (ARCHITECTURE.md "Observability contract").
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SpillStats {
    /// Records currently indexed (restorable).
    pub records: usize,
    /// Record bytes appended since open (headers + payloads + CRCs).
    pub bytes_written: u64,
    /// Blocks restored into a pool (`restore_into` successes).
    pub restored_blocks: usize,
    /// Records quarantined by a checksum failure at read.
    pub corrupt_records: usize,
    /// Live IO failures observed (reads + writes).
    pub io_failures: usize,
    /// Closed segments reclaimed by the capacity cap.
    pub reclaimed_segments: usize,
    /// Records re-indexed by the open-time recovery scan.
    pub recovered_records: usize,
    /// Torn-tail bytes truncated by the open-time recovery scan.
    pub truncated_tail_bytes: u64,
}

#[derive(Debug, Clone, Copy)]
struct RecordLoc {
    seg: u64,
    off: u64,
    payload_len: u32,
}

#[derive(Debug)]
struct Segment {
    idx: u64,
    path: PathBuf,
    /// Commit frontier: bytes known fully written, flushed and
    /// CRC-valid. Advanced only after a successful append + flush.
    committed: u64,
}

/// The crash-safe on-disk store. See the module docs for the format and
/// the crash-safety argument.
#[derive(Debug)]
pub struct SpillTier {
    cfg: SpillConfig,
    dtype: u8,
    shape_fp: u64,
    /// Sorted by `idx`; the last entry is the active (append) segment.
    segments: Vec<Segment>,
    /// Write handle on the active segment.
    active: File,
    index: HashMap<u64, RecordLoc>,
    /// Hashes whose records failed CRC at read — never consulted again.
    quarantined: HashSet<u64>,
    stats: SpillStats,
    consecutive_io_failures: u32,
    disabled: bool,
    #[cfg(any(test, feature = "fault-inject"))]
    io_faults: Option<IoFaultInjector>,
}

impl SpillTier {
    /// Open (or create) the store at `cfg.dir` for a pool of the given
    /// dtype/shape, running the recovery scan over every existing
    /// segment: CRC-valid records are re-indexed, the first torn or
    /// corrupt record and everything after it is truncated away, and
    /// records from a different dtype/shape are ignored. Only
    /// environmental failures (unreadable directory, unopenable files)
    /// error — torn state never does.
    pub fn open(cfg: SpillConfig, dtype: u8, shape_fp: u64) -> Result<SpillTier, SpillError> {
        std::fs::create_dir_all(&cfg.dir)
            .map_err(|e| SpillError::OpenFailed(format!("create {:?}: {e}", cfg.dir)))?;
        let mut found: Vec<(u64, PathBuf)> = Vec::new();
        let entries = std::fs::read_dir(&cfg.dir)
            .map_err(|e| SpillError::OpenFailed(format!("read {:?}: {e}", cfg.dir)))?;
        for entry in entries.flatten() {
            let path = entry.path();
            if let Some(idx) = segment_index(&path) {
                found.push((idx, path));
            }
        }
        found.sort_by_key(|(idx, _)| *idx);

        let mut segments = Vec::new();
        let mut index = HashMap::new();
        let mut stats = SpillStats::default();
        for (idx, path) in found {
            match recover_segment(&path, dtype, shape_fp, idx, &mut index, &mut stats) {
                Some(committed) => segments.push(Segment { idx, path, committed }),
                // Unreadable / headerless / foreign file under our
                // naming scheme: discard rather than misparse.
                None => {
                    let _ = std::fs::remove_file(&path);
                }
            }
        }
        if segments.is_empty() {
            let path = cfg.dir.join(segment_name(0));
            let mut f = OpenOptions::new()
                .read(true)
                .write(true)
                .create(true)
                .truncate(true)
                .open(&path)
                .map_err(|e| SpillError::OpenFailed(format!("create {path:?}: {e}")))?;
            f.write_all(SEGMENT_MAGIC)
                .and_then(|_| f.flush())
                .map_err(|e| SpillError::OpenFailed(format!("init {path:?}: {e}")))?;
            segments.push(Segment { idx: 0, path, committed: SEGMENT_MAGIC.len() as u64 });
        }
        let active_path = &segments.last().expect("at least one segment").path;
        let active = OpenOptions::new()
            .read(true)
            .write(true)
            .open(active_path)
            .map_err(|e| SpillError::OpenFailed(format!("open {active_path:?}: {e}")))?;
        stats.records = index.len();
        Ok(SpillTier {
            cfg,
            dtype,
            shape_fp,
            segments,
            active,
            index,
            quarantined: HashSet::new(),
            stats,
            consecutive_io_failures: 0,
            disabled: false,
            #[cfg(any(test, feature = "fault-inject"))]
            io_faults: None,
        })
    }

    /// [`SpillTier::open`] under an IO fault injector (test/chaos
    /// builds): a `fail_open` plan fails here, before any disk state is
    /// touched; otherwise the injector is armed on the opened tier.
    #[cfg(any(test, feature = "fault-inject"))]
    pub fn open_faulted(
        cfg: SpillConfig,
        dtype: u8,
        shape_fp: u64,
        faults: IoFaultInjector,
    ) -> Result<SpillTier, SpillError> {
        if faults.fail_open() {
            return Err(SpillError::OpenFailed("injected open failure".to_string()));
        }
        let mut tier = SpillTier::open(cfg, dtype, shape_fp)?;
        tier.io_faults = Some(faults);
        Ok(tier)
    }

    /// Arm an IO fault injector on a live tier (test/chaos builds).
    #[cfg(any(test, feature = "fault-inject"))]
    pub fn arm_io_faults(&mut self, faults: IoFaultInjector) {
        self.io_faults = Some(faults);
    }

    /// Is the tier live (circuit closed)?
    pub fn enabled(&self) -> bool {
        !self.disabled
    }

    /// Observability counters.
    pub fn stats(&self) -> SpillStats {
        let mut s = self.stats;
        s.records = self.index.len();
        s
    }

    /// Restorable record count.
    pub fn records(&self) -> usize {
        self.index.len()
    }

    /// Committed bytes across all segments (magic headers included).
    pub fn total_bytes(&self) -> u64 {
        self.segments.iter().map(|s| s.committed).sum()
    }

    /// Is `hash` restorable right now? (Indexed, not quarantined, tier
    /// live.) The admission path gates restore attempts on this.
    pub fn contains(&self, hash: u64) -> bool {
        !self.disabled && self.index.contains_key(&hash)
    }

    /// Offer an evicted block's exact bytes under `hash`. Returns
    /// `Ok(true)` when a record was durably appended (commit frontier
    /// advanced), `Ok(false)` when skipped (duplicate, quarantined hash,
    /// or tier disabled), `Err` on an IO failure — after which the store
    /// is back at its commit frontier (live errors repair by
    /// truncation; a short write models a crash and disables the tier,
    /// leaving the torn tail for the next open's recovery scan).
    pub fn offer(&mut self, hash: u64, payload: &[u8]) -> Result<bool, SpillError> {
        if self.disabled {
            return Ok(false);
        }
        if self.index.contains_key(&hash) || self.quarantined.contains(&hash) {
            return Ok(false);
        }
        match self.append_record(hash, payload) {
            Ok(()) => {
                self.consecutive_io_failures = 0;
                Ok(true)
            }
            Err(e) => {
                self.note_io_failure(&e);
                Err(e)
            }
        }
    }

    /// Read back the payload stored under `hash`, CRC re-verified at
    /// read time. A checksum failure quarantines the record (it will
    /// never be consulted again) and reports `ChecksumMismatch`; the
    /// caller falls back to recompute.
    pub fn restore(&mut self, hash: u64) -> Result<Vec<u8>, SpillError> {
        if self.disabled {
            return Err(SpillError::Disabled);
        }
        if self.quarantined.contains(&hash) {
            return Err(SpillError::Quarantined { hash });
        }
        let Some(loc) = self.index.get(&hash).copied() else {
            return Err(SpillError::Missing { hash });
        };
        let Some(seg) = self.segments.iter().find(|s| s.idx == loc.seg) else {
            return Err(SpillError::Missing { hash });
        };
        let total = RECORD_HEADER_BYTES + loc.payload_len as usize + RECORD_TRAILER_BYTES;
        let mut buf = vec![0u8; total];
        let read = File::open(&seg.path).and_then(|mut f| {
            f.seek(SeekFrom::Start(loc.off))?;
            f.read_exact(&mut buf)
        });
        if let Err(e) = read {
            let err = SpillError::Io(e.to_string());
            self.note_io_failure(&err);
            return Err(err);
        }
        #[cfg(any(test, feature = "fault-inject"))]
        if let Some(inj) = &self.io_faults {
            inj.corrupt_read(&mut buf);
        }
        let crc_off = RECORD_HEADER_BYTES + loc.payload_len as usize;
        let stored = u32::from_le_bytes(buf[crc_off..crc_off + 4].try_into().unwrap());
        if crc32(&[&buf[..crc_off]]) != stored {
            self.index.remove(&hash);
            self.quarantined.insert(hash);
            self.stats.corrupt_records += 1;
            return Err(SpillError::ChecksumMismatch { hash });
        }
        let rhash = u64::from_le_bytes(buf[4..12].try_into().unwrap());
        let rdtype = buf[12];
        let rfp = u64::from_le_bytes(buf[13..21].try_into().unwrap());
        if rhash != hash || rdtype != self.dtype || rfp != self.shape_fp {
            return Err(SpillError::ShapeMismatch { hash });
        }
        self.consecutive_io_failures = 0;
        Ok(buf[RECORD_HEADER_BYTES..crc_off].to_vec())
    }

    /// Restore the record under `hash` straight into `block` of `cache`
    /// — the admission-path entry point. The payload is the pool's own
    /// exact bytes, so a successful restore leaves the block
    /// bit-identical to the one that was evicted.
    pub fn restore_into(
        &mut self,
        hash: u64,
        cache: &mut dyn KvStore,
        block: BlockId,
    ) -> Result<(), SpillError> {
        let bytes = self.restore(hash)?;
        if !cache.import_block(block, &bytes) {
            return Err(SpillError::ShapeMismatch { hash });
        }
        self.stats.restored_blocks += 1;
        Ok(())
    }

    /// Flush the active segment and sync it to the device — the
    /// shutdown-path barrier (graceful drain calls this before exit so
    /// the commit frontier is durable).
    pub fn flush(&mut self) -> Result<(), SpillError> {
        if self.disabled {
            return Ok(());
        }
        self.active
            .flush()
            .and_then(|_| self.active.sync_all())
            .map_err(|e| SpillError::Io(e.to_string()))
    }

    // ---- internals -----------------------------------------------------

    fn append_record(&mut self, hash: u64, payload: &[u8]) -> Result<(), SpillError> {
        self.rotate_if_needed()?;
        let rec_len = (RECORD_HEADER_BYTES + payload.len() + RECORD_TRAILER_BYTES) as u64;
        self.reclaim_if_needed(rec_len);

        let mut rec = Vec::with_capacity(rec_len as usize);
        rec.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        rec.extend_from_slice(&hash.to_le_bytes());
        rec.push(self.dtype);
        rec.extend_from_slice(&self.shape_fp.to_le_bytes());
        rec.extend_from_slice(payload);
        let crc = crc32(&[&rec]);
        rec.extend_from_slice(&crc.to_le_bytes());

        let (seg_idx, off) = {
            let seg = self.segments.last().expect("active segment");
            (seg.idx, seg.committed)
        };
        self.active
            .seek(SeekFrom::Start(off))
            .map_err(|e| SpillError::Io(e.to_string()))?;
        self.write_buf(&rec)?;
        self.active.flush().map_err(|e| SpillError::Io(e.to_string()))?;
        // Success: advance the commit frontier and index the record.
        let seg = self.segments.last_mut().expect("active segment");
        seg.committed += rec.len() as u64;
        self.stats.bytes_written += rec.len() as u64;
        self.index
            .insert(hash, RecordLoc { seg: seg_idx, off, payload_len: payload.len() as u32 });
        Ok(())
    }

    /// Write `buf` through the (possibly fault-injected) device. On an
    /// injected short write / ENOSPC the allowed prefix really lands in
    /// the file — exactly the bytes a kill or a full disk would leave —
    /// and the matching typed error is returned.
    fn write_buf(&mut self, buf: &[u8]) -> Result<(), SpillError> {
        #[cfg(any(test, feature = "fault-inject"))]
        if let Some(inj) = &self.io_faults {
            match inj.write_outcome(buf.len()) {
                IoWriteFault::Short(n) => {
                    let _ = self.active.write_all(&buf[..n]).and_then(|_| self.active.flush());
                    return Err(SpillError::ShortWrite { written: n, expected: buf.len() });
                }
                IoWriteFault::Enospc(n) => {
                    let _ = self.active.write_all(&buf[..n]).and_then(|_| self.active.flush());
                    return Err(SpillError::NoSpace);
                }
                IoWriteFault::None => {}
            }
        }
        self.active.write_all(buf).map_err(|e| SpillError::Io(e.to_string()))
    }

    /// Close the active segment and start a fresh one once it reaches
    /// the rotation size.
    fn rotate_if_needed(&mut self) -> Result<(), SpillError> {
        let last = self.segments.last().expect("active segment");
        if last.committed < self.cfg.segment_bytes {
            return Ok(());
        }
        let idx = last.idx + 1;
        let path = self.cfg.dir.join(segment_name(idx));
        let mut f = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)
            .map_err(|e| SpillError::Io(format!("rotate to {path:?}: {e}")))?;
        f.write_all(SEGMENT_MAGIC)
            .and_then(|_| f.flush())
            .map_err(|e| SpillError::Io(format!("init {path:?}: {e}")))?;
        self.active = f;
        self.segments.push(Segment { idx, path, committed: SEGMENT_MAGIC.len() as u64 });
        Ok(())
    }

    /// Reclaim oldest closed segments until `incoming` more bytes fit
    /// under the capacity cap (the active segment is never reclaimed).
    fn reclaim_if_needed(&mut self, incoming: u64) {
        while self.segments.len() > 1 && self.total_bytes() + incoming > self.cfg.cap_bytes {
            let old = self.segments.remove(0);
            let _ = std::fs::remove_file(&old.path);
            self.index.retain(|_, loc| loc.seg != old.idx);
            self.stats.reclaimed_segments += 1;
        }
    }

    /// Account a live IO failure and drive the degradation ladder: a
    /// short write models a kill (tier off immediately, torn tail left
    /// for recovery); other failures repair the file back to the commit
    /// frontier and open the circuit after N consecutive ones.
    fn note_io_failure(&mut self, e: &SpillError) {
        self.stats.io_failures += 1;
        match e {
            SpillError::ShortWrite { .. } => {
                self.disabled = true;
            }
            _ => {
                let committed = self.segments.last().expect("active segment").committed;
                let _ = self.active.set_len(committed);
                self.consecutive_io_failures += 1;
                if self.consecutive_io_failures >= self.cfg.max_consecutive_io_failures {
                    self.disabled = true;
                }
            }
        }
    }
}

fn segment_name(idx: u64) -> String {
    format!("seg-{idx:08}.ogptqs")
}

/// Parse a segment index out of a `seg-NNNNNNNN.ogptqs` file name.
fn segment_index(path: &Path) -> Option<u64> {
    let name = path.file_name()?.to_str()?;
    let rest = name.strip_prefix("seg-")?.strip_suffix(".ogptqs")?;
    rest.parse().ok()
}

/// Scan one segment: index every CRC-valid record matching
/// `(dtype, shape_fp)`, stop at the first incomplete or corrupt record
/// and truncate the tail there. Returns the recovered commit frontier,
/// or `None` when the file is unreadable or headerless (caller
/// discards it).
fn recover_segment(
    path: &Path,
    dtype: u8,
    shape_fp: u64,
    idx: u64,
    index: &mut HashMap<u64, RecordLoc>,
    stats: &mut SpillStats,
) -> Option<u64> {
    let mut buf = Vec::new();
    File::open(path).and_then(|mut f| f.read_to_end(&mut buf)).ok()?;
    if buf.len() < SEGMENT_MAGIC.len() || &buf[..SEGMENT_MAGIC.len()] != SEGMENT_MAGIC {
        return None;
    }
    let mut off = SEGMENT_MAGIC.len();
    loop {
        if off + RECORD_HEADER_BYTES + RECORD_TRAILER_BYTES > buf.len() {
            break;
        }
        let plen = u32::from_le_bytes(buf[off..off + 4].try_into().unwrap()) as usize;
        let total = RECORD_HEADER_BYTES + plen + RECORD_TRAILER_BYTES;
        if off + total > buf.len() {
            break; // torn mid-payload
        }
        let crc_off = off + RECORD_HEADER_BYTES + plen;
        let stored = u32::from_le_bytes(buf[crc_off..crc_off + 4].try_into().unwrap());
        if crc32(&[&buf[off..crc_off]]) != stored {
            break; // torn or corrupt: truncate from here
        }
        let hash = u64::from_le_bytes(buf[off + 4..off + 12].try_into().unwrap());
        let rdtype = buf[off + 12];
        let rfp = u64::from_le_bytes(buf[off + 13..off + 21].try_into().unwrap());
        if rdtype == dtype && rfp == shape_fp {
            // Later duplicates win (a reclaimed-then-respilled hash).
            index.insert(hash, RecordLoc { seg: idx, off: off as u64, payload_len: plen as u32 });
            stats.recovered_records += 1;
        }
        off += total;
    }
    if off < buf.len() {
        stats.truncated_tail_bytes += (buf.len() - off) as u64;
        OpenOptions::new().write(true).open(path).and_then(|f| f.set_len(off as u64)).ok()?;
    }
    Some(off as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::{PagedKvCache, QuantizedPagedKvCache};
    use crate::runtime::fault::IoFaultPlan;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("opt_gptq_spill_{name}"));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn cfg(name: &str) -> SpillConfig {
        SpillConfig::new(tmp(name))
    }

    fn payload(seed: u8, len: usize) -> Vec<u8> {
        (0..len).map(|i| seed.wrapping_add(i as u8).wrapping_mul(31)).collect()
    }

    #[test]
    fn crc32_known_vector() {
        // The standard IEEE check value.
        assert_eq!(crc32(&[b"123456789"]), 0xCBF4_3926);
        // Streaming over parts equals one pass.
        assert_eq!(crc32(&[b"1234", b"56789"]), 0xCBF4_3926);
    }

    #[test]
    fn offer_restore_roundtrip_and_dedup() {
        let mut t = SpillTier::open(cfg("roundtrip"), 1, 77).unwrap();
        let p = payload(3, 200);
        assert!(t.offer(0xAB, &p).unwrap());
        assert!(!t.offer(0xAB, &p).unwrap(), "duplicate hash is skipped");
        assert!(t.contains(0xAB));
        assert!(!t.contains(0xCD));
        assert_eq!(t.restore(0xAB).unwrap(), p);
        assert_eq!(t.restore(0xCD), Err(SpillError::Missing { hash: 0xCD }));
        assert_eq!(t.records(), 1);
        let _ = std::fs::remove_dir_all(&t.cfg.dir);
    }

    #[test]
    fn reopen_recovers_committed_records() {
        let dir = tmp("reopen");
        let c = SpillConfig::new(&dir);
        let ps: Vec<Vec<u8>> = (0..5).map(|i| payload(i as u8, 64 + i * 7)).collect();
        {
            let mut t = SpillTier::open(c.clone(), 0, 9).unwrap();
            for (i, p) in ps.iter().enumerate() {
                assert!(t.offer(i as u64, p).unwrap());
            }
            t.flush().unwrap();
        }
        let mut t = SpillTier::open(c, 0, 9).unwrap();
        assert_eq!(t.stats().recovered_records, 5);
        assert_eq!(t.stats().truncated_tail_bytes, 0);
        for (i, p) in ps.iter().enumerate() {
            assert_eq!(&t.restore(i as u64).unwrap(), p, "record {i}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn kill_mid_write_reopens_with_torn_tail_truncated() {
        let dir = tmp("torn");
        let c = SpillConfig::new(&dir);
        let good: Vec<Vec<u8>> = (0..3).map(|i| payload(10 + i as u8, 128)).collect();
        let committed_before;
        {
            let mut t = SpillTier::open(c.clone(), 1, 5).unwrap();
            for (i, p) in good.iter().enumerate() {
                assert!(t.offer(i as u64, p).unwrap());
            }
            committed_before = t.total_bytes();
            // Write call 0 after arming = the 4th offer: killed mid-record.
            t.arm_io_faults(IoFaultPlan::new(42).short_write_at(0).injector());
            let err = t.offer(99, &payload(9, 128)).unwrap_err();
            assert!(matches!(err, SpillError::ShortWrite { .. }));
            assert!(!t.enabled(), "a kill-model short write disables the tier");
            assert!(!t.contains(99), "torn record is never indexed");
            // The torn tail is really on disk (the crash left it there).
            let len = std::fs::metadata(dir.join("seg-00000000.ogptqs")).unwrap().len();
            assert!(len > committed_before, "torn bytes beyond the frontier");
        }
        // "Restart": recovery scan must truncate the torn tail and serve
        // every surviving record, each CRC-verified.
        let mut t = SpillTier::open(c, 1, 5).unwrap();
        assert_eq!(t.stats().recovered_records, 3);
        assert!(t.stats().truncated_tail_bytes > 0, "torn tail was truncated");
        assert_eq!(t.total_bytes(), committed_before, "frontier re-derived exactly");
        for (i, p) in good.iter().enumerate() {
            assert_eq!(&t.restore(i as u64).unwrap(), p, "surviving record {i}");
        }
        assert!(!t.contains(99), "the torn record does not exist after recovery");
        // The store keeps working after recovery.
        assert!(t.offer(99, &payload(9, 128)).unwrap());
        assert_eq!(t.restore(99).unwrap(), payload(9, 128));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_read_quarantines_record() {
        let dir = tmp("corrupt");
        let mut t = SpillTier::open(SpillConfig::new(&dir), 1, 5).unwrap();
        let p = payload(7, 256);
        assert!(t.offer(0x11, &p).unwrap());
        assert!(t.offer(0x22, &p).unwrap());
        t.arm_io_faults(IoFaultPlan::new(8).corrupt_read_bit(0).injector());
        // Read 0: one flipped bit → checksum mismatch → quarantine.
        assert_eq!(t.restore(0x11), Err(SpillError::ChecksumMismatch { hash: 0x11 }));
        assert_eq!(t.stats().corrupt_records, 1);
        assert!(!t.contains(0x11), "quarantined record leaves the index");
        assert_eq!(t.restore(0x11), Err(SpillError::Quarantined { hash: 0x11 }));
        // Other records are untouched, and the tier stays enabled:
        // corruption is a data loss, not a device failure.
        assert!(t.enabled());
        assert_eq!(t.restore(0x22).unwrap(), p);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn enospc_repairs_to_frontier_and_circuit_opens() {
        let dir = tmp("enospc");
        let c = SpillConfig::new(&dir);
        let mut t = SpillTier::open(c.clone(), 0, 1).unwrap();
        let p = payload(1, 300);
        assert!(t.offer(1, &p).unwrap());
        let frontier = t.total_bytes();
        // Budget already spent: every further write gets ENOSPC.
        t.arm_io_faults(IoFaultPlan::new(0).enospc_after_bytes(0).injector());
        for i in 0..c.max_consecutive_io_failures {
            let enabled_before = t.enabled();
            assert!(enabled_before, "circuit must still be closed before failure {i}");
            assert_eq!(t.offer(100 + i as u64, &p), Err(SpillError::NoSpace));
            // Live failure: the file is repaired back to the frontier.
            let len = std::fs::metadata(dir.join("seg-00000000.ogptqs")).unwrap().len();
            assert_eq!(len, frontier, "repair after failure {i}");
        }
        assert!(!t.enabled(), "circuit opens after max consecutive failures");
        assert_eq!(t.stats().io_failures, 3);
        // Disabled tier: offers are silently skipped, restores refuse.
        assert_eq!(t.offer(200, &p), Ok(false));
        assert_eq!(t.restore(1), Err(SpillError::Disabled));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn success_resets_the_failure_streak() {
        let dir = tmp("streak");
        let mut t = SpillTier::open(SpillConfig::new(&dir), 0, 1).unwrap();
        let p = payload(2, 100);
        // Two failures, then unlimited budget again.
        t.arm_io_faults(IoFaultPlan::new(0).enospc_after_bytes(0).injector());
        assert_eq!(t.offer(1, &p), Err(SpillError::NoSpace));
        assert_eq!(t.offer(2, &p), Err(SpillError::NoSpace));
        t.arm_io_faults(IoFaultPlan::new(0).injector());
        assert!(t.offer(3, &p).unwrap(), "healthy write succeeds");
        // The streak reset: two more failures do not trip the circuit.
        t.arm_io_faults(IoFaultPlan::new(0).enospc_after_bytes(0).injector());
        assert_eq!(t.offer(4, &p), Err(SpillError::NoSpace));
        assert_eq!(t.offer(5, &p), Err(SpillError::NoSpace));
        assert!(t.enabled(), "streak was reset by the success");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fail_open_is_a_typed_error() {
        let err = SpillTier::open_faulted(
            cfg("failopen"),
            0,
            1,
            IoFaultPlan::new(0).fail_open().injector(),
        )
        .unwrap_err();
        assert!(matches!(err, SpillError::OpenFailed(_)));
    }

    #[test]
    fn capacity_cap_reclaims_oldest_segment() {
        let dir = tmp("reclaim");
        // Tiny geometry: each record ≈ 21 + 64 + 4 = 89 bytes; rotate
        // every 100 bytes, cap at 400 → old segments must be deleted.
        let c = SpillConfig::new(&dir).with_segment_bytes(100).with_cap_bytes(400);
        let mut t = SpillTier::open(c, 0, 1).unwrap();
        for i in 0..8u64 {
            assert!(t.offer(i, &payload(i as u8, 64)).unwrap());
        }
        assert!(t.stats().reclaimed_segments > 0, "cap must reclaim");
        assert!(t.total_bytes() <= 400 + 89 + 8, "bounded near the cap");
        // Newest records survive, oldest were reclaimed with their segment.
        assert!(t.contains(7));
        assert!(!t.contains(0), "oldest record reclaimed");
        assert_eq!(t.restore(7).unwrap(), payload(7, 64));
        // A reclaimed hash can be re-offered (it is a miss now).
        assert!(t.offer(0, &payload(0, 64)).unwrap());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn foreign_shape_records_are_ignored_at_recovery() {
        let dir = tmp("foreign");
        let c = SpillConfig::new(&dir);
        {
            let mut t = SpillTier::open(c.clone(), 0, 111).unwrap();
            assert!(t.offer(1, &payload(1, 50)).unwrap());
        }
        // Same dir, different shape fingerprint: the record is a miss,
        // not an import into the wrong geometry.
        let t = SpillTier::open(c.clone(), 0, 222).unwrap();
        assert!(!t.contains(1));
        assert_eq!(t.stats().recovered_records, 0);
        // And the original shape still sees it.
        let mut t = SpillTier::open(c, 0, 111).unwrap();
        assert_eq!(t.restore(1).unwrap(), payload(1, 50));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn restore_into_is_bit_identical_for_both_pools() {
        let dir = tmp("into");
        // f32 pool.
        let mut f32_pool = PagedKvCache::new(2, 4, 4, 2, 3);
        for s in 0..4 {
            f32_pool.write_token(0, 1, s, &[s as f32 * 0.3 - 1.0; 6], &[s as f32; 6]);
        }
        let fp = shape_fingerprint(&[2, 4, 4, 2, 3, 0]);
        let mut t = SpillTier::open(SpillConfig::new(dir.join("f32")), 0, fp).unwrap();
        assert!(t.offer(7, &f32_pool.export_block(1)).unwrap());
        let mut restored = PagedKvCache::new(2, 4, 4, 2, 3);
        t.restore_into(7, &mut restored, 2).unwrap();
        for layer in 0..2 {
            assert_eq!(f32_pool.key_block(layer, 1), restored.key_block(layer, 2));
            assert_eq!(f32_pool.value_block(layer, 1), restored.value_block(layer, 2));
        }
        assert_eq!(t.stats().restored_blocks, 1);
        // q8 pool: levels move as levels — raw words identical.
        let mut q8_pool = QuantizedPagedKvCache::new(1, 4, 4, 2, 4);
        for s in 0..4 {
            q8_pool.write_token(0, 0, s, &[0.1 * s as f32; 8], &[-0.2 * s as f32; 8]);
        }
        let qfp = shape_fingerprint(&[1, 4, 4, 2, 4, 1]);
        let mut tq = SpillTier::open(SpillConfig::new(dir.join("q8")), 1, qfp).unwrap();
        assert!(tq.offer(8, &q8_pool.export_block(0)).unwrap());
        let mut qrestored = QuantizedPagedKvCache::new(1, 4, 4, 2, 4);
        tq.restore_into(8, &mut qrestored, 3).unwrap();
        let (sk, sv) = q8_pool.block_tiles(0, 0);
        let (rk, rv) = qrestored.block_tiles(0, 3);
        assert_eq!(sk.words, rk.words);
        assert_eq!(sk.scales, rk.scales);
        assert_eq!(sv.words, rv.words);
        assert_eq!(sv.zeros, rv.zeros);
        // A wrong-geometry pool refuses the import as a shape mismatch.
        let mut wrong = PagedKvCache::new(1, 4, 4, 2, 3);
        assert_eq!(
            t.restore_into(7, &mut wrong, 0),
            Err(SpillError::ShapeMismatch { hash: 7 })
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn shape_fingerprint_is_order_sensitive() {
        assert_ne!(shape_fingerprint(&[1, 2]), shape_fingerprint(&[2, 1]));
        assert_eq!(shape_fingerprint(&[1, 2]), shape_fingerprint(&[1, 2]));
        assert_ne!(shape_fingerprint(&[]), shape_fingerprint(&[0]));
    }
}
