//! ALiBi: Attention with Linear Biases (Press et al.), as integrated by
//! the paper (§III.A): a per-head linear penalty `-m_h · (i − j)` added to
//! attention scores in place of an explicit causal-mask tensor.

/// Per-head ALiBi slopes.
///
/// For `n` a power of two: `m_h = 2^(−8·(h+1)/n)`. For other `n`, the
/// original recipe: take the slopes for the next-lower power of two, then
/// interleave slopes from the `2n` sequence for the remainder.
pub fn alibi_slopes(num_heads: usize) -> Vec<f32> {
    fn pow2_slopes(n: usize) -> Vec<f32> {
        let start = 2.0f64.powf(-8.0 / n as f64);
        (0..n).map(|i| (start.powi(i as i32 + 1)) as f32).collect()
    }
    assert!(num_heads > 0);
    if num_heads.is_power_of_two() {
        pow2_slopes(num_heads)
    } else {
        let base = num_heads.next_power_of_two() / 2;
        let mut slopes = pow2_slopes(base);
        let extra = pow2_slopes(2 * base);
        // Odd-indexed slopes of the doubled sequence fill the remainder.
        slopes.extend(extra.iter().step_by(2).take(num_heads - base));
        slopes
    }
}

/// The ALiBi bias for a (query position, key position) pair under head
/// slope `m`: `−m · (i − j)` for `j ≤ i` (0 at the diagonal, growing
/// penalty with distance). Callers handle causality (`j > i` excluded by
/// loop bounds, never by materializing a mask — that is the point).
///
/// This is the scalar *reference* form. The hot paths no longer call it
/// per element: along a KV tile the bias is an arithmetic progression,
/// so [`super::kernel`] folds it into the score pass as one add per
/// slot (`bias += slope`).
#[inline]
pub fn alibi_bias(slope: f32, q_pos: usize, k_pos: usize) -> f32 {
    debug_assert!(k_pos <= q_pos);
    -slope * (q_pos - k_pos) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_of_two_slopes_match_paper() {
        // For 8 heads: 2^-1, 2^-2, …, 2^-8.
        let s = alibi_slopes(8);
        for (i, &v) in s.iter().enumerate() {
            assert!((v - 2.0f32.powi(-(i as i32 + 1))).abs() < 1e-7, "head {i}");
        }
    }

    #[test]
    fn slopes_positive_and_distinct() {
        for n in [1, 2, 3, 5, 8, 12, 16, 20] {
            let s = alibi_slopes(n);
            assert_eq!(s.len(), n);
            assert!(s.iter().all(|&v| v > 0.0 && v < 1.0), "n={n}");
            // All slopes distinct (the non-power-of-two interleave is not
            // monotonic — faithful to the original ALiBi recipe).
            let mut sorted = s.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            sorted.dedup();
            assert_eq!(sorted.len(), n, "n={n}: slopes must be distinct");
        }
        // Power-of-two sets are geometric, hence strictly decreasing.
        for n in [2, 4, 8, 16] {
            let s = alibi_slopes(n);
            for w in s.windows(2) {
                assert!(w[1] < w[0], "n={n}");
            }
        }
    }

    #[test]
    fn non_power_of_two_prefix_matches_lower_power() {
        // First base slopes equal the power-of-two sequence.
        let s12 = alibi_slopes(12);
        let s8 = alibi_slopes(8);
        assert_eq!(&s12[..8], &s8[..]);
    }

    #[test]
    fn bias_zero_on_diagonal_and_monotonic() {
        let m = 0.25;
        assert_eq!(alibi_bias(m, 5, 5), 0.0);
        assert!(alibi_bias(m, 5, 4) > alibi_bias(m, 5, 0));
        assert_eq!(alibi_bias(m, 5, 3), -0.5);
    }
}
