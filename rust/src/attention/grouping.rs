//! Dynamic head grouping by activation similarity (paper §II.B).
//!
//! The paper's "Dynamic Grouping Optimization": measure cosine similarity
//! between query heads' activations and allocate similar heads to the same
//! KV group, "maximizing intra-group similarity while minimizing
//! inter-group differences". This module implements that as a greedy
//! balanced clustering over per-head activation statistics, plus the
//! MHA→GQA weight conversion (mean-pooling K/V heads within each group)
//! the grouping feeds.

use crate::util::rng::Rng;

/// Cosine similarity of two vectors (0 when either is zero).
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
    for (x, y) in a.iter().zip(b) {
        dot += (*x as f64) * (*y as f64);
        na += (*x as f64) * (*x as f64);
        nb += (*y as f64) * (*y as f64);
    }
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    (dot / (na.sqrt() * nb.sqrt())) as f32
}

/// Assign `num_heads` heads to `num_groups` equal-size groups, maximizing
/// intra-group cosine similarity of their activation signatures.
///
/// `signatures[h]` is head `h`'s activation statistic (e.g. its mean query
/// vector over a calibration batch). Greedy seeding + best-fit assignment:
/// k-means-style but with exact group-size balance, as GQA requires equal
/// groups. Returns `assignment[h] = group`.
pub fn group_heads_by_similarity(signatures: &[Vec<f32>], num_groups: usize) -> Vec<usize> {
    let h = signatures.len();
    assert!(num_groups > 0 && h % num_groups == 0, "heads must split evenly");
    let per_group = h / num_groups;

    // Seed: pick the most mutually-dissimilar heads as group anchors
    // (farthest-point heuristic, deterministic).
    let mut anchors = vec![0usize];
    while anchors.len() < num_groups {
        let next = (0..h)
            .filter(|i| !anchors.contains(i))
            .max_by(|&a, &b| {
                let da: f32 = anchors.iter().map(|&s| 1.0 - cosine(&signatures[a], &signatures[s])).sum();
                let db: f32 = anchors.iter().map(|&s| 1.0 - cosine(&signatures[b], &signatures[s])).sum();
                da.partial_cmp(&db).unwrap_or(std::cmp::Ordering::Equal)
            })
            .expect("heads remain");
        anchors.push(next);
    }

    // Best-fit: every head (most-confident first) goes to its most similar
    // anchor that still has room.
    let mut assignment = vec![usize::MAX; h];
    let mut capacity = vec![per_group; num_groups];
    // Order heads by their max anchor similarity, descending, so
    // clear-cut heads claim their group before capacity runs out.
    let mut order: Vec<usize> = (0..h).collect();
    let best_sim = |i: usize| -> f32 {
        anchors
            .iter()
            .map(|&a| cosine(&signatures[i], &signatures[a]))
            .fold(f32::NEG_INFINITY, f32::max)
    };
    order.sort_by(|&a, &b| best_sim(b).partial_cmp(&best_sim(a)).unwrap_or(std::cmp::Ordering::Equal));
    for i in order {
        let mut ranked: Vec<usize> = (0..num_groups).collect();
        ranked.sort_by(|&ga, &gb| {
            let sa = cosine(&signatures[i], &signatures[anchors[ga]]);
            let sb = cosine(&signatures[i], &signatures[anchors[gb]]);
            sb.partial_cmp(&sa).unwrap_or(std::cmp::Ordering::Equal)
        });
        for gr in ranked {
            if capacity[gr] > 0 {
                capacity[gr] -= 1;
                assignment[i] = gr;
                break;
            }
        }
    }
    debug_assert!(assignment.iter().all(|&g| g != usize::MAX));
    assignment
}

/// Mean intra-group cosine similarity under an assignment (the ablation-E
/// quality metric; higher is better).
pub fn intra_group_similarity(signatures: &[Vec<f32>], assignment: &[usize]) -> f32 {
    let mut total = 0.0f32;
    let mut pairs = 0usize;
    for i in 0..signatures.len() {
        for j in i + 1..signatures.len() {
            if assignment[i] == assignment[j] {
                total += cosine(&signatures[i], &signatures[j]);
                pairs += 1;
            }
        }
    }
    if pairs == 0 {
        0.0
    } else {
        total / pairs as f32
    }
}

/// Uniform (contiguous) grouping baseline: heads `[g·G, (g+1)·G)` → group g.
pub fn uniform_grouping(num_heads: usize, num_groups: usize) -> Vec<usize> {
    assert!(num_heads % num_groups == 0);
    let per = num_heads / num_groups;
    (0..num_heads).map(|h| h / per).collect()
}

/// Convert MHA K/V projection weights to GQA by mean-pooling each group's
/// heads (the standard MHA→GQA "uptraining-free" conversion, applied with
/// the dynamic assignment).
///
/// * `wk`: `[num_heads * head_dim, d_model]` (rows = output features)
/// * returns `[num_groups * head_dim, d_model]`
pub fn merge_kv_heads(
    wk: &[f32],
    num_heads: usize,
    head_dim: usize,
    d_model: usize,
    assignment: &[usize],
    num_groups: usize,
) -> Vec<f32> {
    assert_eq!(wk.len(), num_heads * head_dim * d_model);
    assert_eq!(assignment.len(), num_heads);
    let mut out = vec![0.0f32; num_groups * head_dim * d_model];
    let mut counts = vec![0usize; num_groups];
    for h in 0..num_heads {
        let g = assignment[h];
        counts[g] += 1;
        for r in 0..head_dim {
            let src = &wk[(h * head_dim + r) * d_model..(h * head_dim + r + 1) * d_model];
            let dst = &mut out[(g * head_dim + r) * d_model..(g * head_dim + r + 1) * d_model];
            for (d, s) in dst.iter_mut().zip(src) {
                *d += s;
            }
        }
    }
    for g in 0..num_groups {
        let inv = 1.0 / counts[g] as f32;
        for r in 0..head_dim {
            for v in &mut out[(g * head_dim + r) * d_model..(g * head_dim + r + 1) * d_model] {
                *v *= inv;
            }
        }
    }
    out
}

/// Synthetic per-head activation signatures with planted group structure
/// (test/bench helper): heads in the same planted cluster share a base
/// direction plus noise.
pub fn planted_signatures(
    num_heads: usize,
    num_groups: usize,
    dim: usize,
    noise: f32,
    seed: u64,
) -> (Vec<Vec<f32>>, Vec<usize>) {
    let mut rng = Rng::new(seed);
    let bases: Vec<Vec<f32>> = (0..num_groups).map(|_| rng.normal_vec(dim, 1.0)).collect();
    let per = num_heads / num_groups;
    let mut sigs = Vec::with_capacity(num_heads);
    let mut truth = Vec::with_capacity(num_heads);
    for h in 0..num_heads {
        let g = h % num_groups; // interleaved so uniform grouping is WRONG
        truth.push(g);
        let mut s = bases[g].clone();
        for v in &mut s {
            *v += noise * rng.normal_f32(0.0, 1.0);
        }
        sigs.push(s);
        let _ = per;
    }
    (sigs, truth)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cosine_basics() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-6);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-6);
        assert!((cosine(&[1.0, 0.0], &[-1.0, 0.0]) + 1.0).abs() < 1e-6);
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 1.0]), 0.0);
    }

    #[test]
    fn recovers_planted_clusters() {
        let (sigs, truth) = planted_signatures(8, 2, 16, 0.05, 7);
        let got = group_heads_by_similarity(&sigs, 2);
        // Same-cluster heads must share a label (labels may be permuted).
        for i in 0..8 {
            for j in 0..8 {
                assert_eq!(
                    truth[i] == truth[j],
                    got[i] == got[j],
                    "heads {i},{j}: truth {truth:?} got {got:?}"
                );
            }
        }
    }

    #[test]
    fn groups_are_balanced() {
        let (sigs, _) = planted_signatures(12, 3, 8, 0.5, 9);
        let got = group_heads_by_similarity(&sigs, 3);
        let mut counts = [0usize; 3];
        for &g in &got {
            counts[g] += 1;
        }
        assert_eq!(counts, [4, 4, 4]);
    }

    #[test]
    fn similarity_beats_uniform_on_interleaved_structure() {
        let (sigs, _) = planted_signatures(8, 2, 16, 0.1, 11);
        let dynamic = group_heads_by_similarity(&sigs, 2);
        let uniform = uniform_grouping(8, 2);
        let sd = intra_group_similarity(&sigs, &dynamic);
        let su = intra_group_similarity(&sigs, &uniform);
        assert!(sd > su, "dynamic {sd} !> uniform {su}");
    }

    #[test]
    fn merge_kv_heads_means_groups() {
        // 2 heads, head_dim 1, d_model 2, one group: output = mean of rows.
        let wk = vec![1.0, 2.0, 3.0, 4.0];
        let merged = merge_kv_heads(&wk, 2, 1, 2, &[0, 0], 1);
        assert_eq!(merged, vec![2.0, 3.0]);
    }

    #[test]
    fn merge_respects_assignment() {
        // 4 heads → 2 groups with interleaved assignment.
        let wk: Vec<f32> = (0..4).flat_map(|h| vec![h as f32; 3]).collect(); // head_dim 1, d_model 3
        let merged = merge_kv_heads(&wk, 4, 1, 3, &[0, 1, 0, 1], 2);
        assert_eq!(&merged[..3], &[1.0; 3]); // mean of heads 0,2
        assert_eq!(&merged[3..], &[2.0; 3]); // mean of heads 1,3
    }

    #[test]
    fn uniform_grouping_layout() {
        assert_eq!(uniform_grouping(8, 2), vec![0, 0, 0, 0, 1, 1, 1, 1]);
    }
}
