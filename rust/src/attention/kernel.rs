//! The block-tiled, group-major attention kernel core.
//!
//! Both native attention paths — contiguous prefill ([`super::gqa`]) and
//! paged decode ([`super::paged`]) — are thin drivers over this module.
//! The schedule is the one the paper's DCU kernel exploits (§II.C) and
//! the Pallas kernels mirror on TPU:
//!
//! * **Block tiling** — keys/values are consumed in fixed-size tiles
//!   (cache blocks on the paged path, [`KV_TILE`]-row chunks on the
//!   contiguous path) with a flash-style *online softmax*: running max,
//!   running normalizer, and a rescaled accumulator, so no score matrix
//!   is ever materialized at full context width.
//! * **Group-major loops** — within a tile, each K row and each V row is
//!   loaded once per *group* (i.e. once per KV head) and dotted against
//!   all `G = num_heads / num_kv_heads` query heads of that group, not
//!   once per query head. This is the paper's G× traffic saving, now
//!   shared by prefill and decode.
//! * **Incremental ALiBi** — the linear bias `-m_h·(q_pos − k_pos)` is
//!   an arithmetic progression along a tile, so it is folded into the
//!   score pass as one add per slot instead of a per-element call.
//!
//! # Workspace contract
//!
//! [`Workspace`] owns every scratch buffer the kernel needs. Callers
//! *may and should* reuse one workspace across calls (any shapes): the
//! buffers are grown once and reused, so steady-state attention performs
//! **zero heap allocations**. The convenience wrappers in `gqa`/`paged`
//! use a thread-local workspace via [`with_workspace`]; multi-threaded
//! drivers (see [`super::paged::paged_decode_batch`]) run on the
//! persistent worker pool (`crate::runtime::pool`), whose workers keep
//! their thread-local workspaces alive across jobs, steps and layers. A
//! workspace is plain state — no interior mutability — so
//! `&mut Workspace` is the only synchronization needed.
//!
//! # Tile-major multi-row walks
//!
//! The online-softmax state normally covers ONE query row
//! ([`Workspace::begin_row`] … [`Workspace::finish_row`]). Drivers that
//! walk tiles in the *outer* loop and rows in the *inner* loop — the
//! paged-native prefill path, which wants to dequantize a quantized
//! tile **once** and fold it into every query row that sees it — check
//! out one detached [`RowState`] per row ([`Workspace::take_row_states`])
//! and swap each row's state in around its `process_tile` call
//! ([`Workspace::swap_row_state`], six pointer swaps). A row's
//! arithmetic sequence is identical to the row-major walk — same tiles,
//! same order, same values — so results are bit-identical; only the
//! interleaving across rows changes.

use super::alibi::alibi_slopes;
use super::gqa::{AttnConfig, Bias};
use crate::kvcache::QuantKvTile;
use crate::quant::QuantParams;
use crate::tensor::{dot, simd};
use std::cell::RefCell;

/// KV rows per tile on the contiguous (prefill) path. Sized so one tile
/// of K plus one of V for a group stays L1-resident at typical head
/// dims; the paged path tiles by the cache's block size instead.
pub const KV_TILE: usize = 64;

/// Reusable scratch state for one query row's attention.
///
/// See the module docs for the reuse contract. All buffers are sized by
/// [`Workspace::configure`] and survive across calls.
///
/// # Example
///
/// One query row over a single three-key tile (uniform weights, so the
/// output equals the constant V rows):
///
/// ```
/// use opt_gptq::attention::gqa::{AttnConfig, Bias};
/// use opt_gptq::attention::kernel::Workspace;
///
/// let cfg = AttnConfig::dense(2, 1, 4, Bias::None);
/// let mut ws = Workspace::new();
/// ws.configure(&cfg, 8); // tile capacity 8; reuse across calls of any shape
/// ws.begin_row();
/// let q = vec![1.0f32; 2 * 4];  // [num_heads * head_dim]
/// let k = vec![0.5f32; 3 * 4];  // 3 rows of [kv_heads * head_dim]
/// let v = vec![2.0f32; 3 * 4];
/// ws.process_tile(&q, &k, &v, 0, 3, 2); // keys 0..3, query at position 2
/// let mut out = vec![0.0f32; 2 * 4];
/// ws.finish_row(&mut out);
/// assert!(out.iter().all(|o| (o - 2.0).abs() < 1e-6));
/// ```
#[derive(Debug, Default)]
pub struct Workspace {
    num_heads: usize,
    kv_heads: usize,
    head_dim: usize,
    group: usize,
    scale: f32,
    use_alibi: bool,
    tile_cap: usize,
    /// Per-head ALiBi slopes (all zeros for `Bias::None`).
    slopes: Vec<f32>,
    /// Online-softmax running max, per query head.
    m: Vec<f32>,
    /// Online-softmax running normalizer, per query head.
    l: Vec<f32>,
    /// Running weighted-value accumulator, `[num_heads, head_dim]`.
    acc: Vec<f32>,
    /// Per-tile score→weight scratch, group-major `[group, tile_cap]`.
    w: Vec<f32>,
    /// Per-tile dequantized K scratch for the quantized-cache path,
    /// `[tile_cap, kv_heads, head_dim]` (grown on first quantized tile,
    /// then reused — the f32 path never touches it).
    k_dq: Vec<f32>,
    /// Per-tile dequantized V scratch (same shape as `k_dq`).
    v_dq: Vec<f32>,
    /// Reusable pool of detached per-row softmax states for tile-major
    /// multi-row walks (grown once by [`Workspace::take_row_states`]).
    row_states: Vec<RowState>,
    /// Per-head precomputed score lower bound used **only** by
    /// [`Workspace::tile_skippable`] in threshold (lossy) mode — seeded
    /// by the decode driver from the query's self-score
    /// ([`Workspace::seed_from_self_key`]) so the *first* visible tile
    /// can participate in score-bound skipping. Never folded into
    /// `(m, l, acc)`; exact mode never seeds, so its bit-identity is
    /// untouched. `−∞` (the reset value) disables the seed.
    m_seed: Vec<f32>,
    /// Integer-domain query levels, `[num_heads, head_dim]` u8 (one
    /// 8-bit grid per KV-head group; see
    /// [`Workspace::quantize_int_query`]).
    qi_levels: Vec<u8>,
    /// Per query head, the sum of its `head_dim` levels (the `Σq̂`
    /// term of the integer-domain correction).
    qi_sums: Vec<i32>,
    /// Per KV head, the query grid step (NaN poisons the group when
    /// the query row holds non-finite values).
    qi_scale: Vec<f32>,
    /// Per KV head, the query grid zero point.
    qi_zero: Vec<i32>,
}

/// Detached online-softmax state for one query row — the unit a
/// tile-major multi-row driver checks out per row so several rows can
/// share one tile walk (and one in-tile dequant) without losing the
/// single-row kernel schedule. See the module docs; obtained from
/// [`Workspace::take_row_states`] and swapped in/out around
/// [`Workspace::process_tile`] with [`Workspace::swap_row_state`].
#[derive(Debug, Default)]
pub struct RowState {
    m: Vec<f32>,
    l: Vec<f32>,
    acc: Vec<f32>,
}

impl Workspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// (Re)configure for an attention shape and tile capacity.
    ///
    /// Cheap when the shape repeats: buffers only reallocate when they
    /// grow, and the slope table is rebuilt only when the head count or
    /// bias mode changes.
    pub fn configure(&mut self, cfg: &AttnConfig, tile_cap: usize) {
        let (h, kvh, d) = (cfg.num_heads, cfg.num_kv_heads, cfg.head_dim);
        let g = cfg.group_size();
        let use_alibi = cfg.bias == Bias::Alibi;
        if self.num_heads != h || self.use_alibi != use_alibi || self.slopes.len() != h {
            self.slopes = if use_alibi { alibi_slopes(h) } else { vec![0.0; h] };
        }
        self.num_heads = h;
        self.kv_heads = kvh;
        self.head_dim = d;
        self.group = g;
        self.scale = cfg.scale();
        self.use_alibi = use_alibi;
        self.tile_cap = tile_cap.max(1);
        self.m.resize(h, 0.0);
        self.l.resize(h, 0.0);
        self.acc.resize(h * d, 0.0);
        self.w.resize(g * self.tile_cap, 0.0);
        self.m_seed.resize(h, f32::NEG_INFINITY);
    }

    /// Reset the online-softmax state for a fresh query row.
    pub fn begin_row(&mut self) {
        self.m.fill(f32::NEG_INFINITY);
        self.l.fill(0.0);
        self.acc.fill(0.0);
        self.m_seed.fill(f32::NEG_INFINITY);
    }

    /// Swap a detached row's online-softmax state into (or back out of)
    /// the workspace — the pivot of a tile-major multi-row walk. Six
    /// pointer swaps; no allocation, no copy.
    pub fn swap_row_state(&mut self, st: &mut RowState) {
        std::mem::swap(&mut self.m, &mut st.m);
        std::mem::swap(&mut self.l, &mut st.l);
        std::mem::swap(&mut self.acc, &mut st.acc);
    }

    /// Check out `rows` freshly initialized [`RowState`]s (each the
    /// equivalent of [`Workspace::begin_row`]) from the workspace's
    /// reusable pool. Must be called after [`Workspace::configure`]; the
    /// pool grows once and is reused forever, so steady-state checkouts
    /// allocate nothing. Return the vector with
    /// [`Workspace::put_row_states`] when the walk finishes (the returned
    /// vector may be longer than `rows`; only the first `rows` entries
    /// are initialized).
    pub fn take_row_states(&mut self, rows: usize) -> Vec<RowState> {
        // Tile-major walks never seed the skip bound (seeding is a
        // decode-driver feature); clear any seed a previous decode row
        // left behind so prefill skip decisions can't see stale state.
        self.m_seed.fill(f32::NEG_INFINITY);
        let mut pool = std::mem::take(&mut self.row_states);
        if pool.len() < rows {
            pool.resize_with(rows, RowState::default);
        }
        let (h, hd) = (self.num_heads, self.num_heads * self.head_dim);
        for st in &mut pool[..rows] {
            st.m.clear();
            st.m.resize(h, f32::NEG_INFINITY);
            st.l.clear();
            st.l.resize(h, 0.0);
            st.acc.clear();
            st.acc.resize(hd, 0.0);
        }
        pool
    }

    /// Return a row-state pool checked out by
    /// [`Workspace::take_row_states`] so the buffers are reused.
    pub fn put_row_states(&mut self, pool: Vec<RowState>) {
        self.row_states = pool;
    }

    /// Take the per-tile dequant scratch out of the workspace, grown to
    /// hold `tile_cap` rows (`tile_cap × kv_heads × head_dim` each, per
    /// configure). Lets a driver dequantize a quantized tile **once** and
    /// then call [`Workspace::process_tile`] (which needs `&mut self`)
    /// against it for many rows. Must be paired with
    /// [`Workspace::put_quant_scratch`]; `mem::take` swaps in empty Vecs,
    /// so the workspace stays usable and nothing is allocated in steady
    /// state.
    pub fn take_quant_scratch(&mut self) -> (Vec<f32>, Vec<f32>) {
        let cap = self.tile_cap * self.kv_heads * self.head_dim;
        let mut kd = std::mem::take(&mut self.k_dq);
        let mut vd = std::mem::take(&mut self.v_dq);
        if kd.len() < cap {
            kd.resize(cap, 0.0);
        }
        if vd.len() < cap {
            vd.resize(cap, 0.0);
        }
        (kd, vd)
    }

    /// Return the dequant scratch taken by
    /// [`Workspace::take_quant_scratch`].
    pub fn put_quant_scratch(&mut self, k_dq: Vec<f32>, v_dq: Vec<f32>) {
        self.k_dq = k_dq;
        self.v_dq = v_dq;
    }

    /// Fold one KV tile into the running state of query row `q_row`
    /// (`[num_heads * head_dim]`, absolute position `q_pos`).
    ///
    /// `k_tile`/`v_tile` hold `visible` rows laid out `[row, kv_heads,
    /// head_dim]` (row stride `kv_heads * head_dim`) covering absolute
    /// key positions `tile_pos .. tile_pos + visible`. Causality is the
    /// caller's loop bound: rows a query must not see are simply not
    /// passed. `visible` must be in `1..=tile_cap`.
    pub fn process_tile(
        &mut self,
        q_row: &[f32],
        k_tile: &[f32],
        v_tile: &[f32],
        tile_pos: usize,
        visible: usize,
        q_pos: usize,
    ) {
        let (kvh, d, g) = (self.kv_heads, self.head_dim, self.group);
        let tile_cap = self.tile_cap;
        let rs = kvh * d; // tile row stride
        debug_assert!(visible > 0 && visible <= tile_cap, "visible={visible} cap={tile_cap}");
        debug_assert!(tile_pos + visible <= q_pos + 1, "tile reaches past the query position");
        debug_assert_eq!(q_row.len(), self.num_heads * d);
        debug_assert!(k_tile.len() >= visible * rs);
        debug_assert!(v_tile.len() >= visible * rs);

        for kv_head in 0..kvh {
            let head0 = kv_head * g;
            // Pass 1 — raw scores. Each K row is loaded ONCE and dotted
            // against every query head of the group (group-major order).
            for slot in 0..visible {
                let base = slot * rs + kv_head * d;
                let k_vec = &k_tile[base..base + d];
                for gq in 0..g {
                    let q_vec = &q_row[(head0 + gq) * d..(head0 + gq + 1) * d];
                    self.w[gq * tile_cap + slot] = dot(q_vec, k_vec);
                }
            }
            self.fold_tile_scores(kv_head, tile_pos, visible, q_pos);
            self.fold_tile_values(kv_head, v_tile, rs, visible);
        }
    }

    /// Shared score→weight fold for one KV head's group: scale +
    /// incremental ALiBi over the raw scores already sitting in `w`,
    /// tile max, one online rescale of `(m, l, acc)`, then the
    /// `exp(s − m)` transform. Extracted from [`Workspace::process_tile`]
    /// so the integer-domain score path
    /// ([`Workspace::process_quant_tile_int`]) runs the *identical*
    /// online-softmax update — only pass 1 (how raw scores are produced)
    /// differs between the two.
    fn fold_tile_scores(&mut self, kv_head: usize, tile_pos: usize, visible: usize, q_pos: usize) {
        let (d, g) = (self.head_dim, self.group);
        let tile_cap = self.tile_cap;
        let scale = self.scale;
        let head0 = kv_head * g;
        // Per head: scale + incremental ALiBi, tile max, one online
        // rescale of (m, l, acc), then score→weight transform.
        for gq in 0..g {
            let head = head0 + gq;
            let slope = self.slopes[head];
            let row = &mut self.w[gq * tile_cap..gq * tile_cap + visible];
            let mut m_blk = f32::NEG_INFINITY;
            if self.use_alibi {
                // bias(slot) = −slope·(q_pos − (tile_pos+slot)) is an
                // arithmetic progression: one add per slot.
                let mut bias = -slope * (q_pos - tile_pos) as f32;
                for s in row.iter_mut() {
                    *s = *s * scale + bias;
                    bias += slope;
                    m_blk = m_blk.max(*s);
                }
            } else {
                for s in row.iter_mut() {
                    *s *= scale;
                    m_blk = m_blk.max(*s);
                }
            }
            if m_blk == f32::NEG_INFINITY {
                // Every score in the tile is −∞ (e.g. ±∞ inputs): the
                // tile contributes zero weight. Zero the scratch so
                // pass 2 is a no-op and leave (m, l, acc) untouched —
                // this is what keeps the final normalization safe.
                // `max` ignores NaN, so an all-NaN tile also lands
                // here: poison the normalizer instead of masking the
                // upstream numerical bug behind zero output (mixed
                // finite/NaN tiles already propagate via exp()).
                if row.iter().any(|s| s.is_nan()) {
                    self.l[head] = f32::NAN;
                }
                row.fill(0.0);
                continue;
            }
            let m_prev = self.m[head];
            let m_new = m_prev.max(m_blk);
            self.m[head] = m_new;
            let corr = if m_prev == f32::NEG_INFINITY { 0.0 } else { (m_prev - m_new).exp() };
            self.l[head] *= corr;
            if corr != 1.0 {
                for a in &mut self.acc[head * d..(head + 1) * d] {
                    *a *= corr;
                }
            }
            let mut lsum = 0.0f32;
            for s in row.iter_mut() {
                *s = (*s - m_new).exp();
                lsum += *s;
            }
            self.l[head] += lsum;
        }
    }

    /// Shared pass 2 for one KV head's group — weighted values. Each V
    /// row is loaded ONCE per group and accumulated into all G query
    /// heads through the dispatched `axpy` kernel (element-wise
    /// `acc[i] += w · v[i]`, bit-identical across tables by the dispatch
    /// contract). `rs` is the V tile's row stride (`kv_heads·head_dim`).
    fn fold_tile_values(&mut self, kv_head: usize, v_tile: &[f32], rs: usize, visible: usize) {
        let (d, g) = (self.head_dim, self.group);
        let tile_cap = self.tile_cap;
        let head0 = kv_head * g;
        let axpy = simd::active().axpy;
        for slot in 0..visible {
            let base = slot * rs + kv_head * d;
            let v_vec = &v_tile[base..base + d];
            for gq in 0..g {
                let wgt = self.w[gq * tile_cap + slot];
                if wgt == 0.0 {
                    continue;
                }
                let a = &mut self.acc[(head0 + gq) * d..(head0 + gq + 1) * d];
                axpy(wgt, v_vec, a);
            }
        }
    }

    /// Fold one **quantized** KV tile into the running state — the
    /// TurboAttention-style in-tile dequant step.
    ///
    /// The packed tile is dequantized into workspace scratch (`k_dq` /
    /// `v_dq`, grown once to `tile_cap × kv_heads × head_dim` and reused
    /// forever — the zero-alloc contract holds in steady state) and then
    /// folded by [`Workspace::process_tile`], so the quantized cache
    /// inherits the exact group-major online-softmax schedule of the f32
    /// path. Arguments mirror `process_tile`; `k_tile`/`v_tile` must hold
    /// at least `visible` packed rows.
    pub fn process_quant_tile(
        &mut self,
        q_row: &[f32],
        k_tile: &QuantKvTile<'_>,
        v_tile: &QuantKvTile<'_>,
        tile_pos: usize,
        visible: usize,
        q_pos: usize,
    ) {
        let (kvh, d) = (self.kv_heads, self.head_dim);
        debug_assert!(visible > 0 && visible <= self.tile_cap);
        let used = visible * kvh * d;
        let (mut kd, mut vd) = self.take_quant_scratch();
        k_tile.dequantize_into(visible, kvh, d, &mut kd[..used]);
        v_tile.dequantize_into(visible, kvh, d, &mut vd[..used]);
        self.process_tile(q_row, &kd, &vd, tile_pos, visible, q_pos);
        self.put_quant_scratch(kd, vd);
    }

    /// Quantize the query row once per KV-head group for the
    /// integer-domain score path (`--q8-score-domain int`).
    ///
    /// Each group's contiguous segment `q_row[kv_head·G·d ..]` gets one
    /// asymmetric 8-bit grid ([`QuantParams::fit`]); the levels land in
    /// `qi_levels` (`[num_heads, head_dim]` u8) and each head's level
    /// sum in `qi_sums` — the `Σq̂` term of the expanded correction in
    /// [`Workspace::process_quant_tile_int`]. Call once per decode row
    /// before the tile walk; buffers grow once and are reused (the
    /// zero-alloc contract holds in steady state).
    ///
    /// A non-finite query segment sets the group's `qi_scale` to NaN, so
    /// every integer-domain score in that group is NaN and the kernel's
    /// NaN-poisoning semantics apply exactly as on the f32 path.
    pub fn quantize_int_query(&mut self, q_row: &[f32]) {
        let (kvh, d, g, h) = (self.kv_heads, self.head_dim, self.group, self.num_heads);
        debug_assert_eq!(q_row.len(), h * d);
        if self.qi_levels.len() < h * d {
            self.qi_levels.resize(h * d, 0);
        }
        if self.qi_sums.len() < h {
            self.qi_sums.resize(h, 0);
        }
        if self.qi_scale.len() < kvh {
            self.qi_scale.resize(kvh, 0.0);
        }
        if self.qi_zero.len() < kvh {
            self.qi_zero.resize(kvh, 0);
        }
        for kv_head in 0..kvh {
            let seg = &q_row[kv_head * g * d..(kv_head + 1) * g * d];
            if seg.iter().any(|x| !x.is_finite()) {
                self.qi_scale[kv_head] = f32::NAN;
                self.qi_zero[kv_head] = 0;
                for head in kv_head * g..(kv_head + 1) * g {
                    self.qi_sums[head] = 0;
                    self.qi_levels[head * d..(head + 1) * d].fill(0);
                }
                continue;
            }
            let p = QuantParams::fit(seg, 8);
            self.qi_scale[kv_head] = p.scale;
            self.qi_zero[kv_head] = p.zero;
            for gq in 0..g {
                let head = kv_head * g + gq;
                let mut sum = 0i32;
                for (t, &x) in q_row[head * d..(head + 1) * d].iter().enumerate() {
                    let lvl = p.quantize(x);
                    self.qi_levels[head * d + t] = lvl as u8;
                    sum += lvl;
                }
                self.qi_sums[head] = sum;
            }
        }
    }

    /// Fold one quantized KV tile with **integer-domain scoring**
    /// (TurboAttention-style; the opt-in `--q8-score-domain int` path).
    ///
    /// Instead of dequantizing K to f32 and dotting
    /// ([`Workspace::process_quant_tile`]), the packed K levels are
    /// scored directly against the query levels prepared by
    /// [`Workspace::quantize_int_query`] with u8×u8→i32 widening dots.
    /// With `q ≈ qs·(q̂ − qz)` and `k ≈ ks·(k̂ − kz)`, expanding the dot
    /// gives
    ///
    /// ```text
    /// dot(q, k) ≈ qs·ks · (Σq̂k̂ − kz·Σq̂ − qz·Σk̂ + d·qz·kz)
    /// ```
    ///
    /// where the parenthesized correction is exact i64 integer
    /// arithmetic and `qs·ks` is applied **once per (tile, kv_head)**
    /// — the single rescale before the shared online-softmax update
    /// ([`Workspace::fold_tile_scores`]). `Σk̂` is computed once per
    /// (slot, kv_head) and shared across the group's query heads. K is
    /// never dequantized; V still is (pass 2 needs f32 values), so the
    /// tile's K dequant traffic disappears from the decode hot path.
    ///
    /// The score differs from the f32-score q8 path only by the query
    /// quantization error: per score at most `qs/2 · Σ|k̂·ks − kz·ks|`
    /// plus f32 rounding of the rescale — bounded on the parity grid in
    /// `tests/simd_parity.rs`. **Decode-only by design**: the prefill
    /// walk is tile-major and already amortizes each tile's K dequant
    /// across every query row that sees it, so the win there is nil and
    /// the per-row level cache would have to persist across the walk.
    pub fn process_quant_tile_int(
        &mut self,
        q_row: &[f32],
        k_tile: &QuantKvTile<'_>,
        v_tile: &QuantKvTile<'_>,
        tile_pos: usize,
        visible: usize,
        q_pos: usize,
    ) {
        let (kvh, d, g) = (self.kv_heads, self.head_dim, self.group);
        let tile_cap = self.tile_cap;
        debug_assert!(visible > 0 && visible <= tile_cap);
        debug_assert_eq!(q_row.len(), self.num_heads * d);
        debug_assert!(
            self.qi_levels.len() >= self.num_heads * d,
            "quantize_int_query must run before the tile walk"
        );
        let wph = k_tile.words_per_head;
        let kr = simd::active();
        let (q8_dot, q8_sum) = (kr.q8_dot, kr.q8_sum);
        // V is still dequantized per tile; only the K dequant is skipped.
        let used = visible * kvh * d;
        let (kd, mut vd) = self.take_quant_scratch();
        v_tile.dequantize_into(visible, kvh, d, &mut vd[..used]);
        for kv_head in 0..kvh {
            let head0 = kv_head * g;
            let ks = k_tile.scales[kv_head];
            let kz = k_tile.zeros[kv_head] as i64;
            let qz = self.qi_zero[kv_head] as i64;
            // One rescale per (tile, kv_head): both grid steps at once.
            // NaN here (non-finite query) poisons every score below.
            let tile_scale = self.qi_scale[kv_head] * ks;
            for slot in 0..visible {
                let w0 = (slot * kvh + kv_head) * wph;
                let words = &k_tile.words[w0..w0 + wph];
                let ksum = q8_sum(words, d) as i64;
                for gq in 0..g {
                    let head = head0 + gq;
                    let ql = &self.qi_levels[head * d..(head + 1) * d];
                    let idot = q8_dot(ql, words, d) as i64;
                    let qsum = self.qi_sums[head] as i64;
                    // (q̂−qz)·(k̂−kz) expanded; exact in i64.
                    let corr = idot - kz * qsum - qz * ksum + d as i64 * qz * kz;
                    self.w[gq * tile_cap + slot] = tile_scale * corr as f32;
                }
            }
            self.fold_tile_scores(kv_head, tile_pos, visible, q_pos);
            self.fold_tile_values(kv_head, &vd, kvh * d, visible);
        }
        self.put_quant_scratch(kd, vd);
    }

    /// Seed the threshold-mode skip bound from the query's own key — the
    /// one key a causal decode row is always guaranteed to see, written
    /// to the cache just before attention runs.
    ///
    /// Per head, the seed is `scale · dot(q_h, k_self)` (the ALiBi bias
    /// at distance zero is 0), a score the row will actually fold — so
    /// the final running max satisfies `m_final ≥ seed` and any tile
    /// rejected against the seed is rejected against a *lower bound* of
    /// `m_final`: the documented per-score mass bound `e^{log_margin}`
    /// still holds. This is what lets the **first** visible tile
    /// participate in score-bound skipping (before PR 8 the bound only
    /// opened once some tile had set a finite running max).
    ///
    /// Drivers must call this **only in threshold (lossy) mode**
    /// (`skip_threshold > 0`): exact-mode skips are proven against the
    /// exp-underflow margin from the *running* max and stay bit-identical
    /// precisely because no seed participates. (The seed itself can
    /// differ from the folded self-score by ulps of the ALiBi
    /// progression's rounding — harmless inside threshold mode's slack,
    /// not acceptable in exact mode.) Int-domain decode also must not
    /// seed: its folded scores carry quantization error the f32 seed
    /// doesn't. Non-finite self-scores leave the seed disabled (−∞),
    /// preserving NaN-refusal.
    pub fn seed_from_self_key(&mut self, q_row: &[f32], k_self: &[f32]) {
        let (d, g) = (self.head_dim, self.group);
        debug_assert_eq!(q_row.len(), self.num_heads * d);
        debug_assert!(k_self.len() >= self.kv_heads * d);
        for head in 0..self.num_heads {
            let kv_head = head / g;
            let q_vec = &q_row[head * d..(head + 1) * d];
            let k_vec = &k_self[kv_head * d..(kv_head + 1) * d];
            let s = dot(q_vec, k_vec) * self.scale;
            if s.is_finite() {
                self.m_seed[head] = s;
            }
        }
    }

    /// Decide whether a KV tile can be **skipped outright** for query row
    /// `q_row` because its softmax contribution is provably negligible —
    /// the score-bound test behind `SparsityConfig::skip_threshold`.
    ///
    /// `key_bounds(kv_head)` must return `(lo, hi)` such that every
    /// element of every K row in the tile for that KV head lies in
    /// `[lo, hi]` (per-tile metadata maintained by the KV stores; a
    /// conservative `(−∞, +∞)` answer simply disables skipping). From
    /// those bounds the raw dot product of a query head against any key
    /// in the tile is bounded by
    ///
    /// ```text
    /// dot(q, k) ≤ hi·Σ max(q_j, 0) − lo·Σ max(−q_j, 0)
    /// ```
    ///
    /// and the ALiBi bias `−slope·(q_pos − k_pos)` is maximal at the
    /// tile's **last** slot (slopes are ≥ 0), so
    /// `ub = scale·ub_dot − slope·(q_pos − (tile_pos+visible−1))` bounds
    /// every score the tile could produce for that head.
    ///
    /// The tile is skippable when, for every head, `ub` sits below the
    /// running max `m` — or, in threshold-mode decode, below the
    /// self-score seed planted by [`Workspace::seed_from_self_key`],
    /// whichever is larger (the seed is a proven lower bound on the
    /// final max, so rejecting against it preserves the mass bound even
    /// before any tile has run) — by at least `−log_margin` (a negative
    /// number):
    ///
    /// * With `log_margin == EXACT_LOG_MARGIN` the skip is **bit-exact**:
    ///   every score satisfies `s − m ≤ −128`, `expf` of which underflows
    ///   to exactly `0.0f32`, and the tile cannot raise `m` — so
    ///   `process_tile` would have multiplied `l`/`acc` by `corr == 1.0`,
    ///   added `0.0` weights, and hit the `wgt == 0.0` fast path in pass
    ///   2. State is byte-identical either way (asserted in tests).
    /// * With a larger (threshold-mode) margin the skipped mass is bounded
    ///   by `visible · e^{log_margin}` per head, trading exactness for
    ///   more skips.
    ///
    /// All bound arithmetic runs in f64 and carries an explicit rounding
    /// slack, so f32 evaluation inside `process_tile` cannot legally land
    /// above the bound. Non-finite queries, bounds, or running maxima
    /// conservatively refuse the skip, preserving the kernel's
    /// NaN-poisoning semantics. Never call this for a tile the window
    /// rule already hides; window-invisible tiles are not "skipped", they
    /// are simply outside the schedule.
    pub fn tile_skippable(
        &self,
        q_row: &[f32],
        key_bounds: &mut dyn FnMut(usize) -> (f32, f32),
        tile_pos: usize,
        visible: usize,
        q_pos: usize,
        log_margin: f32,
    ) -> bool {
        let (kvh, d, g) = (self.kv_heads, self.head_dim, self.group);
        debug_assert!(visible > 0 && tile_pos + visible <= q_pos + 1);
        debug_assert_eq!(q_row.len(), self.num_heads * d);
        let scale = self.scale as f64;
        let margin = log_margin as f64;
        // Bias of the tile's closest (= last) slot; slopes are ≥ 0 so it
        // dominates the whole tile. Zero slopes (Bias::None) fall out.
        let gap = (q_pos - (tile_pos + visible - 1)) as f64;
        for kv_head in 0..kvh {
            let (lo, hi) = key_bounds(kv_head);
            let (lo, hi) = (lo as f64, hi as f64);
            if !lo.is_finite() || !hi.is_finite() {
                return false; // no usable metadata — cannot prove anything
            }
            let kmax = lo.abs().max(hi.abs());
            for gq in 0..g {
                let head = kv_head * g + gq;
                let m_run = self.m[head];
                if m_run.is_nan() || m_run == f32::INFINITY {
                    // Upstream poison must keep propagating.
                    return false;
                }
                // Threshold-mode decode seeds a per-head lower bound on
                // the final max from the query's self-score
                // (`seed_from_self_key`), so even the first tile — when
                // the running max is still −∞ — can be rejected against
                // it. `max` ignores the −∞ reset; NaN can't reach here
                // (the seed setter rejects non-finite scores).
                let eff = m_run.max(self.m_seed[head]);
                if !eff.is_finite() {
                    // −∞: no mass yet and no seed — the tile would
                    // *define* m.
                    return false;
                }
                let m = eff as f64;
                let q_vec = &q_row[head * d..(head + 1) * d];
                let (mut pos_mass, mut neg_mass) = (0.0f64, 0.0f64);
                for &qv in q_vec {
                    let q = qv as f64;
                    if !q.is_finite() {
                        return false;
                    }
                    if q > 0.0 {
                        pos_mass += q;
                    } else {
                        neg_mass -= q;
                    }
                }
                let ub_dot = hi * pos_mass - lo * neg_mass;
                let bias = -(self.slopes[head] as f64) * gap;
                let ub = scale * ub_dot + bias;
                // Generous cover for the f32 dot/scale/bias rounding that
                // process_tile would perform (relative error ~2⁻²⁴ per
                // step; 1e-4 of the magnitude envelope is orders beyond).
                let slack =
                    1e-4 * (1.0 + scale * kmax * (pos_mass + neg_mass) + bias.abs());
                if !(ub + slack < m + margin) {
                    return false;
                }
            }
        }
        true
    }

    /// Normalize the accumulator into `out_row` (`[num_heads*head_dim]`).
    ///
    /// A head whose normalizer is exactly zero — no visible keys, or
    /// every score was −∞ — yields zeros instead of dividing by zero
    /// (the seed's `1.0 / l` NaN hazard). A NaN normalizer (NaN Q/K/V
    /// upstream) trips a debug assertion with context and is otherwise
    /// allowed to *propagate* as NaN output: silently zeroing it would
    /// mask a real numerical bug behind plausible logits.
    pub fn finish_row(&self, out_row: &mut [f32]) {
        let (h, d) = (self.num_heads, self.head_dim);
        debug_assert_eq!(out_row.len(), h * d);
        for head in 0..h {
            let l = self.l[head];
            debug_assert!(
                !l.is_nan(),
                "attention normalizer is NaN for head {head} (non-finite inputs?)"
            );
            let out = &mut out_row[head * d..(head + 1) * d];
            if l == 0.0 {
                out.fill(0.0);
            } else {
                let inv = 1.0 / l;
                for (o, &a) in out.iter_mut().zip(&self.acc[head * d..(head + 1) * d]) {
                    *o = a * inv;
                }
            }
        }
    }
}

thread_local! {
    static WORKSPACE: RefCell<Workspace> = RefCell::new(Workspace::new());
}

/// Run `f` with this thread's reusable attention workspace.
///
/// The allocating convenience wrappers (`gqa_attention`,
/// `paged_decode_attention`) route through this so repeated calls on one
/// thread reuse scratch buffers. `f` must not re-enter `with_workspace`.
pub fn with_workspace<R>(f: impl FnOnce(&mut Workspace) -> R) -> R {
    WORKSPACE.with(|w| f(&mut w.borrow_mut()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::softmax_inplace;
    use crate::util::rng::Rng;

    /// Naive single-row reference: full softmax per head.
    fn reference_row(
        cfg: &AttnConfig,
        q_row: &[f32],
        k: &[f32],
        v: &[f32],
        kv_len: usize,
        q_pos: usize,
    ) -> Vec<f32> {
        let (h, kvh, d) = (cfg.num_heads, cfg.num_kv_heads, cfg.head_dim);
        let g = cfg.group_size();
        let scale = cfg.scale();
        let slopes = match cfg.bias {
            Bias::Alibi => alibi_slopes(h),
            Bias::None => vec![0.0; h],
        };
        let visible = (q_pos + 1).min(kv_len);
        let mut out = vec![0.0f32; h * d];
        for head in 0..h {
            let kv_head = head / g;
            let q_vec = &q_row[head * d..(head + 1) * d];
            let mut s: Vec<f32> = (0..visible)
                .map(|j| {
                    let k_vec = &k[(j * kvh + kv_head) * d..(j * kvh + kv_head + 1) * d];
                    dot(q_vec, k_vec) * scale - slopes[head] * (q_pos - j) as f32
                })
                .collect();
            softmax_inplace(&mut s);
            for (j, &wj) in s.iter().enumerate() {
                let v_vec = &v[(j * kvh + kv_head) * d..(j * kvh + kv_head + 1) * d];
                for t in 0..d {
                    out[head * d + t] += wj * v_vec[t];
                }
            }
        }
        out
    }

    fn run_tiled(
        cfg: &AttnConfig,
        ws: &mut Workspace,
        q_row: &[f32],
        k: &[f32],
        v: &[f32],
        kv_len: usize,
        q_pos: usize,
        tile: usize,
    ) -> Vec<f32> {
        let rs = cfg.num_kv_heads * cfg.head_dim;
        ws.configure(cfg, tile);
        ws.begin_row();
        let visible = (q_pos + 1).min(kv_len);
        let mut pos = 0;
        while pos < visible {
            let vis = tile.min(visible - pos);
            ws.process_tile(q_row, &k[pos * rs..(pos + vis) * rs], &v[pos * rs..(pos + vis) * rs], pos, vis, q_pos);
            pos += vis;
        }
        let mut out = vec![0.0f32; cfg.num_heads * cfg.head_dim];
        ws.finish_row(&mut out);
        out
    }

    #[test]
    fn tile_size_invariance_matches_reference() {
        let mut ws = Workspace::new();
        for &bias in &[Bias::Alibi, Bias::None] {
            for &(h, kvh) in &[(4usize, 1usize), (4, 2), (8, 8)] {
                for &(kv_len, q_pos) in &[(1usize, 0usize), (5, 4), (16, 9), (33, 40)] {
                    let d = 8;
                    let cfg = AttnConfig::dense(h, kvh, d, bias);
                    let mut rng = Rng::new((h * 100 + kvh * 10 + kv_len) as u64);
                    let q = rng.normal_vec(h * d, 1.0);
                    let k = rng.normal_vec(kv_len * kvh * d, 1.0);
                    let v = rng.normal_vec(kv_len * kvh * d, 1.0);
                    let expect = reference_row(&cfg, &q, &k, &v, kv_len, q_pos);
                    for tile in [1usize, 3, 7, 16, 64] {
                        let got = run_tiled(&cfg, &mut ws, &q, &k, &v, kv_len, q_pos, tile);
                        for (i, (a, b)) in got.iter().zip(&expect).enumerate() {
                            assert!(
                                (a - b).abs() < 1e-5,
                                "bias={bias:?} h={h} kvh={kvh} kv={kv_len} qp={q_pos} tile={tile} i={i}: {a} vs {b}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn no_visible_keys_yields_zeros() {
        let cfg = AttnConfig::dense(2, 1, 4, Bias::None);
        let mut ws = Workspace::new();
        ws.configure(&cfg, 8);
        ws.begin_row();
        let mut out = vec![1.0f32; 8];
        ws.finish_row(&mut out);
        assert_eq!(out, vec![0.0; 8]);
    }

    #[test]
    fn neg_inf_scores_do_not_poison_state() {
        // A tile whose scores are all −∞ must contribute nothing and
        // leave later (finite) tiles intact.
        let cfg = AttnConfig::dense(1, 1, 4, Bias::None);
        let mut ws = Workspace::new();
        ws.configure(&cfg, 4);
        ws.begin_row();
        let q = vec![1.0f32; 4];
        let k_bad = vec![f32::NEG_INFINITY; 4];
        let v_bad = vec![9.0f32; 4];
        ws.process_tile(&q, &k_bad, &v_bad, 0, 1, 5);
        let k_ok = vec![0.5f32; 4];
        let v_ok = vec![2.0f32; 4];
        ws.process_tile(&q, &k_ok, &v_ok, 1, 1, 5);
        let mut out = vec![0.0f32; 4];
        ws.finish_row(&mut out);
        // Only the finite key is weighted → output is exactly its V row.
        for &o in &out {
            assert!((o - 2.0).abs() < 1e-6, "out={out:?}");
        }
    }

    #[test]
    fn quant_tile_matches_dense_tile_on_same_values() {
        // process_quant_tile must be bit-identical to process_tile fed
        // the dequantized copy of the same packed tile.
        use crate::kvcache::QuantKvTile;
        use crate::quant::{packing, QuantParams};
        let (h, kvh, d, slots) = (4usize, 2usize, 8usize, 5usize);
        let cfg = AttnConfig::dense(h, kvh, d, Bias::Alibi);
        let mut rng = Rng::new(11);
        let q = rng.normal_vec(h * d, 1.0);
        let k = rng.normal_vec(slots * kvh * d, 1.0);
        let v = rng.normal_vec(slots * kvh * d, 1.0);
        let wph = d.div_ceil(4);
        let pack = |x: &[f32]| {
            let mut words = vec![0i32; slots * kvh * wph];
            let mut scales = vec![0.0f32; kvh];
            let mut zeros = vec![0i32; kvh];
            for head in 0..kvh {
                let vals: Vec<f32> = (0..slots)
                    .flat_map(|s| x[(s * kvh + head) * d..(s * kvh + head + 1) * d].to_vec())
                    .collect();
                let p = QuantParams::fit(&vals, 8);
                scales[head] = p.scale;
                zeros[head] = p.zero;
                for s in 0..slots {
                    packing::quant_pack_row(
                        &x[(s * kvh + head) * d..(s * kvh + head + 1) * d],
                        &p,
                        &mut words[(s * kvh + head) * wph..(s * kvh + head + 1) * wph],
                    );
                }
            }
            (words, scales, zeros)
        };
        let (kw, ks, kz) = pack(&k);
        let (vw, vs, vz) = pack(&v);
        let k_tile = QuantKvTile { words: &kw, scales: &ks, zeros: &kz, words_per_head: wph };
        let v_tile = QuantKvTile { words: &vw, scales: &vs, zeros: &vz, words_per_head: wph };

        let mut kd = vec![0.0f32; slots * kvh * d];
        let mut vd = vec![0.0f32; slots * kvh * d];
        k_tile.dequantize_into(slots, kvh, d, &mut kd);
        v_tile.dequantize_into(slots, kvh, d, &mut vd);
        // Dequantized values stay near the originals (8-bit grid).
        for (a, b) in kd.iter().zip(&k) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }

        let run = |quant: bool| {
            let mut ws = Workspace::new();
            ws.configure(&cfg, 8);
            ws.begin_row();
            if quant {
                ws.process_quant_tile(&q, &k_tile, &v_tile, 0, slots, slots - 1);
            } else {
                ws.process_tile(&q, &kd, &vd, 0, slots, slots - 1);
            }
            let mut out = vec![0.0f32; h * d];
            ws.finish_row(&mut out);
            out
        };
        assert_eq!(run(true), run(false), "quantized path must share the exact schedule");

        // Integer-domain scoring on the same packed tile: differs from
        // the f32-score q8 path only by the query's 8-bit quantization
        // error (the K-side correction is exact i64 arithmetic), so the
        // outputs stay within a grid-step-sized bound.
        let mut ws = Workspace::new();
        ws.configure(&cfg, 8);
        ws.begin_row();
        ws.quantize_int_query(&q);
        ws.process_quant_tile_int(&q, &k_tile, &v_tile, 0, slots, slots - 1);
        let mut int_out = vec![0.0f32; h * d];
        ws.finish_row(&mut int_out);
        let f32_out = run(true);
        for (i, (a, b)) in int_out.iter().zip(&f32_out).enumerate() {
            assert!((a - b).abs() < 0.1, "i={i}: int {a} vs f32 {b}");
        }
    }

    #[test]
    fn int_domain_nan_query_poisons_normalizer() {
        // The f32 path propagates NaN queries into a NaN normalizer;
        // the integer path must do the same (via the NaN group scale),
        // not round NaN onto the grid and emit plausible logits.
        use crate::kvcache::QuantKvTile;
        use crate::quant::{packing, QuantParams};
        let (h, kvh, d, slots) = (2usize, 1usize, 8usize, 3usize);
        let cfg = AttnConfig::dense(h, kvh, d, Bias::None);
        let mut rng = Rng::new(13);
        let mut q = rng.normal_vec(h * d, 1.0);
        q[3] = f32::NAN;
        let x = rng.normal_vec(slots * kvh * d, 1.0);
        let wph = d.div_ceil(4);
        let p = QuantParams::fit(&x, 8);
        let mut words = vec![0i32; slots * kvh * wph];
        for s in 0..slots {
            packing::quant_pack_row(&x[s * d..(s + 1) * d], &p, &mut words[s * wph..(s + 1) * wph]);
        }
        let scales = vec![p.scale];
        let zeros = vec![p.zero];
        let tile = QuantKvTile { words: &words, scales: &scales, zeros: &zeros, words_per_head: wph };
        let mut ws = Workspace::new();
        ws.configure(&cfg, 4);
        ws.begin_row();
        ws.quantize_int_query(&q);
        ws.process_quant_tile_int(&q, &tile, &tile, 0, slots, slots - 1);
        assert!(ws.l.iter().any(|l| l.is_nan()), "NaN query must poison the normalizer");
    }

    #[test]
    fn self_score_seed_opens_first_tile_skipping() {
        // Threshold mode: with the self-score seed planted, a distant
        // low-magnitude tile is skippable even though the running max is
        // still −∞ — and the skipped mass stays inside the margin, so
        // the output moves by at most a threshold-sized amount.
        let (h, kvh, d) = (4usize, 2usize, 8usize);
        let cfg = AttnConfig::dense(h, kvh, d, Bias::Alibi);
        let mut rng = Rng::new(47);
        let q_pos = 100_000usize;
        let q = rng.normal_vec(h * d, 1.0);
        let self_k = rng.normal_vec(kvh * d, 1.0);
        let far_k: Vec<f32> = rng.normal_vec(4 * kvh * d, 1.0).iter().map(|x| x * 0.01).collect();
        let threshold_margin = 0.01f32.ln(); // t = 1e-2

        let mut ws = Workspace::new();
        ws.configure(&cfg, 4);
        ws.begin_row();
        let bounds = tile_bounds(&far_k, 4, kvh, d);
        let mut kb = |head: usize| bounds[head];
        // Without the seed: running max is −∞, nothing can be proven.
        assert!(!ws.tile_skippable(&q, &mut kb, 0, 4, q_pos, threshold_margin));
        ws.seed_from_self_key(&q, &self_k);
        assert!(
            ws.tile_skippable(&q, &mut kb, 0, 4, q_pos, threshold_margin),
            "seeded bound must open first-tile skipping in threshold mode"
        );
        // The seed only ever *feeds the comparison*: (m, l, acc) are
        // untouched, so a fresh row is indistinguishable state-wise.
        assert!(ws.m.iter().all(|&m| m == f32::NEG_INFINITY));
        assert!(ws.l.iter().all(|&l| l == 0.0));
        // A later begin_row clears the seed (fresh rows don't inherit).
        ws.begin_row();
        assert!(!ws.tile_skippable(&q, &mut kb, 0, 4, q_pos, threshold_margin));
    }

    #[test]
    fn tile_major_row_states_bit_identical_to_row_major() {
        // The multi-row contract: walking tiles in the outer loop with
        // detached per-row states must be BIT-identical to the row-major
        // walk — a row's arithmetic sequence is unchanged, only the
        // interleaving across rows differs.
        let (h, kvh, d) = (4usize, 2usize, 8usize);
        let cfg = AttnConfig::dense(h, kvh, d, Bias::Alibi);
        let (q_len, kv_len, tile) = (5usize, 19usize, 4usize);
        let q_offset = kv_len - q_len;
        let rs = kvh * d;
        let mut rng = Rng::new(23);
        let q = rng.normal_vec(q_len * h * d, 1.0);
        let k = rng.normal_vec(kv_len * rs, 1.0);
        let v = rng.normal_vec(kv_len * rs, 1.0);

        // Row-major reference.
        let mut ws = Workspace::new();
        let mut expect = vec![0.0f32; q_len * h * d];
        for r in 0..q_len {
            let q_pos = q_offset + r;
            expect[r * h * d..(r + 1) * h * d].copy_from_slice(&run_tiled(
                &cfg,
                &mut ws,
                &q[r * h * d..(r + 1) * h * d],
                &k,
                &v,
                kv_len,
                q_pos,
                tile,
            ));
        }

        // Tile-major walk with checked-out row states.
        ws.configure(&cfg, tile);
        let mut states = ws.take_row_states(q_len);
        let mut pos = 0usize;
        while pos < kv_len {
            let in_tile = tile.min(kv_len - pos);
            for (r, st) in states[..q_len].iter_mut().enumerate() {
                let q_pos = q_offset + r;
                if q_pos < pos {
                    continue;
                }
                let vis = in_tile.min(q_pos + 1 - pos);
                ws.swap_row_state(st);
                ws.process_tile(
                    &q[r * h * d..(r + 1) * h * d],
                    &k[pos * rs..(pos + in_tile) * rs],
                    &v[pos * rs..(pos + in_tile) * rs],
                    pos,
                    vis,
                    q_pos,
                );
                ws.swap_row_state(st);
            }
            pos += in_tile;
        }
        let mut got = vec![0.0f32; q_len * h * d];
        for (r, st) in states[..q_len].iter_mut().enumerate() {
            ws.swap_row_state(st);
            ws.finish_row(&mut got[r * h * d..(r + 1) * h * d]);
            ws.swap_row_state(st);
        }
        ws.put_row_states(states);
        assert_eq!(got, expect, "tile-major must be bit-identical to row-major");
    }

    /// Elementwise per-kv-head (lo, hi) over a tile — what the KV-store
    /// metadata promises, computed exactly for the test.
    fn tile_bounds(k_tile: &[f32], visible: usize, kvh: usize, d: usize) -> Vec<(f32, f32)> {
        let mut b = vec![(f32::INFINITY, f32::NEG_INFINITY); kvh];
        for slot in 0..visible {
            for head in 0..kvh {
                for &x in &k_tile[(slot * kvh + head) * d..(slot * kvh + head + 1) * d] {
                    b[head].0 = b[head].0.min(x);
                    b[head].1 = b[head].1.max(x);
                }
            }
        }
        b
    }

    #[test]
    fn exact_skip_leaves_state_bit_identical() {
        // A far-away tile under ALiBi: the score upper bound sits more
        // than EXACT_LOG_MARGIN below the running max, tile_skippable
        // must fire, and actually processing the tile anyway must leave
        // (m, l, acc) and the finished row bit-unchanged — the skip is a
        // pure elision, not an approximation.
        let (h, kvh, d) = (4usize, 2usize, 8usize);
        let cfg = AttnConfig::dense(h, kvh, d, Bias::Alibi);
        let mut rng = Rng::new(41);
        let q_pos = 100_000usize; // huge gap → even the shallowest slope buries the tile
        let q = rng.normal_vec(h * d, 1.0);
        let near_k = rng.normal_vec(4 * kvh * d, 1.0);
        let near_v = rng.normal_vec(4 * kvh * d, 1.0);
        let far_k: Vec<f32> = rng.normal_vec(4 * kvh * d, 1.0).iter().map(|x| x * 0.01).collect();
        let far_v = rng.normal_vec(4 * kvh * d, 1.0);

        let mut ws = Workspace::new();
        ws.configure(&cfg, 4);
        ws.begin_row();
        // Establish a finite running max from the keys next to the query.
        ws.process_tile(&q, &near_k, &near_v, q_pos - 3, 4, q_pos);
        let bounds = tile_bounds(&far_k, 4, kvh, d);
        let mut kb = |head: usize| bounds[head];
        assert!(
            ws.tile_skippable(&q, &mut kb, 0, 4, q_pos, crate::attention::EXACT_LOG_MARGIN),
            "distant low-magnitude tile must be provably skippable"
        );
        let (m0, l0, acc0) = (ws.m.clone(), ws.l.clone(), ws.acc.clone());
        let mut skipped_out = vec![0.0f32; h * d];
        ws.finish_row(&mut skipped_out);
        // Process the tile anyway: nothing may move.
        ws.process_tile(&q, &far_k, &far_v, 0, 4, q_pos);
        assert_eq!(ws.m, m0, "a skippable tile must not move the running max");
        assert_eq!(ws.l, l0, "a skippable tile must not move the normalizer");
        assert_eq!(ws.acc, acc0, "a skippable tile must not move the accumulator");
        let mut processed_out = vec![0.0f32; h * d];
        ws.finish_row(&mut processed_out);
        assert_eq!(skipped_out, processed_out);
    }

    #[test]
    fn near_tiles_and_unknown_bounds_refuse_to_skip() {
        let (h, kvh, d) = (4usize, 2usize, 8usize);
        let cfg = AttnConfig::dense(h, kvh, d, Bias::Alibi);
        let mut rng = Rng::new(42);
        let q = rng.normal_vec(h * d, 1.0);
        let k = rng.normal_vec(4 * kvh * d, 1.0);
        let v = rng.normal_vec(4 * kvh * d, 1.0);
        let mut ws = Workspace::new();
        ws.configure(&cfg, 4);
        ws.begin_row();
        // Before any tile: m is −∞, nothing is skippable.
        let bounds = tile_bounds(&k, 4, kvh, d);
        let mut kb = |head: usize| bounds[head];
        assert!(!ws.tile_skippable(&q, &mut kb, 0, 4, 3, crate::attention::EXACT_LOG_MARGIN));
        ws.process_tile(&q, &k, &v, 0, 4, 3);
        // The tile that *set* the max can never sit 128 nats below it.
        assert!(!ws.tile_skippable(&q, &mut kb, 0, 4, 3, crate::attention::EXACT_LOG_MARGIN));
        // Conservative (−∞, +∞) metadata always refuses.
        let mut unknown = |_head: usize| (f32::NEG_INFINITY, f32::INFINITY);
        assert!(!ws.tile_skippable(&q, &mut unknown, 0, 4, 100, crate::attention::EXACT_LOG_MARGIN));
        // NaN queries refuse (poison must flow through the real pass).
        let mut q_bad = q.clone();
        q_bad[0] = f32::NAN;
        assert!(!ws.tile_skippable(&q_bad, &mut kb, 0, 4, 3, crate::attention::EXACT_LOG_MARGIN));
    }

    #[test]
    fn workspace_reuse_across_shrinking_shapes() {
        // Reconfiguring to a smaller shape must not leak stale state.
        let mut ws = Workspace::new();
        let big = AttnConfig::dense(8, 4, 8, Bias::Alibi);
        let mut rng = Rng::new(3);
        let (kq, kk, kv) =
            (rng.normal_vec(8 * 8, 1.0), rng.normal_vec(20 * 4 * 8, 1.0), rng.normal_vec(20 * 4 * 8, 1.0));
        let _ = run_tiled(&big, &mut ws, &kq, &kk, &kv, 20, 19, 16);
        let small = AttnConfig::dense(2, 1, 4, Bias::None);
        let sq = rng.normal_vec(2 * 4, 1.0);
        let sk = rng.normal_vec(3 * 4, 1.0);
        let sv = rng.normal_vec(3 * 4, 1.0);
        let reused = run_tiled(&small, &mut ws, &sq, &sk, &sv, 3, 2, 4);
        let fresh = run_tiled(&small, &mut Workspace::new(), &sq, &sk, &sv, 3, 2, 4);
        assert_eq!(reused, fresh);
    }
}
