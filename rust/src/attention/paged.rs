//! Paged decode attention — the native mirror of the Pallas kernel.
//!
//! One query token attends over a sequence whose K/V live in
//! non-contiguous pool blocks (via its block table). Since the
//! kernel-core refactor the per-block inner loop lives in
//! [`super::kernel`]: cache blocks are exactly the kernel's KV tiles, so
//! decode and prefill share one block-tiled, group-major online-softmax
//! schedule — each KV block row touched once per *group*, not once per
//! query head, the G× traffic saving the paper's DCU kernel exploits.
//!
//! [`paged_decode_batch`] fans a whole decode step's sequences across a
//! scoped thread pool (`std::thread::scope`, no extra dependencies) with
//! one private [`Workspace`] per worker; its outputs are bit-identical
//! to the serial loop because sequences are independent and the
//! per-sequence schedule is unchanged.
//!
//! Storage-dtype agnostic: drivers take `&dyn KvStore` and dispatch per
//! block on [`KvBlockView`] — dense f32 blocks go straight to
//! `process_tile`, packed 8-bit blocks through `process_quant_tile`
//! (in-tile dequant into workspace scratch), so both cache dtypes share
//! one schedule.

use super::gqa::AttnConfig;
use super::kernel::{with_workspace, Workspace};
use crate::kvcache::{BlockTable, KvBlockView, KvStore};

/// Decode attention for one sequence.
///
/// * `q`: `[num_heads * head_dim]` — the current token's query.
/// * `table`: the sequence's block table; `table.len()` keys are visible
///   (the current token's K/V must already be written).
///
/// Returns `[num_heads * head_dim]`. Allocates only the output; scratch
/// comes from the calling thread's reusable workspace.
pub fn paged_decode_attention(
    cfg: &AttnConfig,
    cache: &dyn KvStore,
    layer: usize,
    q: &[f32],
    table: &BlockTable,
) -> Vec<f32> {
    let mut out = vec![0.0f32; cfg.num_heads * cfg.head_dim];
    with_workspace(|ws| paged_decode_attention_into(cfg, cache, layer, q, table, ws, &mut out));
    out
}

/// Zero-allocation paged decode attention into a caller-owned buffer.
///
/// The workspace may be reused across calls of any shape (see the
/// [`super::kernel`] contract); on a quantized cache the per-tile dequant
/// scratch lives in the same workspace, so steady-state decode stays
/// allocation-free for both dtypes. A head whose every score is −∞
/// yields zeros instead of the seed's `1.0 / 0.0` NaN.
pub fn paged_decode_attention_into(
    cfg: &AttnConfig,
    cache: &dyn KvStore,
    layer: usize,
    q: &[f32],
    table: &BlockTable,
    ws: &mut Workspace,
    out: &mut [f32],
) {
    let (h, kvh, d) = (cfg.num_heads, cfg.num_kv_heads, cfg.head_dim);
    assert_eq!(q.len(), h * d);
    assert_eq!(out.len(), h * d);
    assert_eq!(kvh, cache.kv_heads());
    assert_eq!(d, cache.head_dim());
    let kv_len = table.len();
    assert!(kv_len > 0, "decode over empty cache");
    let q_pos = kv_len - 1;
    let block_size = cache.block_size();
    let rs = kvh * d;

    ws.configure(cfg, block_size);
    ws.begin_row();
    let mut pos = 0usize;
    for &block in table.blocks() {
        if pos >= kv_len {
            break;
        }
        let in_block = block_size.min(kv_len - pos);
        match cache.block_view(layer, block) {
            KvBlockView::F32 { k, v } => {
                ws.process_tile(q, &k[..in_block * rs], &v[..in_block * rs], pos, in_block, q_pos);
            }
            KvBlockView::Q8 { k, v } => {
                ws.process_quant_tile(q, &k, &v, pos, in_block, q_pos);
            }
        }
        pos += in_block;
    }
    ws.finish_row(out);
}

/// Decode attention for a whole batch in one step, fanned across
/// `threads` scoped workers with per-worker workspaces.
///
/// * `qs`: `[batch, num_heads * head_dim]` query rows, one per sequence.
/// * `tables`: one block table per sequence (same order).
/// * `out`: `[batch, num_heads * head_dim]`, fully overwritten.
///
/// Sequences are split into contiguous chunks balanced by **KV length**
/// (attention cost is ∝ `table.len()`, so count-based chunking would
/// let one long-context chunk serialize the step), one worker per
/// chunk, at most `threads` chunks. Outputs are **bit-identical** to
/// the serial loop (`threads == 1`): each sequence's computation is
/// independent and its instruction order is unchanged — threading only
/// changes *who* runs it.
pub fn paged_decode_batch(
    cfg: &AttnConfig,
    cache: &dyn KvStore,
    layer: usize,
    qs: &[f32],
    tables: &[&BlockTable],
    threads: usize,
    out: &mut [f32],
) {
    let row = cfg.num_heads * cfg.head_dim;
    let n = tables.len();
    assert_eq!(qs.len(), n * row);
    assert_eq!(out.len(), n * row);
    if n == 0 {
        return;
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        with_workspace(|ws| {
            for i in 0..n {
                paged_decode_attention_into(
                    cfg,
                    cache,
                    layer,
                    &qs[i * row..(i + 1) * row],
                    tables[i],
                    ws,
                    &mut out[i * row..(i + 1) * row],
                );
            }
        });
        return;
    }
    // Cost-balanced contiguous partition (greedy target cut): a chunk
    // closes as soon as its own cost reaches ⌈total/threads⌉, so every
    // chunk but the last carries ≥ target cost — at most `threads`
    // chunks — and a single dominant sequence gets a chunk to itself
    // instead of dragging the rest of the batch onto its worker.
    let costs: Vec<usize> = tables.iter().map(|t| t.len().max(1)).collect();
    let total_cost: usize = costs.iter().sum();
    let target = total_cost.div_ceil(threads);
    std::thread::scope(|s| {
        let mut rest = out;
        let mut start = 0usize;
        while start < n {
            let mut take = 1usize;
            let mut cost = costs[start];
            while cost < target && start + take < n {
                cost += costs[start + take];
                take += 1;
            }
            // `mem::take` moves the slice out so the split-off chunk keeps
            // the full borrow lifetime the spawned worker needs.
            let (chunk_out, tail) = std::mem::take(&mut rest).split_at_mut(take * row);
            rest = tail;
            let q_chunk = &qs[start * row..(start + take) * row];
            let t_chunk = &tables[start..start + take];
            s.spawn(move || {
                let mut ws = Workspace::new();
                for (j, table) in t_chunk.iter().enumerate() {
                    paged_decode_attention_into(
                        cfg,
                        cache,
                        layer,
                        &q_chunk[j * row..(j + 1) * row],
                        table,
                        &mut ws,
                        &mut chunk_out[j * row..(j + 1) * row],
                    );
                }
            });
            start += take;
        }
    });
}

/// Heuristic fan-out width for one decode step: all cores once the
/// batch's total KV footprint is large enough to amortize the scoped
/// thread spawn, serial otherwise (tiny steps lose more to spawn
/// latency than they gain).
///
/// The model drivers spawn one scope per *layer*, but the ratio is
/// layer-invariant: each layer pays one spawn and does one layer's
/// attention over the same `total_kv_tokens`, so a threshold tuned for
/// one layer holds for any depth. (A persistent pool that amortizes
/// spawns across layers is a ROADMAP follow-up.)
pub fn auto_decode_threads(batch: usize, total_kv_tokens: usize) -> usize {
    const MIN_PARALLEL_KV: usize = 2048;
    if batch < 2 || total_kv_tokens < MIN_PARALLEL_KV {
        return 1;
    }
    std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).min(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::gqa::{gqa_attention, Bias};
    use crate::kvcache::{BlockAllocator, PagedKvCache, QuantizedPagedKvCache};
    use crate::util::rng::Rng;

    /// Build a cache holding `kv_len` random tokens; return (cache, table, k, v).
    fn setup(
        kv_len: usize,
        kvh: usize,
        d: usize,
        block_size: usize,
        seed: u64,
    ) -> (PagedKvCache, BlockTable, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let num_blocks = kv_len.div_ceil(block_size) + 2;
        let mut cache = PagedKvCache::new(1, num_blocks, block_size, kvh, d);
        let mut alloc = BlockAllocator::new(num_blocks, block_size);
        let mut table = BlockTable::new();
        table.reserve(kv_len, &mut alloc);
        let k = rng.normal_vec(kv_len * kvh * d, 1.0);
        let v = rng.normal_vec(kv_len * kvh * d, 1.0);
        for t in 0..kv_len {
            let (b, s) = table.append_slot(block_size);
            cache.write_token(0, b, s, &k[t * kvh * d..(t + 1) * kvh * d], &v[t * kvh * d..(t + 1) * kvh * d]);
        }
        (cache, table, k, v)
    }

    #[test]
    fn matches_contiguous_gqa_reference() {
        for (bias, block_size, kv_len) in
            [(Bias::Alibi, 4, 11), (Bias::None, 8, 16), (Bias::Alibi, 16, 3)]
        {
            let cfg = AttnConfig { num_heads: 4, num_kv_heads: 2, head_dim: 8, bias };
            let (cache, table, k, v) = setup(kv_len, 2, 8, block_size, 42);
            let mut rng = Rng::new(7);
            let q = rng.normal_vec(4 * 8, 1.0);
            let paged = paged_decode_attention(&cfg, &cache, 0, &q, &table);
            let reference = gqa_attention(&cfg, &q, &k, &v, 1, kv_len, kv_len - 1);
            for (a, b) in paged.iter().zip(&reference) {
                assert!((a - b).abs() < 1e-4, "bias={bias:?} bs={block_size} kv={kv_len}");
            }
        }
    }

    #[test]
    fn single_token_cache() {
        let cfg = AttnConfig { num_heads: 2, num_kv_heads: 1, head_dim: 4, bias: Bias::Alibi };
        let (cache, table, _, v) = setup(1, 1, 4, 4, 3);
        let q = vec![0.5; 8];
        let out = paged_decode_attention(&cfg, &cache, 0, &q, &table);
        // Softmax over one key = weight 1 → output equals that V row.
        for head in 0..2 {
            for t in 0..4 {
                assert!((out[head * 4 + t] - v[t]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn online_softmax_is_stable_with_huge_scores() {
        let cfg = AttnConfig { num_heads: 1, num_kv_heads: 1, head_dim: 4, bias: Bias::None };
        let mut cache = PagedKvCache::new(1, 2, 4, 1, 4);
        let mut alloc = BlockAllocator::new(2, 4);
        let mut table = BlockTable::new();
        table.reserve(6, &mut alloc);
        for t in 0..6 {
            let (b, s) = table.append_slot(4);
            // Keys with extreme magnitudes to stress the running max.
            let k = vec![if t % 2 == 0 { 50.0 } else { -50.0 }; 4];
            cache.write_token(0, b, s, &k, &[t as f32; 4]);
        }
        let q = vec![1.0; 4];
        let out = paged_decode_attention(&cfg, &cache, 0, &q, &table);
        assert!(out.iter().all(|v| v.is_finite()));
        // Dominated by even-index (k=+50) values {0,2,4} → mean 2.
        assert!((out[0] - 2.0).abs() < 1e-3, "out={out:?}");
    }

    #[test]
    fn partial_final_block() {
        // kv_len not a multiple of block_size: stale slots in the final
        // block must not contribute.
        let cfg = AttnConfig { num_heads: 2, num_kv_heads: 2, head_dim: 4, bias: Bias::None };
        let (mut cache, table, k, v) = setup(5, 2, 4, 4, 9);
        // Poison the unused slots of the last block.
        let last_block = *table.blocks().last().unwrap();
        for slot in 1..4 {
            cache.write_token(0, last_block, slot, &[999.0; 8], &[999.0; 8]);
        }
        let mut rng = Rng::new(10);
        let q = rng.normal_vec(8, 1.0);
        let out = paged_decode_attention(&cfg, &cache, 0, &q, &table);
        let reference = gqa_attention(&cfg, &q, &k, &v, 1, 5, 4);
        for (a, b) in out.iter().zip(&reference) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn all_neg_inf_scores_yield_zeros_not_nan() {
        // Regression for the seed's final-normalization divide-by-zero:
        // a head that saw only −∞ scores must produce finite zeros.
        let cfg = AttnConfig { num_heads: 2, num_kv_heads: 1, head_dim: 4, bias: Bias::None };
        let mut cache = PagedKvCache::new(1, 2, 4, 1, 4);
        let mut alloc = BlockAllocator::new(2, 4);
        let mut table = BlockTable::new();
        table.reserve(3, &mut alloc);
        for _ in 0..3 {
            let (b, s) = table.append_slot(4);
            cache.write_token(0, b, s, &[f32::NEG_INFINITY; 4], &[1.0; 4]);
        }
        let q = vec![1.0; 8];
        let out = paged_decode_attention(&cfg, &cache, 0, &q, &table);
        assert!(out.iter().all(|v| v.is_finite()), "out={out:?}");
        assert!(out.iter().all(|&v| v == 0.0), "out={out:?}");
    }

    #[test]
    fn batch_matches_serial_per_sequence() {
        let cfg = AttnConfig { num_heads: 4, num_kv_heads: 2, head_dim: 8, bias: Bias::Alibi };
        let (kvh, d, block_size) = (2usize, 8usize, 4usize);
        let lens = [3usize, 9, 17, 1];
        let total_blocks: usize = lens.iter().map(|l| l.div_ceil(block_size)).sum::<usize>() + 1;
        let mut cache = PagedKvCache::new(1, total_blocks, block_size, kvh, d);
        let mut alloc = BlockAllocator::new(total_blocks, block_size);
        let mut rng = Rng::new(5);
        let mut tables = Vec::new();
        for &len in &lens {
            let mut t = BlockTable::new();
            assert!(t.reserve(len, &mut alloc));
            for _ in 0..len {
                let (b, s) = t.append_slot(block_size);
                let k = rng.normal_vec(kvh * d, 1.0);
                let v = rng.normal_vec(kvh * d, 1.0);
                cache.write_token(0, b, s, &k, &v);
            }
            tables.push(t);
        }
        let refs: Vec<&BlockTable> = tables.iter().collect();
        let n = lens.len();
        let row = 4 * 8;
        let qs = rng.normal_vec(n * row, 1.0);
        for threads in [1usize, 2, 4] {
            let mut out = vec![0.0f32; n * row];
            paged_decode_batch(&cfg, &cache, 0, &qs, &refs, threads, &mut out);
            for i in 0..n {
                let one = paged_decode_attention(&cfg, &cache, 0, &qs[i * row..(i + 1) * row], refs[i]);
                assert_eq!(&out[i * row..(i + 1) * row], &one[..], "threads={threads} seq={i}");
            }
        }
    }

    #[test]
    fn quantized_cache_decode_tracks_f32_decode() {
        // Same tokens in an f32 and a q8 cache: outputs agree to within
        // the quantization error (tight bounds live in
        // tests/attention_parity.rs — this is the module smoke check).
        let cfg = AttnConfig { num_heads: 4, num_kv_heads: 2, head_dim: 8, bias: Bias::Alibi };
        let (kvh, d, block_size, kv_len) = (2usize, 8usize, 4usize, 13usize);
        let num_blocks = kv_len.div_ceil(block_size) + 1;
        let mut fcache = PagedKvCache::new(1, num_blocks, block_size, kvh, d);
        let mut qcache = QuantizedPagedKvCache::new(1, num_blocks, block_size, kvh, d);
        let mut alloc = BlockAllocator::new(num_blocks, block_size);
        let mut table = BlockTable::new();
        assert!(table.reserve(kv_len, &mut alloc));
        let mut rng = Rng::new(21);
        for _ in 0..kv_len {
            let (b, s) = table.append_slot(block_size);
            let k = rng.normal_vec(kvh * d, 1.0);
            let v = rng.normal_vec(kvh * d, 1.0);
            fcache.write_token(0, b, s, &k, &v);
            qcache.write_token(0, b, s, &k, &v);
        }
        let q = rng.normal_vec(4 * d, 1.0);
        let f = paged_decode_attention(&cfg, &fcache, 0, &q, &table);
        let qz = paged_decode_attention(&cfg, &qcache, 0, &q, &table);
        for (a, b) in f.iter().zip(&qz) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
    }

    #[test]
    fn quantized_batch_bit_identical_across_threads() {
        let cfg = AttnConfig { num_heads: 4, num_kv_heads: 2, head_dim: 8, bias: Bias::None };
        let (kvh, d, block_size) = (2usize, 8usize, 4usize);
        let lens = [3usize, 11, 6];
        let total_blocks: usize = lens.iter().map(|l| l.div_ceil(block_size)).sum::<usize>() + 1;
        let mut cache = QuantizedPagedKvCache::new(1, total_blocks, block_size, kvh, d);
        let mut alloc = BlockAllocator::new(total_blocks, block_size);
        let mut rng = Rng::new(31);
        let mut tables = Vec::new();
        for &len in &lens {
            let mut t = BlockTable::new();
            assert!(t.reserve(len, &mut alloc));
            for _ in 0..len {
                let (b, s) = t.append_slot(block_size);
                cache.write_token(0, b, s, &rng.normal_vec(kvh * d, 1.0), &rng.normal_vec(kvh * d, 1.0));
            }
            tables.push(t);
        }
        let refs: Vec<&BlockTable> = tables.iter().collect();
        let row = 4 * d;
        let qs = rng.normal_vec(lens.len() * row, 1.0);
        let run = |threads: usize| {
            let mut out = vec![0.0f32; lens.len() * row];
            paged_decode_batch(&cfg, &cache, 0, &qs, &refs, threads, &mut out);
            out
        };
        let serial = run(1);
        assert_eq!(serial, run(2));
        assert_eq!(serial, run(3));
    }

    #[test]
    fn auto_threads_heuristic() {
        assert_eq!(auto_decode_threads(1, 1 << 20), 1, "no fan-out for batch 1");
        assert_eq!(auto_decode_threads(8, 16), 1, "no fan-out for tiny KV");
        assert!(auto_decode_threads(8, 1 << 20) >= 1);
    }
}
