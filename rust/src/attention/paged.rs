//! Paged attention — decode **and** prefill straight over the block
//! table, the native mirror of the Pallas kernel.
//!
//! A query row attends over a sequence whose K/V live in non-contiguous
//! pool blocks (via its block table). Since the kernel-core refactor the
//! per-block inner loop lives in [`super::kernel`]: cache blocks are
//! exactly the kernel's KV tiles, so decode and prefill share one
//! block-tiled, group-major online-softmax schedule — each KV block row
//! touched once per *group*, not once per query head, the G× traffic
//! saving the paper's DCU kernel exploits.
//!
//! **Paged-native prefill** ([`paged_prefill_attention_into`]) walks a
//! chunk's visible context tile by tile directly out of the store:
//! dense f32 blocks are borrowed in place, packed 8-bit blocks are
//! dequantized **once per tile** into workspace scratch and shared by
//! every query row that sees the tile (a tile-major walk over detached
//! per-row softmax states — see the kernel docs). The dense
//! per-layer-per-chunk `KvStore::gather` copy the old prefill path paid
//! is gone from the hot path entirely; `gather` survives only as a
//! test/debug dump.
//!
//! [`paged_decode_batch`] and [`paged_prefill_rows_parallel`] fan their
//! work across the **persistent worker pool**
//! (`crate::runtime::pool`, std-only: parked threads, scoped job
//! batches) — one thread-local [`Workspace`] per worker, alive across
//! jobs, layers and steps. Outputs are bit-identical to the serial loop
//! because the work partition depends only on the requested width,
//! rows/sequences are independent, and each row's schedule is unchanged.
//!
//! Storage-dtype agnostic: drivers take `&dyn KvStore` and dispatch per
//! block on [`KvBlockView`] — dense f32 blocks go straight to
//! `process_tile`, packed 8-bit blocks through the kernel's in-tile
//! dequant scratch, so both cache dtypes share one schedule.

use super::gqa::{AttnConfig, ScoreDomain};
use super::kernel::{with_workspace, Workspace};
use crate::kvcache::{BlockTable, KvBlockView, KvCacheDtype, KvStore, TOMBSTONE};
use crate::runtime::pool;

// Sparsity in the walks (see `super::sparsity` for the contract):
//
// Both drivers enumerate the block table by *index* — `tile_pos =
// index · block_size` — so a tile's absolute position survives eviction:
// a tombstoned entry is stepped over without touching the store, and the
// surviving tiles keep exactly the positions (and therefore exactly the
// arithmetic) they had in the dense walk. Window-invisible blocks are
// elided by `SparsityConfig::block_visible` (decode) / clipped per row by
// `SparsityConfig::visible_q_end` (prefill) — the same block partition on
// both paths, which is what makes chunked prefill, whole-prompt prefill
// and decode agree under a window. Score-bound skips
// (`Workspace::tile_skippable`) run only when `skip_enabled()` and are
// counted separately: a window-invisible tile is *outside the schedule*,
// not "skipped".

/// Decode attention for one sequence.
///
/// * `q`: `[num_heads * head_dim]` — the current token's query.
/// * `table`: the sequence's block table; `table.len()` keys are visible
///   (the current token's K/V must already be written).
///
/// Returns `[num_heads * head_dim]`. Allocates only the output; scratch
/// comes from the calling thread's reusable workspace.
pub fn paged_decode_attention(
    cfg: &AttnConfig,
    cache: &dyn KvStore,
    layer: usize,
    q: &[f32],
    table: &BlockTable,
) -> Vec<f32> {
    let mut out = vec![0.0f32; cfg.num_heads * cfg.head_dim];
    with_workspace(|ws| paged_decode_attention_into(cfg, cache, layer, q, table, ws, &mut out));
    out
}

/// Zero-allocation paged decode attention into a caller-owned buffer.
///
/// The workspace may be reused across calls of any shape (see the
/// [`super::kernel`] contract); on a quantized cache the per-tile dequant
/// scratch lives in the same workspace, so steady-state decode stays
/// allocation-free for both dtypes. A head whose every score is −∞
/// yields zeros instead of the seed's `1.0 / 0.0` NaN.
///
/// Sparsity (`cfg.sparsity`): window-invisible and tombstoned blocks are
/// stepped over without touching the store; with skipping enabled, a
/// visible tile whose score upper bound (from the store's per-tile K
/// metadata) provably underflows is elided too. Returns the number of
/// score-bound skips (0 under a dense config — the `skipped_tiles`
/// metrics feed).
///
/// Score domain (`cfg.score_domain`): with [`ScoreDomain::Int`] and a
/// packed (Q8) store, tile *scores* are computed in the integer domain —
/// the query is quantized once per call and K tiles are scored in
/// i8×i8→i32 widening dots without dequantizing K at all
/// (`Workspace::process_quant_tile_int`); V is still dequantized for the
/// value pass. Bounded-error (see the workspace docs), opt-in via
/// `--q8-score-domain int`, default [`ScoreDomain::F32`].
pub fn paged_decode_attention_into(
    cfg: &AttnConfig,
    cache: &dyn KvStore,
    layer: usize,
    q: &[f32],
    table: &BlockTable,
    ws: &mut Workspace,
    out: &mut [f32],
) -> usize {
    let (h, kvh, d) = (cfg.num_heads, cfg.num_kv_heads, cfg.head_dim);
    assert_eq!(q.len(), h * d);
    assert_eq!(out.len(), h * d);
    assert_eq!(kvh, cache.kv_heads());
    assert_eq!(d, cache.head_dim());
    let kv_len = table.len();
    assert!(kv_len > 0, "decode over empty cache");
    let q_pos = kv_len - 1;
    let block_size = cache.block_size();
    let rs = kvh * d;
    let sp = &cfg.sparsity;
    let query_block = q_pos / block_size;
    let skip_enabled = sp.skip_enabled();
    let log_margin = sp.log_margin();
    // Integer-domain scoring only applies to the packed decode walk:
    // f32 tiles score in f32 regardless (there is nothing to save), so
    // a mismatched library caller degrades gracefully instead of
    // quantizing queries for nothing. The CLI rejects the combination.
    let int_domain = cfg.score_domain == ScoreDomain::Int && cache.dtype() == KvCacheDtype::Q8;
    let mut skipped = 0usize;

    ws.configure(cfg, block_size);
    ws.begin_row();
    if int_domain {
        ws.quantize_int_query(q);
    }
    if skip_enabled && sp.skip_threshold > 0.0 && !int_domain {
        // Threshold mode: seed the per-head skip bound with the query's
        // self-score so even the *first* visible tile can participate in
        // score-bound skipping (the own key is always visible, so the
        // final running max is ≥ this seed — see
        // `Workspace::seed_from_self_key`). Not in exact mode (seeding
        // would break the skip-is-bit-identical contract via the corr=0
        // rescale's signed zeros) and not in the int domain (an f32 seed
        // would be compared against integer-domain scores).
        let own_block = table.blocks()[query_block];
        debug_assert_ne!(own_block, TOMBSTONE, "query's own block evicted");
        let self_slot = q_pos % block_size;
        match cache.block_view(layer, own_block) {
            KvBlockView::F32 { k, .. } => {
                ws.seed_from_self_key(q, &k[self_slot * rs..(self_slot + 1) * rs]);
            }
            KvBlockView::Q8 { k, .. } => {
                let (mut kd, vd) = ws.take_quant_scratch();
                k.dequantize_slot_into(self_slot, kvh, d, &mut kd[..rs]);
                ws.seed_from_self_key(q, &kd[..rs]);
                ws.put_quant_scratch(kd, vd);
            }
        }
    }
    for (bi, &block) in table.blocks().iter().enumerate() {
        let tile_pos = bi * block_size;
        if tile_pos >= kv_len {
            break;
        }
        if block == TOMBSTONE {
            debug_assert!(
                !sp.block_visible(bi, query_block),
                "evicted block {bi} still inside sink ∪ window of q_pos {q_pos}"
            );
            continue;
        }
        if !sp.block_visible(bi, query_block) {
            continue;
        }
        let in_block = block_size.min(kv_len - tile_pos);
        if skip_enabled
            && ws.tile_skippable(
                q,
                &mut |head| cache.key_tile_bounds(layer, block, head),
                tile_pos,
                in_block,
                q_pos,
                log_margin,
            )
        {
            skipped += 1;
            continue;
        }
        match cache.block_view(layer, block) {
            KvBlockView::F32 { k, v } => {
                ws.process_tile(q, &k[..in_block * rs], &v[..in_block * rs], tile_pos, in_block, q_pos);
            }
            KvBlockView::Q8 { k, v } => {
                if int_domain {
                    ws.process_quant_tile_int(q, &k, &v, tile_pos, in_block, q_pos);
                } else {
                    ws.process_quant_tile(q, &k, &v, tile_pos, in_block, q_pos);
                }
            }
        }
    }
    ws.finish_row(out);
    skipped
}

/// Minimum query rows per pool job when the store is packed (Q8): each
/// job's walk re-dequantizes its own prefix tiles, so a job must cover
/// enough rows to amortize that dequant against its score work (per
/// (row, context-token, kv-head): one `head_dim` dequant shared by the
/// job's rows vs `2·G·head_dim` score/value FLOPs per row — at 4 rows
/// per job the duplicated dequant is a small fraction of the job).
pub const MIN_Q8_ROWS_PER_JOB: usize = 4;

/// Streamed **paged-native prefill attention** for one chunk of query
/// rows: the visible context is walked tile by tile straight out of the
/// store's block table — no dense gather, no per-layer copy.
///
/// * `q`: `[q_len, num_heads * head_dim]` — the chunk's query rows at
///   absolute positions `q_offset .. q_offset + q_len`; row `r` attends
///   causally to positions `0 ..= q_offset + r`.
/// * `table` must already hold the chunk's K/V
///   (`table.len() >= q_offset + q_len`) — the model writes a layer's
///   K/V before its attention, exactly as the old gather path did.
///
/// The walk is **tile-major**: per physical block, an f32 tile is
/// borrowed in place and a Q8 tile is dequantized **once** into
/// workspace scratch ([`Workspace::take_quant_scratch`]), then folded
/// into every visible query row through detached per-row softmax states
/// ([`Workspace::take_row_states`]). Dequant volume therefore matches
/// the old dense gather (each context token once per call) while the
/// O(context) dense copy and its allocation disappear.
///
/// Bit-exactness: a row's tile partition is the *physical block*
/// partition — independent of chunk boundaries, and the same partition
/// [`paged_decode_attention_into`] uses at the same position — so
/// chunked prefill, whole-prompt prefill, and the step-serial reference
/// all produce identical rows.
///
/// Returns `(quant_tiles, skipped_tiles)`: the number of quantized tiles
/// dequantized (0 on an f32 store — the
/// `EngineMetrics::prefill_dequant_tiles` feed) and the number of
/// per-(row, tile) score-bound skips (0 under a dense config — the
/// `skipped_tiles` feed).
///
/// Sparsity (`cfg.sparsity`): a tile's visible row range is clipped at
/// the head by causality (`r0`) and at the tail by the sliding window
/// (`SparsityConfig::visible_q_end` — rows whose block has slid past the
/// tile). An empty range elides the tile entirely (no dequant);
/// tombstoned entries are stepped over. The clip is the *same
/// block-granular rule* decode applies, so windowed prefill rows stay
/// bit-identical to windowed decode replay.
#[allow(clippy::too_many_arguments)]
pub fn paged_prefill_attention_into(
    cfg: &AttnConfig,
    cache: &dyn KvStore,
    layer: usize,
    q: &[f32],
    q_len: usize,
    q_offset: usize,
    table: &BlockTable,
    ws: &mut Workspace,
    out: &mut [f32],
) -> (usize, usize) {
    let (h, kvh, d) = (cfg.num_heads, cfg.num_kv_heads, cfg.head_dim);
    let row = h * d;
    assert!(q_len > 0, "empty prefill chunk");
    assert_eq!(q.len(), q_len * row);
    assert_eq!(out.len(), q_len * row);
    assert_eq!(kvh, cache.kv_heads());
    assert_eq!(d, cache.head_dim());
    let kv_len = q_offset + q_len;
    assert!(table.len() >= kv_len, "chunk K/V must be written before its attention");
    let block_size = cache.block_size();
    let rs = kvh * d;
    let sp = &cfg.sparsity;
    let skip_enabled = sp.skip_enabled();
    let log_margin = sp.log_margin();

    ws.configure(cfg, block_size);
    let mut states = ws.take_row_states(q_len);
    let mut quant_tiles = 0usize;
    let mut skipped = 0usize;
    for (bi, &block) in table.blocks().iter().enumerate() {
        let tile_pos = bi * block_size;
        if tile_pos >= kv_len {
            break;
        }
        let in_block = block_size.min(kv_len - tile_pos);
        // First query row that sees this tile (causality: q_pos ≥ tile_pos)
        // and one past the last (window: the tile must not have slid out).
        let r0 = tile_pos.saturating_sub(q_offset);
        let r1 = q_len.min(sp.visible_q_end(bi, block_size).saturating_sub(q_offset));
        if block == TOMBSTONE {
            debug_assert!(
                r0 >= r1,
                "evicted block {bi} still visible to prefill rows {r0}..{r1}"
            );
            continue;
        }
        if r0 >= r1 {
            continue; // window-invisible for every row — skip the dequant too
        }
        match cache.block_view(layer, block) {
            KvBlockView::F32 { k, v } => {
                for (r, st) in states[r0..r1].iter_mut().enumerate() {
                    let q_pos = q_offset + r0 + r;
                    let vis = in_block.min(q_pos + 1 - tile_pos);
                    let q_row = &q[(r0 + r) * row..(r0 + r + 1) * row];
                    ws.swap_row_state(st);
                    if skip_enabled
                        && ws.tile_skippable(
                            q_row,
                            &mut |head| cache.key_tile_bounds(layer, block, head),
                            tile_pos,
                            vis,
                            q_pos,
                            log_margin,
                        )
                    {
                        skipped += 1;
                    } else {
                        ws.process_tile(q_row, &k[..in_block * rs], &v[..in_block * rs], tile_pos, vis, q_pos);
                    }
                    ws.swap_row_state(st);
                }
            }
            KvBlockView::Q8 { k, v } => {
                quant_tiles += 1;
                let used = in_block * rs;
                let (mut kd, mut vd) = ws.take_quant_scratch();
                k.dequantize_into(in_block, kvh, d, &mut kd[..used]);
                v.dequantize_into(in_block, kvh, d, &mut vd[..used]);
                for (r, st) in states[r0..r1].iter_mut().enumerate() {
                    let q_pos = q_offset + r0 + r;
                    let vis = in_block.min(q_pos + 1 - tile_pos);
                    let q_row = &q[(r0 + r) * row..(r0 + r + 1) * row];
                    ws.swap_row_state(st);
                    if skip_enabled
                        && ws.tile_skippable(
                            q_row,
                            &mut |head| cache.key_tile_bounds(layer, block, head),
                            tile_pos,
                            vis,
                            q_pos,
                            log_margin,
                        )
                    {
                        skipped += 1;
                    } else {
                        ws.process_tile(q_row, &kd[..used], &vd[..used], tile_pos, vis, q_pos);
                    }
                    ws.swap_row_state(st);
                }
                ws.put_quant_scratch(kd, vd);
            }
        }
    }
    for (r, st) in states[..q_len].iter_mut().enumerate() {
        ws.swap_row_state(st);
        ws.finish_row(&mut out[r * row..(r + 1) * row]);
        ws.swap_row_state(st);
    }
    ws.put_row_states(states);
    (quant_tiles, skipped)
}

/// Row-parallel streamed prefill: splits the chunk's `q_len` query rows
/// into up to `threads` contiguous ranges and fans them across the
/// persistent worker pool (`crate::runtime::pool`), each range running
/// [`paged_prefill_attention_into`] with its worker's thread-local
/// workspace. Query rows are independent given the cache, and a row's
/// tile schedule depends only on its absolute position and the block
/// table — so outputs are **bit-identical** at every width.
///
/// Returns the total `(quant_tiles, skipped_tiles)` across all workers
/// (each range walks its own tiles, so wider fan-outs re-dequantize
/// shared prefixes — the counts are the honest measured numbers).
///
/// On a **packed (Q8) store** the effective width is additionally
/// capped so every job covers at least [`MIN_Q8_ROWS_PER_JOB`] query
/// rows: each job re-dequantizes its own prefix walk, so narrow row
/// ranges would multiply the chunk's dequant work by the fan-out width.
/// The cap bounds the duplicated dequant at a small fraction of each
/// job's score work; outputs are bit-identical at every width, so the
/// cap is purely a scheduling choice (a pinned
/// `NativeBackend::with_prefill_threads` width acts as an upper bound).
#[allow(clippy::too_many_arguments)]
pub fn paged_prefill_rows_parallel(
    cfg: &AttnConfig,
    cache: &dyn KvStore,
    layer: usize,
    q: &[f32],
    q_len: usize,
    q_offset: usize,
    table: &BlockTable,
    threads: usize,
    out: &mut [f32],
) -> (usize, usize) {
    let row = cfg.num_heads * cfg.head_dim;
    assert_eq!(q.len(), q_len * row);
    assert_eq!(out.len(), q_len * row);
    if q_len == 0 {
        return (0, 0);
    }
    let threads = match cache.dtype() {
        KvCacheDtype::F32 => threads.clamp(1, q_len),
        // Bound the per-width dequant duplication: ≥ MIN_Q8_ROWS_PER_JOB
        // rows share each job's in-tile dequant of the prefix (floor
        // division — ceiling would let small chunks split into jobs
        // below the minimum).
        KvCacheDtype::Q8 => threads.clamp(1, (q_len / MIN_Q8_ROWS_PER_JOB).max(1)),
    };
    if threads == 1 {
        return with_workspace(|ws| {
            paged_prefill_attention_into(cfg, cache, layer, q, q_len, q_offset, table, ws, out)
        });
    }
    let per = q_len.div_ceil(threads);
    let n_jobs = q_len.div_ceil(per);
    let mut tile_counts = vec![(0usize, 0usize); n_jobs];
    let mut jobs: Vec<pool::Job<'_>> = Vec::with_capacity(n_jobs);
    let mut rest = out;
    let mut counts_rest = tile_counts.as_mut_slice();
    let mut start = 0usize;
    while start < q_len {
        let take = per.min(q_len - start);
        let (chunk_out, tail) = std::mem::take(&mut rest).split_at_mut(take * row);
        rest = tail;
        let (count, ctail) = std::mem::take(&mut counts_rest).split_at_mut(1);
        counts_rest = ctail;
        let q_chunk = &q[start * row..(start + take) * row];
        let off = q_offset + start;
        jobs.push(Box::new(move || {
            count[0] = with_workspace(|ws| {
                paged_prefill_attention_into(cfg, cache, layer, q_chunk, take, off, table, ws, chunk_out)
            });
        }));
        start += take;
    }
    pool::global().run(jobs);
    tile_counts.iter().fold((0, 0), |(tq, ts), &(q2, s2)| (tq + q2, ts + s2))
}

/// Decode attention for a whole batch in one step, fanned across
/// `threads` contiguous chunks on the persistent worker pool.
///
/// * `qs`: `[batch, num_heads * head_dim]` query rows, one per sequence.
/// * `tables`: one block table per sequence (same order).
/// * `out`: `[batch, num_heads * head_dim]`, fully overwritten.
///
/// Sequences are split into contiguous chunks balanced by **KV length**
/// (attention cost is ∝ `table.len()`, so count-based chunking would
/// let one long-context chunk serialize the step), one pool job per
/// chunk, at most `threads` chunks. Outputs are **bit-identical** to
/// the serial loop (`threads == 1`): each sequence's computation is
/// independent and its instruction order is unchanged — the pool only
/// changes *who* runs it.
///
/// Returns the batch's total score-bound tile skips (0 under a dense
/// config) — the decode-side `skipped_tiles` metrics feed.
pub fn paged_decode_batch(
    cfg: &AttnConfig,
    cache: &dyn KvStore,
    layer: usize,
    qs: &[f32],
    tables: &[&BlockTable],
    threads: usize,
    out: &mut [f32],
) -> usize {
    let row = cfg.num_heads * cfg.head_dim;
    let n = tables.len();
    assert_eq!(qs.len(), n * row);
    assert_eq!(out.len(), n * row);
    if n == 0 {
        return 0;
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return with_workspace(|ws| {
            let mut skipped = 0usize;
            for i in 0..n {
                skipped += paged_decode_attention_into(
                    cfg,
                    cache,
                    layer,
                    &qs[i * row..(i + 1) * row],
                    tables[i],
                    ws,
                    &mut out[i * row..(i + 1) * row],
                );
            }
            skipped
        });
    }
    // Cost-balanced contiguous partition (greedy target cut): a chunk
    // closes as soon as its own cost reaches ⌈total/threads⌉, so every
    // chunk but the last carries ≥ target cost — at most `threads`
    // chunks — and a single dominant sequence gets a chunk to itself
    // instead of dragging the rest of the batch onto its worker.
    let costs: Vec<usize> = tables.iter().map(|t| t.len().max(1)).collect();
    let total_cost: usize = costs.iter().sum();
    let target = total_cost.div_ceil(threads);
    let mut jobs: Vec<pool::Job<'_>> = Vec::with_capacity(threads);
    let mut skip_counts = vec![0usize; threads.min(n)];
    let mut rest = out;
    let mut counts_rest = skip_counts.as_mut_slice();
    let mut start = 0usize;
    while start < n {
        let mut take = 1usize;
        let mut cost = costs[start];
        while cost < target && start + take < n {
            cost += costs[start + take];
            take += 1;
        }
        // `mem::take` moves the slice out so the split-off chunk keeps
        // the full borrow lifetime the pool job needs.
        let (chunk_out, tail) = std::mem::take(&mut rest).split_at_mut(take * row);
        rest = tail;
        let (count, ctail) = std::mem::take(&mut counts_rest).split_at_mut(1);
        counts_rest = ctail;
        let q_chunk = &qs[start * row..(start + take) * row];
        let t_chunk = &tables[start..start + take];
        jobs.push(Box::new(move || {
            // The worker's thread-local workspace persists across jobs,
            // layers and steps — scratch grows once per worker.
            with_workspace(|ws| {
                for (j, table) in t_chunk.iter().enumerate() {
                    count[0] += paged_decode_attention_into(
                        cfg,
                        cache,
                        layer,
                        &q_chunk[j * row..(j + 1) * row],
                        table,
                        ws,
                        &mut chunk_out[j * row..(j + 1) * row],
                    );
                }
            });
        }));
        start += take;
    }
    pool::global().run(jobs);
    skip_counts.iter().sum()
}

/// Heuristic fan-out width for one decode step: all cores once the
/// batch's total KV footprint is large enough to amortize the fan-out
/// overhead, serial otherwise (tiny steps lose more to job dispatch
/// than they gain).
///
/// Since the persistent-pool refactor the per-layer cost is a batch of
/// queue pushes plus a condvar wakeup on parked workers
/// (`crate::runtime::pool`) — no thread spawn or teardown — but the
/// ratio argument is unchanged and layer-invariant: each layer pays one
/// dispatch and does one layer's attention over the same
/// `total_kv_tokens`, so a threshold tuned for one layer holds for any
/// depth. The serial path additionally skips the pool entirely (no
/// boxing, caller's thread-local workspace).
pub fn auto_decode_threads(batch: usize, total_kv_tokens: usize) -> usize {
    const MIN_PARALLEL_KV: usize = 2048;
    if batch < 2 || total_kv_tokens < MIN_PARALLEL_KV {
        return 1;
    }
    std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).min(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::alibi::alibi_slopes;
    use crate::attention::gqa::{gqa_attention, Bias};
    use crate::attention::SparsityConfig;
    use crate::kvcache::{BlockAllocator, PagedKvCache, QuantizedPagedKvCache};
    use crate::util::rng::Rng;

    /// Build a cache holding `kv_len` random tokens; return (cache, table, k, v).
    fn setup(
        kv_len: usize,
        kvh: usize,
        d: usize,
        block_size: usize,
        seed: u64,
    ) -> (PagedKvCache, BlockTable, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let num_blocks = kv_len.div_ceil(block_size) + 2;
        let mut cache = PagedKvCache::new(1, num_blocks, block_size, kvh, d);
        let mut alloc = BlockAllocator::new(num_blocks, block_size);
        let mut table = BlockTable::new();
        table.reserve(kv_len, &mut alloc);
        let k = rng.normal_vec(kv_len * kvh * d, 1.0);
        let v = rng.normal_vec(kv_len * kvh * d, 1.0);
        for t in 0..kv_len {
            let (b, s) = table.append_slot(block_size);
            cache.write_token(0, b, s, &k[t * kvh * d..(t + 1) * kvh * d], &v[t * kvh * d..(t + 1) * kvh * d]);
        }
        (cache, table, k, v)
    }

    #[test]
    fn matches_contiguous_gqa_reference() {
        for (bias, block_size, kv_len) in
            [(Bias::Alibi, 4, 11), (Bias::None, 8, 16), (Bias::Alibi, 16, 3)]
        {
            let cfg = AttnConfig::dense(4, 2, 8, bias);
            let (cache, table, k, v) = setup(kv_len, 2, 8, block_size, 42);
            let mut rng = Rng::new(7);
            let q = rng.normal_vec(4 * 8, 1.0);
            let paged = paged_decode_attention(&cfg, &cache, 0, &q, &table);
            let reference = gqa_attention(&cfg, &q, &k, &v, 1, kv_len, kv_len - 1);
            for (a, b) in paged.iter().zip(&reference) {
                assert!((a - b).abs() < 1e-4, "bias={bias:?} bs={block_size} kv={kv_len}");
            }
        }
    }

    #[test]
    fn single_token_cache() {
        let cfg = AttnConfig::dense(2, 1, 4, Bias::Alibi);
        let (cache, table, _, v) = setup(1, 1, 4, 4, 3);
        let q = vec![0.5; 8];
        let out = paged_decode_attention(&cfg, &cache, 0, &q, &table);
        // Softmax over one key = weight 1 → output equals that V row.
        for head in 0..2 {
            for t in 0..4 {
                assert!((out[head * 4 + t] - v[t]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn online_softmax_is_stable_with_huge_scores() {
        let cfg = AttnConfig::dense(1, 1, 4, Bias::None);
        let mut cache = PagedKvCache::new(1, 2, 4, 1, 4);
        let mut alloc = BlockAllocator::new(2, 4);
        let mut table = BlockTable::new();
        table.reserve(6, &mut alloc);
        for t in 0..6 {
            let (b, s) = table.append_slot(4);
            // Keys with extreme magnitudes to stress the running max.
            let k = vec![if t % 2 == 0 { 50.0 } else { -50.0 }; 4];
            cache.write_token(0, b, s, &k, &[t as f32; 4]);
        }
        let q = vec![1.0; 4];
        let out = paged_decode_attention(&cfg, &cache, 0, &q, &table);
        assert!(out.iter().all(|v| v.is_finite()));
        // Dominated by even-index (k=+50) values {0,2,4} → mean 2.
        assert!((out[0] - 2.0).abs() < 1e-3, "out={out:?}");
    }

    #[test]
    fn partial_final_block() {
        // kv_len not a multiple of block_size: stale slots in the final
        // block must not contribute.
        let cfg = AttnConfig::dense(2, 2, 4, Bias::None);
        let (mut cache, table, k, v) = setup(5, 2, 4, 4, 9);
        // Poison the unused slots of the last block.
        let last_block = *table.blocks().last().unwrap();
        for slot in 1..4 {
            cache.write_token(0, last_block, slot, &[999.0; 8], &[999.0; 8]);
        }
        let mut rng = Rng::new(10);
        let q = rng.normal_vec(8, 1.0);
        let out = paged_decode_attention(&cfg, &cache, 0, &q, &table);
        let reference = gqa_attention(&cfg, &q, &k, &v, 1, 5, 4);
        for (a, b) in out.iter().zip(&reference) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn all_neg_inf_scores_yield_zeros_not_nan() {
        // Regression for the seed's final-normalization divide-by-zero:
        // a head that saw only −∞ scores must produce finite zeros.
        let cfg = AttnConfig::dense(2, 1, 4, Bias::None);
        let mut cache = PagedKvCache::new(1, 2, 4, 1, 4);
        let mut alloc = BlockAllocator::new(2, 4);
        let mut table = BlockTable::new();
        table.reserve(3, &mut alloc);
        for _ in 0..3 {
            let (b, s) = table.append_slot(4);
            cache.write_token(0, b, s, &[f32::NEG_INFINITY; 4], &[1.0; 4]);
        }
        let q = vec![1.0; 8];
        let out = paged_decode_attention(&cfg, &cache, 0, &q, &table);
        assert!(out.iter().all(|v| v.is_finite()), "out={out:?}");
        assert!(out.iter().all(|&v| v == 0.0), "out={out:?}");
    }

    #[test]
    fn batch_matches_serial_per_sequence() {
        let cfg = AttnConfig::dense(4, 2, 8, Bias::Alibi);
        let (kvh, d, block_size) = (2usize, 8usize, 4usize);
        let lens = [3usize, 9, 17, 1];
        let total_blocks: usize = lens.iter().map(|l| l.div_ceil(block_size)).sum::<usize>() + 1;
        let mut cache = PagedKvCache::new(1, total_blocks, block_size, kvh, d);
        let mut alloc = BlockAllocator::new(total_blocks, block_size);
        let mut rng = Rng::new(5);
        let mut tables = Vec::new();
        for &len in &lens {
            let mut t = BlockTable::new();
            assert!(t.reserve(len, &mut alloc));
            for _ in 0..len {
                let (b, s) = t.append_slot(block_size);
                let k = rng.normal_vec(kvh * d, 1.0);
                let v = rng.normal_vec(kvh * d, 1.0);
                cache.write_token(0, b, s, &k, &v);
            }
            tables.push(t);
        }
        let refs: Vec<&BlockTable> = tables.iter().collect();
        let n = lens.len();
        let row = 4 * 8;
        let qs = rng.normal_vec(n * row, 1.0);
        for threads in [1usize, 2, 4] {
            let mut out = vec![0.0f32; n * row];
            paged_decode_batch(&cfg, &cache, 0, &qs, &refs, threads, &mut out);
            for i in 0..n {
                let one = paged_decode_attention(&cfg, &cache, 0, &qs[i * row..(i + 1) * row], refs[i]);
                assert_eq!(&out[i * row..(i + 1) * row], &one[..], "threads={threads} seq={i}");
            }
        }
    }

    #[test]
    fn quantized_cache_decode_tracks_f32_decode() {
        // Same tokens in an f32 and a q8 cache: outputs agree to within
        // the quantization error (tight bounds live in
        // tests/attention_parity.rs — this is the module smoke check).
        let cfg = AttnConfig::dense(4, 2, 8, Bias::Alibi);
        let (kvh, d, block_size, kv_len) = (2usize, 8usize, 4usize, 13usize);
        let num_blocks = kv_len.div_ceil(block_size) + 1;
        let mut fcache = PagedKvCache::new(1, num_blocks, block_size, kvh, d);
        let mut qcache = QuantizedPagedKvCache::new(1, num_blocks, block_size, kvh, d);
        let mut alloc = BlockAllocator::new(num_blocks, block_size);
        let mut table = BlockTable::new();
        assert!(table.reserve(kv_len, &mut alloc));
        let mut rng = Rng::new(21);
        for _ in 0..kv_len {
            let (b, s) = table.append_slot(block_size);
            let k = rng.normal_vec(kvh * d, 1.0);
            let v = rng.normal_vec(kvh * d, 1.0);
            fcache.write_token(0, b, s, &k, &v);
            qcache.write_token(0, b, s, &k, &v);
        }
        let q = rng.normal_vec(4 * d, 1.0);
        let f = paged_decode_attention(&cfg, &fcache, 0, &q, &table);
        let qz = paged_decode_attention(&cfg, &qcache, 0, &q, &table);
        for (a, b) in f.iter().zip(&qz) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
    }

    #[test]
    fn quantized_batch_bit_identical_across_threads() {
        let cfg = AttnConfig::dense(4, 2, 8, Bias::None);
        let (kvh, d, block_size) = (2usize, 8usize, 4usize);
        let lens = [3usize, 11, 6];
        let total_blocks: usize = lens.iter().map(|l| l.div_ceil(block_size)).sum::<usize>() + 1;
        let mut cache = QuantizedPagedKvCache::new(1, total_blocks, block_size, kvh, d);
        let mut alloc = BlockAllocator::new(total_blocks, block_size);
        let mut rng = Rng::new(31);
        let mut tables = Vec::new();
        for &len in &lens {
            let mut t = BlockTable::new();
            assert!(t.reserve(len, &mut alloc));
            for _ in 0..len {
                let (b, s) = t.append_slot(block_size);
                cache.write_token(0, b, s, &rng.normal_vec(kvh * d, 1.0), &rng.normal_vec(kvh * d, 1.0));
            }
            tables.push(t);
        }
        let refs: Vec<&BlockTable> = tables.iter().collect();
        let row = 4 * d;
        let qs = rng.normal_vec(lens.len() * row, 1.0);
        let run = |threads: usize| {
            let mut out = vec![0.0f32; lens.len() * row];
            paged_decode_batch(&cfg, &cache, 0, &qs, &refs, threads, &mut out);
            out
        };
        let serial = run(1);
        assert_eq!(serial, run(2));
        assert_eq!(serial, run(3));
    }

    #[test]
    fn int_domain_decode_tracks_f32_reference() {
        // Integer-domain q8 scoring adds query-quantization error on top
        // of the KV grid error; outputs must stay close to the f32-cache
        // reference (tight grids live in tests/simd_parity.rs).
        let mut cfg = AttnConfig::dense(4, 2, 8, Bias::Alibi);
        cfg.score_domain = ScoreDomain::Int;
        let (kvh, d, block_size, kv_len) = (2usize, 8usize, 4usize, 13usize);
        let num_blocks = kv_len.div_ceil(block_size) + 1;
        let mut fcache = PagedKvCache::new(1, num_blocks, block_size, kvh, d);
        let mut qcache = QuantizedPagedKvCache::new(1, num_blocks, block_size, kvh, d);
        let mut alloc = BlockAllocator::new(num_blocks, block_size);
        let mut table = BlockTable::new();
        assert!(table.reserve(kv_len, &mut alloc));
        let mut rng = Rng::new(47);
        for _ in 0..kv_len {
            let (b, s) = table.append_slot(block_size);
            let k = rng.normal_vec(kvh * d, 1.0);
            let v = rng.normal_vec(kvh * d, 1.0);
            fcache.write_token(0, b, s, &k, &v);
            qcache.write_token(0, b, s, &k, &v);
        }
        let q = rng.normal_vec(4 * d, 1.0);
        let f = paged_decode_attention(&cfg, &fcache, 0, &q, &table);
        let qi = paged_decode_attention(&cfg, &qcache, 0, &q, &table);
        for (a, b) in f.iter().zip(&qi) {
            assert!((a - b).abs() < 0.1, "{a} vs {b}");
        }
        // On an f32 store the knob is inert: bit-identical to F32 mode.
        let dense = AttnConfig::dense(4, 2, 8, Bias::Alibi);
        assert_eq!(f, paged_decode_attention(&dense, &fcache, 0, &q, &table));
    }

    #[test]
    fn int_domain_batch_bit_identical_across_threads() {
        let mut cfg = AttnConfig::dense(4, 2, 8, Bias::None);
        cfg.score_domain = ScoreDomain::Int;
        let (kvh, d, block_size) = (2usize, 8usize, 4usize);
        let lens = [3usize, 11, 6];
        let total_blocks: usize = lens.iter().map(|l| l.div_ceil(block_size)).sum::<usize>() + 1;
        let mut cache = QuantizedPagedKvCache::new(1, total_blocks, block_size, kvh, d);
        let mut alloc = BlockAllocator::new(total_blocks, block_size);
        let mut rng = Rng::new(37);
        let mut tables = Vec::new();
        for &len in &lens {
            let mut t = BlockTable::new();
            assert!(t.reserve(len, &mut alloc));
            for _ in 0..len {
                let (b, s) = t.append_slot(block_size);
                cache.write_token(0, b, s, &rng.normal_vec(kvh * d, 1.0), &rng.normal_vec(kvh * d, 1.0));
            }
            tables.push(t);
        }
        let refs: Vec<&BlockTable> = tables.iter().collect();
        let row = 4 * d;
        let qs = rng.normal_vec(lens.len() * row, 1.0);
        let run = |threads: usize| {
            let mut out = vec![0.0f32; lens.len() * row];
            paged_decode_batch(&cfg, &cache, 0, &qs, &refs, threads, &mut out);
            out
        };
        let serial = run(1);
        assert_eq!(serial, run(2));
        assert_eq!(serial, run(3));
    }

    #[test]
    fn auto_threads_heuristic() {
        assert_eq!(auto_decode_threads(1, 1 << 20), 1, "no fan-out for batch 1");
        assert_eq!(auto_decode_threads(8, 16), 1, "no fan-out for tiny KV");
        assert!(auto_decode_threads(8, 1 << 20) >= 1);
    }

    #[test]
    fn streamed_prefill_matches_contiguous_reference() {
        // The paged-native prefill walk must agree with the contiguous
        // kernel over the gathered context (different tile partition →
        // fp tolerance, not bit equality).
        for (bias, block_size, base, q_len) in
            [(Bias::Alibi, 4, 7, 5), (Bias::None, 8, 0, 9), (Bias::Alibi, 16, 20, 3)]
        {
            let (h, kvh, d) = (4usize, 2usize, 8usize);
            let cfg = AttnConfig::dense(h, kvh, d, bias);
            let kv_len = base + q_len;
            let (cache, table, k, v) = setup(kv_len, kvh, d, block_size, 91);
            let mut rng = Rng::new(12);
            let q = rng.normal_vec(q_len * h * d, 1.0);
            let mut ws = Workspace::new();
            let mut out = vec![0.0f32; q_len * h * d];
            let (tiles, skips) =
                paged_prefill_attention_into(&cfg, &cache, 0, &q, q_len, base, &table, &mut ws, &mut out);
            assert_eq!(tiles, 0, "f32 store dequantizes nothing");
            assert_eq!(skips, 0, "dense config never skips");
            let reference = gqa_attention(&cfg, &q, &k, &v, q_len, kv_len, base);
            for (i, (a, b)) in out.iter().zip(&reference).enumerate() {
                assert!(
                    (a - b).abs() < 1e-4,
                    "bias={bias:?} bs={block_size} base={base} i={i}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn streamed_prefill_rows_bit_identical_to_paged_decode() {
        // Stronger than the contiguous check: a streamed prefill row's
        // tile partition IS the block partition, so each row must be
        // BIT-identical to paged decode replay of the same position
        // (f32 store: values never requantize).
        let (h, kvh, d, block_size) = (4usize, 2usize, 8usize, 4usize);
        let cfg = AttnConfig::dense(h, kvh, d, Bias::Alibi);
        let (base, q_len) = (6usize, 7usize);
        let kv_len = base + q_len;
        let mut rng = Rng::new(55);
        let num_blocks = kv_len.div_ceil(block_size) + 1;
        let mut cache = PagedKvCache::new(1, num_blocks, block_size, kvh, d);
        let mut alloc = BlockAllocator::new(num_blocks, block_size);
        let mut table = BlockTable::new();
        assert!(table.reserve(kv_len, &mut alloc));
        let q = rng.normal_vec(q_len * h * d, 1.0);
        // Write tokens one at a time; capture the decode reference for
        // each prefill row at exactly its causal cache state.
        let mut dec_rows = Vec::new();
        for t in 0..kv_len {
            let (b, s) = table.append_slot(block_size);
            let k = rng.normal_vec(kvh * d, 1.0);
            let v = rng.normal_vec(kvh * d, 1.0);
            cache.write_token(0, b, s, &k, &v);
            if t >= base {
                let r = t - base;
                dec_rows.push(paged_decode_attention(&cfg, &cache, 0, &q[r * h * d..(r + 1) * h * d], &table));
            }
        }
        let mut ws = Workspace::new();
        let mut out = vec![0.0f32; q_len * h * d];
        paged_prefill_attention_into(&cfg, &cache, 0, &q, q_len, base, &table, &mut ws, &mut out);
        for (r, dec) in dec_rows.iter().enumerate() {
            assert_eq!(&out[r * h * d..(r + 1) * h * d], &dec[..], "row {r} diverged from decode");
        }
    }

    #[test]
    fn streamed_prefill_parallel_bit_identical_at_every_width() {
        // The pool fan-out must never change numerics: row partition
        // depends only on the width, each row's walk is unchanged.
        let (h, kvh, d, block_size) = (4usize, 2usize, 8usize, 4usize);
        let cfg = AttnConfig::dense(h, kvh, d, Bias::Alibi);
        for (base, q_len) in [(0usize, 7usize), (9, 5), (0, 70)] {
            let kv_len = base + q_len;
            let (cache, table, _, _) = setup(kv_len, kvh, d, block_size, 71);
            let mut rng = Rng::new(13);
            let q = rng.normal_vec(q_len * h * d, 1.0);
            let mut serial = vec![0.0f32; q_len * h * d];
            paged_prefill_rows_parallel(&cfg, &cache, 0, &q, q_len, base, &table, 1, &mut serial);
            for threads in [2usize, 3, 8] {
                let mut out = vec![0.0f32; q_len * h * d];
                paged_prefill_rows_parallel(&cfg, &cache, 0, &q, q_len, base, &table, threads, &mut out);
                assert_eq!(out, serial, "threads={threads} base={base} q_len={q_len}");
            }
        }
    }

    #[test]
    fn streamed_prefill_q8_counts_tiles_and_tracks_f32() {
        // Same tokens in an f32 and a q8 store: the streamed prefill
        // outputs agree within quantization error (tight grid bounds
        // live in tests/attention_parity.rs), and the q8 walk reports
        // its dequantized tile count.
        let (h, kvh, d, block_size) = (4usize, 2usize, 8usize, 4usize);
        let cfg = AttnConfig::dense(h, kvh, d, Bias::Alibi);
        let (base, q_len) = (5usize, 6usize);
        let kv_len = base + q_len;
        let num_blocks = kv_len.div_ceil(block_size) + 1;
        let mut fcache = PagedKvCache::new(1, num_blocks, block_size, kvh, d);
        let mut qcache = QuantizedPagedKvCache::new(1, num_blocks, block_size, kvh, d);
        let mut alloc = BlockAllocator::new(num_blocks, block_size);
        let mut table = BlockTable::new();
        assert!(table.reserve(kv_len, &mut alloc));
        let mut rng = Rng::new(61);
        for _ in 0..kv_len {
            let (b, s) = table.append_slot(block_size);
            let k = rng.normal_vec(kvh * d, 1.0);
            let v = rng.normal_vec(kvh * d, 1.0);
            fcache.write_token(0, b, s, &k, &v);
            qcache.write_token(0, b, s, &k, &v);
        }
        let q = rng.normal_vec(q_len * h * d, 1.0);
        let mut ws = Workspace::new();
        let mut f_out = vec![0.0f32; q_len * h * d];
        let mut q_out = vec![0.0f32; q_len * h * d];
        let (f_tiles, f_skips) =
            paged_prefill_attention_into(&cfg, &fcache, 0, &q, q_len, base, &table, &mut ws, &mut f_out);
        let (q_tiles, q_skips) =
            paged_prefill_attention_into(&cfg, &qcache, 0, &q, q_len, base, &table, &mut ws, &mut q_out);
        assert_eq!(f_tiles, 0);
        assert_eq!((f_skips, q_skips), (0, 0), "dense config never skips");
        assert_eq!(q_tiles, kv_len.div_ceil(block_size), "one dequant per visible tile");
        for (a, b) in f_out.iter().zip(&q_out) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }

        // The parallel driver caps the q8 fan-out at one job per
        // MIN_Q8_ROWS_PER_JOB rows, so total dequant work stays bounded
        // even at an absurd requested width — and numerics never change.
        let mut par_out = vec![0.0f32; q_len * h * d];
        let (par_tiles, _) =
            paged_prefill_rows_parallel(&cfg, &qcache, 0, &q, q_len, base, &table, 64, &mut par_out);
        assert_eq!(par_out, q_out, "width must not change numerics");
        let max_jobs = (q_len / MIN_Q8_ROWS_PER_JOB).max(1);
        assert!(
            par_tiles <= max_jobs * kv_len.div_ceil(block_size),
            "q8 dequant amplification must be capped: {par_tiles}"
        );
    }

    #[test]
    fn windowed_decode_matches_masked_naive_reference() {
        // The windowed walk against an independent f64 softmax computed
        // over exactly the positions `block_visible` admits — catches
        // both a wrong mask and a walk that shifts tile positions.
        let (h, kvh, d, block_size) = (4usize, 2usize, 8usize, 4usize);
        let mut cfg = AttnConfig::dense(h, kvh, d, Bias::Alibi);
        cfg.sparsity = SparsityConfig::windowed(2, 1);
        let kv_len = 23usize;
        let (cache, table, k, v) = setup(kv_len, kvh, d, block_size, 99);
        let mut rng = Rng::new(17);
        let q = rng.normal_vec(h * d, 1.0);
        let out = paged_decode_attention(&cfg, &cache, 0, &q, &table);

        let q_pos = kv_len - 1;
        let qb = q_pos / block_size;
        let slopes = alibi_slopes(h);
        let scale = 1.0 / (d as f64).sqrt();
        let g = h / kvh;
        let rs = kvh * d;
        for head in 0..h {
            let kh = head / g;
            let qv = &q[head * d..(head + 1) * d];
            let mut scores = Vec::new();
            let mut idx = Vec::new();
            for j in 0..kv_len {
                if !cfg.sparsity.block_visible(j / block_size, qb) {
                    continue;
                }
                let kr = &k[j * rs + kh * d..j * rs + (kh + 1) * d];
                let dot: f64 = qv.iter().zip(kr).map(|(a, b)| *a as f64 * *b as f64).sum();
                scores.push(dot * scale - slopes[head] as f64 * (q_pos - j) as f64);
                idx.push(j);
            }
            assert!(scores.len() < kv_len, "window must mask something at this shape");
            let m = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let w: Vec<f64> = scores.iter().map(|s| (s - m).exp()).collect();
            let l: f64 = w.iter().sum();
            for t in 0..d {
                let acc: f64 = w
                    .iter()
                    .zip(&idx)
                    .map(|(wj, &j)| wj * v[j * rs + kh * d + t] as f64)
                    .sum();
                let expect = (acc / l) as f32;
                let got = out[head * d + t];
                assert!((got - expect).abs() < 1e-4, "head={head} t={t}: {got} vs {expect}");
            }
        }
    }

    #[test]
    fn window_eviction_is_numerics_invariant() {
        // Freeing everything behind the eviction frontier must leave the
        // windowed walk bit-identical: index enumeration preserves the
        // surviving tiles' absolute positions and the tombstoned entries
        // were invisible already.
        let (h, kvh, d, bs) = (4usize, 2usize, 8usize, 4usize);
        let mut cfg = AttnConfig::dense(h, kvh, d, Bias::Alibi);
        cfg.sparsity = SparsityConfig::windowed(2, 1);
        let kv_len = 27usize;
        let num_blocks = kv_len.div_ceil(bs) + 1;
        let mut cache = PagedKvCache::new(1, num_blocks, bs, kvh, d);
        let mut alloc = BlockAllocator::new(num_blocks, bs);
        let mut table = BlockTable::new();
        assert!(table.reserve(kv_len, &mut alloc));
        let mut rng = Rng::new(23);
        for _ in 0..kv_len {
            let (b, s) = table.append_slot(bs);
            let k = rng.normal_vec(kvh * d, 1.0);
            let v = rng.normal_vec(kvh * d, 1.0);
            cache.write_token(0, b, s, &k, &v);
        }
        let q = rng.normal_vec(h * d, 1.0);

        let dense_cfg = AttnConfig::dense(h, kvh, d, Bias::Alibi);
        let dense = paged_decode_attention(&dense_cfg, &cache, 0, &q, &table);
        let before = paged_decode_attention(&cfg, &cache, 0, &q, &table);
        assert_ne!(dense, before, "window must actually mask at this shape");

        let used_before = alloc.num_used();
        let frontier = cfg.sparsity.evict_frontier(kv_len - 1, bs);
        let freed = table.evict_leading(cfg.sparsity.sink_blocks, frontier, &mut alloc);
        assert!(freed > 0, "long context must evict something");
        assert_eq!(alloc.num_used(), used_before - freed, "freed blocks return to the pool");

        let after = paged_decode_attention(&cfg, &cache, 0, &q, &table);
        assert_eq!(after, before, "eviction changed windowed decode numerics");
    }

    #[test]
    fn windowed_prefill_rows_bit_identical_to_windowed_decode() {
        // The prefill row clip (`visible_q_end`) and the decode mask
        // (`block_visible`) are the same block partition: every prefill
        // row must equal the decode replay at its causal cache state.
        let (h, kvh, d, bs) = (4usize, 2usize, 8usize, 4usize);
        let mut cfg = AttnConfig::dense(h, kvh, d, Bias::Alibi);
        cfg.sparsity = SparsityConfig::windowed(2, 1);
        let (base, q_len) = (10usize, 9usize);
        let kv_len = base + q_len;
        let mut rng = Rng::new(77);
        let num_blocks = kv_len.div_ceil(bs) + 1;
        let mut cache = PagedKvCache::new(1, num_blocks, bs, kvh, d);
        let mut alloc = BlockAllocator::new(num_blocks, bs);
        let mut table = BlockTable::new();
        assert!(table.reserve(kv_len, &mut alloc));
        let q = rng.normal_vec(q_len * h * d, 1.0);
        let mut dec_rows = Vec::new();
        for t in 0..kv_len {
            let (b, s) = table.append_slot(bs);
            let k = rng.normal_vec(kvh * d, 1.0);
            let v = rng.normal_vec(kvh * d, 1.0);
            cache.write_token(0, b, s, &k, &v);
            if t >= base {
                let r = t - base;
                dec_rows
                    .push(paged_decode_attention(&cfg, &cache, 0, &q[r * h * d..(r + 1) * h * d], &table));
            }
        }
        let mut ws = Workspace::new();
        let mut out = vec![0.0f32; q_len * h * d];
        let (_, skipped) =
            paged_prefill_attention_into(&cfg, &cache, 0, &q, q_len, base, &table, &mut ws, &mut out);
        assert_eq!(skipped, 0, "window-invisible tiles are not score-bound skips");
        for (r, dec) in dec_rows.iter().enumerate() {
            assert_eq!(&out[r * h * d..(r + 1) * h * d], &dec[..], "row {r} diverged from decode");
        }
        // And the parallel fan-out preserves the windowed rows too.
        for threads in [2usize, 4] {
            let mut par = vec![0.0f32; q_len * h * d];
            paged_prefill_rows_parallel(&cfg, &cache, 0, &q, q_len, base, &table, threads, &mut par);
            assert_eq!(par, out, "threads={threads}");
        }
    }

    #[test]
    fn windowed_q8_prefill_elides_invisible_tiles_without_dequant() {
        // A tile no chunk row can see must not even be dequantized: the
        // quant-tile count drops to exactly the visible-block count.
        let (h, kvh, d, bs) = (4usize, 2usize, 8usize, 4usize);
        let mut cfg = AttnConfig::dense(h, kvh, d, Bias::Alibi);
        cfg.sparsity = SparsityConfig::windowed(1, 1);
        let (base, q_len) = (16usize, 4usize);
        let kv_len = base + q_len; // 5 blocks; rows live in block 4
        let num_blocks = kv_len.div_ceil(bs) + 1;
        let mut qcache = QuantizedPagedKvCache::new(1, num_blocks, bs, kvh, d);
        let mut alloc = BlockAllocator::new(num_blocks, bs);
        let mut table = BlockTable::new();
        assert!(table.reserve(kv_len, &mut alloc));
        let mut rng = Rng::new(31);
        for _ in 0..kv_len {
            let (b, s) = table.append_slot(bs);
            let k = rng.normal_vec(kvh * d, 1.0);
            let v = rng.normal_vec(kvh * d, 1.0);
            qcache.write_token(0, b, s, &k, &v);
        }
        let q = rng.normal_vec(q_len * h * d, 1.0);
        let mut ws = Workspace::new();
        let mut out = vec![0.0f32; q_len * h * d];
        let (q_tiles, skipped) =
            paged_prefill_attention_into(&cfg, &qcache, 0, &q, q_len, base, &table, &mut ws, &mut out);
        // Visible blocks for rows 16..=19 (query block 4, W=1, sink=1):
        // block 0 (sink) and block 4 (own) — blocks 1..=3 slid out.
        assert_eq!(q_tiles, 2, "invisible tiles must not be dequantized");
        assert_eq!(skipped, 0, "skipping is off");
        assert!(out.iter().all(|x| x.is_finite()));
    }
}
