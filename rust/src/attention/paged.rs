//! Paged decode attention — the native mirror of the Pallas kernel.
//!
//! One query token attends over a sequence whose K/V live in
//! non-contiguous pool blocks (via its block table). The inner loop is
//! block-wise with an *online softmax* (running max + rescaled
//! accumulator), the same schedule the Pallas kernel uses on TPU: each
//! KV block is touched exactly once per *group*, not once per query head
//! — the G× traffic saving the paper's DCU kernel exploits.

use super::alibi::alibi_slopes;
use super::gqa::{AttnConfig, Bias};
use crate::kvcache::{BlockTable, PagedKvCache};

/// Decode attention for one sequence.
///
/// * `q`: `[num_heads * head_dim]` — the current token's query.
/// * `table`: the sequence's block table; `table.len()` keys are visible
///   (the current token's K/V must already be written).
///
/// Returns `[num_heads * head_dim]`.
pub fn paged_decode_attention(
    cfg: &AttnConfig,
    cache: &PagedKvCache,
    layer: usize,
    q: &[f32],
    table: &BlockTable,
) -> Vec<f32> {
    let (h, kvh, d) = (cfg.num_heads, cfg.num_kv_heads, cfg.head_dim);
    assert_eq!(q.len(), h * d);
    assert_eq!(kvh, cache.kv_heads());
    assert_eq!(d, cache.head_dim());
    let g = cfg.group_size();
    let scale = cfg.scale();
    let kv_len = table.len();
    assert!(kv_len > 0, "decode over empty cache");
    let q_pos = kv_len - 1;
    let slopes = match cfg.bias {
        Bias::Alibi => alibi_slopes(h),
        Bias::None => vec![0.0; h],
    };
    let block_size = cache.block_size();

    // Online-softmax state per query head.
    let mut m = vec![f32::NEG_INFINITY; h]; // running max
    let mut l = vec![0.0f32; h]; // running normalizer
    let mut acc = vec![0.0f32; h * d]; // running weighted sum

    // Per-block score buffer (one query head at a time).
    let mut scores = vec![0.0f32; block_size];
    let mut pos = 0usize;
    for &block in table.blocks() {
        if pos >= kv_len {
            break;
        }
        let in_block = block_size.min(kv_len - pos);
        let kb = cache.key_block(layer, block);
        let vb = cache.value_block(layer, block);
        // Process per KV head so each block row is read once per GROUP,
        // with a two-pass block-level online softmax: score the whole
        // block first, then rescale the accumulator ONCE per block
        // (instead of once per slot) before the weighted-value pass.
        for kv_head in 0..kvh {
            for gq in 0..g {
                let head = kv_head * g + gq;
                let q_vec = &q[head * d..(head + 1) * d];
                // Pass 1: scores + block max.
                let mut m_blk = f32::NEG_INFINITY;
                for (slot, s_out) in scores[..in_block].iter_mut().enumerate() {
                    let k_vec = &kb[(slot * kvh + kv_head) * d..(slot * kvh + kv_head + 1) * d];
                    let mut s = crate::tensor::dot(q_vec, k_vec) * scale;
                    if cfg.bias == Bias::Alibi {
                        s -= slopes[head] * (q_pos - (pos + slot)) as f32;
                    }
                    m_blk = m_blk.max(s);
                    *s_out = s;
                }
                // Single rescale to the new running max.
                let m_new = m[head].max(m_blk);
                let corr = (m[head] - m_new).exp();
                m[head] = m_new;
                l[head] *= corr;
                let a = &mut acc[head * d..(head + 1) * d];
                if corr != 1.0 {
                    for av in a.iter_mut() {
                        *av *= corr;
                    }
                }
                // Pass 2: weighted-value accumulation.
                for (slot, &s) in scores[..in_block].iter().enumerate() {
                    let w = (s - m_new).exp();
                    l[head] += w;
                    let v_vec = &vb[(slot * kvh + kv_head) * d..(slot * kvh + kv_head + 1) * d];
                    for (av, &vv) in a.iter_mut().zip(v_vec) {
                        *av += w * vv;
                    }
                }
            }
        }
        pos += in_block;
    }

    // Normalize.
    let mut out = vec![0.0f32; h * d];
    for head in 0..h {
        let inv = 1.0 / l[head];
        for t in 0..d {
            out[head * d + t] = acc[head * d + t] * inv;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::gqa::gqa_attention;
    use crate::kvcache::BlockAllocator;
    use crate::util::rng::Rng;

    /// Build a cache holding `kv_len` random tokens; return (cache, table, k, v).
    fn setup(
        kv_len: usize,
        kvh: usize,
        d: usize,
        block_size: usize,
        seed: u64,
    ) -> (PagedKvCache, BlockTable, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let num_blocks = kv_len.div_ceil(block_size) + 2;
        let mut cache = PagedKvCache::new(1, num_blocks, block_size, kvh, d);
        let mut alloc = BlockAllocator::new(num_blocks, block_size);
        let mut table = BlockTable::new();
        table.reserve(kv_len, &mut alloc);
        let k = rng.normal_vec(kv_len * kvh * d, 1.0);
        let v = rng.normal_vec(kv_len * kvh * d, 1.0);
        for t in 0..kv_len {
            let (b, s) = table.append_slot(block_size);
            cache.write_token(0, b, s, &k[t * kvh * d..(t + 1) * kvh * d], &v[t * kvh * d..(t + 1) * kvh * d]);
        }
        (cache, table, k, v)
    }

    #[test]
    fn matches_contiguous_gqa_reference() {
        for (bias, block_size, kv_len) in
            [(Bias::Alibi, 4, 11), (Bias::None, 8, 16), (Bias::Alibi, 16, 3)]
        {
            let cfg = AttnConfig { num_heads: 4, num_kv_heads: 2, head_dim: 8, bias };
            let (cache, table, k, v) = setup(kv_len, 2, 8, block_size, 42);
            let mut rng = Rng::new(7);
            let q = rng.normal_vec(4 * 8, 1.0);
            let paged = paged_decode_attention(&cfg, &cache, 0, &q, &table);
            let reference = gqa_attention(&cfg, &q, &k, &v, 1, kv_len, kv_len - 1);
            for (a, b) in paged.iter().zip(&reference) {
                assert!((a - b).abs() < 1e-4, "bias={bias:?} bs={block_size} kv={kv_len}");
            }
        }
    }

    #[test]
    fn single_token_cache() {
        let cfg = AttnConfig { num_heads: 2, num_kv_heads: 1, head_dim: 4, bias: Bias::Alibi };
        let (cache, table, _, v) = setup(1, 1, 4, 4, 3);
        let q = vec![0.5; 8];
        let out = paged_decode_attention(&cfg, &cache, 0, &q, &table);
        // Softmax over one key = weight 1 → output equals that V row.
        for head in 0..2 {
            for t in 0..4 {
                assert!((out[head * 4 + t] - v[t]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn online_softmax_is_stable_with_huge_scores() {
        let cfg = AttnConfig { num_heads: 1, num_kv_heads: 1, head_dim: 4, bias: Bias::None };
        let mut cache = PagedKvCache::new(1, 2, 4, 1, 4);
        let mut alloc = BlockAllocator::new(2, 4);
        let mut table = BlockTable::new();
        table.reserve(6, &mut alloc);
        for t in 0..6 {
            let (b, s) = table.append_slot(4);
            // Keys with extreme magnitudes to stress the running max.
            let k = vec![if t % 2 == 0 { 50.0 } else { -50.0 }; 4];
            cache.write_token(0, b, s, &k, &[t as f32; 4]);
        }
        let q = vec![1.0; 4];
        let out = paged_decode_attention(&cfg, &cache, 0, &q, &table);
        assert!(out.iter().all(|v| v.is_finite()));
        // Dominated by even-index (k=+50) values {0,2,4} → mean 2.
        assert!((out[0] - 2.0).abs() < 1e-3, "out={out:?}");
    }

    #[test]
    fn partial_final_block() {
        // kv_len not a multiple of block_size: stale slots in the final
        // block must not contribute.
        let cfg = AttnConfig { num_heads: 2, num_kv_heads: 2, head_dim: 4, bias: Bias::None };
        let (mut cache, table, k, v) = setup(5, 2, 4, 4, 9);
        // Poison the unused slots of the last block.
        let last_block = *table.blocks().last().unwrap();
        for slot in 1..4 {
            cache.write_token(0, last_block, slot, &[999.0; 8], &[999.0; 8]);
        }
        let mut rng = Rng::new(10);
        let q = rng.normal_vec(8, 1.0);
        let out = paged_decode_attention(&cfg, &cache, 0, &q, &table);
        let reference = gqa_attention(&cfg, &q, &k, &v, 1, 5, 4);
        for (a, b) in out.iter().zip(&reference) {
            assert!((a - b).abs() < 1e-4);
        }
    }
}
